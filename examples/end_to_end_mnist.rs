//! End-to-end validation driver (EXPERIMENTS.md §End-to-End).
//!
//! Trains the paper's architecture shrunk to ~1.1M parameters
//! ([784, 512, 512, 512, 512] ≈ 784·512 + 3·512² + heads) for several
//! hundred optimizer steps with the All-Layers PFF scheduler over 4 nodes,
//! on synthetic MNIST-geometry data (real MNIST is used automatically if
//! `data/mnist/` holds the IDX files), logging the loss curve and final
//! accuracy — proving L3 scheduling, the parameter store, negative-sample
//! orchestration and the engine compose end to end.
//!
//! ```bash
//! cargo run --release --example end_to_end_mnist            # native engine
//! cargo run --release --example end_to_end_mnist -- --xla   # AOT artifacts
//! ```
//! (The XLA path needs `make artifacts PROFILES=reduced` and dims
//! [784,256,256,256,256]; it switches automatically.)

use pff::config::{EngineKind, ExperimentConfig, Scheduler};
use pff::coordinator::RunEvent;
use pff::data::DatasetKind;
use pff::ff::{ClassifierMode, NegStrategy};
use pff::metrics::SpanKind;
use pff::Experiment;

fn main() -> anyhow::Result<()> {
    let use_xla = std::env::args().any(|a| a == "--xla");

    let mut cfg = ExperimentConfig::default();
    cfg.name = "end-to-end-mnist".into();
    cfg.dataset = if std::path::Path::new("data/mnist/train-images-idx3-ubyte").exists() {
        DatasetKind::Mnist
    } else {
        DatasetKind::SynthMnist
    };
    cfg.scheduler = Scheduler::AllLayers;
    cfg.neg = NegStrategy::Random; // best accuracy/time at this scale (§5.4)
    cfg.classifier = ClassifierMode::Goodness;
    cfg.nodes = 4;
    cfg.batch = 64;
    if use_xla {
        cfg.engine = EngineKind::Xla;
        cfg.dims = vec![784, 256, 256, 256, 256]; // matches the `reduced` profile
        cfg.train_n = 512;
        cfg.test_n = 128;
        cfg.epochs = 16;
        cfg.splits = 8;
        cfg.eval_chunk = 64;
    } else {
        cfg.dims = vec![784, 512, 512, 512, 512]; // ~1.2M params
        cfg.train_n = 2048;
        cfg.test_n = 512;
        cfg.epochs = 64; // 64 epochs × 32 batches × 4 layers ≈ 8k steps
        cfg.splits = 8;
    }

    let params: usize = cfg
        .dims
        .windows(2)
        .map(|w| w[0] * w[1] + w[1])
        .sum();
    let steps = (cfg.train_n as u32 / cfg.batch as u32) * cfg.epochs * cfg.num_layers() as u32;
    println!(
        "end-to-end: {} params, {} FF train steps, dataset={}, engine={}, {} nodes",
        params,
        steps,
        cfg.dataset,
        if use_xla { "xla" } else { "native" },
        cfg.nodes
    );

    let t0 = std::time::Instant::now();
    let report = Experiment::builder()
        .config(cfg)
        .observer(|ev| {
            if let RunEvent::ChapterFinished { node, chapter, loss, .. } = ev {
                eprintln!("[node {node}] chapter {chapter} finished (loss {loss:.4})");
            }
        })
        .launch()?
        .join()?;
    println!("\n===== RESULT =====");
    println!("{}", report.summary());
    println!("total wall (incl. eval): {:.1}s", t0.elapsed().as_secs_f64());
    println!("\nloss curve (FF layer loss, mean per chapter):\n{}", report.curve.render(16));
    println!("per-node accounting:");
    for n in &report.node_reports {
        println!(
            "  node {}: busy {:.1}s (train {:.1}s, fwd {:.1}s, neg {:.1}s) wait {:.1}s",
            n.node,
            n.busy(),
            n.in_kind(SpanKind::Train),
            n.in_kind(SpanKind::Forward),
            n.in_kind(SpanKind::NegGen),
            n.waiting()
        );
    }
    println!(
        "communication: {} publishes, {:.2} MB total (weights+biases only — the PFF/DFF delta)",
        report.comm.puts,
        report.comm.bytes_put as f64 / 1e6
    );
    let floor = if use_xla { 0.12 } else { 0.45 };
    anyhow::ensure!(
        report.test_accuracy > floor,
        "end-to-end accuracy suspiciously low: {:.1}%",
        report.test_accuracy * 100.0
    );
    println!("\nOK: accuracy {:.2}% — all layers compose.", report.test_accuracy * 100.0);
    Ok(())
}
