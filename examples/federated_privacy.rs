//! Federated PFF (§4.3): four parties train on private shards, exchanging
//! only layer parameters — never data. Demonstrates the privacy scenario
//! from the paper's future-work list and compares against (a) one party
//! training alone on its shard and (b) centralized All-Layers training.
//!
//! ```bash
//! cargo run --release --example federated_privacy
//! ```

use pff::config::{ExperimentConfig, Scheduler};
use pff::ff::NegStrategy;
use pff::Experiment;

/// One blocking run through the session API.
fn run(cfg: ExperimentConfig) -> anyhow::Result<pff::ExperimentReport> {
    Experiment::builder().config(cfg).launch()?.join()
}

fn base() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.dims = vec![784, 128, 128, 128];
    cfg.train_n = 4096; // 1024 per party
    cfg.test_n = 512;
    cfg.epochs = 128;
    cfg.splits = 8;
    cfg.neg = NegStrategy::Random;
    cfg
}

fn main() -> anyhow::Result<()> {
    // (a) one party alone: sequential on a quarter of the data.
    let mut solo = base();
    solo.name = "solo (1/4 data)".into();
    solo.scheduler = Scheduler::Sequential;
    solo.train_n /= 4;
    let solo_rep = run(solo)?;

    // (b) federated: 4 parties, same 4 quarters, parameters exchanged.
    let mut fed = base();
    fed.name = "federated (4 shards)".into();
    fed.scheduler = Scheduler::Federated;
    fed.nodes = 4;
    let fed_rep = run(fed)?;

    // (c) centralized All-Layers with the pooled data (upper bound).
    let mut central = base();
    central.name = "centralized".into();
    central.scheduler = Scheduler::AllLayers;
    central.nodes = 4;
    let central_rep = run(central)?;

    println!("\n===== Federated PFF: accuracy from private shards =====");
    for r in [&solo_rep, &fed_rep, &central_rep] {
        println!("{}", r.summary());
    }
    println!(
        "\nfederated gained {:+.2} pts over training alone (centralized: {:+.2} pts); \
         raw data never left a node — only {:.2} MB of layer parameters moved.",
        (fed_rep.test_accuracy - solo_rep.test_accuracy) * 100.0,
        (central_rep.test_accuracy - solo_rep.test_accuracy) * 100.0,
        fed_rep.comm.bytes_put as f64 / 1e6
    );
    anyhow::ensure!(
        fed_rep.test_accuracy >= solo_rep.test_accuracy - 0.02,
        "federated should not be clearly worse than solo"
    );
    Ok(())
}
