//! Matmul shape-sweep microbenchmark (§Perf tooling): measures the two
//! tensor contractions that dominate every FF step — `x̂·W` and the
//! gradient `x̂ᵀ·dz` — at the reduced and paper shapes.
//!
//! ```bash
//! cargo run --release --example mm_bench
//! ```

use pff::tensor::{ops, Matrix, Rng};
use std::time::Instant;

fn main() {
    let mut rng = Rng::new(1);
    for (b, k, n) in [(128usize, 784usize, 256usize), (128, 2000, 2000), (128, 784, 2000)] {
        let a = Matrix::rand_uniform(b, k, 0.0, 1.0, &mut rng);
        let w = Matrix::rand_uniform(k, n, -0.1, 0.1, &mut rng);
        let dz = Matrix::rand_uniform(b, n, -0.1, 0.1, &mut rng);
        let gf = |t: f64, fl: f64| fl / t / 1e9;
        let reps = 10;
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(ops::matmul(&a, &w));
        }
        let t_mm = t0.elapsed().as_secs_f64() / f64::from(reps);
        let t0 = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(ops::matmul_at_b(&a, &dz));
        }
        let t_at = t0.elapsed().as_secs_f64() / f64::from(reps);
        let fl = 2.0 * b as f64 * k as f64 * n as f64;
        println!(
            "{b}x{k}x{n}: matmul {:.2}ms ({:.1} GF/s)  at_b {:.2}ms ({:.1} GF/s)",
            t_mm * 1e3,
            gf(t_mm, fl),
            t_at * 1e3,
            gf(t_at, fl)
        );
    }
}
