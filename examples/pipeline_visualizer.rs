//! Pipeline visualizer: renders the paper's Figures 1/2/4/5/6 as ASCII
//! Gantt charts from the discrete-event simulator, then sweeps node count
//! to show where All-Layers PFF's speedup saturates.
//!
//! ```bash
//! cargo run --release --example pipeline_visualizer
//! ```

use pff::config::ExperimentConfig;
use pff::ff::NegStrategy;
use pff::harness::figures;
use pff::sim::schedules::{SimParams, SimVariant};
use pff::sim::{build_schedule, simulate, CostModel};

fn main() -> anyhow::Result<()> {
    println!("{}", figures::all_schedule_figures());

    println!("\n===== node-count sweep (All-Layers, AdaptiveNEG, paper scale) =====");
    let cfg = ExperimentConfig::paper_mnist();
    let cm = CostModel::paper_testbed(&cfg);
    let seq = simulate(&build_schedule(
        SimVariant::SequentialFF,
        &cm,
        &SimParams { nodes: 1, neg: NegStrategy::Adaptive, softmax_head: false, perfopt: false },
    ));
    println!("sequential baseline: {:.0}s (paper: 11,190s)", seq.makespan);
    for nodes in [2, 4, 5, 10, 20] {
        if cfg.splits as usize % nodes != 0 {
            continue;
        }
        let p = SimParams { nodes, neg: NegStrategy::Adaptive, softmax_head: false, perfopt: false };
        let r = simulate(&build_schedule(SimVariant::AllLayersPFF, &cm, &p));
        println!(
            "  N = {nodes:<3} makespan {:>8.0}s  speedup {:>5.2}x  utilization {:>5.1}%",
            r.makespan,
            seq.makespan / r.makespan,
            r.utilization() * 100.0
        );
    }
    println!("\n(paper: 3.75x speedup / 94% utilization at N = 4)");
    Ok(())
}
