//! Quickstart: train a small FF network with the All-Layers PFF scheduler
//! on synthetic MNIST-geometry data, following live progress through the
//! experiment session API (`Experiment::builder()` → `RunHandle`).
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pff::config::{ExperimentConfig, Scheduler};
use pff::coordinator::RunEvent;
use pff::ff::NegStrategy;
use pff::Experiment;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::reduced_mnist();
    cfg.name = "quickstart".into();
    cfg.scheduler = Scheduler::AllLayers;
    cfg.nodes = 4;
    cfg.neg = NegStrategy::Random;
    cfg.dims = vec![784, 128, 128, 128, 128];
    cfg.train_n = 1024;
    cfg.test_n = 512;
    cfg.epochs = 64;
    cfg.splits = 8;

    println!(
        "Training a {:?} FF net with {} ({} nodes, {} chapters of {} epoch(s))...",
        cfg.dims,
        cfg.scheduler,
        cfg.nodes,
        cfg.splits,
        cfg.epochs_per_chapter()
    );

    // Observers replace the old `verbose` printing: the library is silent,
    // this callback decides what progress looks like.
    let handle = Experiment::builder()
        .config(cfg)
        .observer(|ev| {
            if let RunEvent::ChapterFinished { node, chapter, loss, .. } = ev {
                eprintln!("  node {node}: chapter {chapter} done (loss {loss:.4})");
            }
        })
        .launch()?;

    // The handle is the live view: events() streams RunEvents (with full
    // replay), cancel() aborts promptly, join() returns the report.
    let report = handle.join()?;
    println!("\n{}", report.summary());
    println!("\ntraining curve:\n{}", report.curve.render(10));
    println!(
        "pipeline model: makespan {:.2}s over {} nodes, utilization {:.1}%",
        report.modeled.modeled_makespan,
        report.node_reports.len(),
        report.modeled.utilization * 100.0
    );
    Ok(())
}
