//! Serve smoke: end-to-end proof that `pff serve` answers the v4
//! CLASSIFY ops with predictions **bitwise identical** to offline eval
//! of the same checkpoint, under concurrent load, and dies cleanly on
//! SIGTERM.
//!
//! This process is the *client* side: it loads the checkpoint itself to
//! compute the offline reference (goodness scoring stacks every class
//! overlay into one tall batch, so labels are row-independent — batch
//! composition on the server cannot change them), spawns a real
//! `pff serve` OS process, fires N concurrent single-row CLASSIFY
//! requests plus one whole-matrix CLASSIFY_BATCH, compares labels, then
//! SIGTERMs the server and checks the shutdown was clean.
//!
//! ```bash
//! cargo build --release
//! cargo run --release --bin pff -- train --dims 784,32,32 --train_n 256 \
//!     --epochs 8 --checkpoint_dir ckpt --checkpoint_every 1
//! cargo run --release --example serve_smoke -- --checkpoint ckpt/latest.ckpt
//! ```

use std::path::PathBuf;
use std::process::Command;
use std::sync::Arc;
use std::time::Duration;

use pff::coordinator::store::MemStore;
use pff::coordinator::{eval, RunCheckpoint};
use pff::engine::factory_for;
use pff::ff::predict_goodness;
use pff::tensor::{Matrix, Rng};
use pff::transport::tcp::TcpStoreClient;

/// Locate the `pff` binary next to this example (`target/<profile>/pff`),
/// overridable via `PFF_BIN`.
fn pff_binary() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("PFF_BIN") {
        let p = PathBuf::from(p);
        return p.exists().then_some(p);
    }
    let exe = std::env::current_exe().ok()?; // target/<profile>/examples/serve_smoke
    let dir = exe.parent()?.parent()?;
    let cand = dir.join(if cfg!(windows) { "pff.exe" } else { "pff" });
    cand.exists().then_some(cand)
}

fn free_port() -> anyhow::Result<u16> {
    let l = std::net::TcpListener::bind(("127.0.0.1", 0))?;
    Ok(l.local_addr()?.port())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut checkpoint = None;
    let mut requests = 64usize;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--checkpoint" => {
                checkpoint = args.get(i + 1).cloned();
                i += 2;
            }
            "--requests" => {
                requests = args.get(i + 1).map(|v| v.parse()).transpose()?.unwrap_or(requests);
                i += 2;
            }
            other => anyhow::bail!("unknown flag {other} (expected --checkpoint, --requests)"),
        }
    }
    let checkpoint =
        checkpoint.ok_or_else(|| anyhow::anyhow!("--checkpoint PATH is required"))?;
    let bin = pff_binary().ok_or_else(|| {
        anyhow::anyhow!("pff binary not found (run `cargo build --release` first, or set PFF_BIN)")
    })?;

    // --- offline reference from the same checkpoint -----------------------
    let ck = RunCheckpoint::load(&checkpoint)?;
    let cfg = ck.experiment_config()?.validated()?;
    let store = MemStore::new();
    store.restore(ck.store.clone());
    let model = eval::assemble(&store, &cfg)?;
    let in_dim = model.net.layers[0].w.rows;
    let x = Matrix::rand_uniform(requests, in_dim, 0.0, 1.0, &mut Rng::new(4242));
    let mut eng = factory_for(cfg.engine, &cfg.artifact_dir)?()?;
    let offline = predict_goodness(eng.as_mut(), &model.net, &x)?;
    println!("[smoke] offline reference: {requests} rows, in_dim {in_dim}");

    // --- real `pff serve` process -----------------------------------------
    let port = free_port()?;
    let addr = format!("127.0.0.1:{port}");
    let mut server = Command::new(&bin)
        .args(["serve", "--checkpoint", &checkpoint, "--addr", &addr])
        .args(["--max-batch", "16", "--max-delay-us", "1000"])
        .spawn()?;
    let sock_addr: std::net::SocketAddr = addr.parse()?;
    let client = {
        let mut tries = 0;
        loop {
            match TcpStoreClient::connect(sock_addr) {
                Ok(c) => break Arc::new(c),
                Err(e) => {
                    tries += 1;
                    anyhow::ensure!(tries < 300, "serve process never came up: {e:#}");
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    };

    // --- N concurrent CLASSIFY requests, one multiplexed connection -------
    let threads = 8.min(requests);
    let handles: Vec<_> = (0..threads)
        .map(|j| {
            let c = client.clone();
            let x = x.clone();
            std::thread::spawn(move || -> anyhow::Result<Vec<(usize, u8)>> {
                let mut got = Vec::new();
                let mut k = j;
                while k < x.rows {
                    let row = x.rows_range(k, k + 1).data;
                    got.push((k, c.classify(&row)?));
                    k += threads;
                }
                Ok(got)
            })
        })
        .collect();
    let mut served = vec![0u8; requests];
    for h in handles {
        for (k, label) in h.join().expect("client thread panicked")? {
            served[k] = label;
        }
    }
    anyhow::ensure!(
        served == offline,
        "served CLASSIFY labels diverge from offline eval (first mismatch at row {:?})",
        served.iter().zip(&offline).position(|(a, b)| a != b)
    );
    println!("[smoke] {requests} concurrent CLASSIFY replies match offline eval bitwise");

    // --- whole-matrix CLASSIFY_BATCH --------------------------------------
    let batch = client.classify_batch(&x)?;
    anyhow::ensure!(batch == offline, "CLASSIFY_BATCH labels diverge from offline eval");
    println!("[smoke] CLASSIFY_BATCH of {requests} rows matches offline eval bitwise");

    // --- clean SIGTERM shutdown -------------------------------------------
    drop(client);
    let pid = server.id().to_string();
    let killed = Command::new("kill").arg(&pid).status()?;
    anyhow::ensure!(killed.success(), "kill -TERM {pid} failed");
    let status = server.wait()?;
    #[cfg(unix)]
    {
        use std::os::unix::process::ExitStatusExt;
        // Exited, 143 from a shell wrapper, or terminated by SIGTERM (15)
        // directly — all count as a prompt, clean death.
        let clean =
            status.success() || status.code() == Some(143) || status.signal() == Some(15);
        anyhow::ensure!(clean, "serve process did not exit cleanly on SIGTERM: {status}");
    }
    #[cfg(not(unix))]
    anyhow::ensure!(status.success(), "serve process did not exit cleanly: {status}");
    println!("[smoke] serve process shut down cleanly on SIGTERM ({status})");
    Ok(())
}
