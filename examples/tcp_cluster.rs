//! TCP cluster demo: the paper's socket deployment, now with real OS
//! processes. The leader (this process) hosts the parameter store on a TCP
//! port and parks until N `pff worker` processes register over the v2
//! protocol, train their chapters, and report DONE. Falls back to
//! in-process worker threads (same wire protocol) when the `pff` binary
//! has not been built yet. Finishes by comparing against the pure
//! in-process transport — the wire must not change what is learned.
//!
//! ```bash
//! cargo build --release                      # builds the pff binary
//! cargo run --release --example tcp_cluster
//! ```

use std::path::PathBuf;
use std::process::Command;
use std::time::Duration;

use pff::config::{ExperimentConfig, Scheduler, TransportKind};
use pff::coordinator::node::run_worker;
use pff::coordinator::{Experiment, ExperimentReport, RunEvent};
use pff::ff::NegStrategy;

/// One blocking run through the session API, printing cluster membership
/// (the default-observer behavior of the `pff` binary).
fn run(cfg: ExperimentConfig) -> anyhow::Result<ExperimentReport> {
    Experiment::builder()
        .config(cfg)
        .observer(|ev| {
            if let RunEvent::WorkersRegistered { .. } = ev {
                eprintln!("[leader] {ev}");
            }
        })
        .launch()?
        .join()
}

/// Locate the `pff` binary next to this example (`target/<profile>/pff`),
/// overridable via `PFF_BIN`.
fn pff_binary() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("PFF_BIN") {
        let p = PathBuf::from(p);
        return p.exists().then_some(p);
    }
    let exe = std::env::current_exe().ok()?; // target/<profile>/examples/tcp_cluster
    let dir = exe.parent()?.parent()?;
    let cand = dir.join(if cfg!(windows) { "pff.exe" } else { "pff" });
    cand.exists().then_some(cand)
}

fn free_port() -> anyhow::Result<u16> {
    let l = std::net::TcpListener::bind(("127.0.0.1", 0))?;
    Ok(l.local_addr()?.port())
}

/// Leader in this process, N workers as real OS processes. The workers
/// receive the leader's FULL config through a `--config` file rendered by
/// `ExperimentConfig::to_kv_string`, so leader and workers cannot drift.
fn run_multiprocess(
    cfg: &ExperimentConfig,
    bin: &std::path::Path,
) -> anyhow::Result<ExperimentReport> {
    let port = free_port()?;
    let addr = format!("127.0.0.1:{port}");
    let cfg_path = std::env::temp_dir().join(format!("pff-cluster-{}.cfg", std::process::id()));
    std::fs::write(&cfg_path, cfg.to_kv_string())?;
    let cfg_path_s = cfg_path.display().to_string();

    let mut children = Vec::new();
    for i in 0..cfg.nodes {
        children.push(
            Command::new(bin)
                .arg("worker")
                .args(["--connect", &addr, "--node-id", &i.to_string(), "--connect-wait-s", "60"])
                .args(["--config", &cfg_path_s])
                .spawn()?,
        );
    }
    let mut lcfg = cfg.clone();
    lcfg.name = "tcp-cluster-multiprocess".into();
    lcfg.cluster = true;
    lcfg.tcp_port = port;
    let report = run(lcfg);
    for mut c in children {
        let status = c.wait()?;
        anyhow::ensure!(status.success(), "worker process exited with {status}");
    }
    std::fs::remove_file(&cfg_path).ok();
    report
}

/// Same cluster protocol, workers as threads (fallback without the binary).
fn run_threaded(cfg: &ExperimentConfig) -> anyhow::Result<ExperimentReport> {
    let port = free_port()?;
    let addr: std::net::SocketAddr = format!("127.0.0.1:{port}").parse()?;
    let mut lcfg = cfg.clone();
    lcfg.name = "tcp-cluster-threads".into();
    lcfg.cluster = true;
    lcfg.tcp_port = port;
    let leader = std::thread::spawn(move || run(lcfg));
    let workers: Vec<_> = (0..cfg.nodes as u32)
        .map(|i| {
            let wcfg = cfg.clone();
            std::thread::spawn(move || run_worker(&wcfg, addr, Some(i), Duration::from_secs(30)))
        })
        .collect();
    for w in workers {
        w.join().expect("worker thread panicked")?;
    }
    leader.join().expect("leader thread panicked")
}

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "tcp-cluster".into();
    cfg.dims = vec![784, 96, 96, 96];
    cfg.train_n = 1024;
    cfg.test_n = 256;
    cfg.epochs = 48;
    cfg.splits = 8;
    cfg.neg = NegStrategy::Random;
    cfg.scheduler = Scheduler::AllLayers;
    cfg.nodes = 2;
    cfg.transport = TransportKind::Tcp;

    // --- cluster run: N OS processes (or threads, without the binary) -----
    let t0 = std::time::Instant::now();
    let (cluster, mode) = match pff_binary() {
        Some(bin) => {
            println!("spawning {} worker process(es) of {}", cfg.nodes, bin.display());
            (run_multiprocess(&cfg, &bin)?, "multi-process")
        }
        None => {
            eprintln!(
                "note: pff binary not found (run `cargo build --release` first, or set \
                 PFF_BIN) — falling back to worker threads over the same TCP protocol"
            );
            (run_threaded(&cfg)?, "threads")
        }
    };
    let cluster_wall = t0.elapsed().as_secs_f64();

    // --- reference: in-process transport ----------------------------------
    let mut mcfg = cfg.clone();
    mcfg.transport = TransportKind::InProc;
    mcfg.name = "inproc".into();
    let t1 = std::time::Instant::now();
    let mem = run(mcfg)?;
    let mem_wall = t1.elapsed().as_secs_f64();

    println!("\n===== transport comparison (same experiment) =====");
    println!("cluster ({mode}): {}", cluster.summary());
    println!("inproc:           {}", mem.summary());
    println!(
        "\nwire traffic: {} puts / {} gets, {:.2} MB published, {:.2} MB fetched",
        cluster.comm.puts,
        cluster.comm.gets,
        cluster.comm.bytes_put as f64 / 1e6,
        cluster.comm.bytes_get as f64 / 1e6
    );
    println!("wall: cluster {cluster_wall:.1}s vs inproc {mem_wall:.1}s (loopback + process overhead)");
    anyhow::ensure!(
        (cluster.test_accuracy - mem.test_accuracy).abs() < 0.02,
        "cluster accuracy must match in-proc within 2% (got {:.1}% vs {:.1}%)",
        cluster.test_accuracy * 100.0,
        mem.test_accuracy * 100.0
    );
    println!("accuracies agree across transports — wire format and cluster mode are faithful.");
    Ok(())
}
