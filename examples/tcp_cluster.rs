//! TCP cluster demo: the paper's socket deployment. The leader hosts the
//! parameter store on a TCP port; node workers connect as real network
//! clients (loopback here; point them at another host in a real cluster).
//! Compares the communication profile against the in-process transport.
//!
//! ```bash
//! cargo run --release --example tcp_cluster
//! ```

use pff::config::{ExperimentConfig, Scheduler, TransportKind};
use pff::coordinator::run_experiment;
use pff::ff::NegStrategy;

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.name = "tcp-cluster".into();
    cfg.dims = vec![784, 96, 96, 96];
    cfg.train_n = 1024;
    cfg.test_n = 256;
    cfg.epochs = 48;
    cfg.splits = 8;
    cfg.neg = NegStrategy::Random;
    cfg.scheduler = Scheduler::AllLayers;
    cfg.nodes = 4;

    cfg.transport = TransportKind::Tcp;
    cfg.tcp_port = 0; // ephemeral
    let t0 = std::time::Instant::now();
    let tcp = run_experiment(&cfg)?;
    let tcp_wall = t0.elapsed().as_secs_f64();

    cfg.transport = TransportKind::InProc;
    cfg.name = "inproc".into();
    let t1 = std::time::Instant::now();
    let mem = run_experiment(&cfg)?;
    let mem_wall = t1.elapsed().as_secs_f64();

    println!("\n===== transport comparison (same experiment) =====");
    println!("tcp:    {}", tcp.summary());
    println!("inproc: {}", mem.summary());
    println!(
        "\nwire traffic: {} puts / {} gets, {:.2} MB published, {:.2} MB fetched",
        tcp.comm.puts,
        tcp.comm.gets,
        tcp.comm.bytes_put as f64 / 1e6,
        tcp.comm.bytes_get as f64 / 1e6
    );
    println!("wall: tcp {tcp_wall:.1}s vs inproc {mem_wall:.1}s (loopback overhead)");
    anyhow::ensure!(
        (tcp.test_accuracy - mem.test_accuracy).abs() < 0.05,
        "transport must not change learning outcomes"
    );
    println!("accuracies agree across transports — wire format is faithful.");
    Ok(())
}
