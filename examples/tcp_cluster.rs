//! TCP cluster demo: the paper's socket deployment, now with real OS
//! processes. The leader (this process) hosts the parameter store on a TCP
//! port and parks until N `pff worker` processes register over the v2
//! protocol, train their chapters, and report DONE. Falls back to
//! in-process worker threads (same wire protocol) when the `pff` binary
//! has not been built yet. Finishes by comparing against the pure
//! in-process transport — the wire must not change what is learned.
//!
//! `--kill-one` exercises crash recovery: one worker is SIGKILLed
//! mid-run, a replacement process adopts its vacated node id through the
//! registry's reconnect lease, fast-forwards past the chapters the store
//! already holds, and the run still reproduces the in-process result
//! (bitwise on the store contents — `ship_opt_state` keeps Adam moments
//! in the published layers, so the replacement resumes exactly).
//!
//! `--elastic` exercises the elastic dispatcher: the leader opens the
//! task graph at `min_workers = 2` (below the 3 logical nodes), a third
//! worker joins mid-run, one of the originals is SIGKILLed WITHOUT a
//! replacement — its task leases are requeued to the survivors — and the
//! run must still complete with the in-process accuracy.
//!
//! `--wire-codec bf16|i8` runs the cluster with quantized publishes
//! (protocol v4 `PUT_LAYER_Q`/`PUT_HEAD_Q` frames) while the in-process
//! reference stays full f32 — the closing accuracy gate then doubles as
//! the lossy codec's accuracy-parity check (tolerance, not bitwise).
//!
//! ```bash
//! cargo build --release                      # builds the pff binary
//! cargo run --release --example tcp_cluster
//! cargo run --release --example tcp_cluster -- --kill-one
//! cargo run --release --example tcp_cluster -- --elastic
//! cargo run --release --example tcp_cluster -- --wire-codec bf16
//! ```

use std::net::SocketAddr;
use std::path::PathBuf;
use std::process::{Child, Command};
use std::time::Duration;

use pff::config::{ExperimentConfig, Scheduler, TransportKind};
use pff::coordinator::node::run_worker;
use pff::coordinator::{Experiment, ExperimentReport, RunEvent};
use pff::ff::NegStrategy;
use pff::transport::codec::WireCodec;
use pff::transport::tcp::TcpStoreClient;

/// One blocking run through the session API, printing cluster membership
/// (the default-observer behavior of the `pff` binary).
fn run(cfg: ExperimentConfig) -> anyhow::Result<ExperimentReport> {
    Experiment::builder()
        .config(cfg)
        .observer(|ev| {
            if let RunEvent::WorkersRegistered { .. } = ev {
                eprintln!("[leader] {ev}");
            }
        })
        .launch()?
        .join()
}

/// Locate the `pff` binary next to this example (`target/<profile>/pff`),
/// overridable via `PFF_BIN`.
fn pff_binary() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("PFF_BIN") {
        let p = PathBuf::from(p);
        return p.exists().then_some(p);
    }
    let exe = std::env::current_exe().ok()?; // target/<profile>/examples/tcp_cluster
    let dir = exe.parent()?.parent()?;
    let cand = dir.join(if cfg!(windows) { "pff.exe" } else { "pff" });
    cand.exists().then_some(cand)
}

fn free_port() -> anyhow::Result<u16> {
    let l = std::net::TcpListener::bind(("127.0.0.1", 0))?;
    Ok(l.local_addr()?.port())
}

/// Spawn one `pff worker` process against the leader at `addr`.
fn spawn_worker(
    bin: &std::path::Path,
    addr: &str,
    cfg_path: &str,
    node_id: usize,
) -> anyhow::Result<Child> {
    Ok(Command::new(bin)
        .arg("worker")
        .args(["--connect", addr, "--node-id", &node_id.to_string(), "--connect-wait-s", "60"])
        .args(["--config", cfg_path])
        .spawn()?)
}

/// Leader in this process, N workers as real OS processes. The workers
/// receive the leader's FULL config through a `--config` file rendered by
/// `ExperimentConfig::to_kv_string`, so leader and workers cannot drift.
///
/// With `kill_one`, worker 0 is SIGKILLed once the pipeline is provably
/// mid-run (chapter 1's layer 0 published), and a replacement process
/// adopts the vacated node id — the crash-recovery path end to end.
fn run_multiprocess(
    cfg: &ExperimentConfig,
    bin: &std::path::Path,
    kill_one: bool,
) -> anyhow::Result<ExperimentReport> {
    let port = free_port()?;
    let addr = format!("127.0.0.1:{port}");
    let sock_addr: SocketAddr = addr.parse()?;
    let cfg_path = std::env::temp_dir().join(format!("pff-cluster-{}.cfg", std::process::id()));
    std::fs::write(&cfg_path, cfg.to_kv_string())?;
    let cfg_path_s = cfg_path.display().to_string();

    let mut children = Vec::new();
    for i in 0..cfg.nodes {
        children.push(spawn_worker(bin, &addr, &cfg_path_s, i)?);
    }

    // Chaos thread: wait until the run is provably underway, then SIGKILL
    // worker 0 and spawn its replacement. Runs alongside the parked leader.
    let chaos = if kill_one {
        let mut victim = children.remove(0);
        let bin = bin.to_path_buf();
        let (addr2, cfg_path2) = (addr.clone(), cfg_path_s.clone());
        Some(std::thread::spawn(move || -> anyhow::Result<Child> {
            // The leader binds its port inside run(); retry until it is up.
            let observer = {
                let mut tries = 0;
                loop {
                    match TcpStoreClient::connect(sock_addr) {
                        Ok(c) => break c,
                        Err(e) => {
                            tries += 1;
                            anyhow::ensure!(tries < 300, "leader never came up: {e:#}");
                            std::thread::sleep(Duration::from_millis(100));
                        }
                    }
                }
            };
            // Chapter 1's layer 0 published ⇒ the pipeline is mid-run.
            observer.get_layer(0, 1, Duration::from_secs(120))?;
            victim.kill()?; // SIGKILL on unix
            let status = victim.wait()?;
            anyhow::ensure!(!status.success(), "victim was supposed to die mid-run");
            println!("[chaos] SIGKILLed worker 0 ({status}); waiting for the vacancy");
            // Spawn the replacement only once the leader has processed the
            // dead socket and vacated node 0 — a HELLO for a still-registered
            // id would be refused outright (HELLO rejections do not retry).
            let deadline = std::time::Instant::now() + Duration::from_secs(30);
            while observer.list_nodes()?.iter().any(|n| n.id == 0) {
                anyhow::ensure!(
                    std::time::Instant::now() < deadline,
                    "leader never vacated node 0 after the SIGKILL"
                );
                std::thread::sleep(Duration::from_millis(50));
            }
            println!("[chaos] node 0 vacated; spawning replacement");
            spawn_worker(&bin, &addr2, &cfg_path2, 0)
        }))
    } else {
        None
    };

    let mut lcfg = cfg.clone();
    lcfg.name = "tcp-cluster-multiprocess".into();
    lcfg.cluster = true;
    lcfg.tcp_port = port;
    let report = run(lcfg);
    if let Some(h) = chaos {
        let mut replacement = h.join().expect("chaos thread panicked")?;
        let status = replacement.wait()?;
        anyhow::ensure!(status.success(), "replacement worker exited with {status}");
        println!("[chaos] replacement worker 0 finished cleanly");
    }
    for mut c in children {
        let status = c.wait()?;
        anyhow::ensure!(status.success(), "worker process exited with {status}");
    }
    std::fs::remove_file(&cfg_path).ok();
    report
}

/// Elastic membership end to end: the leader admits the run at
/// `min_workers = 2` (of 3 logical nodes — worker affinity buckets are
/// re-bucketed over whoever is registered), a third worker process joins
/// once the pipeline is provably mid-run, and then one of the original
/// workers is SIGKILLed with NO replacement. The dispatcher requeues the
/// victim's open task leases to the survivors, the registry settles the
/// vacancy after the graph drains, and the leader completes normally.
fn run_elastic(cfg: &ExperimentConfig, bin: &std::path::Path) -> anyhow::Result<ExperimentReport> {
    let port = free_port()?;
    let addr = format!("127.0.0.1:{port}");
    let sock_addr: SocketAddr = addr.parse()?;
    let cfg_path = std::env::temp_dir().join(format!("pff-elastic-{}.cfg", std::process::id()));
    std::fs::write(&cfg_path, cfg.to_kv_string())?;
    let cfg_path_s = cfg_path.display().to_string();

    // Only 2 of the 3 logical nodes' worth of workers at admission time.
    let mut victim = spawn_worker(bin, &addr, &cfg_path_s, 0)?;
    let mut survivor = spawn_worker(bin, &addr, &cfg_path_s, 1)?;

    // Chaos thread, alongside the parked leader: grow the pool mid-run,
    // then shrink it by SIGKILL. Owns the victim so the kill and its
    // status check happen in one place; hands the late joiner back.
    let chaos = {
        let bin = bin.to_path_buf();
        let (addr2, cfg_path2) = (addr.clone(), cfg_path_s.clone());
        std::thread::spawn(move || -> anyhow::Result<Child> {
            let observer = {
                let mut tries = 0;
                loop {
                    match TcpStoreClient::connect(sock_addr) {
                        Ok(c) => break c,
                        Err(e) => {
                            tries += 1;
                            anyhow::ensure!(tries < 300, "leader never came up: {e:#}");
                            std::thread::sleep(Duration::from_millis(100));
                        }
                    }
                }
            };
            // Chapter 1's layer 0 published ⇒ the graph opened with only
            // two workers and is mid-run. NOW grow the pool.
            observer.get_layer(0, 1, Duration::from_secs(120))?;
            println!("[chaos] pipeline is mid-run; joining a third worker");
            let late = spawn_worker(&bin, &addr2, &cfg_path2, 2)?;
            let deadline = std::time::Instant::now() + Duration::from_secs(30);
            while observer.list_nodes()?.len() < 3 {
                anyhow::ensure!(
                    std::time::Instant::now() < deadline,
                    "third worker never registered with the leader"
                );
                std::thread::sleep(Duration::from_millis(50));
            }
            println!("[chaos] third worker registered; SIGKILLing worker 0 (no replacement)");
            victim.kill()?; // SIGKILL on unix; leases requeue to the survivors
            let vstatus = victim.wait()?;
            anyhow::ensure!(!vstatus.success(), "victim was supposed to die mid-run: {vstatus}");
            Ok(late)
        })
    };

    let mut lcfg = cfg.clone();
    lcfg.name = "tcp-cluster-elastic".into();
    lcfg.cluster = true;
    lcfg.tcp_port = port;
    lcfg.min_workers = 2;
    let report = run(lcfg)?;
    let mut late = chaos.join().expect("chaos thread panicked")?;
    for (name, c) in [("survivor", &mut survivor), ("late-joiner", &mut late)] {
        let status = c.wait()?;
        anyhow::ensure!(status.success(), "{name} worker exited with {status}");
    }
    std::fs::remove_file(&cfg_path).ok();
    report
}

/// Same cluster protocol, workers as threads (fallback without the binary).
fn run_threaded(cfg: &ExperimentConfig) -> anyhow::Result<ExperimentReport> {
    let port = free_port()?;
    let addr: std::net::SocketAddr = format!("127.0.0.1:{port}").parse()?;
    let mut lcfg = cfg.clone();
    lcfg.name = "tcp-cluster-threads".into();
    lcfg.cluster = true;
    lcfg.tcp_port = port;
    let leader = std::thread::spawn(move || run(lcfg));
    let workers: Vec<_> = (0..cfg.nodes as u32)
        .map(|i| {
            let wcfg = cfg.clone();
            std::thread::spawn(move || run_worker(&wcfg, addr, Some(i), Duration::from_secs(30)))
        })
        .collect();
    for w in workers {
        w.join().expect("worker thread panicked")?;
    }
    leader.join().expect("leader thread panicked")
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let kill_one = args.iter().any(|a| a == "--kill-one");
    let elastic = args.iter().any(|a| a == "--elastic");
    anyhow::ensure!(!(kill_one && elastic), "--kill-one and --elastic are mutually exclusive");
    let wire_codec: WireCodec = match args.iter().position(|a| a == "--wire-codec") {
        Some(i) => args
            .get(i + 1)
            .ok_or_else(|| anyhow::anyhow!("--wire-codec needs a value (f32, bf16 or i8)"))?
            .parse()?,
        None => WireCodec::F32,
    };
    let mut cfg = ExperimentConfig::default();
    cfg.name = "tcp-cluster".into();
    cfg.dims = vec![784, 96, 96, 96];
    cfg.train_n = 1024;
    cfg.test_n = 256;
    cfg.epochs = 48;
    cfg.splits = 8;
    cfg.neg = NegStrategy::Random;
    cfg.scheduler = Scheduler::AllLayers;
    cfg.nodes = if elastic { 3 } else { 2 };
    cfg.transport = TransportKind::Tcp;
    // Adam moments travel with the published layers, so a replacement
    // worker resumes the crashed node's optimizer state exactly — the
    // crash-recovery run reproduces the in-proc weights bitwise. (It also
    // licenses cross-worker task stealing in the elastic run.)
    cfg.ship_opt_state = true;
    cfg.wire_codec = wire_codec;
    if wire_codec != WireCodec::F32 {
        println!("cluster publishes ride the {wire_codec} wire codec; reference stays f32");
    }

    // --- cluster run: N OS processes (or threads, without the binary) -----
    let t0 = std::time::Instant::now();
    let (cluster, mode) = match pff_binary() {
        Some(bin) if elastic => {
            println!("elastic run: 2 workers at admission, 1 late joiner, 1 SIGKILL");
            (run_elastic(&cfg, &bin)?, "multi-process, elastic")
        }
        Some(bin) => {
            println!("spawning {} worker process(es) of {}", cfg.nodes, bin.display());
            let mode = if kill_one { "multi-process, kill-one" } else { "multi-process" };
            (run_multiprocess(&cfg, &bin, kill_one)?, mode)
        }
        None if kill_one || elastic => anyhow::bail!(
            "--kill-one/--elastic need the pff binary (run `cargo build --release` first, \
             or set PFF_BIN)"
        ),
        None => {
            eprintln!(
                "note: pff binary not found (run `cargo build --release` first, or set \
                 PFF_BIN) — falling back to worker threads over the same TCP protocol"
            );
            (run_threaded(&cfg)?, "threads")
        }
    };
    let cluster_wall = t0.elapsed().as_secs_f64();

    // --- reference: in-process transport ----------------------------------
    let mut mcfg = cfg.clone();
    mcfg.transport = TransportKind::InProc;
    // The reference always trains in full f32, so with --wire-codec the
    // closing accuracy gate doubles as the lossy codec's parity check.
    mcfg.wire_codec = WireCodec::F32;
    mcfg.name = "inproc".into();
    let t1 = std::time::Instant::now();
    let mem = run(mcfg)?;
    let mem_wall = t1.elapsed().as_secs_f64();

    println!("\n===== transport comparison (same experiment) =====");
    println!("cluster ({mode}): {}", cluster.summary());
    println!("inproc:           {}", mem.summary());
    println!(
        "\nwire traffic: {} puts / {} gets, {:.2} MB published, {:.2} MB fetched",
        cluster.comm.puts,
        cluster.comm.gets,
        cluster.comm.bytes_put as f64 / 1e6,
        cluster.comm.bytes_get as f64 / 1e6
    );
    println!("wall: cluster {cluster_wall:.1}s vs inproc {mem_wall:.1}s (loopback + process overhead)");
    anyhow::ensure!(
        (cluster.test_accuracy - mem.test_accuracy).abs() < 0.02,
        "cluster accuracy must match in-proc within 2% (got {:.1}% vs {:.1}%)",
        cluster.test_accuracy * 100.0,
        mem.test_accuracy * 100.0
    );
    println!("accuracies agree across transports — wire format and cluster mode are faithful.");
    Ok(())
}
