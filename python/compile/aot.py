"""AOT exporter: lower the L2 step functions to HLO **text** artifacts.

Interchange is HLO text, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage::

    python -m compile.aot --out ../artifacts [--profile quick,reduced]

Profiles pick the (dims, batch) grid the Rust engine will request; each
(op, din, dout, batch, norm) combination becomes one ``*.hlo.txt`` plus a
line in ``manifest.txt``.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True so the
    Rust side always unpacks a tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    """f32 ShapeDtypeStruct helper."""
    return jax.ShapeDtypeStruct(shape, F32)


def lower_layer_fwd(din, dout, b, norm):
    fn = functools.partial(model.layer_fwd.__wrapped__, normalize=norm)
    return jax.jit(fn).lower(spec(din, dout), spec(dout), spec(b, din))


def lower_head_logits(din, classes, b):
    return jax.jit(model.head_logits.__wrapped__).lower(
        spec(din, classes), spec(classes), spec(b, din)
    )


def lower_ff_step(din, dout, b, norm):
    fn = functools.partial(model.ff_step.__wrapped__, normalize=norm)
    return jax.jit(fn).lower(
        spec(din, dout), spec(dout),                  # w, b
        spec(din, dout), spec(din, dout),             # m_w, v_w
        spec(dout), spec(dout),                       # m_b, v_b
        spec(),                                       # t
        spec(b, din), spec(b, din),                   # x_pos, x_neg
        spec(b),                                      # mask
        spec(), spec(),                               # theta, lr
    )


def lower_head_step(din, classes, b):
    return jax.jit(model.head_step.__wrapped__).lower(
        spec(din, classes), spec(classes),
        spec(din, classes), spec(din, classes),
        spec(classes), spec(classes),
        spec(),
        spec(b, din), spec(b, classes), spec(b),
        spec(),
    )


def lower_perfopt_step(din, dout, classes, b, norm):
    fn = functools.partial(model.perfopt_step.__wrapped__, normalize=norm)
    return jax.jit(fn).lower(
        spec(din, dout), spec(dout),                  # lw, lb
        spec(dout, classes), spec(classes),           # hw, hb
        spec(din, dout), spec(din, dout), spec(dout), spec(dout),          # layer opt
        spec(dout, classes), spec(dout, classes), spec(classes), spec(classes),  # head opt
        spec(),
        spec(b, din), spec(b, classes), spec(b),
        spec(),
    )


# ---------------------------------------------------------------------------
# Profiles: the (dims, batch, eval-batch) grids the rust configs use.
# ---------------------------------------------------------------------------

PROFILES = {
    # tiny dims for fast integration tests (rust/tests/xla_vs_native.rs)
    "test": {"dims": [784, 32, 32, 32], "batch": 16, "classes": 10},
    # harness Scale::quick()
    "quick": {"dims": [784, 64, 64, 64, 64], "batch": 64, "classes": 10},
    # harness Scale::reduced() / ExperimentConfig::default()
    "reduced": {"dims": [784, 256, 256, 256, 256], "batch": 64, "classes": 10},
    # the paper's full architecture (§5.1)
    "paper": {"dims": [784, 2000, 2000, 2000, 2000], "batch": 64, "classes": 10},
}


def profile_modules(prof):
    """Yield (op, din, dout, batch, norm, lower_fn) for one profile."""
    dims, batch, classes = prof["dims"], prof["batch"], prof["classes"]
    seen = set()
    for i in range(len(dims) - 1):
        din, dout, norm = dims[i], dims[i + 1], i > 0
        key = (din, dout, norm)
        if key in seen:
            continue
        seen.add(key)
        yield ("layer_fwd", din, dout, batch, norm,
               lambda a=din, o=dout, n=norm: lower_layer_fwd(a, o, batch, n))
        yield ("ff_step", din, dout, batch, norm,
               lambda a=din, o=dout, n=norm: lower_ff_step(a, o, batch, n))
        yield ("perfopt_step", din, dout, batch, norm,
               lambda a=din, o=dout, n=norm: lower_perfopt_step(a, o, classes, batch, n))
        # per-layer head (PerfOpt prediction path)
        hkey = ("hl", dout)
        if hkey not in seen:
            seen.add(hkey)
            yield ("head_logits", dout, classes, batch, False,
                   lambda a=dout: lower_head_logits(a, classes, batch))
    # full-network softmax head: features = all-but-first activations
    head_din = sum(dims[2:])
    yield ("head_logits", head_din, classes, batch, False,
           lambda: lower_head_logits(head_din, classes, batch))
    yield ("head_step", head_din, classes, batch, False,
           lambda: lower_head_step(head_din, classes, batch))


def build(out_dir: str, profiles) -> list:
    """Lower every module of the given profiles into ``out_dir``;
    returns manifest lines."""
    os.makedirs(out_dir, exist_ok=True)
    lines = []
    done = set()
    for name in profiles:
        prof = PROFILES[name]
        for op, din, dout, batch, norm, lower in profile_modules(prof):
            key = (op, din, dout, batch, norm)
            if key in done:
                continue
            done.add(key)
            tag = "norm" if norm else "raw"
            fname = f"{op}_{din}x{dout}_b{batch}_{tag}.hlo.txt"
            path = os.path.join(out_dir, fname)
            text = to_hlo_text(lower())
            with open(path, "w") as f:
                f.write(text)
            lines.append(
                f"op={op} din={din} dout={dout} b={batch} norm={int(norm)} file={fname}"
            )
            print(f"  wrote {fname} ({len(text) // 1024} KiB)")
    return lines


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument(
        "--profile",
        default="test,quick,reduced",
        help=f"comma-separated profiles from {sorted(PROFILES)}",
    )
    args = ap.parse_args()
    profiles = [p.strip() for p in args.profile.split(",") if p.strip()]
    for p in profiles:
        if p not in PROFILES:
            raise SystemExit(f"unknown profile '{p}' (have {sorted(PROFILES)})")
    print(f"lowering profiles {profiles} -> {args.out}")
    lines = build(args.out, profiles)
    manifest = os.path.join(args.out, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("# generated by python -m compile.aot — do not edit\n")
        f.write("\n".join(lines) + "\n")
    print(f"wrote {manifest} ({len(lines)} modules)")


if __name__ == "__main__":
    main()
