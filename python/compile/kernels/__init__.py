"""L1 Pallas kernels (``ff_layer``) and their pure-jnp oracle (``ref``)."""
