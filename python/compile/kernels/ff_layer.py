"""L1 — Pallas kernels for the FF compute hot-spot.

Six kernels cover everything the train/predict steps need:

=================  =========================================================
``normalize``      row-wise length normalization (Hinton's inter-layer rule)
``linear_fwd``     fused x @ W + b (+ optional ReLU) — the MXU workhorse
``rowsumsq``       per-row goodness reduction, fused over column tiles
``matmul_at_b``    gradient contraction dW = xᵀ·dz
``colsum``         bias gradient
``adam``           fused elementwise Adam update
=================  =========================================================

TPU mapping (DESIGN.md §Hardware-Adaptation): ``linear_fwd`` tiles
(B_tile × dout_tile) output panes with the full K dimension resident —
W panes stream HBM→VMEM once per grid column and x row-panes once per
grid row; goodness is fused per-pane so ``y`` never round-trips. On this
CPU image every ``pallas_call`` uses ``interpret=True`` (real-TPU lowering
emits Mosaic custom-calls the CPU PJRT client cannot execute); the
lowered HLO is therefore plain XLA ops and runs anywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.ref import ADAM_B1, ADAM_B2, ADAM_EPS, EPS

# Preferred tile edges (MXU-friendly); shrunk to fit small dims.
PREF_ROW_TILE = 64
PREF_COL_TILE = 256


def _tile(n: int, pref: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``pref`` (grids need exact
    tiling; favors the MXU-sized tile when dims allow)."""
    if n <= pref:
        return n
    for cand in range(pref, 0, -1):
        if n % cand == 0:
            return cand
    return n


def normalize(x):
    """Row-normalize ``x`` with a row-tiled Pallas kernel."""
    bsz, din = x.shape
    bt = _tile(bsz, PREF_ROW_TILE)

    def kernel(x_ref, o_ref):
        xv = x_ref[...]
        norm = jnp.sqrt(jnp.sum(xv * xv, axis=1, keepdims=True))
        o_ref[...] = xv / (norm + EPS)

    return pl.pallas_call(
        kernel,
        grid=(bsz // bt,),
        in_specs=[pl.BlockSpec((bt, din), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bt, din), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, din), x.dtype),
        interpret=True,
    )(x)


def linear_fwd(w, b, x, relu: bool):
    """Fused ``x @ w + b`` (+ ReLU) over (row, col)-tiled output panes.

    The K dimension stays whole per pane: on TPU that makes W's
    (din × col_tile) pane the VMEM-resident operand while x rows stream —
    the schedule the paper's one-layer-per-node placement implies.
    """
    bsz, din = x.shape
    dout = w.shape[1]
    bt = _tile(bsz, PREF_ROW_TILE)
    nt = _tile(dout, PREF_COL_TILE)

    def kernel(x_ref, w_ref, b_ref, o_ref):
        z = jnp.dot(x_ref[...], w_ref[...]) + b_ref[...]
        o_ref[...] = jnp.maximum(z, 0.0) if relu else z

    return pl.pallas_call(
        kernel,
        grid=(bsz // bt, dout // nt),
        in_specs=[
            pl.BlockSpec((bt, din), lambda i, j: (i, 0)),
            pl.BlockSpec((din, nt), lambda i, j: (0, j)),
            pl.BlockSpec((nt,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bt, nt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, dout), x.dtype),
        interpret=True,
    )(x, w, b)


def rowsumsq(y):
    """Goodness reduction: per-row sum of squares, accumulated across
    column tiles (keeps each pane in VMEM once)."""
    bsz, dout = y.shape
    bt = _tile(bsz, PREF_ROW_TILE)
    nt = _tile(dout, PREF_COL_TILE)
    ncols = dout // nt

    def kernel(y_ref, o_ref):
        j = pl.program_id(1)
        part = jnp.sum(y_ref[...] * y_ref[...], axis=1)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = part

        @pl.when(j != 0)
        def _acc():
            o_ref[...] += part

    return pl.pallas_call(
        kernel,
        grid=(bsz // bt, ncols),
        in_specs=[pl.BlockSpec((bt, nt), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bt,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((bsz,), y.dtype),
        interpret=True,
    )(y)


def matmul_at_b(a, dz):
    """Gradient contraction ``dW = aᵀ @ dz`` over (din, dout) tiles."""
    bsz, din = a.shape
    dout = dz.shape[1]
    it = _tile(din, PREF_COL_TILE)
    jt = _tile(dout, PREF_COL_TILE)

    def kernel(a_ref, dz_ref, o_ref):
        o_ref[...] = jnp.dot(a_ref[...].T, dz_ref[...])

    return pl.pallas_call(
        kernel,
        grid=(din // it, dout // jt),
        in_specs=[
            pl.BlockSpec((bsz, it), lambda i, j: (0, i)),
            pl.BlockSpec((bsz, jt), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((it, jt), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((din, dout), a.dtype),
        interpret=True,
    )(a, dz)


def colsum(dz):
    """Bias gradient: column sums over column tiles."""
    bsz, dout = dz.shape
    jt = _tile(dout, PREF_COL_TILE)

    def kernel(dz_ref, o_ref):
        o_ref[...] = jnp.sum(dz_ref[...], axis=0)

    return pl.pallas_call(
        kernel,
        grid=(dout // jt,),
        in_specs=[pl.BlockSpec((bsz, jt), lambda j: (0, j))],
        out_specs=pl.BlockSpec((jt,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dout,), dz.dtype),
        interpret=True,
    )(dz)


def adam(p, m, v, g, t, lr):
    """Fused elementwise Adam update; works on any-rank params by
    flattening to 1-D tiles (the VPU-side kernel)."""
    shape = p.shape
    flat = int(jnp.size(p))
    pt = _tile(flat, 4096)
    p1, m1, v1, g1 = (a.reshape((flat,)) for a in (p, m, v, g))

    def kernel(p_ref, m_ref, v_ref, g_ref, t_ref, lr_ref, po_ref, mo_ref, vo_ref):
        gv = g_ref[...]
        m2 = ADAM_B1 * m_ref[...] + (1.0 - ADAM_B1) * gv
        v2 = ADAM_B2 * v_ref[...] + (1.0 - ADAM_B2) * gv * gv
        tv = t_ref[0]
        alpha = lr_ref[0] * jnp.sqrt(1.0 - ADAM_B2**tv) / (1.0 - ADAM_B1**tv)
        po_ref[...] = p_ref[...] - alpha * m2 / (jnp.sqrt(v2) + ADAM_EPS)
        mo_ref[...] = m2
        vo_ref[...] = v2

    t1 = jnp.reshape(t, (1,)).astype(p.dtype)
    lr1 = jnp.reshape(lr, (1,)).astype(p.dtype)
    outs = pl.pallas_call(
        kernel,
        grid=(flat // pt,),
        in_specs=[
            pl.BlockSpec((pt,), lambda i: (i,)),
            pl.BlockSpec((pt,), lambda i: (i,)),
            pl.BlockSpec((pt,), lambda i: (i,)),
            pl.BlockSpec((pt,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((pt,), lambda i: (i,)),
            pl.BlockSpec((pt,), lambda i: (i,)),
            pl.BlockSpec((pt,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((flat,), p.dtype)] * 3,
        interpret=True,
    )(p1, m1, v1, g1, t1, lr1)
    return tuple(o.reshape(shape) for o in outs)


@functools.partial(jax.jit, static_argnames=("normalize_input", "relu"))
def layer_fwd(w, b, x, normalize_input: bool, relu: bool = True):
    """Composite forward built from the kernels (normalize → linear)."""
    xn = normalize(x) if normalize_input else x
    return linear_fwd(w, b, xn, relu=relu)
