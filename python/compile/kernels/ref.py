"""Pure-jnp oracle for the Pallas kernels and the L2 step functions.

Every kernel in ``ff_layer.py`` has a reference twin here; pytest pins the
two against each other (``python/tests/test_kernel.py``), and the Rust
NativeEngine implements exactly the same math — so all three layers of the
stack agree numerically.
"""

import jax.numpy as jnp

EPS = 1e-8  # length-normalization fuzz — keep in sync with rust NORM_EPS

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def normalize_rows(x):
    """Row-wise length normalization x / (||x||_2 + EPS)."""
    norm = jnp.sqrt(jnp.sum(x * x, axis=1, keepdims=True))
    return x / (norm + EPS)


def linear_fwd(w, b, x, relu):
    """z = x @ w + b, optionally ReLU'd."""
    z = x @ w + b
    return jnp.maximum(z, 0.0) if relu else z


def layer_fwd(w, b, x, normalize):
    """FF layer forward: relu((normalize(x)) @ w + b)."""
    xn = normalize_rows(x) if normalize else x
    return linear_fwd(w, b, xn, relu=True)


def rowsumsq(y):
    """Per-row goodness g_i = sum_j y_ij^2 (paper Eq. 1's inner sum)."""
    return jnp.sum(y * y, axis=1)


def matmul_at_b(a, dz):
    """Gradient contraction dW = a^T @ dz."""
    return a.T @ dz


def colsum(dz):
    """Bias gradient db = sum over rows."""
    return jnp.sum(dz, axis=0)


def adam_update(p, m, v, g, t, lr):
    """One fused Adam step (bias corrections folded into the step size)."""
    m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    alpha = lr * jnp.sqrt(1.0 - ADAM_B2**t) / (1.0 - ADAM_B1**t)
    p2 = p - alpha * m2 / (jnp.sqrt(v2) + ADAM_EPS)
    return p2, m2, v2


def softplus(x):
    """Numerically-stable ln(1 + e^x)."""
    return jnp.logaddexp(x, 0.0)


def sigmoid(x):
    """Logistic function."""
    return 1.0 / (1.0 + jnp.exp(-x))


# ---------------------------------------------------------------------------
# Whole-step references (mirror python/compile/model.py, used by
# tests/test_model.py to validate the jitted/AOT'd step functions).
# ---------------------------------------------------------------------------


def ff_step_ref(w, b, m_w, v_w, m_b, v_b, t, x_pos, x_neg, mask, theta, lr, normalize):
    """Reference FF train step. Returns the same 10-tuple as the artifact."""
    xp = normalize_rows(x_pos) if normalize else x_pos
    xn = normalize_rows(x_neg) if normalize else x_neg
    x = jnp.concatenate([xp, xn], axis=0)
    y = linear_fwd(w, b, x, relu=True)
    d_out = y.shape[1]
    g = rowsumsq(y) / d_out  # MEAN of squares — see rust engine::native
    bsz = x_pos.shape[0]
    g_pos, g_neg = g[:bsz], g[bsz:]
    count = jnp.maximum(jnp.sum(mask), 1.0)
    loss_pos = jnp.sum(mask * softplus(theta - g_pos)) / count
    loss_neg = jnp.sum(mask * softplus(g_neg - theta)) / count
    gm_pos = jnp.sum(mask * g_pos) / count
    gm_neg = jnp.sum(mask * g_neg) / count
    coef_pos = -sigmoid(theta - g_pos) * mask
    coef_neg = sigmoid(g_neg - theta) * mask
    coef = jnp.concatenate([coef_pos, coef_neg], axis=0)
    dz = coef[:, None] * 2.0 * y / (2.0 * count * d_out)
    dw = matmul_at_b(x, dz)
    db = colsum(dz)
    w2, m_w2, v_w2 = adam_update(w, m_w, v_w, dw, t, lr)
    b2, m_b2, v_b2 = adam_update(b, m_b, v_b, db, t, lr)
    return w2, b2, m_w2, v_w2, m_b2, v_b2, loss_pos, loss_neg, gm_pos, gm_neg


def head_step_ref(w, b, m_w, v_w, m_b, v_b, t, x, onehot, mask, lr):
    """Reference softmax-head CE step. Returns the same 7-tuple."""
    logits = linear_fwd(w, b, x, relu=False)
    zmax = jnp.max(logits, axis=1, keepdims=True)
    ez = jnp.exp(logits - zmax)
    p = ez / jnp.sum(ez, axis=1, keepdims=True)
    count = jnp.maximum(jnp.sum(mask), 1.0)
    logp = jnp.log(jnp.maximum(jnp.sum(p * onehot, axis=1), 1e-12))
    loss = -jnp.sum(mask * logp) / count
    dlogits = (p - onehot) * (mask / count)[:, None]
    dw = matmul_at_b(x, dlogits)
    db = colsum(dlogits)
    w2, m_w2, v_w2 = adam_update(w, m_w, v_w, dw, t, lr)
    b2, m_b2, v_b2 = adam_update(b, m_b, v_b, db, t, lr)
    return w2, b2, m_w2, v_w2, m_b2, v_b2, loss


def perfopt_step_ref(
    lw, lb, hw, hb,
    lm_w, lv_w, lm_b, lv_b,
    hm_w, hv_w, hm_b, hv_b,
    t, x, onehot, mask, lr, normalize,
):
    """Reference Performance-Optimized (layer+head local BP) step."""
    xn = normalize_rows(x) if normalize else x
    y = linear_fwd(lw, lb, xn, relu=True)
    logits = linear_fwd(hw, hb, y, relu=False)
    zmax = jnp.max(logits, axis=1, keepdims=True)
    ez = jnp.exp(logits - zmax)
    p = ez / jnp.sum(ez, axis=1, keepdims=True)
    count = jnp.maximum(jnp.sum(mask), 1.0)
    logp = jnp.log(jnp.maximum(jnp.sum(p * onehot, axis=1), 1e-12))
    loss = -jnp.sum(mask * logp) / count
    dlogits = (p - onehot) * (mask / count)[:, None]
    dhw = matmul_at_b(y, dlogits)
    dhb = colsum(dlogits)
    dy = dlogits @ hw.T
    dz = jnp.where(y > 0.0, dy, 0.0)
    dlw = matmul_at_b(xn, dz)
    dlb = colsum(dz)
    lw2, lm_w2, lv_w2 = adam_update(lw, lm_w, lv_w, dlw, t, lr)
    lb2, lm_b2, lv_b2 = adam_update(lb, lm_b, lv_b, dlb, t, lr)
    hw2, hm_w2, hv_w2 = adam_update(hw, hm_w, hv_w, dhw, t, lr)
    hb2, hm_b2, hv_b2 = adam_update(hb, hm_b, hv_b, dhb, t, lr)
    return (
        lw2, lb2, hw2, hb2,
        lm_w2, lv_w2, lm_b2, lv_b2,
        hm_w2, hv_w2, hm_b2, hv_b2,
        loss,
    )
