"""L2 — the paper's per-layer train/predict steps as JAX functions.

Each function composes the L1 Pallas kernels (``kernels.ff_layer``) into
one fused computation, is ``jax.jit``-lowered ONCE by ``aot.py``, and runs
from Rust as a single PJRT execution per call — no Python on the training
path.

Masking contract (shared with ``rust/src/engine/xla.rs``): HLO modules are
shape-static, so the Rust engine pads short batches with zero rows and
passes a 0/1 ``mask``; masked-out rows contribute nothing to losses or
gradients.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels import ff_layer as k
from compile.kernels.ref import sigmoid, softplus


@functools.partial(jax.jit, static_argnames=("normalize",))
def layer_fwd(w, b, x, normalize: bool):
    """FF layer forward: relu((normalize?)(x) @ w + b)."""
    return k.layer_fwd(w, b, x, normalize_input=normalize, relu=True)


@functools.partial(jax.jit, static_argnames=())
def head_logits(w, b, x):
    """Linear head logits (no activation)."""
    return k.linear_fwd(w, b, x, relu=False)


@functools.partial(jax.jit, static_argnames=("normalize",))
def ff_step(w, b, m_w, v_w, m_b, v_b, t, x_pos, x_neg, mask, theta, lr, normalize: bool):
    """One FF minibatch update (§3): goodness-logistic loss on a fused
    pos+neg batch, single Adam step.

    Returns ``(w', b', m_w', v_w', m_b', v_b', loss_pos, loss_neg,
    goodness_pos, goodness_neg)``.
    """
    xp = k.normalize(x_pos) if normalize else x_pos
    xn = k.normalize(x_neg) if normalize else x_neg
    x = jnp.concatenate([xp, xn], axis=0)
    y = k.linear_fwd(w, b, x, relu=True)
    d_out = y.shape[1]
    # Goodness = MEAN of squares (paper Eq. 1 with the 1/D threshold
    # coefficient folded in) — keeps a fresh layer below θ so the positive
    # pass dominates early; sums start above θ and collapse the layer.
    g = k.rowsumsq(y) / d_out
    bsz = x_pos.shape[0]
    g_pos, g_neg = g[:bsz], g[bsz:]
    count = jnp.maximum(jnp.sum(mask), 1.0)
    loss_pos = jnp.sum(mask * softplus(theta - g_pos)) / count
    loss_neg = jnp.sum(mask * softplus(g_neg - theta)) / count
    gm_pos = jnp.sum(mask * g_pos) / count
    gm_neg = jnp.sum(mask * g_neg) / count
    # dL/dg with the ReLU chain factor 2y and batch mean folded into dz.
    coef = jnp.concatenate(
        [-sigmoid(theta - g_pos) * mask, sigmoid(g_neg - theta) * mask], axis=0
    )
    dz = coef[:, None] * 2.0 * y / (2.0 * count * d_out)
    dw = k.matmul_at_b(x, dz)
    db = k.colsum(dz)
    w2, m_w2, v_w2 = k.adam(w, m_w, v_w, dw, t, lr)
    b2, m_b2, v_b2 = k.adam(b, m_b, v_b, db, t, lr)
    return w2, b2, m_w2, v_w2, m_b2, v_b2, loss_pos, loss_neg, gm_pos, gm_neg


def _softmax_ce(logits, onehot, mask):
    """Masked mean softmax cross-entropy + dlogits."""
    zmax = jnp.max(logits, axis=1, keepdims=True)
    ez = jnp.exp(logits - zmax)
    p = ez / jnp.sum(ez, axis=1, keepdims=True)
    count = jnp.maximum(jnp.sum(mask), 1.0)
    logp = jnp.log(jnp.maximum(jnp.sum(p * onehot, axis=1), 1e-12))
    loss = -jnp.sum(mask * logp) / count
    dlogits = (p - onehot) * (mask / count)[:, None]
    return loss, dlogits


@jax.jit
def head_step(w, b, m_w, v_w, m_b, v_b, t, x, onehot, mask, lr):
    """Softmax-head CE step (§3 Softmax prediction, trained by BP).

    Returns ``(w', b', m_w', v_w', m_b', v_b', loss)``.
    """
    logits = k.linear_fwd(w, b, x, relu=False)
    loss, dlogits = _softmax_ce(logits, onehot, mask)
    dw = k.matmul_at_b(x, dlogits)
    db = k.colsum(dlogits)
    w2, m_w2, v_w2 = k.adam(w, m_w, v_w, dw, t, lr)
    b2, m_b2, v_b2 = k.adam(b, m_b, v_b, db, t, lr)
    return w2, b2, m_w2, v_w2, m_b2, v_b2, loss


@functools.partial(jax.jit, static_argnames=("normalize",))
def perfopt_step(
    lw, lb, hw, hb,
    lm_w, lv_w, lm_b, lv_b,
    hm_w, hv_w, hm_b, hv_b,
    t, x, onehot, mask, lr, normalize: bool,
):
    """Performance-Optimized step (§4.4): CE through (layer, head) with
    gradients stopped at the layer input; two Adam updates.

    Returns ``(lw', lb', hw', hb', 8×moments, loss)`` — 13 outputs.
    """
    xn = k.normalize(x) if normalize else x
    y = k.linear_fwd(lw, lb, xn, relu=True)
    logits = k.linear_fwd(hw, hb, y, relu=False)
    loss, dlogits = _softmax_ce(logits, onehot, mask)
    dhw = k.matmul_at_b(y, dlogits)
    dhb = k.colsum(dlogits)
    dy = dlogits @ hw.T
    dz = jnp.where(y > 0.0, dy, 0.0)
    dlw = k.matmul_at_b(xn, dz)
    dlb = k.colsum(dz)
    lw2, lm_w2, lv_w2 = k.adam(lw, lm_w, lv_w, dlw, t, lr)
    lb2, lm_b2, lv_b2 = k.adam(lb, lm_b, lv_b, dlb, t, lr)
    hw2, hm_w2, hv_w2 = k.adam(hw, hm_w, hv_w, dhw, t, lr)
    hb2, hm_b2, hv_b2 = k.adam(hb, hm_b, hv_b, dhb, t, lr)
    return (
        lw2, lb2, hw2, hb2,
        lm_w2, lv_w2, lm_b2, lv_b2,
        hm_w2, hv_w2, hm_b2, hv_b2,
        loss,
    )
