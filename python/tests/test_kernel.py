"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (and the normalize/relu flags); assert_allclose
against ``kernels.ref``. This is the CORE numeric signal of the stack —
the Rust NativeEngine and the AOT artifacts both chain back to these
kernels.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ff_layer as k
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rng_mat(rng, r, c, lo=-1.0, hi=1.0):
    return jnp.asarray(rng.uniform(lo, hi, size=(r, c)), dtype=jnp.float32)


dims = st.sampled_from([1, 2, 3, 5, 8, 16, 48, 64])
batches = st.sampled_from([1, 2, 4, 16, 64])


@settings(**SETTINGS)
@given(b=batches, din=dims, seed=st.integers(0, 2**31 - 1))
def test_normalize_matches_ref(b, din, seed):
    rng = np.random.default_rng(seed)
    x = rng_mat(rng, b, din)
    assert_allclose(k.normalize(x), ref.normalize_rows(x), rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(b=batches, din=dims, dout=dims, relu=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_linear_fwd_matches_ref(b, din, dout, relu, seed):
    rng = np.random.default_rng(seed)
    w, bb, x = rng_mat(rng, din, dout), rng_mat(rng, 1, dout)[0], rng_mat(rng, b, din)
    assert_allclose(
        k.linear_fwd(w, bb, x, relu=relu),
        ref.linear_fwd(w, bb, x, relu=relu),
        rtol=1e-4,
        atol=1e-5,
    )


@settings(**SETTINGS)
@given(b=batches, dout=dims, seed=st.integers(0, 2**31 - 1))
def test_rowsumsq_matches_ref(b, dout, seed):
    rng = np.random.default_rng(seed)
    y = rng_mat(rng, b, dout)
    assert_allclose(k.rowsumsq(y), ref.rowsumsq(y), rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(b=batches, din=dims, dout=dims, seed=st.integers(0, 2**31 - 1))
def test_matmul_at_b_matches_ref(b, din, dout, seed):
    rng = np.random.default_rng(seed)
    a, dz = rng_mat(rng, b, din), rng_mat(rng, b, dout)
    assert_allclose(k.matmul_at_b(a, dz), ref.matmul_at_b(a, dz), rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(b=batches, dout=dims, seed=st.integers(0, 2**31 - 1))
def test_colsum_matches_ref(b, dout, seed):
    rng = np.random.default_rng(seed)
    dz = rng_mat(rng, b, dout)
    assert_allclose(k.colsum(dz), ref.colsum(dz), rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    din=dims,
    dout=dims,
    t=st.integers(1, 1000),
    lr=st.sampled_from([1e-4, 1e-3, 1e-2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_adam_matches_ref(din, dout, t, lr, seed):
    rng = np.random.default_rng(seed)
    p, m, v, g = (rng_mat(rng, din, dout) for _ in range(4))
    v = jnp.abs(v)  # second moment is nonneg
    tf = jnp.float32(t)
    got = k.adam(p, m, v, g, tf, jnp.float32(lr))
    want = ref.adam_update(p, m, v, g, tf, jnp.float32(lr))
    for gg, ww in zip(got, want):
        assert_allclose(gg, ww, rtol=1e-4, atol=1e-6)


def test_normalize_zero_row_finite():
    x = jnp.zeros((2, 8), dtype=jnp.float32)
    out = k.normalize(x)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_layer_fwd_composite_matches_ref():
    rng = np.random.default_rng(7)
    w, b, x = rng_mat(rng, 48, 64), rng_mat(rng, 1, 64)[0], rng_mat(rng, 16, 48, 0.0, 1.0)
    got = k.layer_fwd(w, b, x, normalize_input=True)
    want = ref.layer_fwd(w, b, x, normalize=True)
    assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    assert bool(jnp.all(got >= 0.0))


@pytest.mark.parametrize("n,pref,expect_div", [(2000, 256, True), (64, 256, True), (48, 64, True), (7, 4, True)])
def test_tile_divides(n, pref, expect_div):
    t = k._tile(n, pref)
    assert 1 <= t <= max(n, pref)
    assert n % t == 0
