"""L2 correctness: the jitted step functions vs the pure-jnp references,
including the masking contract the Rust engine relies on."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

SETTINGS = dict(max_examples=10, deadline=None)


def mats(seed, b, din, dout, classes=10):
    rng = np.random.default_rng(seed)
    f = lambda *s: jnp.asarray(rng.uniform(-0.5, 0.5, size=s), dtype=jnp.float32)
    w, bb = f(din, dout) / np.sqrt(din), jnp.zeros((dout,), jnp.float32)
    zeros2, zeros1 = jnp.zeros((din, dout), jnp.float32), jnp.zeros((dout,), jnp.float32)
    x = jnp.asarray(rng.uniform(0.0, 1.0, size=(b, din)), dtype=jnp.float32)
    labels = rng.integers(0, classes, size=b)
    onehot = jnp.asarray(np.eye(classes, dtype=np.float32)[labels])
    return rng, w, bb, zeros2, zeros1, x, onehot


@settings(**SETTINGS)
@given(
    b=st.sampled_from([2, 8, 16]),
    din=st.sampled_from([8, 32]),
    dout=st.sampled_from([8, 32]),
    norm=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_ff_step_matches_ref(b, din, dout, norm, seed):
    rng, w, bb, z2, z1, x_pos, _ = mats(seed, b, din, dout)
    x_neg = jnp.asarray(rng.uniform(0.0, 1.0, size=(b, din)), dtype=jnp.float32)
    mask = jnp.ones((b,), jnp.float32)
    args = (w, bb, z2, z2, z1, z1, jnp.float32(1.0), x_pos, x_neg, mask,
            jnp.float32(2.0), jnp.float32(0.01))
    got = model.ff_step(*args, normalize=norm)
    want = ref.ff_step_ref(*args, normalize=norm)
    for g, w_ in zip(got, want):
        assert_allclose(g, w_, rtol=2e-4, atol=1e-5)


def test_ff_step_mask_ignores_padded_rows():
    # 4 real rows padded to 8 must equal the unpadded 4-row step.
    _, w, bb, z2, z1, x_pos, _ = mats(3, 8, 16, 12)
    rng = np.random.default_rng(4)
    x_neg = jnp.asarray(rng.uniform(0, 1, size=(8, 16)), dtype=jnp.float32)
    mask_full = jnp.ones((4,), jnp.float32)
    small = model.ff_step(
        w, bb, z2, z2, z1, z1, jnp.float32(1.0),
        x_pos[:4], x_neg[:4], mask_full, jnp.float32(2.0), jnp.float32(0.01),
        normalize=False,
    )
    xp_pad = jnp.concatenate([x_pos[:4], jnp.zeros((4, 16), jnp.float32)])
    xn_pad = jnp.concatenate([x_neg[:4], jnp.zeros((4, 16), jnp.float32)])
    mask_pad = jnp.concatenate([jnp.ones((4,)), jnp.zeros((4,))]).astype(jnp.float32)
    padded = model.ff_step(
        w, bb, z2, z2, z1, z1, jnp.float32(1.0),
        xp_pad, xn_pad, mask_pad, jnp.float32(2.0), jnp.float32(0.01),
        normalize=False,
    )
    for s, p in zip(small, padded):
        assert_allclose(s, p, rtol=1e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    b=st.sampled_from([2, 8]),
    din=st.sampled_from([8, 24]),
    seed=st.integers(0, 2**31 - 1),
)
def test_head_step_matches_ref(b, din, seed):
    _, w, bb, _, _, x, onehot = mats(seed, b, din, 10)
    z2 = jnp.zeros((din, 10), jnp.float32)
    z1 = jnp.zeros((10,), jnp.float32)
    w = w[:, :10] if w.shape[1] >= 10 else jnp.zeros((din, 10), jnp.float32)
    mask = jnp.ones((b,), jnp.float32)
    args = (w, z1, z2, z2, z1, z1, jnp.float32(1.0), x, onehot, mask, jnp.float32(1e-3))
    got = model.head_step(*args)
    want = ref.head_step_ref(*args)
    for g, w_ in zip(got, want):
        assert_allclose(g, w_, rtol=2e-4, atol=1e-5)


@settings(**SETTINGS)
@given(norm=st.booleans(), seed=st.integers(0, 2**31 - 1))
def test_perfopt_step_matches_ref(norm, seed):
    b, din, dout, classes = 8, 16, 12, 10
    rng, lw, lb, z2, z1, x, onehot = mats(seed, b, din, dout, classes)
    hw = jnp.asarray(rng.uniform(-0.3, 0.3, size=(dout, classes)), jnp.float32)
    hb = jnp.zeros((classes,), jnp.float32)
    hz2 = jnp.zeros((dout, classes), jnp.float32)
    hz1 = jnp.zeros((classes,), jnp.float32)
    mask = jnp.ones((b,), jnp.float32)
    args = (lw, lb, hw, hb, z2, z2, z1, z1, hz2, hz2, hz1, hz1,
            jnp.float32(1.0), x, onehot, mask, jnp.float32(0.01))
    got = model.perfopt_step(*args, normalize=norm)
    want = ref.perfopt_step_ref(*args, normalize=norm)
    assert len(got) == 13
    for g, w_ in zip(got, want):
        assert_allclose(g, w_, rtol=2e-4, atol=1e-5)


def test_ff_training_separates_goodness():
    """Behavioral: repeated steps must grow the pos/neg goodness margin."""
    _, w, bb, z2, z1, x_pos, _ = mats(11, 16, 20, 24)
    rng = np.random.default_rng(12)
    # pos: energy in first half; neg: second half.
    x_pos = x_pos.at[:, :10].add(1.0)
    x_neg = jnp.asarray(rng.uniform(0, 0.1, size=(16, 20)), jnp.float32).at[:, 10:].add(1.0)
    mask = jnp.ones((16,), jnp.float32)
    m_w, v_w, m_b, v_b = z2, z2, z1, z1
    first_margin = None
    for t in range(1, 151):
        out = model.ff_step(
            w, bb, m_w, v_w, m_b, v_b, jnp.float32(t), x_pos, x_neg, mask,
            jnp.float32(2.0), jnp.float32(0.01), normalize=False,
        )
        w, bb, m_w, v_w, m_b, v_b = out[:6]
        margin = float(out[8] - out[9])
        if first_margin is None:
            first_margin = margin
    assert margin > first_margin + 1.0, f"margin {first_margin} -> {margin}"


def test_layer_fwd_shapes_and_nonneg():
    _, w, bb, _, _, x, _ = mats(5, 8, 16, 12)
    y = model.layer_fwd(w, bb, x, normalize=True)
    assert y.shape == (8, 12)
    assert bool(jnp.all(y >= 0.0))
