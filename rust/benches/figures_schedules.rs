//! Bench: regenerate the paper's schedule figures (1, 2, 4, 5, 6) as
//! ASCII Gantt charts, plus the Figure 3 split study and the DES speedup
//! summary every figure's caption implies.
//!
//! `cargo bench --bench figures_schedules`

use pff::config::{EngineKind, ExperimentConfig};
use pff::ff::NegStrategy;
use pff::harness::{figures, Scale};
use pff::sim::schedules::{SimParams, SimVariant};
use pff::sim::{build_schedule, gantt, simulate, CostModel};

fn main() {
    println!("{}", figures::all_schedule_figures());

    // Figure 3 (measured): split granularity vs accuracy.
    let mut scale = Scale::quick();
    scale.train_n = 384;
    scale.test_n = 192;
    scale.epochs = 4;
    let pts = figures::figure3_measured(&scale, EngineKind::Native, 42, &[1, 2, 4])
        .expect("figure 3 runs");
    println!("── Figure 3: accuracy vs split count (measured, reduced scale) ──");
    for (s, acc) in pts {
        println!("  S = {s:<3} accuracy = {:.2}%", acc * 100.0);
    }

    // Paper-scale DES summary for all variants (the figures' captions).
    println!("\n── DES summary @ paper scale (N=4, AdaptiveNEG) ──");
    let cfg = ExperimentConfig::paper_mnist();
    let cm = CostModel::paper_testbed(&cfg);
    let p = SimParams { nodes: 4, neg: NegStrategy::Adaptive, softmax_head: false, perfopt: false };
    for v in [
        SimVariant::SequentialFF,
        SimVariant::SingleLayerPFF,
        SimVariant::AllLayersPFF,
        SimVariant::FederatedPFF,
        SimVariant::BackpropPipeline,
        SimVariant::Dff,
    ] {
        let r = simulate(&build_schedule(v, &cm, &p));
        println!("  {}", gantt::summary_line(&v.to_string(), &r));
    }
}
