//! Micro-benchmarks of the task-graph dispatcher: lease/complete
//! throughput draining a pipeline lattice with 1 and 4 workers, and the
//! headline elastic-scheduling number — steal wake latency, the time from
//! a task becoming ready on a busy worker's queue to an idle peer waking
//! and leasing it (server-side Condvar, no poll interval anywhere).
//!
//! ```bash
//! cargo bench --bench micro_dispatch                       # full scale
//! cargo bench --bench micro_dispatch -- --quick            # CI smoke
//! cargo bench --bench micro_dispatch -- --json OUT.json    # perf artifact
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use pff::bench_util::{BenchStats, JsonReport};
use pff::coordinator::{Dispatcher, EventBus, TaskGraph, TaskGraphBuilder};

struct Opts {
    quick: bool,
    json: Option<String>,
}

fn parse_opts() -> Opts {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = Opts { quick: false, json: None };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => {
                opts.quick = true;
                i += 1;
            }
            "--json" => {
                opts.json = args.get(i + 1).cloned();
                i += 2;
            }
            // tolerate cargo-bench passthrough flags like --bench
            _ => i += 1,
        }
    }
    opts
}

/// Stats from a pre-collected sample vector (seconds).
fn stats_of(mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        iters: samples.len() as u32,
        min_s: samples[0],
        mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_s: samples[samples.len() / 2],
    }
}

/// The standard pipeline lattice over a `splits × layers` grid,
/// round-robin homes — the same shape `TaskGraph::pipeline` builds for
/// the whole-network schedulers, without needing a full config.
fn lattice(splits: u32, layers: usize, homes: usize) -> TaskGraph {
    let mut b = TaskGraphBuilder::new(homes, layers, splits, false);
    for c in 0..splits {
        for l in 0..layers {
            b.task(c, l, c as usize % homes).unwrap();
        }
    }
    for c in 0..splits {
        for l in 0..layers {
            if l > 0 {
                b.edge((c, l - 1), (c, l)).unwrap();
            }
            if c > 0 {
                b.edge((c - 1, l), (c, l)).unwrap();
            }
        }
    }
    b.build().unwrap()
}

/// Wall seconds for `workers` threads to drain `graph` with zero-cost
/// task bodies — pure dispatcher overhead (lease + complete + wakeups).
fn drain(graph: &TaskGraph, workers: usize) -> f64 {
    let d = Arc::new(Dispatcher::new(graph.clone(), EventBus::new(), true, false));
    for w in 0..workers {
        d.worker_joined(w as u32, "bench");
    }
    d.open();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..workers)
        .map(|w| {
            let d = d.clone();
            std::thread::spawn(move || {
                while let Some(t) = d.next_task(w as u32, Duration::from_secs(10)).unwrap() {
                    d.complete(w as u32, t.id, 0.0, 0.0, 0.0).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

/// Steal wake latency: every task is homed on worker 0, and completing
/// the head fans out TWO ready successors onto worker 0's queue — a
/// backlog ≥ 2 makes that queue steal-eligible, so the parked idle
/// worker 1 must wake and STEAL one. Timed from just before the
/// `complete` to the thief's lease landing.
fn steal_wake_latency(n: u32) -> BenchStats {
    let mut samples = Vec::with_capacity(n as usize);
    for _ in 0..n {
        let mut b = TaskGraphBuilder::new(1, 1, 3, false);
        for c in 0..3 {
            b.task(c, 0, 0).unwrap();
        }
        b.edge((0, 0), (1, 0)).unwrap();
        b.edge((0, 0), (2, 0)).unwrap();
        let d = Arc::new(Dispatcher::new(b.build().unwrap(), EventBus::new(), true, false));
        d.worker_joined(0, "victim");
        d.worker_joined(1, "thief");
        d.open();
        // Only the head is ready; worker 0 leases it before the thief
        // thread exists, so the thief can only ever park.
        let head = d.next_task(0, Duration::from_secs(5)).unwrap().unwrap();
        let d2 = d.clone();
        let thief = std::thread::spawn(move || {
            let t = d2.next_task(1, Duration::from_secs(5)).unwrap().unwrap();
            (t, Instant::now())
        });
        // Let the thief provably park before the handoff.
        std::thread::sleep(Duration::from_millis(2));
        let t0 = Instant::now();
        d.complete(0, head.id, 0.0, 0.0, 0.0).unwrap();
        let (stolen, woke) = thief.join().unwrap();
        samples.push(woke.duration_since(t0).as_secs_f64());
        assert!(stolen.chapter > 0, "the thief must have stolen a successor task");
        d.complete(1, stolen.id, 0.0, 0.0, 0.0).unwrap();
        let rest = d.next_task(0, Duration::from_secs(5)).unwrap().unwrap();
        d.complete(0, rest.id, 0.0, 0.0, 0.0).unwrap();
    }
    stats_of(samples)
}

fn main() {
    let opts = parse_opts();
    let mut report = JsonReport::new("micro_dispatch");

    let (splits, layers) = if opts.quick { (16u32, 3usize) } else { (64, 3) };
    let iters = if opts.quick { 5 } else { 20 };
    let graph = lattice(splits, layers, 2);
    let tasks = graph.len() as f64;

    for workers in [1usize, 4] {
        drain(&graph, workers); // warmup
        let samples: Vec<f64> = (0..iters).map(|_| drain(&graph, workers)).collect();
        let s = stats_of(samples);
        let noun = if workers == 1 { "worker" } else { "workers" };
        report.add(
            format!(
                "[dispatch] drain {splits}x{layers} lattice, {workers} {noun}  \
                 ({:.0} tasks/s)",
                tasks / s.min_s
            ),
            s,
        );
    }

    // The elastic-scheduling acceptance number: ready-on-a-busy-peer to
    // stolen-by-an-idle-worker, through the Condvar park/notify path.
    let s = steal_wake_latency(if opts.quick { 20 } else { 100 });
    report.add(format!("[dispatch] steal wake latency (p50 {:.3} ms)", s.p50_s * 1e3), s);

    report.write(opts.json.as_deref());
}
