//! Micro-benchmarks of the compute engine hot paths (the §Perf L3/L2
//! working set): FF step, forward, head step, perfopt step, adaptive
//! neg-label generation — native engine, plus XLA when the feature and
//! artifacts are present.
//!
//! ```bash
//! cargo bench --bench micro_engine                      # full scale
//! cargo bench --bench micro_engine -- --quick           # CI smoke scale
//! cargo bench --bench micro_engine -- --json OUT.json   # perf artifact
//! ```

use pff::bench_util::{bench, fmt_s, BenchStats};
use pff::engine::{Engine, NativeEngine};
use pff::ff::{negative, FFLayer, FFNetwork, LinearHead};
use pff::tensor::{ops, pool, AdamState, Matrix, Rng};

/// One named measurement, accumulated for the optional JSON artifact.
struct Record {
    name: String,
    stats: BenchStats,
}

/// One thread-sweep measurement (the `threads` key of the artifact).
struct ThreadRecord {
    name: String,
    threads: usize,
    stats: BenchStats,
}

/// Collects records and mirrors them to stdout.
#[derive(Default)]
struct Report {
    records: Vec<Record>,
    threads: Vec<ThreadRecord>,
}

fn record_json(name: &str, s: &BenchStats, extra: &str) -> String {
    format!(
        "{{\"name\": {name:?}, {extra}\"mean_s\": {:.9}, \"min_s\": {:.9}, \
         \"p50_s\": {:.9}, \"iters\": {}}}",
        s.mean_s, s.min_s, s.p50_s, s.iters
    )
}

impl Report {
    fn add(&mut self, name: String, stats: BenchStats) {
        println!("{}", stats.line(&name));
        self.records.push(Record { name, stats });
    }

    /// Record one sweep point. `name` stays free of measured values so
    /// the artifact's `threads` records join across runs/PRs; the
    /// throughput only decorates the stdout line.
    fn add_threads(&mut self, name: String, threads: usize, gflops: f64, stats: BenchStats) {
        println!("{}", stats.line(&format!("{name} ({gflops:.2} GFLOP/s)")));
        self.threads.push(ThreadRecord { name, threads, stats });
    }

    /// Hand-rolled JSON (no serde offline): one object per record, plus
    /// the `threads` sweep tracking parallel-kernel scaling over PRs.
    fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"bench\": \"micro_engine\",\n  \"records\": [\n");
        for (i, r) in self.records.iter().enumerate() {
            out.push_str(&format!(
                "    {}{}\n",
                record_json(&r.name, &r.stats, ""),
                if i + 1 < self.records.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n  \"threads\": [\n");
        for (i, r) in self.threads.iter().enumerate() {
            out.push_str(&format!(
                "    {}{}\n",
                record_json(&r.name, &r.stats, &format!("\"threads\": {}, ", r.threads)),
                if i + 1 < self.threads.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

struct Opts {
    quick: bool,
    json: Option<String>,
}

fn parse_opts() -> Opts {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = Opts { quick: false, json: None };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => {
                opts.quick = true;
                i += 1;
            }
            "--json" => {
                opts.json = args.get(i + 1).cloned();
                i += 2;
            }
            // tolerate cargo-bench passthrough flags like --bench
            _ => i += 1,
        }
    }
    opts
}

fn bench_engine(
    report: &mut Report,
    eng: &mut dyn Engine,
    dims: &[usize],
    batch: usize,
    warmup: u32,
    iters: u32,
) {
    let mut rng = Rng::new(42);
    let (din, dout) = (dims[0], dims[1]);
    let mut layer = FFLayer::new(din, dout, false, &mut rng);
    let mut opt = AdamState::new(din, dout);
    let x_pos = Matrix::rand_uniform(batch, din, 0.0, 1.0, &mut rng);
    let x_neg = Matrix::rand_uniform(batch, din, 0.0, 1.0, &mut rng);

    let s = bench(warmup, iters, || {
        eng.ff_train_step(&mut layer, &mut opt, &x_pos, &x_neg, 2.0, 0.01).unwrap();
    });
    let flops = 4.0 * (2 * batch) as f64 * din as f64 * dout as f64;
    report.add(
        format!(
            "[{}] ff_step {din}x{dout} b{batch}  ({:.2} GFLOP/s)",
            eng.name(),
            flops / s.min_s / 1e9
        ),
        s,
    );

    let s = bench(warmup, iters, || {
        eng.layer_forward(&layer, &x_pos).unwrap();
    });
    report.add(format!("[{}] layer_forward {din}x{dout} b{batch}", eng.name()), s);

    let head_din: usize = dims[2..].iter().sum::<usize>().max(dout);
    let mut head = LinearHead::new(head_din, 10, &mut rng);
    let mut hopt = AdamState::new(head_din, 10);
    let hx = Matrix::rand_uniform(batch, head_din, 0.0, 1.0, &mut rng);
    let labels: Vec<u8> = (0..batch).map(|i| (i % 10) as u8).collect();
    let s = bench(warmup, iters, || {
        eng.head_train_step(&mut head, &mut hopt, &hx, &labels, 1e-3).unwrap();
    });
    report.add(format!("[{}] head_step {head_din}x10 b{batch}", eng.name()), s);

    let mut po_head = LinearHead::new(dout, 10, &mut rng);
    let (mut po_l, mut po_h) = (AdamState::new(din, dout), AdamState::new(dout, 10));
    let s = bench(warmup, iters, || {
        eng.perfopt_train_step(&mut layer, &mut po_head, &mut po_l, &mut po_h, &x_pos, &labels, 0.01)
            .unwrap();
    });
    report.add(format!("[{}] perfopt_step {din}x{dout} b{batch}", eng.name()), s);
}

#[cfg(feature = "xla")]
fn xla_micro(report: &mut Report, warmup: u32, iters: u32) {
    use pff::engine::XlaEngine;
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        println!("\n(artifacts/ missing — run `make artifacts` to include XLA micro-benches)");
        return;
    }
    println!("\n── micro: XLA engine (test profile 784→32, b16) ──");
    match XlaEngine::new("artifacts") {
        Ok(mut xla) => {
            let mut rng = Rng::new(42);
            let mut layer = FFLayer::new(784, 32, false, &mut rng);
            let mut opt = AdamState::new(784, 32);
            let xp = Matrix::rand_uniform(16, 784, 0.0, 1.0, &mut rng);
            let xn = Matrix::rand_uniform(16, 784, 0.0, 1.0, &mut rng);
            let s = bench(warmup, iters, || {
                xla.ff_train_step(&mut layer, &mut opt, &xp, &xn, 2.0, 0.01).unwrap();
            });
            report.add("[xla] ff_step 784x32 b16 (incl. PJRT transfer)".to_string(), s);
            let s = bench(warmup, iters, || {
                xla.layer_forward(&layer, &xp).unwrap();
            });
            report.add("[xla] layer_forward 784x32 b16".to_string(), s);
        }
        Err(e) => println!("  (skipping XLA micro-bench: {e})"),
    }
}

#[cfg(not(feature = "xla"))]
fn xla_micro(_report: &mut Report, _warmup: u32, _iters: u32) {
    println!("\n(xla feature disabled — rebuild with `--features xla` for XLA micro-benches)");
}

/// Thread-count sweep over the paper-shape kernels (784×2000) and the
/// full FF train step. Kernels are bit-identical at every count, so this
/// measures pure scaling; the records land under the artifact's `threads`
/// key so CI tracks the parallel-runtime trajectory from this PR onward.
fn threads_sweep(report: &mut Report, quick: bool) {
    println!("\n── micro: thread-count sweep (bit-deterministic parallel kernels) ──");
    let (mm_warmup, mm_iters, step_iters) = if quick { (1, 2, 2) } else { (2, 8, 4) };
    let counts = [1usize, 2, 4, 8];
    let mut rng = Rng::new(42);
    let w = Matrix::rand_uniform(784, 2000, -0.05, 0.05, &mut rng);
    for batch in [128usize, 512] {
        let a = Matrix::rand_uniform(batch, 784, 0.0, 1.0, &mut rng);
        let flops = 2.0 * batch as f64 * 784.0 * 2000.0;
        for t in counts {
            pool::set_threads(t);
            let s = bench(mm_warmup, mm_iters, || {
                std::hint::black_box(ops::matmul(&a, &w));
            });
            let gflops = flops / s.min_s / 1e9;
            report.add_threads(format!("matmul 784x2000 b{batch} t{t}"), t, gflops, s);
        }
    }

    let batch = 128usize;
    let x_pos = Matrix::rand_uniform(batch, 784, 0.0, 1.0, &mut rng);
    let x_neg = Matrix::rand_uniform(batch, 784, 0.0, 1.0, &mut rng);
    let flops = 4.0 * (2 * batch) as f64 * 784.0 * 2000.0;
    for t in counts {
        // Fresh identically-seeded layer/opt/engine per count so every
        // sweep point measures the same work (same weights, same ReLU
        // sparsity, workspace warmed by the warmup iteration) and the
        // artifact's t=1 vs t=N ratio is pure scaling.
        let mut step_rng = Rng::new(7);
        let mut layer = FFLayer::new(784, 2000, false, &mut step_rng);
        let mut opt = AdamState::new(784, 2000);
        let mut eng = NativeEngine::new();
        pool::set_threads(t);
        let s = bench(1, step_iters, || {
            eng.ff_train_step(&mut layer, &mut opt, &x_pos, &x_neg, 2.0, 0.01).unwrap();
        });
        report.add_threads(format!("ff_step 784x2000 b{batch} t{t}"), t, flops / s.min_s / 1e9, s);
    }
    pool::set_threads(0); // back to the env/auto default for later sections
}

fn main() {
    let opts = parse_opts();
    let mut report = Report::default();
    let (dims, batch, warmup, iters): (&[usize], usize, u32, u32) = if opts.quick {
        (&[784, 64, 64, 64, 64], 32, 1, 5)
    } else {
        (&[784, 256, 256, 256, 256], 64, 3, 20)
    };

    println!(
        "── micro: native engine ({} dims {dims:?}) ──",
        if opts.quick { "quick" } else { "reduced" }
    );
    let mut native = NativeEngine::new();
    bench_engine(&mut report, &mut native, dims, batch, warmup, iters);

    println!("\n── micro: AdaptiveNEG sweep (the most expensive coordinator stage) ──");
    let (sweep_n, sweep_reps) = if opts.quick { (128usize, 2u32) } else { (512, 5) };
    let mut rng = Rng::new(7);
    let net = FFNetwork::new(dims, 10, &mut rng);
    let x = Matrix::rand_uniform(sweep_n, 784, 0.0, 1.0, &mut rng);
    let truth: Vec<u8> = (0..sweep_n).map(|i| (i % 10) as u8).collect();
    let s = bench(1, sweep_reps, || {
        negative::adaptive_neg_labels(&mut native, &net, &x, &truth, 256).unwrap();
    });
    let per_sample = s.min_s / sweep_n as f64;
    report.add(format!("[native] adaptive_neg_labels n={sweep_n} (10-way sweep)"), s);
    println!(
        "        per-sample cost {} — vs one ff_step costing ~the same per 128 samples",
        fmt_s(per_sample)
    );

    threads_sweep(&mut report, opts.quick);

    xla_micro(&mut report, warmup, iters);

    if let Some(path) = opts.json {
        std::fs::write(&path, report.to_json()).expect("writing json artifact");
        println!("\nwrote perf artifact: {path}");
    }
}
