//! Micro-benchmarks of the compute engine hot paths (the §Perf L3/L2
//! working set): FF step, forward, head step, perfopt step, adaptive
//! neg-label generation — native engine, plus XLA when artifacts exist.
//!
//! `cargo bench --bench micro_engine`

use pff::bench_util::{bench, fmt_s};
use pff::engine::{Engine, NativeEngine, XlaEngine};
use pff::ff::{negative, FFLayer, FFNetwork, LinearHead};
use pff::tensor::{AdamState, Matrix, Rng};

fn bench_engine(eng: &mut dyn Engine, dims: &[usize], batch: usize) {
    let mut rng = Rng::new(42);
    let (din, dout) = (dims[0], dims[1]);
    let mut layer = FFLayer::new(din, dout, false, &mut rng);
    let mut opt = AdamState::new(din, dout);
    let x_pos = Matrix::rand_uniform(batch, din, 0.0, 1.0, &mut rng);
    let x_neg = Matrix::rand_uniform(batch, din, 0.0, 1.0, &mut rng);

    let s = bench(3, 20, || {
        eng.ff_train_step(&mut layer, &mut opt, &x_pos, &x_neg, 2.0, 0.01).unwrap();
    });
    let flops = 4.0 * (2 * batch) as f64 * din as f64 * dout as f64;
    println!(
        "{}",
        s.line(&format!(
            "[{}] ff_step {din}x{dout} b{batch}  ({:.2} GFLOP/s)",
            eng.name(),
            flops / s.min_s / 1e9
        ))
    );

    let s = bench(3, 20, || {
        eng.layer_forward(&layer, &x_pos).unwrap();
    });
    println!("{}", s.line(&format!("[{}] layer_forward {din}x{dout} b{batch}", eng.name())));

    let head_din: usize = dims[2..].iter().sum::<usize>().max(dout);
    let mut head = LinearHead::new(head_din, 10, &mut rng);
    let mut hopt = AdamState::new(head_din, 10);
    let hx = Matrix::rand_uniform(batch, head_din, 0.0, 1.0, &mut rng);
    let labels: Vec<u8> = (0..batch).map(|i| (i % 10) as u8).collect();
    let s = bench(3, 20, || {
        eng.head_train_step(&mut head, &mut hopt, &hx, &labels, 1e-3).unwrap();
    });
    println!("{}", s.line(&format!("[{}] head_step {head_din}x10 b{batch}", eng.name())));

    let mut po_head = LinearHead::new(dout, 10, &mut rng);
    let (mut po_l, mut po_h) = (AdamState::new(din, dout), AdamState::new(dout, 10));
    let s = bench(3, 20, || {
        eng.perfopt_train_step(&mut layer, &mut po_head, &mut po_l, &mut po_h, &x_pos, &labels, 0.01)
            .unwrap();
    });
    println!("{}", s.line(&format!("[{}] perfopt_step {din}x{dout} b{batch}", eng.name())));
}

fn main() {
    println!("── micro: native engine (reduced dims 784→256→…) ──");
    let mut native = NativeEngine::new();
    bench_engine(&mut native, &[784, 256, 256, 256, 256], 64);

    println!("\n── micro: AdaptiveNEG sweep (the most expensive coordinator stage) ──");
    let mut rng = Rng::new(7);
    let net = FFNetwork::new(&[784, 256, 256, 256, 256], 10, &mut rng);
    let x = Matrix::rand_uniform(512, 784, 0.0, 1.0, &mut rng);
    let truth: Vec<u8> = (0..512).map(|i| (i % 10) as u8).collect();
    let s = bench(1, 5, || {
        negative::adaptive_neg_labels(&mut native, &net, &x, &truth, 256).unwrap();
    });
    println!("{}", s.line("[native] adaptive_neg_labels n=512 (10-way sweep)"));
    println!(
        "        per-sample cost {} — vs one ff_step costing ~the same per 128 samples",
        fmt_s(s.min_s / 512.0)
    );

    // XLA engine, when artifacts are present (test profile dims).
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        println!("\n── micro: XLA engine (test profile 784→32, b16) ──");
        match XlaEngine::new("artifacts") {
            Ok(mut xla) => {
                let mut rng = Rng::new(42);
                let mut layer = FFLayer::new(784, 32, false, &mut rng);
                let mut opt = AdamState::new(784, 32);
                let xp = Matrix::rand_uniform(16, 784, 0.0, 1.0, &mut rng);
                let xn = Matrix::rand_uniform(16, 784, 0.0, 1.0, &mut rng);
                let s = bench(3, 20, || {
                    xla.ff_train_step(&mut layer, &mut opt, &xp, &xn, 2.0, 0.01).unwrap();
                });
                println!("{}", s.line("[xla] ff_step 784x32 b16 (incl. PJRT transfer)"));
                let s = bench(3, 20, || {
                    xla.layer_forward(&layer, &xp).unwrap();
                });
                println!("{}", s.line("[xla] layer_forward 784x32 b16"));
            }
            Err(e) => println!("  (skipping XLA micro-bench: {e})"),
        }
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` to include XLA micro-benches)");
    }
}
