//! Load-test harness for `pff serve`: latency and throughput of the
//! batched classify path, in-process and across the v4 wire protocol.
//!
//! The headline records are an open-loop arrival run (requests fired on
//! a fixed RPS schedule regardless of completions, so queueing delay is
//! measured honestly) with p50/p95/p99 latency, and a closed-loop
//! saturation sweep over client counts to find peak throughput.
//!
//! ```bash
//! cargo bench --bench micro_serve                       # full scale
//! cargo bench --bench micro_serve -- --quick            # CI smoke
//! cargo bench --bench micro_serve -- --json OUT.json    # perf artifact
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use pff::bench_util::{bench, BenchStats, JsonReport};
use pff::coordinator::eval::TrainedModel;
use pff::coordinator::serve::{BatchServer, ServeOptions};
use pff::coordinator::store::MemStore;
use pff::coordinator::NodeRegistry;
use pff::engine::native_factory;
use pff::ff::FFNetwork;
use pff::tensor::{Matrix, Rng};
use pff::transport::tcp::{StoreServer, TcpStoreClient};

struct Opts {
    quick: bool,
    json: Option<String>,
}

fn parse_opts() -> Opts {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = Opts { quick: false, json: None };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => {
                opts.quick = true;
                i += 1;
            }
            "--json" => {
                opts.json = args.get(i + 1).cloned();
                i += 2;
            }
            // tolerate cargo-bench passthrough flags like --bench
            _ => i += 1,
        }
    }
    opts
}

/// Percentile (0..=100) of a pre-sorted sample vector.
fn pct(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p / 100.0).round() as usize;
    sorted[idx]
}

/// Latency-record stats: `min_s`/`p50_s` carry the p50 (the gated
/// number — far more stable run-to-run than the true minimum).
fn latency_stats(mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        iters: samples.len() as u32,
        min_s: pct(&samples, 50.0),
        mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_s: pct(&samples, 50.0),
    }
}

fn serve_model(quick: bool) -> TrainedModel {
    let mut rng = Rng::new(7);
    let dims: &[usize] = if quick { &[784, 64, 64] } else { &[784, 128, 128, 128] };
    TrainedModel {
        net: FFNetwork::new(dims, 10, &mut rng),
        head: None,
        layer_heads: Vec::new(),
    }
}

/// A pool of distinct single feature rows, cycled by request index.
fn row_pool(n: usize, in_dim: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(11);
    (0..n)
        .map(|_| Matrix::rand_uniform(1, in_dim, 0.0, 1.0, &mut rng).data)
        .collect()
}

fn start_stack(quick: bool) -> (Arc<BatchServer>, StoreServer, usize) {
    let model = serve_model(quick);
    let in_dim = model.net.layers[0].w.rows;
    let srv = BatchServer::start(model, native_factory(), ServeOptions::default()).unwrap();
    let server = StoreServer::start_serving(
        Arc::new(MemStore::new()),
        Arc::new(NodeRegistry::new()),
        srv.clone(),
        "127.0.0.1:0",
    )
    .unwrap();
    (srv, server, in_dim)
}

/// Open-loop arrival: `clients` sender threads share a fixed global RPS
/// schedule (request k departs at k/rps seconds, threads take every
/// `clients`-th slot). A sender never waits for a reply before the next
/// slot comes due on its own schedule, so server-side queueing shows up
/// as latency instead of silently throttling the offered load.
fn open_loop(
    client: &Arc<TcpStoreClient>,
    rows: &[Vec<f32>],
    clients: usize,
    rps: f64,
    total: usize,
) -> Vec<f64> {
    let start = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|j| {
            let c = client.clone();
            let rows = rows.to_vec();
            std::thread::spawn(move || {
                let mut lat = Vec::new();
                let mut k = j;
                while k < total {
                    let due = Duration::from_secs_f64(k as f64 / rps);
                    let now = start.elapsed();
                    if due > now {
                        std::thread::sleep(due - now);
                    }
                    let t0 = Instant::now();
                    c.classify(&rows[k % rows.len()]).unwrap();
                    lat.push(t0.elapsed().as_secs_f64());
                    k += clients;
                }
                lat
            })
        })
        .collect();
    handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
}

/// Closed-loop hammer: every client keeps exactly one classify in
/// flight. Returns aggregate requests per second.
fn closed_loop_rate(client: &Arc<TcpStoreClient>, rows: &[Vec<f32>], clients: usize, per: u32) -> f64 {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|j| {
            let c = client.clone();
            let rows = rows.to_vec();
            std::thread::spawn(move || {
                for k in 0..per as usize {
                    c.classify(&rows[(j + k) % rows.len()]).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (clients as u32 * per) as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let opts = parse_opts();
    let mut report = JsonReport::new("micro_serve");
    let (warmup, iters) = if opts.quick { (1, 5) } else { (2, 20) };

    // --- in-process admission queue, no wire ---------------------------
    {
        let model = serve_model(opts.quick);
        let in_dim = model.net.layers[0].w.rows;
        let srv =
            BatchServer::start(model, native_factory(), ServeOptions::default()).unwrap();
        let rows = row_pool(64, in_dim);

        let n = if opts.quick { 200 } else { 1000 };
        let mut samples = Vec::with_capacity(n);
        for k in 0..n {
            let x = Matrix { rows: 1, cols: in_dim, data: rows[k % rows.len()].clone() };
            let t0 = Instant::now();
            srv.classify_blocking(x).unwrap();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = pct(&samples, 99.0);
        report.add(
            format!("[inproc] single-row classify  (p99 {:.3} ms)", p99 * 1e3),
            latency_stats(samples),
        );

        // 64-row frames through the same queue: amortized row throughput.
        let frame = {
            let mut rng = Rng::new(13);
            Matrix::rand_uniform(64, in_dim, 0.0, 1.0, &mut rng)
        };
        let s = bench(warmup, iters, || {
            srv.classify_blocking(frame.clone()).unwrap();
        });
        report.add(
            format!("[inproc] 64-row batch classify  ({:.0} rows/s)", 64.0 / s.min_s),
            s,
        );
        srv.shutdown();
    }

    // --- wire path: CLASSIFY over one multiplexed connection -----------
    {
        let (srv, server, in_dim) = start_stack(opts.quick);
        let client = Arc::new(TcpStoreClient::connect(server.addr).unwrap());
        let rows = row_pool(64, in_dim);

        // closed-loop round-trip latency, single requester
        let n = if opts.quick { 200 } else { 1000 };
        let mut samples = Vec::with_capacity(n);
        for k in 0..n {
            let t0 = Instant::now();
            client.classify(&rows[k % rows.len()]).unwrap();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99 = pct(&samples, 99.0);
        report.add(
            format!("[wire]   single-row classify round-trip  (p99 {:.3} ms)", p99 * 1e3),
            latency_stats(samples),
        );

        // open-loop arrival at a fixed offered load
        let (rps, total) = if opts.quick { (500.0, 1000) } else { (500.0, 5000) };
        let mut lat = open_loop(&client, &rows, 4, rps, total);
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (p95, p99) = (pct(&lat, 95.0), pct(&lat, 99.0));
        report.add(
            format!(
                "[wire]   open-loop classify @ 500 rps, 4 clients  (p95 {:.3} ms, p99 {:.3} ms)",
                p95 * 1e3,
                p99 * 1e3
            ),
            latency_stats(lat),
        );

        // batch frames across the wire
        let frame = {
            let mut rng = Rng::new(17);
            Matrix::rand_uniform(64, in_dim, 0.0, 1.0, &mut rng)
        };
        let s = bench(warmup, iters, || {
            client.classify_batch(&frame).unwrap();
        });
        report.add(
            format!("[wire]   classify_batch 64-row frames  ({:.0} rows/s)", 64.0 / s.min_s),
            s,
        );

        // saturation sweep: closed-loop clients doubling until the peak
        let per: u32 = if opts.quick { 100 } else { 400 };
        let mut peak = (0usize, 0.0f64);
        for clients in [1usize, 2, 4, 8] {
            let rate = closed_loop_rate(&client, &rows, clients, per);
            if rate > peak.1 {
                peak = (clients, rate);
            }
        }
        let s = BenchStats {
            iters: 15 * per,
            min_s: 1.0 / peak.1,
            mean_s: 1.0 / peak.1,
            p50_s: 1.0 / peak.1,
        };
        report.add(
            format!(
                "[wire]   saturation sweep, 1-8 clients  (peak {:.0}/s @ {} clients)",
                peak.1, peak.0
            ),
            s,
        );

        drop(client);
        server.shutdown();
        srv.shutdown();
    }

    report.write(opts.json.as_deref());
}
