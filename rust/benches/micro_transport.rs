//! Micro-benchmarks of the parameter store and TCP transport: publish/
//! fetch latency and throughput for paper-scale layer payloads, plus the
//! protocol-v2 headline numbers — blocking-wait wake latency (server-side
//! Condvar, no poll interval) and multiplexed in-flight throughput on one
//! connection.
//!
//! ```bash
//! cargo bench --bench micro_transport                       # full scale
//! cargo bench --bench micro_transport -- --quick            # CI smoke
//! cargo bench --bench micro_transport -- --json OUT.json    # perf artifact
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use pff::bench_util::{bench, BenchStats, JsonReport};
use pff::config::ExperimentConfig;
use pff::coordinator::store::{LayerDelta, LayerParams, MemStore, ParamStore};
use pff::coordinator::RunCheckpoint;
use pff::tensor::{Matrix, Rng};
use pff::transport::codec::WireCodec;
use pff::transport::tcp::{StoreServer, TcpStoreClient};

fn params(din: usize, dout: usize) -> LayerParams {
    let mut rng = Rng::new(1);
    LayerParams {
        w: Matrix::randn_scaled(din, dout, &mut rng),
        b: vec![0.0; dout],
        normalize_input: true,
        opt: None,
    }
}

struct Opts {
    quick: bool,
    json: Option<String>,
}

fn parse_opts() -> Opts {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = Opts { quick: false, json: None };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--quick" => {
                opts.quick = true;
                i += 1;
            }
            "--json" => {
                opts.json = args.get(i + 1).cloned();
                i += 2;
            }
            // tolerate cargo-bench passthrough flags like --bench
            _ => i += 1,
        }
    }
    opts
}

/// Stats from a pre-collected sample vector (seconds).
fn stats_of(mut samples: Vec<f64>) -> BenchStats {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    BenchStats {
        iters: samples.len() as u32,
        min_s: samples[0],
        mean_s: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_s: samples[samples.len() / 2],
    }
}

/// Publish→wakeup latency of a blocking get across the wire: a waiter
/// parks on `WAIT_LAYER` (server-side Condvar), and we time from just
/// before the publish until the waiter's response lands. Protocol v1
/// quantized this at its 5 ms poll interval; v2 should sit well under
/// 1 ms on localhost.
fn wait_wake_latency(n: u32) -> BenchStats {
    let mem = Arc::new(MemStore::new());
    let server = StoreServer::start(mem.clone(), 0).unwrap();
    let waiter_client = Arc::new(TcpStoreClient::connect(server.addr).unwrap());
    let publisher = TcpStoreClient::connect(server.addr).unwrap();
    let p = params(64, 64);

    let mut samples = Vec::with_capacity(n as usize);
    for chapter in 0..n {
        let wc = waiter_client.clone();
        let h = std::thread::spawn(move || {
            wc.get_layer(0, chapter, Duration::from_secs(5)).unwrap();
            Instant::now()
        });
        // Condvar handoff: publish only once the server-side wait thread is
        // provably parked on the store.
        mem.wait_for_waiters(1, Duration::from_secs(5)).unwrap();
        let t0 = Instant::now();
        publisher.put_layer(0, chapter, p.clone()).unwrap();
        let woke = h.join().unwrap();
        samples.push(woke.duration_since(t0).as_secs_f64());
    }
    server.shutdown();
    stats_of(samples)
}

/// Aggregate get throughput with `threads` concurrent in-flight requests
/// multiplexed over ONE connection.
fn multiplexed_gets(threads: usize, gets_per_thread: u32) -> f64 {
    let mem = Arc::new(MemStore::new());
    let server = StoreServer::start(mem, 0).unwrap();
    let client = Arc::new(TcpStoreClient::connect(server.addr).unwrap());
    client.put_layer(0, 0, params(64, 64)).unwrap();

    let t0 = Instant::now();
    let handles: Vec<_> = (0..threads)
        .map(|_| {
            let c = client.clone();
            std::thread::spawn(move || {
                for _ in 0..gets_per_thread {
                    c.get_layer(0, 0, Duration::from_secs(5)).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    server.shutdown();
    (threads as u32 * gets_per_thread) as f64 / secs
}

fn main() {
    let opts = parse_opts();
    let mut report = JsonReport::new("micro_transport");

    let sizes: &[(usize, usize, &str)] = if opts.quick {
        &[(256, 256, "reduced layer (256x256, 256 KB)")]
    } else {
        &[
            (256, 256, "reduced layer (256x256, 256 KB)"),
            (2000, 2000, "paper layer (2000x2000, 16 MB)"),
        ]
    };
    let (warmup, iters) = if opts.quick { (1, 5) } else { (2, 20) };

    for &(din, dout, label) in sizes {
        let p = params(din, dout);
        let mb = p.wire_bytes() as f64 / 1e6;

        // in-proc store
        let store = MemStore::new();
        let s = bench(warmup, iters, || {
            store.put_layer(0, 0, p.clone()).unwrap();
            store.get_layer(0, 0, Duration::from_secs(1)).unwrap();
        });
        report.add(
            format!("[inproc] put+get {label}  ({:.0} MB/s)", 2.0 * mb / s.min_s),
            s,
        );

        // tcp store
        let mem = Arc::new(MemStore::new());
        let server = StoreServer::start(mem, 0).unwrap();
        let client = TcpStoreClient::connect(server.addr).unwrap();
        let s = bench(warmup, iters.min(10), || {
            client.put_layer(0, 0, p.clone()).unwrap();
            client.get_layer(0, 0, Duration::from_secs(5)).unwrap();
        });
        report.add(format!("[tcp]    put+get {label}  ({:.0} MB/s)", 2.0 * mb / s.min_s), s);

        // delta vs full publish (PR 7): 8 changed rows against a base
        // chapter already on the server — the wire carries only those rows,
        // and the label reports the delta's fraction of the full frame.
        let mut next = p.clone();
        let step = (next.w.rows / 8).max(1);
        for r in (0..next.w.rows).step_by(step).take(8) {
            next.w.data[r * next.w.cols] += 1.0;
        }
        let delta_bytes = LayerDelta::diff(&p, &next).unwrap().wire_bytes();
        client.put_layer(0, 0, p.clone()).unwrap();
        let mut chapter = 0u32;
        let s = bench(warmup, iters.min(10), || {
            chapter += 1;
            let d = LayerDelta::diff(&p, &next).unwrap();
            client.put_layer_delta(0, chapter, 0, d).unwrap();
        });
        report.add(
            format!(
                "[tcp]    delta publish 8-row {label}  ({:.1}% of full wire)",
                100.0 * delta_bytes as f64 / p.wire_bytes() as f64
            ),
            s,
        );

        // quantized publish (PR 9): PUT_LAYER_Q ships a bf16/i8 frame;
        // the label reports the frame's share of the f32 full frame.
        for codec in [WireCodec::Bf16, WireCodec::I8] {
            let q = codec.quantize_layer(&p);
            let pct = 100.0 * q.wire_bytes() as f64 / p.wire_bytes() as f64;
            let mut chapter = 1000u32; // clear of the delta bench's chapters
            let s = bench(warmup, iters.min(10), || {
                chapter += 1;
                client.put_layer_q(0, chapter, codec.quantize_layer(&p)).unwrap();
            });
            report.add(
                format!("[tcp]    {codec} quantized publish {label}  ({pct:.1}% of f32 wire)"),
                s,
            );
        }
        server.shutdown();
    }

    // checkpoint encode with a quantized store section (PR 9, format v2):
    // the file shrinks by the same codec ratio, because published params
    // are codec fixed points and so keep their compact frames on disk.
    {
        let (din, dout) = if opts.quick { (256, 256) } else { (1000, 1000) };
        for codec in [WireCodec::Bf16, WireCodec::I8] {
            let store = MemStore::new();
            for l in 0..6usize {
                store.put_layer_q(l, 0, codec.quantize_layer(&params(din, dout))).unwrap();
            }
            let mut cfg = ExperimentConfig::tiny();
            cfg.wire_codec = codec;
            let ck = RunCheckpoint {
                config_kv: cfg.to_kv_string(),
                scheduler: "all_layers".into(),
                completed: vec![],
                rng: Rng::new(1).state(),
                store: store.dump(),
            };
            let raw = ck.encode_with(WireCodec::F32).len();
            let quant = ck.encode().len();
            let s = bench(warmup, iters, || {
                std::hint::black_box(ck.encode());
            });
            report.add(
                format!(
                    "[ckpt]   encode 6-entry {codec} store  ({:.1}% of f32 bytes)",
                    100.0 * quant as f64 / raw as f64
                ),
                s,
            );
        }
    }

    // COW store (PR 7): dump() of a store holding multi-MB entries is
    // O(entries) refcount bumps, not an O(bytes) deep copy...
    let (din, dout) = if opts.quick { (256, 256) } else { (1000, 1000) };
    let store = Arc::new(MemStore::new());
    for l in 0..12usize {
        store.put_layer(l, 0, params(din, dout)).unwrap();
    }
    let s = bench(warmup, iters, || {
        std::hint::black_box(store.dump());
    });
    report.add(format!("[store]  dump of 12-entry multi-MB store  ({:.1} us)", s.min_s * 1e6), s);

    // ...and therefore publishes stay fast while a dumper hot-loops (the
    // checkpoint-writer-stalls-the-pipeline regression, as a number).
    {
        use std::sync::atomic::{AtomicBool, Ordering};
        let stop = Arc::new(AtomicBool::new(false));
        let (s2, stop2) = (store.clone(), stop.clone());
        let dumper = std::thread::spawn(move || {
            let mut n = 0u64;
            while !stop2.load(Ordering::Relaxed) {
                std::hint::black_box(s2.dump());
                n += 1;
            }
            n
        });
        let p = params(din, dout);
        let mut chapter = 0u32;
        let s = bench(warmup, iters, || {
            chapter += 1;
            store.put_layer(0, chapter, p.clone()).unwrap();
        });
        stop.store(true, Ordering::Relaxed);
        let dumps = dumper.join().unwrap();
        report.add(format!("[store]  publish under hot dump loop  ({dumps} dumps raced)"), s);
    }

    // blocking-wait wake latency (the v2 acceptance number: p50 < 1 ms,
    // i.e. no 5 ms poll quantization anywhere on the dependency path)
    let s = wait_wake_latency(if opts.quick { 20 } else { 100 });
    report.add(
        format!("[tcp]    blocking-wait wake latency (p50 {:.3} ms)", s.p50_s * 1e3),
        s,
    );

    // multiplexing: concurrent in-flight gets on one connection
    let gets = if opts.quick { 50 } else { 200 };
    let rate = multiplexed_gets(8, gets);
    let s = BenchStats {
        iters: 8 * gets,
        min_s: 1.0 / rate,
        mean_s: 1.0 / rate,
        p50_s: 1.0 / rate,
    };
    report.add(format!("[tcp]    8-way multiplexed gets, one conn ({rate:.0}/s)"), s);

    // codec throughput in isolation
    let p = params(if opts.quick { 256 } else { 2000 }, if opts.quick { 256 } else { 2000 });
    let s = bench(warmup, iters, || {
        let mut e = pff::transport::codec::Enc::new();
        e.layer_params(&p);
        let buf = e.finish();
        let got = pff::transport::codec::Dec::new(&buf).layer_params().unwrap();
        std::hint::black_box(got);
    });
    let mb = p.wire_bytes() as f64 / 1e6;
    report.add(format!("[codec]  enc+dec layer ({:.0} MB/s)", 2.0 * mb / s.min_s), s);

    report.write(opts.json.as_deref());
}
