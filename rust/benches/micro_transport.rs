//! Micro-benchmarks of the parameter store and TCP transport: publish/
//! fetch latency and throughput for paper-scale layer payloads — the
//! coordinator-side §Perf working set.
//!
//! `cargo bench --bench micro_transport`

use std::sync::Arc;
use std::time::Duration;

use pff::bench_util::bench;
use pff::coordinator::store::{LayerParams, MemStore, ParamStore};
use pff::tensor::{Matrix, Rng};
use pff::transport::tcp::{StoreServer, TcpStoreClient};

fn params(din: usize, dout: usize) -> LayerParams {
    let mut rng = Rng::new(1);
    LayerParams {
        w: Matrix::randn_scaled(din, dout, &mut rng),
        b: vec![0.0; dout],
        normalize_input: true,
        opt: None,
    }
}

fn main() {
    for (din, dout, label) in [
        (256usize, 256usize, "reduced layer (256x256, 256 KB)"),
        (2000, 2000, "paper layer (2000x2000, 16 MB)"),
    ] {
        let p = params(din, dout);
        let mb = p.wire_bytes() as f64 / 1e6;

        // in-proc store
        let store = MemStore::new();
        let s = bench(2, 20, || {
            store.put_layer(0, 0, p.clone()).unwrap();
            store.get_layer(0, 0, Duration::from_secs(1)).unwrap();
        });
        println!(
            "{}",
            s.line(&format!("[inproc] put+get {label}  ({:.0} MB/s)", 2.0 * mb / s.min_s))
        );

        // tcp store
        let mem = Arc::new(MemStore::new());
        let server = StoreServer::start(mem, 0).unwrap();
        let client = TcpStoreClient::connect(server.addr).unwrap();
        let s = bench(2, 10, || {
            client.put_layer(0, 0, p.clone()).unwrap();
            client.get_layer(0, 0, Duration::from_secs(5)).unwrap();
        });
        println!(
            "{}",
            s.line(&format!("[tcp]    put+get {label}  ({:.0} MB/s)", 2.0 * mb / s.min_s))
        );
        server.shutdown();
    }

    // codec throughput in isolation
    let p = params(2000, 2000);
    let s = bench(2, 20, || {
        let mut e = pff::transport::codec::Enc::new();
        e.layer_params(&p);
        let buf = e.finish();
        let got = pff::transport::codec::Dec::new(&buf).layer_params().unwrap();
        std::hint::black_box(got);
    });
    let mb = p.wire_bytes() as f64 / 1e6;
    println!("{}", s.line(&format!("[codec]  enc+dec paper layer ({:.0} MB/s)", 2.0 * mb / s.min_s)));
}
