//! Bench: regenerate paper Table 1 (FF / DFF / PFF comparison, Goodness
//! classifier) — measured at reduced scale + DES at paper scale.
//!
//! `cargo bench --bench table1_pff_variants`
//! Env: PFF_SCALE=quick|reduced (default quick), PFF_SEED.

use pff::config::EngineKind;
use pff::harness::{table1, Scale};

fn main() {
    let scale = match std::env::var("PFF_SCALE").as_deref() {
        Ok("reduced") => Scale::reduced(),
        _ => Scale::quick(),
    };
    let seed = std::env::var("PFF_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t0 = std::time::Instant::now();
    table1::run(&scale, EngineKind::Native, seed).expect("table1 harness");
    println!("\n[bench] table1 total: {:.1}s", t0.elapsed().as_secs_f64());
}
