//! Bench: regenerate paper Table 2 (AdaptiveNEG: Goodness vs Softmax).
//!
//! `cargo bench --bench table2_adaptive_classifier`

use pff::config::EngineKind;
use pff::harness::{table2, Scale};

fn main() {
    let scale = match std::env::var("PFF_SCALE").as_deref() {
        Ok("reduced") => Scale::reduced(),
        _ => Scale::quick(),
    };
    let seed = std::env::var("PFF_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t0 = std::time::Instant::now();
    table2::run(&scale, EngineKind::Native, seed).expect("table2 harness");
    println!("\n[bench] table2 total: {:.1}s", t0.elapsed().as_secs_f64());
}
