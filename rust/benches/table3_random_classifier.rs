//! Bench: regenerate paper Table 3 (RandomNEG: Goodness vs Softmax).
//!
//! `cargo bench --bench table3_random_classifier`

use pff::config::EngineKind;
use pff::harness::{table3, Scale};

fn main() {
    let scale = match std::env::var("PFF_SCALE").as_deref() {
        Ok("reduced") => Scale::reduced(),
        _ => Scale::quick(),
    };
    let seed = std::env::var("PFF_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t0 = std::time::Instant::now();
    table3::run(&scale, EngineKind::Native, seed).expect("table3 harness");
    println!("\n[bench] table3 total: {:.1}s", t0.elapsed().as_secs_f64());
}
