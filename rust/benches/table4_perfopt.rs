//! Bench: regenerate paper Table 4 (Performance-Optimized model, MNIST).
//!
//! `cargo bench --bench table4_perfopt`

use pff::config::EngineKind;
use pff::harness::{table4, Scale};

fn main() {
    let scale = match std::env::var("PFF_SCALE").as_deref() {
        Ok("reduced") => Scale::reduced(),
        _ => Scale::quick(),
    };
    let seed = std::env::var("PFF_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t0 = std::time::Instant::now();
    table4::run(&scale, EngineKind::Native, seed).expect("table4 harness");
    println!("\n[bench] table4 total: {:.1}s", t0.elapsed().as_secs_f64());
}
