//! Bench: regenerate paper Table 5 (CIFAR-10 grid on synthetic
//! CIFAR-geometry data).
//!
//! `cargo bench --bench table5_cifar`

use pff::config::EngineKind;
use pff::harness::{table5, Scale};

fn main() {
    let scale = match std::env::var("PFF_SCALE").as_deref() {
        Ok("reduced") => Scale::reduced(),
        _ => Scale::quick(),
    };
    let seed = std::env::var("PFF_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);
    let t0 = std::time::Instant::now();
    table5::run(&scale, EngineKind::Native, seed).expect("table5 harness");
    println!("\n[bench] table5 total: {:.1}s", t0.elapsed().as_secs_f64());
}
