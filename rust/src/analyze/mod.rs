//! `pff analyze` — offline, std-only static analysis over the repo tree.
//!
//! The analyzer enforces *repo invariants*: cross-file consistency rules
//! the compiler cannot see (wire opcodes vs `PROTOCOL.md`, config keys vs
//! the README table) and project discipline the type system does not
//! encode (no `thread::sleep` synchronization, no printing from library
//! code, ranked locks only in the coordinator/transport). It is purely
//! lexical/structural — no rustc, no network, no dependencies — so it
//! runs identically on a laptop and in the blocking `analyze` CI job.
//!
//! A finding can be silenced at the site with an inline pragma, always
//! with a reason:
//!
//! ```text
//! // pff-allow(no-sleep-sync): error-path backoff, not synchronization.
//! std::thread::sleep(delay);
//! ```
//!
//! The pragma may sit on the offending line or anywhere in the block of
//! `//` comment lines immediately above it.

pub mod rules;

use std::fmt;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// How bad a finding is. Every current rule reports [`Severity::Error`];
/// the distinction exists so future advisory rules don't need a schema
/// change (JSON consumers already see a `severity` field).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Advisory: reported, still fails the run (exit is on any finding).
    Warning,
    /// A violated repo invariant.
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Severity::Warning => write!(f, "warning"),
            Severity::Error => write!(f, "error"),
        }
    }
}

/// One finding: a rule, a place, a message.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    /// Rule id, e.g. `lock-discipline` (also the `pff-allow(..)` key).
    pub rule: &'static str,
    /// File the finding is in (normalized to forward slashes).
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Finding severity.
    pub severity: Severity,
    /// Human explanation of the violated invariant.
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}: {}:{}: [{}] {}",
            self.severity, self.file, self.line, self.rule, self.message
        )
    }
}

/// One file of the analyzed tree, held entirely in memory.
pub struct SourceFile {
    /// Path as given (used for display and scope decisions).
    pub path: PathBuf,
    /// Normalized path string: forward slashes only.
    pub key: String,
    /// Full file contents.
    pub text: String,
    /// Line starts are implicit; rules index by line via `lines()`.
    lines: Vec<String>,
}

impl SourceFile {
    /// Build a file from a path and its contents (tests use literals).
    pub fn new(path: impl Into<PathBuf>, text: impl Into<String>) -> Self {
        let path = path.into();
        let text = text.into();
        let key = path.to_string_lossy().replace('\\', "/");
        let lines = text.lines().map(str::to_owned).collect();
        SourceFile { path, key, text, lines }
    }

    /// The file's lines, without terminators.
    pub fn lines(&self) -> &[String] {
        &self.lines
    }

    /// Does the normalized path end with `suffix` (component-aligned)?
    pub fn ends_with(&self, suffix: &str) -> bool {
        self.key == suffix
            || self
                .key
                .strip_suffix(suffix)
                .map(|pre| pre.ends_with('/'))
                .unwrap_or(false)
    }
}

/// The set of files under analysis, in deterministic (sorted) order.
pub struct Tree {
    files: Vec<SourceFile>,
}

impl Tree {
    /// Build a tree from in-memory files (fixture tests).
    pub fn from_files(mut files: Vec<SourceFile>) -> Self {
        files.sort_by(|a, b| a.key.cmp(&b.key));
        Tree { files }
    }

    /// Load every `.rs` / `.md` file under `roots` (files are taken
    /// as-is; directories are walked recursively, skipping hidden
    /// entries and `target/`).
    pub fn load(roots: &[PathBuf]) -> Result<Self> {
        let mut files = Vec::new();
        for root in roots {
            if root.is_file() {
                files.push(read_source(root)?);
            } else if root.is_dir() {
                walk(root, &mut files)?;
            } else {
                bail!("analyze: path '{}' does not exist", root.display());
            }
        }
        Ok(Tree::from_files(files))
    }

    /// All files, sorted by normalized path.
    pub fn files(&self) -> &[SourceFile] {
        &self.files
    }

    /// First file whose path ends with `suffix` (component-aligned).
    pub fn find(&self, suffix: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.ends_with(suffix))
    }
}

fn read_source(path: &Path) -> Result<SourceFile> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("analyze: reading {}", path.display()))?;
    Ok(SourceFile::new(path, text))
}

fn walk(dir: &Path, out: &mut Vec<SourceFile>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("analyze: listing {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name.starts_with('.') || name == "target" {
            continue;
        }
        if p.is_dir() {
            walk(&p, out)?;
        } else if matches!(p.extension().and_then(|e| e.to_str()), Some("rs" | "md")) {
            out.push(read_source(&p)?);
        }
    }
    Ok(())
}

/// The roots `pff analyze` scans when given no paths: the crate sources,
/// the integration tests, the examples, and the README — resolved
/// relative to the current directory, which may be the repo root or
/// `rust/`.
pub fn default_roots() -> Result<Vec<PathBuf>> {
    let cwd = std::env::current_dir().context("analyze: no working directory")?;
    let base = if cwd.join("rust/src").is_dir() {
        cwd
    } else if cwd.join("src").is_dir() && cwd.join("../examples").is_dir() {
        cwd.join("..")
    } else {
        bail!(
            "analyze: run from the repo root (or rust/), or pass explicit PATHS; \
             '{}' holds neither rust/src nor src",
            cwd.display()
        );
    };
    let mut roots = vec![base.join("rust/src"), base.join("rust/tests"), base.join("examples")];
    let readme = base.join("README.md");
    if readme.is_file() {
        roots.push(readme);
    }
    Ok(roots)
}

/// Is a finding of `rule` at 0-based line `idx` suppressed by a
/// `pff-allow(rule)` pragma — on the line itself, or anywhere in the
/// contiguous block of `//` comment lines immediately above it?
pub fn is_suppressed(file: &SourceFile, idx: usize, rule: &str) -> bool {
    let needle = format!("pff-allow({rule})");
    let lines = file.lines();
    if lines.get(idx).map(|l| l.contains(&needle)).unwrap_or(false) {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = lines[i].trim_start();
        if !t.starts_with("//") {
            break;
        }
        if t.contains(&needle) {
            return true;
        }
    }
    false
}

/// Record a finding unless an inline pragma suppresses it.
/// `idx` is 0-based; the stored line is 1-based.
pub(crate) fn emit(
    out: &mut Vec<Diagnostic>,
    file: &SourceFile,
    idx: usize,
    rule: &'static str,
    message: String,
) {
    if is_suppressed(file, idx, rule) {
        return;
    }
    out.push(Diagnostic {
        rule,
        file: file.key.clone(),
        line: idx + 1,
        severity: Severity::Error,
        message,
    });
}

/// Run every rule over the tree; findings come back sorted by
/// `(file, line, rule)` so output is deterministic.
pub fn analyze(tree: &Tree) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for rule in rules::ALL {
        (rule.check)(tree, &mut out);
    }
    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

/// Human-readable report: one line per finding.
pub fn render_human(diags: &[Diagnostic]) -> String {
    use std::fmt::Write;
    let mut s = String::new();
    for d in diags {
        let _ = writeln!(s, "{d}");
    }
    s
}

/// Machine-readable report (hand-rolled JSON; the crate is std-only).
pub fn render_json(diags: &[Diagnostic]) -> String {
    use std::fmt::Write;
    let mut s = String::from("{\"findings\":[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "{{\"rule\":{},\"file\":{},\"line\":{},\"severity\":{},\"message\":{}}}",
            json_str(d.rule),
            json_str(&d.file),
            d.line,
            json_str(&d.severity.to_string()),
            json_str(&d.message),
        );
    }
    let _ = write!(s, "],\"count\":{}}}", diags.len());
    s
}

fn json_str(v: &str) -> String {
    let mut s = String::with_capacity(v.len() + 2);
    s.push('"');
    for c in v.chars() {
        match c {
            '"' => s.push_str("\\\""),
            '\\' => s.push_str("\\\\"),
            '\n' => s.push_str("\\n"),
            '\r' => s.push_str("\\r"),
            '\t' => s.push_str("\\t"),
            c if (c as u32) < 0x20 => s.push_str(&format!("\\u{:04x}", c as u32)),
            c => s.push(c),
        }
    }
    s.push('"');
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(text: &str) -> SourceFile {
        SourceFile::new("x/y.rs", text)
    }

    #[test]
    fn pragma_on_the_line_suppresses() {
        let f = file("std::thread::sleep(d); // pff-allow(no-sleep-sync): backoff\n");
        assert!(is_suppressed(&f, 0, "no-sleep-sync"));
        assert!(!is_suppressed(&f, 0, "lock-discipline"), "wrong rule must not match");
    }

    #[test]
    fn pragma_in_the_comment_block_above_suppresses() {
        let f = file(
            "// pff-allow(no-sleep-sync): connection backoff against a\n\
             // leader that has not bound its listener yet — three lines\n\
             // of justification, pragma on the first.\n\
             std::thread::sleep(d);\n",
        );
        assert!(is_suppressed(&f, 3, "no-sleep-sync"));
    }

    #[test]
    fn pragma_does_not_leak_past_code() {
        let f = file(
            "// pff-allow(no-sleep-sync): covers only the next statement\n\
             std::thread::sleep(a);\n\
             std::thread::sleep(b);\n",
        );
        assert!(is_suppressed(&f, 1, "no-sleep-sync"));
        assert!(!is_suppressed(&f, 2, "no-sleep-sync"), "code line breaks the block");
    }

    #[test]
    fn ends_with_is_component_aligned() {
        let f = SourceFile::new("rust/src/transport/tcp.rs", "");
        assert!(f.ends_with("transport/tcp.rs"));
        assert!(f.ends_with("tcp.rs"));
        assert!(!f.ends_with("ansport/tcp.rs"), "partial component must not match");
    }

    #[test]
    fn json_escapes_and_counts() {
        let d = Diagnostic {
            rule: "config-keys",
            file: "a\"b.rs".into(),
            line: 3,
            severity: Severity::Error,
            message: "tab\there".into(),
        };
        let j = render_json(&[d]);
        assert!(j.contains("\"count\":1"), "{j}");
        assert!(j.contains("a\\\"b.rs"), "{j}");
        assert!(j.contains("tab\\there"), "{j}");
        assert_eq!(render_json(&[]), "{\"findings\":[],\"count\":0}");
    }

    #[test]
    fn findings_sort_deterministically() {
        let mk = |file: &str, line| Diagnostic {
            rule: "no-sleep-sync",
            file: file.into(),
            line,
            severity: Severity::Error,
            message: String::new(),
        };
        let t = Tree::from_files(vec![]);
        assert!(analyze(&t).is_empty(), "empty tree is clean");
        let mut v = vec![mk("b.rs", 1), mk("a.rs", 9), mk("a.rs", 2)];
        v.sort_by(|a, b| (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line)));
        assert_eq!(v[0].file, "a.rs");
        assert_eq!(v[0].line, 2);
    }
}
