//! The repo-invariant rules behind `pff analyze`.
//!
//! Each rule is a plain `fn(&Tree, &mut Vec<Diagnostic>)` registered in
//! [`ALL`]. Rules come in two shapes:
//!
//! * **structural** — cross-file consistency the compiler cannot check:
//!   [`wire_opcodes`] (tcp.rs ↔ PROTOCOL.md), [`config_keys`]
//!   (`ExperimentConfig::set` ↔ `to_kv_string` ↔ README table),
//!   [`event_csv_exhaustive`] (`RunEvent` ↔ Display ↔ CSV projection);
//! * **lexical** — per-line discipline: [`no_sleep_sync`],
//!   [`no_print_in_lib`], [`lock_discipline`].
//!
//! A rule that cannot find its anchor file (e.g. `pff analyze src/ff.rs`
//! loads no `PROTOCOL.md`) reports nothing: scoped runs check what they
//! can see, the full default-root run checks everything.

use super::{emit, Diagnostic, SourceFile, Tree};

/// One registered rule.
pub struct Rule {
    /// Rule id — also the `pff-allow(id)` suppression key.
    pub id: &'static str,
    /// One-line description for docs and `--help`.
    pub summary: &'static str,
    /// The check itself.
    pub check: fn(&Tree, &mut Vec<Diagnostic>),
}

/// Every rule, in documentation order.
pub const ALL: &[Rule] = &[
    Rule {
        id: "wire-opcodes",
        summary: "wire opcode consts are unique, version-gated consistently, \
                  and documented in PROTOCOL.md",
        check: wire_opcodes,
    },
    Rule {
        id: "config-keys",
        summary: "every ExperimentConfig::set key round-trips through \
                  to_kv_string and appears in the README config table",
        check: config_keys,
    },
    Rule {
        id: "no-sleep-sync",
        summary: "no thread::sleep synchronization in library or test code",
        check: no_sleep_sync,
    },
    Rule {
        id: "no-print-in-lib",
        summary: "library modules emit RunEvents, they do not print",
        check: no_print_in_lib,
    },
    Rule {
        id: "event-csv-exhaustive",
        summary: "every RunEvent variant is rendered by Display and \
                  projected by event_csv_row",
        check: event_csv_exhaustive,
    },
    Rule {
        id: "lock-discipline",
        summary: "coordinator/transport code takes ranked locks \
                  (sync::OrderedMutex), never raw std primitives",
        check: lock_discipline,
    },
];

// --- shared lexical helpers -------------------------------------------------

/// Is the line comment-only (`//`, `///`, `//!`)?
fn is_comment(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// The code portion of a line: everything before a trailing `//` comment.
/// (`://` is kept — URLs in strings are not comments.)
fn code_part(line: &str) -> &str {
    let b = line.as_bytes();
    for i in 0..b.len().saturating_sub(1) {
        if b[i] == b'/' && b[i + 1] == b'/' && (i == 0 || b[i - 1] != b':') {
            return &line[..i];
        }
    }
    line
}

/// Net brace depth change of a code fragment. Format-string braces are
/// always balanced, so counting raw characters is exact enough here.
fn net_braces(code: &str) -> i32 {
    let mut n = 0;
    for c in code.chars() {
        match c {
            '{' => n += 1,
            '}' => n -= 1,
            _ => {}
        }
    }
    n
}

/// `(start, end)` line indices of the brace block opened on `start`
/// (inclusive of the closing line).
fn block_range(lines: &[String], start: usize) -> (usize, usize) {
    let mut depth = 0i32;
    let mut opened = false;
    for (i, l) in lines.iter().enumerate().skip(start) {
        if is_comment(l) {
            continue;
        }
        let code = code_part(l);
        if code.contains('{') {
            opened = true;
        }
        depth += net_braces(code);
        if opened && depth <= 0 {
            return (start, i);
        }
    }
    (start, lines.len().saturating_sub(1))
}

/// Line ranges covered by `#[cfg(test)]` items (test mods and helpers).
fn test_regions(lines: &[String]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut i = 0;
    while i < lines.len() {
        if lines[i].trim_start().starts_with("#[cfg(test)]") {
            // The guarded item's opening brace is on this or a nearby line
            // (attributes and signatures are short in this codebase).
            let open = (i..lines.len().min(i + 5))
                .find(|&j| code_part(&lines[j]).contains('{'));
            if let Some(j) = open {
                let (_, end) = block_range(lines, j);
                regions.push((i, end));
                i = end + 1;
                continue;
            }
        }
        i += 1;
    }
    regions
}

fn in_regions(regions: &[(usize, usize)], idx: usize) -> bool {
    regions.iter().any(|&(a, b)| idx >= a && idx <= b)
}

/// Does `code` contain `tok` as a token (previous char not `[A-Za-z0-9_]`)?
/// `OrderedMutex` therefore does not count as a `Mutex` hit.
fn has_token(code: &str, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find(tok) {
        let i = from + pos;
        let pre_ident =
            i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_');
        if !pre_ident {
            return true;
        }
        from = i + tok.len();
    }
    false
}

/// Scan the contiguous `//` comment block above `idx` for `v<N>+`
/// (a version-gate marker like "v3+ only").
fn version_gate_above(lines: &[String], idx: usize) -> Option<u32> {
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let t = lines[i].trim_start();
        if !t.starts_with("//") {
            return None;
        }
        if let Some(v) = find_version_gate(t) {
            return Some(v);
        }
    }
    None
}

/// Find `v<digits>+` in a string.
fn find_version_gate(s: &str) -> Option<u32> {
    let b = s.as_bytes();
    for i in 0..b.len() {
        if b[i] == b'v' && i + 1 < b.len() && b[i + 1].is_ascii_digit() {
            let mut j = i + 1;
            while j < b.len() && b[j].is_ascii_digit() {
                j += 1;
            }
            if j < b.len() && b[j] == b'+' {
                return s[i + 1..j].parse().ok();
            }
        }
    }
    None
}

// --- rule: wire-opcodes -----------------------------------------------------

/// Parse `pub const NAME: u8 = 0xHH;` lines of `mod op` in tcp.rs, plus
/// the two protocol version consts; cross-check against PROTOCOL.md.
fn wire_opcodes(tree: &Tree, out: &mut Vec<Diagnostic>) {
    const RULE: &str = "wire-opcodes";
    let Some(tcp) = tree.find("transport/tcp.rs") else { return };
    let lines = tcp.lines();

    let parse_u8_const = |name: &str| -> Option<(usize, u32)> {
        let pat = format!("pub const {name}: u8 =");
        lines.iter().enumerate().find_map(|(i, l)| {
            let code = code_part(l);
            let rest = code.split(&pat as &str).nth(1)?;
            let v = rest.trim().trim_end_matches(';').trim();
            let parsed = v
                .strip_prefix("0x")
                .and_then(|h| u32::from_str_radix(h, 16).ok())
                .or_else(|| v.parse().ok())?;
            Some((i, parsed))
        })
    };

    let Some(start) = lines
        .iter()
        .position(|l| !is_comment(l) && code_part(l).contains("mod op"))
    else {
        return;
    };
    let (_, end) = block_range(lines, start);

    // (line, NAME, value, version gate from the comment above)
    let mut ops: Vec<(usize, String, u32, Option<u32>)> = Vec::new();
    for i in start..=end.min(lines.len() - 1) {
        let t = lines[i].trim_start();
        if !t.starts_with("pub const ") {
            continue;
        }
        let Some(rest) = t.strip_prefix("pub const ") else { continue };
        let Some((name, tail)) = rest.split_once(':') else { continue };
        if !tail.contains("u8") {
            continue;
        }
        let Some(val) = tail.split('=').nth(1) else { continue };
        let val = val.trim().trim_end_matches(';').trim();
        let Some(v) =
            val.strip_prefix("0x").and_then(|h| u32::from_str_radix(h, 16).ok())
        else {
            emit(
                out,
                tcp,
                i,
                RULE,
                format!("opcode {name} is not written as a hex literal ({val})"),
            );
            continue;
        };
        ops.push((i, name.trim().to_string(), v, version_gate_above(lines, i)));
    }

    // Uniqueness.
    for (k, (i, name, v, _)) in ops.iter().enumerate() {
        if let Some((_, first, _, _)) = ops[..k].iter().find(|(_, _, pv, _)| pv == v) {
            emit(
                out,
                tcp,
                *i,
                RULE,
                format!("duplicate wire opcode {v:#04x}: {name} collides with {first}"),
            );
        }
    }

    let cur = parse_u8_const("PROTOCOL_VERSION");
    let min = parse_u8_const("MIN_PROTOCOL_VERSION");

    let Some(proto) = tree.find("PROTOCOL.md") else { return };
    let ptext = &proto.text;

    if let (Some((cur_i, cur_v)), Some((min_i, min_v))) = (cur, min) {
        if !ptext.contains(&format!("[{min_v}, {cur_v}]")) {
            emit(
                out,
                tcp,
                min_i,
                RULE,
                format!(
                    "HELLO negotiation range [{min_v}, {cur_v}] is not stated in \
                     PROTOCOL.md (the handshake section must quote the range)"
                ),
            );
        }
        if !proto.lines().first().map(|l| l.contains(&format!("v{cur_v}"))).unwrap_or(false)
        {
            emit(
                out,
                tcp,
                cur_i,
                RULE,
                format!("PROTOCOL.md's title does not name protocol v{cur_v}"),
            );
        }
        for (i, name, v, gate) in &ops {
            if let Some(g) = gate {
                if *g > cur_v {
                    emit(
                        out,
                        tcp,
                        *i,
                        RULE,
                        format!(
                            "{name} is gated at v{g}+ but PROTOCOL_VERSION is {cur_v}"
                        ),
                    );
                }
            }
            let row = proto
                .lines()
                .iter()
                .enumerate()
                .find(|(_, l)| l.trim_start().starts_with(&format!("| {v:#04x}")));
            match row {
                None => emit(
                    out,
                    tcp,
                    *i,
                    RULE,
                    format!(
                        "opcode {v:#04x} ({name}) is missing from the PROTOCOL.md \
                         opcode table"
                    ),
                ),
                Some((_, l)) => {
                    if !has_token(l, name) {
                        emit(
                            out,
                            tcp,
                            *i,
                            RULE,
                            format!(
                                "PROTOCOL.md documents {v:#04x} under a different \
                                 name than {name}"
                            ),
                        );
                    }
                    match gate {
                        Some(g) if !l.contains(&format!("(v{g}+)")) => emit(
                            out,
                            tcp,
                            *i,
                            RULE,
                            format!(
                                "{name} is version-gated (v{g}+ in its comment) but \
                                 its PROTOCOL.md row is not marked (v{g}+)"
                            ),
                        ),
                        None if l.contains("(v") => emit(
                            out,
                            tcp,
                            *i,
                            RULE,
                            format!(
                                "PROTOCOL.md marks {v:#04x} version-gated but \
                                 {name}'s comment carries no v<N>+ gate"
                            ),
                        ),
                        _ => {}
                    }
                }
            }
        }
    }

    // Reverse direction: no documented opcode without a const.
    for (i, l) in proto.lines().iter().enumerate() {
        let t = l.trim_start();
        let Some(rest) = t.strip_prefix("| 0x") else { continue };
        let hex: String = rest.chars().take_while(|c| c.is_ascii_hexdigit()).collect();
        let Ok(v) = u32::from_str_radix(&hex, 16) else { continue };
        if !ops.iter().any(|(_, _, ov, _)| *ov == v) {
            emit(
                out,
                proto,
                i,
                RULE,
                format!(
                    "PROTOCOL.md documents opcode {v:#04x} which transport/tcp.rs \
                     does not define"
                ),
            );
        }
    }
}

// --- rule: config-keys ------------------------------------------------------

/// Extract the key literals of `ExperimentConfig::set`'s top-level match
/// and require each to (a) appear quoted outside `set` — which in this
/// crate means the `to_kv_string` emitter the round-trip test diffs —
/// and (b) appear backticked in the README configuration table.
fn config_keys(tree: &Tree, out: &mut Vec<Diagnostic>) {
    const RULE: &str = "config-keys";
    let Some(cfg) = tree.find("config/mod.rs") else { return };
    let lines = cfg.lines();

    let Some(set_start) = lines.iter().position(|l| code_part(l).contains("pub fn set("))
    else {
        return;
    };
    let Some(match_line) = (set_start..lines.len().min(set_start + 6))
        .find(|&i| code_part(&lines[i]).contains("match key"))
    else {
        return;
    };

    // (line, key) arms at depth 1 of the match.
    let mut keys: Vec<(usize, String)> = Vec::new();
    let mut depth = 0i32;
    let mut match_end = match_line;
    for (i, l) in lines.iter().enumerate().skip(match_line) {
        if is_comment(l) {
            continue;
        }
        let code = code_part(l);
        if depth == 1 {
            let t = code.trim_start();
            if let Some(rest) = t.strip_prefix('"') {
                if let Some((key, tail)) = rest.split_once('"') {
                    if tail.contains("=>") {
                        keys.push((i, key.to_string()));
                    }
                }
            }
        }
        depth += net_braces(code);
        if i > match_line && depth <= 0 {
            match_end = i;
            break;
        }
    }

    let readme = tree.find("README.md");
    for (i, key) in &keys {
        let quoted = format!("\"{key}\"");
        let outside = lines
            .iter()
            .enumerate()
            .any(|(j, l)| (j < set_start || j > match_end) && l.contains(&quoted));
        if !outside {
            emit(
                out,
                cfg,
                *i,
                RULE,
                format!(
                    "config key '{key}' is set-only: it never appears quoted outside \
                     ExperimentConfig::set, so to_kv_string (and the kv round-trip \
                     test) cannot be covering it"
                ),
            );
        }
        if let Some(rd) = readme {
            if !rd.text.contains(&format!("`{key}`")) {
                emit(
                    out,
                    cfg,
                    *i,
                    RULE,
                    format!(
                        "config key '{key}' is missing from the README configuration \
                         table (expected a backticked `{key}` entry)"
                    ),
                );
            }
        }
    }
}

// --- rule: no-sleep-sync ----------------------------------------------------

/// `thread::sleep` in `src/` or `tests/` is a poll where a Condvar (or a
/// store/event wait) belongs. Genuine backoffs carry a pragma.
fn no_sleep_sync(tree: &Tree, out: &mut Vec<Diagnostic>) {
    const RULE: &str = "no-sleep-sync";
    for f in tree.files() {
        let in_scope = f.key.ends_with(".rs")
            && (f.key.contains("src/") || f.key.contains("tests/"))
            && !f.key.contains("src/analyze/");
        if !in_scope {
            continue;
        }
        for (i, l) in f.lines().iter().enumerate() {
            if is_comment(l) {
                continue;
            }
            if code_part(l).contains("thread::sleep") {
                emit(
                    out,
                    f,
                    i,
                    RULE,
                    "thread::sleep used as synchronization — park on a Condvar or \
                     an event (sync::OrderedCondvar, store waits, wait_for_waiters) \
                     instead; pff-allow(no-sleep-sync) only for genuine backoff or \
                     measured workloads"
                        .into(),
                );
            }
        }
    }
}

// --- rule: no-print-in-lib --------------------------------------------------

/// Library modules report through the `RunEvent` bus; printing belongs
/// to the binary (`main.rs`, `src/bin/`) and to tests.
fn no_print_in_lib(tree: &Tree, out: &mut Vec<Diagnostic>) {
    const RULE: &str = "no-print-in-lib";
    const TOKENS: &[&str] = &["println!", "eprintln!", "print!", "eprint!"];
    for f in tree.files() {
        let in_scope = f.key.ends_with(".rs")
            && f.key.contains("src/")
            && !f.key.ends_with("main.rs")
            && !f.key.contains("/bin/")
            && !f.key.contains("src/analyze/")
            && !f.key.ends_with("bench_util.rs");
        if !in_scope {
            continue;
        }
        let tests = test_regions(f.lines());
        for (i, l) in f.lines().iter().enumerate() {
            if is_comment(l) || in_regions(&tests, i) {
                continue;
            }
            let code = code_part(l);
            if TOKENS.iter().any(|t| has_token(code, t)) {
                emit(
                    out,
                    f,
                    i,
                    RULE,
                    "library code must not print — emit a RunEvent on the bus and \
                     let the binary's observer decide what reaches stderr"
                        .into(),
                );
            }
        }
    }
}

// --- rule: event-csv-exhaustive ---------------------------------------------

/// Every `RunEvent` variant must be rendered by the Display impl and
/// projected by `metrics::csv::event_csv_row`, and the projection must
/// not hide behind a wildcard arm.
fn event_csv_exhaustive(tree: &Tree, out: &mut Vec<Diagnostic>) {
    const RULE: &str = "event-csv-exhaustive";
    let Some(ev) = tree.find("coordinator/events.rs") else { return };
    let lines = ev.lines();

    let Some(enum_start) =
        lines.iter().position(|l| code_part(l).contains("pub enum RunEvent"))
    else {
        return;
    };

    // (line, Variant) at depth 1 of the enum body.
    let mut variants: Vec<(usize, String)> = Vec::new();
    let mut depth = 0i32;
    for (i, l) in lines.iter().enumerate().skip(enum_start) {
        if is_comment(l) {
            continue;
        }
        let code = code_part(l);
        if depth == 1 {
            let t = code.trim_start();
            if t.starts_with(|c: char| c.is_ascii_uppercase()) {
                let name: String = t
                    .chars()
                    .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                    .collect();
                let tail = t[name.len()..].trim_start();
                if tail.is_empty() || tail.starts_with(['{', '(', ',']) {
                    variants.push((i, name));
                }
            }
        }
        depth += net_braces(code);
        if i > enum_start && depth <= 0 {
            break;
        }
    }

    let region_text = |file: &SourceFile, start: usize| -> String {
        let (_, end) = block_range(file.lines(), start);
        file.lines()[start..=end].join("\n")
    };

    let display = lines
        .iter()
        .position(|l| {
            let c = code_part(l);
            c.contains("impl") && c.contains("Display for RunEvent")
        })
        .map(|start| region_text(ev, start));

    let csv = tree.find("metrics/csv.rs");
    let csv_region = csv.and_then(|f| {
        f.lines()
            .iter()
            .position(|l| code_part(l).contains("fn event_csv_row"))
            .map(|start| (f, start, region_text(f, start)))
    });

    for (i, name) in &variants {
        let qualified = format!("RunEvent::{name}");
        if let Some(d) = &display {
            if !d.contains(&qualified) {
                emit(
                    out,
                    ev,
                    *i,
                    RULE,
                    format!("{qualified} is not rendered by the Display impl"),
                );
            }
        }
        if let Some((_, _, text)) = &csv_region {
            if !text.contains(&qualified) {
                emit(
                    out,
                    ev,
                    *i,
                    RULE,
                    format!(
                        "{qualified} has no event_csv_row projection in \
                         metrics/csv.rs"
                    ),
                );
            }
        }
    }
    if let Some((csv_file, start, _)) = &csv_region {
        let (_, end) = block_range(csv_file.lines(), *start);
        for i in *start..=end {
            if is_comment(&csv_file.lines()[i]) {
                continue;
            }
            if code_part(&csv_file.lines()[i]).trim_start().starts_with("_ =>") {
                emit(
                    out,
                    csv_file,
                    i,
                    RULE,
                    "wildcard arm in event_csv_row defeats the exhaustiveness \
                     guarantee — name every RunEvent variant"
                        .into(),
                );
            }
        }
    }
}

// --- rule: lock-discipline --------------------------------------------------

/// Coordinator/transport modules (and the tensor pool) take locks only
/// through `sync::OrderedMutex` / `sync::OrderedCondvar`, whose static
/// `LockRank`s make acquisition order a debug-mode assertion instead of
/// a code-review hope. Raw std primitives — and the `.lock().unwrap()`
/// idiom the wrappers make impossible — are findings.
fn lock_discipline(tree: &Tree, out: &mut Vec<Diagnostic>) {
    const RULE: &str = "lock-discipline";
    const RAW: &[&str] = &["Mutex", "Condvar", "RwLock"];
    for f in tree.files() {
        let in_scope = f.key.ends_with(".rs")
            && (f.key.contains("coordinator/")
                || f.key.contains("transport/")
                || f.ends_with("tensor/pool.rs"));
        if !in_scope {
            continue;
        }
        for (i, l) in f.lines().iter().enumerate() {
            if is_comment(l) {
                continue;
            }
            let code = code_part(l);
            if let Some(tok) = RAW.iter().find(|t| has_token(code, t)) {
                emit(
                    out,
                    f,
                    i,
                    RULE,
                    format!(
                        "raw std {tok} in a ranked-lock module — use \
                         sync::OrderedMutex / sync::OrderedCondvar with a LockRank"
                    ),
                );
            } else if code.contains(".lock().unwrap()") {
                emit(
                    out,
                    f,
                    i,
                    RULE,
                    ".lock().unwrap() — OrderedMutex::lock is infallible (it \
                     recovers poisoning); this call site is holding a raw lock"
                        .into(),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyze::analyze;

    fn run_rule(id: &str, files: Vec<SourceFile>) -> Vec<Diagnostic> {
        let tree = Tree::from_files(files);
        let rule = ALL.iter().find(|r| r.id == id).expect("known rule id");
        let mut out = Vec::new();
        (rule.check)(&tree, &mut out);
        out
    }

    fn f(path: &str, text: &str) -> SourceFile {
        SourceFile::new(path, text)
    }

    #[test]
    fn rule_ids_are_unique_and_complete() {
        let mut ids: Vec<&str> = ALL.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        let n = ids.len();
        ids.dedup();
        assert_eq!(ids.len(), n, "duplicate rule ids");
        assert_eq!(n, 6, "six rules ship with this analyzer");
    }

    // -- wire-opcodes fixtures --

    const TCP_OK: &str = "pub const PROTOCOL_VERSION: u8 = 3;\n\
        pub const MIN_PROTOCOL_VERSION: u8 = 2;\n\
        mod op {\n\
        \u{20}   pub const HELLO: u8 = 0x01;\n\
        \u{20}   pub const PUT: u8 = 0x10;\n\
        \u{20}   /// v3+ only: delta publish.\n\
        \u{20}   pub const PUT_DELTA: u8 = 0x25;\n\
        }\n";

    const PROTO_OK: &str = "# wire protocol, v3\n\
        HELLO accepts `[2, 3]` and settles on min(client, server).\n\
        | op | name | body |\n\
        | 0x01 | HELLO | - |\n\
        | 0x10 | PUT | - |\n\
        | 0x25 | PUT_DELTA (v3+) | - |\n";

    #[test]
    fn wire_opcodes_clean_tree_passes() {
        let out = run_rule(
            "wire-opcodes",
            vec![
                f("rust/src/transport/tcp.rs", TCP_OK),
                f("rust/src/transport/PROTOCOL.md", PROTO_OK),
            ],
        );
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn wire_opcodes_flags_duplicate_values() {
        let tcp = TCP_OK.replace("pub const PUT: u8 = 0x10;", "pub const PUT: u8 = 0x01;");
        let out = run_rule("wire-opcodes", vec![f("rust/src/transport/tcp.rs", &tcp)]);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].message.contains("duplicate"), "{}", out[0].message);
        assert_eq!(out[0].line, 5);
    }

    #[test]
    fn wire_opcodes_flags_undocumented_and_phantom_opcodes() {
        let proto = PROTO_OK.replace("| 0x10 | PUT | - |", "| 0x30 | GHOST | - |");
        let out = run_rule(
            "wire-opcodes",
            vec![
                f("rust/src/transport/tcp.rs", TCP_OK),
                f("rust/src/transport/PROTOCOL.md", &proto),
            ],
        );
        assert!(
            out.iter().any(|d| d.message.contains("missing from the PROTOCOL.md")),
            "{out:?}"
        );
        assert!(
            out.iter().any(|d| d.message.contains("does not define")),
            "{out:?}"
        );
    }

    #[test]
    fn wire_opcodes_flags_gate_drift() {
        // Code says v3+, doc row lost its (v3+) marker.
        let proto = PROTO_OK.replace(" (v3+)", "");
        let out = run_rule(
            "wire-opcodes",
            vec![
                f("rust/src/transport/tcp.rs", TCP_OK),
                f("rust/src/transport/PROTOCOL.md", &proto),
            ],
        );
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].message.contains("not marked (v3+)"), "{}", out[0].message);
    }

    #[test]
    fn wire_opcodes_flags_missing_negotiation_range() {
        let proto = PROTO_OK.replace("`[2, 3]`", "`some versions`");
        let out = run_rule(
            "wire-opcodes",
            vec![
                f("rust/src/transport/tcp.rs", TCP_OK),
                f("rust/src/transport/PROTOCOL.md", &proto),
            ],
        );
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].message.contains("[2, 3]"), "{}", out[0].message);
    }

    // -- config-keys fixtures --

    const CFG_OK: &str = "impl C {\n\
        \u{20}   pub fn set(&mut self, key: &str, v: &str) -> Result<()> {\n\
        \u{20}       match key {\n\
        \u{20}           \"alpha\" => self.alpha = v.parse()?,\n\
        \u{20}           \"beta\" => self.beta = v.parse()?,\n\
        \u{20}           other => bail!(\"unknown config key\"),\n\
        \u{20}       }\n\
        \u{20}       Ok(())\n\
        \u{20}   }\n\
        \u{20}   pub fn to_kv_string(&self) -> String {\n\
        \u{20}       kv(\"alpha\", 1) + &kv(\"beta\", 2)\n\
        \u{20}   }\n\
        }\n";

    const README_OK: &str = "## Configuration\n| `alpha` | x |\n| `beta` | y |\n";

    #[test]
    fn config_keys_clean_tree_passes() {
        let out = run_rule(
            "config-keys",
            vec![f("rust/src/config/mod.rs", CFG_OK), f("README.md", README_OK)],
        );
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn config_keys_flags_set_only_and_undocumented_keys() {
        let cfg = CFG_OK.replace(" + &kv(\"beta\", 2)", "");
        let readme = README_OK.replace("| `beta` | y |\n", "");
        let out = run_rule(
            "config-keys",
            vec![f("rust/src/config/mod.rs", &cfg), f("README.md", &readme)],
        );
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out.iter().all(|d| d.message.contains("'beta'")), "{out:?}");
        assert!(out.iter().any(|d| d.message.contains("set-only")), "{out:?}");
        assert!(out.iter().any(|d| d.message.contains("README")), "{out:?}");
    }

    #[test]
    fn config_keys_ignores_nested_value_matches() {
        // A nested match inside an arm must not contribute phantom keys.
        let cfg = CFG_OK.replace(
            "\"beta\" => self.beta = v.parse()?,",
            "\"beta\" => {\n            self.beta = match v {\n                \
             \"fast\" => 1,\n                _ => 0,\n            };\n        }",
        );
        let out = run_rule(
            "config-keys",
            vec![f("rust/src/config/mod.rs", &cfg), f("README.md", README_OK)],
        );
        // "fast" is a value alias, not a key — it must not be reported.
        assert!(out.iter().all(|d| !d.message.contains("'fast'")), "{out:?}");
    }

    // -- no-sleep-sync fixtures --

    #[test]
    fn no_sleep_sync_flags_library_sleeps_and_honors_pragmas() {
        let bad = "fn wait() {\n    std::thread::sleep(d);\n}\n";
        let out = run_rule("no-sleep-sync", vec![f("rust/src/coordinator/x.rs", bad)]);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 2);

        let allowed = "fn backoff() {\n    \
            // pff-allow(no-sleep-sync): connect backoff, not a wait.\n    \
            std::thread::sleep(d);\n}\n";
        let out = run_rule("no-sleep-sync", vec![f("rust/src/coordinator/x.rs", allowed)]);
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn no_sleep_sync_skips_examples_and_comments() {
        let text = "// thread::sleep in a comment is fine\nfn f() { std::thread::sleep(d); }\n";
        assert!(run_rule("no-sleep-sync", vec![f("examples/demo.rs", text)]).is_empty());
        let commented = "fn f() {\n    // std::thread::sleep(d);\n}\n";
        assert!(run_rule(
            "no-sleep-sync",
            vec![f("rust/tests/t.rs", commented)]
        )
        .is_empty());
    }

    // -- no-print-in-lib fixtures --

    #[test]
    fn no_print_in_lib_flags_library_prints() {
        let bad = "fn go() {\n    eprintln!(\"progress\");\n}\n";
        let out = run_rule("no-print-in-lib", vec![f("rust/src/coordinator/x.rs", bad)]);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].line, 2);
    }

    #[test]
    fn no_print_in_lib_permits_binary_tests_and_pragmas() {
        let text = "fn main() {\n    println!(\"cli output\");\n}\n";
        assert!(run_rule("no-print-in-lib", vec![f("rust/src/main.rs", text)]).is_empty());
        assert!(run_rule("no-print-in-lib", vec![f("rust/src/bin/gate.rs", text)]).is_empty());

        let tests = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
            println!(\"debugging a test is fine\");\n    }\n}\n";
        assert!(run_rule("no-print-in-lib", vec![f("rust/src/ff/x.rs", tests)]).is_empty());

        let allowed = "fn go() {\n    \
            // pff-allow(no-print-in-lib): no bus exists yet here.\n    \
            eprintln!(\"listener dying\");\n}\n";
        assert!(run_rule("no-print-in-lib", vec![f("rust/src/transport/x.rs", allowed)])
            .is_empty());
    }

    // -- event-csv-exhaustive fixtures --

    const EVENTS_OK: &str = "pub enum RunEvent {\n\
        \u{20}   /// Something started.\n\
        \u{20}   Started { node: usize },\n\
        \u{20}   Done { ok: bool },\n\
        }\n\
        impl std::fmt::Display for RunEvent {\n\
        \u{20}   fn fmt(&self, f: &mut F) -> R {\n\
        \u{20}       match self {\n\
        \u{20}           RunEvent::Started { node } => write!(f, \"{node}\"),\n\
        \u{20}           RunEvent::Done { ok } => write!(f, \"{ok}\"),\n\
        \u{20}       }\n\
        \u{20}   }\n\
        }\n";

    const CSV_OK: &str = "pub fn event_csv_row(ev: &RunEvent) -> Vec<String> {\n\
        \u{20}   match ev {\n\
        \u{20}       RunEvent::Started { .. } => vec![],\n\
        \u{20}       RunEvent::Done { .. } => vec![],\n\
        \u{20}   }\n\
        }\n";

    #[test]
    fn event_csv_clean_tree_passes() {
        let out = run_rule(
            "event-csv-exhaustive",
            vec![
                f("rust/src/coordinator/events.rs", EVENTS_OK),
                f("rust/src/metrics/csv.rs", CSV_OK),
            ],
        );
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn event_csv_flags_unprojected_variant_and_wildcard() {
        let csv = CSV_OK.replace("RunEvent::Done { .. } => vec![],", "_ => vec![],");
        let out = run_rule(
            "event-csv-exhaustive",
            vec![
                f("rust/src/coordinator/events.rs", EVENTS_OK),
                f("rust/src/metrics/csv.rs", &csv),
            ],
        );
        assert!(
            out.iter().any(|d| d.message.contains("RunEvent::Done")
                && d.message.contains("event_csv_row")),
            "{out:?}"
        );
        assert!(out.iter().any(|d| d.message.contains("wildcard")), "{out:?}");
    }

    #[test]
    fn event_csv_flags_missing_display_arm() {
        let ev = EVENTS_OK.replace(
            "RunEvent::Done { ok } => write!(f, \"{ok}\"),",
            "_ => unreachable!(),",
        );
        let out = run_rule(
            "event-csv-exhaustive",
            vec![f("rust/src/coordinator/events.rs", &ev)],
        );
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].message.contains("Display"), "{}", out[0].message);
    }

    // -- lock-discipline fixtures --

    #[test]
    fn lock_discipline_flags_raw_primitives() {
        let bad = "use std::sync::Mutex;\n\
            fn f() {\n\
            \u{20}   let m = Mutex::new(0);\n\
            \u{20}   let c = Condvar::new();\n\
            \u{20}   *m.lock().unwrap() += 1;\n\
            }\n";
        let out = run_rule("lock-discipline", vec![f("rust/src/coordinator/x.rs", bad)]);
        // use + Mutex::new + Condvar::new + lock().unwrap() — 4 sites.
        assert_eq!(out.len(), 4, "{out:?}");
    }

    #[test]
    fn lock_discipline_accepts_ranked_wrappers_and_other_modules() {
        let good = "use crate::sync::{LockRank, OrderedCondvar, OrderedMutex};\n\
            fn f() {\n\
            \u{20}   let m = OrderedMutex::new(LockRank::Store, 0);\n\
            \u{20}   let cv = OrderedCondvar::new();\n\
            \u{20}   *m.lock() += 1;\n\
            }\n";
        assert!(run_rule("lock-discipline", vec![f("rust/src/coordinator/x.rs", good)])
            .is_empty());

        // Raw locks outside the ranked modules (e.g. tests/) are not this
        // rule's business.
        let elsewhere = "fn f() { let _ = std::sync::Mutex::new(0); }\n";
        assert!(run_rule("lock-discipline", vec![f("rust/tests/t.rs", elsewhere)])
            .is_empty());
    }

    // -- whole-pipeline smoke over fixtures --

    #[test]
    fn analyze_runs_all_rules_and_sorts_output() {
        let tree = Tree::from_files(vec![
            f(
                "rust/src/coordinator/z.rs",
                "fn f() {\n    std::thread::sleep(d);\n    println!(\"x\");\n}\n",
            ),
        ]);
        let out = analyze(&tree);
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out[0].line <= out[1].line, "sorted by line");
        assert!(out.iter().any(|d| d.rule == "no-sleep-sync"));
        assert!(out.iter().any(|d| d.rule == "no-print-in-lib"));
    }
}
