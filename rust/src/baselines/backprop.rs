//! Plain backpropagation trainer on the same MLP architecture — the
//! reference point of Figure 1 (what PFF competes with) and a sanity
//! ceiling for accuracy at reduced scale.
//!
//! Implemented directly on the tensor substrate (no Engine indirection:
//! BP's whole point is the *global* backward pass the Engine contract
//! deliberately does not expose).

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::lr::cooldown;
use crate::data::DataBundle;
use crate::tensor::{ops, AdamState, Matrix, Rng};

/// A BP-trained MLP: ReLU hidden layers + linear softmax output.
#[derive(Clone, Debug)]
pub struct BpNet {
    /// Hidden + output weight matrices.
    pub ws: Vec<Matrix>,
    /// Biases.
    pub bs: Vec<Vec<f32>>,
}

impl BpNet {
    /// Random init for `dims` + a `classes`-way output layer.
    pub fn new(dims: &[usize], classes: usize, rng: &mut Rng) -> Self {
        let mut full: Vec<usize> = dims.to_vec();
        full.push(classes);
        let ws = full.windows(2).map(|w| Matrix::randn_scaled(w[0], w[1], rng)).collect();
        let bs = full[1..].iter().map(|&d| vec![0.0; d]).collect();
        BpNet { ws, bs }
    }

    /// Forward pass returning all post-activation tensors (logits last).
    pub fn forward(&self, x: &Matrix) -> Vec<Matrix> {
        let mut acts = Vec::with_capacity(self.ws.len());
        let mut h = x.clone();
        for (i, (w, b)) in self.ws.iter().zip(&self.bs).enumerate() {
            let mut z = ops::matmul(&h, w);
            ops::add_bias(&mut z, b);
            if i + 1 < self.ws.len() {
                ops::relu_inplace(&mut z);
            }
            acts.push(z.clone());
            h = z;
        }
        acts
    }

    /// Predictions (argmax of logits).
    pub fn predict(&self, x: &Matrix) -> Vec<u8> {
        ops::argmax_rows(self.forward(x).last().unwrap())
    }
}

/// Report from a BP training run.
#[derive(Clone, Debug)]
pub struct BpReport {
    /// Test accuracy.
    pub test_accuracy: f64,
    /// Wall seconds.
    pub wall_s: f64,
    /// Final model.
    pub net: BpNet,
}

/// Train with minibatch Adam BP for `cfg.epochs` epochs.
pub fn run_backprop(cfg: &ExperimentConfig, bundle: &DataBundle) -> Result<BpReport> {
    let t0 = std::time::Instant::now();
    let mut rng = Rng::derive(cfg.seed, 0x4250_0000); // "BP"
    let mut net = BpNet::new(&cfg.dims, cfg.classes, &mut rng);
    let mut opts: Vec<AdamState> =
        net.ws.iter().map(|w| AdamState::new(w.rows, w.cols)).collect();

    let train = &bundle.train;
    for epoch in 0..cfg.epochs {
        let lr = cooldown(cfg.lr_head.max(1e-4), epoch, cfg.epochs);
        let mut order: Vec<usize> = (0..train.len()).collect();
        let mut srng = Rng::derive(cfg.seed, 0x4250_5348 ^ u64::from(epoch));
        srng.shuffle(&mut order);
        for idx in order.chunks(cfg.batch) {
            let x = train.x.gather_rows(idx);
            let y: Vec<u8> = idx.iter().map(|&r| train.y[r]).collect();
            step(&mut net, &mut opts, &x, &y, lr);
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let preds = net.predict(&bundle.test.x);
    let test_accuracy = crate::ff::classifier::accuracy(&preds, &bundle.test.y);
    Ok(BpReport { test_accuracy, wall_s, net })
}

/// One minibatch BP step (softmax CE, full backward, Adam).
fn step(net: &mut BpNet, opts: &mut [AdamState], x: &Matrix, y: &[u8], lr: f32) {
    let acts = net.forward(x);
    let n_layers = net.ws.len();
    let inv_b = 1.0 / x.rows as f32;
    // dlogits = (softmax - onehot)/B
    let mut delta = ops::softmax_rows(acts.last().unwrap());
    for (r, &l) in y.iter().enumerate() {
        delta.row_mut(r)[l as usize] -= 1.0;
    }
    for v in &mut delta.data {
        *v *= inv_b;
    }
    // Backward through layers.
    for l in (0..n_layers).rev() {
        let input = if l == 0 { x } else { &acts[l - 1] };
        let dw = ops::matmul_at_b(input, &delta);
        let db = ops::col_sum(&delta);
        if l > 0 {
            let mut dprev = ops::matmul_a_bt(&delta, &net.ws[l]);
            // ReLU mask of the previous activation
            for (dv, av) in dprev.data.iter_mut().zip(&acts[l - 1].data) {
                if *av <= 0.0 {
                    *dv = 0.0;
                }
            }
            delta = dprev;
        }
        opts[l].step(&mut net.ws[l], &mut net.bs[l], &dw, &db, lr);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synth_mnist;

    #[test]
    fn backprop_learns_synth_mnist() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.dims = vec![784, 48, 48];
        cfg.epochs = 3;
        cfg.lr_head = 0.002;
        let bundle = synth_mnist(256, 128, 7);
        let rep = run_backprop(&cfg, &bundle).unwrap();
        assert!(
            rep.test_accuracy > 0.5,
            "BP should learn synth-mnist well, got {:.1}%",
            rep.test_accuracy * 100.0
        );
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(1);
        let net = BpNet::new(&[10, 8, 6], 4, &mut rng);
        let x = Matrix::rand_uniform(3, 10, 0.0, 1.0, &mut rng);
        let acts = net.forward(&x);
        assert_eq!(acts.len(), 3);
        assert_eq!((acts[2].rows, acts[2].cols), (3, 4));
        // hidden activations ReLU'd, logits not necessarily positive
        assert!(acts[0].data.iter().all(|&v| v >= 0.0));
    }
}
