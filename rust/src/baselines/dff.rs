//! DFF (Distributed Forward-Forward, [11]) reimplementation — the
//! measured baseline of Table 1.
//!
//! Design points reproduced from the paper's §2/§6 description:
//! * **full-batch** training: one FF update per layer per round on the
//!   entire dataset ("feeds the data as whole", unlike PFF's minibatches);
//! * **fixed** random negative labels (no adaptive refresh);
//! * layer-servers exchange the **whole dataset's activations** (we
//!   account the bytes; the actual movement is a forward transform);
//! * **no classifier head**: goodness prediction only;
//! * many more rounds needed (the paper quotes DFF at 1000 epochs).

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::eval::{evaluate, TrainedModel};
use crate::data::DataBundle;
use crate::engine::Engine;
use crate::ff::negative::random_wrong_labels;
use crate::ff::overlay::overlay_labels;
use crate::ff::{ClassifierMode, FFNetwork};
use crate::metrics::CommStats;
use crate::tensor::{AdamState, Rng};

/// Outcome of a DFF run.
#[derive(Clone, Debug)]
pub struct DffReport {
    /// Test accuracy.
    pub test_accuracy: f64,
    /// Wall seconds of training.
    pub wall_s: f64,
    /// Bytes that would cross the wire (activation shipping).
    pub comm: CommStats,
    /// Final model.
    pub model: TrainedModel,
}

/// Train with DFF's scheme for `rounds` full-batch rounds.
pub fn run_dff(
    eng: &mut dyn Engine,
    cfg: &ExperimentConfig,
    bundle: &DataBundle,
    rounds: u32,
) -> Result<DffReport> {
    let t0 = std::time::Instant::now();
    let mut rng = Rng::derive(cfg.seed, 0x4446_4600); // "DFF"
    let mut net = FFNetwork::new(&cfg.dims, cfg.classes, &mut rng);
    let mut opts: Vec<AdamState> =
        net.layers.iter().map(|l| AdamState::new(l.d_in(), l.d_out())).collect();

    // Fixed negatives, chosen once (DFF has no adaptive refresh).
    let neg_labels = random_wrong_labels(cfg.seed, 0, &bundle.train.y, cfg.classes);
    let x_pos0 = overlay_labels(&bundle.train.x, &bundle.train.y, cfg.classes);
    let x_neg0 = overlay_labels(&bundle.train.x, &neg_labels, cfg.classes);

    let mut comm = CommStats::default();
    let n_layers = net.layers.len();
    for _round in 0..rounds {
        let mut x_pos = x_pos0.clone();
        let mut x_neg = x_neg0.clone();
        for (l, (layer, opt)) in net.layers.iter_mut().zip(opts.iter_mut()).enumerate() {
            // ONE update on the whole dataset (full batch — no cooldown,
            // matching DFF's coarse update cadence).
            eng.ff_train_step(layer, opt, &x_pos, &x_neg, cfg.theta, cfg.lr_ff)?;
            if l + 1 < n_layers {
                x_pos = eng.layer_forward(layer, &x_pos)?;
                x_neg = eng.layer_forward(layer, &x_neg)?;
                // activations of the whole dataset cross the wire (pos+neg)
                let bytes = (x_pos.data.len() + x_neg.data.len()) as u64 * 4;
                comm.puts += 1;
                comm.bytes_put += bytes;
            }
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let model = TrainedModel { net, head: None, layer_heads: Vec::new() };
    let mut eval_cfg = cfg.clone();
    eval_cfg.classifier = ClassifierMode::Goodness;
    eval_cfg.perfopt = false;
    let test_accuracy = evaluate(eng, &model, &bundle.test, &eval_cfg)?;
    Ok(DffReport { test_accuracy, wall_s, comm, model })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synth_mnist;
    use crate::engine::NativeEngine;

    #[test]
    fn dff_learns_something_but_lags_pff() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.dims = vec![784, 48, 48, 48];
        cfg.train_n = 384;
        cfg.test_n = 192;
        cfg.epochs = 80;
        let mut bundle = synth_mnist(cfg.train_n, cfg.test_n, cfg.seed);
        bundle.train.center_rows();
        bundle.test.center_rows();
        let mut eng = NativeEngine::new();
        // DFF gets generous rounds (paper: 1000 epochs vs PFF's 100).
        let rep = run_dff(&mut eng, &cfg, &bundle, 160).unwrap();
        // DFF's full-batch scheme learns very slowly (the paper needed
        // 1000 epochs for 93%); here we only require a sane finite run.
        assert!(
            rep.test_accuracy.is_finite() && rep.test_accuracy >= 0.0,
            "DFF accuracy invalid: {}",
            rep.test_accuracy
        );
        assert!(rep.comm.bytes_put > 0, "activation shipping must be accounted");

        // And the PFF run should beat it — Table 1's story.
        let mut pff_cfg = cfg.clone();
        pff_cfg.neg = crate::ff::NegStrategy::Random;
        let pff = crate::coordinator::Experiment::builder()
            .config(pff_cfg)
            .data(bundle)
            .run()
            .unwrap();
        assert!(
            pff.test_accuracy > rep.test_accuracy,
            "PFF ({:.1}%) must beat DFF ({:.1}%)",
            pff.test_accuracy * 100.0,
            rep.test_accuracy * 100.0
        );
    }

    #[test]
    fn dff_comm_is_activation_scale() {
        // Activation bytes per round ≫ parameter bytes: the §6 claim.
        let mut cfg = ExperimentConfig::tiny();
        cfg.dims = vec![784, 32, 32, 32];
        cfg.train_n = 128;
        let mut bundle = synth_mnist(cfg.train_n, 32, cfg.seed);
        bundle.train.center_rows();
        bundle.test.center_rows();
        let mut eng = NativeEngine::new();
        let rep = run_dff(&mut eng, &cfg, &bundle, 1).unwrap();
        // 2 inter-layer hops × (pos+neg) × 128 rows × 32 cols × 4 bytes
        assert_eq!(rep.comm.bytes_put, 2 * 2 * 128 * 32 * 4);
    }
}
