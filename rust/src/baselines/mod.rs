//! Comparator systems the paper evaluates against.
//!
//! * [`dff`] — a faithful reimplementation of DFF [11]'s *design points*
//!   (full-batch training, fixed negatives, activation-shipping topology,
//!   no classifier head): Table 1's 93.15% row. The paper attributes
//!   DFF's accuracy gap exactly to these choices (§6); reproducing the gap
//!   means reproducing the choices, not the bugs.
//! * [`backprop`] — a plain backpropagation trainer for the same
//!   architecture: the reference point of Figure 1 and the implicit
//!   accuracy ceiling.

pub mod backprop;
pub mod dff;
