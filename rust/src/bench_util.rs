//! Benchmark utilities for the `harness = false` bench targets (criterion
//! is unavailable offline — DESIGN.md substitution table).
//!
//! [`bench`] runs warmup + timed iterations and reports min/mean/p50
//! wall-clock; table-reproduction benches print paper-style rows via
//! [`Row`]/[`print_table`].

use std::time::Instant;

/// Timing statistics from [`bench`].
#[derive(Clone, Copy, Debug)]
pub struct BenchStats {
    /// Iterations measured.
    pub iters: u32,
    /// Minimum seconds per iteration.
    pub min_s: f64,
    /// Mean seconds.
    pub mean_s: f64,
    /// Median seconds.
    pub p50_s: f64,
}

impl BenchStats {
    /// `name: mean ± spread` display line.
    pub fn line(&self, name: &str) -> String {
        format!(
            "{:<44} {:>10}  min {:>10}  p50 {:>10}  ({} iters)",
            name,
            fmt_s(self.mean_s),
            fmt_s(self.min_s),
            fmt_s(self.p50_s),
            self.iters
        )
    }
}

/// Human-format seconds.
pub fn fmt_s(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench(warmup: u32, iters: u32, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let min_s = times[0];
    let mean_s = times.iter().sum::<f64>() / times.len() as f64;
    let p50_s = times[times.len() / 2];
    BenchStats { iters: times.len() as u32, min_s, mean_s, p50_s }
}

/// Named-measurement collector with a hand-rolled JSON artifact writer
/// (no serde offline) — the `BENCH_*.json` perf-trajectory files CI
/// uploads. Mirrors every record to stdout as it is added.
pub struct JsonReport {
    bench: String,
    records: Vec<(String, BenchStats)>,
}

impl JsonReport {
    /// Start a report for the bench named `bench`.
    pub fn new(bench: &str) -> Self {
        JsonReport { bench: bench.into(), records: Vec::new() }
    }

    /// Record one measurement (also printed immediately).
    pub fn add(&mut self, name: String, stats: BenchStats) {
        println!("{}", stats.line(&name));
        self.records.push((name, stats));
    }

    /// Render the artifact: one object per record.
    pub fn to_json(&self) -> String {
        let mut out = format!("{{\n  \"bench\": {:?},\n  \"records\": [\n", self.bench);
        for (i, (name, s)) in self.records.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"name\": {:?}, \"mean_s\": {:.9}, \"min_s\": {:.9}, \
                 \"p50_s\": {:.9}, \"iters\": {}}}{}\n",
                name,
                s.mean_s,
                s.min_s,
                s.p50_s,
                s.iters,
                if i + 1 < self.records.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the artifact to `path` when `Some`.
    pub fn write(&self, path: Option<&str>) {
        if let Some(path) = path {
            std::fs::write(path, self.to_json()).expect("writing json artifact");
            println!("wrote {path}");
        }
    }
}

/// A row of a paper-style results table.
#[derive(Clone, Debug)]
pub struct Row {
    /// Cells, column order matching the header.
    pub cells: Vec<String>,
}

/// Print a fixed-width table with header and rows.
pub fn print_table(title: &str, header: &[&str], rows: &[Row]) {
    println!("\n=== {title} ===");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.cells.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  | ")
    };
    let head: Vec<String> = header.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&head));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 5 * widths.len()));
    for r in rows {
        println!("{}", fmt_row(&r.cells));
    }
}

/// Convenience: build a row from display items.
#[macro_export]
macro_rules! row {
    ($($cell:expr),* $(,)?) => {
        $crate::bench_util::Row { cells: vec![$(format!("{}", $cell)),*] }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_positive_time() {
        let stats = bench(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(stats.iters, 5);
        assert!(stats.min_s >= 0.0);
        assert!(stats.mean_s >= stats.min_s);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_s(2.5).contains('s'));
        assert!(fmt_s(0.002).contains("ms"));
        assert!(fmt_s(2e-6).contains("µs"));
    }

    #[test]
    fn json_report_shape() {
        let mut r = JsonReport::new("unit");
        r.add("a".into(), bench(0, 2, || {}));
        r.add("b".into(), bench(0, 2, || {}));
        let json = r.to_json();
        assert!(json.contains("\"bench\": \"unit\""));
        assert!(json.contains("\"name\": \"a\","));
        assert_eq!(json.matches("mean_s").count(), 2);
    }

    #[test]
    fn row_macro_formats() {
        let r = row!["a", 42, format!("{:.1}", 1.25)];
        assert_eq!(r.cells, vec!["a", "42", "1.2"]);
    }
}
