//! `bench_gate` — CI guard comparing a fresh `BENCH_*.json` artifact
//! against a committed baseline and failing on throughput regression.
//!
//! ```bash
//! bench_gate --baseline ci/baselines/micro_engine.json \
//!            --fresh rust/BENCH_micro_engine.json [--max-regress 0.25]
//! ```
//!
//! Every record in the artifacts measures seconds per iteration
//! (`min_s`), so "throughput regression" means time growth: the gate
//! fails when `fresh.min_s > baseline.min_s * (1 + max_regress)` for any
//! record present in the baseline, or when a baseline record disappears
//! from the fresh run (coverage loss). `min_s` is the comparison metric —
//! it is the least noisy statistic on shared CI runners.
//!
//! Record names are matched after stripping the trailing parenthesized
//! decoration the benches append (measured GFLOP/s / MB/s values change
//! every run; the shape prefix is the stable identity). A missing
//! baseline file is a clean skip — the gate bootstraps itself the first
//! time CI uploads an artifact worth committing.

use std::collections::BTreeMap;
use std::process::ExitCode;

/// One normalized bench record.
#[derive(Clone, Debug, PartialEq)]
struct Record {
    name: String,
    min_s: f64,
}

/// Strip a trailing `(...)` decoration (and surrounding whitespace) from a
/// record name: `"ff_step 784x64 b32  (3.1 GFLOP/s)"` → `"ff_step 784x64 b32"`.
/// Inner parenthesized groups (shape labels) survive.
fn normalize(name: &str) -> String {
    let trimmed = name.trim_end();
    if trimmed.ends_with(')') {
        if let Some(open) = trimmed.rfind('(') {
            return trimmed[..open].trim_end().to_string();
        }
    }
    trimmed.to_string()
}

/// Extract the quoted string value following `"name":` in `obj`.
fn field_str(obj: &str, key: &str) -> Option<String> {
    let tag = format!("\"{key}\":");
    let at = obj.find(&tag)? + tag.len();
    let rest = obj[at..].trim_start();
    let rest = rest.strip_prefix('"')?;
    let end = rest.find('"')?;
    Some(rest[..end].to_string())
}

/// Extract the numeric value following `"min_s":` (etc.) in `obj`.
fn field_num(obj: &str, key: &str) -> Option<f64> {
    let tag = format!("\"{key}\":");
    let at = obj.find(&tag)? + tag.len();
    let rest = obj[at..].trim_start();
    let numeric =
        |c: char| c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == 'E' || c == '+';
    let end = rest.find(|c: char| !numeric(c)).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Parse every record object out of a `JsonReport`/`micro_engine`-style
/// artifact: any `{...}` containing both a `"name"` string and a
/// `"min_s"` number (the `threads` sweep entries qualify too).
fn parse_records(json: &str) -> Vec<Record> {
    let mut out = Vec::new();
    // Record objects never nest, so splitting on '{' and reading up to the
    // matching '}' per segment is exact for this writer.
    for seg in json.split('{').skip(1) {
        let obj = seg.split('}').next().unwrap_or("");
        if let (Some(name), Some(min_s)) = (field_str(obj, "name"), field_num(obj, "min_s")) {
            out.push(Record { name: normalize(&name), min_s });
        }
    }
    out
}

fn usage() -> ! {
    eprintln!(
        "usage: bench_gate --baseline FILE --fresh FILE [--max-regress FRACTION]\n\
         fails (exit 1) when any baseline record runs >FRACTION slower (default 0.25)\n\
         or disappears from the fresh artifact; missing baseline FILE = clean skip"
    );
    std::process::exit(2)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut baseline: Option<String> = None;
    let mut fresh: Option<String> = None;
    let mut max_regress = 0.25f64;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--baseline" => {
                baseline = args.get(i + 1).cloned();
                i += 2;
            }
            "--fresh" => {
                fresh = args.get(i + 1).cloned();
                i += 2;
            }
            "--max-regress" => {
                max_regress = match args.get(i + 1).and_then(|v| v.parse().ok()) {
                    Some(v) => v,
                    None => usage(),
                };
                i += 2;
            }
            _ => usage(),
        }
    }
    let (Some(baseline_path), Some(fresh_path)) = (baseline, fresh) else { usage() };

    let baseline_text = match std::fs::read_to_string(&baseline_path) {
        Ok(t) => t,
        Err(_) => {
            println!(
                "bench_gate: no baseline at {baseline_path} — skipping (commit one from a \
                 CI artifact to arm the gate)"
            );
            return ExitCode::SUCCESS;
        }
    };
    let fresh_text = match std::fs::read_to_string(&fresh_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("bench_gate: cannot read fresh artifact {fresh_path}: {e}");
            return ExitCode::FAILURE;
        }
    };

    let base: BTreeMap<String, f64> =
        parse_records(&baseline_text).into_iter().map(|r| (r.name, r.min_s)).collect();
    let fresh: BTreeMap<String, f64> =
        parse_records(&fresh_text).into_iter().map(|r| (r.name, r.min_s)).collect();
    if base.is_empty() {
        eprintln!("bench_gate: baseline {baseline_path} contains no records");
        return ExitCode::FAILURE;
    }

    let mut failures = Vec::new();
    println!("bench_gate: {} baseline records, threshold +{:.0}%", base.len(), max_regress * 100.0);
    for (name, &base_min) in &base {
        match fresh.get(name) {
            None => failures.push(format!("'{name}': present in baseline, missing from fresh run")),
            Some(&fresh_min) => {
                let ratio = fresh_min / base_min;
                let verdict = if ratio > 1.0 + max_regress { "REGRESSED" } else { "ok" };
                println!(
                    "  {verdict:<9} {name}  base {base_min:.6}s → fresh {fresh_min:.6}s \
                     ({:+.1}%)",
                    (ratio - 1.0) * 100.0
                );
                if ratio > 1.0 + max_regress {
                    failures.push(format!(
                        "'{name}': {:.1}% slower than baseline ({base_min:.6}s → {fresh_min:.6}s)",
                        (ratio - 1.0) * 100.0
                    ));
                }
            }
        }
    }
    for name in fresh.keys().filter(|n| !base.contains_key(*n)) {
        println!("  new       {name} (not in baseline — consider refreshing it)");
    }

    if failures.is_empty() {
        println!("bench_gate: PASS");
        ExitCode::SUCCESS
    } else {
        eprintln!("bench_gate: FAIL — {} regression(s):", failures.len());
        for f in &failures {
            eprintln!("  {f}");
        }
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_strips_only_the_trailing_decoration() {
        assert_eq!(normalize("ff_step 784x64 b32  (3.14 GFLOP/s)"), "ff_step 784x64 b32");
        assert_eq!(
            normalize("[tcp]    put+get reduced layer (256x256, 256 KB)  (123 MB/s)"),
            "[tcp]    put+get reduced layer (256x256, 256 KB)"
        );
        assert_eq!(normalize("matmul 784x2000 b128 t4"), "matmul 784x2000 b128 t4");
        assert_eq!(normalize("[tcp]    blocking-wait wake latency (p50 0.4 ms)"),
            "[tcp]    blocking-wait wake latency");
    }

    #[test]
    fn parses_records_and_threads_sweep_entries() {
        let json = r#"{
  "bench": "micro_engine",
  "records": [
    {"name": "[native] ff_step 784x64 b32  (3.1 GFLOP/s)", "mean_s": 0.002, "min_s": 0.001500000, "p50_s": 0.002, "iters": 5}
  ],
  "threads": [
    {"name": "matmul 784x2000 b128 t4", "threads": 4, "mean_s": 0.05, "min_s": 0.040000000, "p50_s": 0.05, "iters": 2}
  ]
}"#;
        let recs = parse_records(json);
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0], Record { name: "[native] ff_step 784x64 b32".into(), min_s: 0.0015 });
        assert_eq!(recs[1].name, "matmul 784x2000 b128 t4");
        assert!((recs[1].min_s - 0.04).abs() < 1e-12);
    }

    #[test]
    fn field_num_handles_scientific_and_negative() {
        assert_eq!(field_num(r#""min_s": 1.5e-3, "x": 1"#, "min_s"), Some(0.0015));
        assert_eq!(field_num(r#""min_s": -2"#, "min_s"), Some(-2.0));
        assert_eq!(field_num(r#""other": 1"#, "min_s"), None);
    }
}
