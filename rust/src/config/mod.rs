//! Experiment configuration: typed struct + file/CLI parsing.
//!
//! The offline environment has no clap/serde, so this is a small
//! hand-rolled config system: a `key = value` file format
//! ([`ExperimentConfig::from_file`]) and `--key value` / `--key=value` CLI
//! overrides ([`ExperimentConfig::apply_cli`]), both funneling through
//! [`ExperimentConfig::set`] so every knob is settable from either place.

mod parse;

pub use parse::{parse_kv_file, parse_kv_str};

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::data::DatasetKind;
use crate::ff::perfopt::PerfOptReadout;
use crate::ff::{ClassifierMode, NegStrategy};
use crate::transport::codec::WireCodec;

/// Which PFF scheduler runs the experiment (paper §4).
///
/// This enum is a *parse-level alias*: config files and CLI flags parse
/// into it, and the coordinator resolves [`Scheduler::key`] through
/// [`crate::coordinator::schedulers::SchedulerRegistry`] to obtain the
/// actual strategy object. Custom strategies registered by name (see
/// `Experiment::builder().scheduler_named(..)`) bypass the enum entirely.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheduler {
    /// N=1, layers in sequence — equivalent to original FF (§5.2 baseline).
    Sequential,
    /// One node per layer (§4.1).
    SingleLayer,
    /// Every node trains all layers in a rotating pipeline (§4.2).
    AllLayers,
    /// All-Layers over per-node private data shards (§4.3).
    Federated,
}

impl Scheduler {
    /// Canonical registry key (the name the built-in strategy factories
    /// are registered under).
    pub fn key(&self) -> &'static str {
        match self {
            Scheduler::Sequential => "sequential",
            Scheduler::SingleLayer => "single-layer",
            Scheduler::AllLayers => "all-layers",
            Scheduler::Federated => "federated",
        }
    }
}

impl std::fmt::Display for Scheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Scheduler::Sequential => write!(f, "Sequential"),
            Scheduler::SingleLayer => write!(f, "Single-Layer"),
            Scheduler::AllLayers => write!(f, "All-Layers"),
            Scheduler::Federated => write!(f, "Federated"),
        }
    }
}

impl std::str::FromStr for Scheduler {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => Ok(Scheduler::Sequential),
            "single-layer" | "single_layer" | "single" => Ok(Scheduler::SingleLayer),
            "all-layers" | "all_layers" | "all" => Ok(Scheduler::AllLayers),
            "federated" | "fed" => Ok(Scheduler::Federated),
            other => {
                // Registry-driven error: list every name the coordinator
                // would actually accept, so a typo'd `--scheduler` flag
                // tells the user what exists (custom strategies included).
                let known = crate::coordinator::schedulers::SchedulerRegistry::global().names();
                bail!("unknown scheduler '{other}' (known names: {})", known.join(", "))
            }
        }
    }
}

/// Compute backend selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Pure-Rust reference engine.
    Native,
    /// AOT HLO artifacts executed via PJRT (`artifacts/`).
    Xla,
}

impl std::str::FromStr for EngineKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(EngineKind::Native),
            "xla" | "pjrt" => Ok(EngineKind::Xla),
            other => bail!("unknown engine '{other}'"),
        }
    }
}

/// How nodes talk to the parameter store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Shared-memory store (threads in one process).
    InProc,
    /// TCP to a leader-hosted store server (the paper's socket setup).
    Tcp,
}

impl std::str::FromStr for TransportKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "inproc" | "in-proc" | "mem" => Ok(TransportKind::InProc),
            "tcp" | "socket" => Ok(TransportKind::Tcp),
            other => bail!("unknown transport '{other}'"),
        }
    }
}

/// Full experiment description. One of these drives an experiment session
/// ([`crate::coordinator::Experiment`]) end to end; it is validated once,
/// at the builder boundary (`ExperimentBuilder::launch`).
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Label used in reports/CSV.
    pub name: String,
    /// Dataset selector.
    pub dataset: DatasetKind,
    /// Max train examples (0 = dataset default).
    pub train_n: usize,
    /// Max test examples (0 = dataset default).
    pub test_n: usize,
    /// Layer widths including input, e.g. `[784, 2000, 2000, 2000, 2000]`.
    pub dims: Vec<usize>,
    /// Label classes.
    pub classes: usize,
    /// Total training epochs `E`.
    pub epochs: u32,
    /// Number of splits/chapters `S`; each chapter is `E/S` epochs.
    pub splits: u32,
    /// Minibatch size.
    pub batch: usize,
    /// Compute nodes `N`.
    pub nodes: usize,
    /// Pipeline scheduler.
    pub scheduler: Scheduler,
    /// Negative-data strategy.
    pub neg: NegStrategy,
    /// Classifier mode.
    pub classifier: ClassifierMode,
    /// Performance-Optimized variant (§4.4): per-layer CE heads, no
    /// negative data. Overrides `neg`/`classifier` semantics.
    pub perfopt: bool,
    /// PerfOpt readout (Table 4's two rows).
    pub perfopt_readout: PerfOptReadout,
    /// Goodness threshold θ.
    pub theta: f32,
    /// FF-layer Adam learning rate (paper: 0.01).
    pub lr_ff: f32,
    /// Softmax-head Adam learning rate (paper: 1e-4... see §5.1; the head
    /// converges far faster with ~1e-3 at reduced scale).
    pub lr_head: f32,
    /// Master seed (data, init, shuffles, negatives all derive from it).
    pub seed: u64,
    /// Compute backend.
    pub engine: EngineKind,
    /// Artifact directory for [`EngineKind::Xla`].
    pub artifact_dir: PathBuf,
    /// Ship Adam moments along with published layers (ablation; the paper
    /// ships only weights+biases).
    pub ship_opt_state: bool,
    /// Train the softmax head inside the pipeline (vs post-hoc).
    pub head_inline: bool,
    /// Chunk rows for AdaptiveNEG/goodness evaluation sweeps.
    pub eval_chunk: usize,
    /// Subsample size for AdaptiveNEG label refresh (0 = full train set).
    pub neg_subsample: usize,
    /// Store transport.
    pub transport: TransportKind,
    /// Multi-process cluster mode: the leader hosts the store and waits
    /// for `nodes` external `pff worker --connect` processes instead of
    /// spawning node threads. Requires `transport = tcp` and a fixed
    /// `tcp_port` (workers must know where to connect).
    pub cluster: bool,
    /// TCP port when `transport == Tcp` (leader binds 127.0.0.1:port).
    pub tcp_port: u16,
    /// Blocking-get timeout (seconds) — deadlock tripwire.
    pub store_timeout_s: u64,
    /// In-proc dispatcher worker threads draining the task graph
    /// (`--workers`). 0 = auto: one worker per logical node (`nodes`),
    /// which reproduces the static per-node schedule bit-exactly.
    /// Deployment-only: any value trains the same weights.
    pub workers: usize,
    /// Cluster admission threshold (`--min_workers`): the leader opens
    /// the task graph once this many workers have registered instead of
    /// parking until exactly `nodes` arrive; further workers may join
    /// mid-run and departed workers' leases are requeued. 0 = `nodes`.
    pub min_workers: usize,
    /// Kernel worker threads per process for the parallel tensor runtime
    /// (`--threads`). 0 = auto: `PFF_THREADS` env, else all cores. Results
    /// are bit-identical at every value — only wall-clock changes.
    pub threads: usize,
    /// Directory for durable `RunCheckpoint` files (`--checkpoint_dir`).
    /// Empty (the default) disables checkpointing. The supervisor writes
    /// `latest.ckpt` there atomically (tmp + rename) and `pff train
    /// --resume PATH` rehydrates a run from it.
    pub checkpoint_dir: PathBuf,
    /// Completed chapters between checkpoint writes (`--checkpoint_every`,
    /// ≥ 1). Only meaningful when `checkpoint_dir` is set.
    pub checkpoint_every: u32,
    /// Checkpoint rotations to keep (`--checkpoint_keep`, ≥ 1). 1 keeps
    /// only `latest.ckpt`; K > 1 additionally keeps the previous K−1
    /// writes as `latest.ckpt.1` (newest) … `latest.ckpt.K-1` (oldest).
    pub checkpoint_keep: u32,
    /// Publish bitwise row deltas against the previous chapter when the
    /// store supports them (`--delta_publish`). Deployment-only: the
    /// reconstruction is bit-exact, so trained weights are identical
    /// either way — only `wire_bytes` changes. Ignored (full frames) when
    /// `ship_opt_state` is on or the transport predates protocol v3.
    pub delta_publish: bool,
    /// Lossy compression for published matrices and checkpoint payloads
    /// (`--wire_codec`): `f32` (default, lossless), `bf16` (~50% of the
    /// f32 matrix bytes) or `i8` (per-row affine, ~26%). Training-
    /// relevant: the publisher rounds through the codec before every
    /// store write, so the codec shapes the trained weights — but
    /// identically on every transport (in-proc and TCP runs stay
    /// bitwise equal, and `f32` is bitwise identical to pre-v4 runs).
    pub wire_codec: WireCodec,
    /// Print per-chapter progress lines.
    pub verbose: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "pff".into(),
            dataset: DatasetKind::SynthMnist,
            train_n: 2000,
            test_n: 500,
            dims: vec![784, 256, 256, 256, 256],
            classes: 10,
            epochs: 40,
            splits: 8,
            batch: 64,
            nodes: 4,
            scheduler: Scheduler::AllLayers,
            neg: NegStrategy::Adaptive,
            classifier: ClassifierMode::Goodness,
            perfopt: false,
            perfopt_readout: PerfOptReadout::AllLayers,
            theta: 2.0,
            lr_ff: 0.01,
            lr_head: 0.001,
            seed: 42,
            engine: EngineKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            ship_opt_state: false,
            head_inline: true,
            eval_chunk: 256,
            neg_subsample: 0,
            transport: TransportKind::InProc,
            cluster: false,
            tcp_port: 0,
            store_timeout_s: 300,
            workers: 0,
            min_workers: 0,
            threads: 0,
            checkpoint_dir: PathBuf::new(),
            checkpoint_every: 1,
            checkpoint_keep: 1,
            delta_publish: true,
            wire_codec: WireCodec::F32,
            verbose: false,
        }
    }
}

impl ExperimentConfig {
    /// Reduced-scale MNIST-geometry preset sized for this testbed: all code
    /// paths of the paper's §5.1 setup, smaller extents.
    pub fn reduced_mnist() -> Self {
        ExperimentConfig::default()
    }

    /// Tiny preset for unit/integration tests (~2 s per run on one core).
    /// FF is epoch-hungry: anything below ~80 epochs at this scale leaves
    /// the upper layers' goodness margins under the per-class score bias
    /// and accuracy collapses (see EXPERIMENTS.md §Stability).
    pub fn tiny() -> Self {
        ExperimentConfig {
            train_n: 512,
            test_n: 256,
            dims: vec![784, 64, 64, 64],
            epochs: 80,
            splits: 8,
            nodes: 1,
            scheduler: Scheduler::Sequential,
            ..ExperimentConfig::default()
        }
    }

    /// The paper's full §5.1 configuration (MNIST, [784,2000×4], E=100,
    /// S=100, B=64, N=4). Costly on one CPU — used by the DES at full
    /// scale and available for real runs.
    pub fn paper_mnist() -> Self {
        ExperimentConfig {
            name: "paper-mnist".into(),
            dataset: DatasetKind::SynthMnist,
            train_n: 60_000,
            test_n: 10_000,
            dims: vec![784, 2000, 2000, 2000, 2000],
            epochs: 100,
            splits: 100,
            batch: 64,
            nodes: 4,
            ..ExperimentConfig::default()
        }
    }

    /// Epochs per chapter `C = E/S`.
    pub fn epochs_per_chapter(&self) -> u32 {
        self.epochs / self.splits
    }

    /// Number of FF layers `L = dims.len() - 1`.
    pub fn num_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// Validate cross-field invariants; returns a normalized copy.
    pub fn validated(mut self) -> Result<Self> {
        if self.dims.len() < 3 {
            bail!("need ≥2 layers (≥3 dims) — goodness prediction skips the first layer");
        }
        if self.splits == 0 || self.epochs == 0 {
            bail!("epochs and splits must be ≥1");
        }
        if self.epochs % self.splits != 0 {
            bail!("epochs ({}) must be divisible by splits ({})", self.epochs, self.splits);
        }
        match self.scheduler {
            Scheduler::Sequential => {
                self.nodes = 1;
            }
            Scheduler::SingleLayer => {
                if self.nodes != self.num_layers() {
                    bail!(
                        "Single-Layer PFF needs nodes == layers ({} != {})",
                        self.nodes,
                        self.num_layers()
                    );
                }
            }
            Scheduler::AllLayers | Scheduler::Federated => {
                if self.nodes == 0 {
                    bail!("nodes must be ≥1");
                }
                if self.splits as usize % self.nodes != 0 {
                    bail!(
                        "All-Layers/Federated PFF needs splits % nodes == 0 ({} % {})",
                        self.splits,
                        self.nodes
                    );
                }
            }
        }
        if self.batch == 0 {
            bail!("batch must be ≥1");
        }
        if self.checkpoint_every == 0 {
            bail!("checkpoint_every must be ≥1 (completed chapters between checkpoint writes)");
        }
        if self.checkpoint_keep == 0 {
            bail!("checkpoint_keep must be ≥1 (1 keeps only latest.ckpt)");
        }
        if self.cluster {
            if self.transport != TransportKind::Tcp {
                bail!("cluster mode needs transport = tcp (workers are separate processes)");
            }
            if self.tcp_port == 0 {
                bail!("cluster mode needs a fixed tcp_port (workers must know where to connect)");
            }
        }
        Ok(self)
    }

    /// Set one knob by key (the single source of truth for file + CLI).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value.trim();
        match key {
            "name" => self.name = v.into(),
            "dataset" => self.dataset = v.parse()?,
            "train_n" => self.train_n = v.parse()?,
            "test_n" => self.test_n = v.parse()?,
            "dims" => {
                self.dims = v
                    .split(|c| c == ',' || c == 'x')
                    .map(|d| d.trim().parse::<usize>().context("dims"))
                    .collect::<Result<_>>()?;
            }
            "classes" => self.classes = v.parse()?,
            "epochs" => self.epochs = v.parse()?,
            "splits" => self.splits = v.parse()?,
            "batch" => self.batch = v.parse()?,
            "nodes" => self.nodes = v.parse()?,
            "scheduler" => self.scheduler = v.parse()?,
            "neg" => {
                self.neg = match v.to_ascii_lowercase().as_str() {
                    "adaptive" | "adaptiveneg" => NegStrategy::Adaptive,
                    "random" | "randomneg" => NegStrategy::Random,
                    "fixed" | "fixedneg" => NegStrategy::Fixed,
                    other => bail!("unknown neg strategy '{other}'"),
                }
            }
            "classifier" => {
                self.classifier = match v.to_ascii_lowercase().as_str() {
                    "goodness" => ClassifierMode::Goodness,
                    "softmax" => ClassifierMode::Softmax,
                    other => bail!("unknown classifier '{other}'"),
                }
            }
            "perfopt" => self.perfopt = parse_bool(v)?,
            "perfopt_readout" => {
                self.perfopt_readout = match v.to_ascii_lowercase().as_str() {
                    "last" | "last-layer" => PerfOptReadout::LastLayer,
                    "all" | "all-layers" => PerfOptReadout::AllLayers,
                    other => bail!("unknown readout '{other}'"),
                }
            }
            "theta" => self.theta = v.parse()?,
            "lr_ff" => self.lr_ff = v.parse()?,
            "lr_head" => self.lr_head = v.parse()?,
            "seed" => self.seed = v.parse()?,
            "engine" => self.engine = v.parse()?,
            "artifact_dir" => self.artifact_dir = PathBuf::from(v),
            "ship_opt_state" => self.ship_opt_state = parse_bool(v)?,
            "head_inline" => self.head_inline = parse_bool(v)?,
            "eval_chunk" => self.eval_chunk = v.parse()?,
            "neg_subsample" => self.neg_subsample = v.parse()?,
            "transport" => self.transport = v.parse()?,
            "cluster" => self.cluster = parse_bool(v)?,
            "tcp_port" => self.tcp_port = v.parse()?,
            "store_timeout_s" => self.store_timeout_s = v.parse()?,
            "workers" => self.workers = v.parse()?,
            "min_workers" => self.min_workers = v.parse()?,
            "threads" => self.threads = v.parse()?,
            "checkpoint_dir" => self.checkpoint_dir = PathBuf::from(v),
            "checkpoint_every" => self.checkpoint_every = v.parse()?,
            "checkpoint_keep" => self.checkpoint_keep = v.parse()?,
            "delta_publish" => self.delta_publish = parse_bool(v)?,
            "wire_codec" => self.wire_codec = v.parse()?,
            "verbose" => self.verbose = parse_bool(v)?,
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Load a `key = value` config file over the defaults.
    pub fn from_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        for (k, v) in parse_kv_file(path)? {
            cfg.set(&k, &v).with_context(|| format!("config key '{k}'"))?;
        }
        Ok(cfg)
    }

    /// Render the full configuration in the `key = value` file format
    /// [`ExperimentConfig::from_file`] parses; every value round-trips
    /// through [`ExperimentConfig::set`]. Cluster launchers use this to
    /// ship ONE canonical config to `pff worker` processes instead of
    /// hand-maintaining flag lists that silently drift from the leader's.
    pub fn to_kv_string(&self) -> String {
        use std::fmt::Write;
        fn kv(out: &mut String, k: &str, v: impl std::fmt::Display) {
            let _ = writeln!(out, "{k} = {v}");
        }
        let dims = self.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",");
        let neg = match self.neg {
            NegStrategy::Adaptive => "adaptive",
            NegStrategy::Random => "random",
            NegStrategy::Fixed => "fixed",
        };
        let classifier = match self.classifier {
            ClassifierMode::Goodness => "goodness",
            ClassifierMode::Softmax => "softmax",
        };
        let readout = match self.perfopt_readout {
            PerfOptReadout::LastLayer => "last",
            PerfOptReadout::AllLayers => "all",
        };
        let engine = match self.engine {
            EngineKind::Native => "native",
            EngineKind::Xla => "xla",
        };
        let transport = match self.transport {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
        };
        let mut out = String::new();
        kv(&mut out, "name", &self.name);
        kv(&mut out, "dataset", self.dataset);
        kv(&mut out, "train_n", self.train_n);
        kv(&mut out, "test_n", self.test_n);
        kv(&mut out, "dims", dims);
        kv(&mut out, "classes", self.classes);
        kv(&mut out, "epochs", self.epochs);
        kv(&mut out, "splits", self.splits);
        kv(&mut out, "batch", self.batch);
        kv(&mut out, "nodes", self.nodes);
        kv(&mut out, "scheduler", self.scheduler.to_string().to_ascii_lowercase());
        kv(&mut out, "neg", neg);
        kv(&mut out, "classifier", classifier);
        kv(&mut out, "perfopt", self.perfopt);
        kv(&mut out, "perfopt_readout", readout);
        kv(&mut out, "theta", self.theta);
        kv(&mut out, "lr_ff", self.lr_ff);
        kv(&mut out, "lr_head", self.lr_head);
        kv(&mut out, "seed", self.seed);
        kv(&mut out, "engine", engine);
        kv(&mut out, "artifact_dir", self.artifact_dir.display());
        kv(&mut out, "ship_opt_state", self.ship_opt_state);
        kv(&mut out, "head_inline", self.head_inline);
        kv(&mut out, "eval_chunk", self.eval_chunk);
        kv(&mut out, "neg_subsample", self.neg_subsample);
        kv(&mut out, "transport", transport);
        kv(&mut out, "cluster", self.cluster);
        kv(&mut out, "tcp_port", self.tcp_port);
        kv(&mut out, "store_timeout_s", self.store_timeout_s);
        kv(&mut out, "workers", self.workers);
        kv(&mut out, "min_workers", self.min_workers);
        kv(&mut out, "threads", self.threads);
        kv(&mut out, "checkpoint_dir", self.checkpoint_dir.display());
        kv(&mut out, "checkpoint_every", self.checkpoint_every);
        kv(&mut out, "checkpoint_keep", self.checkpoint_keep);
        kv(&mut out, "delta_publish", self.delta_publish);
        kv(&mut out, "wire_codec", self.wire_codec);
        kv(&mut out, "verbose", self.verbose);
        out
    }

    /// Apply `--key value` / `--key=value` CLI pairs over `self`.
    pub fn apply_cli(&mut self, args: &[String]) -> Result<()> {
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                bail!("expected --key, got '{a}'");
            };
            if let Some((k, v)) = key.split_once('=') {
                self.set(k, v)?;
                i += 1;
            } else {
                let v = args.get(i + 1).with_context(|| format!("--{key} needs a value"))?;
                self.set(key, v)?;
                i += 2;
            }
        }
        Ok(())
    }
}

fn parse_bool(v: &str) -> Result<bool> {
    match v.to_ascii_lowercase().as_str() {
        "true" | "1" | "yes" | "on" => Ok(true),
        "false" | "0" | "no" | "off" => Ok(false),
        other => bail!("expected bool, got '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validated().unwrap();
        ExperimentConfig::tiny().validated().unwrap();
        ExperimentConfig::paper_mnist().validated().unwrap();
    }

    #[test]
    fn single_layer_node_constraint() {
        let mut cfg = ExperimentConfig::default();
        cfg.scheduler = Scheduler::SingleLayer;
        cfg.nodes = 2; // dims has 4 layers
        assert!(cfg.clone().validated().is_err());
        cfg.nodes = 4;
        cfg.validated().unwrap();
    }

    #[test]
    fn all_layers_divisibility() {
        let mut cfg = ExperimentConfig::default();
        cfg.scheduler = Scheduler::AllLayers;
        cfg.splits = 5;
        cfg.epochs = 5;
        cfg.nodes = 4;
        assert!(cfg.clone().validated().is_err());
        cfg.nodes = 5;
        cfg.validated().unwrap();
    }

    #[test]
    fn sequential_forces_one_node() {
        let mut cfg = ExperimentConfig::default();
        cfg.scheduler = Scheduler::Sequential;
        cfg.nodes = 8;
        assert_eq!(cfg.validated().unwrap().nodes, 1);
    }

    #[test]
    fn set_and_cli_roundtrip() {
        let mut cfg = ExperimentConfig::default();
        let args: Vec<String> = [
            "--scheduler", "single-layer", "--neg=random", "--dims", "784,128,128,128,128",
            "--epochs=8", "--splits", "8", "--nodes=4", "--classifier", "softmax",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        cfg.apply_cli(&args).unwrap();
        assert_eq!(cfg.scheduler, Scheduler::SingleLayer);
        assert_eq!(cfg.neg, NegStrategy::Random);
        assert_eq!(cfg.dims, vec![784, 128, 128, 128, 128]);
        assert_eq!(cfg.classifier, ClassifierMode::Softmax);
        cfg.validated().unwrap();
    }

    #[test]
    fn to_kv_string_roundtrips_every_field() {
        let mut cfg = ExperimentConfig::default();
        cfg.name = "kv-roundtrip".into();
        cfg.dims = vec![784, 96, 96];
        cfg.scheduler = Scheduler::SingleLayer;
        cfg.nodes = 2;
        cfg.neg = NegStrategy::Fixed;
        cfg.classifier = ClassifierMode::Softmax;
        cfg.perfopt = true;
        cfg.perfopt_readout = PerfOptReadout::LastLayer;
        cfg.ship_opt_state = true;
        cfg.transport = TransportKind::Tcp;
        cfg.cluster = true;
        cfg.tcp_port = 7441;
        cfg.lr_head = 0.00025;
        cfg.workers = 5;
        cfg.min_workers = 2;
        cfg.threads = 6;
        cfg.checkpoint_dir = PathBuf::from("ckpts/run1");
        cfg.checkpoint_every = 3;
        cfg.checkpoint_keep = 4;
        cfg.delta_publish = false;
        cfg.wire_codec = WireCodec::Bf16;
        cfg.verbose = true;

        let mut parsed = ExperimentConfig::default();
        for (k, v) in parse::parse_kv_str(&cfg.to_kv_string()).unwrap() {
            parsed.set(&k, &v).unwrap_or_else(|e| panic!("key '{k}': {e}"));
        }
        assert_eq!(format!("{parsed:?}"), format!("{cfg:?}"), "kv serialization must round-trip");
    }

    #[test]
    fn cluster_mode_constraints() {
        let mut cfg = ExperimentConfig::default();
        cfg.cluster = true;
        assert!(cfg.clone().validated().is_err(), "cluster needs tcp transport");
        cfg.transport = TransportKind::Tcp;
        assert!(cfg.clone().validated().is_err(), "cluster needs a fixed port");
        cfg.tcp_port = 7441;
        cfg.validated().unwrap();
    }

    #[test]
    fn checkpoint_keys_roundtrip_and_validate() {
        let mut cfg = ExperimentConfig::default();
        cfg.set("checkpoint_dir", "ckpt").unwrap();
        cfg.set("checkpoint_every", "4").unwrap();
        assert_eq!(cfg.checkpoint_dir, PathBuf::from("ckpt"));
        assert_eq!(cfg.checkpoint_every, 4);
        cfg.clone().validated().unwrap();
        cfg.checkpoint_every = 0;
        let err = cfg.clone().validated().unwrap_err();
        assert!(err.to_string().contains("checkpoint_every"), "{err}");
        cfg.checkpoint_every = 1;
        cfg.checkpoint_keep = 0;
        let err = cfg.validated().unwrap_err();
        assert!(err.to_string().contains("checkpoint_keep"), "{err}");
        // An empty dir (checkpointing off) round-trips through the kv form.
        let off = ExperimentConfig::default();
        let mut parsed = ExperimentConfig::default();
        parsed.checkpoint_dir = PathBuf::from("stale");
        for (k, v) in parse::parse_kv_str(&off.to_kv_string()).unwrap() {
            parsed.set(&k, &v).unwrap();
        }
        assert_eq!(parsed.checkpoint_dir, PathBuf::new());
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = ExperimentConfig::default();
        assert!(cfg.set("bogus", "1").is_err());
    }

    #[test]
    fn epochs_per_chapter() {
        let mut cfg = ExperimentConfig::default();
        cfg.epochs = 100;
        cfg.splits = 25;
        assert_eq!(cfg.epochs_per_chapter(), 4);
    }
}
