//! Minimal `key = value` config-file parser (comments with `#`, blank
//! lines ignored, last write wins).

use std::path::Path;

use anyhow::{bail, Context, Result};

/// Parse a config file into ordered `(key, value)` pairs.
pub fn parse_kv_file(path: impl AsRef<Path>) -> Result<Vec<(String, String)>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading config {}", path.as_ref().display()))?;
    parse_kv_str(&text)
}

/// Parse config text (see [`parse_kv_file`]).
pub fn parse_kv_str(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = match raw.find('#') {
            Some(i) => &raw[..i],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("config line {}: expected 'key = value', got '{raw}'", lineno + 1);
        };
        let key = k.trim();
        let val = v.trim().trim_matches('"');
        if key.is_empty() {
            bail!("config line {}: empty key", lineno + 1);
        }
        out.push((key.to_string(), val.to_string()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pairs_comments_quotes() {
        let text = "\n# comment\nscheduler = all-layers\nname = \"run 1\"  # inline\n\nepochs=8\n";
        let kv = parse_kv_str(text).unwrap();
        assert_eq!(
            kv,
            vec![
                ("scheduler".into(), "all-layers".into()),
                ("name".into(), "run 1".into()),
                ("epochs".into(), "8".into()),
            ]
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_kv_str("not a pair\n").is_err());
        assert!(parse_kv_str("= value\n").is_err());
    }
}
