//! Durable run checkpoints and the change-driven checkpoint writer.
//!
//! Pipelined FF is unusually checkpointable: the *only* live state of a
//! run is what the chapter-versioned store already holds (published
//! `LayerParams`/`HeadParams` with optional Adam snapshots, negative
//! labels) plus a per-node chapter cursor — everything else re-derives
//! deterministically from the seed. A [`RunCheckpoint`] captures exactly
//! that, serialized **through the transport codec** (`transport::codec`),
//! so the disk format and the wire format share one tested
//! encoder/decoder, and writes it atomically (tmp + rename): a `SIGKILL`
//! at any instant leaves either the previous or the next valid file,
//! never a torn one.
//!
//! Resume (`Experiment::builder().resume_from(path)` /
//! `pff train --resume PATH`) rehydrates the `MemStore` from the dump and
//! launches normally; the schedulers fast-forward past the longest
//! complete prefix of each node's chapter assignment by probing the store
//! ([`crate::coordinator::Scheduler::chapter_complete`]). Because the
//! kernels are bit-deterministic, an interrupted-then-resumed run
//! reproduces the uninterrupted run's weights **bitwise** whenever Adam
//! moments ride with the published layers (`ship_opt_state = true`); the
//! sorted dump then makes the final checkpoint files byte-comparable —
//! CI's chaos gate literally `cmp`s them.
//!
//! The [`CheckpointWriter`] runs on its own thread, parked on the store's
//! change counter ([`MemStore::wait_version_change`]) — change-driven
//! like everything else in the control plane, no poll interval — and
//! emits a [`RunEvent::CheckpointWritten`] per landed file. Capturing is
//! cheap: [`MemStore::dump`] hands back `Arc` refcounts, not tensor
//! copies, so the store lock is held O(entries) and serialization runs
//! entirely on this thread. With `checkpoint_keep > 1` each write first
//! rotates `latest.ckpt` → `latest.ckpt.1` → … so the last K snapshots
//! survive (e.g. to step back past a run that went bad late).

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::{parse_kv_str, ExperimentConfig};
use crate::coordinator::events::{EventBus, RunEvent};
use crate::coordinator::schedulers::Scheduler;
use crate::coordinator::store::{HeadParams, LayerParams, MemStore, ParamStore, StoreDump};
use crate::metrics::CommStats;
use crate::tensor::{Rng, RngState};
use crate::transport::codec::{
    read_frame, write_frame, Dec, Enc, QuantHeadParams, QuantLayerParams, WireCodec,
};

/// File magic: the bytes `PFFC` (written little-endian as a `u32`).
pub const CHECKPOINT_MAGIC: u32 = 0x4346_4650;

/// On-disk format version. Bump on any layout change; readers accept
/// `1..=CHECKPOINT_VERSION` and refuse anything newer with a clear
/// error. v2 stores layer/head entries as self-describing quantized
/// frames (`wire_codec`); v1 files (plain f32 frames) stay readable.
pub const CHECKPOINT_VERSION: u32 = 2;

/// Default checkpoint file name inside `checkpoint_dir`.
pub const CHECKPOINT_FILE: &str = "latest.ckpt";

/// Size guard when reading checkpoint files (matches the wire frame cap).
const MAX_CHECKPOINT: usize = 1 << 30;

/// Config keys that must match between a checkpoint and a resumed run —
/// everything that shapes the training trajectory. Deployment knobs
/// (transport, ports, timeouts, thread count, checkpoint settings,
/// eval-only keys) may differ freely.
const STRICT_KEYS: &[&str] = &[
    "dataset",
    "train_n",
    "dims",
    "classes",
    "epochs",
    "splits",
    "batch",
    "nodes",
    "scheduler",
    "neg",
    "classifier",
    "perfopt",
    "theta",
    "lr_ff",
    "lr_head",
    "seed",
    "engine",
    "ship_opt_state",
    "head_inline",
    "neg_subsample",
    // The publisher rounds every publish through the codec, so it shapes
    // the stored bits (and thus the trajectory) like any training knob.
    "wire_codec",
];

/// A versioned, durable snapshot of one training run.
#[derive(Clone, Debug)]
pub struct RunCheckpoint {
    /// The validated [`ExperimentConfig`], in its canonical `key = value`
    /// form (the same rendering cluster launchers ship to workers).
    pub config_kv: String,
    /// Registry name of the scheduler that ran (custom schedulers record
    /// theirs, not the parse-level enum).
    pub scheduler: String,
    /// Per-node chapter cursor: how many of node *i*'s assigned chapters
    /// were fully published when this snapshot was taken.
    pub completed: Vec<u32>,
    /// State of the master RNG stream (`Rng::new(cfg.seed)`). The
    /// built-in schedulers re-derive every stream from
    /// `(seed, chapter, purpose)` tags, so there is no live mid-run
    /// generator to capture — this records the root state so the format
    /// can transport live generator state (`Rng::state` /
    /// `Rng::from_state`) for consumers that do hold one.
    pub rng: RngState,
    /// Sorted dump of the parameter store (see [`StoreDump`]).
    pub store: StoreDump,
}

impl RunCheckpoint {
    /// Snapshot the current run state: sorted store dump + the chapter
    /// cursor (works identically for in-proc nodes and external cluster
    /// workers — both publish into the same leader-side store). The
    /// cursor is computed **from the dump itself**, not from a second
    /// look at the live store, so `completed` exactly matches what the
    /// checkpoint contains even while nodes keep publishing.
    pub fn capture(
        cfg: &ExperimentConfig,
        scheduler: &dyn Scheduler,
        store: &MemStore,
    ) -> Result<RunCheckpoint> {
        let dump = store.dump();
        let completed = completed_chapters(scheduler, &DumpView::new(&dump), cfg)?;
        Ok(RunCheckpoint {
            config_kv: cfg.to_kv_string(),
            scheduler: scheduler.name().to_string(),
            completed,
            rng: Rng::new(cfg.seed).state(),
            store: dump,
        })
    }

    /// Total completed chapter-assignments across all nodes.
    pub fn total_completed(&self) -> u32 {
        self.completed.iter().sum()
    }

    /// Reconstruct the [`ExperimentConfig`] this checkpoint embeds.
    pub fn experiment_config(&self) -> Result<ExperimentConfig> {
        let mut cfg = ExperimentConfig::default();
        for (k, v) in parse_kv_str(&self.config_kv)? {
            cfg.set(&k, &v).with_context(|| format!("checkpoint config key '{k}'"))?;
        }
        Ok(cfg)
    }

    /// Verify `cfg` is a legal configuration to resume this checkpoint
    /// under: every training-relevant key must match (see the module
    /// docs for which keys are deployment-only and may differ).
    pub fn check_compat(&self, cfg: &ExperimentConfig) -> Result<()> {
        // Normalize the checkpoint's kv through a config round-trip so
        // files predating a strict key (e.g. v1 files without
        // `wire_codec`) compare against its default instead of <unset>.
        let theirs: HashMap<String, String> =
            parse_kv_str(&self.experiment_config()?.to_kv_string())?.into_iter().collect();
        let ours: HashMap<String, String> =
            parse_kv_str(&cfg.to_kv_string())?.into_iter().collect();
        for key in STRICT_KEYS {
            let (a, b) = (theirs.get(*key), ours.get(*key));
            if a != b {
                bail!(
                    "resume config mismatch on '{key}': checkpoint has {}, run has {} — \
                     a resumed run must keep the training-relevant configuration",
                    a.map_or("<unset>".into(), |v| format!("'{v}'")),
                    b.map_or("<unset>".into(), |v| format!("'{v}'")),
                );
            }
        }
        Ok(())
    }

    /// The `wire_codec` this checkpoint's embedded config declares — the
    /// codec [`RunCheckpoint::encode`] compresses the store section with.
    /// A missing or unparsable key means f32 (configs predating the key).
    pub fn wire_codec(&self) -> WireCodec {
        parse_kv_str(&self.config_kv)
            .ok()
            .and_then(|kvs| {
                kvs.into_iter().find(|(k, _)| k == "wire_codec").and_then(|(_, v)| v.parse().ok())
            })
            .unwrap_or_default()
    }

    /// Serialize to the versioned payload (no outer frame), compressing
    /// the store section with the embedded config's `wire_codec`.
    pub fn encode(&self) -> Vec<u8> {
        self.encode_with(self.wire_codec())
    }

    /// [`RunCheckpoint::encode`] with an explicit store-section codec.
    ///
    /// Decoding is ALWAYS bitwise lossless: a lossy codec is applied only
    /// to entries it round-trips exactly (published params are codec
    /// fixed points by quantize-at-publish, so in practice all of them);
    /// anything else keeps a full f32 frame. The frames are
    /// self-describing (per-matrix tag byte), so the reader never needs
    /// to know which path an entry took.
    pub fn encode_with(&self, codec: WireCodec) -> Vec<u8> {
        let mut e = Enc::new();
        e.u32(CHECKPOINT_MAGIC);
        e.u32(CHECKPOINT_VERSION);
        e.str(&self.config_kv);
        e.str(&self.scheduler);
        e.u32(self.completed.len() as u32);
        for &c in &self.completed {
            e.u32(c);
        }
        e.u64(self.rng.state);
        match self.rng.spare_normal {
            None => e.u8(0),
            Some(v) => {
                e.u8(1);
                e.f32(v);
            }
        }
        e.u32(self.store.layers.len() as u32);
        for (slot, chapter, p) in &self.store.layers {
            e.u32(*slot as u32);
            e.u32(*chapter);
            e.quant_layer_params(&quant_layer_lossless(codec, p));
        }
        e.u32(self.store.heads.len() as u32);
        for (chapter, p) in &self.store.heads {
            e.u32(*chapter);
            e.quant_head_params(&quant_head_lossless(codec, p));
        }
        e.u32(self.store.negs.len() as u32);
        for (chapter, labels) in &self.store.negs {
            e.u32(*chapter);
            e.bytes(labels);
        }
        e.finish()
    }

    /// Decode a payload produced by [`RunCheckpoint::encode`]. Rejects
    /// wrong magic, unsupported versions, truncation, and trailing bytes
    /// with distinct, actionable errors.
    pub fn decode(buf: &[u8]) -> Result<RunCheckpoint> {
        let mut d = Dec::new(buf);
        let magic = d.u32().context("checkpoint too short for the magic header")?;
        if magic != CHECKPOINT_MAGIC {
            bail!("not a pff checkpoint (bad magic {magic:#010x}, want {CHECKPOINT_MAGIC:#010x})");
        }
        let version = d.u32()?;
        if version == 0 || version > CHECKPOINT_VERSION {
            bail!(
                "checkpoint format v{version} is not supported \
                 (this build reads v1..v{CHECKPOINT_VERSION})"
            );
        }
        let config_kv = d.str().context("checkpoint config block")?;
        let scheduler = d.str().context("checkpoint scheduler name")?;
        let n = d.u32()? as usize;
        let mut completed = Vec::with_capacity(n);
        for _ in 0..n {
            completed.push(d.u32()?);
        }
        let rng_state = d.u64()?;
        let spare_normal = if d.u8()? != 0 { Some(d.f32()?) } else { None };
        let rng = RngState { state: rng_state, spare_normal };
        let n = d.u32()? as usize;
        let mut layers = Vec::with_capacity(n);
        for _ in 0..n {
            let slot = d.u32()? as usize;
            let chapter = d.u32()?;
            // v1 stored bare f32 frames; v2 frames carry a codec tag.
            let p = if version >= 2 {
                d.quant_layer_params().context("checkpoint layer entry")?.dequantize()
            } else {
                d.layer_params().context("checkpoint layer entry")?
            };
            layers.push((slot, chapter, Arc::new(p)));
        }
        let n = d.u32()? as usize;
        let mut heads = Vec::with_capacity(n);
        for _ in 0..n {
            let chapter = d.u32()?;
            let p = if version >= 2 {
                d.quant_head_params().context("checkpoint head entry")?.dequantize()
            } else {
                d.head_params().context("checkpoint head entry")?
            };
            heads.push((chapter, Arc::new(p)));
        }
        let n = d.u32()? as usize;
        let mut negs = Vec::with_capacity(n);
        for _ in 0..n {
            let chapter = d.u32()?;
            negs.push((chapter, Arc::new(d.bytes()?)));
        }
        if d.remaining() != 0 {
            bail!("checkpoint has {} trailing bytes (corrupt or mismatched format)", d.remaining());
        }
        Ok(RunCheckpoint {
            config_kv,
            scheduler,
            completed,
            rng,
            store: StoreDump { layers, heads, negs },
        })
    }

    /// Write atomically to `path` (frame into a sibling `.tmp`, then
    /// rename over). Returns the file size in bytes.
    pub fn save(&self, path: &Path) -> Result<u64> {
        let payload = self.encode();
        let mut file_bytes = Vec::with_capacity(payload.len() + 4);
        write_frame(&mut file_bytes, &payload)?;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
            }
        }
        let tmp = PathBuf::from(format!("{}.tmp", path.display()));
        std::fs::write(&tmp, &file_bytes)
            .with_context(|| format!("writing checkpoint {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing checkpoint {}", path.display()))?;
        Ok(file_bytes.len() as u64)
    }

    /// Load and validate a checkpoint file written by
    /// [`RunCheckpoint::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<RunCheckpoint> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        let mut cur = std::io::Cursor::new(&bytes[..]);
        let payload = read_frame(&mut cur, MAX_CHECKPOINT)
            .with_context(|| format!("checkpoint {} is truncated or corrupt", path.display()))?;
        if (cur.position() as usize) != bytes.len() {
            bail!("checkpoint {} has data past the frame (corrupt)", path.display());
        }
        RunCheckpoint::decode(&payload)
            .with_context(|| format!("decoding checkpoint {}", path.display()))
    }
}

/// Quantize one layer entry for the checkpoint's store section, keeping
/// the f32 frame whenever the codec would not round-trip it bitwise (a
/// published entry is a codec fixed point, so the fallback only fires on
/// foreign data — e.g. entries injected by tests or older runs).
fn quant_layer_lossless(codec: WireCodec, p: &LayerParams) -> QuantLayerParams {
    let q = codec.quantize_layer(p);
    if codec != WireCodec::F32 {
        let mut a = Enc::new();
        a.layer_params(&q.dequantize());
        let mut b = Enc::new();
        b.layer_params(p);
        if a.finish() != b.finish() {
            return WireCodec::F32.quantize_layer(p);
        }
    }
    q
}

/// [`quant_layer_lossless`] for head entries.
fn quant_head_lossless(codec: WireCodec, p: &HeadParams) -> QuantHeadParams {
    let q = codec.quantize_head(p);
    if codec != WireCodec::F32 {
        let mut a = Enc::new();
        a.head_params(&q.dequantize());
        let mut b = Enc::new();
        b.head_params(p);
        if a.finish() != b.finish() {
            return WireCodec::F32.quantize_head(p);
        }
    }
    q
}

/// Per-node chapter cursor, derived from what the store actually holds:
/// the longest prefix of each node's planned chapters whose outputs are
/// all published ([`Scheduler::chapter_complete`]). Chapters of a node
/// are only ever published by that node, so a cursor computed while other
/// nodes keep publishing is still exact.
pub fn completed_chapters(
    scheduler: &dyn Scheduler,
    store: &dyn ParamStore,
    cfg: &ExperimentConfig,
) -> Result<Vec<u32>> {
    let plan = scheduler.plan(cfg)?;
    let mut out = Vec::with_capacity(plan.chapters.len());
    for (node, chapters) in plan.chapters.iter().enumerate() {
        let mut n = 0u32;
        for &c in chapters {
            if !scheduler.chapter_complete(store, cfg, node, c)? {
                break;
            }
            n += 1;
        }
        out.push(n);
    }
    Ok(out)
}

/// Probe-only [`ParamStore`] view over a [`StoreDump`]: the chapter
/// cursor is computed against the SAME snapshot the checkpoint persists
/// (one lock acquisition produced both), so `completed` can never lag
/// the dump's actual contents. Only the `has_*` probes are answerable;
/// everything else is a hard error — `chapter_complete` implementations
/// must stay presence-only.
struct DumpView {
    layers: HashSet<(usize, u32)>,
    heads: HashSet<u32>,
    negs: HashSet<u32>,
}

impl DumpView {
    fn new(dump: &StoreDump) -> Self {
        DumpView {
            layers: dump.layers.iter().map(|&(l, c, _)| (l, c)).collect(),
            heads: dump.heads.iter().map(|&(c, _)| c).collect(),
            negs: dump.negs.iter().map(|&(c, _)| c).collect(),
        }
    }
}

impl ParamStore for DumpView {
    fn put_layer(&self, _layer: usize, _chapter: u32, _params: LayerParams) -> Result<()> {
        bail!("checkpoint dump view is presence-probe-only")
    }
    fn get_layer(&self, _layer: usize, _chapter: u32, _t: Duration) -> Result<Arc<LayerParams>> {
        bail!("checkpoint dump view is presence-probe-only")
    }
    fn put_head(&self, _chapter: u32, _params: HeadParams) -> Result<()> {
        bail!("checkpoint dump view is presence-probe-only")
    }
    fn get_head(&self, _chapter: u32, _t: Duration) -> Result<Arc<HeadParams>> {
        bail!("checkpoint dump view is presence-probe-only")
    }
    fn put_neg(&self, _chapter: u32, _labels: Vec<u8>) -> Result<()> {
        bail!("checkpoint dump view is presence-probe-only")
    }
    fn get_neg(&self, _chapter: u32, _t: Duration) -> Result<Vec<u8>> {
        bail!("checkpoint dump view is presence-probe-only")
    }
    fn latest_layer(&self, _layer: usize) -> Result<Option<(u32, Arc<LayerParams>)>> {
        bail!("checkpoint dump view is presence-probe-only")
    }
    fn latest_head(&self) -> Result<Option<(u32, Arc<HeadParams>)>> {
        bail!("checkpoint dump view is presence-probe-only")
    }
    fn comm_stats(&self) -> CommStats {
        CommStats::default()
    }
    fn has_layer(&self, layer: usize, chapter: u32) -> Result<bool> {
        Ok(self.layers.contains(&(layer, chapter)))
    }
    fn has_head(&self, chapter: u32) -> Result<bool> {
        Ok(self.heads.contains(&chapter))
    }
    fn has_neg(&self, chapter: u32) -> Result<bool> {
        Ok(self.negs.contains(&chapter))
    }
}

/// Shift older checkpoint rotations up one slot before `path` is
/// overwritten, keeping `keep` files total (the imminent write included):
/// `path` → `path.1` (newest rotation) → … → `path.{keep-1}` (oldest),
/// dropping anything past that. `keep == 1` preserves the classic
/// single-file overwrite. Every step is a whole-file rename of an
/// already-atomically-written checkpoint, so a kill mid-rotation leaves
/// every surviving file complete and loadable.
fn rotate_history(path: &Path, keep: u32) -> Result<()> {
    if keep <= 1 || !path.exists() {
        return Ok(());
    }
    let slot = |i: u32| PathBuf::from(format!("{}.{i}", path.display()));
    std::fs::remove_file(slot(keep - 1)).ok();
    for i in (1..keep - 1).rev() {
        let from = slot(i);
        if from.exists() {
            let to = slot(i + 1);
            std::fs::rename(&from, &to).with_context(|| {
                format!("rotating checkpoint {} → {}", from.display(), to.display())
            })?;
        }
    }
    let to = slot(1);
    std::fs::rename(path, &to)
        .with_context(|| format!("rotating checkpoint {} → {}", path.display(), to.display()))?;
    Ok(())
}

/// Everything one checkpoint write needs; shared between the writer
/// thread (periodic) and `finish` (final snapshot).
struct WriterCtx {
    cfg: ExperimentConfig,
    scheduler: Arc<dyn Scheduler>,
    store: Arc<MemStore>,
    bus: EventBus,
    path: PathBuf,
    every: u32,
    keep: u32,
}

impl WriterCtx {
    /// Capture + rotate + save + announce. Returns the total
    /// completed-chapter count the snapshot recorded.
    fn write_now(&self) -> Result<u32> {
        let ck = RunCheckpoint::capture(&self.cfg, self.scheduler.as_ref(), &self.store)?;
        let total = ck.total_completed();
        rotate_history(&self.path, self.keep)?;
        let wire_bytes = ck.save(&self.path)?;
        // f32-equivalent size (+4 for the file's frame-length prefix),
        // so observers can read the compression ratio off the event.
        let raw_bytes = if self.cfg.wire_codec == WireCodec::F32 {
            wire_bytes
        } else {
            ck.encode_with(WireCodec::F32).len() as u64 + 4
        };
        self.bus.emit(RunEvent::CheckpointWritten {
            path: self.path.display().to_string(),
            wire_bytes,
            raw_bytes,
        });
        Ok(total)
    }
}

/// Background checkpoint writer for one run.
///
/// Parks on the store's change counter; whenever publishes land it
/// recomputes the chapter cursor and writes a fresh checkpoint once
/// `checkpoint_every` more chapter-assignments have completed since the
/// last write. An initial checkpoint is written at spawn (so a kill at
/// any point after launch finds a resumable file), and
/// [`CheckpointWriter::finish`] writes the final end-of-run snapshot.
pub struct CheckpointWriter {
    stop: Arc<AtomicBool>,
    store: Arc<MemStore>,
    ctx: Arc<WriterCtx>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl CheckpointWriter {
    /// Start the writer for a run whose `cfg.checkpoint_dir` is set.
    /// Writes the initial checkpoint synchronously (a launch error here
    /// surfaces immediately rather than mid-run).
    ///
    /// `resuming` declares whether this run rehydrated from a checkpoint:
    /// a FRESH run pointed at a directory that already holds a
    /// `latest.ckpt` is refused — the initial write would clobber the
    /// previous run's only resume point (the classic "re-ran the command
    /// but forgot --resume" data loss).
    pub fn spawn(
        cfg: &ExperimentConfig,
        scheduler: Arc<dyn Scheduler>,
        store: Arc<MemStore>,
        bus: EventBus,
        resuming: bool,
    ) -> Result<CheckpointWriter> {
        let path = cfg.checkpoint_dir.join(CHECKPOINT_FILE);
        if !resuming && path.exists() {
            bail!(
                "refusing to overwrite existing checkpoint {}: resume it with \
                 `--resume {}` (or `.resume_from(..)`), or point checkpoint_dir \
                 elsewhere / remove the file to start fresh",
                path.display(),
                path.display(),
            );
        }
        let ctx = Arc::new(WriterCtx {
            path,
            every: cfg.checkpoint_every.max(1),
            keep: cfg.checkpoint_keep.max(1),
            cfg: cfg.clone(),
            scheduler,
            store: store.clone(),
            bus,
        });
        // Baseline BEFORE the initial write: a publish landing while that
        // write runs must still wake the thread (a spurious wake that
        // finds nothing new is harmless; a swallowed one loses a chapter
        // from the last periodic checkpoint).
        let baseline = store.version();
        let mut last_total = ctx.write_now().context("writing the initial checkpoint")?;
        let stop = Arc::new(AtomicBool::new(false));
        let (ctx2, stop2) = (ctx.clone(), stop.clone());
        let thread = std::thread::Builder::new()
            .name("pff-checkpoint".into())
            .spawn(move || {
                let mut seen = baseline;
                loop {
                    if stop2.load(Ordering::SeqCst) {
                        return;
                    }
                    // Change-driven park: wakes on any publish, on
                    // MemStore::touch (finish), or on store close (cancel).
                    match ctx2.store.wait_version_change(seen, Duration::from_secs(3600)) {
                        Ok(v) if v == seen => continue,
                        Ok(v) => seen = v,
                        Err(_) => return, // store closed — run is over
                    }
                    if stop2.load(Ordering::SeqCst) {
                        return;
                    }
                    let total = match completed_chapters(
                        ctx2.scheduler.as_ref(),
                        ctx2.store.as_ref(),
                        &ctx2.cfg,
                    ) {
                        Ok(c) => c.iter().sum::<u32>(),
                        Err(_) => continue,
                    };
                    if total >= last_total.saturating_add(ctx2.every) {
                        match ctx2.write_now() {
                            Ok(t) => last_total = t,
                            // pff-allow(no-print-in-lib): disk trouble must
                            // not kill the run (the next publish retries),
                            // and the background writer holds no EventBus —
                            // stderr is the only reporting channel.
                            Err(e) => eprintln!("[pff-checkpoint] write failed: {e:#}"),
                        }
                    }
                }
            })
            .context("spawning the checkpoint writer thread")?;
        Ok(CheckpointWriter { stop, store, ctx, thread: Some(thread) })
    }

    /// Stop the writer thread. With `write_final`, capture one last
    /// checkpoint of the store's end-of-run state on the calling thread —
    /// the file CI's chaos gate byte-compares between an interrupted and
    /// an uninterrupted run.
    pub fn finish(mut self, write_final: bool) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        self.store.touch();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
        if write_final {
            self.ctx.write_now().context("writing the final checkpoint")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::schedulers::{head_slot, AllLayers, SingleLayer};
    use crate::coordinator::store::{HeadParams, LayerParams, OptSnapshot};
    use crate::tensor::Matrix;

    fn layer_with_opt(seed: u64) -> LayerParams {
        let mut rng = Rng::new(seed);
        LayerParams {
            w: Matrix::randn_scaled(3, 2, &mut rng),
            b: vec![0.5, -0.5],
            normalize_input: true,
            opt: Some(OptSnapshot {
                m_w: Matrix::randn_scaled(3, 2, &mut rng),
                v_w: Matrix::randn_scaled(3, 2, &mut rng),
                m_b: vec![0.1, 0.2],
                v_b: vec![0.3, 0.4],
                t: 7,
            }),
        }
    }

    fn sample_checkpoint() -> RunCheckpoint {
        let mut rng = Rng::new(11);
        RunCheckpoint {
            config_kv: ExperimentConfig::tiny().to_kv_string(),
            scheduler: "all-layers".into(),
            completed: vec![3, 2],
            rng: RngState { state: 0xDEAD_BEEF, spare_normal: Some(-0.75) },
            store: StoreDump {
                layers: vec![
                    (0, 0, Arc::new(layer_with_opt(1))),
                    (
                        0,
                        1,
                        Arc::new(LayerParams {
                            // NaN payload and a 0×N shape must survive bitwise.
                            w: Matrix::from_vec(1, 3, vec![f32::NAN, f32::INFINITY, -0.0]),
                            b: vec![f32::NAN],
                            normalize_input: false,
                            opt: None,
                        }),
                    ),
                    (
                        head_slot(1),
                        2,
                        Arc::new(LayerParams {
                            w: Matrix::from_vec(0, 4, vec![]),
                            b: vec![],
                            normalize_input: false,
                            opt: None,
                        }),
                    ),
                ],
                heads: vec![(
                    1,
                    Arc::new(HeadParams {
                        w: Matrix::randn_scaled(2, 4, &mut rng),
                        b: vec![0.0; 4],
                        opt: None,
                    }),
                )],
                negs: vec![(2, Arc::new(vec![1, 2, 3])), (4, Arc::new(vec![]))],
            },
        }
    }

    #[test]
    fn encode_decode_is_bit_exact_including_nan_and_zero_row_shapes() {
        let ck = sample_checkpoint();
        let bytes = ck.encode();
        let got = RunCheckpoint::decode(&bytes).unwrap();
        // Re-encoding the decoded value must reproduce the exact bytes —
        // bit-exactness through NaN payloads included.
        assert_eq!(got.encode(), bytes);
        assert_eq!(got.scheduler, "all-layers");
        assert_eq!(got.completed, vec![3, 2]);
        assert_eq!(got.rng, ck.rng);
        assert_eq!(got.store.layers.len(), 3);
        let (slot, chapter, nan_layer) = &got.store.layers[1];
        assert_eq!((*slot, *chapter), (0, 1));
        assert!(nan_layer.w.data[0].is_nan());
        assert_eq!(nan_layer.w.data[2].to_bits(), (-0.0f32).to_bits());
        let (_, _, empty) = &got.store.layers[2];
        assert_eq!((empty.w.rows, empty.w.cols), (0, 4));
        assert_eq!(got.store.negs[1].0, 4);
        assert!(got.store.negs[1].1.is_empty());
    }

    #[test]
    fn v2_quantized_store_section_shrinks_and_roundtrips() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.wire_codec = WireCodec::Bf16;
        let mut rng = Rng::new(3);
        // A published entry: a bf16 fixed point by quantize-at-publish.
        let rounded = WireCodec::Bf16
            .quantize_layer(&LayerParams {
                w: Matrix::randn_scaled(16, 16, &mut rng),
                b: vec![0.25; 16],
                normalize_input: true,
                opt: None,
            })
            .dequantize();
        let ck = RunCheckpoint {
            config_kv: cfg.to_kv_string(),
            scheduler: "all-layers".into(),
            completed: vec![1],
            rng: Rng::new(cfg.seed).state(),
            store: StoreDump { layers: vec![(0, 0, Arc::new(rounded))], ..StoreDump::default() },
        };
        assert_eq!(ck.wire_codec(), WireCodec::Bf16);
        let bytes = ck.encode();
        let raw = ck.encode_with(WireCodec::F32);
        assert!(
            bytes.len() < raw.len(),
            "bf16 store section must shrink ({} vs {} bytes)",
            bytes.len(),
            raw.len()
        );
        let got = RunCheckpoint::decode(&bytes).unwrap();
        assert_eq!(got.encode(), bytes, "decode must be bitwise lossless");
        // The uncompressed rendering decodes to the same checkpoint.
        let got_raw = RunCheckpoint::decode(&raw).unwrap();
        assert_eq!(got_raw.encode(), bytes);
    }

    #[test]
    fn lossy_codec_never_corrupts_foreign_entries() {
        // sample_checkpoint's entries are NOT i8 fixed points (random
        // floats, NaN payloads): per-entry fallback must keep the encode
        // bitwise lossless anyway.
        let mut ck = sample_checkpoint();
        let mut cfg = ExperimentConfig::tiny();
        cfg.wire_codec = WireCodec::I8;
        ck.config_kv = cfg.to_kv_string();
        let bytes = ck.encode();
        let got = RunCheckpoint::decode(&bytes).unwrap();
        assert_eq!(got.encode(), bytes);
        let (_, _, nan_layer) = &got.store.layers[1];
        assert!(nan_layer.w.data[0].is_nan());
        assert_eq!(nan_layer.w.data[2].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn v1_files_stay_readable() {
        let mut ck = sample_checkpoint();
        // A v1-era config predates the wire_codec key entirely.
        ck.config_kv = ck
            .config_kv
            .lines()
            .filter(|l| !l.trim_start().starts_with("wire_codec"))
            .collect::<Vec<_>>()
            .join("\n");
        // Hand-write the v1 layout (version 1, bare f32 frames) — what
        // pre-v2 builds produced.
        let mut e = Enc::new();
        e.u32(CHECKPOINT_MAGIC);
        e.u32(1);
        e.str(&ck.config_kv);
        e.str(&ck.scheduler);
        e.u32(ck.completed.len() as u32);
        for &c in &ck.completed {
            e.u32(c);
        }
        e.u64(ck.rng.state);
        match ck.rng.spare_normal {
            None => e.u8(0),
            Some(v) => {
                e.u8(1);
                e.f32(v);
            }
        }
        e.u32(ck.store.layers.len() as u32);
        for (slot, chapter, p) in &ck.store.layers {
            e.u32(*slot as u32);
            e.u32(*chapter);
            e.layer_params(p);
        }
        e.u32(ck.store.heads.len() as u32);
        for (chapter, p) in &ck.store.heads {
            e.u32(*chapter);
            e.head_params(p);
        }
        e.u32(ck.store.negs.len() as u32);
        for (chapter, labels) in &ck.store.negs {
            e.u32(*chapter);
            e.bytes(labels);
        }
        let got = RunCheckpoint::decode(&e.finish()).unwrap();
        assert_eq!(got.encode(), ck.encode(), "v1 payload must decode to the same checkpoint");
        // check_compat normalizes the old config through a round-trip, so
        // the absent wire_codec key compares as the f32 default.
        got.check_compat(&ExperimentConfig::tiny()).unwrap();
    }

    #[test]
    fn decode_rejects_bad_magic_version_and_trailing_bytes() {
        let ck = sample_checkpoint();
        let bytes = ck.encode();

        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        let err = RunCheckpoint::decode(&bad_magic).unwrap_err();
        assert!(err.to_string().contains("magic"), "{err}");

        let mut bad_version = bytes.clone();
        bad_version[4] = 99;
        let err = RunCheckpoint::decode(&bad_version).unwrap_err();
        assert!(err.to_string().contains("v99"), "{err}");

        let mut trailing = bytes.clone();
        trailing.push(0);
        let err = RunCheckpoint::decode(&trailing).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");

        // Truncation anywhere inside the payload fails cleanly.
        assert!(RunCheckpoint::decode(&bytes[..bytes.len() / 2]).is_err());
    }

    #[test]
    fn save_load_roundtrip_and_corrupt_file_rejection() {
        let dir = std::env::temp_dir().join(format!("pff_ckpt_unit_{}", std::process::id()));
        let path = dir.join("latest.ckpt");
        let ck = sample_checkpoint();
        let bytes = ck.save(&path).unwrap();
        assert!(bytes > 0);
        let got = RunCheckpoint::load(&path).unwrap();
        assert_eq!(got.encode(), ck.encode());

        // A torn write (truncated file) is refused with a clear error.
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let err = RunCheckpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("truncated or corrupt"), "{err:#}");

        // Garbage past the frame is also refused.
        let mut padded = full.clone();
        padded.extend_from_slice(b"junk");
        std::fs::write(&path, &padded).unwrap();
        let err = RunCheckpoint::load(&path).unwrap_err();
        assert!(format!("{err:#}").contains("past the frame"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn config_roundtrip_and_compat_guard() {
        let cfg = ExperimentConfig::tiny();
        let ck = RunCheckpoint {
            config_kv: cfg.to_kv_string(),
            scheduler: "sequential".into(),
            completed: vec![0],
            rng: Rng::new(cfg.seed).state(),
            store: StoreDump::default(),
        };
        let parsed = ck.experiment_config().unwrap();
        assert_eq!(format!("{parsed:?}"), format!("{cfg:?}"));
        ck.check_compat(&cfg).unwrap();

        // Deployment knobs may differ...
        let mut moved = cfg.clone();
        moved.threads = 7;
        moved.checkpoint_dir = PathBuf::from("elsewhere");
        moved.store_timeout_s = 5;
        ck.check_compat(&moved).unwrap();

        // ...training-relevant keys may not.
        let mut reseeded = cfg.clone();
        reseeded.seed = 1;
        let err = ck.check_compat(&reseeded).unwrap_err();
        assert!(err.to_string().contains("'seed'"), "{err}");
    }

    #[test]
    fn completed_chapters_tracks_store_prefixes() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.scheduler = crate::config::Scheduler::AllLayers;
        cfg.nodes = 2;
        let cfg = cfg.validated().unwrap();
        let store = MemStore::new();
        let p = || LayerParams {
            w: Matrix::zeros(2, 2),
            b: vec![0.0; 2],
            normalize_input: false,
            opt: None,
        };
        // Node 0 owns chapters 0,2,4,..; node 1 owns 1,3,5,..
        // Publish all layers for chapters 0 and 1, plus a partial chapter 2.
        for c in [0u32, 1] {
            for l in 0..cfg.num_layers() {
                store.put_layer(l, c, p()).unwrap();
            }
        }
        store.put_layer(0, 2, p()).unwrap();
        let done = completed_chapters(&AllLayers, &store, &cfg).unwrap();
        assert_eq!(done, vec![1, 1], "partial chapter 2 must not count");

        // Single-Layer cursor: node i's prefix over slot i.
        let mut cfg = ExperimentConfig::tiny();
        cfg.scheduler = crate::config::Scheduler::SingleLayer;
        cfg.nodes = 3;
        let cfg = cfg.validated().unwrap();
        let store = MemStore::new();
        for c in 0..3u32 {
            store.put_layer(0, c, p()).unwrap();
        }
        store.put_layer(1, 0, p()).unwrap();
        let done = completed_chapters(&SingleLayer, &store, &cfg).unwrap();
        assert_eq!(done, vec![3, 1, 0]);
    }

    #[test]
    fn checkpoint_rotation_keeps_bounded_history() {
        let dir = std::env::temp_dir().join(format!("pff_ckpt_rot_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join(CHECKPOINT_FILE);
        let ck = sample_checkpoint();
        // keep = 3: latest + two rotations; older writes fall off the end.
        for _ in 0..5 {
            rotate_history(&path, 3).unwrap();
            ck.save(&path).unwrap();
        }
        assert!(path.exists());
        assert!(dir.join("latest.ckpt.1").exists());
        assert!(dir.join("latest.ckpt.2").exists());
        assert!(!dir.join("latest.ckpt.3").exists(), "history must stay bounded at keep");
        // Every surviving rotation is a complete, loadable checkpoint.
        let old = RunCheckpoint::load(dir.join("latest.ckpt.2")).unwrap();
        assert_eq!(old.encode(), ck.encode());
        // keep = 1 rotates nothing: the single-file overwrite behavior.
        rotate_history(&path, 1).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writer_emits_initial_checkpoint_and_final_snapshot() {
        let dir = std::env::temp_dir().join(format!("pff_ckpt_writer_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = ExperimentConfig::tiny();
        cfg.checkpoint_dir = dir.clone();
        cfg.checkpoint_keep = 2;
        let cfg = cfg.validated().unwrap();
        let store = Arc::new(MemStore::new());
        let bus = EventBus::new();
        let rx = bus.subscribe();
        let writer =
            CheckpointWriter::spawn(&cfg, Arc::new(AllLayers), store.clone(), bus.clone(), false)
                .unwrap();
        // Initial write landed synchronously.
        let ev = rx.try_iter().next().expect("initial CheckpointWritten");
        let RunEvent::CheckpointWritten { path, wire_bytes, .. } = ev else {
            panic!("expected CheckpointWritten, got {ev}");
        };
        assert!(wire_bytes > 0);
        assert!(std::path::Path::new(&path).exists());

        store
            .put_layer(
                0,
                0,
                LayerParams {
                    w: Matrix::zeros(2, 2),
                    b: vec![0.0; 2],
                    normalize_input: false,
                    opt: None,
                },
            )
            .unwrap();
        writer.finish(true).unwrap();
        let ck = RunCheckpoint::load(dir.join(CHECKPOINT_FILE)).unwrap();
        assert_eq!(ck.store.layers.len(), 1, "final snapshot must include late publishes");
        // keep = 2: the final write rotated the initial one into slot .1.
        let rotated = RunCheckpoint::load(dir.join("latest.ckpt.1")).unwrap();
        assert_eq!(rotated.store.layers.len(), 0, "slot .1 holds the previous (initial) write");

        // A fresh (non-resume) writer aimed at this directory must refuse
        // to clobber the existing resume point; a resuming one may.
        let err =
            CheckpointWriter::spawn(&cfg, Arc::new(AllLayers), store.clone(), bus.clone(), false)
                .unwrap_err();
        assert!(err.to_string().contains("--resume"), "{err}");
        CheckpointWriter::spawn(&cfg, Arc::new(AllLayers), store, bus, true)
            .unwrap()
            .finish(false)
            .unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
