//! The work-bucket dispatcher: ready-queue + per-worker deques with
//! stealing over a [`TaskGraph`].
//!
//! Modeled on mmtk-core's packet buckets and the dynec blocker-count
//! snippet (SNIPPETS.md): every task carries a blocker count (its graph
//! in-degree); completing a task decrements its dependents' counts, and a
//! count hitting zero moves the task into the queue of its *assignee* —
//! the registered worker `workers[home % workers.len()]`, so with worker
//! count == node count every task queues on its paper-static owner and
//! the drain order is exactly the static schedule. An idle worker first
//! drains its own queue in `(chapter, layer)` order, then *steals* the
//! largest outstanding task from the most loaded peer — the elastic path
//! that keeps a heterogeneous fleet busy.
//!
//! Workers may join and leave mid-run: joining rebalances the ready
//! queues; leaving requeues the departed worker's leased tasks (the
//! crash-recovery path, driven by the registry's lease expiry or a
//! connection drop).
//!
//! The dispatcher is also the single emitter of chapter progress events:
//! it groups tasks by `(chapter, home)` and emits `ChapterStarted` /
//! `ChapterFinished` exactly as the static per-node scripts did, plus the
//! per-lease `TaskStarted` / `TaskStolen` and membership
//! `WorkerJoined` / `WorkerLeft` events. Events are always emitted
//! *after* releasing the internal lock (observers run on the emitting
//! thread).

use std::collections::{BTreeSet, HashMap, HashSet};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::coordinator::events::{EventBus, RunEvent};
use crate::coordinator::taskgraph::{Task, TaskGraph};
use crate::sync::{LockRank, OrderedCondvar, OrderedMutex};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TaskState {
    /// Blocked on dependencies.
    Pending,
    /// All dependencies done; queued for (or awaiting) a worker.
    Ready,
    /// Leased to worker `.0`.
    Leased(u32),
    /// Completed (or pre-completed by the resume scan).
    Done,
}

/// Per-`(chapter, home)` progress group — the unit the static path called
/// "a chapter on a node", reconstructed for event parity.
struct Group {
    total: usize,
    done: usize,
    /// Whether any task of the group was actually leased (false for
    /// fully pre-completed groups, which emit no events).
    started: bool,
    busy_s: f64,
    wait_s: f64,
    last_loss: f32,
    last_layer: usize,
}

/// Queue key: tasks order by `(chapter, layer, id)` so a drain always
/// takes the earliest cell first (and steals take the latest).
type Key = (u32, usize, usize);

struct Inner {
    state: Vec<TaskState>,
    blockers: Vec<u32>,
    /// Ready tasks, bucketed by assignee worker.
    queues: HashMap<u32, BTreeSet<Key>>,
    /// Registered workers, sorted by id.
    workers: Vec<u32>,
    /// Workers currently holding a lease.
    busy: HashSet<u32>,
    groups: HashMap<(u32, usize), Group>,
    /// Ready tasks with no registered worker to hold them yet.
    limbo: BTreeSet<Key>,
    /// Whether leasing has begun (false while admission waits for
    /// `min_workers`).
    open: bool,
    closed: Option<String>,
    done: usize,
}

/// Result of a non-blocking [`Dispatcher::poll_task`].
pub enum Poll {
    /// A task was leased to the polling worker.
    Task(Task),
    /// The run is complete — no more tasks will ever be available.
    Complete,
    /// Nothing available right now; ask again (or block).
    Pending,
}

/// The shared task dispatcher — see the module docs.
pub struct Dispatcher {
    graph: TaskGraph,
    inner: OrderedMutex<Inner>,
    cond: OrderedCondvar,
    bus: EventBus,
    /// Whether idle workers may steal from peers' queues. Off for cluster
    /// runs without `ship_opt_state`: each worker process has a private
    /// `OptBank`, so moving a home's task across processes would drop its
    /// Adam moments unless the wire carries them.
    allow_steal: bool,
    /// Whether membership changes emit `WorkerJoined`/`WorkerLeft`
    /// (cluster runs; the in-proc pool joins silently).
    announce: bool,
}

impl Dispatcher {
    /// Build a dispatcher over `graph`, emitting progress on `bus`.
    pub fn new(graph: TaskGraph, bus: EventBus, allow_steal: bool, announce: bool) -> Self {
        let n = graph.len();
        let mut state = Vec::with_capacity(n);
        let mut blockers = Vec::with_capacity(n);
        let mut limbo = BTreeSet::new();
        let mut groups: HashMap<(u32, usize), Group> = HashMap::new();
        for t in graph.tasks() {
            let deg = graph.in_degree(t.id);
            blockers.push(deg);
            if deg == 0 {
                state.push(TaskState::Ready);
                limbo.insert((t.chapter, t.layer, t.id));
            } else {
                state.push(TaskState::Pending);
            }
            let g = groups.entry((t.chapter, t.home)).or_insert(Group {
                total: 0,
                done: 0,
                started: false,
                busy_s: 0.0,
                wait_s: 0.0,
                last_loss: 0.0,
                last_layer: 0,
            });
            g.total += 1;
        }
        Dispatcher {
            graph,
            inner: OrderedMutex::new(
                LockRank::Dispatcher,
                Inner {
                    state,
                    blockers,
                    queues: HashMap::new(),
                    workers: Vec::new(),
                    busy: HashSet::new(),
                    groups,
                    limbo,
                    open: false,
                    closed: None,
                    done: 0,
                },
            ),
            cond: OrderedCondvar::new(),
            bus,
            allow_steal,
            announce,
        }
    }

    /// The graph this dispatcher drains.
    pub fn graph(&self) -> &TaskGraph {
        &self.graph
    }

    /// Begin leasing tasks (admission gate satisfied).
    pub fn open(&self) {
        let mut g = self.inner.lock();
        g.open = true;
        drop(g);
        self.cond.notify_all();
    }

    /// Register a worker; its bucket of homed tasks becomes available and
    /// ready tasks rebalance across the new membership.
    pub fn worker_joined(&self, id: u32, name: &str) {
        let mut g = self.inner.lock();
        let announce = if g.workers.contains(&id) {
            false
        } else {
            g.workers.push(id);
            g.workers.sort_unstable();
            rebuild(&self.graph, &mut g);
            self.announce
        };
        drop(g);
        self.cond.notify_all();
        if announce {
            self.bus.emit(RunEvent::WorkerJoined { worker: id as usize, name: name.to_string() });
        }
    }

    /// Deregister a worker: its leased tasks return to Ready and the
    /// queues rebalance. Returns the `(chapter, layer)` cells that were
    /// requeued, for lease-expiry attribution.
    pub fn worker_left(&self, id: u32) -> Vec<(u32, usize)> {
        let mut g = self.inner.lock();
        let was = g.workers.len();
        g.workers.retain(|w| *w != id);
        if g.workers.len() == was {
            return Vec::new(); // never registered (or already removed)
        }
        g.busy.remove(&id);
        let mut cells = Vec::new();
        for t in self.graph.tasks() {
            if g.state[t.id] == TaskState::Leased(id) {
                g.state[t.id] = TaskState::Ready;
                cells.push(t.cell());
            }
        }
        rebuild(&self.graph, &mut g);
        let complete = g.done == self.graph.len();
        drop(g);
        self.cond.notify_all();
        if self.announce && !complete {
            self.bus.emit(RunEvent::WorkerLeft { worker: id as usize, requeued: cells.len() });
        }
        cells
    }

    /// Blocking task fetch for `worker`: parks until a task leases, the
    /// run completes (`None`), the dispatcher closes (error), or
    /// `timeout` elapses (error).
    pub fn next_task(&self, worker: u32, timeout: Duration) -> Result<Option<Task>> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock();
        loop {
            if let Some(reason) = &g.closed {
                bail!("dispatcher closed: {reason}");
            }
            if g.done == self.graph.len() {
                return Ok(None);
            }
            // A waiter can be parked here on behalf of a connection that
            // dropped (worker_left already ran): never lease to a worker
            // outside the membership, or the grant is orphaned — its
            // requeue scan has already happened.
            if !g.workers.contains(&worker) {
                bail!("worker {worker} is not registered with the dispatcher (departed?)");
            }
            if g.open {
                if let Some((id, stolen_from)) = pick(&self.graph, &mut g, worker, self.allow_steal)
                {
                    let (task, events) = lease(&self.graph, &mut g, worker, id, stolen_from);
                    drop(g);
                    for ev in events {
                        self.bus.emit(ev);
                    }
                    return Ok(Some(task));
                }
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("worker {worker}: no ready task within {timeout:?} (run stalled)");
            }
            let (g2, _) = self.cond.wait_timeout(g, deadline - now);
            g = g2;
        }
    }

    /// Non-blocking task fetch (the TCP server's inline try before it
    /// parks a waiter thread).
    pub fn poll_task(&self, worker: u32) -> Result<Poll> {
        let mut g = self.inner.lock();
        if let Some(reason) = &g.closed {
            bail!("dispatcher closed: {reason}");
        }
        if g.done == self.graph.len() {
            return Ok(Poll::Complete);
        }
        if !g.workers.contains(&worker) {
            bail!("worker {worker} is not registered with the dispatcher (departed?)");
        }
        if g.open {
            if let Some((id, stolen_from)) = pick(&self.graph, &mut g, worker, self.allow_steal) {
                let (task, events) = lease(&self.graph, &mut g, worker, id, stolen_from);
                drop(g);
                for ev in events {
                    self.bus.emit(ev);
                }
                return Ok(Poll::Task(task));
            }
        }
        Ok(Poll::Pending)
    }

    /// Report task `id` complete by `worker`: unblocks dependents,
    /// accounts the `(chapter, home)` group and emits `ChapterFinished`
    /// when the group closes.
    pub fn complete(
        &self,
        worker: u32,
        id: usize,
        loss: f32,
        busy_s: f64,
        wait_s: f64,
    ) -> Result<()> {
        let mut g = self.inner.lock();
        // Bounds-check before indexing: `id` comes straight off the wire
        // (TASK_DONE), and a panic here would poison the dispatcher mutex
        // and kill the whole run on one malformed frame.
        ensure!(
            id < g.state.len(),
            "task id {id} out of range (graph has {} tasks)",
            g.state.len()
        );
        ensure!(
            g.state[id] == TaskState::Leased(worker),
            "task {id} is not leased to worker {worker}"
        );
        g.state[id] = TaskState::Done;
        g.done += 1;
        g.busy.remove(&worker);
        let t = self.graph.task(id);
        let mut events = Vec::new();
        let group = g.groups.get_mut(&(t.chapter, t.home)).expect("group exists");
        group.done += 1;
        group.busy_s += busy_s;
        group.wait_s += wait_s;
        group.last_loss = loss;
        group.last_layer = t.layer;
        if group.done == group.total && group.started {
            let layer =
                if group.total == self.graph.n_layers() { None } else { Some(group.last_layer) };
            events.push(RunEvent::ChapterFinished {
                node: t.home,
                layer,
                chapter: t.chapter,
                loss: group.last_loss,
                busy_s: group.busy_s,
                wait_s: group.wait_s,
            });
        }
        unblock_dependents(&self.graph, &mut g, id);
        drop(g);
        self.cond.notify_all();
        for ev in events {
            self.bus.emit(ev);
        }
        Ok(())
    }

    /// Return a leased task to the ready queue without completing it —
    /// the grant never reached its worker (the reply write failed), so
    /// someone else must run it. No-op when `worker` no longer holds the
    /// lease (e.g. `worker_left` already requeued it).
    pub fn release(&self, worker: u32, id: usize) {
        let mut g = self.inner.lock();
        if id >= g.state.len() || g.state[id] != TaskState::Leased(worker) {
            return;
        }
        g.state[id] = TaskState::Ready;
        g.busy.remove(&worker);
        enqueue_ready(&mut g, self.graph.task(id));
        drop(g);
        self.cond.notify_all();
    }

    /// Mark task `id` done without executing it (resume fast-forward).
    /// Only legal while its blockers are already cleared — the scan walks
    /// the graph in dependency order, so a pre-completable task is always
    /// Ready. Emits nothing.
    pub fn precomplete(&self, id: usize) -> Result<()> {
        let mut g = self.inner.lock();
        ensure!(
            id < g.state.len(),
            "precomplete: task id {id} out of range (graph has {} tasks)",
            g.state.len()
        );
        ensure!(
            g.state[id] == TaskState::Ready,
            "precomplete: task {id} has unfinished dependencies"
        );
        let t = self.graph.task(id);
        remove_ready(&mut g, (t.chapter, t.layer, t.id));
        g.state[id] = TaskState::Done;
        g.done += 1;
        g.groups.get_mut(&(t.chapter, t.home)).expect("group exists").done += 1;
        unblock_dependents(&self.graph, &mut g, id);
        drop(g);
        self.cond.notify_all();
        Ok(())
    }

    /// Park until every task is done (Ok), the dispatcher closes (error),
    /// or `timeout` elapses (error).
    pub fn wait_complete(&self, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock();
        loop {
            if let Some(reason) = &g.closed {
                bail!("dispatcher closed: {reason}");
            }
            if g.done == self.graph.len() {
                return Ok(());
            }
            let now = Instant::now();
            if now >= deadline {
                bail!(
                    "run incomplete after {timeout:?}: {}/{} tasks done",
                    g.done,
                    self.graph.len()
                );
            }
            let (g2, _) = self.cond.wait_timeout(g, deadline - now);
            g = g2;
        }
    }

    /// Tasks completed so far.
    pub fn completed(&self) -> usize {
        self.inner.lock().done
    }

    /// Abort the run: every parked and future call errors with `reason`
    /// (first close wins).
    pub fn close(&self, reason: &str) {
        let mut g = self.inner.lock();
        if g.closed.is_none() {
            g.closed = Some(reason.to_string());
        }
        drop(g);
        self.cond.notify_all();
    }
}

/// Queue the ready task `t` on its assignee (or limbo when no workers).
fn enqueue_ready(g: &mut Inner, t: Task) {
    let key = (t.chapter, t.layer, t.id);
    if g.workers.is_empty() {
        g.limbo.insert(key);
    } else {
        let w = g.workers[t.home % g.workers.len()];
        g.queues.entry(w).or_default().insert(key);
    }
}

/// Remove a ready task's key from wherever it is queued.
fn remove_ready(g: &mut Inner, key: Key) {
    if g.limbo.remove(&key) {
        return;
    }
    for q in g.queues.values_mut() {
        if q.remove(&key) {
            return;
        }
    }
}

/// Rebuild every queue from scratch for the current membership.
fn rebuild(graph: &TaskGraph, g: &mut Inner) {
    g.limbo.clear();
    let ws = g.workers.clone();
    g.queues.retain(|w, _| ws.contains(w));
    for q in g.queues.values_mut() {
        q.clear();
    }
    for &w in &ws {
        g.queues.entry(w).or_default();
    }
    for t in graph.tasks() {
        if g.state[t.id] == TaskState::Ready {
            enqueue_ready(g, *t);
        }
    }
}

/// Decrement `id`'s dependents' blocker counts; newly unblocked tasks
/// become Ready and queue on their assignee.
fn unblock_dependents(graph: &TaskGraph, g: &mut Inner, id: usize) {
    for &d in graph.dependents(id) {
        g.blockers[d] -= 1;
        if g.blockers[d] == 0 && g.state[d] == TaskState::Pending {
            g.state[d] = TaskState::Ready;
            enqueue_ready(g, graph.task(d));
        }
    }
}

/// Choose a task for `worker`: own queue front first, then — when
/// stealing is allowed — the *back* of the most loaded eligible peer
/// queue (a peer is eligible when it is busy executing or has ≥ 2 queued
/// tasks, so we never race an idle peer for its only task).
fn pick(
    _graph: &TaskGraph,
    g: &mut Inner,
    worker: u32,
    allow_steal: bool,
) -> Option<(usize, Option<u32>)> {
    if let Some(q) = g.queues.get_mut(&worker) {
        if let Some(&key) = q.iter().next() {
            q.remove(&key);
            return Some((key.2, None));
        }
    }
    if allow_steal {
        let mut best: Option<(usize, u32)> = None;
        for (&w, q) in &g.queues {
            if w == worker || q.is_empty() {
                continue;
            }
            if g.busy.contains(&w) || q.len() >= 2 {
                let better = match best {
                    None => true,
                    Some((len, bw)) => q.len() > len || (q.len() == len && w < bw),
                };
                if better {
                    best = Some((q.len(), w));
                }
            }
        }
        if let Some((_, from)) = best {
            let q = g.queues.get_mut(&from).expect("best queue exists");
            let key = *q.iter().next_back().expect("best queue non-empty");
            q.remove(&key);
            return Some((key.2, Some(from)));
        }
    }
    None
}

/// Lease `id` to `worker`, producing the events to emit after unlocking.
fn lease(
    graph: &TaskGraph,
    g: &mut Inner,
    worker: u32,
    id: usize,
    stolen_from: Option<u32>,
) -> (Task, Vec<RunEvent>) {
    let t = graph.task(id);
    g.state[id] = TaskState::Leased(worker);
    g.busy.insert(worker);
    let mut events = Vec::new();
    let group = g.groups.get_mut(&(t.chapter, t.home)).expect("group exists");
    if !group.started {
        group.started = true;
        let layer = if group.total == graph.n_layers() { None } else { Some(t.layer) };
        events.push(RunEvent::ChapterStarted { node: t.home, layer, chapter: t.chapter });
    }
    if let Some(from) = stolen_from {
        events.push(RunEvent::TaskStolen {
            worker: worker as usize,
            from: from as usize,
            chapter: t.chapter,
            layer: t.layer,
        });
    }
    events.push(RunEvent::TaskStarted { worker: worker as usize, chapter: t.chapter, layer: t.layer });
    (t, events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn graph(nodes: usize, splits: u32) -> TaskGraph {
        let mut cfg = ExperimentConfig::tiny();
        cfg.nodes = nodes;
        cfg.splits = splits;
        cfg.epochs = splits;
        TaskGraph::pipeline(&cfg, false, |c, _| c as usize % nodes)
            .build()
            .unwrap()
    }

    fn drain_single(d: &Dispatcher, worker: u32) -> Vec<(u32, usize)> {
        let mut order = Vec::new();
        while let Some(t) = d.next_task(worker, Duration::from_secs(5)).unwrap() {
            order.push(t.cell());
            d.complete(worker, t.id, 0.5, 0.0, 0.0).unwrap();
        }
        order
    }

    #[test]
    fn single_worker_drains_in_serial_order() {
        let g = graph(2, 3);
        let want: Vec<(u32, usize)> =
            g.serial_order().into_iter().map(|id| g.task(id).cell()).collect();
        let d = Dispatcher::new(g, EventBus::new(), true, false);
        d.worker_joined(0, "w0");
        d.open();
        assert_eq!(drain_single(&d, 0), want);
        d.wait_complete(Duration::from_millis(10)).unwrap();
    }

    #[test]
    fn next_task_blocks_until_open() {
        let d = Dispatcher::new(graph(1, 2), EventBus::new(), true, false);
        d.worker_joined(0, "w0");
        let err = d.next_task(0, Duration::from_millis(20)).unwrap_err();
        assert!(err.to_string().contains("no ready task"), "{err}");
        d.open();
        assert!(d.next_task(0, Duration::from_secs(1)).unwrap().is_some());
    }

    #[test]
    fn worker_left_requeues_leases() {
        let d = Dispatcher::new(graph(2, 2), EventBus::new(), true, false);
        d.worker_joined(0, "w0");
        d.worker_joined(1, "w1");
        d.open();
        let t = d.next_task(0, Duration::from_secs(1)).unwrap().unwrap();
        let cells = d.worker_left(0);
        assert_eq!(cells, vec![t.cell()]);
        // The survivor can retake and finish everything.
        assert_eq!(drain_single(&d, 1).len(), d.graph().len());
    }

    #[test]
    fn departed_worker_cannot_lease() {
        use std::sync::Arc;
        let d = Arc::new(Dispatcher::new(graph(1, 4), EventBus::new(), true, false));
        d.worker_joined(0, "w0");
        d.worker_joined(1, "w1");
        d.open();
        // Worker 0 takes the only ready task; worker 1's fetch parks.
        let t = d.next_task(0, Duration::from_secs(1)).unwrap().unwrap();
        let d2 = d.clone();
        let parked = std::thread::spawn(move || d2.next_task(1, Duration::from_secs(5)));
        // Worker 1's connection drops while the waiter is parked: the
        // waiter must bail, not lease a survivor's task later.
        d.worker_left(1);
        let err = parked.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("not registered"), "{err}");
        // A poll for the departed worker errors too.
        assert!(d.poll_task(1).is_err());
        d.complete(0, t.id, 0.0, 0.0, 0.0).unwrap();
        // The survivor drains the rest.
        assert_eq!(drain_single(&d, 0).len(), d.graph().len() - 1);
    }

    #[test]
    fn complete_rejects_out_of_range_id_without_poisoning() {
        let d = Dispatcher::new(graph(1, 2), EventBus::new(), true, false);
        d.worker_joined(0, "w0");
        d.open();
        let t = d.next_task(0, Duration::from_secs(1)).unwrap().unwrap();
        let err = d.complete(0, usize::MAX, 0.0, 0.0, 0.0).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        assert!(d.precomplete(usize::MAX).is_err());
        // The mutex is not poisoned: the run continues normally.
        d.complete(0, t.id, 0.0, 0.0, 0.0).unwrap();
        assert_eq!(d.completed(), 1);
    }

    #[test]
    fn release_requeues_an_unnotified_lease() {
        let d = Dispatcher::new(graph(1, 2), EventBus::new(), true, false);
        d.worker_joined(0, "w0");
        d.open();
        let t = d.next_task(0, Duration::from_secs(1)).unwrap().unwrap();
        d.release(0, t.id);
        // The same task leases again.
        let t2 = d.next_task(0, Duration::from_secs(1)).unwrap().unwrap();
        assert_eq!(t.id, t2.id);
        // Releasing a lease the worker no longer holds is a no-op.
        d.release(1, t2.id);
        d.release(0, usize::MAX);
        d.complete(0, t2.id, 0.0, 0.0, 0.0).unwrap();
    }

    #[test]
    fn precomplete_skips_without_events() {
        let g = graph(1, 2);
        let order = g.serial_order();
        let bus = EventBus::new();
        let d = Dispatcher::new(g, bus.clone(), true, false);
        for id in order {
            d.precomplete(id).unwrap();
        }
        d.wait_complete(Duration::from_millis(10)).unwrap();
        assert!(bus.history().is_empty(), "precompletion must be silent");
        d.worker_joined(0, "w0");
        d.open();
        assert!(d.next_task(0, Duration::from_secs(1)).unwrap().is_none());
    }

    #[test]
    fn precomplete_rejects_blocked_tasks() {
        let g = graph(1, 2);
        let blocked = g.id_of(1, 0).unwrap();
        let d = Dispatcher::new(g, EventBus::new(), true, false);
        assert!(d.precomplete(blocked).is_err());
    }

    #[test]
    fn close_unblocks_with_reason() {
        let d = Dispatcher::new(graph(1, 2), EventBus::new(), true, false);
        d.close("boom");
        let err = d.next_task(0, Duration::from_secs(5)).unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
        let err = d.wait_complete(Duration::from_secs(5)).unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
    }

    #[test]
    fn steal_takes_from_loaded_peer() {
        // 1 node, so every task homes on worker 0's bucket; worker 1 can
        // only make progress by stealing.
        let d = Dispatcher::new(graph(1, 4), EventBus::new(), true, false);
        d.worker_joined(0, "w0");
        d.worker_joined(1, "w1");
        d.open();
        let a = d.next_task(0, Duration::from_secs(1)).unwrap().unwrap();
        // Worker 0 is busy; worker 1 steals the next ready task.
        let b = d.next_task(1, Duration::from_secs(1)).unwrap().unwrap();
        assert_ne!(a.id, b.id);
        d.complete(0, a.id, 0.0, 0.0, 0.0).unwrap();
        d.complete(1, b.id, 0.0, 0.0, 0.0).unwrap();
    }

    #[test]
    fn chapter_events_group_by_home() {
        let g = graph(2, 2);
        let bus = EventBus::new();
        let d = Dispatcher::new(g, bus.clone(), true, false);
        d.worker_joined(0, "w0");
        d.open();
        drain_single(&d, 0);
        let hist = bus.history();
        let started = hist
            .iter()
            .filter(|e| matches!(e, RunEvent::ChapterStarted { .. }))
            .count();
        let finished = hist
            .iter()
            .filter(|e| matches!(e, RunEvent::ChapterFinished { .. }))
            .count();
        assert_eq!(started, 2);
        assert_eq!(finished, 2);
    }
}
