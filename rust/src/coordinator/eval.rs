//! Model assembly from the store and test-set evaluation.

use anyhow::{Context, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::schedulers::head_slot;
use crate::coordinator::store::ParamStore;
use crate::data::Dataset;
use crate::engine::Engine;
use crate::ff::classifier::{accuracy, predict_goodness, predict_softmax};
use crate::ff::perfopt::{predict as perfopt_predict, PerfOptReadout};
use crate::ff::{ClassifierMode, FFNetwork, LinearHead};
use crate::tensor::{AdamState, Rng};

/// The assembled output of a PFF run: whatever is needed to predict.
#[derive(Clone, Debug)]
pub struct TrainedModel {
    /// The FF network (latest published version of every layer).
    pub net: FFNetwork,
    /// Full-network softmax head (Softmax classifier mode).
    pub head: Option<LinearHead>,
    /// Per-layer heads (PerfOpt mode).
    pub layer_heads: Vec<LinearHead>,
}

/// Assemble the final model from the latest store versions.
pub fn assemble(store: &dyn ParamStore, cfg: &ExperimentConfig) -> Result<TrainedModel> {
    let n_layers = cfg.num_layers();
    let mut layers = Vec::with_capacity(n_layers);
    for l in 0..n_layers {
        let (_, params) = store
            .latest_layer(l)?
            .with_context(|| format!("no published version of layer {l}"))?;
        let (layer, _) = params.to_layer();
        layers.push(layer);
    }
    let net = FFNetwork { layers, classes: cfg.classes };

    let head = store.latest_head()?.map(|(_, p)| p.to_head().0);

    let mut layer_heads = Vec::new();
    if cfg.perfopt {
        for l in 0..n_layers {
            let (_, params) = store
                .latest_layer(head_slot(l))?
                .with_context(|| format!("no published PerfOpt head for layer {l}"))?;
            let (hl, _) = params.to_layer();
            layer_heads.push(LinearHead { w: hl.w, b: hl.b });
        }
    }
    Ok(TrainedModel { net, head, layer_heads })
}

/// Train the full-network softmax head post-hoc (when `head_inline` is
/// off, §3: "trained using backpropagation … at the end of the training").
/// Returns the trained head and the time spent, in seconds.
pub fn train_head_posthoc(
    eng: &mut dyn Engine,
    model: &TrainedModel,
    train: &Dataset,
    cfg: &ExperimentConfig,
) -> Result<(LinearHead, f64)> {
    use crate::coordinator::lr::cooldown;
    use crate::ff::classifier::head_features;

    let t0 = std::time::Instant::now();
    let mut rng = Rng::derive(cfg.seed, 0x504F_5354); // "POST"
    let mut head = model.net.new_head(&mut rng);
    let mut opt = AdamState::new(head.w.rows, head.w.cols);
    let feats = head_features(eng, &model.net, &train.x)?;
    for epoch in 0..cfg.epochs {
        let lr = cooldown(cfg.lr_head, epoch, cfg.epochs);
        let mut order: Vec<usize> = (0..train.len()).collect();
        let mut shuffle_rng = Rng::derive(cfg.seed, 0x5053_4846 ^ u64::from(epoch));
        shuffle_rng.shuffle(&mut order);
        for idx in order.chunks(cfg.batch) {
            let bx = feats.gather_rows(idx);
            let by: Vec<u8> = idx.iter().map(|&r| train.y[r]).collect();
            eng.head_train_step(&mut head, &mut opt, &bx, &by, lr)?;
        }
    }
    Ok((head, t0.elapsed().as_secs_f64()))
}

/// Evaluate the model on `data` (chunked), per the configured classifier.
///
/// Batched + parallel: the per-chunk gather is one contiguous memcpy
/// ([`crate::tensor::Matrix::rows_range`]) and the chunk size scales with
/// the kernel thread count, so the big stacked goodness matmuls inside
/// keep every worker busy. Rows are scored independently, so neither the
/// chunk size nor the thread count changes a single output bit.
pub fn evaluate(
    eng: &mut dyn Engine,
    model: &TrainedModel,
    data: &Dataset,
    cfg: &ExperimentConfig,
) -> Result<f64> {
    // Batch factor capped at 8: past that the stacked goodness tensor's
    // footprint grows faster than the parallel win.
    let chunk = cfg.eval_chunk.max(1) * crate::tensor::pool::current_threads().clamp(1, 8);
    let mut preds: Vec<u8> = Vec::with_capacity(data.len());
    let mut r0 = 0;
    while r0 < data.len() {
        let r1 = (r0 + chunk).min(data.len());
        let xb = data.x.rows_range(r0, r1);
        let mut p = if cfg.perfopt {
            perfopt_predict(eng, &model.net, &model.layer_heads, &xb, cfg.perfopt_readout)?
        } else {
            match cfg.classifier {
                ClassifierMode::Goodness => predict_goodness(eng, &model.net, &xb)?,
                ClassifierMode::Softmax => {
                    let head = model.head.as_ref().context("softmax mode but no head trained")?;
                    predict_softmax(eng, &model.net, head, &xb)?
                }
            }
        };
        preds.append(&mut p);
        r0 = r1;
    }
    Ok(accuracy(&preds, &data.y))
}

/// Evaluate with an explicit readout override (Table 4 reports both
/// PerfOpt readouts from the same trained model).
pub fn evaluate_perfopt_readout(
    eng: &mut dyn Engine,
    model: &TrainedModel,
    data: &Dataset,
    cfg: &ExperimentConfig,
    readout: PerfOptReadout,
) -> Result<f64> {
    let mut c = cfg.clone();
    c.perfopt = true;
    c.perfopt_readout = readout;
    evaluate(eng, model, data, &c)
}
