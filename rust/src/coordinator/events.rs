//! Typed run-progress events and the observer bus.
//!
//! Everything a running experiment used to `eprintln!` is now a
//! [`RunEvent`] emitted on an [`EventBus`]: node chapter progress, layer
//! publishes (with wire bytes), cluster membership, the final evaluation
//! and a terminal [`RunEvent::Done`]. The library itself prints nothing —
//! consumers attach callbacks with [`EventBus::observe`] (or
//! `ExperimentBuilder::observer`) or pull a replayed stream with
//! [`EventBus::subscribe`] / `RunHandle::events`.
//!
//! Ordering: emissions are serialized through one lock, so every
//! subscriber channel sees the global emission order (in particular, a
//! node's `ChapterStarted` always precedes its `ChapterFinished`, and
//! `Done` is last). Callback observers run outside the lock — they may be
//! interleaved across concurrently-emitting nodes, but each sees every
//! event exactly once.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::registry::NodeInfo;
use crate::metrics::LossCurve;
use crate::sync::{LockRank, OrderedMutex};

/// One typed progress event from a running experiment.
#[derive(Clone, Debug)]
pub enum RunEvent {
    /// Cluster mode: the expected workers have all registered.
    WorkersRegistered {
        /// The registered roster (id + self-reported name).
        workers: Vec<NodeInfo>,
    },
    /// A node began a chapter. `layer` is the owned layer for
    /// layer-pinned schedulers (Single-Layer), `None` when the chapter
    /// spans every layer (Sequential / All-Layers / Federated).
    ChapterStarted {
        /// Node index.
        node: usize,
        /// Owned layer, when the scheduler pins one per node.
        layer: Option<usize>,
        /// Chapter index in `[0, S)`.
        chapter: u32,
    },
    /// A node finished a chapter.
    ChapterFinished {
        /// Node index.
        node: usize,
        /// Owned layer, when the scheduler pins one per node.
        layer: Option<usize>,
        /// Chapter index in `[0, S)`.
        chapter: u32,
        /// Mean training loss of the chapter (last layer's, for
        /// whole-network chapters).
        loss: f32,
        /// Seconds of compute (train/forward/publish/neg-gen spans) inside
        /// the chapter — the kernel-time half of the perf split.
        busy_s: f64,
        /// Seconds blocked on store dependencies (wait spans) inside the
        /// chapter — the coordination half of the perf split.
        wait_s: f64,
    },
    /// A node published layer parameters to the store. `layer` values of
    /// [`crate::coordinator::schedulers::HEAD_SLOT_BASE`] and above are
    /// PerfOpt per-layer heads (see `schedulers::head_slot`).
    LayerPublished {
        /// Publishing node.
        node: usize,
        /// Store layer slot.
        layer: usize,
        /// Chapter the parameters belong to.
        chapter: u32,
        /// Approximate bytes on the wire (the §6 communication metric).
        wire_bytes: u64,
        /// Bytes the same publish would cost as an uncompressed f32 full
        /// frame — `wire_bytes / raw_bytes` is the observed compression
        /// ratio of the active `wire_codec` (and of delta publishes).
        raw_bytes: u64,
    },
    /// A node published the full-network softmax head.
    HeadPublished {
        /// Publishing node.
        node: usize,
        /// Chapter the head belongs to.
        chapter: u32,
        /// Approximate bytes on the wire.
        wire_bytes: u64,
    },
    /// A durable run checkpoint landed on disk (atomic write + rename).
    CheckpointWritten {
        /// Path of the checkpoint file.
        path: String,
        /// Serialized size in bytes (same codec as the wire format).
        wire_bytes: u64,
        /// Bytes the same checkpoint would occupy at full f32 (format-v2
        /// files shrink below this under `wire_codec=bf16`/`i8`).
        raw_bytes: u64,
    },
    /// The dispatcher leased a `(chapter, layer)` task to a worker.
    TaskStarted {
        /// Worker id the lease went to.
        worker: usize,
        /// Chapter of the leased cell.
        chapter: u32,
        /// Layer of the leased cell.
        layer: usize,
    },
    /// A worker stole a queued task from another worker's deque.
    TaskStolen {
        /// The thief.
        worker: usize,
        /// The victim whose queue the task came from.
        from: usize,
        /// Chapter of the stolen cell.
        chapter: u32,
        /// Layer of the stolen cell.
        layer: usize,
    },
    /// A worker joined the dispatcher mid-run (elastic membership).
    WorkerJoined {
        /// Worker id.
        worker: usize,
        /// Self-reported worker name.
        name: String,
    },
    /// A worker left (or was declared dead); its leased tasks were requeued.
    WorkerLeft {
        /// Worker id.
        worker: usize,
        /// Number of leased tasks returned to the ready set.
        requeued: usize,
    },
    /// Test-set evaluation finished.
    Eval {
        /// Accuracy in `[0, 1]`.
        accuracy: f64,
    },
    /// The run is over; no further events follow. Emitted on success,
    /// failure and cancellation alike.
    Done {
        /// Whether the run produced a report.
        ok: bool,
    },
}

impl std::fmt::Display for RunEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunEvent::WorkersRegistered { workers } => {
                let names: Vec<String> =
                    workers.iter().map(|w| format!("{}#{}", w.name, w.id)).collect();
                write!(f, "{} worker(s) registered: {}", workers.len(), names.join(", "))
            }
            RunEvent::ChapterStarted { node, layer: Some(l), chapter } => {
                write!(f, "node {node}: chapter {chapter} started (layer {l})")
            }
            RunEvent::ChapterStarted { node, layer: None, chapter } => {
                write!(f, "node {node}: chapter {chapter} started")
            }
            RunEvent::ChapterFinished { node, layer: Some(l), chapter, loss, busy_s, wait_s } => {
                write!(
                    f,
                    "node {node}: chapter {chapter} finished (layer {l}, loss {loss:.4}, \
                     busy {busy_s:.2}s, wait {wait_s:.2}s)"
                )
            }
            RunEvent::ChapterFinished { node, layer: None, chapter, loss, busy_s, wait_s } => {
                write!(
                    f,
                    "node {node}: chapter {chapter} finished (loss {loss:.4}, \
                     busy {busy_s:.2}s, wait {wait_s:.2}s)"
                )
            }
            RunEvent::LayerPublished { node, layer, chapter, wire_bytes, raw_bytes } => {
                let b = wire_bytes;
                if raw_bytes == wire_bytes {
                    write!(f, "node {node}: published layer {layer} @ chapter {chapter} ({b} B)")
                } else {
                    write!(
                        f,
                        "node {node}: published layer {layer} @ chapter {chapter} \
                         ({b} of {raw_bytes} raw B)"
                    )
                }
            }
            RunEvent::HeadPublished { node, chapter, wire_bytes } => {
                write!(f, "node {node}: published head @ chapter {chapter} ({wire_bytes} B)")
            }
            RunEvent::CheckpointWritten { path, wire_bytes, raw_bytes } => {
                if raw_bytes == wire_bytes {
                    write!(f, "checkpoint written: {path} ({wire_bytes} B)")
                } else {
                    write!(f, "checkpoint written: {path} ({wire_bytes} of {raw_bytes} raw B)")
                }
            }
            RunEvent::TaskStarted { worker, chapter, layer } => {
                write!(f, "worker {worker}: task chapter {chapter} / layer {layer} started")
            }
            RunEvent::TaskStolen { worker, from, chapter, layer } => {
                write!(
                    f,
                    "worker {worker}: stole task chapter {chapter} / layer {layer} from worker {from}"
                )
            }
            RunEvent::WorkerJoined { worker, name } => {
                write!(f, "worker {worker} ({name}) joined")
            }
            RunEvent::WorkerLeft { worker, requeued } => {
                write!(f, "worker {worker} left ({requeued} task(s) requeued)")
            }
            RunEvent::Eval { accuracy } => write!(f, "eval: accuracy {:.2}%", accuracy * 100.0),
            RunEvent::Done { ok: true } => write!(f, "done"),
            RunEvent::Done { ok: false } => write!(f, "done (run failed)"),
        }
    }
}

/// Callback observer type (runs on the emitting thread; keep it cheap and
/// never emit from inside one).
type Observer<E> = Arc<dyn Fn(&E) + Send + Sync>;

struct BusInner<E> {
    /// Every event emitted so far, replayed to late subscribers so
    /// `RunHandle::events()` never misses the start of a run.
    history: Vec<E>,
    senders: Vec<Sender<E>>,
    observers: Vec<Observer<E>>,
}

impl<E> Default for BusInner<E> {
    fn default() -> Self {
        BusInner { history: Vec::new(), senders: Vec::new(), observers: Vec::new() }
    }
}

/// Cheap-to-clone multi-consumer event bus (std `mpsc` fan-out plus
/// callback observers), generic over the event type. All clones share one
/// stream. Training emits [`RunEvent`] on the [`EventBus`] alias; the
/// serve path emits `ServeEvent` on a `Bus<ServeEvent>` — same replay,
/// ordering and observer semantics, one implementation.
pub struct Bus<E> {
    inner: Arc<OrderedMutex<BusInner<E>>>,
}

/// The training-run event bus (see [`Bus`]).
pub type EventBus = Bus<RunEvent>;

impl<E> Clone for Bus<E> {
    fn clone(&self) -> Self {
        Bus { inner: self.inner.clone() }
    }
}

impl<E> Default for Bus<E> {
    fn default() -> Self {
        Bus { inner: Arc::new(OrderedMutex::new(LockRank::Events, BusInner::default())) }
    }
}

impl<E: Clone + Send> Bus<E> {
    /// Fresh bus with no subscribers.
    pub fn new() -> Self {
        Bus::default()
    }

    /// Emit an event to every observer and subscriber.
    pub fn emit(&self, ev: E) {
        let observers: Vec<Observer<E>> = {
            let mut g = self.inner.lock();
            g.history.push(ev.clone());
            // Channel sends happen under the lock so every subscriber sees
            // the exact global emission order; a dropped Receiver just
            // unsubscribes itself here.
            g.senders.retain(|s| s.send(ev.clone()).is_ok());
            g.observers.clone()
        };
        for obs in observers {
            obs(&ev);
        }
    }

    /// Subscribe a channel. The full event history is replayed first, so
    /// subscribing after launch loses nothing.
    pub fn subscribe(&self) -> Receiver<E> {
        let (tx, rx) = channel();
        let mut g = self.inner.lock();
        for ev in &g.history {
            let _ = tx.send(ev.clone());
        }
        g.senders.push(tx);
        rx
    }

    /// Attach a callback observer (no replay — attach before launch to see
    /// everything).
    pub fn observe(&self, f: impl Fn(&E) + Send + Sync + 'static) {
        self.inner.lock().observers.push(Arc::new(f));
    }

    /// Snapshot of every event emitted so far (the replay history).
    pub fn history(&self) -> Vec<E> {
        self.inner.lock().history.clone()
    }

    /// Number of events emitted so far.
    pub fn len(&self) -> usize {
        self.inner.lock().history.len()
    }

    /// True when nothing has been emitted yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Thread-safe event collector: an observer that records every event for
/// post-run analysis — a chapter-loss [`LossCurve`] or a CSV log (the
/// `metrics/` consumers the coordinator's ad-hoc printing used to be).
///
/// ```no_run
/// # use std::sync::Arc;
/// # use pff::coordinator::{EventLog, Experiment};
/// # use pff::config::ExperimentConfig;
/// let log = Arc::new(EventLog::new());
/// let sink = log.clone();
/// let report = Experiment::builder()
///     .config(ExperimentConfig::tiny())
///     .observer(move |ev| sink.record(ev))
///     .launch()?
///     .join()?;
/// log.write_csv("metrics/events.csv")?;
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct EventLog {
    events: OrderedMutex<Vec<RunEvent>>,
}

impl Default for EventLog {
    fn default() -> Self {
        EventLog { events: OrderedMutex::new(LockRank::Events, Vec::new()) }
    }
}

impl EventLog {
    /// Fresh empty log.
    pub fn new() -> Self {
        EventLog::default()
    }

    /// Record one event (observer body).
    pub fn record(&self, ev: &RunEvent) {
        self.events.lock().push(ev.clone());
    }

    /// Snapshot of everything recorded so far.
    pub fn snapshot(&self) -> Vec<RunEvent> {
        self.events.lock().clone()
    }

    /// Fold the recorded `ChapterFinished` losses into a [`LossCurve`]
    /// (epoch-sorted; concurrent nodes emit out of order).
    pub fn chapter_curve(&self, epochs_per_chapter: u32) -> LossCurve {
        let mut curve = LossCurve::default();
        for ev in self.events.lock().iter() {
            if let RunEvent::ChapterFinished { chapter, loss, .. } = ev {
                curve.push_chapter(*chapter, epochs_per_chapter, *loss);
            }
        }
        curve.sort_by_epoch();
        curve
    }

    /// Write the log as CSV (one row per event, empty cells where a column
    /// does not apply). `busy_s`/`wait_s` carry the per-chapter
    /// compute/wait split so perf analyses can separate kernel time from
    /// store-wait time straight from `--event-csv` output.
    pub fn write_csv(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        let rows: Vec<Vec<String>> =
            self.snapshot().iter().map(crate::metrics::csv::event_csv_row).collect();
        crate::metrics::csv::write_csv(path, crate::metrics::csv::EVENT_CSV_HEADER, &rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finished(node: usize, chapter: u32, loss: f32) -> RunEvent {
        RunEvent::ChapterFinished {
            node,
            layer: None,
            chapter,
            loss,
            busy_s: 0.25,
            wait_s: 0.05,
        }
    }

    #[test]
    fn subscribe_replays_history() {
        let bus = EventBus::new();
        bus.emit(RunEvent::ChapterStarted { node: 0, layer: None, chapter: 0 });
        bus.emit(finished(0, 0, 0.5));
        let rx = bus.subscribe();
        bus.emit(RunEvent::Done { ok: true });
        let got: Vec<RunEvent> = rx.try_iter().collect();
        assert_eq!(got.len(), 3, "history replay + live event");
        assert!(matches!(got[0], RunEvent::ChapterStarted { .. }));
        assert!(matches!(got[2], RunEvent::Done { ok: true }));
    }

    #[test]
    fn dropped_subscriber_is_pruned() {
        let bus = EventBus::new();
        drop(bus.subscribe());
        bus.emit(RunEvent::Done { ok: true });
        assert_eq!(bus.len(), 1);
        let rx = bus.subscribe();
        assert_eq!(rx.try_iter().count(), 1);
    }

    #[test]
    fn observers_see_every_event() {
        let bus = EventBus::new();
        // Observers run OUTSIDE the bus lock, so an observer may take an
        // Events-ranked lock of its own without a rank violation.
        let n = Arc::new(OrderedMutex::new(LockRank::Events, 0usize));
        let n2 = n.clone();
        bus.observe(move |_| *n2.lock() += 1);
        bus.emit(RunEvent::Eval { accuracy: 0.9 });
        bus.emit(RunEvent::Done { ok: true });
        assert_eq!(*n.lock(), 2);
    }

    #[test]
    fn event_log_curve_and_csv() {
        let log = EventLog::new();
        // out-of-order chapters, as concurrent nodes produce them
        log.record(&finished(1, 1, 0.4));
        log.record(&finished(0, 0, 0.8));
        log.record(&RunEvent::LayerPublished {
            node: 0,
            layer: 2,
            chapter: 0,
            wire_bytes: 64,
            raw_bytes: 128,
        });
        log.record(&RunEvent::Eval { accuracy: 0.75 });
        let curve = log.chapter_curve(4);
        let epochs: Vec<f32> = curve.points.iter().map(|p| p.epoch).collect();
        assert_eq!(epochs, vec![4.0, 8.0], "sorted by epoch");
        assert_eq!(curve.points[0].loss, 0.8);

        let dir = std::env::temp_dir().join(format!("pff_evlog_{}", std::process::id()));
        let path = dir.join("events.csv");
        log.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with(
            "event,node,layer,chapter,loss,wire_bytes,accuracy,ok,busy_s,wait_s,raw_bytes\n"
        ));
        assert!(text.contains("layer_published,0,2,0,,64,,,,,128"));
        assert!(text.contains("chapter_finished,0,,0,0.8,,,,0.250000,0.050000,"));
        assert!(text.contains("eval,"));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn task_and_membership_events_render() {
        use crate::metrics::csv::event_csv_row;
        let s = RunEvent::TaskStolen { worker: 2, from: 0, chapter: 3, layer: 1 }.to_string();
        assert!(s.contains("worker 2") && s.contains("chapter 3") && s.contains("worker 0"), "{s}");
        assert_eq!(
            event_csv_row(&RunEvent::TaskStarted { worker: 1, chapter: 4, layer: 2 })[..4],
            ["task_started".to_string(), "1".into(), "2".into(), "4".into()]
        );
        let left = event_csv_row(&RunEvent::WorkerLeft { worker: 1, requeued: 3 });
        assert_eq!(left[0], "worker_left");
        assert_eq!(left[5], "3");
        let bus = EventBus::new();
        bus.emit(RunEvent::WorkerJoined { worker: 5, name: "late".into() });
        assert!(matches!(bus.history()[0], RunEvent::WorkerJoined { worker: 5, .. }));
    }

    #[test]
    fn display_is_human_readable() {
        let s = RunEvent::ChapterFinished {
            node: 2,
            layer: Some(1),
            chapter: 3,
            loss: 0.25,
            busy_s: 1.5,
            wait_s: 0.25,
        }
        .to_string();
        assert!(s.contains("node 2") && s.contains("chapter 3") && s.contains("0.2500"), "{s}");
        assert!(s.contains("busy 1.50s") && s.contains("wait 0.25s"), "{s}");
        assert_eq!(RunEvent::Done { ok: true }.to_string(), "done");
    }
}
