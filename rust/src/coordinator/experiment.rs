//! The experiment session API: [`Experiment::builder`] → [`RunHandle`].
//!
//! A session is configured once (`.config`, optionally `.data`, `.store`,
//! `.scheduler`/`.scheduler_named`, `.observer`), validated **once** at
//! the builder boundary, and launched onto a supervisor thread.
//! [`RunHandle`] is the live view: `join()` for the final
//! [`ExperimentReport`], `events()` for a replayed + live
//! [`RunEvent`] stream, `cancel()` to abort — cancellation closes the
//! parameter store, node registry and task dispatcher so store-waiting
//! workers and a parked cluster leader unblock promptly instead of
//! running out their timeouts.
//!
//! Execution is graph-driven: the session builds the scheduler's
//! [`crate::coordinator::taskgraph::TaskGraph`] once, hands it to a
//! shared [`Dispatcher`], and runs a pool of workers (`cfg.workers`
//! threads in-proc, or external `pff worker` processes in cluster mode)
//! that drain task leases until the graph is done.

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::{ExperimentConfig, TransportKind};
use crate::coordinator::checkpoint::{CheckpointWriter, RunCheckpoint};
use crate::coordinator::dispatch::Dispatcher;
use crate::coordinator::eval;
use crate::coordinator::events::{EventBus, RunEvent};
use crate::coordinator::node::{drain_tasks, DispatcherSource, OptBank, TaskScratch};
use crate::coordinator::registry::NodeRegistry;
use crate::coordinator::schedulers::{Scheduler, SchedulerRegistry};
use crate::coordinator::store::{MemStore, ParamStore};
use crate::coordinator::{ExperimentReport, NodeCtx};
use crate::data::{load_dataset, DataBundle, Dataset};
use crate::engine::{factory_for, Engine};
use crate::ff::ClassifierMode;
use crate::metrics::{makespan, LossCurve, NodeReport, SpanRecorder};
use crate::sync::{LockRank, OrderedMutex};
use crate::transport::tcp::{StoreServer, TcpStoreClient};

type CancelHook = Box<dyn Fn() + Send + Sync>;

struct CancelInner {
    flag: AtomicBool,
    hooks: OrderedMutex<Vec<CancelHook>>,
}

impl Default for CancelInner {
    fn default() -> Self {
        CancelInner {
            flag: AtomicBool::new(false),
            hooks: OrderedMutex::new(LockRank::Cancel, Vec::new()),
        }
    }
}

/// Cooperative cancellation token shared between a [`RunHandle`] and the
/// run it supervises. Cloning shares the token.
#[derive(Clone, Default)]
pub struct CancelToken {
    inner: Arc<CancelInner>,
}

impl CancelToken {
    /// Trip the token: runs every registered hook (store/registry close)
    /// exactly once. Idempotent.
    pub fn cancel(&self) {
        if self.inner.flag.swap(true, Ordering::SeqCst) {
            return;
        }
        let hooks = std::mem::take(&mut *self.inner.hooks.lock());
        for h in hooks {
            h();
        }
    }

    /// Whether [`CancelToken::cancel`] was called.
    pub fn is_cancelled(&self) -> bool {
        self.inner.flag.load(Ordering::SeqCst)
    }

    /// Register a hook to run at cancellation (runs immediately if the
    /// token already tripped). Hooks must be idempotent.
    pub(crate) fn on_cancel(&self, f: impl Fn() + Send + Sync + 'static) {
        if self.is_cancelled() {
            f();
            return;
        }
        self.inner.hooks.lock().push(Box::new(f));
        // Lost-wakeup guard: cancel() may have drained between the check
        // and the push — drain again under the tripped flag.
        if self.is_cancelled() {
            let hooks = std::mem::take(&mut *self.inner.hooks.lock());
            for h in hooks {
                h();
            }
        }
    }
}

/// Entry point of the session API. See the module docs and
/// [`ExperimentBuilder`].
pub struct Experiment;

impl Experiment {
    /// Start describing an experiment session.
    pub fn builder() -> ExperimentBuilder {
        ExperimentBuilder::default()
    }
}

enum SchedulerChoice {
    /// Resolve through [`SchedulerRegistry::global`] at launch.
    Named(String),
    /// Use this instance directly.
    Instance(Arc<dyn Scheduler>),
}

enum ResumeSource {
    /// Load (and validate) the file at launch.
    Path(PathBuf),
    /// Use this already-loaded checkpoint — the CLI loads the file once
    /// to extract the embedded config and must not decode the (possibly
    /// hundreds of MB) store dump a second time.
    Loaded(Box<RunCheckpoint>),
}

/// Builder for one experiment session. Configuration methods chain by
/// value; [`ExperimentBuilder::launch`] takes `&mut self` so a second
/// launch on the same builder is a clean runtime error rather than a
/// silent re-run.
#[derive(Default)]
pub struct ExperimentBuilder {
    cfg: Option<ExperimentConfig>,
    data: Option<Arc<DataBundle>>,
    store: Option<Arc<dyn ParamStore>>,
    scheduler: Option<SchedulerChoice>,
    resume: Option<ResumeSource>,
    bus: EventBus,
    launched: bool,
}

impl ExperimentBuilder {
    /// The experiment configuration (required). Validated once, at
    /// [`ExperimentBuilder::launch`].
    pub fn config(mut self, cfg: ExperimentConfig) -> Self {
        self.cfg = Some(cfg);
        self
    }

    /// Pre-loaded data (optional — the session loads `cfg.dataset`
    /// otherwise). Benches pass one bundle to many sessions.
    pub fn data(mut self, bundle: impl Into<Arc<DataBundle>>) -> Self {
        self.data = Some(bundle.into());
        self
    }

    /// Inject a parameter store (optional; in-proc transport only — the
    /// TCP server hosts its own [`MemStore`]). Lets tests pre-seed
    /// parameters or wrap the store for fault injection.
    pub fn store(mut self, store: Arc<dyn ParamStore>) -> Self {
        self.store = Some(store);
        self
    }

    /// Run a specific scheduler instance instead of resolving
    /// `cfg.scheduler` through the registry.
    pub fn scheduler(mut self, scheduler: impl Scheduler + 'static) -> Self {
        self.scheduler = Some(SchedulerChoice::Instance(Arc::new(scheduler)));
        self
    }

    /// Run the scheduler registered under `name` (built-in or custom; see
    /// [`SchedulerRegistry::register`]).
    pub fn scheduler_named(mut self, name: impl Into<String>) -> Self {
        self.scheduler = Some(SchedulerChoice::Named(name.into()));
        self
    }

    /// Attach a callback observer for [`RunEvent`]s (called on the
    /// emitting thread; keep it cheap). Repeatable.
    pub fn observer(self, f: impl Fn(&RunEvent) + Send + Sync + 'static) -> Self {
        self.bus.observe(f);
        self
    }

    /// Resume from a [`RunCheckpoint`] file: the session rehydrates the
    /// parameter store from the checkpoint before launching, and every
    /// node fast-forwards past the chapters whose outputs are already
    /// published. With `.config()` omitted the checkpoint's embedded
    /// config is used; an explicit config must agree with the checkpoint
    /// on every training-relevant key (deployment knobs may differ).
    /// Because kernels are bit-deterministic, a resumed run reproduces
    /// the uninterrupted run's weights bitwise when Adam moments ship
    /// with the layers (`ship_opt_state = true`).
    pub fn resume_from(mut self, path: impl Into<PathBuf>) -> Self {
        self.resume = Some(ResumeSource::Path(path.into()));
        self
    }

    /// [`ExperimentBuilder::resume_from`] with an already-loaded
    /// [`RunCheckpoint`] — skips re-reading and re-decoding the file when
    /// the caller just loaded it (e.g. to extract the embedded config).
    pub fn resume_from_checkpoint(mut self, ck: RunCheckpoint) -> Self {
        self.resume = Some(ResumeSource::Loaded(Box::new(ck)));
        self
    }

    /// Validate, resolve the scheduler, and start the run on a supervisor
    /// thread. Errors immediately on missing config, double launch,
    /// invalid config, unknown scheduler name, or a store/transport
    /// combination that cannot work.
    pub fn launch(&mut self) -> Result<RunHandle> {
        if self.launched {
            bail!(
                "this ExperimentBuilder was already launched (or a launch was attempted) \
                 — build a new one per run"
            );
        }
        // Mark consumed up front: a launch that fails below (invalid
        // config, unknown scheduler) must not leave a half-drained builder
        // reporting "missing config" on retry.
        self.launched = true;
        let resume = match self.resume.take() {
            Some(ResumeSource::Path(path)) => Some(
                RunCheckpoint::load(&path)
                    .with_context(|| format!("loading resume checkpoint {}", path.display()))?,
            ),
            Some(ResumeSource::Loaded(ck)) => Some(*ck),
            None => None,
        };
        let cfg = match self.cfg.take() {
            Some(cfg) => cfg,
            None => match &resume {
                // Resume-only launch: the checkpoint embeds its config.
                Some(ck) => ck.experiment_config()?,
                None => bail!(
                    "Experiment::builder() needs .config(cfg) (or .resume_from(path)) \
                     before .launch()"
                ),
            },
        };
        // THE validation point: everything downstream (session, nodes,
        // shims) trusts the config as-is.
        let cfg = cfg.validated()?;
        if let Some(ck) = &resume {
            ck.check_compat(&cfg)?;
        }
        let scheduler = match self.scheduler.take() {
            Some(SchedulerChoice::Instance(s)) => s,
            Some(SchedulerChoice::Named(n)) => SchedulerRegistry::global().resolve(&n)?,
            // A checkpoint records the *registry* name of whatever ran —
            // resolving it (rather than the parse-level enum) keeps custom
            // named schedulers resumable.
            None => match &resume {
                Some(ck) => SchedulerRegistry::global().resolve(&ck.scheduler)?,
                None => SchedulerRegistry::global().resolve(cfg.scheduler.key())?,
            },
        };
        if self.store.is_some() && (cfg.transport != TransportKind::InProc || cfg.cluster) {
            bail!(
                "a custom .store() works with transport = inproc only \
                 (the TCP server hosts its own MemStore)"
            );
        }
        if self.store.is_some()
            && (resume.is_some() || !cfg.checkpoint_dir.as_os_str().is_empty())
        {
            bail!(
                "checkpoint/resume needs the built-in MemStore — remove .store(..) \
                 or the checkpoint/resume options"
            );
        }

        let data = self.data.take();
        let store = self.store.take();
        let bus = self.bus.clone();
        let cancel = CancelToken::default();
        let (bus2, cancel2) = (bus.clone(), cancel.clone());
        let thread = std::thread::Builder::new()
            .name("pff-experiment".into())
            .spawn(move || {
                let mut res =
                    run_session(cfg, data, store, scheduler, resume, bus2.clone(), cancel2.clone());
                if res.is_err() && cancel2.is_cancelled() {
                    res = res.context("run cancelled");
                }
                bus2.emit(RunEvent::Done { ok: res.is_ok() });
                res
            })
            .context("spawning the experiment supervisor thread")?;
        Ok(RunHandle { thread, cancel, bus })
    }

    /// [`ExperimentBuilder::launch`] + [`RunHandle::join`] in one call —
    /// the blocking path most tests and harnesses use.
    pub fn run(&mut self) -> Result<ExperimentReport> {
        self.launch()?.join()
    }
}

/// A live experiment run.
///
/// Dropping the handle detaches the run (it keeps training); call
/// [`RunHandle::cancel`] first to abort it.
pub struct RunHandle {
    thread: JoinHandle<Result<ExperimentReport>>,
    cancel: CancelToken,
    bus: EventBus,
}

impl RunHandle {
    /// Block until the run finishes and return its report (or its error;
    /// a cancelled run reports `run cancelled`).
    pub fn join(self) -> Result<ExperimentReport> {
        self.thread
            .join()
            .map_err(|_| anyhow!("experiment supervisor thread panicked"))?
    }

    /// Abort the run: closes the parameter store and node registry so
    /// blocked waits unblock promptly; nodes also check the token at
    /// chapter boundaries. Idempotent; safe from any thread.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// Whether [`RunHandle::cancel`] was called.
    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Whether the run has finished (successfully or not).
    pub fn is_finished(&self) -> bool {
        self.thread.is_finished()
    }

    /// Subscribe to the event stream. The full history since launch is
    /// replayed first, so a post-launch subscription misses nothing; the
    /// channel then carries live events through the terminal
    /// [`RunEvent::Done`].
    pub fn events(&self) -> std::sync::mpsc::Receiver<RunEvent> {
        self.bus.subscribe()
    }
}

/// One full experiment, on the supervisor thread. `cfg` is validated;
/// `resume` (when present) was loaded and compatibility-checked at the
/// builder boundary.
fn run_session(
    cfg: ExperimentConfig,
    data: Option<Arc<DataBundle>>,
    custom_store: Option<Arc<dyn ParamStore>>,
    scheduler: Arc<dyn Scheduler>,
    resume: Option<RunCheckpoint>,
    bus: EventBus,
    cancel: CancelToken,
) -> Result<ExperimentReport> {
    // Size the parallel kernel runtime for this run (0 = PFF_THREADS env,
    // else all cores). Kernels are bit-identical at every thread count,
    // so this only moves wall-clock.
    crate::tensor::pool::set_threads(cfg.threads);
    let bundle = match data {
        Some(b) => b,
        None => Arc::new(load_dataset(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?),
    };
    let factory = factory_for(cfg.engine, &cfg.artifact_dir)?;
    let graph = scheduler.graph(&cfg).context("building the scheduler's task graph")?;

    // --- store + transport ---------------------------------------------------
    // `store`: what nodes and final assembly read through. `mem`: the
    // concrete instance we own (absent only when a custom store was
    // injected) — the TCP server and the cancel hook need it.
    let (store, mem): (Arc<dyn ParamStore>, Option<Arc<MemStore>>) = match custom_store {
        Some(s) => (s, None),
        None => {
            let m = Arc::new(MemStore::new());
            (m.clone() as Arc<dyn ParamStore>, Some(m))
        }
    };
    {
        // Every store — owned MemStore or injected test double — gets a
        // close hook, so a cancelled run never sits out a parked blocking
        // read's full timeout (ParamStore::close defaults to a no-op).
        let s = store.clone();
        cancel.on_cancel(move || s.close());
    }
    // Resume: rehydrate the store from the checkpoint BEFORE anything can
    // read it (nodes, workers, the checkpoint writer). The schedulers then
    // fast-forward past whatever the dump already covers.
    let resuming = resume.is_some();
    if let Some(ck) = resume {
        let m = mem.as_ref().expect("launch() guards resume against custom stores");
        m.restore(ck.store);
    }
    // Cluster membership is elastic (workers may join mid-run with ids
    // beyond `cfg.nodes`), so the cluster registry is unbounded; the
    // non-cluster registry keeps its capacity bound so a mis-launched
    // worker with an out-of-range --node-id is refused at HELLO.
    let registry = if cfg.cluster {
        Arc::new(NodeRegistry::new())
    } else {
        Arc::new(NodeRegistry::with_capacity(cfg.nodes))
    };
    // Reconnect lease: a worker that drops mid-chapter must be replaced
    // within the store-timeout window or the leader's completion park
    // fails fast, naming the dropped node.
    registry.set_lease(Duration::from_secs(cfg.store_timeout_s));
    {
        let r = registry.clone();
        cancel.on_cancel(move || r.close());
    }
    // The work-bucket dispatcher every worker (in-proc thread or remote
    // process) drains. Stealing moves a home's tasks across workers,
    // which is only safe when the Adam moments travel with the layer:
    // in-proc workers share one OptBank, cluster workers need
    // `ship_opt_state` so the wire carries the moments.
    let allow_steal = !cfg.cluster || cfg.ship_opt_state;
    let dispatcher = Arc::new(Dispatcher::new(graph, bus.clone(), allow_steal, cfg.cluster));
    {
        let d = dispatcher.clone();
        cancel.on_cancel(move || d.close("run cancelled"));
    }
    // Resume fast-forward: walk the graph in dependency order and mark
    // done every task whose published outputs the rehydrated store
    // already holds — but only while its dependencies were themselves
    // pre-completed, so a half-written frontier re-executes (bitwise
    // identically) instead of leaving holes behind it.
    if resuming {
        let g = dispatcher.graph();
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); g.len()];
        for id in 0..g.len() {
            for &d in g.dependents(id) {
                preds[d].push(id);
            }
        }
        let mut pre = vec![false; g.len()];
        for id in g.serial_order() {
            if preds[id].iter().all(|&p| pre[p])
                && scheduler.task_done(store.as_ref(), &cfg, g.task(id))?
            {
                dispatcher.precomplete(id)?;
                pre[id] = true;
            }
        }
    }
    // Durable checkpoints: a change-driven writer thread snapshots the
    // store every `checkpoint_every` completed chapters (and once at
    // launch, so a kill at any point finds a resumable file). A fresh run
    // aimed at a directory that already holds a checkpoint is refused
    // inside spawn — only a resume may overwrite a resume point.
    let ckpt = if !cfg.checkpoint_dir.as_os_str().is_empty() {
        let m = mem.clone().expect("launch() guards checkpointing against custom stores");
        Some(CheckpointWriter::spawn(&cfg, scheduler.clone(), m, bus.clone(), resuming)?)
    } else {
        None
    };
    let server = match cfg.transport {
        TransportKind::InProc => None,
        TransportKind::Tcp => {
            let m = mem.clone().expect("launch() rejects custom stores over tcp");
            // Cluster workers lease tasks over the wire, so the server
            // needs the dispatcher; plain TCP-store clients don't.
            let disp = if cfg.cluster { Some(dispatcher.clone()) } else { None };
            Some(StoreServer::start_full(m, registry.clone(), disp, cfg.tcp_port)?)
        }
    };

    let server_addr = server.as_ref().map(|s| s.addr);
    let origin = Instant::now();
    let run_result: Result<(Vec<NodeReport>, LossCurve)> = if cfg.cluster {
        // --- external workers: `pff worker --connect` processes ----------------
        // Admission waits for `min_workers` (default: the node count),
        // then the dispatcher opens — later joiners pick up leases
        // mid-run, and leavers' leases requeue (elastic membership).
        (|| {
            let reg_timeout = Duration::from_secs(cfg.store_timeout_s);
            // Each chapter's progress is already bounded by the store timeout
            // (the dependency-wait tripwire), so completion gets S times that.
            let done_timeout = reg_timeout * cfg.splits.max(1);
            let min_workers = if cfg.min_workers == 0 { cfg.nodes } else { cfg.min_workers };
            let workers = registry
                .wait_for_workers(min_workers, reg_timeout)
                .context("waiting for cluster workers to register")?;
            bus.emit(RunEvent::WorkersRegistered { workers });
            dispatcher.open();
            dispatcher
                .wait_complete(done_timeout)
                .context("waiting for the task graph to drain")?;
            // All tasks are done: a worker that dropped after its last
            // completion (but before its DONE frame) must not fail the
            // final roster park below.
            registry.settle_vacancies();
            registry
                .wait_for_done(registry.worker_count(), reg_timeout)
                .context("waiting for cluster workers to finish")?;
            Ok((Vec::new(), LossCurve::default()))
        })()
    } else {
        // --- in-process worker pool: `cfg.workers` threads drain the graph -----
        (|| {
            let node_store = |_: usize| -> Result<Arc<dyn ParamStore>> {
                match (cfg.transport, server_addr) {
                    (TransportKind::InProc, _) => Ok(store.clone()),
                    (TransportKind::Tcp, Some(addr)) => {
                        Ok(Arc::new(TcpStoreClient::connect(addr)?) as Arc<dyn ParamStore>)
                    }
                    _ => unreachable!(),
                }
            };

            // Data placement comes from the scheduler's graph, not from an
            // enum match — custom schedulers opt into sharding there. Every
            // worker sees every home's shard: a stolen task still trains on
            // its home's data.
            let g = dispatcher.graph();
            let shards: Vec<Arc<Dataset>> = if g.shard_data() {
                bundle.train.shard(g.nodes()).into_iter().map(Arc::new).collect()
            } else {
                let full = Arc::new(bundle.train.clone());
                (0..g.nodes()).map(|_| full.clone()).collect()
            };

            // Pool size: one worker per home by default — that makes the
            // dispatcher's affinity buckets coincide with the static plan,
            // so the drain IS the paper's schedule. `cfg.workers` scales
            // the pool elastically in either direction.
            let pool = if cfg.workers == 0 { g.nodes() } else { cfg.workers };
            for w in 0..pool {
                dispatcher.worker_joined(w as u32, &format!("pool-{w}"));
            }
            dispatcher.open();
            // One OptBank for the whole pool: Adam moments key on the
            // task's home, so a home's per-layer chain sees its own
            // moments no matter which worker runs each task.
            let opt_bank = OptBank::new();

            let mut handles = Vec::with_capacity(pool);
            for w in 0..pool {
                let cfg_n = cfg.clone();
                let store = node_store(w)?;
                let factory = factory.clone();
                let sched = scheduler.clone();
                let bus_n = bus.clone();
                let cancel_n = cancel.clone();
                let shards_n = shards.clone();
                let bank = opt_bank.clone();
                let disp = dispatcher.clone();
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("pff-worker-{w}"))
                        .spawn(move || -> Result<(NodeReport, LossCurve)> {
                            let timeout = Duration::from_secs(cfg_n.store_timeout_s);
                            let engine = factory().context("constructing worker engine")?;
                            let mut ctx = NodeCtx {
                                node_id: 0,
                                cfg: cfg_n,
                                store,
                                engine,
                                data: shards_n[0].clone(),
                                rec: SpanRecorder::new(origin, w),
                                curve: LossCurve::default(),
                                opt_bank: bank,
                                scratch: TaskScratch::default(),
                                bus: bus_n,
                                cancel: cancel_n,
                            };
                            let source = DispatcherSource { dispatcher: disp, timeout };
                            drain_tasks(&mut ctx, sched.as_ref(), &source, &shards_n, w as u32)?;
                            Ok((ctx.rec.finish(), ctx.curve))
                        })?,
                );
            }

            let mut node_reports = Vec::with_capacity(pool);
            let mut curve = LossCurve::default();
            // A failing worker closes the dispatcher, so its peers error
            // out too ("dispatcher closed: ..."); report the root cause,
            // not an echo.
            let mut first_err: Option<(bool, anyhow::Error)> = None;
            for (i, h) in handles.into_iter().enumerate() {
                match h.join().map_err(|_| anyhow!("worker {i} panicked")) {
                    Ok(Ok((rep, c))) => {
                        node_reports.push(rep);
                        curve.merge(&c);
                    }
                    Ok(Err(e)) | Err(e) => {
                        let root = !format!("{e:#}").contains("dispatcher closed");
                        let replace = match &first_err {
                            None => true,
                            Some((prev_root, _)) => root && !prev_root,
                        };
                        if replace {
                            first_err = Some((root, e.context(format!("worker {i} failed"))));
                        }
                    }
                }
            }
            if let Some((_, e)) = first_err {
                return Err(e);
            }
            Ok((node_reports, curve))
        })()
    };
    let (node_reports, curve) = match run_result {
        Ok(v) => v,
        Err(e) => {
            // Stop the checkpoint writer without a final write: the last
            // periodic checkpoint on disk is the resume point.
            if let Some(w) = ckpt {
                let _ = w.finish(false);
            }
            // Don't leak the listener/accept thread on a failed run — the
            // fixed cluster port must stay rebindable for a retry.
            if let Some(srv) = server {
                srv.shutdown();
            }
            return Err(e);
        }
    };
    let wall_s = origin.elapsed().as_secs_f64();
    // Final checkpoint: the complete end-of-run store state. Written after
    // wall-clock stops so checkpoint IO never skews the timing numbers. A
    // failed write must not leak the accept thread / bound cluster port
    // (the same invariant the training error path protects).
    if let Some(w) = ckpt {
        if let Err(e) = w.finish(true) {
            if let Some(srv) = server {
                srv.shutdown();
            }
            return Err(e);
        }
    }

    // --- assemble + post-hoc head + evaluate -----------------------------------
    // Read through the leader-side store directly (same data the clients
    // wrote — over TCP, `store` IS the server's MemStore).
    let mut model = eval::assemble(store.as_ref(), &cfg)?;
    let comm = store.comm_stats();
    if let Some(srv) = server {
        srv.shutdown();
    }

    let mut leader_engine: Box<dyn Engine> = factory()?;
    let mut head_posthoc_s = 0.0;
    if cfg.classifier == ClassifierMode::Softmax && !cfg.perfopt && model.head.is_none() {
        let (head, secs) =
            eval::train_head_posthoc(leader_engine.as_mut(), &model, &bundle.train, &cfg)?;
        model.head = Some(head);
        head_posthoc_s = secs;
    }

    let eval_t0 = Instant::now();
    let test_accuracy = eval::evaluate(leader_engine.as_mut(), &model, &bundle.test, &cfg)?;
    let eval_s = eval_t0.elapsed().as_secs_f64();
    bus.emit(RunEvent::Eval { accuracy: test_accuracy });

    let modeled = makespan(&node_reports);
    Ok(ExperimentReport {
        name: cfg.name.clone(),
        scheduler: scheduler.name().to_string(),
        test_accuracy,
        wall_s,
        head_posthoc_s,
        eval_s,
        modeled,
        comm,
        node_reports,
        curve,
        model,
    })
}
