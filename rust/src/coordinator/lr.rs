//! Learning-rate cooldown schedule (§5.1: "cooldowns after the 50th
//! epoch" of 100).
//!
//! Matches the reference FF implementations: constant for the first half
//! of training, then linear decay to ~0 at the final epoch:
//!
//! `lr(e) = lr                          e ≤ E/2`
//! `lr(e) = lr · 2(1 + E − e)/E         e > E/2`

/// Learning rate at (0-based) global epoch `epoch` of `total_epochs`.
pub fn cooldown(base_lr: f32, epoch: u32, total_epochs: u32) -> f32 {
    let e = epoch + 1; // 1-based epoch, as in the reference schedule
    let half = total_epochs / 2;
    if e <= half || total_epochs == 0 {
        base_lr
    } else {
        base_lr * 2.0 * (1 + total_epochs - e) as f32 / total_epochs as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_first_half() {
        for e in 0..50 {
            assert_eq!(cooldown(0.01, e, 100), 0.01);
        }
    }

    #[test]
    fn decays_second_half_monotonically() {
        let mut prev = cooldown(0.01, 50, 100);
        for e in 51..100 {
            let lr = cooldown(0.01, e, 100);
            assert!(lr < prev, "epoch {e}: {lr} !< {prev}");
            prev = lr;
        }
    }

    #[test]
    fn near_continuous_at_half() {
        let before = cooldown(0.01, 49, 100); // epoch 50 (1-based)
        let after = cooldown(0.01, 50, 100); // epoch 51
        assert!((before - after).abs() < 0.01 * 0.05, "{before} vs {after}");
    }

    #[test]
    fn final_epoch_small_but_positive() {
        let last = cooldown(0.01, 99, 100);
        assert!(last > 0.0 && last < 0.001);
    }

    #[test]
    fn short_runs_work() {
        assert_eq!(cooldown(0.5, 0, 2), 0.5);
        assert!(cooldown(0.5, 1, 2) <= 0.5);
    }
}
