//! Layer-3 coordinator: the paper's contribution.
//!
//! The public surface is the session API in [`experiment`]:
//! [`Experiment::builder()`] configures a run (config, optional data /
//! store / scheduler / observers), `.launch()` validates once and returns
//! a [`RunHandle`] — `join()` for the final [`ExperimentReport`]
//! (accuracy, wall time, modeled multi-node makespan, utilization,
//! communication volume, loss curve), `events()` for a typed
//! [`RunEvent`] stream, `cancel()` to abort promptly.
//!
//! Scheduling strategies are open: the four paper schedulers implement
//! the object-safe [`Scheduler`] trait and live in a
//! [`SchedulerRegistry`]; the `config::Scheduler` enum is only a
//! parse-level alias resolved through that registry, so new strategies
//! (and custom ones registered from binaries/tests) are additions, not
//! edits to this module.
//!
//! Execution is graph-driven: a scheduler describes its run as a
//! [`TaskGraph`] of `(chapter, layer)` work items (edges = the paper's
//! §4.1/§4.2 publish dependencies), and the shared [`Dispatcher`] leases
//! ready tasks to an elastic pool of workers — in-proc threads or
//! external `pff worker` processes — with per-worker affinity buckets
//! and work stealing. The static [`SchedulePlan`] survives as a derived
//! read-only rendering for harnesses and the gantt simulator.

pub mod checkpoint;
pub mod dispatch;
pub mod eval;
pub mod events;
pub mod experiment;
pub mod lr;
pub mod node;
pub mod registry;
pub mod schedulers;
pub mod serve;
pub mod store;
pub mod taskgraph;

pub use checkpoint::{CheckpointWriter, RunCheckpoint};
pub use dispatch::Dispatcher;
pub use eval::TrainedModel;
pub use events::{Bus, EventBus, EventLog, RunEvent};
pub use experiment::{CancelToken, Experiment, ExperimentBuilder, RunHandle};
pub use node::NodeCtx;
pub use registry::NodeRegistry;
pub use schedulers::{SchedulePlan, Scheduler, SchedulerRegistry};
pub use serve::{BatchServer, ServeEvent, ServeOptions};
pub use taskgraph::{Task, TaskGraph, TaskGraphBuilder};

use crate::metrics::{CommStats, LossCurve, MakespanModel, NodeReport};

/// Everything a finished experiment reports (EXPERIMENTS.md rows are
/// printed from these).
#[derive(Clone, Debug)]
pub struct ExperimentReport {
    /// Experiment label.
    pub name: String,
    /// Scheduler that ran (its registry name, e.g. `"all-layers"` —
    /// custom schedulers report theirs).
    pub scheduler: String,
    /// Test-set accuracy in `[0, 1]`.
    pub test_accuracy: f64,
    /// Real wall-clock seconds of the distributed training phase.
    pub wall_s: f64,
    /// Post-hoc head training seconds (0 when head is inline/absent).
    pub head_posthoc_s: f64,
    /// Evaluation seconds (excluded from training time, like the paper).
    pub eval_s: f64,
    /// Modeled multi-node timing (per-node busy, makespan, utilization) —
    /// see `metrics::makespan` for why this exists on a 1-core testbed.
    pub modeled: MakespanModel,
    /// Store communication counters.
    pub comm: CommStats,
    /// Per-node span reports.
    pub node_reports: Vec<NodeReport>,
    /// Merged training curve.
    pub curve: LossCurve,
    /// The assembled model.
    pub model: TrainedModel,
}

impl ExperimentReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<28} acc {:>6.2}%  busy {:>8.2}s  makespan {:>8.2}s  util {:>5.1}%  comm {:.1} MB",
            self.name,
            self.test_accuracy * 100.0,
            self.modeled.total_busy,
            self.modeled.modeled_makespan,
            self.modeled.utilization * 100.0,
            self.comm.bytes_put as f64 / 1e6,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, Scheduler as SchedulerKind, TransportKind};
    use crate::ff::{ClassifierMode, NegStrategy};
    use anyhow::Result;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::tiny();
        cfg.neg = NegStrategy::Random;
        cfg
    }

    /// The one blocking path every test goes through — the builder.
    fn run(cfg: &ExperimentConfig) -> Result<ExperimentReport> {
        Experiment::builder().config(cfg.clone()).run()
    }

    #[test]
    fn sequential_beats_chance() {
        let mut cfg = quick_cfg();
        cfg.scheduler = SchedulerKind::Sequential;
        let rep = run(&cfg).unwrap();
        assert!(
            rep.test_accuracy > 0.25,
            "sequential FF should beat 10% chance clearly, got {:.1}%",
            rep.test_accuracy * 100.0
        );
        assert!(rep.modeled.total_busy > 0.0);
        assert_eq!(rep.node_reports.len(), 1);
        assert_eq!(rep.scheduler, "sequential");
    }

    #[test]
    fn all_layers_matches_sequential_model_bitwise() {
        // With N nodes the pipeline executes the SAME chapter sequence as
        // sequential (same seeds, same order of updates per layer) when
        // opt state is shipped — the trained weights must agree.
        let mut cfg = quick_cfg();
        cfg.ship_opt_state = true;
        cfg.scheduler = SchedulerKind::Sequential;
        let seq = run(&cfg).unwrap();
        cfg.scheduler = SchedulerKind::AllLayers;
        cfg.nodes = 2;
        let pff = run(&cfg).unwrap();
        for (a, b) in seq.model.net.layers.iter().zip(&pff.model.net.layers) {
            assert!(
                a.w.max_abs_diff(&b.w) < 1e-5,
                "All-Layers must reproduce sequential weights (diff {})",
                a.w.max_abs_diff(&b.w)
            );
        }
        assert!((seq.test_accuracy - pff.test_accuracy).abs() < 0.02);
    }

    #[test]
    fn single_layer_runs_and_learns() {
        let mut cfg = quick_cfg();
        cfg.scheduler = SchedulerKind::SingleLayer;
        cfg.nodes = 3; // 3 layers
        let rep = run(&cfg).unwrap();
        assert!(rep.test_accuracy > 0.25, "got {:.1}%", rep.test_accuracy * 100.0);
        assert_eq!(rep.node_reports.len(), 3);
        // every node published its layer each chapter (3 nodes × 8 chapters)
        assert!(rep.comm.puts >= 24);
    }

    #[test]
    fn federated_runs_on_shards() {
        let mut cfg = quick_cfg();
        cfg.scheduler = SchedulerKind::Federated;
        cfg.nodes = 2;
        cfg.train_n = 768; // 384 per shard — enough to beat chance
        let rep = run(&cfg).unwrap();
        assert!(rep.test_accuracy > 0.15, "got {:.1}%", rep.test_accuracy * 100.0);
    }

    #[test]
    fn perfopt_runs() {
        let mut cfg = quick_cfg();
        cfg.perfopt = true;
        cfg.scheduler = SchedulerKind::AllLayers;
        cfg.nodes = 2;
        let rep = run(&cfg).unwrap();
        assert!(rep.test_accuracy > 0.3, "got {:.1}%", rep.test_accuracy * 100.0);
        assert_eq!(rep.model.layer_heads.len(), 3);
    }

    #[test]
    fn softmax_classifier_inline() {
        let mut cfg = quick_cfg();
        cfg.classifier = ClassifierMode::Softmax;
        cfg.scheduler = SchedulerKind::AllLayers;
        cfg.nodes = 2;
        let rep = run(&cfg).unwrap();
        assert!(rep.model.head.is_some());
        assert!(rep.test_accuracy > 0.25, "got {:.1}%", rep.test_accuracy * 100.0);
        assert_eq!(rep.head_posthoc_s, 0.0);
    }

    #[test]
    fn tcp_transport_end_to_end() {
        let mut cfg = quick_cfg();
        cfg.transport = TransportKind::Tcp;
        cfg.scheduler = SchedulerKind::AllLayers;
        cfg.nodes = 2;
        let rep = run(&cfg).unwrap();
        assert!(rep.test_accuracy > 0.25, "got {:.1}%", rep.test_accuracy * 100.0);
        assert!(rep.comm.bytes_put > 0);
    }

    /// Cluster mode end to end: the leader waits for external workers that
    /// join over TCP (threads here; `pff worker` processes in the example
    /// and CI smoke), and the result matches the in-proc run bitwise when
    /// opt state is shipped. The leader's registration report arrives as a
    /// `WorkersRegistered` event.
    #[test]
    fn cluster_mode_matches_inproc() {
        let mut cfg = quick_cfg();
        cfg.scheduler = SchedulerKind::AllLayers;
        cfg.nodes = 2;
        cfg.ship_opt_state = true;
        let inproc = run(&cfg).unwrap();

        // free localhost port for the leader
        let port = {
            let l = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap().port()
        };
        let mut lcfg = cfg.clone();
        lcfg.transport = TransportKind::Tcp;
        lcfg.cluster = true;
        lcfg.tcp_port = port;
        let leader = Experiment::builder().config(lcfg).launch().unwrap();
        let events = leader.events();

        let addr: std::net::SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
        let mut wcfg = cfg.clone();
        wcfg.transport = TransportKind::Tcp;
        let workers: Vec<_> = (0..2u32)
            .map(|i| {
                let wcfg = wcfg.clone();
                std::thread::spawn(move || {
                    crate::coordinator::node::run_worker(
                        &wcfg,
                        addr,
                        Some(i),
                        std::time::Duration::from_secs(30),
                    )
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap().unwrap();
        }
        let clustered = leader.join().unwrap();
        let registered = events.try_iter().any(|ev| {
            matches!(&ev, RunEvent::WorkersRegistered { workers } if workers.len() == 2)
        });
        assert!(registered, "leader must announce worker registration on the event bus");
        for (a, b) in inproc.model.net.layers.iter().zip(&clustered.model.net.layers) {
            assert_eq!(a.w.data, b.w.data, "cluster run must reproduce in-proc weights bitwise");
        }
        assert!(
            (inproc.test_accuracy - clustered.test_accuracy).abs() < 0.02,
            "in-proc {:.1}% vs cluster {:.1}%",
            inproc.test_accuracy * 100.0,
            clustered.test_accuracy * 100.0
        );
    }
}
