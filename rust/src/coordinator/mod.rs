//! Layer-3 coordinator: the paper's contribution.
//!
//! [`run_experiment`] is the single entry point: it loads data, builds the
//! parameter store (in-process or TCP), spawns one worker thread per node
//! running the configured scheduler, assembles the final model from the
//! store, trains the post-hoc head if needed, evaluates, and returns a
//! full [`ExperimentReport`] (accuracy, wall time, modeled multi-node
//! makespan, utilization, communication volume, loss curve).

pub mod eval;
pub mod lr;
pub mod node;
pub mod registry;
pub mod schedulers;
pub mod store;

pub use eval::TrainedModel;
pub use node::NodeCtx;
pub use registry::NodeRegistry;

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::config::{ExperimentConfig, Scheduler, TransportKind};
use crate::coordinator::store::{MemStore, ParamStore};
use crate::data::{load_dataset, DataBundle};
use crate::engine::{factory_for, Engine, EngineFactory};
use crate::ff::ClassifierMode;
use crate::metrics::{makespan, CommStats, LossCurve, MakespanModel, NodeReport, SpanRecorder};
use crate::transport::tcp::{StoreServer, TcpStoreClient};

/// Everything a finished experiment reports (EXPERIMENTS.md rows are
/// printed from these).
#[derive(Clone, Debug)]
pub struct ExperimentReport {
    /// Experiment label.
    pub name: String,
    /// Scheduler used.
    pub scheduler: Scheduler,
    /// Test-set accuracy in `[0, 1]`.
    pub test_accuracy: f64,
    /// Real wall-clock seconds of the distributed training phase.
    pub wall_s: f64,
    /// Post-hoc head training seconds (0 when head is inline/absent).
    pub head_posthoc_s: f64,
    /// Evaluation seconds (excluded from training time, like the paper).
    pub eval_s: f64,
    /// Modeled multi-node timing (per-node busy, makespan, utilization) —
    /// see `metrics::makespan` for why this exists on a 1-core testbed.
    pub modeled: MakespanModel,
    /// Store communication counters.
    pub comm: CommStats,
    /// Per-node span reports.
    pub node_reports: Vec<NodeReport>,
    /// Merged training curve.
    pub curve: LossCurve,
    /// The assembled model.
    pub model: TrainedModel,
}

impl ExperimentReport {
    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<28} acc {:>6.2}%  busy {:>8.2}s  makespan {:>8.2}s  util {:>5.1}%  comm {:.1} MB",
            self.name,
            self.test_accuracy * 100.0,
            self.modeled.total_busy,
            self.modeled.modeled_makespan,
            self.modeled.utilization * 100.0,
            self.comm.bytes_put as f64 / 1e6,
        )
    }
}

/// Resolve the configured backend through the [`crate::engine`] registry
/// seam (errors immediately — with a rebuild hint — when the binary was
/// built without the requested backend).
fn engine_factory(cfg: &ExperimentConfig) -> Result<EngineFactory> {
    factory_for(cfg.engine, &cfg.artifact_dir)
}

/// Run a full PFF experiment per `cfg`. See module docs.
pub fn run_experiment(cfg: &ExperimentConfig) -> Result<ExperimentReport> {
    let cfg = cfg.clone().validated()?;
    let bundle = load_dataset(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    run_experiment_with_data(&cfg, &bundle)
}

/// Run with pre-loaded data (benches reuse one bundle across many runs).
pub fn run_experiment_with_data(
    cfg: &ExperimentConfig,
    bundle: &DataBundle,
) -> Result<ExperimentReport> {
    let cfg = cfg.clone().validated()?;
    let factory = engine_factory(&cfg)?;

    // --- store + transport ---------------------------------------------------
    let mem = Arc::new(MemStore::new());
    // Capacity-bounded: a mis-launched worker with an out-of-range
    // --node-id is refused at HELLO instead of poisoning membership.
    let registry = Arc::new(NodeRegistry::with_capacity(cfg.nodes));
    let server = match cfg.transport {
        TransportKind::InProc => None,
        TransportKind::Tcp => {
            Some(StoreServer::start_with(mem.clone(), registry.clone(), cfg.tcp_port)?)
        }
    };

    let server_addr = server.as_ref().map(|s| s.addr);
    let origin = Instant::now();
    let run_result: Result<(Vec<NodeReport>, LossCurve)> = if cfg.cluster {
        // --- external workers: `pff worker --connect` processes ----------------
        // Membership and completion both ride the registry's Condvar — the
        // leader parks exactly like a blocked store read, no polling.
        (|| {
            let reg_timeout = Duration::from_secs(cfg.store_timeout_s);
            // Each chapter's progress is already bounded by the store timeout
            // (the dependency-wait tripwire), so completion gets S times that.
            let done_timeout = reg_timeout * cfg.splits.max(1);
            let workers = registry
                .wait_for_workers(cfg.nodes, reg_timeout)
                .context("waiting for cluster workers to register")?;
            eprintln!(
                "[leader] {} worker(s) registered: {}",
                workers.len(),
                workers
                    .iter()
                    .map(|w| format!("{}#{}", w.name, w.id))
                    .collect::<Vec<_>>()
                    .join(", ")
            );
            registry
                .wait_for_done(cfg.nodes, done_timeout)
                .context("waiting for cluster workers to finish")?;
            Ok((Vec::new(), LossCurve::default()))
        })()
    } else {
        // --- in-process nodes: one thread per node -----------------------------
        (|| {
            let node_store = |_: usize| -> Result<Arc<dyn ParamStore>> {
                match (cfg.transport, server_addr) {
                    (TransportKind::InProc, _) => Ok(mem.clone()),
                    (TransportKind::Tcp, Some(addr)) => {
                        Ok(Arc::new(TcpStoreClient::connect(addr)?) as Arc<dyn ParamStore>)
                    }
                    _ => unreachable!(),
                }
            };

            // data placement
            let shards: Vec<crate::data::Dataset> = if cfg.scheduler == Scheduler::Federated {
                bundle.train.shard(cfg.nodes)
            } else {
                vec![bundle.train.clone(); cfg.nodes]
            };

            let mut handles = Vec::with_capacity(cfg.nodes);
            for (node_id, data) in shards.into_iter().enumerate() {
                let cfg_n = cfg.clone();
                let store = node_store(node_id)?;
                let factory = factory.clone();
                handles.push(
                    std::thread::Builder::new()
                        .name(format!("pff-node-{node_id}"))
                        .spawn(move || -> Result<(NodeReport, LossCurve)> {
                            let engine = factory().context("constructing node engine")?;
                            let mut ctx = NodeCtx {
                                node_id,
                                cfg: cfg_n,
                                store,
                                engine,
                                data,
                                rec: SpanRecorder::new(origin, node_id),
                                curve: LossCurve::default(),
                                opt_cache: HashMap::new(),
                                head_opt: None,
                            };
                            schedulers::run_node(&mut ctx)?;
                            Ok((ctx.rec.finish(), ctx.curve))
                        })?,
                );
            }

            let mut node_reports = Vec::with_capacity(cfg.nodes);
            let mut curve = LossCurve::default();
            for (i, h) in handles.into_iter().enumerate() {
                let (rep, c) = h
                    .join()
                    .map_err(|_| anyhow::anyhow!("node {i} panicked"))?
                    .with_context(|| format!("node {i} failed"))?;
                node_reports.push(rep);
                curve.merge(&c);
            }
            Ok((node_reports, curve))
        })()
    };
    let (node_reports, curve) = match run_result {
        Ok(v) => v,
        Err(e) => {
            // Don't leak the listener/accept thread on a failed run — the
            // fixed cluster port must stay rebindable for a retry.
            if let Some(srv) = server {
                srv.shutdown();
            }
            return Err(e);
        }
    };
    let wall_s = origin.elapsed().as_secs_f64();

    // --- assemble + post-hoc head + evaluate -----------------------------------
    // Read through the mem store directly (same data the clients wrote).
    let mut model = eval::assemble(mem.as_ref(), &cfg)?;
    let comm = mem.comm_stats();
    if let Some(srv) = server {
        srv.shutdown();
    }

    let mut leader_engine: Box<dyn Engine> = factory()?;
    let mut head_posthoc_s = 0.0;
    if cfg.classifier == ClassifierMode::Softmax && !cfg.perfopt && model.head.is_none() {
        let (head, secs) =
            eval::train_head_posthoc(leader_engine.as_mut(), &model, &bundle.train, &cfg)?;
        model.head = Some(head);
        head_posthoc_s = secs;
    }

    let eval_t0 = Instant::now();
    let test_accuracy = eval::evaluate(leader_engine.as_mut(), &model, &bundle.test, &cfg)?;
    let eval_s = eval_t0.elapsed().as_secs_f64();

    let modeled = makespan(&node_reports);
    Ok(ExperimentReport {
        name: cfg.name.clone(),
        scheduler: cfg.scheduler,
        test_accuracy,
        wall_s,
        head_posthoc_s,
        eval_s,
        modeled,
        comm,
        node_reports,
        curve,
        model,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Scheduler;
    use crate::ff::NegStrategy;

    fn quick_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::tiny();
        cfg.neg = NegStrategy::Random;
        cfg
    }

    #[test]
    fn sequential_beats_chance() {
        let mut cfg = quick_cfg();
        cfg.scheduler = Scheduler::Sequential;
        let rep = run_experiment(&cfg).unwrap();
        assert!(
            rep.test_accuracy > 0.25,
            "sequential FF should beat 10% chance clearly, got {:.1}%",
            rep.test_accuracy * 100.0
        );
        assert!(rep.modeled.total_busy > 0.0);
        assert_eq!(rep.node_reports.len(), 1);
    }

    #[test]
    fn all_layers_matches_sequential_model_bitwise() {
        // With N nodes the pipeline executes the SAME chapter sequence as
        // sequential (same seeds, same order of updates per layer) when
        // opt state is shipped — the trained weights must agree.
        let mut cfg = quick_cfg();
        cfg.ship_opt_state = true;
        cfg.scheduler = Scheduler::Sequential;
        let seq = run_experiment(&cfg).unwrap();
        cfg.scheduler = Scheduler::AllLayers;
        cfg.nodes = 2;
        let pff = run_experiment(&cfg).unwrap();
        for (a, b) in seq.model.net.layers.iter().zip(&pff.model.net.layers) {
            assert!(
                a.w.max_abs_diff(&b.w) < 1e-5,
                "All-Layers must reproduce sequential weights (diff {})",
                a.w.max_abs_diff(&b.w)
            );
        }
        assert!((seq.test_accuracy - pff.test_accuracy).abs() < 0.02);
    }

    #[test]
    fn single_layer_runs_and_learns() {
        let mut cfg = quick_cfg();
        cfg.scheduler = Scheduler::SingleLayer;
        cfg.nodes = 3; // 3 layers
        let rep = run_experiment(&cfg).unwrap();
        assert!(rep.test_accuracy > 0.25, "got {:.1}%", rep.test_accuracy * 100.0);
        assert_eq!(rep.node_reports.len(), 3);
        // every node published its layer each chapter (3 nodes × 8 chapters)
        assert!(rep.comm.puts >= 24);
    }

    #[test]
    fn federated_runs_on_shards() {
        let mut cfg = quick_cfg();
        cfg.scheduler = Scheduler::Federated;
        cfg.nodes = 2;
        cfg.train_n = 768; // 384 per shard — enough to beat chance
        let rep = run_experiment(&cfg).unwrap();
        assert!(rep.test_accuracy > 0.15, "got {:.1}%", rep.test_accuracy * 100.0);
    }

    #[test]
    fn perfopt_runs() {
        let mut cfg = quick_cfg();
        cfg.perfopt = true;
        cfg.scheduler = Scheduler::AllLayers;
        cfg.nodes = 2;
        let rep = run_experiment(&cfg).unwrap();
        assert!(rep.test_accuracy > 0.3, "got {:.1}%", rep.test_accuracy * 100.0);
        assert_eq!(rep.model.layer_heads.len(), 3);
    }

    #[test]
    fn softmax_classifier_inline() {
        let mut cfg = quick_cfg();
        cfg.classifier = ClassifierMode::Softmax;
        cfg.scheduler = Scheduler::AllLayers;
        cfg.nodes = 2;
        let rep = run_experiment(&cfg).unwrap();
        assert!(rep.model.head.is_some());
        assert!(rep.test_accuracy > 0.25, "got {:.1}%", rep.test_accuracy * 100.0);
        assert_eq!(rep.head_posthoc_s, 0.0);
    }

    #[test]
    fn tcp_transport_end_to_end() {
        let mut cfg = quick_cfg();
        cfg.transport = TransportKind::Tcp;
        cfg.scheduler = Scheduler::AllLayers;
        cfg.nodes = 2;
        let rep = run_experiment(&cfg).unwrap();
        assert!(rep.test_accuracy > 0.25, "got {:.1}%", rep.test_accuracy * 100.0);
        assert!(rep.comm.bytes_put > 0);
    }

    /// Cluster mode end to end: the leader waits for external workers that
    /// join over TCP (threads here; `pff worker` processes in the example
    /// and CI smoke), and the result matches the in-proc run bitwise when
    /// opt state is shipped.
    #[test]
    fn cluster_mode_matches_inproc() {
        let mut cfg = quick_cfg();
        cfg.scheduler = Scheduler::AllLayers;
        cfg.nodes = 2;
        cfg.ship_opt_state = true;
        let inproc = run_experiment(&cfg).unwrap();

        // free localhost port for the leader
        let port = {
            let l = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
            l.local_addr().unwrap().port()
        };
        let mut lcfg = cfg.clone();
        lcfg.transport = TransportKind::Tcp;
        lcfg.cluster = true;
        lcfg.tcp_port = port;
        let leader = std::thread::spawn(move || run_experiment(&lcfg));

        let addr: std::net::SocketAddr = format!("127.0.0.1:{port}").parse().unwrap();
        let mut wcfg = cfg.clone();
        wcfg.transport = TransportKind::Tcp;
        let workers: Vec<_> = (0..2u32)
            .map(|i| {
                let wcfg = wcfg.clone();
                std::thread::spawn(move || {
                    crate::coordinator::node::run_worker(
                        &wcfg,
                        addr,
                        Some(i),
                        std::time::Duration::from_secs(30),
                    )
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap().unwrap();
        }
        let clustered = leader.join().unwrap().unwrap();
        for (a, b) in inproc.model.net.layers.iter().zip(&clustered.model.net.layers) {
            assert_eq!(a.w.data, b.w.data, "cluster run must reproduce in-proc weights bitwise");
        }
        assert!(
            (inproc.test_accuracy - clustered.test_accuracy).abs() < 0.02,
            "in-proc {:.1}% vs cluster {:.1}%",
            inproc.test_accuracy * 100.0,
            clustered.test_accuracy * 100.0
        );
    }
}
