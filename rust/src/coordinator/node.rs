//! Per-worker execution context and the chapter-training primitives
//! shared by every scheduler.
//!
//! A *worker* is one executor in the distributed system (a thread here; a
//! machine in the paper's testbed). Since the TaskGraph redesign a worker
//! drains `(chapter, layer)` tasks from a [`TaskSource`]; each task runs
//! under the identity of its *home* — the logical node of the paper's
//! static mapping — so data sharding and optimizer continuity are
//! placement-independent. All schedulers compose the same primitives, so
//! their only differences are the dependency graphs they build and where
//! their negative labels come from — exactly the deltas the paper
//! describes.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::config::{ExperimentConfig, TransportKind};
use crate::coordinator::dispatch::Dispatcher;
use crate::coordinator::events::{EventBus, RunEvent};
use crate::coordinator::experiment::CancelToken;
use crate::coordinator::lr::cooldown;
use crate::coordinator::schedulers::Scheduler;
use crate::coordinator::store::{HeadParams, LayerDelta, LayerParams, ParamStore};
use crate::coordinator::taskgraph::Task;
use crate::data::{load_dataset, Dataset};
use crate::engine::{factory_for, Engine};
use crate::ff::negative::{adaptive_neg_labels, random_wrong_labels};
use crate::ff::overlay::{overlay_labels, overlay_neutral};
use crate::ff::{FFLayer, FFNetwork, LinearHead, NegStrategy};
use crate::metrics::{LossCurve, NodeReport, SpanKind, SpanRecorder};
use crate::sync::{LockRank, OrderedMutex};
use crate::tensor::{AdamState, Matrix, Rng};
use crate::transport::codec::WireCodec;
use crate::transport::tcp::TcpStoreClient;

/// RNG stream tags for deterministic, scheduler-independent derivations.
mod stream {
    pub const LAYER_INIT: u64 = 0x4C41_5945; // "LAYE"
    pub const HEAD_INIT: u64 = 0x4845_4144; // "HEAD"
    pub const SHUFFLE: u64 = 0x5348_5546; // "SHUF"
}

/// Shared bank of Adam states, keyed by `(home, slot)`.
///
/// The paper ships only weights+biases, so moments stay node-local (see
/// DESIGN.md). With tasks free to land on any worker, "node-local" means
/// *home-keyed*: every task of one home's per-slot chain is totally
/// ordered by the graph's layer edges, so a `take` always observes the
/// matching `put` of the home's previous chapter — bit-identical to the
/// static per-node caches, under any placement. In-proc all workers share
/// one bank; a cluster worker process has its own (the dispatcher only
/// moves tasks across processes when `ship_opt_state` carries the moments
/// on the wire).
#[derive(Clone)]
pub struct OptBank {
    inner: Arc<OrderedMutex<HashMap<(usize, usize), AdamState>>>,
}

impl Default for OptBank {
    fn default() -> Self {
        OptBank { inner: Arc::new(OrderedMutex::new(LockRank::OptState, HashMap::new())) }
    }
}

impl OptBank {
    /// Fresh empty bank.
    pub fn new() -> Self {
        OptBank::default()
    }

    /// Remove and return the state for `(home, slot)`, if present.
    pub fn take(&self, home: usize, slot: usize) -> Option<AdamState> {
        self.inner.lock().remove(&(home, slot))
    }

    /// Store the state for `(home, slot)`.
    pub fn put(&self, home: usize, slot: usize, opt: AdamState) {
        self.inner.lock().insert((home, slot), opt);
    }
}

/// Forwarded FF activations carried between consecutive same-chapter
/// tasks on one worker: the `(pos, neg)` tensors as they stand entering
/// `next_layer` of `chapter`, plus the layers forwarded through (for
/// last-layer duties that need the whole network).
pub struct FfActCache {
    /// Chapter the activations belong to.
    pub chapter: u32,
    /// Layer these activations are the *input* of.
    pub next_layer: usize,
    /// Positive-overlay activations at `next_layer`.
    pub x_pos: Matrix,
    /// Negative-overlay activations at `next_layer`.
    pub x_neg: Matrix,
    /// Layers `0..next_layer` the inputs were forwarded through.
    pub layers: Vec<FFLayer>,
}

/// PerfOpt cousin of [`FfActCache`]: the neutral-overlay tensor entering
/// `next_layer` of `chapter`.
pub struct PoActCache {
    /// Chapter the activations belong to.
    pub chapter: u32,
    /// Layer these activations are the *input* of.
    pub next_layer: usize,
    /// Neutral-overlay activations at `next_layer`.
    pub x: Matrix,
}

/// Per-worker scratch caches. Purely an optimization: every entry is a
/// bit-exact copy of state reconstructible from the store, so a cache
/// miss (task landed on a different worker) recomputes identical values.
#[derive(Default)]
pub struct TaskScratch {
    /// Negative labels per chapter (deterministic in the chapter, so
    /// memoizable across the tasks that share it).
    pub neg: HashMap<u32, Vec<u8>>,
    /// FF activation hand-off between consecutive tasks.
    pub ff: Option<FfActCache>,
    /// PerfOpt activation hand-off between consecutive tasks.
    pub po: Option<PoActCache>,
    /// Last layer params this worker published, keyed `(home, slot)` with
    /// the chapter they were published at — the diff base for delta
    /// publishes. Bit-exact copies of store entries, so a miss (the task
    /// was stolen by another worker) just falls back to a full publish.
    pub last_pub: HashMap<(usize, usize), (u32, Arc<LayerParams>)>,
}

/// Everything one worker needs to run tasks of an experiment.
pub struct NodeCtx {
    /// The *home* of the task currently executing (set by
    /// [`drain_tasks`] before each `run_task`) — the logical node of the
    /// paper's static mapping, in `[0, N)`.
    pub node_id: usize,
    /// Experiment configuration (validated).
    pub cfg: ExperimentConfig,
    /// Parameter store handle (shared or TCP).
    pub store: Arc<dyn ParamStore>,
    /// Compute backend (owned; never crosses threads).
    pub engine: Box<dyn Engine>,
    /// The current home's training data (full set, or its shard for
    /// Federated) — swapped alongside `node_id`.
    pub data: Arc<Dataset>,
    /// Span recorder for utilization accounting.
    pub rec: SpanRecorder,
    /// Training curve (merged by the leader afterwards).
    pub curve: LossCurve,
    /// Home-keyed Adam states (shared across in-proc workers).
    pub opt_bank: OptBank,
    /// Worker-local activation/label caches.
    pub scratch: TaskScratch,
    /// Run-event bus (chapter progress, publishes). A default bus has no
    /// subscribers — emission is then a no-op beyond a history push.
    pub bus: EventBus,
    /// Cooperative cancellation token (checked at task boundaries;
    /// `RunHandle::cancel` also closes the store to unblock waits).
    pub cancel: CancelToken,
}

impl NodeCtx {
    /// Blocking-get timeout from config.
    pub fn timeout(&self) -> Duration {
        Duration::from_secs(self.cfg.store_timeout_s)
    }

    /// Emit a run event on this worker's bus.
    pub fn emit(&self, ev: RunEvent) {
        self.bus.emit(ev);
    }

    /// Error out if the run was cancelled (task-boundary check — the
    /// prompt path is the store close, but custom stores only get this
    /// cooperative check).
    pub fn ensure_live(&self) -> Result<()> {
        if self.cancel.is_cancelled() {
            bail!("node {}: run cancelled", self.node_id);
        }
        Ok(())
    }

    /// Deterministic fresh layer `l` — *identical across nodes and
    /// schedulers* for a given experiment seed, so Sequential vs pipelined
    /// runs start from the same model.
    pub fn fresh_layer(&self, l: usize) -> FFLayer {
        let mut rng = Rng::derive(self.cfg.seed, stream::LAYER_INIT ^ l as u64);
        FFLayer::new(self.cfg.dims[l], self.cfg.dims[l + 1], l > 0, &mut rng)
    }

    /// Deterministic fresh softmax head for the full network features.
    pub fn fresh_full_head(&self) -> LinearHead {
        let d: usize = self.cfg.dims[2..].iter().sum();
        let mut rng = Rng::derive(self.cfg.seed, stream::HEAD_INIT);
        LinearHead::new(d, self.cfg.classes, &mut rng)
    }

    /// Deterministic fresh per-layer head (PerfOpt).
    pub fn fresh_layer_head(&self, l: usize) -> LinearHead {
        let mut rng = Rng::derive(self.cfg.seed, stream::HEAD_INIT ^ (l as u64) << 8);
        LinearHead::new(self.cfg.dims[l + 1], self.cfg.classes, &mut rng)
    }

    /// Positive inputs: data with true labels overlaid.
    pub fn positive_inputs(&self) -> Matrix {
        overlay_labels(&self.data.x, &self.data.y, self.cfg.classes)
    }

    /// Negative inputs for given wrong labels.
    pub fn negative_inputs(&self, neg_labels: &[u8]) -> Matrix {
        overlay_labels(&self.data.x, neg_labels, self.cfg.classes)
    }

    /// Neutral-overlay inputs (PerfOpt / Softmax-head features).
    pub fn neutral_inputs(&self) -> Matrix {
        overlay_neutral(&self.data.x, self.cfg.classes)
    }

    /// Derived wrong labels for `chapter` (RandomNEG; FixedNEG passes 0).
    /// Identical on every node — no communication needed.
    pub fn derived_neg_labels(&self, chapter: u32) -> Vec<u8> {
        random_wrong_labels(self.cfg.seed, chapter, &self.data.y, self.cfg.classes)
    }

    /// Negative labels to *use* for `chapter` under the configured
    /// strategy, when the home can evaluate the network locally
    /// (Sequential / All-Layers / Federated).
    ///
    /// AdaptiveNEG: chapters before the home has a trained network fall
    /// back to the random derivation; afterwards the caller supplies the
    /// current network via `net` and labels are the most-predicted
    /// incorrect class (§5), computed locally.
    pub fn local_neg_labels(&mut self, chapter: u32, net: Option<&FFNetwork>) -> Result<Vec<u8>> {
        match self.cfg.neg {
            NegStrategy::Fixed => Ok(self.derived_neg_labels(0)),
            NegStrategy::Random => Ok(self.derived_neg_labels(chapter)),
            NegStrategy::Adaptive => match net {
                None => Ok(self.derived_neg_labels(0)),
                Some(net) => {
                    let chunk = self.cfg.eval_chunk;
                    let sub = self.cfg.neg_subsample;
                    let eng = self.engine.as_mut();
                    let rec = &mut self.rec;
                    let data = &self.data;
                    rec.time(SpanKind::NegGen, usize::MAX, chapter, || {
                        if sub == 0 || sub >= data.len() {
                            adaptive_neg_labels(eng, net, &data.x, &data.y, chunk)
                        } else {
                            // Refresh a deterministic subsample; reuse the
                            // random derivation elsewhere (cheap hybrid).
                            let mut labels = random_wrong_labels(
                                self.cfg.seed,
                                chapter,
                                &data.y,
                                self.cfg.classes,
                            );
                            let rows: Vec<usize> = (0..sub).map(|i| i * data.len() / sub).collect();
                            let xs = data.x.gather_rows(&rows);
                            let ys: Vec<u8> = rows.iter().map(|&r| data.y[r]).collect();
                            let adap = adaptive_neg_labels(eng, net, &xs, &ys, chunk)?;
                            for (ri, &r) in rows.iter().enumerate() {
                                labels[r] = adap[ri];
                            }
                            Ok(labels)
                        }
                    })
                }
            },
        }
    }

    /// Train one FF layer for one chapter (`C = E/S` mini-epochs) on
    /// already-transformed positive/negative inputs. Returns mean loss.
    ///
    /// `chapter` positions the LR cooldown: by chapter `c` the layer has
    /// already seen `c·C` epochs.
    pub fn train_ff_layer_chapter(
        &mut self,
        layer: &mut FFLayer,
        opt: &mut AdamState,
        layer_idx: usize,
        chapter: u32,
        x_pos: &Matrix,
        x_neg: &Matrix,
    ) -> Result<f32> {
        let c_epochs = self.cfg.epochs_per_chapter();
        let base_lr = self.cfg.lr_ff;
        let total = self.cfg.epochs;
        let batch = self.cfg.batch;
        let seed = self.cfg.seed;
        let eng = self.engine.as_mut();
        let rec = &mut self.rec;
        let n = x_pos.rows;
        let mut mean_loss = 0.0f32;
        let mut steps = 0u32;
        rec.time(SpanKind::Train, layer_idx, chapter, || -> Result<()> {
            for me in 0..c_epochs {
                let epoch = chapter * c_epochs + me;
                let lr = cooldown(base_lr, epoch, total);
                let mut rng = Rng::derive(
                    seed,
                    stream::SHUFFLE ^ (u64::from(epoch) << 16) ^ (layer_idx as u64),
                );
                let mut order: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut order);
                for idx in order.chunks(batch) {
                    let bp = x_pos.gather_rows(idx);
                    let bn = x_neg.gather_rows(idx);
                    let stats = eng.ff_train_step(layer, opt, &bp, &bn, self.cfg.theta, lr)?;
                    mean_loss += stats.loss();
                    steps += 1;
                }
            }
            Ok(())
        })?;
        let loss = if steps > 0 { mean_loss / steps as f32 } else { 0.0 };
        let epoch_f = (chapter + 1) as f32 * c_epochs as f32;
        self.curve.push_loss(epoch_f, loss);
        Ok(loss)
    }

    /// Train one PerfOpt (layer, head) pair for one chapter. Returns mean
    /// CE loss.
    #[allow(clippy::too_many_arguments)]
    pub fn train_perfopt_layer_chapter(
        &mut self,
        layer: &mut FFLayer,
        head: &mut LinearHead,
        opt_layer: &mut AdamState,
        opt_head: &mut AdamState,
        layer_idx: usize,
        chapter: u32,
        x: &Matrix,
        labels: &[u8],
    ) -> Result<f32> {
        let c_epochs = self.cfg.epochs_per_chapter();
        let base_lr = self.cfg.lr_ff;
        let total = self.cfg.epochs;
        let batch = self.cfg.batch;
        let seed = self.cfg.seed;
        let eng = self.engine.as_mut();
        let rec = &mut self.rec;
        let n = x.rows;
        let mut mean_loss = 0.0f32;
        let mut steps = 0u32;
        rec.time(SpanKind::Train, layer_idx, chapter, || -> Result<()> {
            for me in 0..c_epochs {
                let epoch = chapter * c_epochs + me;
                let lr = cooldown(base_lr, epoch, total);
                let mut rng = Rng::derive(
                    seed,
                    stream::SHUFFLE ^ (u64::from(epoch) << 16) ^ (layer_idx as u64),
                );
                let mut order: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut order);
                for idx in order.chunks(batch) {
                    let bx = x.gather_rows(idx);
                    let by: Vec<u8> = idx.iter().map(|&r| labels[r]).collect();
                    let loss =
                        eng.perfopt_train_step(layer, head, opt_layer, opt_head, &bx, &by, lr)?;
                    mean_loss += loss;
                    steps += 1;
                }
            }
            Ok(())
        })?;
        let loss = if steps > 0 { mean_loss / steps as f32 } else { 0.0 };
        self.curve.push_loss((chapter + 1) as f32 * c_epochs as f32, loss);
        Ok(loss)
    }

    /// Train the full-network softmax head for one chapter on precomputed
    /// features. Head LR follows its own cooldown from `cfg.lr_head`.
    pub fn train_head_chapter(
        &mut self,
        head: &mut LinearHead,
        opt: &mut AdamState,
        chapter: u32,
        feats: &Matrix,
        labels: &[u8],
    ) -> Result<f32> {
        let c_epochs = self.cfg.epochs_per_chapter();
        let base_lr = self.cfg.lr_head;
        let total = self.cfg.epochs;
        let batch = self.cfg.batch;
        let seed = self.cfg.seed;
        let eng = self.engine.as_mut();
        let rec = &mut self.rec;
        let n = feats.rows;
        let mut mean_loss = 0.0f32;
        let mut steps = 0u32;
        rec.time(SpanKind::HeadTrain, usize::MAX, chapter, || -> Result<()> {
            for me in 0..c_epochs {
                let epoch = chapter * c_epochs + me;
                let lr = cooldown(base_lr, epoch, total);
                let mut rng = Rng::derive(seed, stream::SHUFFLE ^ (u64::from(epoch) << 32));
                let mut order: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut order);
                for idx in order.chunks(batch) {
                    let bx = feats.gather_rows(idx);
                    let by: Vec<u8> = idx.iter().map(|&r| labels[r]).collect();
                    mean_loss += eng.head_train_step(head, opt, &bx, &by, lr)?;
                    steps += 1;
                }
            }
            Ok(())
        })?;
        Ok(if steps > 0 { mean_loss / steps as f32 } else { 0.0 })
    }

    /// Forward both pos/neg tensors through `layer` (timed as Forward).
    pub fn forward_pair(
        &mut self,
        layer: &FFLayer,
        layer_idx: usize,
        chapter: u32,
        x_pos: Matrix,
        x_neg: Matrix,
    ) -> Result<(Matrix, Matrix)> {
        let eng = self.engine.as_mut();
        self.rec.time(SpanKind::Forward, layer_idx, chapter, || {
            Ok((eng.layer_forward(layer, &x_pos)?, eng.layer_forward(layer, &x_neg)?))
        })
    }

    /// Fetch `(layer, chapter)` from the store (timed as WaitLayer — the
    /// blocking read is the pipeline dependency). The returned `Arc` is
    /// the store's own copy-on-write entry; call
    /// [`LayerParams::to_layer`] to materialize a trainable copy.
    pub fn fetch_layer(&mut self, layer: usize, chapter: u32) -> Result<Arc<LayerParams>> {
        let store = self.store.clone();
        let to = self.timeout();
        self.rec
            .time(SpanKind::WaitLayer, layer, chapter, || store.get_layer(layer, chapter, to))
    }

    /// Publish a layer (timed as Publish; emits
    /// [`RunEvent::LayerPublished`] with the wire size actually shipped).
    ///
    /// When `cfg.delta_publish` is on, the transport negotiated delta
    /// support, no optimizer snapshot travels, and this worker published
    /// the base itself (see [`TaskScratch::last_pub`]), only the changed
    /// rows go over the wire — and only when that is actually smaller
    /// than the full layer. Every fallback ships the full layer, and
    /// reconstruction is bitwise, so weights are identical either way.
    ///
    /// With `wire_codec != f32` the publisher rounds the params through
    /// the codec *here*, before any store write (quantize-at-publish):
    /// every transport then stores the same dequantized bits — an in-proc
    /// store via [`ParamStore::put_layer_q`]'s local dequantize, a v4 TCP
    /// peer by dequantizing the identical frame server-side — so runs
    /// stay bitwise transport-independent. Deltas compose: they diff
    /// rounded-vs-rounded params (bit-exact f32 rows) and ship only when
    /// smaller than the quantized full frame.
    pub fn publish_layer(
        &mut self,
        layer_idx: usize,
        chapter: u32,
        layer: &FFLayer,
        opt: Option<&AdamState>,
    ) -> Result<()> {
        let ship_opt = self.cfg.ship_opt_state;
        let params = LayerParams::from_layer(layer, if ship_opt { opt } else { None });
        let full_bytes = params.wire_bytes();
        let store = self.store.clone();
        let key = (self.node_id, layer_idx);
        let codec = self.cfg.wire_codec;

        // Round through the codec up front; under f32 this is the
        // identity and `frame_bytes == full_bytes`, keeping the default
        // configuration bitwise identical to the pre-codec publish path.
        let (params, q, frame_bytes) = if codec == WireCodec::F32 {
            (Arc::new(params), None, full_bytes)
        } else {
            let q = codec.quantize_layer(&params);
            let bytes = q.wire_bytes();
            (Arc::new(q.dequantize()), Some(q), bytes)
        };

        let wire_bytes = if self.cfg.delta_publish && !ship_opt && store.supports_deltas() {
            let delta = self
                .scratch
                .last_pub
                .get(&key)
                .and_then(|(bc, base)| LayerDelta::diff(base, &params).map(|d| (*bc, d)))
                .filter(|(_, d)| d.wire_bytes() < frame_bytes);
            let shipped = match delta {
                Some((base_chapter, d)) => {
                    let bytes = d.wire_bytes();
                    self.rec.time(SpanKind::Publish, layer_idx, chapter, || {
                        store.put_layer_delta(layer_idx, chapter, base_chapter, d)
                    })?;
                    bytes
                }
                None => {
                    self.rec.time(SpanKind::Publish, layer_idx, chapter, || match q {
                        Some(q) => store.put_layer_q(layer_idx, chapter, q),
                        None => store.put_layer(layer_idx, chapter, params.as_ref().clone()),
                    })?;
                    frame_bytes
                }
            };
            self.scratch.last_pub.insert(key, (chapter, params));
            shipped
        } else {
            self.rec.time(SpanKind::Publish, layer_idx, chapter, || match q {
                Some(q) => store.put_layer_q(layer_idx, chapter, q),
                // Sole holder here, so this unwraps without copying tensors.
                None => store.put_layer(
                    layer_idx,
                    chapter,
                    Arc::try_unwrap(params).unwrap_or_else(|a| a.as_ref().clone()),
                ),
            })?;
            frame_bytes
        };
        self.emit(RunEvent::LayerPublished {
            node: self.node_id,
            layer: layer_idx,
            chapter,
            wire_bytes,
            raw_bytes: full_bytes,
        });
        Ok(())
    }

    /// Publish the full-network softmax head (timed as Publish; emits
    /// [`RunEvent::HeadPublished`]). Quantize-at-publish applies exactly
    /// as in [`NodeCtx::publish_layer`].
    pub fn publish_head(
        &mut self,
        chapter: u32,
        head: &LinearHead,
        opt: Option<&AdamState>,
    ) -> Result<()> {
        let params = HeadParams::from_head(head, if self.cfg.ship_opt_state { opt } else { None });
        let store = self.store.clone();
        let codec = self.cfg.wire_codec;
        let wire_bytes = if codec == WireCodec::F32 {
            let bytes = params.wire_bytes();
            self.rec
                .time(SpanKind::Publish, usize::MAX, chapter, || store.put_head(chapter, params))?;
            bytes
        } else {
            let q = codec.quantize_head(&params);
            let bytes = q.wire_bytes();
            self.rec
                .time(SpanKind::Publish, usize::MAX, chapter, || store.put_head_q(chapter, q))?;
            bytes
        };
        self.emit(RunEvent::HeadPublished { node: self.node_id, chapter, wire_bytes });
        Ok(())
    }

    /// Take (or create) the current home's Adam state for store slot
    /// `slot` (a layer index, a PerfOpt head slot, or
    /// [`crate::coordinator::schedulers::CLS_HEAD_SLOT`]), preferring a
    /// shipped snapshot when `ship_opt_state` is on. `(d_in, d_out)` sizes
    /// a fresh state when neither exists.
    pub fn take_opt_sized(
        &mut self,
        slot: usize,
        shipped: Option<AdamState>,
        d_in: usize,
        d_out: usize,
    ) -> AdamState {
        if self.cfg.ship_opt_state {
            if let Some(s) = shipped {
                return s;
            }
        }
        self.opt_bank
            .take(self.node_id, slot)
            .unwrap_or_else(|| AdamState::new(d_in, d_out))
    }

    /// [`NodeCtx::take_opt_sized`] for a plain FF layer index.
    pub fn take_opt(&mut self, layer_idx: usize, shipped: Option<AdamState>) -> AdamState {
        let (d_in, d_out) = (self.cfg.dims[layer_idx], self.cfg.dims[layer_idx + 1]);
        self.take_opt_sized(layer_idx, shipped, d_in, d_out)
    }

    /// Return the Adam state to the current home's bank slot.
    pub fn put_opt(&mut self, slot: usize, opt: AdamState) {
        self.opt_bank.put(self.node_id, slot, opt);
    }
}

/// Where a worker's tasks come from: the in-proc [`Dispatcher`] or the
/// leader over TCP. `next` blocks until a task is ready (or the run
/// completes → `None`); `done` reports a completed lease; `fail` tells
/// the source this worker is going down with an error.
pub trait TaskSource {
    /// Next task for `worker`, or `None` when the run is complete.
    fn next(&self, worker: u32) -> Result<Option<Task>>;
    /// Report `task` complete with its loss and busy/wait split.
    fn done(&self, worker: u32, task: Task, loss: f32, busy_s: f64, wait_s: f64) -> Result<()>;
    /// Report this worker failing (best-effort; must not block).
    fn fail(&self, worker: u32, reason: &str);
}

/// [`TaskSource`] over the in-proc work-bucket dispatcher.
pub struct DispatcherSource {
    /// The shared dispatcher.
    pub dispatcher: Arc<Dispatcher>,
    /// Per-`next` park timeout.
    pub timeout: Duration,
}

impl TaskSource for DispatcherSource {
    fn next(&self, worker: u32) -> Result<Option<Task>> {
        self.dispatcher.next_task(worker, self.timeout)
    }
    fn done(&self, worker: u32, task: Task, loss: f32, busy_s: f64, wait_s: f64) -> Result<()> {
        self.dispatcher.complete(worker, task.id, loss, busy_s, wait_s)
    }
    fn fail(&self, _worker: u32, reason: &str) {
        // Closing the dispatcher unblocks every parked peer with the error.
        self.dispatcher.close(reason);
    }
}

/// [`TaskSource`] over the leader's TCP task frames (cluster worker).
pub struct TcpTaskSource {
    /// Connection to the leader.
    pub client: Arc<TcpStoreClient>,
    /// Per-`next` server-side wait budget.
    pub timeout: Duration,
}

impl TaskSource for TcpTaskSource {
    fn next(&self, _worker: u32) -> Result<Option<Task>> {
        self.client.next_task(self.timeout)
    }
    fn done(&self, _worker: u32, task: Task, loss: f32, busy_s: f64, wait_s: f64) -> Result<()> {
        self.client.task_done(task.id as u64, loss, busy_s, wait_s)
    }
    fn fail(&self, _worker: u32, _reason: &str) {
        // The connection drop is the signal: the leader requeues our
        // leased tasks when the registry notices the disconnect.
    }
}

/// Drain tasks from `source` until the run completes: fetch (timed as
/// WaitTask), assume the task home's identity (node id + data shard),
/// execute hermetically, report. On a task error the source is notified
/// (`fail`) before the error propagates, so peers don't park forever on
/// a dependency that will never publish.
pub fn drain_tasks(
    ctx: &mut NodeCtx,
    scheduler: &dyn Scheduler,
    source: &dyn TaskSource,
    shards: &[Arc<Dataset>],
    worker: u32,
) -> Result<()> {
    loop {
        ctx.ensure_live()?;
        let task = ctx
            .rec
            .time(SpanKind::WaitTask, usize::MAX, 0, || source.next(worker))?;
        let Some(task) = task else { break };
        ctx.node_id = task.home;
        ctx.data = shards[task.home].clone();
        let mark = ctx.rec.mark();
        match scheduler.run_task(ctx, task) {
            Ok(loss) => {
                let (busy_s, wait_s) = ctx.rec.split_since(mark);
                source.done(worker, task, loss, busy_s, wait_s)?;
            }
            Err(e) => {
                source.fail(worker, &format!("{e:#}"));
                return Err(e);
            }
        }
    }
    Ok(())
}

/// Outcome of one external worker run ([`run_worker`]).
#[derive(Debug)]
pub struct WorkerRun {
    /// The worker id the leader assigned (or confirmed).
    pub node_id: usize,
    /// Span report (busy/wait accounting) for this worker.
    pub report: NodeReport,
    /// This worker's training curve.
    pub curve: LossCurve,
    /// Wall-clock seconds from connect to DONE.
    pub wall_s: f64,
}

/// Entry point of the `pff worker --connect <addr>` process: join the
/// leader's cluster over TCP, drain task leases against the remote store,
/// and report `DONE`.
///
/// The worker loads its data locally (synthetic sets derive
/// deterministically from `cfg.seed`, so every process sees identical
/// examples without shipping them); Federated runs carve every home's
/// shard up front, since a task of any home may land here. Worker ids are
/// elastic — a late joiner's id may exceed `cfg.nodes`; tasks still run
/// under their *home* identity. The scheduler resolves through the
/// [`crate::coordinator::schedulers::SchedulerRegistry`]; progress events
/// print to stderr only when `cfg.verbose` is set (library silence
/// otherwise).
pub fn run_worker(
    cfg: &ExperimentConfig,
    addr: SocketAddr,
    requested_id: Option<u32>,
    connect_wait: Duration,
) -> Result<WorkerRun> {
    let cfg = cfg.clone().validated()?;
    ensure!(
        cfg.transport == TransportKind::Tcp,
        "worker mode needs transport = tcp (got {:?})",
        cfg.transport
    );
    // Workers size their kernel runtime from the shared config, exactly
    // like the in-proc session path.
    crate::tensor::pool::set_threads(cfg.threads);
    let scheduler = crate::coordinator::schedulers::for_config(&cfg)?;
    let graph = scheduler.graph(&cfg)?;
    let name = format!("worker-{}", std::process::id());
    let client = TcpStoreClient::connect_worker_retry(addr, requested_id, &name, connect_wait)?;
    let worker_id = client.node_id().context("leader did not assign a worker id")? as usize;

    let bundle = load_dataset(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    // Same placement seam as the in-proc coordinator: the scheduler's
    // graph decides sharding, not the config enum.
    let shards: Vec<Arc<Dataset>> = if graph.shard_data() {
        bundle.train.shard(graph.nodes()).into_iter().map(Arc::new).collect()
    } else {
        let full = Arc::new(bundle.train);
        (0..graph.nodes()).map(|_| full.clone()).collect()
    };
    let factory = factory_for(cfg.engine, &cfg.artifact_dir)?;
    let engine = factory().context("constructing worker engine")?;

    let bus = EventBus::new();
    if cfg.verbose {
        // pff-allow(no-print-in-lib): this verbose-gated observer IS the
        // bus consumer of a standalone worker process — there is no
        // leader-side subscriber to forward these events to.
        bus.observe(|ev| eprintln!("[pff-worker] {ev}"));
    }
    let client = Arc::new(client);
    let task_timeout = Duration::from_secs(cfg.store_timeout_s);
    let origin = Instant::now();
    let mut ctx = NodeCtx {
        node_id: 0,
        cfg,
        store: client.clone() as Arc<dyn ParamStore>,
        engine,
        data: shards[0].clone(),
        rec: SpanRecorder::new(origin, worker_id),
        curve: LossCurve::default(),
        opt_bank: OptBank::new(),
        scratch: TaskScratch::default(),
        bus,
        cancel: CancelToken::default(),
    };
    let source = TcpTaskSource { client: client.clone(), timeout: task_timeout };
    drain_tasks(&mut ctx, scheduler.as_ref(), &source, &shards, worker_id as u32)?;
    client.done().context("reporting DONE to the leader")?;
    Ok(WorkerRun {
        node_id: worker_id,
        report: ctx.rec.finish(),
        curve: ctx.curve,
        wall_s: origin.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::MemStore;
    use crate::data::synth::synth_mnist;
    use crate::engine::NativeEngine;
    use std::time::Instant;

    fn ctx(nodes: usize) -> NodeCtx {
        let mut cfg = ExperimentConfig::tiny();
        cfg.nodes = nodes;
        let mut bundle = synth_mnist(64, 16, cfg.seed);
        bundle.train.center_rows();
        NodeCtx {
            node_id: 0,
            cfg,
            store: Arc::new(MemStore::new()),
            engine: Box::new(NativeEngine::new()),
            data: Arc::new(bundle.train),
            rec: SpanRecorder::new(Instant::now(), 0),
            curve: LossCurve::default(),
            opt_bank: OptBank::new(),
            scratch: TaskScratch::default(),
            bus: EventBus::new(),
            cancel: CancelToken::default(),
        }
    }

    #[test]
    fn fresh_layer_deterministic_across_nodes() {
        let a = ctx(1);
        let mut b = ctx(4);
        b.node_id = 3;
        assert_eq!(a.fresh_layer(1).w, b.fresh_layer(1).w);
        assert_ne!(a.fresh_layer(0).w.data, a.fresh_layer(1).w.data);
    }

    #[test]
    fn overlay_inputs_shapes() {
        let c = ctx(1);
        let pos = c.positive_inputs();
        assert_eq!((pos.rows, pos.cols), (64, 784));
        let neg = c.negative_inputs(&c.derived_neg_labels(0));
        assert_eq!(neg.rows, 64);
        // pos and neg differ only in the overlay region
        for r in 0..pos.rows {
            assert_eq!(pos.row(r)[10..], neg.row(r)[10..]);
        }
    }

    #[test]
    fn train_chapter_reduces_loss_and_records_span() {
        let mut c = ctx(1);
        c.cfg.epochs = 32;
        c.cfg.splits = 4; // 8 epochs per chapter
        let mut layer = c.fresh_layer(0);
        let mut opt = AdamState::new(784, 64);
        let x_pos = c.positive_inputs();
        let x_neg = c.negative_inputs(&c.derived_neg_labels(0));
        let mut losses = Vec::new();
        for ch in 0..4 {
            losses.push(
                c.train_ff_layer_chapter(&mut layer, &mut opt, 0, ch, &x_pos, &x_neg)
                    .unwrap(),
            );
        }
        assert!(
            losses[3] < losses[0],
            "loss should fall over chapters: {losses:?}"
        );
        let rep = c.rec.finish();
        assert!(rep.in_kind(SpanKind::Train) > 0.0);
        assert_eq!(c.curve.points.len(), 4);
    }

    #[test]
    fn opt_cache_roundtrip() {
        let mut c = ctx(1);
        let mut opt = c.take_opt(2, None);
        assert_eq!(opt.t, 0);
        opt.t = 9;
        c.put_opt(2, opt);
        assert_eq!(c.take_opt(2, None).t, 9);
        // shipped state wins when enabled
        c.cfg.ship_opt_state = true;
        let mut shipped = AdamState::new(c.cfg.dims[2], c.cfg.dims[3]);
        shipped.t = 77;
        assert_eq!(c.take_opt(2, Some(shipped)).t, 77);
    }

    #[test]
    fn opt_bank_is_home_keyed_and_shared() {
        let mut c = ctx(2);
        let mut opt = c.take_opt(1, None);
        opt.t = 5;
        c.put_opt(1, opt);
        // Another worker sharing the bank sees home 0's state under home
        // 0 only — switching homes yields a fresh state.
        c.node_id = 1;
        assert_eq!(c.take_opt(1, None).t, 0);
        c.node_id = 0;
        assert_eq!(c.take_opt(1, None).t, 5);
    }

    #[test]
    fn local_neg_labels_respects_strategy() {
        let mut c = ctx(1);
        c.cfg.neg = NegStrategy::Fixed;
        let f0 = c.local_neg_labels(0, None).unwrap();
        let f5 = c.local_neg_labels(5, None).unwrap();
        assert_eq!(f0, f5, "FixedNEG must not re-roll");
        c.cfg.neg = NegStrategy::Random;
        let r0 = c.local_neg_labels(0, None).unwrap();
        let r5 = c.local_neg_labels(5, None).unwrap();
        assert_ne!(r0, r5, "RandomNEG must re-roll per chapter");
        assert!(r0.iter().zip(&c.data.y).all(|(n, t)| n != t));
    }
}
