//! Per-node execution context and the chapter-training primitives shared
//! by every scheduler.
//!
//! A *node* is one worker in the distributed system (a thread here; a
//! machine in the paper's testbed). All schedulers compose the same four
//! primitives, so their only differences are *which* layer/chapter pairs a
//! node handles and *where* its negative labels come from — exactly the
//! deltas the paper describes.

use std::collections::HashMap;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::config::{ExperimentConfig, TransportKind};
use crate::coordinator::events::{EventBus, RunEvent};
use crate::coordinator::experiment::CancelToken;
use crate::coordinator::lr::cooldown;
use crate::coordinator::store::{HeadParams, LayerParams, ParamStore};
use crate::data::{load_dataset, Dataset};
use crate::engine::{factory_for, Engine};
use crate::ff::negative::{adaptive_neg_labels, random_wrong_labels};
use crate::ff::overlay::{overlay_labels, overlay_neutral};
use crate::ff::{FFLayer, FFNetwork, LinearHead, NegStrategy};
use crate::metrics::{LossCurve, NodeReport, SpanKind, SpanRecorder};
use crate::tensor::{AdamState, Matrix, Rng};
use crate::transport::tcp::TcpStoreClient;

/// RNG stream tags for deterministic, scheduler-independent derivations.
mod stream {
    pub const LAYER_INIT: u64 = 0x4C41_5945; // "LAYE"
    pub const HEAD_INIT: u64 = 0x4845_4144; // "HEAD"
    pub const SHUFFLE: u64 = 0x5348_5546; // "SHUF"
}

/// Everything one node needs to run its part of an experiment.
pub struct NodeCtx {
    /// Node index in `[0, N)`.
    pub node_id: usize,
    /// Experiment configuration (validated).
    pub cfg: ExperimentConfig,
    /// Parameter store handle (shared or TCP).
    pub store: Arc<dyn ParamStore>,
    /// Compute backend (owned; never crosses threads).
    pub engine: Box<dyn Engine>,
    /// This node's training data (full set, or its shard for Federated).
    pub data: Dataset,
    /// Span recorder for utilization accounting.
    pub rec: SpanRecorder,
    /// Training curve (merged by the leader afterwards).
    pub curve: LossCurve,
    /// Node-local Adam states per layer index (the paper ships only
    /// weights+biases, so moments stay with the node — see DESIGN.md).
    pub opt_cache: HashMap<usize, AdamState>,
    /// Node-local Adam state for the softmax head.
    pub head_opt: Option<AdamState>,
    /// Run-event bus (chapter progress, publishes). A default bus has no
    /// subscribers — emission is then a no-op beyond a history push.
    pub bus: EventBus,
    /// Cooperative cancellation token (checked at chapter boundaries;
    /// `RunHandle::cancel` also closes the store to unblock waits).
    pub cancel: CancelToken,
}

impl NodeCtx {
    /// Blocking-get timeout from config.
    pub fn timeout(&self) -> Duration {
        Duration::from_secs(self.cfg.store_timeout_s)
    }

    /// Emit a run event on this node's bus.
    pub fn emit(&self, ev: RunEvent) {
        self.bus.emit(ev);
    }

    /// Error out if the run was cancelled (scheduler chapter-boundary
    /// check — the prompt path is the store close, but custom stores only
    /// get this cooperative check).
    pub fn ensure_live(&self) -> Result<()> {
        if self.cancel.is_cancelled() {
            bail!("node {}: run cancelled", self.node_id);
        }
        Ok(())
    }

    /// Deterministic fresh layer `l` — *identical across nodes and
    /// schedulers* for a given experiment seed, so Sequential vs pipelined
    /// runs start from the same model.
    pub fn fresh_layer(&self, l: usize) -> FFLayer {
        let mut rng = Rng::derive(self.cfg.seed, stream::LAYER_INIT ^ l as u64);
        FFLayer::new(self.cfg.dims[l], self.cfg.dims[l + 1], l > 0, &mut rng)
    }

    /// Deterministic fresh softmax head for the full network features.
    pub fn fresh_full_head(&self) -> LinearHead {
        let d: usize = self.cfg.dims[2..].iter().sum();
        let mut rng = Rng::derive(self.cfg.seed, stream::HEAD_INIT);
        LinearHead::new(d, self.cfg.classes, &mut rng)
    }

    /// Deterministic fresh per-layer head (PerfOpt).
    pub fn fresh_layer_head(&self, l: usize) -> LinearHead {
        let mut rng = Rng::derive(self.cfg.seed, stream::HEAD_INIT ^ (l as u64) << 8);
        LinearHead::new(self.cfg.dims[l + 1], self.cfg.classes, &mut rng)
    }

    /// Positive inputs: data with true labels overlaid.
    pub fn positive_inputs(&self) -> Matrix {
        overlay_labels(&self.data.x, &self.data.y, self.cfg.classes)
    }

    /// Negative inputs for given wrong labels.
    pub fn negative_inputs(&self, neg_labels: &[u8]) -> Matrix {
        overlay_labels(&self.data.x, neg_labels, self.cfg.classes)
    }

    /// Neutral-overlay inputs (PerfOpt / Softmax-head features).
    pub fn neutral_inputs(&self) -> Matrix {
        overlay_neutral(&self.data.x, self.cfg.classes)
    }

    /// Derived wrong labels for `chapter` (RandomNEG; FixedNEG passes 0).
    /// Identical on every node — no communication needed.
    pub fn derived_neg_labels(&self, chapter: u32) -> Vec<u8> {
        random_wrong_labels(self.cfg.seed, chapter, &self.data.y, self.cfg.classes)
    }

    /// Negative labels to *use* for `chapter` under the configured
    /// strategy, when the node can evaluate the network locally
    /// (Sequential / All-Layers / Federated).
    ///
    /// AdaptiveNEG: chapters before the node has a trained network fall
    /// back to the random derivation; afterwards the caller supplies the
    /// current network via `net` and labels are the most-predicted
    /// incorrect class (§5), computed locally.
    pub fn local_neg_labels(&mut self, chapter: u32, net: Option<&FFNetwork>) -> Result<Vec<u8>> {
        match self.cfg.neg {
            NegStrategy::Fixed => Ok(self.derived_neg_labels(0)),
            NegStrategy::Random => Ok(self.derived_neg_labels(chapter)),
            NegStrategy::Adaptive => match net {
                None => Ok(self.derived_neg_labels(0)),
                Some(net) => {
                    let chunk = self.cfg.eval_chunk;
                    let sub = self.cfg.neg_subsample;
                    let eng = self.engine.as_mut();
                    let rec = &mut self.rec;
                    let data = &self.data;
                    rec.time(SpanKind::NegGen, usize::MAX, chapter, || {
                        if sub == 0 || sub >= data.len() {
                            adaptive_neg_labels(eng, net, &data.x, &data.y, chunk)
                        } else {
                            // Refresh a deterministic subsample; reuse the
                            // random derivation elsewhere (cheap hybrid).
                            let mut labels = random_wrong_labels(
                                self.cfg.seed,
                                chapter,
                                &data.y,
                                self.cfg.classes,
                            );
                            let rows: Vec<usize> = (0..sub).map(|i| i * data.len() / sub).collect();
                            let xs = data.x.gather_rows(&rows);
                            let ys: Vec<u8> = rows.iter().map(|&r| data.y[r]).collect();
                            let adap = adaptive_neg_labels(eng, net, &xs, &ys, chunk)?;
                            for (ri, &r) in rows.iter().enumerate() {
                                labels[r] = adap[ri];
                            }
                            Ok(labels)
                        }
                    })
                }
            },
        }
    }

    /// Train one FF layer for one chapter (`C = E/S` mini-epochs) on
    /// already-transformed positive/negative inputs. Returns mean loss.
    ///
    /// `chapter` positions the LR cooldown: by chapter `c` the layer has
    /// already seen `c·C` epochs.
    pub fn train_ff_layer_chapter(
        &mut self,
        layer: &mut FFLayer,
        opt: &mut AdamState,
        layer_idx: usize,
        chapter: u32,
        x_pos: &Matrix,
        x_neg: &Matrix,
    ) -> Result<f32> {
        let c_epochs = self.cfg.epochs_per_chapter();
        let base_lr = self.cfg.lr_ff;
        let total = self.cfg.epochs;
        let batch = self.cfg.batch;
        let seed = self.cfg.seed;
        let eng = self.engine.as_mut();
        let rec = &mut self.rec;
        let n = x_pos.rows;
        let mut mean_loss = 0.0f32;
        let mut steps = 0u32;
        rec.time(SpanKind::Train, layer_idx, chapter, || -> Result<()> {
            for me in 0..c_epochs {
                let epoch = chapter * c_epochs + me;
                let lr = cooldown(base_lr, epoch, total);
                let mut rng = Rng::derive(
                    seed,
                    stream::SHUFFLE ^ (u64::from(epoch) << 16) ^ (layer_idx as u64),
                );
                let mut order: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut order);
                for idx in order.chunks(batch) {
                    let bp = x_pos.gather_rows(idx);
                    let bn = x_neg.gather_rows(idx);
                    let stats = eng.ff_train_step(layer, opt, &bp, &bn, self.cfg.theta, lr)?;
                    mean_loss += stats.loss();
                    steps += 1;
                }
            }
            Ok(())
        })?;
        let loss = if steps > 0 { mean_loss / steps as f32 } else { 0.0 };
        let epoch_f = (chapter + 1) as f32 * c_epochs as f32;
        self.curve.push_loss(epoch_f, loss);
        Ok(loss)
    }

    /// Train one PerfOpt (layer, head) pair for one chapter. Returns mean
    /// CE loss.
    #[allow(clippy::too_many_arguments)]
    pub fn train_perfopt_layer_chapter(
        &mut self,
        layer: &mut FFLayer,
        head: &mut LinearHead,
        opt_layer: &mut AdamState,
        opt_head: &mut AdamState,
        layer_idx: usize,
        chapter: u32,
        x: &Matrix,
        labels: &[u8],
    ) -> Result<f32> {
        let c_epochs = self.cfg.epochs_per_chapter();
        let base_lr = self.cfg.lr_ff;
        let total = self.cfg.epochs;
        let batch = self.cfg.batch;
        let seed = self.cfg.seed;
        let eng = self.engine.as_mut();
        let rec = &mut self.rec;
        let n = x.rows;
        let mut mean_loss = 0.0f32;
        let mut steps = 0u32;
        rec.time(SpanKind::Train, layer_idx, chapter, || -> Result<()> {
            for me in 0..c_epochs {
                let epoch = chapter * c_epochs + me;
                let lr = cooldown(base_lr, epoch, total);
                let mut rng = Rng::derive(
                    seed,
                    stream::SHUFFLE ^ (u64::from(epoch) << 16) ^ (layer_idx as u64),
                );
                let mut order: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut order);
                for idx in order.chunks(batch) {
                    let bx = x.gather_rows(idx);
                    let by: Vec<u8> = idx.iter().map(|&r| labels[r]).collect();
                    let loss =
                        eng.perfopt_train_step(layer, head, opt_layer, opt_head, &bx, &by, lr)?;
                    mean_loss += loss;
                    steps += 1;
                }
            }
            Ok(())
        })?;
        let loss = if steps > 0 { mean_loss / steps as f32 } else { 0.0 };
        self.curve.push_loss((chapter + 1) as f32 * c_epochs as f32, loss);
        Ok(loss)
    }

    /// Train the full-network softmax head for one chapter on precomputed
    /// features. Head LR follows its own cooldown from `cfg.lr_head`.
    pub fn train_head_chapter(
        &mut self,
        head: &mut LinearHead,
        opt: &mut AdamState,
        chapter: u32,
        feats: &Matrix,
        labels: &[u8],
    ) -> Result<f32> {
        let c_epochs = self.cfg.epochs_per_chapter();
        let base_lr = self.cfg.lr_head;
        let total = self.cfg.epochs;
        let batch = self.cfg.batch;
        let seed = self.cfg.seed;
        let eng = self.engine.as_mut();
        let rec = &mut self.rec;
        let n = feats.rows;
        let mut mean_loss = 0.0f32;
        let mut steps = 0u32;
        rec.time(SpanKind::HeadTrain, usize::MAX, chapter, || -> Result<()> {
            for me in 0..c_epochs {
                let epoch = chapter * c_epochs + me;
                let lr = cooldown(base_lr, epoch, total);
                let mut rng = Rng::derive(seed, stream::SHUFFLE ^ (u64::from(epoch) << 32));
                let mut order: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut order);
                for idx in order.chunks(batch) {
                    let bx = feats.gather_rows(idx);
                    let by: Vec<u8> = idx.iter().map(|&r| labels[r]).collect();
                    mean_loss += eng.head_train_step(head, opt, &bx, &by, lr)?;
                    steps += 1;
                }
            }
            Ok(())
        })?;
        Ok(if steps > 0 { mean_loss / steps as f32 } else { 0.0 })
    }

    /// Forward both pos/neg tensors through `layer` (timed as Forward).
    pub fn forward_pair(
        &mut self,
        layer: &FFLayer,
        layer_idx: usize,
        chapter: u32,
        x_pos: Matrix,
        x_neg: Matrix,
    ) -> Result<(Matrix, Matrix)> {
        let eng = self.engine.as_mut();
        self.rec.time(SpanKind::Forward, layer_idx, chapter, || {
            Ok((eng.layer_forward(layer, &x_pos)?, eng.layer_forward(layer, &x_neg)?))
        })
    }

    /// Fetch `(layer, chapter)` from the store (timed as WaitLayer — the
    /// blocking read is the pipeline dependency).
    pub fn fetch_layer(&mut self, layer: usize, chapter: u32) -> Result<LayerParams> {
        let store = self.store.clone();
        let to = self.timeout();
        self.rec
            .time(SpanKind::WaitLayer, layer, chapter, || store.get_layer(layer, chapter, to))
    }

    /// Publish a layer (timed as Publish; emits
    /// [`RunEvent::LayerPublished`] with the wire size).
    pub fn publish_layer(
        &mut self,
        layer_idx: usize,
        chapter: u32,
        layer: &FFLayer,
        opt: Option<&AdamState>,
    ) -> Result<()> {
        let params = LayerParams::from_layer(layer, if self.cfg.ship_opt_state { opt } else { None });
        let wire_bytes = params.wire_bytes();
        let store = self.store.clone();
        self.rec
            .time(SpanKind::Publish, layer_idx, chapter, || store.put_layer(layer_idx, chapter, params))?;
        self.emit(RunEvent::LayerPublished {
            node: self.node_id,
            layer: layer_idx,
            chapter,
            wire_bytes,
        });
        Ok(())
    }

    /// Publish the full-network softmax head (timed as Publish; emits
    /// [`RunEvent::HeadPublished`]).
    pub fn publish_head(
        &mut self,
        chapter: u32,
        head: &LinearHead,
        opt: Option<&AdamState>,
    ) -> Result<()> {
        let params = HeadParams::from_head(head, if self.cfg.ship_opt_state { opt } else { None });
        let wire_bytes = params.wire_bytes();
        let store = self.store.clone();
        self.rec
            .time(SpanKind::Publish, usize::MAX, chapter, || store.put_head(chapter, params))?;
        self.emit(RunEvent::HeadPublished { node: self.node_id, chapter, wire_bytes });
        Ok(())
    }

    /// Take (or create) the node-local Adam state for store slot `slot`
    /// (a layer index, or a PerfOpt head slot), preferring a shipped
    /// snapshot when `ship_opt_state` is on. `(d_in, d_out)` sizes a fresh
    /// state when neither exists.
    pub fn take_opt_sized(
        &mut self,
        slot: usize,
        shipped: Option<AdamState>,
        d_in: usize,
        d_out: usize,
    ) -> AdamState {
        if self.cfg.ship_opt_state {
            if let Some(s) = shipped {
                return s;
            }
        }
        self.opt_cache.remove(&slot).unwrap_or_else(|| AdamState::new(d_in, d_out))
    }

    /// [`NodeCtx::take_opt_sized`] for a plain FF layer index.
    pub fn take_opt(&mut self, layer_idx: usize, shipped: Option<AdamState>) -> AdamState {
        let (d_in, d_out) = (self.cfg.dims[layer_idx], self.cfg.dims[layer_idx + 1]);
        self.take_opt_sized(layer_idx, shipped, d_in, d_out)
    }

    /// Return the Adam state to the node-local cache.
    pub fn put_opt(&mut self, layer_idx: usize, opt: AdamState) {
        self.opt_cache.insert(layer_idx, opt);
    }
}

/// Outcome of one external worker run ([`run_worker`]).
#[derive(Debug)]
pub struct WorkerRun {
    /// The node id the leader assigned (or confirmed).
    pub node_id: usize,
    /// Span report (busy/wait accounting) for this worker.
    pub report: NodeReport,
    /// This worker's training curve.
    pub curve: LossCurve,
    /// Wall-clock seconds from connect to DONE.
    pub wall_s: f64,
}

/// Entry point of the `pff worker --connect <addr>` process: join the
/// leader's cluster over TCP, run this node's scheduler chapters against
/// the remote store, and report `DONE`.
///
/// The worker loads its data locally (synthetic sets derive
/// deterministically from `cfg.seed`, so every process sees identical
/// examples without shipping them); Federated runs carve the node's shard
/// from the leader-assigned node id. The scheduler resolves through the
/// [`crate::coordinator::schedulers::SchedulerRegistry`]; progress events
/// print to stderr only when `cfg.verbose` is set (library silence
/// otherwise).
pub fn run_worker(
    cfg: &ExperimentConfig,
    addr: SocketAddr,
    requested_id: Option<u32>,
    connect_wait: Duration,
) -> Result<WorkerRun> {
    let cfg = cfg.clone().validated()?;
    ensure!(
        cfg.transport == TransportKind::Tcp,
        "worker mode needs transport = tcp (got {:?})",
        cfg.transport
    );
    // Workers size their kernel runtime from the shared config, exactly
    // like the in-proc session path.
    crate::tensor::pool::set_threads(cfg.threads);
    let scheduler = crate::coordinator::schedulers::for_config(&cfg)?;
    let name = format!("worker-{}", std::process::id());
    let client = TcpStoreClient::connect_worker_retry(addr, requested_id, &name, connect_wait)?;
    let node_id = client.node_id().context("leader did not assign a node id")? as usize;
    ensure!(
        node_id < cfg.nodes,
        "assigned node id {node_id} out of range for a {}-node experiment",
        cfg.nodes
    );

    let bundle = load_dataset(cfg.dataset, cfg.train_n, cfg.test_n, cfg.seed)?;
    // Same placement seam as the in-proc coordinator: the scheduler's
    // plan decides sharding, not the config enum.
    let data = if scheduler.plan(&cfg).shard_data {
        bundle.train.shard(cfg.nodes).swap_remove(node_id)
    } else {
        bundle.train
    };
    let factory = factory_for(cfg.engine, &cfg.artifact_dir)?;
    let engine = factory().context("constructing worker engine")?;

    let bus = EventBus::new();
    if cfg.verbose {
        bus.observe(|ev| eprintln!("[pff-worker] {ev}"));
    }
    let client = Arc::new(client);
    let origin = Instant::now();
    let mut ctx = NodeCtx {
        node_id,
        cfg,
        store: client.clone() as Arc<dyn ParamStore>,
        engine,
        data,
        rec: SpanRecorder::new(origin, node_id),
        curve: LossCurve::default(),
        opt_cache: HashMap::new(),
        head_opt: None,
        bus,
        cancel: CancelToken::default(),
    };
    scheduler.run_node(&mut ctx)?;
    client.done().context("reporting DONE to the leader")?;
    Ok(WorkerRun {
        node_id,
        report: ctx.rec.finish(),
        curve: ctx.curve,
        wall_s: origin.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::MemStore;
    use crate::data::synth::synth_mnist;
    use crate::engine::NativeEngine;
    use std::time::Instant;

    fn ctx(nodes: usize) -> NodeCtx {
        let mut cfg = ExperimentConfig::tiny();
        cfg.nodes = nodes;
        let mut bundle = synth_mnist(64, 16, cfg.seed);
        bundle.train.center_rows();
        NodeCtx {
            node_id: 0,
            cfg,
            store: Arc::new(MemStore::new()),
            engine: Box::new(NativeEngine::new()),
            data: bundle.train,
            rec: SpanRecorder::new(Instant::now(), 0),
            curve: LossCurve::default(),
            opt_cache: HashMap::new(),
            head_opt: None,
            bus: EventBus::new(),
            cancel: CancelToken::default(),
        }
    }

    #[test]
    fn fresh_layer_deterministic_across_nodes() {
        let a = ctx(1);
        let mut b = ctx(4);
        b.node_id = 3;
        assert_eq!(a.fresh_layer(1).w, b.fresh_layer(1).w);
        assert_ne!(a.fresh_layer(0).w.data, a.fresh_layer(1).w.data);
    }

    #[test]
    fn overlay_inputs_shapes() {
        let c = ctx(1);
        let pos = c.positive_inputs();
        assert_eq!((pos.rows, pos.cols), (64, 784));
        let neg = c.negative_inputs(&c.derived_neg_labels(0));
        assert_eq!(neg.rows, 64);
        // pos and neg differ only in the overlay region
        for r in 0..pos.rows {
            assert_eq!(pos.row(r)[10..], neg.row(r)[10..]);
        }
    }

    #[test]
    fn train_chapter_reduces_loss_and_records_span() {
        let mut c = ctx(1);
        c.cfg.epochs = 32;
        c.cfg.splits = 4; // 8 epochs per chapter
        let mut layer = c.fresh_layer(0);
        let mut opt = AdamState::new(784, 64);
        let x_pos = c.positive_inputs();
        let x_neg = c.negative_inputs(&c.derived_neg_labels(0));
        let mut losses = Vec::new();
        for ch in 0..4 {
            losses.push(
                c.train_ff_layer_chapter(&mut layer, &mut opt, 0, ch, &x_pos, &x_neg)
                    .unwrap(),
            );
        }
        assert!(
            losses[3] < losses[0],
            "loss should fall over chapters: {losses:?}"
        );
        let rep = c.rec.finish();
        assert!(rep.in_kind(SpanKind::Train) > 0.0);
        assert_eq!(c.curve.points.len(), 4);
    }

    #[test]
    fn opt_cache_roundtrip() {
        let mut c = ctx(1);
        let mut opt = c.take_opt(2, None);
        assert_eq!(opt.t, 0);
        opt.t = 9;
        c.put_opt(2, opt);
        assert_eq!(c.take_opt(2, None).t, 9);
        // shipped state wins when enabled
        c.cfg.ship_opt_state = true;
        let mut shipped = AdamState::new(c.cfg.dims[2], c.cfg.dims[3]);
        shipped.t = 77;
        assert_eq!(c.take_opt(2, Some(shipped)).t, 77);
    }

    #[test]
    fn local_neg_labels_respects_strategy() {
        let mut c = ctx(1);
        c.cfg.neg = NegStrategy::Fixed;
        let f0 = c.local_neg_labels(0, None).unwrap();
        let f5 = c.local_neg_labels(5, None).unwrap();
        assert_eq!(f0, f5, "FixedNEG must not re-roll");
        c.cfg.neg = NegStrategy::Random;
        let r0 = c.local_neg_labels(0, None).unwrap();
        let r5 = c.local_neg_labels(5, None).unwrap();
        assert_ne!(r0, r5, "RandomNEG must re-roll per chapter");
        assert!(r0.iter().zip(&c.data.y).all(|(n, t)| n != t));
    }
}
