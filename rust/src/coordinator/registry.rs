//! Leader-side registry of cluster nodes.
//!
//! In multi-process cluster mode (`pff worker --connect`), worker
//! processes announce themselves to the leader through the v2 `HELLO`
//! handshake (see `transport/PROTOCOL.md`). The leader parks on this
//! registry's Condvar until the expected number of workers has joined,
//! and again until every worker has reported `DONE` — the same
//! wait-on-publish discipline the parameter store uses, so there is no
//! polling anywhere in the control plane.
//!
//! Membership is crash-tolerant: a worker whose connection drops before
//! its `DONE` is deregistered (freeing its node id for a restarted
//! process); completed workers stay on the roster. Each such drop opens a
//! **reconnect lease**: the vacated id is held for adoption by a
//! replacement `pff worker` for a configurable window
//! ([`NodeRegistry::set_lease`]); when the lease expires with nobody
//! adopting, [`NodeRegistry::wait_for_done`] fails fast, naming the
//! dropped node, instead of hanging the leader until the full timeout.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::sync::{LockRank, OrderedCondvar, OrderedMutex};

/// One registered worker.
#[derive(Clone, Debug)]
pub struct NodeInfo {
    /// Node index in `[0, N)` (drives chapter/shard assignment).
    pub id: u32,
    /// Self-reported name (worker processes use `worker-<pid>`).
    pub name: String,
}

struct WorkerEntry {
    info: NodeInfo,
    done: bool,
}

/// A node id vacated by a mid-run disconnect, awaiting adoption by a
/// replacement worker (reconnect lease).
struct Vacancy {
    info: NodeInfo,
    since: Instant,
    /// `(chapter, layer)` task cells the worker held a dispatcher lease
    /// on when it dropped — surfaced in the lease-expiry error so the
    /// operator sees exactly which work was orphaned.
    tasks: Vec<(u32, usize)>,
}

#[derive(Default)]
struct RegistryInner {
    workers: Vec<WorkerEntry>,
    /// Ids vacated by crashed (pre-`DONE`) workers, with drop timestamps.
    vacancies: Vec<Vacancy>,
    /// Reconnect-lease window; `None` = wait out the caller's timeout.
    lease: Option<Duration>,
    /// Set by [`NodeRegistry::close`]: parked leaders wake with an error
    /// and new registrations are refused (run cancellation).
    closed: bool,
}

/// Membership + completion tracking for one training run.
pub struct NodeRegistry {
    inner: OrderedMutex<RegistryInner>,
    cv: OrderedCondvar,
    /// `Some(n)`: node ids are bounded to `[0, n)` and at most `n`
    /// workers may hold a registration at once.
    capacity: Option<usize>,
}

impl Default for NodeRegistry {
    fn default() -> Self {
        NodeRegistry::new()
    }
}

impl NodeRegistry {
    /// Fresh unbounded registry (tests, ad-hoc servers).
    pub fn new() -> Self {
        NodeRegistry {
            inner: OrderedMutex::new(LockRank::Registry, RegistryInner::default()),
            cv: OrderedCondvar::new(),
            capacity: None,
        }
    }

    /// Registry for an `n`-node cluster: requested ids must be `< n`, and
    /// registration is refused once `n` workers hold live entries — a
    /// mis-launched `--node-id 7` fails fast at `HELLO` instead of
    /// satisfying the leader's membership count with a bogus node.
    pub fn with_capacity(n: usize) -> Self {
        NodeRegistry {
            inner: OrderedMutex::new(LockRank::Registry, RegistryInner::default()),
            cv: OrderedCondvar::new(),
            capacity: Some(n),
        }
    }

    /// Register a worker. `requested = Some(id)` claims a specific node
    /// index (rejected when already taken); `None` auto-assigns the
    /// smallest free index.
    pub fn register(&self, requested: Option<u32>, name: &str) -> Result<u32> {
        let mut g = self.inner.lock();
        if g.closed {
            bail!("registry closed (run cancelled or finished)");
        }
        if let Some(cap) = self.capacity {
            if let Some(id) = requested {
                if id as usize >= cap {
                    bail!("node id {id} out of range for a {cap}-node cluster");
                }
            } else if g.workers.len() >= cap {
                bail!("cluster is full ({cap} nodes registered)");
            }
        }
        let id = match requested {
            Some(id) => {
                if g.workers.iter().any(|w| w.info.id == id) {
                    bail!("node id {id} is already registered");
                }
                id
            }
            None => {
                let mut id = 0u32;
                while g.workers.iter().any(|w| w.info.id == id) {
                    id += 1;
                }
                id
            }
        };
        g.workers.push(WorkerEntry { info: NodeInfo { id, name: name.into() }, done: false });
        // A registration adopting a vacated id settles its reconnect lease.
        g.vacancies.retain(|v| v.info.id != id);
        drop(g);
        self.cv.notify_all();
        Ok(id)
    }

    /// Set the reconnect-lease window: how long a mid-run disconnect may
    /// stay vacant before [`NodeRegistry::wait_for_done`] gives up on the
    /// run. Unset, a dropped worker simply runs out the caller's timeout.
    pub fn set_lease(&self, lease: Duration) {
        self.inner.lock().lease = Some(lease);
        self.cv.notify_all();
    }

    /// Node ids currently vacated by mid-run disconnects (awaiting a
    /// replacement under the reconnect lease).
    pub fn vacancies(&self) -> Vec<NodeInfo> {
        self.inner.lock().vacancies.iter().map(|v| v.info.clone()).collect()
    }

    /// Record node `id`'s `DONE`. Duplicate DONEs are an error — the
    /// completion count must never run ahead of actual worker completion.
    pub fn mark_done(&self, id: u32) -> Result<()> {
        let mut g = self.inner.lock();
        let Some(w) = g.workers.iter_mut().find(|w| w.info.id == id) else {
            bail!("DONE from unregistered node {id}");
        };
        if w.done {
            bail!("duplicate DONE from node {id}");
        }
        w.done = true;
        drop(g);
        self.cv.notify_all();
        Ok(())
    }

    /// A worker's connection dropped. Unfinished workers are removed
    /// (their id becomes claimable by a restarted process) and a
    /// reconnect lease opens on the vacated id; finished ones stay on
    /// the roster.
    pub fn disconnect(&self, id: u32) {
        self.disconnect_with_tasks(id, Vec::new());
    }

    /// [`NodeRegistry::disconnect`], recording the `(chapter, layer)`
    /// task cells the worker held dispatcher leases on at the drop —
    /// [`NodeRegistry::wait_for_done`]'s lease-expiry error names them.
    pub fn disconnect_with_tasks(&self, id: u32, tasks: Vec<(u32, usize)>) {
        let mut g = self.inner.lock();
        if let Some(pos) = g.workers.iter().position(|w| w.info.id == id && !w.done) {
            let entry = g.workers.remove(pos);
            g.vacancies.push(Vacancy { info: entry.info, since: Instant::now(), tasks });
            drop(g);
            self.cv.notify_all();
        }
    }

    /// Clear every open vacancy. The elastic leader calls this after the
    /// dispatcher reports all tasks complete: a worker that dropped after
    /// its last task finished (but before its `DONE` landed) must not
    /// fail the run's final completion park.
    pub fn settle_vacancies(&self) {
        self.inner.lock().vacancies.clear();
        self.cv.notify_all();
    }

    /// Close the registry: parked [`NodeRegistry::wait_for_workers`] /
    /// [`NodeRegistry::wait_for_done`] callers wake with an error and new
    /// registrations are refused. Idempotent; `RunHandle::cancel` uses
    /// this to unpark a cluster leader promptly.
    pub fn close(&self) {
        self.inner.lock().closed = true;
        self.cv.notify_all();
    }

    /// Snapshot of the registered workers.
    pub fn workers(&self) -> Vec<NodeInfo> {
        self.inner.lock().workers.iter().map(|w| w.info.clone()).collect()
    }

    /// Registered-worker count.
    pub fn worker_count(&self) -> usize {
        self.inner.lock().workers.len()
    }

    /// Count of workers that reported `DONE`.
    pub fn done_count(&self) -> usize {
        self.inner.lock().workers.iter().filter(|w| w.done).count()
    }

    /// Park until at least `n` workers have registered.
    pub fn wait_for_workers(&self, n: usize, timeout: Duration) -> Result<Vec<NodeInfo>> {
        self.wait_until(timeout, &format!("{n} registered workers"), |g| {
            (g.workers.len() >= n).then(|| g.workers.iter().map(|w| w.info.clone()).collect())
        })
    }

    /// Park until at least `n` workers have reported `DONE`.
    ///
    /// Lease-aware: when a worker dropped mid-run and its vacated id was
    /// not adopted by a replacement within the reconnect lease
    /// ([`NodeRegistry::set_lease`]), this fails fast naming the dropped
    /// node — the leader does not sit out the full timeout for a node
    /// that provably is not coming back.
    pub fn wait_for_done(&self, n: usize, timeout: Duration) -> Result<()> {
        let mut guard = self.inner.lock();
        let deadline = Instant::now() + timeout;
        loop {
            if guard.closed {
                bail!("registry closed while waiting for {n} workers to finish");
            }
            if guard.workers.iter().filter(|w| w.done).count() >= n {
                return Ok(());
            }
            let now = Instant::now();
            if let Some(lease) = guard.lease {
                if let Some(v) =
                    guard.vacancies.iter().find(|v| now.duration_since(v.since) >= lease)
                {
                    let held = if v.tasks.is_empty() {
                        String::new()
                    } else {
                        let cells: Vec<String> =
                            v.tasks.iter().map(|(c, l)| format!("{c}/{l}")).collect();
                        format!(" while holding task lease(s) chapter/layer: {}", cells.join(", "))
                    };
                    bail!(
                        "node {} ({}) disconnected before DONE{} and no replacement adopted \
                         its id within the {:?} reconnect lease",
                        v.info.id,
                        v.info.name,
                        held,
                        lease
                    );
                }
            }
            if now >= deadline {
                bail!("registry: timed out after {timeout:?} waiting for {n} workers to finish");
            }
            // Wake at the earliest of the overall deadline and the next
            // lease expiry, so an expired lease is noticed promptly.
            let mut wake = deadline;
            if let Some(lease) = guard.lease {
                for v in &guard.vacancies {
                    wake = wake.min(v.since + lease);
                }
            }
            let dur = wake.saturating_duration_since(now).max(Duration::from_millis(1));
            let (g, _) = self.cv.wait_timeout(guard, dur);
            guard = g;
        }
    }

    fn wait_until<T>(
        &self,
        timeout: Duration,
        what: &str,
        mut probe: impl FnMut(&RegistryInner) -> Option<T>,
    ) -> Result<T> {
        let mut guard = self.inner.lock();
        let deadline = Instant::now() + timeout;
        loop {
            if guard.closed {
                bail!("registry closed while waiting for {what}");
            }
            if let Some(v) = probe(&guard) {
                return Ok(v);
            }
            let now = Instant::now();
            if now >= deadline {
                bail!("registry: timed out after {timeout:?} waiting for {what}");
            }
            let (g, _) = self.cv.wait_timeout(guard, deadline - now);
            guard = g;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn auto_assignment_fills_smallest_free_id() {
        let r = NodeRegistry::new();
        assert_eq!(r.register(None, "a").unwrap(), 0);
        assert_eq!(r.register(None, "b").unwrap(), 1);
        assert_eq!(r.register(Some(5), "c").unwrap(), 5);
        assert_eq!(r.register(None, "d").unwrap(), 2, "smallest free id, not max+1");
        assert_eq!(r.worker_count(), 4);
    }

    #[test]
    fn duplicate_requested_id_rejected() {
        let r = NodeRegistry::new();
        r.register(Some(0), "a").unwrap();
        let err = r.register(Some(0), "b").unwrap_err();
        assert!(err.to_string().contains("already registered"), "{err}");
    }

    #[test]
    fn capacity_bounds_ids_and_count() {
        let r = NodeRegistry::with_capacity(2);
        let err = r.register(Some(2), "oob").unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        r.register(None, "a").unwrap();
        r.register(None, "b").unwrap();
        let err = r.register(None, "c").unwrap_err();
        assert!(err.to_string().contains("full"), "{err}");
    }

    #[test]
    fn wait_for_workers_wakes_on_register() {
        let r = Arc::new(NodeRegistry::new());
        let r2 = r.clone();
        let h = std::thread::spawn(move || r2.wait_for_workers(2, Duration::from_secs(5)));
        r.register(None, "a").unwrap();
        r.register(None, "b").unwrap();
        let workers = h.join().unwrap().unwrap();
        assert_eq!(workers.len(), 2);
    }

    #[test]
    fn done_tracking_rejects_duplicates_and_times_out() {
        let r = NodeRegistry::new();
        let id = r.register(None, "a").unwrap();
        assert!(r.mark_done(99).is_err());
        r.mark_done(id).unwrap();
        assert_eq!(r.done_count(), 1);
        let err = r.mark_done(id).unwrap_err();
        assert!(err.to_string().contains("duplicate DONE"), "{err}");
        r.wait_for_done(1, Duration::from_millis(10)).unwrap();
        let err = r.wait_for_done(2, Duration::from_millis(20)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn close_unparks_waiters_and_refuses_registration() {
        let r = Arc::new(NodeRegistry::new());
        let r2 = r.clone();
        let h = std::thread::spawn(move || r2.wait_for_workers(1, Duration::from_secs(60)));
        let t0 = std::time::Instant::now();
        // Give the waiter a moment to park, then close under it.
        while !h.is_finished() && t0.elapsed() < Duration::from_millis(50) {
            std::thread::yield_now();
        }
        r.close();
        let err = h.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("closed"), "{err}");
        let err = r.register(None, "late").unwrap_err();
        assert!(err.to_string().contains("closed"), "{err}");
    }

    #[test]
    fn expired_lease_fails_wait_for_done_fast_and_names_the_node() {
        let r = NodeRegistry::with_capacity(2);
        r.set_lease(Duration::from_millis(30));
        r.register(Some(0), "survivor").unwrap();
        r.register(Some(1), "crasher").unwrap();
        r.mark_done(0).unwrap();
        r.disconnect(1);
        assert_eq!(r.vacancies().len(), 1);
        let t0 = Instant::now();
        let err = r.wait_for_done(2, Duration::from_secs(60)).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(10), "lease expiry must act early");
        let msg = err.to_string();
        assert!(msg.contains("node 1") && msg.contains("crasher"), "{msg}");
        assert!(msg.contains("lease"), "{msg}");
    }

    #[test]
    fn expired_lease_names_orphaned_task_cells() {
        let r = NodeRegistry::with_capacity(2);
        r.set_lease(Duration::from_millis(20));
        r.register(Some(0), "survivor").unwrap();
        r.register(Some(1), "crasher").unwrap();
        r.mark_done(0).unwrap();
        r.disconnect_with_tasks(1, vec![(3, 1), (4, 0)]);
        let msg = r.wait_for_done(2, Duration::from_secs(60)).unwrap_err().to_string();
        assert!(msg.contains("node 1") && msg.contains("crasher"), "{msg}");
        assert!(msg.contains("task lease"), "{msg}");
        assert!(msg.contains("3/1") && msg.contains("4/0"), "{msg}");
    }

    #[test]
    fn settle_vacancies_clears_open_leases() {
        let r = NodeRegistry::with_capacity(2);
        r.set_lease(Duration::from_millis(1));
        r.register(Some(0), "a").unwrap();
        r.register(Some(1), "b").unwrap();
        r.mark_done(0).unwrap();
        // Pre-done disconnect opens a vacancy whose 1ms lease would fail
        // the park below; park on the Condvar until the lease provably
        // expired (no sleep-based timing), then settle it.
        r.disconnect_with_tasks(1, vec![(0, 0)]);
        let err = r.wait_for_done(2, Duration::from_secs(60)).unwrap_err();
        assert!(err.to_string().contains("reconnect lease"), "{err}");
        r.settle_vacancies();
        assert!(r.vacancies().is_empty());
        r.wait_for_done(1, Duration::from_millis(50)).unwrap();
    }

    #[test]
    fn replacement_adoption_settles_the_lease() {
        let r = Arc::new(NodeRegistry::with_capacity(2));
        r.set_lease(Duration::from_secs(60));
        r.register(Some(0), "a").unwrap();
        r.register(Some(1), "doomed").unwrap();
        r.mark_done(0).unwrap();
        r.disconnect(1);

        let r2 = r.clone();
        let h = std::thread::spawn(move || r2.wait_for_done(2, Duration::from_secs(30)));
        // Replacement adopts the vacated id: the lease settles and the
        // leader's park completes once the replacement reports DONE.
        r.register(Some(1), "replacement").unwrap();
        assert!(r.vacancies().is_empty(), "adoption must clear the vacancy");
        r.mark_done(1).unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn disconnect_frees_unfinished_ids_only() {
        let r = NodeRegistry::with_capacity(2);
        r.register(Some(0), "crashes").unwrap();
        r.register(Some(1), "finishes").unwrap();
        r.mark_done(1).unwrap();

        // Crash before DONE: the id is reclaimable by a restart.
        r.disconnect(0);
        assert_eq!(r.worker_count(), 1);
        assert_eq!(r.register(Some(0), "restarted").unwrap(), 0);

        // Disconnect after DONE: the roster (and the done count) survive.
        r.disconnect(1);
        assert_eq!(r.done_count(), 1);
        assert_eq!(r.worker_count(), 2);
    }
}
