//! All-Layers PFF (§4.2, Algorithm 2, Figure 5) — also Sequential (N=1)
//! and Federated (sharded data).
//!
//! Chapter `c` homes on node `c mod N`; the task for `(c, l)` fetches
//! layer `l` as published at the *previous* chapter (the pipeline
//! predecessor), trains it for `C = E/S` epochs on the chapter's
//! activations, publishes, and forwards the activations for `(c, l+1)`.
//! Under AdaptiveNEG the labels for chapter `c ≥ N` are derived from the
//! network as published at the home's previous chapter `c − N` (the
//! paper's §5.2 note on why All-Layers suits AdaptiveNEG), encoded as an
//! extra graph edge `(c−N, L−1) → (c, 0)`.
//!
//! Task bodies are *hermetic*: everything a task consumes comes from the
//! store or the per-worker [`TaskScratch`] caches (which only ever hold
//! bit-exact copies of published state), so a task computes identical
//! weights no matter which worker runs it. Chapter progress events are
//! emitted by the dispatcher; the bodies only account spans and publish.
//!
//! [`TaskScratch`]: crate::coordinator::node::TaskScratch

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::node::{FfActCache, NodeCtx, PoActCache};
use crate::coordinator::schedulers::{head_slot, CLS_HEAD_SLOT};
use crate::coordinator::store::ParamStore;
use crate::coordinator::taskgraph::{Task, TaskGraph};
use crate::ff::classifier::head_features;
use crate::ff::{ClassifierMode, FFLayer, FFNetwork, LinearHead, NegStrategy};
use crate::metrics::SpanKind;
use crate::tensor::Matrix;

/// The All-Layers / Sequential / Federated dependency graph: the pipeline
/// lattice with `home(c, l) = c mod N`, plus — under AdaptiveNEG — the
/// label-production edges `(c−N, L−1) → (c, 0)` (chapter `c`'s negatives
/// are derived from the network as published at the home's previous
/// chapter).
pub fn graph(cfg: &ExperimentConfig, shard_data: bool) -> Result<TaskGraph> {
    let n = cfg.nodes.max(1);
    let mut b = TaskGraph::pipeline(cfg, shard_data, |c, _| c as usize % n);
    if !cfg.perfopt && cfg.neg == NegStrategy::Adaptive {
        let last = cfg.num_layers() - 1;
        for c in n as u32..cfg.splits {
            b.edge((c - n as u32, last), (c, 0))?;
        }
    }
    b.build()
}

/// Everything a whole-network chapter publishes (every layer, the PerfOpt
/// heads, and — in inline-Softmax mode — the classifier head) is already
/// in `store`. This is the chapter-granular resume probe for the
/// Sequential / All-Layers / Federated mappings.
pub fn chapter_complete(
    store: &dyn ParamStore,
    cfg: &ExperimentConfig,
    chapter: u32,
) -> Result<bool> {
    for l in 0..cfg.num_layers() {
        if !store.has_layer(l, chapter)? {
            return Ok(false);
        }
        if cfg.perfopt && !store.has_layer(head_slot(l), chapter)? {
            return Ok(false);
        }
    }
    if !cfg.perfopt
        && cfg.head_inline
        && cfg.classifier == ClassifierMode::Softmax
        && !store.has_head(chapter)?
    {
        return Ok(false);
    }
    Ok(true)
}

/// Everything `task` publishes is already in `store` — the per-cell
/// resume probe (layer, PerfOpt head slot, and — on the last layer in
/// inline-Softmax mode — the classifier head).
pub fn task_done(store: &dyn ParamStore, cfg: &ExperimentConfig, task: Task) -> Result<bool> {
    let (c, l) = (task.chapter, task.layer);
    if !store.has_layer(l, c)? {
        return Ok(false);
    }
    if cfg.perfopt && !store.has_layer(head_slot(l), c)? {
        return Ok(false);
    }
    if l == cfg.num_layers() - 1
        && !cfg.perfopt
        && cfg.head_inline
        && cfg.classifier == ClassifierMode::Softmax
        && !store.has_head(c)?
    {
        return Ok(false);
    }
    Ok(true)
}

/// Execute one All-Layers `(chapter, layer)` task hermetically.
pub fn run_task(ctx: &mut NodeCtx, task: Task) -> Result<f32> {
    if ctx.cfg.perfopt {
        run_task_perfopt(ctx, task)
    } else {
        run_task_ff(ctx, task)
    }
}

fn run_task_ff(ctx: &mut NodeCtx, task: Task) -> Result<f32> {
    let chapter = task.chapter;
    let l = task.layer;
    let n_layers = ctx.cfg.num_layers();

    // --- chapter activations at layer l ------------------------------------
    // Consecutive same-chapter tasks on one worker reuse the forwarded
    // activations; otherwise rebuild from the store (bit-exact copies of
    // what the producing worker forwarded through).
    let hit = ctx
        .scratch
        .ff
        .as_ref()
        .is_some_and(|c| c.chapter == chapter && c.next_layer == l);
    let (x_pos, x_neg, below) = if hit {
        let c = ctx.scratch.ff.take().expect("checked above");
        (c.x_pos, c.x_neg, c.layers)
    } else {
        let neg_labels = neg_labels_for(ctx, chapter)?;
        rebuild_ff_inputs(ctx, chapter, l, &neg_labels)?
    };

    // --- own layer at the previous chapter ----------------------------------
    let (mut layer, shipped) = if chapter == 0 {
        (ctx.fresh_layer(l), None)
    } else {
        ctx.fetch_layer(l, chapter - 1)?.to_layer()
    };
    let mut opt = ctx.take_opt(l, shipped);
    let loss = ctx.train_ff_layer_chapter(&mut layer, &mut opt, l, chapter, &x_pos, &x_neg)?;
    ctx.publish_layer(l, chapter, &layer, Some(&opt))?;

    if l + 1 < n_layers {
        let (np, nn) = ctx.forward_pair(&layer, l, chapter, x_pos, x_neg)?;
        let mut layers = below;
        layers.push(layer);
        ctx.scratch.ff =
            Some(FfActCache { chapter, next_layer: l + 1, x_pos: np, x_neg: nn, layers });
    } else {
        ctx.scratch.ff = None;
        let mut layers = below;
        layers.push(layer);
        let net = FFNetwork { layers, classes: ctx.cfg.classes };
        // --- inline softmax-head stage (§5.3/§5.4 timing analysis) ---------
        if ctx.cfg.head_inline && ctx.cfg.classifier == ClassifierMode::Softmax {
            train_and_publish_head(ctx, chapter, &net)?;
        }
    }
    ctx.put_opt(l, opt);
    Ok(loss)
}

/// Negative labels for `chapter`, memoized per worker. AdaptiveNEG
/// derives them from the network as published at the home's previous
/// chapter `c − N` (chapters `c < N` are each home's first chapter and
/// fall back to the derived random labels) — bit-identical to the static
/// path's UpdateXNEG because published layers are exact copies and the
/// label sweep is deterministic in `(chapter, net, shard)`.
pub(crate) fn neg_labels_for(ctx: &mut NodeCtx, chapter: u32) -> Result<Vec<u8>> {
    if let Some(v) = ctx.scratch.neg.get(&chapter) {
        return Ok(v.clone());
    }
    let labels = match ctx.cfg.neg {
        NegStrategy::Adaptive => {
            let n = ctx.cfg.nodes.max(1) as u32;
            if chapter < n {
                ctx.derived_neg_labels(0)
            } else {
                let src = chapter - n;
                let n_layers = ctx.cfg.num_layers();
                let mut layers = Vec::with_capacity(n_layers);
                for l in 0..n_layers {
                    let (layer, _) = ctx.fetch_layer(l, src)?.to_layer();
                    layers.push(layer);
                }
                let net = FFNetwork { layers, classes: ctx.cfg.classes };
                ctx.local_neg_labels(chapter, Some(&net))?
            }
        }
        _ => ctx.local_neg_labels(chapter, None)?,
    };
    ctx.scratch.neg.insert(chapter, labels.clone());
    Ok(labels)
}

/// Cache-miss path of the chapter-activation reuse: overlay the inputs
/// and forward them through layers `0..layer` as published at THIS
/// chapter, returning the `(pos, neg)` activations and the forwarded-
/// through layers (for last-layer duties that need the whole network).
pub(crate) fn rebuild_ff_inputs(
    ctx: &mut NodeCtx,
    chapter: u32,
    layer: usize,
    neg_labels: &[u8],
) -> Result<(Matrix, Matrix, Vec<FFLayer>)> {
    let mut x_pos = ctx.positive_inputs();
    let mut x_neg = ctx.negative_inputs(neg_labels);
    let mut below = Vec::with_capacity(layer);
    for l in 0..layer {
        let (pl, _) = ctx.fetch_layer(l, chapter)?.to_layer();
        let (np, nn) = ctx.forward_pair(&pl, l, chapter, x_pos, x_neg)?;
        x_pos = np;
        x_neg = nn;
        below.push(pl);
    }
    Ok((x_pos, x_neg, below))
}

/// Train the full-network softmax head for one chapter and publish it.
/// Hermetic: the head comes from the store (previous chapter) or fresh,
/// its optimizer from the shared bank under [`CLS_HEAD_SLOT`].
pub(crate) fn train_and_publish_head(
    ctx: &mut NodeCtx,
    chapter: u32,
    net: &FFNetwork,
) -> Result<()> {
    let (mut head, shipped_opt) = if chapter == 0 {
        (ctx.fresh_full_head(), None)
    } else {
        let to = ctx.timeout();
        let store = ctx.store.clone();
        let params = ctx
            .rec
            .time(SpanKind::WaitLayer, usize::MAX, chapter, || store.get_head(chapter - 1, to))?;
        params.to_head()
    };
    let mut opt = ctx.take_opt_sized(CLS_HEAD_SLOT, shipped_opt, head.w.rows, head.w.cols);

    // Features on this home's data under the current network.
    let eng = ctx.engine.as_mut();
    let data_x = ctx.data.x.clone();
    let feats = ctx
        .rec
        .time(SpanKind::Forward, usize::MAX, chapter, || head_features(eng, net, &data_x))?;
    let labels = ctx.data.y.clone();
    ctx.train_head_chapter(&mut head, &mut opt, chapter, &feats, &labels)?;

    ctx.publish_head(chapter, &head, Some(&opt))?;
    ctx.put_opt(CLS_HEAD_SLOT, opt);
    Ok(())
}

/// Execute one PerfOpt (§4.4) task: neutral overlay, no negatives; the
/// layer trains jointly with its private head by local backprop. Shared
/// verbatim by All-Layers and Single-Layer — the body only depends on
/// the cell and the store, not on the home mapping.
pub(crate) fn run_task_perfopt(ctx: &mut NodeCtx, task: Task) -> Result<f32> {
    let chapter = task.chapter;
    let l = task.layer;
    let n_layers = ctx.cfg.num_layers();

    let x = po_inputs_at(ctx, chapter, l)?;

    let (mut layer, shipped) = if chapter == 0 {
        (ctx.fresh_layer(l), None)
    } else {
        ctx.fetch_layer(l, chapter - 1)?.to_layer()
    };
    let (mut head, head_shipped) = if chapter == 0 {
        (ctx.fresh_layer_head(l), None)
    } else {
        let (hl, opt) = ctx.fetch_layer(head_slot(l), chapter - 1)?.to_layer();
        (LinearHead { w: hl.w, b: hl.b }, opt)
    };
    let mut opt_layer = ctx.take_opt(l, shipped);
    let mut opt_head = ctx.take_opt_sized(head_slot(l), head_shipped, head.w.rows, head.w.cols);
    let labels = ctx.data.y.clone();
    let loss = ctx.train_perfopt_layer_chapter(
        &mut layer, &mut head, &mut opt_layer, &mut opt_head, l, chapter, &x, &labels,
    )?;
    ctx.publish_layer(l, chapter, &layer, Some(&opt_layer))?;
    // Publish the head through the layer namespace (normalize=false).
    let head_as_layer = FFLayer { w: head.w.clone(), b: head.b.clone(), normalize_input: false };
    ctx.publish_layer(head_slot(l), chapter, &head_as_layer, Some(&opt_head))?;

    if l + 1 < n_layers {
        let eng = ctx.engine.as_mut();
        let nx = ctx.rec.time(SpanKind::Forward, l, chapter, || eng.layer_forward(&layer, &x))?;
        ctx.scratch.po = Some(PoActCache { chapter, next_layer: l + 1, x: nx });
    } else {
        ctx.scratch.po = None;
    }
    ctx.put_opt(l, opt_layer);
    ctx.put_opt(head_slot(l), opt_head);
    Ok(loss)
}

/// PerfOpt activation reuse: the neutral overlay forwarded through layers
/// `0..layer` as published at THIS chapter (cache hit on consecutive
/// same-chapter tasks, store rebuild otherwise).
pub(crate) fn po_inputs_at(ctx: &mut NodeCtx, chapter: u32, layer: usize) -> Result<Matrix> {
    let hit = ctx
        .scratch
        .po
        .as_ref()
        .is_some_and(|c| c.chapter == chapter && c.next_layer == layer);
    if hit {
        return Ok(ctx.scratch.po.take().expect("checked above").x);
    }
    let mut x = ctx.neutral_inputs();
    for l in 0..layer {
        let (pl, _) = ctx.fetch_layer(l, chapter)?.to_layer();
        let eng = ctx.engine.as_mut();
        x = ctx.rec.time(SpanKind::Forward, l, chapter, || eng.layer_forward(&pl, &x))?;
    }
    Ok(x)
}
