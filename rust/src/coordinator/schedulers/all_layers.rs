//! All-Layers PFF (§4.2, Algorithm 2, Figure 5) — also Sequential (N=1)
//! and Federated (sharded data).
//!
//! Node *i* executes chapters `i, i+N, 2N+i, …`. Within a chapter it
//! trains every layer in order: fetch the layer as published at the
//! *previous* chapter (blocking on the pipeline predecessor), train it for
//! `C = E/S` epochs, publish, transform the data forward, move on. After
//! the chapter it refreshes its own negative labels (AdaptiveNEG computes
//! them locally with the just-trained network — the paper's §5.2 note on
//! why All-Layers beats Single-Layer for AdaptiveNEG).
//!
//! Progress surfaces as [`RunEvent`]s on `ctx.bus` (chapter start/finish
//! with the chapter's mean loss, plus per-publish wire accounting from
//! `NodeCtx::publish_layer`) — no printing in the library.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::events::RunEvent;
use crate::coordinator::node::NodeCtx;
use crate::coordinator::schedulers::head_slot;
use crate::coordinator::store::ParamStore;
use crate::ff::classifier::head_features;
use crate::ff::{ClassifierMode, FFNetwork, NegStrategy};
use crate::metrics::SpanKind;
use crate::tensor::AdamState;

/// Everything a whole-network chapter publishes (every layer, the PerfOpt
/// heads, and — in inline-Softmax mode — the classifier head) is already
/// in `store`. This is the resume/fast-forward probe for the
/// Sequential / All-Layers / Federated mappings.
pub fn chapter_complete(
    store: &dyn ParamStore,
    cfg: &ExperimentConfig,
    chapter: u32,
) -> Result<bool> {
    for l in 0..cfg.num_layers() {
        if !store.has_layer(l, chapter)? {
            return Ok(false);
        }
        if cfg.perfopt && !store.has_layer(head_slot(l), chapter)? {
            return Ok(false);
        }
    }
    if !cfg.perfopt
        && cfg.head_inline
        && cfg.classifier == ClassifierMode::Softmax
        && !store.has_head(chapter)?
    {
        return Ok(false);
    }
    Ok(true)
}

/// Run one All-Layers node to completion.
///
/// Resume-aware: before training, the node skips the longest prefix of
/// its chapter assignment whose outputs are already fully published
/// (rehydrated checkpoint, or surviving leader store after a worker
/// crash). Only this node ever publishes its assigned chapters, so the
/// probe cannot race other nodes' progress.
pub fn run_node(ctx: &mut NodeCtx) -> Result<()> {
    let n_nodes = ctx.cfg.nodes as u32;
    let splits = ctx.cfg.splits;
    let n_layers = ctx.cfg.num_layers();
    let my_chapters: Vec<u32> =
        (ctx.node_id as u32..splits).step_by(n_nodes as usize).collect();

    // --- resume fast-forward -----------------------------------------------
    let mut done = 0usize;
    for &c in &my_chapters {
        if !chapter_complete(ctx.store.as_ref(), &ctx.cfg, c)? {
            break;
        }
        done += 1;
    }

    // AdaptiveNEG labels for the node's next chapter, computed after each
    // finished chapter with the then-current network.
    let mut pending_adaptive: Option<Vec<u8>> = None;
    if done > 0 && !ctx.cfg.perfopt && ctx.cfg.neg == NegStrategy::Adaptive {
        if let (Some(&last), Some(&next)) = (my_chapters.get(done - 1), my_chapters.get(done)) {
            // Rebuild exactly the labels the interrupted run computed after
            // its last completed chapter: the network as published at that
            // chapter is in the store, and the label sweep is
            // bit-deterministic, so the resumed stream continues bitwise.
            let mut layers = Vec::with_capacity(n_layers);
            for l in 0..n_layers {
                let (layer, _) = ctx.fetch_layer(l, last)?.into_layer();
                layers.push(layer);
            }
            let net = FFNetwork { layers, classes: ctx.cfg.classes };
            pending_adaptive = Some(ctx.local_neg_labels(next, Some(&net))?);
        }
    }

    for &chapter in &my_chapters[done..] {
        ctx.ensure_live()?;
        ctx.emit(RunEvent::ChapterStarted { node: ctx.node_id, layer: None, chapter });
        let mark = ctx.rec.mark();
        let loss = if ctx.cfg.perfopt {
            run_chapter_perfopt(ctx, chapter, n_layers)?
        } else {
            run_chapter_ff(ctx, chapter, n_layers, &mut pending_adaptive)?
        };
        let (busy_s, wait_s) = ctx.rec.split_since(mark);
        ctx.emit(RunEvent::ChapterFinished {
            node: ctx.node_id,
            layer: None,
            chapter,
            loss,
            busy_s,
            wait_s,
        });
    }
    Ok(())
}

fn run_chapter_ff(
    ctx: &mut NodeCtx,
    chapter: u32,
    n_layers: usize,
    pending_adaptive: &mut Option<Vec<u8>>,
) -> Result<f32> {
    // --- negative labels for this chapter ---------------------------------
    let neg_labels = match ctx.cfg.neg {
        NegStrategy::Adaptive => {
            pending_adaptive.take().unwrap_or_else(|| ctx.derived_neg_labels(0))
        }
        _ => ctx.local_neg_labels(chapter, None)?,
    };

    let mut x_pos = ctx.positive_inputs();
    let mut x_neg = ctx.negative_inputs(&neg_labels);
    let mut trained: Vec<crate::ff::FFLayer> = Vec::with_capacity(n_layers);
    let mut last_loss = 0.0f32;

    for l in 0..n_layers {
        // Fetch the pipeline predecessor's version (or fresh at chapter 0).
        let (mut layer, shipped) = if chapter == 0 {
            (ctx.fresh_layer(l), None)
        } else {
            let params = ctx.fetch_layer(l, chapter - 1)?;
            let (layer, opt) = params.into_layer();
            (layer, opt)
        };
        let mut opt = ctx.take_opt(l, shipped);
        last_loss = ctx.train_ff_layer_chapter(&mut layer, &mut opt, l, chapter, &x_pos, &x_neg)?;
        ctx.publish_layer(l, chapter, &layer, Some(&opt))?;
        let (np, nn) = ctx.forward_pair(&layer, l, chapter, x_pos, x_neg)?;
        x_pos = np;
        x_neg = nn;
        ctx.put_opt(l, opt);
        trained.push(layer);
    }

    let net = FFNetwork { layers: trained, classes: ctx.cfg.classes };

    // --- inline softmax-head stage (§5.3/§5.4 timing analysis) ------------
    if ctx.cfg.head_inline && ctx.cfg.classifier == ClassifierMode::Softmax {
        train_and_publish_head(ctx, chapter, &net)?;
    }

    // --- UpdateXNEG: labels for this node's next chapter -------------------
    if ctx.cfg.neg == NegStrategy::Adaptive {
        let next = chapter + ctx.cfg.nodes as u32;
        if next < ctx.cfg.splits {
            *pending_adaptive = Some(ctx.local_neg_labels(next, Some(&net))?);
        }
    }
    Ok(last_loss)
}

fn run_chapter_perfopt(ctx: &mut NodeCtx, chapter: u32, n_layers: usize) -> Result<f32> {
    // PerfOpt (§4.4): neutral overlay, no negatives; each layer trains
    // jointly with its private head by local backprop.
    let mut x = ctx.neutral_inputs();
    let labels = ctx.data.y.clone();
    let mut last_loss = 0.0f32;

    for l in 0..n_layers {
        let (mut layer, shipped) = if chapter == 0 {
            (ctx.fresh_layer(l), None)
        } else {
            let params = ctx.fetch_layer(l, chapter - 1)?;
            let (layer, opt) = params.into_layer();
            (layer, opt)
        };
        let (mut head, head_shipped) = if chapter == 0 {
            (ctx.fresh_layer_head(l), None)
        } else {
            let params = ctx.fetch_layer(head_slot(l), chapter - 1)?;
            let (hl, opt) = params.into_layer();
            (crate::ff::LinearHead { w: hl.w, b: hl.b }, opt)
        };
        let mut opt_layer = ctx.take_opt(l, shipped);
        let mut opt_head = ctx.take_opt_sized(
            head_slot(l),
            head_shipped,
            head.w.rows,
            head.w.cols,
        );
        last_loss = ctx.train_perfopt_layer_chapter(
            &mut layer, &mut head, &mut opt_layer, &mut opt_head, l, chapter, &x, &labels,
        )?;
        ctx.publish_layer(l, chapter, &layer, Some(&opt_layer))?;
        // Publish the head through the layer namespace (normalize=false).
        let head_as_layer = crate::ff::FFLayer {
            w: head.w.clone(),
            b: head.b.clone(),
            normalize_input: false,
        };
        ctx.publish_layer(head_slot(l), chapter, &head_as_layer, Some(&opt_head))?;
        let eng = ctx.engine.as_mut();
        x = ctx.rec.time(SpanKind::Forward, l, chapter, || eng.layer_forward(&layer, &x))?;
        ctx.put_opt(l, opt_layer);
        ctx.put_opt(head_slot(l), opt_head);
    }
    Ok(last_loss)
}

/// Train the full-network softmax head for one chapter and publish it.
fn train_and_publish_head(ctx: &mut NodeCtx, chapter: u32, net: &FFNetwork) -> Result<()> {
    let (mut head, shipped_opt) = if chapter == 0 {
        (ctx.fresh_full_head(), None)
    } else {
        let to = ctx.timeout();
        let store = ctx.store.clone();
        let params = ctx
            .rec
            .time(SpanKind::WaitLayer, usize::MAX, chapter, || store.get_head(chapter - 1, to))?;
        params.into_head()
    };
    let mut opt = if ctx.cfg.ship_opt_state {
        shipped_opt.unwrap_or_else(|| AdamState::new(head.w.rows, head.w.cols))
    } else {
        ctx.head_opt.take().unwrap_or_else(|| AdamState::new(head.w.rows, head.w.cols))
    };

    // Features on this node's data under the current network.
    let eng = ctx.engine.as_mut();
    let data_x = ctx.data.x.clone();
    let feats = ctx
        .rec
        .time(SpanKind::Forward, usize::MAX, chapter, || head_features(eng, net, &data_x))?;
    let labels = ctx.data.y.clone();
    ctx.train_head_chapter(&mut head, &mut opt, chapter, &feats, &labels)?;

    ctx.publish_head(chapter, &head, Some(&opt))?;
    ctx.head_opt = Some(opt);
    Ok(())
}
