//! The PFF schedulers (§4) as graph builders over the `(chapter, layer)`
//! task lattice.
//!
//! | Scheduler | task homes | extra edges | neg-label flow |
//! |---|---|---|---|
//! | Sequential | every cell homes on node 0 (≡ original FF) | — | local |
//! | Single-Layer (§4.1) | `(c, l)` homes on node `l` | `(c−2, L−1) → (c, 0)` under AdaptiveNEG | last layer publishes |
//! | All-Layers (§4.2) | `(c, l)` homes on node `c mod N` | `(c−N, L−1) → (c, 0)` under AdaptiveNEG | each home computes its own |
//! | Federated (§4.3) | as All-Layers, over private shards | as All-Layers | local (per shard) |
//!
//! PerfOpt (§4.4) is orthogonal: the same graphs, with the FF two-pass
//! task body replaced by the local-BP (layer, head) CE step and no
//! negatives (and no Adaptive edges — there are no negatives to derive).
//!
//! Since the TaskGraph redesign a scheduler is two things: a
//! [`Scheduler::graph`] that emits the dependency graph of
//! `(chapter, layer)` work items, and a [`Scheduler::run_task`] that
//! executes one of those items hermetically (fetching everything it needs
//! from the store / per-worker caches, publishing everything it produces).
//! The dispatcher ([`crate::coordinator::dispatch`]) drains the graph with
//! any number of workers; [`SchedulePlan`] survives as a *derived*,
//! read-only rendering for harnesses and `sim::gantt`.
//!
//! Each strategy registers a factory in the [`SchedulerRegistry`] under a
//! canonical name. The [`crate::config::Scheduler`] enum is a parse-level
//! alias: the coordinator resolves `cfg.scheduler.key()` through the
//! registry (see [`for_config`]), so adding a scheduler means registering
//! a factory — from `main.rs`, a bench or a test — not editing a `match`
//! in the coordinator core. Custom schedulers reach a run via
//! `Experiment::builder().scheduler(..)` / `.scheduler_named(..)`.
//!
//! # Migrating a custom scheduler (pre-TaskGraph → TaskGraph)
//!
//! Custom schedulers registered via `.scheduler_named(..)` implement
//! `graph()` + `run_task()` instead of `plan()` + `run_node()`:
//!
//! - `plan()` → `graph()`: return a [`crate::coordinator::TaskGraph`].
//!   For the common shapes, start from
//!   `TaskGraph::pipeline(cfg, shard_data, home_of)` (the §4.1/§4.2
//!   lattice), add any extra edges, then `.build()`. A derived
//!   `SchedulePlan` is synthesized automatically from the homes.
//! - `run_node()` (a whole node's script) → `run_task()` (one
//!   `(chapter, layer)` cell). The task body must be *hermetic*: fetch
//!   predecessor layers through `ctx` / the store rather than assuming
//!   earlier state lives in local variables, and key persistent optimizer
//!   state through `ctx.take_opt*` / `ctx.put_opt` (backed by the shared
//!   [`crate::coordinator::node::OptBank`], keyed by the task's *home* so
//!   moments survive the task landing on any worker).

pub mod all_layers;
pub mod single_layer;

use std::collections::HashMap;
use std::sync::{Arc, OnceLock};

use anyhow::{bail, Result};

use crate::config::{ExperimentConfig, Scheduler as SchedulerKind};
use crate::coordinator::node::NodeCtx;
use crate::coordinator::store::ParamStore;
use crate::coordinator::taskgraph::{Task, TaskGraph};
use crate::sync::{LockRank, OrderedMutex};

/// Store "layer index" namespace for PerfOpt per-layer heads: head of FF
/// layer `l` is published under slot `HEAD_SLOT_BASE + l`. Keeps the store
/// API minimal while giving per-(layer, chapter) head versioning.
pub const HEAD_SLOT_BASE: usize = 1_000_000;

/// Store slot for the PerfOpt head of layer `l`.
pub fn head_slot(l: usize) -> usize {
    HEAD_SLOT_BASE + l
}

/// [`crate::coordinator::node::OptBank`] slot for the full-network softmax
/// classifier head (inline-head training). Distinct from every FF layer
/// slot and every PerfOpt [`head_slot`].
pub const CLS_HEAD_SLOT: usize = usize::MAX;

/// A scheduler's node→work mapping rendered as the static assignment
/// tables the paper draws — since the TaskGraph redesign a *derived*,
/// read-only view (see [`SchedulePlan::from_graph`]) consumed by
/// harnesses, dashboards and `sim::gantt`. The coordinator itself
/// schedules from the graph.
#[derive(Clone, Debug)]
pub struct SchedulePlan {
    /// Scheduler name (matches [`Scheduler::name`]).
    pub scheduler: String,
    /// Number of nodes the plan spans.
    pub nodes: usize,
    /// Chapters node `i` executes, in order.
    pub chapters: Vec<Vec<u32>>,
    /// Layers node `i` trains within one of its chapters.
    pub layers: Vec<Vec<usize>>,
    /// Whether each node trains on a private shard (Federated) instead of
    /// the full dataset.
    pub shard_data: bool,
}

impl SchedulePlan {
    /// Render a [`TaskGraph`] as per-home assignment tables: node `i`'s
    /// chapters/layers are the distinct chapters/layers among the tasks
    /// homed on `i`, sorted ascending.
    pub fn from_graph(name: &str, g: &TaskGraph) -> Self {
        let n = g.nodes();
        let mut chapters: Vec<Vec<u32>> = vec![Vec::new(); n];
        let mut layers: Vec<Vec<usize>> = vec![Vec::new(); n];
        for t in g.tasks() {
            chapters[t.home].push(t.chapter);
            layers[t.home].push(t.layer);
        }
        for v in &mut chapters {
            v.sort_unstable();
            v.dedup();
        }
        for v in &mut layers {
            v.sort_unstable();
            v.dedup();
        }
        SchedulePlan {
            scheduler: name.into(),
            nodes: n,
            chapters,
            layers,
            shard_data: g.shard_data(),
        }
    }

    /// Round-robin whole-network plan (Sequential / All-Layers /
    /// Federated): node `i` runs chapters `i, i+N, …`, training every
    /// layer. Reusable by custom schedulers with the same shape.
    pub fn round_robin(name: &str, cfg: &ExperimentConfig, shard_data: bool) -> Self {
        let n = cfg.nodes.max(1);
        let all_layers: Vec<usize> = (0..cfg.num_layers()).collect();
        SchedulePlan {
            scheduler: name.into(),
            nodes: n,
            chapters: (0..n)
                .map(|i| (i as u32..cfg.splits).step_by(n).collect())
                .collect(),
            layers: vec![all_layers; n],
            shard_data,
        }
    }

    /// Layer-ownership plan (Single-Layer): node `i` owns layer `i` and
    /// runs every chapter on it.
    pub fn layer_owner(name: &str, cfg: &ExperimentConfig) -> Self {
        let n = cfg.nodes.max(1);
        SchedulePlan {
            scheduler: name.into(),
            nodes: n,
            chapters: vec![(0..cfg.splits).collect(); n],
            layers: (0..n).map(|i| vec![i]).collect(),
            shard_data: false,
        }
    }

    /// Total chapter executions across all nodes.
    pub fn total_chapters(&self) -> usize {
        self.chapters.iter().map(Vec::len).sum()
    }
}

/// One PFF scheduling strategy: the dependency graph of a run plus the
/// hermetic body of one `(chapter, layer)` task.
///
/// Object-safe by design — the coordinator, the CLI and the cluster
/// worker all drive `Arc<dyn Scheduler>`, and new strategies plug in
/// through the [`SchedulerRegistry`] without touching the coordinator.
/// Implementations compose the chapter primitives on [`NodeCtx`]
/// (fetch/train/publish/forward) and emit progress on `ctx.bus`.
pub trait Scheduler: Send + Sync {
    /// Canonical (registry) name, e.g. `"all-layers"`.
    fn name(&self) -> &str;

    /// The dependency graph this scheduler will execute for `cfg`: one
    /// task per `(chapter, layer)` cell, edges encoding every
    /// publish-before-consume constraint the task bodies rely on.
    fn graph(&self, cfg: &ExperimentConfig) -> Result<TaskGraph>;

    /// The node→work mapping as static assignment tables — derived from
    /// [`Scheduler::graph`] by default; only override to customize the
    /// rendering.
    fn plan(&self, cfg: &ExperimentConfig) -> Result<SchedulePlan> {
        Ok(SchedulePlan::from_graph(self.name(), &self.graph(cfg)?))
    }

    /// Execute one task hermetically on the calling worker: fetch
    /// predecessors (store / `ctx.scratch`), train, publish, and return
    /// the task's mean loss. `ctx.node_id` is the task's *home* when this
    /// is called, so `ctx.take_opt*`/`ctx.put_opt` and data sharding see
    /// exactly the static plan's per-node state.
    fn run_task(&self, ctx: &mut NodeCtx, task: Task) -> Result<f32>;

    /// Whether everything `task` publishes is already in `store` — the
    /// per-cell resume/fast-forward probe. The resume scan walks the
    /// graph in dependency order and pre-completes the longest fully
    /// published prefix using this. The conservative default answers
    /// `false` ("never skip"), so custom schedulers that don't implement
    /// it redo work instead of losing it.
    fn task_done(
        &self,
        _store: &dyn ParamStore,
        _cfg: &ExperimentConfig,
        _task: Task,
    ) -> Result<bool> {
        Ok(false)
    }

    /// Whether everything node `node` publishes for `chapter` is already
    /// in `store` — the chapter-granular probe checkpoint cursors use.
    /// Same conservative default as [`Scheduler::task_done`].
    fn chapter_complete(
        &self,
        _store: &dyn ParamStore,
        _cfg: &ExperimentConfig,
        _node: usize,
        _chapter: u32,
    ) -> Result<bool> {
        Ok(false)
    }
}

/// Sequential FF (§5.2 baseline): one node, every chapter in order —
/// All-Layers with N = 1 (identical dependency structure, no partner).
pub struct Sequential;

impl Scheduler for Sequential {
    fn name(&self) -> &str {
        "sequential"
    }
    fn graph(&self, cfg: &ExperimentConfig) -> Result<TaskGraph> {
        all_layers::graph(cfg, false)
    }
    fn run_task(&self, ctx: &mut NodeCtx, task: Task) -> Result<f32> {
        all_layers::run_task(ctx, task)
    }
    fn task_done(
        &self,
        store: &dyn ParamStore,
        cfg: &ExperimentConfig,
        task: Task,
    ) -> Result<bool> {
        all_layers::task_done(store, cfg, task)
    }
    fn chapter_complete(
        &self,
        store: &dyn ParamStore,
        cfg: &ExperimentConfig,
        _node: usize,
        chapter: u32,
    ) -> Result<bool> {
        all_layers::chapter_complete(store, cfg, chapter)
    }
}

/// Single-Layer PFF (§4.1): node *i* permanently owns layer *i*.
pub struct SingleLayer;

impl Scheduler for SingleLayer {
    fn name(&self) -> &str {
        "single-layer"
    }
    fn graph(&self, cfg: &ExperimentConfig) -> Result<TaskGraph> {
        single_layer::graph(cfg)
    }
    fn run_task(&self, ctx: &mut NodeCtx, task: Task) -> Result<f32> {
        single_layer::run_task(ctx, task)
    }
    fn task_done(
        &self,
        store: &dyn ParamStore,
        cfg: &ExperimentConfig,
        task: Task,
    ) -> Result<bool> {
        single_layer::task_done(store, cfg, task)
    }
    fn chapter_complete(
        &self,
        store: &dyn ParamStore,
        cfg: &ExperimentConfig,
        node: usize,
        chapter: u32,
    ) -> Result<bool> {
        single_layer::chapter_complete(store, cfg, node, chapter)
    }
}

/// All-Layers PFF (§4.2): rotating whole-network pipeline.
pub struct AllLayers;

impl Scheduler for AllLayers {
    fn name(&self) -> &str {
        "all-layers"
    }
    fn graph(&self, cfg: &ExperimentConfig) -> Result<TaskGraph> {
        all_layers::graph(cfg, false)
    }
    fn run_task(&self, ctx: &mut NodeCtx, task: Task) -> Result<f32> {
        all_layers::run_task(ctx, task)
    }
    fn task_done(
        &self,
        store: &dyn ParamStore,
        cfg: &ExperimentConfig,
        task: Task,
    ) -> Result<bool> {
        all_layers::task_done(store, cfg, task)
    }
    fn chapter_complete(
        &self,
        store: &dyn ParamStore,
        cfg: &ExperimentConfig,
        _node: usize,
        chapter: u32,
    ) -> Result<bool> {
        all_layers::chapter_complete(store, cfg, chapter)
    }
}

/// Federated PFF (§4.3): All-Layers over per-node private data shards —
/// the only difference is data placement (`shard_data`).
pub struct Federated;

impl Scheduler for Federated {
    fn name(&self) -> &str {
        "federated"
    }
    fn graph(&self, cfg: &ExperimentConfig) -> Result<TaskGraph> {
        all_layers::graph(cfg, true)
    }
    fn run_task(&self, ctx: &mut NodeCtx, task: Task) -> Result<f32> {
        all_layers::run_task(ctx, task)
    }
    fn task_done(
        &self,
        store: &dyn ParamStore,
        cfg: &ExperimentConfig,
        task: Task,
    ) -> Result<bool> {
        all_layers::task_done(store, cfg, task)
    }
    fn chapter_complete(
        &self,
        store: &dyn ParamStore,
        cfg: &ExperimentConfig,
        _node: usize,
        chapter: u32,
    ) -> Result<bool> {
        all_layers::chapter_complete(store, cfg, chapter)
    }
}

type SchedulerFactory = Box<dyn Fn() -> Arc<dyn Scheduler> + Send + Sync>;

/// Name → factory registry of scheduling strategies.
///
/// The process-wide [`SchedulerRegistry::global`] instance is pre-seeded
/// with the paper's four strategies; anything with access to the crate
/// (binaries, benches, tests) can [`SchedulerRegistry::register`] more and
/// select them via `Experiment::builder().scheduler_named(..)`.
pub struct SchedulerRegistry {
    inner: OrderedMutex<HashMap<String, SchedulerFactory>>,
}

impl Default for SchedulerRegistry {
    fn default() -> Self {
        SchedulerRegistry { inner: OrderedMutex::new(LockRank::SchedRegistry, HashMap::new()) }
    }
}

impl SchedulerRegistry {
    /// Fresh empty registry (tests; production code uses [`global`]).
    ///
    /// [`global`]: SchedulerRegistry::global
    pub fn new() -> Self {
        SchedulerRegistry::default()
    }

    /// The process-wide registry, seeded with the four built-ins.
    pub fn global() -> &'static SchedulerRegistry {
        static GLOBAL: OnceLock<SchedulerRegistry> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let r = SchedulerRegistry::new();
            r.register(SchedulerKind::Sequential.key(), || Arc::new(Sequential));
            r.register(SchedulerKind::SingleLayer.key(), || Arc::new(SingleLayer));
            r.register(SchedulerKind::AllLayers.key(), || Arc::new(AllLayers));
            r.register(SchedulerKind::Federated.key(), || Arc::new(Federated));
            r
        })
    }

    /// Register (or replace) a factory under `name` (case-insensitive).
    pub fn register<F>(&self, name: &str, factory: F)
    where
        F: Fn() -> Arc<dyn Scheduler> + Send + Sync + 'static,
    {
        self.inner.lock().insert(name.to_ascii_lowercase(), Box::new(factory));
    }

    /// Construct the scheduler registered under `name`. An exact
    /// (case-insensitive) registration always wins; only unregistered
    /// names fall back to the parse-level aliases of the built-ins
    /// (`"seq"`, `"all"`, …) via [`crate::config::Scheduler`]'s parser —
    /// so registering a custom scheduler under an alias is honored, not
    /// silently shadowed by the enum.
    pub fn resolve(&self, name: &str) -> Result<Arc<dyn Scheduler>> {
        let g = self.inner.lock();
        if let Some(f) = g.get(&name.to_ascii_lowercase()) {
            return Ok(f());
        }
        if let Ok(kind) = name.parse::<SchedulerKind>() {
            if let Some(f) = g.get(kind.key()) {
                return Ok(f());
            }
        }
        let mut known: Vec<&str> = g.keys().map(String::as_str).collect();
        known.sort_unstable();
        bail!("unknown scheduler '{name}' (known names: {})", known.join(", "))
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.inner.lock().keys().cloned().collect();
        v.sort_unstable();
        v
    }
}

/// Resolve the scheduler a configuration names, through the global
/// registry — the parse-level enum's single exit into runtime behavior.
pub fn for_config(cfg: &ExperimentConfig) -> Result<Arc<dyn Scheduler>> {
    SchedulerRegistry::global().resolve(cfg.scheduler.key())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_registry_resolves_builtins_and_aliases() {
        let reg = SchedulerRegistry::global();
        for name in ["sequential", "single-layer", "all-layers", "federated", "seq", "all"] {
            reg.resolve(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        }
        assert_eq!(reg.resolve("all_layers").unwrap().name(), "all-layers");
        let err = reg.resolve("no-such-strategy").unwrap_err();
        assert!(err.to_string().contains("known names:"), "{err}");
    }

    #[test]
    fn for_config_follows_the_enum() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.scheduler = SchedulerKind::SingleLayer;
        assert_eq!(for_config(&cfg).unwrap().name(), "single-layer");
    }

    #[test]
    fn round_robin_plan_partitions_chapters() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.scheduler = SchedulerKind::AllLayers;
        cfg.nodes = 2;
        let cfg = cfg.validated().unwrap();
        let plan = AllLayers.plan(&cfg).unwrap();
        assert_eq!(plan.nodes, 2);
        assert_eq!(plan.chapters[0], vec![0, 2, 4, 6]);
        assert_eq!(plan.chapters[1], vec![1, 3, 5, 7]);
        assert_eq!(plan.total_chapters() as u32, cfg.splits);
        assert_eq!(plan.layers[0], vec![0, 1, 2]);
        assert!(!plan.shard_data);
        assert!(Federated.plan(&cfg).unwrap().shard_data);
    }

    #[test]
    fn layer_owner_plan_pins_layers() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.scheduler = SchedulerKind::SingleLayer;
        cfg.nodes = 3;
        let cfg = cfg.validated().unwrap();
        let plan = SingleLayer.plan(&cfg).unwrap();
        assert_eq!(plan.layers, vec![vec![0], vec![1], vec![2]]);
        assert!(plan.chapters.iter().all(|c| c.len() == cfg.splits as usize));
    }

    #[test]
    fn derived_plan_matches_legacy_static_shapes() {
        let mut cfg = ExperimentConfig::tiny();
        cfg.nodes = 2;
        let cfg = cfg.validated().unwrap();
        let derived = AllLayers.plan(&cfg).unwrap();
        let legacy = SchedulePlan::round_robin("all-layers", &cfg, false);
        assert_eq!(derived.chapters, legacy.chapters);
        assert_eq!(derived.layers, legacy.layers);
        let mut cfg = ExperimentConfig::tiny();
        cfg.nodes = 3;
        let cfg = cfg.validated().unwrap();
        let derived = SingleLayer.plan(&cfg).unwrap();
        let legacy = SchedulePlan::layer_owner("single-layer", &cfg);
        assert_eq!(derived.layers, legacy.layers);
        assert_eq!(derived.chapters, legacy.chapters);
    }

    #[test]
    fn local_registry_is_isolated() {
        let reg = SchedulerRegistry::new();
        assert!(reg.resolve("sequential").is_err());
        reg.register("MyCustom", || Arc::new(Sequential));
        assert_eq!(reg.resolve("mycustom").unwrap().name(), "sequential");
        assert_eq!(reg.names(), vec!["mycustom".to_string()]);
    }
}
