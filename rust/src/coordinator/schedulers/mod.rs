//! The PFF schedulers (§4): what each node does, in terms of the
//! primitives in [`crate::coordinator::node`].
//!
//! | Scheduler | node→work mapping | neg-label flow |
//! |---|---|---|
//! | Sequential | 1 node runs every chapter (≡ original FF) | local |
//! | Single-Layer (§4.1) | node *i* owns layer *i*, every chapter | last node publishes (AdaptiveNEG) |
//! | All-Layers (§4.2) | node *i* runs chapters `i, i+N, …` whole-network | each node computes its own |
//! | Federated (§4.3) | All-Layers over private data shards | local (per shard) |
//!
//! PerfOpt (§4.4) is orthogonal: the same mappings, with the FF two-pass
//! step replaced by the local-BP (layer, head) CE step and no negatives.

pub mod all_layers;
pub mod single_layer;

use anyhow::Result;

use crate::config::Scheduler;
use crate::coordinator::node::NodeCtx;

/// Store "layer index" namespace for PerfOpt per-layer heads: head of FF
/// layer `l` is published under slot `HEAD_SLOT_BASE + l`. Keeps the store
/// API minimal while giving per-(layer, chapter) head versioning.
pub const HEAD_SLOT_BASE: usize = 1_000_000;

/// Store slot for the PerfOpt head of layer `l`.
pub fn head_slot(l: usize) -> usize {
    HEAD_SLOT_BASE + l
}

/// Run one node's script for the configured scheduler. Blocks until the
/// node has finished all its chapters.
pub fn run_node(ctx: &mut NodeCtx) -> Result<()> {
    match ctx.cfg.scheduler {
        // Sequential is All-Layers with N = 1 — identical dependency
        // structure, no pipeline partner. Federated differs from
        // All-Layers only in the data each ctx carries (leader shards it).
        Scheduler::Sequential | Scheduler::AllLayers | Scheduler::Federated => {
            all_layers::run_node(ctx)
        }
        Scheduler::SingleLayer => single_layer::run_node(ctx),
    }
}
