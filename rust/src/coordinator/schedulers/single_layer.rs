//! Single-Layer PFF (§4.1, Algorithm 1, Figure 4).
//!
//! Node *i* permanently owns layer *i*. Every chapter it re-fetches layers
//! `0..i` as published *this chapter* by its predecessors, forwards the
//! dataset through them, trains its own layer for `C` epochs and
//! publishes. The last node additionally produces the AdaptiveNEG labels
//! for the next chapter ("the last node generates and publishes the
//! generated labels", §5.2) and — in Softmax mode — trains the classifier
//! head as an extra pipeline stage (§5.4's "only adds a small delay").
//!
//! Progress surfaces as [`RunEvent`]s on `ctx.bus` with `layer` set to the
//! node's owned layer.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::events::RunEvent;
use crate::coordinator::node::NodeCtx;
use crate::coordinator::schedulers::head_slot;
use crate::coordinator::store::ParamStore;
use crate::ff::classifier::head_features;
use crate::ff::{ClassifierMode, FFLayer, FFNetwork, LinearHead, NegStrategy};
use crate::metrics::SpanKind;
use crate::tensor::AdamState;

/// Everything node `node` (owner of layer `node`) publishes for `chapter`
/// is already in `store` — the Single-Layer resume/fast-forward probe.
/// The last node also publishes the AdaptiveNEG labels (two chapters
/// ahead) and, in inline-Softmax mode, the classifier head.
pub fn chapter_complete(
    store: &dyn ParamStore,
    cfg: &ExperimentConfig,
    node: usize,
    chapter: u32,
) -> Result<bool> {
    let my_layer = node;
    if !store.has_layer(my_layer, chapter)? {
        return Ok(false);
    }
    if cfg.perfopt && !store.has_layer(head_slot(my_layer), chapter)? {
        return Ok(false);
    }
    if my_layer == cfg.num_layers() - 1 && !cfg.perfopt {
        if cfg.neg == NegStrategy::Adaptive
            && chapter + 2 < cfg.splits
            && !store.has_neg(chapter + 2)?
        {
            return Ok(false);
        }
        if cfg.head_inline && cfg.classifier == ClassifierMode::Softmax && !store.has_head(chapter)?
        {
            return Ok(false);
        }
    }
    Ok(true)
}

/// Run one Single-Layer node (owning layer `ctx.node_id`) to completion.
///
/// Resume-aware: the node skips chapters whose outputs it already finds
/// published (rehydrated checkpoint / surviving leader store) and
/// rehydrates its working state — the owned layer, its PerfOpt head and,
/// on the last node, the classifier head — from the last completed
/// chapter's published version. Adam moments come back exactly when
/// `ship_opt_state` is on (making resume bitwise); otherwise they restart
/// from the published weights.
pub fn run_node(ctx: &mut NodeCtx) -> Result<()> {
    let my_layer = ctx.node_id;
    let n_layers = ctx.cfg.num_layers();
    let is_last = my_layer == n_layers - 1;
    let splits = ctx.cfg.splits;

    let mut layer = ctx.fresh_layer(my_layer);
    let mut opt = AdamState::new(ctx.cfg.dims[my_layer], ctx.cfg.dims[my_layer + 1]);

    // PerfOpt: this node also owns layer my_layer's head.
    let mut po_head = if ctx.cfg.perfopt { Some(ctx.fresh_layer_head(my_layer)) } else { None };
    let mut po_head_opt = po_head
        .as_ref()
        .map(|h| AdamState::new(h.w.rows, h.w.cols));

    // Last node in Softmax mode owns the classifier head.
    let mut cls_head: Option<LinearHead> = None;
    let mut cls_opt: Option<AdamState> = None;

    // --- resume fast-forward -----------------------------------------------
    let mut start = 0u32;
    while start < splits
        && chapter_complete(ctx.store.as_ref(), &ctx.cfg, my_layer, start)?
    {
        start += 1;
    }
    if start > 0 {
        let last = start - 1;
        let (l2, shipped) = ctx.fetch_layer(my_layer, last)?.into_layer();
        layer = l2;
        if ctx.cfg.ship_opt_state {
            if let Some(s) = shipped {
                opt = s;
            }
        }
        if let Some(h) = po_head.as_mut() {
            let (hl, hopt) = ctx.fetch_layer(head_slot(my_layer), last)?.into_layer();
            *h = LinearHead { w: hl.w, b: hl.b };
            if ctx.cfg.ship_opt_state {
                if let Some(s) = hopt {
                    po_head_opt = Some(s);
                }
            }
        }
        if is_last
            && !ctx.cfg.perfopt
            && ctx.cfg.head_inline
            && ctx.cfg.classifier == ClassifierMode::Softmax
        {
            let store = ctx.store.clone();
            let to = ctx.timeout();
            let (h, hopt) = store.get_head(last, to)?.into_head();
            cls_head = Some(h);
            cls_opt = if ctx.cfg.ship_opt_state { hopt } else { None };
        }
    }

    for chapter in start..splits {
        ctx.ensure_live()?;
        ctx.emit(RunEvent::ChapterStarted { node: ctx.node_id, layer: Some(my_layer), chapter });
        let mark = ctx.rec.mark();
        let loss = if ctx.cfg.perfopt {
            run_chapter_perfopt(
                ctx,
                chapter,
                my_layer,
                &mut layer,
                &mut opt,
                po_head.as_mut().unwrap(),
                po_head_opt.as_mut().unwrap(),
            )?
        } else {
            run_chapter_ff(
                ctx,
                chapter,
                my_layer,
                is_last,
                &mut layer,
                &mut opt,
                &mut cls_head,
                &mut cls_opt,
            )?
        };
        let (busy_s, wait_s) = ctx.rec.split_since(mark);
        ctx.emit(RunEvent::ChapterFinished {
            node: ctx.node_id,
            layer: Some(my_layer),
            chapter,
            loss,
            busy_s,
            wait_s,
        });
    }
    Ok(())
}

#[allow(clippy::too_many_arguments)]
fn run_chapter_ff(
    ctx: &mut NodeCtx,
    chapter: u32,
    my_layer: usize,
    is_last: bool,
    layer: &mut FFLayer,
    opt: &mut AdamState,
    cls_head: &mut Option<LinearHead>,
    cls_opt: &mut Option<AdamState>,
) -> Result<f32> {
    // --- negative labels ---------------------------------------------------
    // AdaptiveNEG: published by the last node with a TWO-chapter lag
    // (labels for chapter c are generated after chapter c-2 finishes).
    // Waiting on chapter c-1's labels would serialize the entire
    // wavefront — the §5.2 bottleneck; the lag keeps the pipeline full at
    // the cost of one chapter of staleness. Chapters 0-1 fall back to the
    // derived random labels (every node derives identically).
    let neg_labels = match ctx.cfg.neg {
        NegStrategy::Adaptive if chapter > 1 => {
            let store = ctx.store.clone();
            let to = ctx.timeout();
            ctx.rec
                .time(SpanKind::WaitNeg, usize::MAX, chapter, || store.get_neg(chapter, to))?
        }
        NegStrategy::Adaptive => ctx.derived_neg_labels(0),
        _ => ctx.local_neg_labels(chapter, None)?,
    };

    let mut x_pos = ctx.positive_inputs();
    let mut x_neg = ctx.negative_inputs(&neg_labels);

    // --- fetch predecessors at THIS chapter and forward --------------------
    let mut fetched: Vec<FFLayer> = Vec::with_capacity(my_layer);
    for l in 0..my_layer {
        let params = ctx.fetch_layer(l, chapter)?;
        let (pl, _) = params.into_layer();
        let (np, nn) = ctx.forward_pair(&pl, l, chapter, x_pos, x_neg)?;
        x_pos = np;
        x_neg = nn;
        fetched.push(pl);
    }

    // --- train + publish own layer -----------------------------------------
    let loss = ctx.train_ff_layer_chapter(layer, opt, my_layer, chapter, &x_pos, &x_neg)?;
    ctx.publish_layer(my_layer, chapter, layer, Some(opt))?;

    // --- last-node duties ----------------------------------------------------
    if is_last {
        let mut layers = fetched;
        layers.push(layer.clone());
        let net = FFNetwork { layers, classes: ctx.cfg.classes };

        if ctx.cfg.neg == NegStrategy::Adaptive && chapter + 2 < ctx.cfg.splits {
            let labels = ctx.local_neg_labels(chapter + 2, Some(&net))?;
            let store = ctx.store.clone();
            ctx.rec.time(SpanKind::Publish, usize::MAX, chapter, || {
                store.put_neg(chapter + 2, labels)
            })?;
        }

        if ctx.cfg.head_inline && ctx.cfg.classifier == ClassifierMode::Softmax {
            let head = cls_head.get_or_insert_with(|| ctx.fresh_full_head());
            let opt_h = cls_opt
                .get_or_insert_with(|| AdamState::new(head.w.rows, head.w.cols));
            let eng = ctx.engine.as_mut();
            let data_x = ctx.data.x.clone();
            let feats = ctx.rec.time(SpanKind::Forward, usize::MAX, chapter, || {
                head_features(eng, &net, &data_x)
            })?;
            let labels = ctx.data.y.clone();
            // NOTE: can't call ctx.train_head_chapter with head borrowed
            // from cls_head (both need ctx fields) — take/put instead.
            let mut head_owned = head.clone();
            let mut opt_owned = opt_h.clone();
            ctx.train_head_chapter(&mut head_owned, &mut opt_owned, chapter, &feats, &labels)?;
            ctx.publish_head(chapter, &head_owned, Some(&opt_owned))?;
            *cls_head = Some(head_owned);
            *cls_opt = Some(opt_owned);
        }
    }
    Ok(loss)
}

#[allow(clippy::too_many_arguments)]
fn run_chapter_perfopt(
    ctx: &mut NodeCtx,
    chapter: u32,
    my_layer: usize,
    layer: &mut FFLayer,
    opt: &mut AdamState,
    head: &mut LinearHead,
    head_opt: &mut AdamState,
) -> Result<f32> {
    let mut x = ctx.neutral_inputs();
    for l in 0..my_layer {
        let params = ctx.fetch_layer(l, chapter)?;
        let (pl, _) = params.into_layer();
        let eng = ctx.engine.as_mut();
        x = ctx.rec.time(SpanKind::Forward, l, chapter, || eng.layer_forward(&pl, &x))?;
    }
    let labels = ctx.data.y.clone();
    let loss = ctx
        .train_perfopt_layer_chapter(layer, head, opt, head_opt, my_layer, chapter, &x, &labels)?;
    ctx.publish_layer(my_layer, chapter, layer, Some(opt))?;
    let head_as_layer =
        FFLayer { w: head.w.clone(), b: head.b.clone(), normalize_input: false };
    ctx.publish_layer(head_slot(my_layer), chapter, &head_as_layer, Some(head_opt))?;
    Ok(loss)
}
