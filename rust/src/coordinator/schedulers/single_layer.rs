//! Single-Layer PFF (§4.1, Algorithm 1, Figure 4).
//!
//! The task for `(c, l)` homes on node `l` — the layer's permanent owner
//! in the paper's static mapping. It re-fetches layers `0..l` as
//! published *this chapter* by its predecessors, forwards the dataset
//! through them, trains the owned layer for `C` epochs and publishes.
//! The last layer's task additionally produces the AdaptiveNEG labels
//! two chapters ahead ("the last node generates and publishes the
//! generated labels", §5.2) — an extra graph edge `(c−2, L−1) → (c, 0)`
//! — and, in Softmax mode, trains the classifier head as an extra
//! pipeline stage (§5.4's "only adds a small delay").
//!
//! Task bodies are hermetic (store + per-worker caches only), so the
//! dispatcher may run them on any worker; optimizer moments persist in
//! the shared `OptBank` under the task's home.

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::coordinator::node::{FfActCache, NodeCtx};
use crate::coordinator::schedulers::{all_layers, head_slot};
use crate::coordinator::store::ParamStore;
use crate::coordinator::taskgraph::{Task, TaskGraph};
use crate::ff::{ClassifierMode, FFNetwork, NegStrategy};
use crate::metrics::SpanKind;

/// The Single-Layer dependency graph: the pipeline lattice with
/// `home(c, l) = l`, plus — under AdaptiveNEG — the label-production
/// edges `(c−2, L−1) → (c, 0)` (the last layer's task at chapter `c−2`
/// publishes the labels chapter `c` consumes; the two-chapter lag keeps
/// the wavefront full, §5.2).
pub fn graph(cfg: &ExperimentConfig) -> Result<TaskGraph> {
    let mut b = TaskGraph::pipeline(cfg, false, |_, l| l);
    if !cfg.perfopt && cfg.neg == NegStrategy::Adaptive {
        let last = cfg.num_layers() - 1;
        for c in 2..cfg.splits {
            b.edge((c - 2, last), (c, 0))?;
        }
    }
    b.build()
}

/// Everything node `node` (owner of layer `node`) publishes for `chapter`
/// is already in `store` — the Single-Layer chapter-granular resume
/// probe. The last node also publishes the AdaptiveNEG labels (two
/// chapters ahead) and, in inline-Softmax mode, the classifier head.
pub fn chapter_complete(
    store: &dyn ParamStore,
    cfg: &ExperimentConfig,
    node: usize,
    chapter: u32,
) -> Result<bool> {
    let my_layer = node;
    if !store.has_layer(my_layer, chapter)? {
        return Ok(false);
    }
    if cfg.perfopt && !store.has_layer(head_slot(my_layer), chapter)? {
        return Ok(false);
    }
    if my_layer == cfg.num_layers() - 1 && !cfg.perfopt {
        if cfg.neg == NegStrategy::Adaptive
            && chapter + 2 < cfg.splits
            && !store.has_neg(chapter + 2)?
        {
            return Ok(false);
        }
        if cfg.head_inline && cfg.classifier == ClassifierMode::Softmax && !store.has_head(chapter)?
        {
            return Ok(false);
        }
    }
    Ok(true)
}

/// Everything `task` publishes is already in `store` — the per-cell
/// resume probe (same duties as [`chapter_complete`], one cell at a
/// time).
pub fn task_done(store: &dyn ParamStore, cfg: &ExperimentConfig, task: Task) -> Result<bool> {
    let (c, l) = (task.chapter, task.layer);
    if !store.has_layer(l, c)? {
        return Ok(false);
    }
    if cfg.perfopt && !store.has_layer(head_slot(l), c)? {
        return Ok(false);
    }
    if l == cfg.num_layers() - 1 && !cfg.perfopt {
        if cfg.neg == NegStrategy::Adaptive && c + 2 < cfg.splits && !store.has_neg(c + 2)? {
            return Ok(false);
        }
        if cfg.head_inline && cfg.classifier == ClassifierMode::Softmax && !store.has_head(c)? {
            return Ok(false);
        }
    }
    Ok(true)
}

/// Execute one Single-Layer `(chapter, layer)` task hermetically.
pub fn run_task(ctx: &mut NodeCtx, task: Task) -> Result<f32> {
    if ctx.cfg.perfopt {
        // PerfOpt bodies are mapping-independent — share All-Layers'.
        return all_layers::run_task_perfopt(ctx, task);
    }
    let chapter = task.chapter;
    let my_layer = task.layer;
    let n_layers = ctx.cfg.num_layers();
    let is_last = my_layer == n_layers - 1;

    // --- chapter activations at the owned layer -----------------------------
    let hit = ctx
        .scratch
        .ff
        .as_ref()
        .is_some_and(|c| c.chapter == chapter && c.next_layer == my_layer);
    let (x_pos, x_neg, below) = if hit {
        let c = ctx.scratch.ff.take().expect("checked above");
        (c.x_pos, c.x_neg, c.layers)
    } else {
        let neg_labels = neg_labels_for(ctx, chapter)?;
        all_layers::rebuild_ff_inputs(ctx, chapter, my_layer, &neg_labels)?
    };

    // --- own layer at the previous chapter ----------------------------------
    let (mut layer, shipped) = if chapter == 0 {
        (ctx.fresh_layer(my_layer), None)
    } else {
        ctx.fetch_layer(my_layer, chapter - 1)?.to_layer()
    };
    let mut opt = ctx.take_opt(my_layer, shipped);
    let loss = ctx.train_ff_layer_chapter(&mut layer, &mut opt, my_layer, chapter, &x_pos, &x_neg)?;
    ctx.publish_layer(my_layer, chapter, &layer, Some(&opt))?;

    if is_last {
        ctx.scratch.ff = None;
        let mut layers = below;
        layers.push(layer.clone());
        let net = FFNetwork { layers, classes: ctx.cfg.classes };

        if ctx.cfg.neg == NegStrategy::Adaptive && chapter + 2 < ctx.cfg.splits {
            let labels = ctx.local_neg_labels(chapter + 2, Some(&net))?;
            let store = ctx.store.clone();
            ctx.rec.time(SpanKind::Publish, usize::MAX, chapter, || {
                store.put_neg(chapter + 2, labels)
            })?;
        }

        if ctx.cfg.head_inline && ctx.cfg.classifier == ClassifierMode::Softmax {
            all_layers::train_and_publish_head(ctx, chapter, &net)?;
        }
    } else {
        let (np, nn) = ctx.forward_pair(&layer, my_layer, chapter, x_pos, x_neg)?;
        let mut layers = below;
        layers.push(layer);
        ctx.scratch.ff =
            Some(FfActCache { chapter, next_layer: my_layer + 1, x_pos: np, x_neg: nn, layers });
    }
    ctx.put_opt(my_layer, opt);
    Ok(loss)
}

/// Negative labels for `chapter`, memoized per worker. AdaptiveNEG:
/// published by the last layer's task with a TWO-chapter lag (labels for
/// chapter `c` are generated after chapter `c−2` finishes). Waiting on
/// chapter `c−1`'s labels would serialize the entire wavefront — the
/// §5.2 bottleneck; the lag keeps the pipeline full at the cost of one
/// chapter of staleness. Chapters 0-1 fall back to the derived random
/// labels (every home derives identically).
fn neg_labels_for(ctx: &mut NodeCtx, chapter: u32) -> Result<Vec<u8>> {
    if let Some(v) = ctx.scratch.neg.get(&chapter) {
        return Ok(v.clone());
    }
    let labels = match ctx.cfg.neg {
        NegStrategy::Adaptive if chapter > 1 => {
            let store = ctx.store.clone();
            let to = ctx.timeout();
            ctx.rec
                .time(SpanKind::WaitNeg, usize::MAX, chapter, || store.get_neg(chapter, to))?
        }
        NegStrategy::Adaptive => ctx.derived_neg_labels(0),
        _ => ctx.local_neg_labels(chapter, None)?,
    };
    ctx.scratch.neg.insert(chapter, labels.clone());
    Ok(labels)
}
