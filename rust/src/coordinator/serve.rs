//! Batched inference serving: the admission queue behind `pff serve`.
//!
//! A [`BatchServer`] keeps a [`TrainedModel`] resident next to a
//! dedicated engine thread and coalesces concurrent classify requests
//! into engine-sized batches: a flush happens when the queue holds
//! [`ServeOptions::max_batch`] rows **or** the oldest queued request has
//! waited [`ServeOptions::max_delay`], whichever comes first. Each flush
//! concatenates the queued feature rows into one tall matrix and scores
//! every label overlay through the existing
//! [`predict_goodness`](crate::ff::predict_goodness) path — the same
//! per-row bit-deterministic kernel offline `pff eval` uses, which is
//! what lets the serve-smoke CI job demand bitwise equality between
//! served and offline predictions.
//!
//! Completion is callback-based: [`BatchServer::submit`] hands the queue
//! a feature matrix plus a `FnOnce(Result<Vec<u8>>)` invoked (outside
//! every lock) with the predicted labels. The TCP layer captures its
//! connection writer in that callback, so a parked request costs no
//! thread; in-process callers use [`BatchServer::classify_blocking`].
//!
//! Progress is observable as a [`ServeEvent`] stream on a
//! [`Bus<ServeEvent>`] — the same replay/observer machinery as the
//! training [`RunEvent`](crate::coordinator::RunEvent) bus.
//!
//! Locking: the queue lock is [`LockRank::Serve`] — above the store
//! (rehydration happens before the server starts; the batcher holds no
//! store lock) and below the event bus, so emitting from either side of
//! the queue is rank-clean.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::events::Bus;
use crate::coordinator::eval::TrainedModel;
use crate::engine::EngineFactory;
use crate::ff::predict_goodness;
use crate::sync::{LockRank, OrderedCondvar, OrderedMutex};
use crate::tensor::Matrix;

/// Batching knobs for a [`BatchServer`].
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Flush as soon as the queue holds this many feature rows. A single
    /// request larger than this still ships alone (requests are never
    /// split across batches — a reply is one request's rows exactly).
    pub max_batch: usize,
    /// Flush when the oldest queued request has waited this long, even
    /// if the batch is not full. This bounds p99 latency at low load;
    /// raising it trades latency for larger (more efficient) batches.
    pub max_delay: Duration,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { max_batch: 32, max_delay: Duration::from_micros(500) }
    }
}

/// One typed progress event from a running [`BatchServer`].
#[derive(Clone, Debug)]
pub enum ServeEvent {
    /// A request entered the queue.
    Enqueued {
        /// Rows in the request.
        rows: usize,
        /// Requests in the queue after admission (queue depth).
        queue_requests: usize,
        /// Feature rows in the queue after admission.
        queue_rows: usize,
    },
    /// The batcher drained the queue head into one engine batch.
    BatchFlushed {
        /// Whole requests coalesced into the batch.
        requests: usize,
        /// Total feature rows scored.
        rows: usize,
        /// Queue wait of the oldest request in the batch, microseconds.
        oldest_wait_us: u64,
    },
    /// One request completed (its slice of a flushed batch).
    RequestDone {
        /// Rows in the request.
        rows: usize,
        /// Enqueue-to-reply latency, microseconds.
        latency_us: u64,
    },
    /// A flushed batch failed in the engine; every member request got
    /// the error.
    BatchFailed {
        /// Requests that received the error.
        requests: usize,
        /// The engine error, stringified.
        error: String,
    },
    /// The server shut down; queued-but-unflushed requests were failed.
    ShutDown {
        /// Requests failed by the shutdown drain.
        dropped: usize,
    },
}

impl std::fmt::Display for ServeEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeEvent::Enqueued { rows, queue_requests, queue_rows } => {
                write!(f, "enqueued {rows} row(s) (queue: {queue_requests} req / {queue_rows} rows)")
            }
            ServeEvent::BatchFlushed { requests, rows, oldest_wait_us } => {
                write!(f, "flushed {requests} req / {rows} rows (oldest waited {oldest_wait_us} us)")
            }
            ServeEvent::RequestDone { rows, latency_us } => {
                write!(f, "request done: {rows} row(s) in {latency_us} us")
            }
            ServeEvent::BatchFailed { requests, error } => {
                write!(f, "batch failed for {requests} req: {error}")
            }
            ServeEvent::ShutDown { dropped } => {
                write!(f, "serve queue shut down ({dropped} queued request(s) dropped)")
            }
        }
    }
}

/// One queued classify request.
struct PendingReq {
    x: Matrix,
    done: Box<dyn FnOnce(Result<Vec<u8>>) + Send>,
    t_enq: Instant,
}

struct QueueInner {
    pending: VecDeque<PendingReq>,
    /// Total feature rows across `pending` (the flush trigger).
    queued_rows: usize,
    /// `Some(reason)` once the server stops admitting requests.
    closed: Option<String>,
}

/// The admission queue + resident-model batcher behind `pff serve`.
/// Cheap to share (`Arc`); see the module docs for semantics.
pub struct BatchServer {
    inner: OrderedMutex<QueueInner>,
    cv: OrderedCondvar,
    events: Bus<ServeEvent>,
    opts: ServeOptions,
    /// Input dim the model expects (`layers[0].w` rows); requests with
    /// any other width are rejected at admission.
    in_dim: usize,
    batcher: OrderedMutex<Option<JoinHandle<()>>>,
}

impl BatchServer {
    /// Start the batcher thread around `model`. The engine is built from
    /// `factory` *on* the batcher thread (engines are per-thread); a
    /// factory failure closes the queue with the error as the reason, so
    /// later submits fail fast instead of hanging.
    pub fn start(
        model: TrainedModel,
        factory: EngineFactory,
        opts: ServeOptions,
    ) -> Result<Arc<BatchServer>> {
        if opts.max_batch == 0 {
            bail!("--max-batch must be at least 1");
        }
        let Some(first) = model.net.layers.first() else {
            bail!("cannot serve an empty network");
        };
        let in_dim = first.w.rows;
        if in_dim < model.net.classes {
            bail!(
                "model input dim {in_dim} is smaller than its class count {} — \
                 goodness overlays need the first {} input dims",
                model.net.classes,
                model.net.classes
            );
        }
        let srv = Arc::new(BatchServer {
            inner: OrderedMutex::new(
                LockRank::Serve,
                QueueInner { pending: VecDeque::new(), queued_rows: 0, closed: None },
            ),
            cv: OrderedCondvar::new(),
            events: Bus::new(),
            opts,
            in_dim,
            batcher: OrderedMutex::new(LockRank::Serve, None),
        });
        let srv2 = srv.clone();
        let handle = std::thread::Builder::new()
            .name("pff-serve-batcher".into())
            .spawn(move || srv2.batcher_loop(model, factory))
            .map_err(|e| anyhow!("failed to spawn the serve batcher: {e}"))?;
        *srv.batcher.lock() = Some(handle);
        Ok(srv)
    }

    /// The server's [`ServeEvent`] bus (observe, subscribe or snapshot).
    pub fn events(&self) -> &Bus<ServeEvent> {
        &self.events
    }

    /// The batching knobs this server runs with.
    pub fn options(&self) -> ServeOptions {
        self.opts
    }

    /// Queue `x` (one feature row per prediction) and return immediately;
    /// `done` runs with the predicted labels — one per row, in row order —
    /// once the containing batch is scored. On `Err` the request was never
    /// admitted and `done` was **not** (and will never be) invoked.
    pub fn submit(
        &self,
        x: Matrix,
        done: impl FnOnce(Result<Vec<u8>>) + Send + 'static,
    ) -> Result<()> {
        if x.rows == 0 {
            bail!("classify request has no rows");
        }
        if x.cols != self.in_dim {
            bail!(
                "classify request has {} feature column(s) but the served model \
                 expects {}",
                x.cols,
                self.in_dim
            );
        }
        let (queue_requests, queue_rows, rows) = {
            let mut g = self.inner.lock();
            if let Some(reason) = &g.closed {
                bail!("serve queue is closed: {reason}");
            }
            let rows = x.rows;
            g.queued_rows += rows;
            g.pending.push_back(PendingReq {
                x,
                done: Box::new(done),
                t_enq: Instant::now(),
            });
            (g.pending.len(), g.queued_rows, rows)
        };
        self.cv.notify_all();
        self.events.emit(ServeEvent::Enqueued { rows, queue_requests, queue_rows });
        Ok(())
    }

    /// Convenience wrapper for in-process callers (tests, benches): queue
    /// `x` and block until its labels arrive.
    pub fn classify_blocking(&self, x: Matrix) -> Result<Vec<u8>> {
        let (tx, rx) = mpsc::channel();
        self.submit(x, move |r| {
            let _ = tx.send(r);
        })?;
        rx.recv().map_err(|_| anyhow!("serve queue dropped the request reply"))?
    }

    /// Stop admitting requests, fail everything still queued with a clean
    /// error, and join the batcher thread. Idempotent. Must not be called
    /// from inside a completion callback (it would join its own thread).
    pub fn shutdown(&self) {
        let drained = {
            let mut g = self.inner.lock();
            if g.closed.is_some() {
                None
            } else {
                g.closed = Some("server shut down".into());
                g.queued_rows = 0;
                Some(std::mem::take(&mut g.pending))
            }
        };
        self.cv.notify_all();
        if let Some(drained) = drained {
            let dropped = drained.len();
            for req in drained {
                (req.done)(Err(anyhow!(
                    "serve queue shut down before the request was scored"
                )));
            }
            self.events.emit(ServeEvent::ShutDown { dropped });
        }
        let handle = self.batcher.lock().take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }

    /// Close the queue with `reason` and fail everything queued (engine
    /// startup failure path — runs on the batcher thread itself).
    fn close_with(&self, reason: String) {
        let drained = {
            let mut g = self.inner.lock();
            if g.closed.is_none() {
                g.closed = Some(reason);
            }
            g.queued_rows = 0;
            std::mem::take(&mut g.pending)
        };
        let dropped = drained.len();
        for req in drained {
            (req.done)(Err(anyhow!("serve queue closed before the request was scored")));
        }
        if dropped > 0 {
            self.events.emit(ServeEvent::ShutDown { dropped });
        }
    }

    /// The batcher thread: park until the flush condition holds, drain
    /// whole requests into one tall matrix, score it, slice the labels
    /// back per request. Compute and callbacks run with no lock held.
    fn batcher_loop(&self, model: TrainedModel, factory: EngineFactory) {
        let mut eng = match factory() {
            Ok(e) => e,
            Err(e) => {
                self.close_with(format!("serve engine failed to start: {e}"));
                return;
            }
        };
        loop {
            let batch = {
                let mut g = self.inner.lock();
                loop {
                    if g.closed.is_some() {
                        // shutdown() already drained and failed the queue
                        return;
                    }
                    let Some(oldest) = g.pending.front() else {
                        g = self.cv.wait(g);
                        continue;
                    };
                    let waited = oldest.t_enq.elapsed();
                    if g.queued_rows >= self.opts.max_batch || waited >= self.opts.max_delay {
                        break;
                    }
                    let (g2, _) = self.cv.wait_timeout(g, self.opts.max_delay - waited);
                    g = g2;
                }
                // Drain whole requests while the batch stays under
                // max_batch rows; an oversized request still goes alone.
                let mut batch: Vec<PendingReq> = Vec::new();
                let mut rows = 0usize;
                while let Some(front) = g.pending.front() {
                    if !batch.is_empty() && rows + front.x.rows > self.opts.max_batch {
                        break;
                    }
                    rows += front.x.rows;
                    let req = g.pending.pop_front().expect("front just observed");
                    g.queued_rows -= req.x.rows;
                    batch.push(req);
                }
                batch
            };
            let rows: usize = batch.iter().map(|r| r.x.rows).sum();
            let oldest_wait_us = batch
                .first()
                .map(|r| r.t_enq.elapsed().as_micros() as u64)
                .unwrap_or(0);
            let mut data = Vec::with_capacity(rows * self.in_dim);
            for req in &batch {
                data.extend_from_slice(&req.x.data);
            }
            let x = Matrix { rows, cols: self.in_dim, data };
            // goodness_scores stacks all class overlays into one tall
            // batch and scores each row independently — served labels are
            // bitwise the offline-eval labels for the same rows.
            let result = predict_goodness(eng.as_mut(), &model.net, &x);
            self.events.emit(ServeEvent::BatchFlushed {
                requests: batch.len(),
                rows,
                oldest_wait_us,
            });
            match result {
                Ok(labels) => {
                    let mut off = 0usize;
                    for req in batch {
                        let n = req.x.rows;
                        let slice = labels[off..off + n].to_vec();
                        off += n;
                        let latency_us = req.t_enq.elapsed().as_micros() as u64;
                        (req.done)(Ok(slice));
                        self.events.emit(ServeEvent::RequestDone { rows: n, latency_us });
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    let requests = batch.len();
                    for req in batch {
                        (req.done)(Err(anyhow!("batch scoring failed: {msg}")));
                    }
                    self.events.emit(ServeEvent::BatchFailed { requests, error: msg });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native_factory;
    use crate::ff::FFNetwork;
    use crate::tensor::Rng;

    fn tiny_model() -> TrainedModel {
        let mut rng = Rng::new(7);
        TrainedModel {
            net: FFNetwork::new(&[8, 16, 16], 4, &mut rng),
            head: None,
            layer_heads: Vec::new(),
        }
    }

    fn rows(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::rand_uniform(n, 8, 0.0, 1.0, &mut rng)
    }

    #[test]
    fn serves_bitwise_offline_predictions() {
        let model = tiny_model();
        let x = rows(6, 11);
        let mut eng = native_factory()().unwrap();
        let offline = predict_goodness(eng.as_mut(), &model.net, &x).unwrap();
        let srv = BatchServer::start(
            model,
            native_factory(),
            ServeOptions { max_batch: 4, max_delay: Duration::from_millis(5) },
        )
        .unwrap();
        let served = srv.classify_blocking(x).unwrap();
        assert_eq!(served, offline, "served labels must match offline eval bitwise");
        srv.shutdown();
    }

    #[test]
    fn rejects_bad_width_and_empty_requests() {
        let srv =
            BatchServer::start(tiny_model(), native_factory(), ServeOptions::default()).unwrap();
        assert!(srv.submit(Matrix::zeros(0, 8), |_| {}).is_err(), "zero rows");
        let err = srv.submit(Matrix::zeros(1, 5), |_| {}).unwrap_err().to_string();
        assert!(err.contains("expects 8"), "{err}");
        srv.shutdown();
    }

    #[test]
    fn oversized_request_ships_alone() {
        let srv = BatchServer::start(
            tiny_model(),
            native_factory(),
            ServeOptions { max_batch: 2, max_delay: Duration::from_secs(5) },
        )
        .unwrap();
        // 5 rows > max_batch=2: still one reply with 5 labels.
        let labels = srv.classify_blocking(rows(5, 3)).unwrap();
        assert_eq!(labels.len(), 5);
        let flushed = srv
            .events()
            .history()
            .iter()
            .any(|ev| matches!(ev, ServeEvent::BatchFlushed { requests: 1, rows: 5, .. }));
        assert!(flushed, "oversized request must flush as one batch");
        srv.shutdown();
    }

    #[test]
    fn shutdown_is_idempotent_and_closes_submits() {
        let srv =
            BatchServer::start(tiny_model(), native_factory(), ServeOptions::default()).unwrap();
        srv.shutdown();
        srv.shutdown();
        let err = srv.submit(rows(1, 1), |_| {}).unwrap_err().to_string();
        assert!(err.contains("closed"), "{err}");
    }
}
