//! Chapter-versioned parameter store — the synchronization backbone of all
//! PFF schedulers.
//!
//! The paper's pseudo-code talks in `PublishLayer(chapter, layer)` /
//! `getLayer(layer, chapter)` pairs. This module gives those operations a
//! concrete home: an append-only map from `(layer, chapter)` to parameters
//! with *blocking* reads — `get_layer(l, c)` parks until some node has
//! published that exact version. The blocking read IS the pipeline
//! dependency: Single-Layer PFF's node `i` blocking on `(i−1, c)` is
//! precisely the arrow in the paper's Figure 4.
//!
//! Entries are **copy-on-write**: the store holds `Arc`s, so snapshots
//! (`dump`), fetches, and the TCP server's reply paths clone refcounts,
//! never tensors. The lock hold of a full-store [`MemStore::dump`] is
//! O(entries), which is what keeps the checkpoint writer from stalling
//! publishers mid-run. Published values are immutable; an overwrite at the
//! same key swaps the `Arc`, it never mutates in place.
//!
//! Two deployments (selected by [`crate::config::TransportKind`]):
//! in-process ([`MemStore`], threads share one instance) and remote
//! (leader hosts a [`MemStore`] behind the TCP server in
//! [`crate::transport::tcp`], workers use `TcpStoreClient`).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::ff::{FFLayer, LinearHead};
use crate::metrics::CommStats;
use crate::sync::{LockRank, OrderedCondvar, OrderedMutex};
use crate::tensor::adam::AdamConfig;
use crate::tensor::{AdamState, Matrix};
use crate::transport::codec::{QuantHeadParams, QuantLayerParams};

/// Published form of one FF layer: weights + bias, optionally with Adam
/// moments (`ship_opt_state` ablation — the paper ships only w/b).
#[derive(Clone, Debug)]
pub struct LayerParams {
    /// Weight matrix `(d_in, d_out)`.
    pub w: Matrix,
    /// Bias.
    pub b: Vec<f32>,
    /// Whether the layer normalizes its input (carried so a fetched layer
    /// reconstructs identically on any node).
    pub normalize_input: bool,
    /// Optional optimizer snapshot.
    pub opt: Option<OptSnapshot>,
}

/// Adam moments snapshot for shipping with a layer.
#[derive(Clone, Debug)]
pub struct OptSnapshot {
    /// First moment (weights).
    pub m_w: Matrix,
    /// Second moment (weights).
    pub v_w: Matrix,
    /// First moment (bias).
    pub m_b: Vec<f32>,
    /// Second moment (bias).
    pub v_b: Vec<f32>,
    /// Adam step counter.
    pub t: u32,
}

impl OptSnapshot {
    /// Capture from an [`AdamState`].
    pub fn from_state(s: &AdamState) -> Self {
        OptSnapshot { m_w: s.m_w.clone(), v_w: s.v_w.clone(), m_b: s.m_b.clone(), v_b: s.v_b.clone(), t: s.t }
    }

    /// Restore into an [`AdamState`]. Constructs the state directly from
    /// the snapshot's matrices — this sits on the every-get
    /// deserialization path of `ship_opt_state` runs, so it must not
    /// allocate throwaway zeroed moments first.
    pub fn restore(&self) -> AdamState {
        AdamState {
            m_w: self.m_w.clone(),
            v_w: self.v_w.clone(),
            m_b: self.m_b.clone(),
            v_b: self.v_b.clone(),
            t: self.t,
            cfg: AdamConfig::default(),
        }
    }
}

impl LayerParams {
    /// Snapshot a live layer (and optionally its optimizer).
    pub fn from_layer(layer: &FFLayer, opt: Option<&AdamState>) -> Self {
        LayerParams {
            w: layer.w.clone(),
            b: layer.b.clone(),
            normalize_input: layer.normalize_input,
            opt: opt.map(OptSnapshot::from_state),
        }
    }

    /// Materialize as a live layer, consuming the params (no tensor copy).
    pub fn into_layer(self) -> (FFLayer, Option<AdamState>) {
        let opt = self.opt.as_ref().map(OptSnapshot::restore);
        (FFLayer { w: self.w, b: self.b, normalize_input: self.normalize_input }, opt)
    }

    /// Materialize a live layer by cloning. This is the fetch path for
    /// shared (`Arc`-held) store entries: the store's copy stays immutable
    /// while the node trains its own.
    pub fn to_layer(&self) -> (FFLayer, Option<AdamState>) {
        let opt = self.opt.as_ref().map(OptSnapshot::restore);
        (
            FFLayer { w: self.w.clone(), b: self.b.clone(), normalize_input: self.normalize_input },
            opt,
        )
    }

    /// Approximate wire size (the communication-volume metric of §6).
    pub fn wire_bytes(&self) -> u64 {
        let base = (self.w.data.len() + self.b.len()) * 4 + 24;
        let opt = self.opt.as_ref().map_or(0, |o| {
            (o.m_w.data.len() + o.v_w.data.len() + o.m_b.len() + o.v_b.len()) * 4 + 8
        });
        (base + opt) as u64
    }
}

/// Published softmax head.
#[derive(Clone, Debug)]
pub struct HeadParams {
    /// Weights `(d_in, classes)`.
    pub w: Matrix,
    /// Bias.
    pub b: Vec<f32>,
    /// Optional optimizer snapshot.
    pub opt: Option<OptSnapshot>,
}

impl HeadParams {
    /// Snapshot a live head.
    pub fn from_head(h: &LinearHead, opt: Option<&AdamState>) -> Self {
        HeadParams { w: h.w.clone(), b: h.b.clone(), opt: opt.map(OptSnapshot::from_state) }
    }

    /// Materialize as a live head, consuming the params.
    pub fn into_head(self) -> (LinearHead, Option<AdamState>) {
        let opt = self.opt.as_ref().map(OptSnapshot::restore);
        (LinearHead { w: self.w, b: self.b }, opt)
    }

    /// Materialize a live head by cloning (fetch path for shared entries).
    pub fn to_head(&self) -> (LinearHead, Option<AdamState>) {
        let opt = self.opt.as_ref().map(OptSnapshot::restore);
        (LinearHead { w: self.w.clone(), b: self.b.clone() }, opt)
    }

    /// Approximate wire size.
    pub fn wire_bytes(&self) -> u64 {
        ((self.w.data.len() + self.b.len()) * 4 + 16) as u64
    }
}

/// A sparse row-level update of one published layer against a base chapter
/// already in the store: only the rows whose bits changed travel, plus the
/// (cheap) full bias and the normalize flag. Reconstruction
/// ([`LayerDelta::apply`]) is bitwise — unchanged rows come from the base,
/// changed rows carry the exact new bits — so delta publishes preserve the
/// repo's bitwise-identical-weights invariant.
///
/// Deltas never carry optimizer snapshots: `ship_opt_state` runs always
/// publish full layers ([`LayerDelta::diff`] returns `None`).
#[derive(Clone, Debug)]
pub struct LayerDelta {
    /// Ascending indices of the changed rows of `w`.
    pub rows: Vec<u32>,
    /// Replacement rows, `(rows.len(), w.cols)` row-major.
    pub data: Matrix,
    /// Full bias of the new layer.
    pub b: Vec<f32>,
    /// Normalize-input flag of the new layer.
    pub normalize_input: bool,
}

impl LayerDelta {
    /// Diff `new` against `base`, bit-exactly (`f32::to_bits` compare).
    /// Returns `None` when a delta cannot represent the update: shape
    /// change, or either side ships an optimizer snapshot.
    pub fn diff(base: &LayerParams, new: &LayerParams) -> Option<LayerDelta> {
        if base.opt.is_some() || new.opt.is_some() {
            return None;
        }
        if base.w.rows != new.w.rows || base.w.cols != new.w.cols || base.b.len() != new.b.len()
        {
            return None;
        }
        let cols = new.w.cols;
        let mut rows: Vec<u32> = Vec::new();
        for r in 0..new.w.rows {
            let a = &base.w.data[r * cols..(r + 1) * cols];
            let b = &new.w.data[r * cols..(r + 1) * cols];
            if a.iter().zip(b).any(|(x, y)| x.to_bits() != y.to_bits()) {
                rows.push(r as u32);
            }
        }
        let mut data = Vec::with_capacity(rows.len() * cols);
        for &r in &rows {
            let r = r as usize;
            data.extend_from_slice(&new.w.data[r * cols..(r + 1) * cols]);
        }
        Some(LayerDelta {
            data: Matrix { rows: rows.len(), cols, data },
            rows,
            b: new.b.clone(),
            normalize_input: new.normalize_input,
        })
    }

    /// Rebuild the full layer this delta encodes, against `base`. Bitwise:
    /// `apply(diff(base, new), base) == new` for every representable pair.
    pub fn apply(&self, base: &LayerParams) -> Result<LayerParams> {
        let cols = base.w.cols;
        if self.data.cols != cols || self.data.rows != self.rows.len() {
            bail!(
                "layer delta shape mismatch: {} rows × {} cols of data for {} row indices against a {}×{} base",
                self.data.rows,
                self.data.cols,
                self.rows.len(),
                base.w.rows,
                cols
            );
        }
        if self.b.len() != base.b.len() {
            bail!("layer delta bias length {} != base bias length {}", self.b.len(), base.b.len());
        }
        let mut w = base.w.clone();
        for (i, &r) in self.rows.iter().enumerate() {
            let r = r as usize;
            if r >= w.rows {
                bail!("layer delta row {r} out of range for a {}-row base", w.rows);
            }
            w.data[r * cols..(r + 1) * cols]
                .copy_from_slice(&self.data.data[i * cols..(i + 1) * cols]);
        }
        Ok(LayerParams { w, b: self.b.clone(), normalize_input: self.normalize_input, opt: None })
    }

    /// Approximate wire size (what `CommStats` and
    /// `RunEvent::LayerPublished` report for a delta publish).
    pub fn wire_bytes(&self) -> u64 {
        ((self.data.data.len() + self.b.len()) * 4 + self.rows.len() * 4 + 32) as u64
    }
}

/// The store interface the schedulers program against. Fetches hand back
/// shared `Arc`s — the store's entry and the caller's handle are the same
/// immutable allocation; call [`LayerParams::to_layer`] /
/// [`HeadParams::to_head`] to materialize a private trainable copy.
pub trait ParamStore: Send + Sync {
    /// Publish layer `l` as of `chapter`.
    fn put_layer(&self, layer: usize, chapter: u32, params: LayerParams) -> Result<()>;
    /// Block until `(layer, chapter)` is available (or `timeout`).
    fn get_layer(&self, layer: usize, chapter: u32, timeout: Duration) -> Result<Arc<LayerParams>>;
    /// Publish the softmax head as of `chapter`.
    fn put_head(&self, chapter: u32, params: HeadParams) -> Result<()>;
    /// Block until the head at `chapter` is available.
    fn get_head(&self, chapter: u32, timeout: Duration) -> Result<Arc<HeadParams>>;
    /// Publish negative labels computed after `chapter`.
    fn put_neg(&self, chapter: u32, labels: Vec<u8>) -> Result<()>;
    /// Block until negative labels for `chapter` are available.
    fn get_neg(&self, chapter: u32, timeout: Duration) -> Result<Vec<u8>>;
    /// Most recent chapter of `layer`, if any (final model assembly).
    fn latest_layer(&self, layer: usize) -> Result<Option<(u32, Arc<LayerParams>)>>;
    /// Most recent head, if any.
    fn latest_head(&self) -> Result<Option<(u32, Arc<HeadParams>)>>;
    /// Communication counters.
    fn comm_stats(&self) -> CommStats;

    /// Publish layer `l` at `chapter` as a row [`LayerDelta`] against
    /// `base_chapter`, which the caller guarantees is already published.
    /// Only stores that answer `true` from [`ParamStore::supports_deltas`]
    /// accept this; publishers fall back to [`ParamStore::put_layer`]
    /// otherwise (see `NodeCtx::publish_layer`).
    fn put_layer_delta(
        &self,
        _layer: usize,
        _chapter: u32,
        _base_chapter: u32,
        _delta: LayerDelta,
    ) -> Result<()> {
        bail!("delta publish not supported by this store")
    }

    /// Whether [`ParamStore::put_layer_delta`] is available (e.g. a TCP
    /// client only after the server negotiated protocol v3).
    fn supports_deltas(&self) -> bool {
        false
    }

    /// Publish layer `l` at `chapter` from an already-quantized frame.
    /// The default dequantizes locally and stores the rounded params —
    /// exactly what the TCP server does with the same `q` bits on the
    /// other side of a v4 `PUT_LAYER_Q`, so every transport writes
    /// identical bytes into its store (tcp-vs-inproc bitwise equality).
    /// A protocol-v4 TCP client overrides this to ship `q` itself.
    fn put_layer_q(&self, layer: usize, chapter: u32, q: QuantLayerParams) -> Result<()> {
        self.put_layer(layer, chapter, q.dequantize())
    }

    /// Quantized-frame variant of [`ParamStore::put_head`] (see
    /// [`ParamStore::put_layer_q`] for the determinism contract).
    fn put_head_q(&self, chapter: u32, q: QuantHeadParams) -> Result<()> {
        self.put_head(chapter, q.dequantize())
    }

    /// Non-blocking presence probe: is `(layer, chapter)` published?
    /// Resume fast-forward uses this to skip chapters whose outputs are
    /// already in the store. The conservative default answers `false`
    /// ("not provably published"), so wrapper stores that don't implement
    /// it never cause completed work to be skipped — they just redo it.
    fn has_layer(&self, _layer: usize, _chapter: u32) -> Result<bool> {
        Ok(false)
    }

    /// Non-blocking presence probe for the head at `chapter` (see
    /// [`ParamStore::has_layer`] for the conservative default).
    fn has_head(&self, _chapter: u32) -> Result<bool> {
        Ok(false)
    }

    /// Non-blocking presence probe for negative labels at `chapter` (see
    /// [`ParamStore::has_layer`] for the conservative default).
    fn has_neg(&self, _chapter: u32) -> Result<bool> {
        Ok(false)
    }

    /// Unblock every parked blocking read (run cancellation). The session
    /// driver registers this as a cancel hook for *every* store — injected
    /// test doubles included — so a cancelled run never sits out a
    /// parked `get_layer`'s full timeout. Stores without a close notion
    /// may keep the no-op default.
    fn close(&self) {}
}

/// A consistent snapshot of everything a [`MemStore`] holds — the store
/// half of a `RunCheckpoint`. Entries are **sorted** (layers by
/// `(slot, chapter)`, heads/negs by chapter), so identical store contents
/// always serialize to identical bytes and "resumed run matches
/// uninterrupted run" can be checked with a plain file compare.
///
/// The snapshot shares the store's allocations (`Arc`s): taking it costs
/// O(entries) refcount bumps, and serializing it happens entirely outside
/// the store lock.
#[derive(Clone, Debug, Default)]
pub struct StoreDump {
    /// `(slot, chapter, params)` for every published layer (PerfOpt heads
    /// ride in the high-slot namespace, see `schedulers::head_slot`).
    pub layers: Vec<(usize, u32, Arc<LayerParams>)>,
    /// `(chapter, params)` for every published full-network head.
    pub heads: Vec<(u32, Arc<HeadParams>)>,
    /// `(chapter, labels)` for every published negative-label set.
    pub negs: Vec<(u32, Arc<Vec<u8>>)>,
}

#[derive(Default)]
struct MemInner {
    layers: HashMap<(usize, u32), Arc<LayerParams>>,
    heads: HashMap<u32, Arc<HeadParams>>,
    negs: HashMap<u32, Arc<Vec<u8>>>,
    stats: CommStats,
    /// Threads currently parked inside [`MemStore::wait_for`]. Lets tests
    /// and benchmarks synchronize on "the reader is actually blocked"
    /// without sleep-based handoffs (see [`MemStore::wait_for_waiters`]).
    waiting: usize,
    /// Set by [`MemStore::close`]: every blocking read — parked or future
    /// — errors out immediately. `RunHandle::cancel` uses this to unblock
    /// store-waiting nodes promptly.
    closed: bool,
    /// Monotonic change counter, bumped by every publish (and by
    /// [`MemStore::touch`]). Checkpoint writers park on it via
    /// [`MemStore::wait_version_change`] — change-driven, no poll loop.
    version: u64,
}

/// In-process [`ParamStore`] ([`OrderedMutex`] + [`OrderedCondvar`] at
/// [`LockRank::Store`], `Arc` copy-on-write entries).
pub struct MemStore {
    inner: OrderedMutex<MemInner>,
    cv: OrderedCondvar,
}

impl Default for MemStore {
    fn default() -> Self {
        MemStore {
            inner: OrderedMutex::new(LockRank::Store, MemInner::default()),
            cv: OrderedCondvar::new(),
        }
    }
}

impl MemStore {
    /// Fresh empty store.
    pub fn new() -> Self {
        MemStore::default()
    }

    /// Close the store: every parked blocking read wakes with an error,
    /// and future blocking reads fail immediately. Idempotent; publishes
    /// and non-blocking probes keep working (final assembly still reads
    /// whatever was published before the close).
    pub fn close(&self) {
        self.inner.lock().closed = true;
        self.cv.notify_all();
    }

    /// Panic while holding the store lock, poisoning the underlying
    /// `std::sync::Mutex`. Test-only: pins the [`OrderedMutex`] recovery
    /// contract — a publisher crash must not cascade into every other
    /// store user.
    #[cfg(test)]
    pub(crate) fn poison_for_test(&self) {
        let s = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = self.inner.lock();
            panic!("deliberate panic while holding the store lock");
        }));
        assert!(s.is_err());
    }

    /// Whether [`MemStore::close`] was called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().closed
    }

    fn wait_for<T>(
        &self,
        timeout: Duration,
        what: &str,
        mut probe: impl FnMut(&mut MemInner) -> Option<T>,
    ) -> Result<T> {
        let mut guard = self.inner.lock();
        if guard.closed {
            anyhow::bail!("store closed while waiting for {what}");
        }
        if let Some(v) = probe(&mut guard) {
            return Ok(v);
        }
        let deadline = std::time::Instant::now() + timeout;
        guard.waiting += 1;
        // Wake wait_for_waiters observers of the parked-thread count.
        self.cv.notify_all();
        let result = loop {
            let now = std::time::Instant::now();
            if now >= deadline {
                break Err(anyhow::anyhow!("store: timed out after {timeout:?} waiting for {what}"));
            }
            let (g, _) = self.cv.wait_timeout(guard, deadline - now);
            guard = g;
            if guard.closed {
                break Err(anyhow::anyhow!("store closed while waiting for {what}"));
            }
            if let Some(v) = probe(&mut guard) {
                break Ok(v);
            }
        };
        guard.waiting -= 1;
        result
    }

    /// Block until at least `n` threads are parked inside a blocking get.
    ///
    /// Deterministic replacement for the `sleep(..)` handoffs tests used to
    /// need before publishing to an (intended-to-be) blocked reader: the
    /// publisher waits on the same Condvar until the reader is provably
    /// parked, so there is no timing guesswork and no poll interval.
    pub fn wait_for_waiters(&self, n: usize, timeout: Duration) -> Result<()> {
        let mut guard = self.inner.lock();
        let deadline = std::time::Instant::now() + timeout;
        while guard.waiting < n {
            let now = std::time::Instant::now();
            if now >= deadline {
                bail!(
                    "store: timed out after {timeout:?} waiting for {n} parked readers (have {})",
                    guard.waiting
                );
            }
            let (g, _) = self.cv.wait_timeout(guard, deadline - now);
            guard = g;
        }
        Ok(())
    }

    /// Threads currently parked inside a blocking get.
    pub fn waiter_count(&self) -> usize {
        self.inner.lock().waiting
    }

    /// Current change-counter value (see [`MemStore::wait_version_change`]).
    pub fn version(&self) -> u64 {
        self.inner.lock().version
    }

    /// Bump the change counter without publishing anything — wakes
    /// [`MemStore::wait_version_change`] parkers. The checkpoint writer's
    /// `finish()` uses this to unpark its thread promptly.
    pub fn touch(&self) {
        self.inner.lock().version += 1;
        self.cv.notify_all();
    }

    /// Park until the change counter moves past `seen` (any publish or
    /// [`MemStore::touch`]), the store closes (error), or `timeout`
    /// elapses (returns the unchanged counter). This is the checkpoint
    /// writer's wait primitive: strictly change-driven, no poll interval.
    ///
    /// An advance that raced a close is still an advance: the method
    /// reports it (`Ok`) so the caller can act on the publishes it missed
    /// — the checkpoint writer's final dump depends on this. "Closed" is
    /// only an error when nothing changed since `seen`.
    pub fn wait_version_change(&self, seen: u64, timeout: Duration) -> Result<u64> {
        let mut guard = self.inner.lock();
        let deadline = std::time::Instant::now() + timeout;
        while guard.version == seen && !guard.closed {
            let now = std::time::Instant::now();
            if now >= deadline {
                return Ok(guard.version);
            }
            let (g, _) = self.cv.wait_timeout(guard, deadline - now);
            guard = g;
        }
        if guard.version != seen {
            return Ok(guard.version);
        }
        bail!("store closed while waiting for a version change");
    }

    /// Consistent snapshot of the full store contents, sorted (see
    /// [`StoreDump`]). Taken under one lock, so a dump never interleaves
    /// with a publish — but the lock hold is O(entries): each entry costs
    /// one `Arc` refcount bump, tensors are never copied. Serialization of
    /// the returned dump happens with no lock held at all. Does not count
    /// toward [`CommStats`].
    pub fn dump(&self) -> StoreDump {
        let g = self.inner.lock();
        let mut layers: Vec<(usize, u32, Arc<LayerParams>)> =
            g.layers.iter().map(|(&(l, c), p)| (l, c, Arc::clone(p))).collect();
        layers.sort_by_key(|&(l, c, _)| (l, c));
        let mut heads: Vec<(u32, Arc<HeadParams>)> =
            g.heads.iter().map(|(&c, p)| (c, Arc::clone(p))).collect();
        heads.sort_by_key(|&(c, _)| c);
        let mut negs: Vec<(u32, Arc<Vec<u8>>)> =
            g.negs.iter().map(|(&c, v)| (c, Arc::clone(v))).collect();
        negs.sort_by_key(|&(c, _)| c);
        StoreDump { layers, heads, negs }
    }

    /// Rehydrate the store from a checkpoint dump (resume path). Entries
    /// overwrite any existing keys; [`CommStats`] is untouched — restored
    /// parameters were never on the wire in this run. Wakes every waiter,
    /// exactly like a publish.
    pub fn restore(&self, dump: StoreDump) {
        let mut g = self.inner.lock();
        for (l, c, p) in dump.layers {
            g.layers.insert((l, c), p);
        }
        for (c, p) in dump.heads {
            g.heads.insert(c, p);
        }
        for (c, v) in dump.negs {
            g.negs.insert(c, v);
        }
        g.version += 1;
        drop(g);
        self.cv.notify_all();
    }

    /// Non-blocking fetch: `(layer, chapter)` if already published (a hit
    /// counts as a get in [`CommStats`], exactly like the blocking path).
    /// Backs the v2+ wire protocol's immediate `GET_LAYER` and the
    /// `WAIT_LAYER` fast path (see `transport/PROTOCOL.md`).
    pub fn try_layer(&self, layer: usize, chapter: u32) -> Option<Arc<LayerParams>> {
        let mut g = self.inner.lock();
        let p = g.layers.get(&(layer, chapter)).cloned()?;
        g.stats.gets += 1;
        g.stats.bytes_get += p.wire_bytes();
        Some(p)
    }

    /// Non-blocking fetch: the head at `chapter` if already published.
    pub fn try_head(&self, chapter: u32) -> Option<Arc<HeadParams>> {
        let mut g = self.inner.lock();
        let p = g.heads.get(&chapter).cloned()?;
        g.stats.gets += 1;
        g.stats.bytes_get += p.wire_bytes();
        Some(p)
    }

    /// Non-blocking fetch: negative labels at `chapter` if published.
    pub fn try_neg(&self, chapter: u32) -> Option<Arc<Vec<u8>>> {
        let mut g = self.inner.lock();
        let p = g.negs.get(&chapter).cloned()?;
        g.stats.gets += 1;
        g.stats.bytes_get += p.len() as u64;
        Some(p)
    }
}

impl ParamStore for MemStore {
    fn put_layer(&self, layer: usize, chapter: u32, params: LayerParams) -> Result<()> {
        let params = Arc::new(params);
        let mut g = self.inner.lock();
        g.stats.puts += 1;
        g.stats.bytes_put += params.wire_bytes();
        g.layers.insert((layer, chapter), params);
        g.version += 1;
        drop(g);
        self.cv.notify_all();
        Ok(())
    }

    fn get_layer(&self, layer: usize, chapter: u32, timeout: Duration) -> Result<Arc<LayerParams>> {
        // Fetch + stats accounting in ONE critical section: the probe runs
        // under the store lock, so no dump()/close() can interleave
        // between handing out the entry and counting it.
        self.wait_for(timeout, &format!("layer {layer} @ chapter {chapter}"), |g| {
            let p = g.layers.get(&(layer, chapter)).cloned()?;
            g.stats.gets += 1;
            g.stats.bytes_get += p.wire_bytes();
            Some(p)
        })
    }

    fn put_head(&self, chapter: u32, params: HeadParams) -> Result<()> {
        let params = Arc::new(params);
        let mut g = self.inner.lock();
        g.stats.puts += 1;
        g.stats.bytes_put += params.wire_bytes();
        g.heads.insert(chapter, params);
        g.version += 1;
        drop(g);
        self.cv.notify_all();
        Ok(())
    }

    fn get_head(&self, chapter: u32, timeout: Duration) -> Result<Arc<HeadParams>> {
        self.wait_for(timeout, &format!("head @ chapter {chapter}"), |g| {
            let p = g.heads.get(&chapter).cloned()?;
            g.stats.gets += 1;
            g.stats.bytes_get += p.wire_bytes();
            Some(p)
        })
    }

    fn put_neg(&self, chapter: u32, labels: Vec<u8>) -> Result<()> {
        let labels = Arc::new(labels);
        let mut g = self.inner.lock();
        g.stats.puts += 1;
        g.stats.bytes_put += labels.len() as u64;
        g.negs.insert(chapter, labels);
        g.version += 1;
        drop(g);
        self.cv.notify_all();
        Ok(())
    }

    fn get_neg(&self, chapter: u32, timeout: Duration) -> Result<Vec<u8>> {
        self.wait_for(timeout, &format!("neg labels @ chapter {chapter}"), |g| {
            let v = g.negs.get(&chapter).cloned()?;
            g.stats.gets += 1;
            g.stats.bytes_get += v.len() as u64;
            Some(v.as_ref().clone())
        })
    }

    fn latest_layer(&self, layer: usize) -> Result<Option<(u32, Arc<LayerParams>)>> {
        let g = self.inner.lock();
        Ok(g.layers
            .iter()
            .filter(|((l, _), _)| *l == layer)
            .max_by_key(|((_, c), _)| *c)
            .map(|((_, c), p)| (*c, Arc::clone(p))))
    }

    fn latest_head(&self) -> Result<Option<(u32, Arc<HeadParams>)>> {
        let g = self.inner.lock();
        Ok(g.heads.iter().max_by_key(|(c, _)| **c).map(|(c, p)| (*c, Arc::clone(p))))
    }

    fn comm_stats(&self) -> CommStats {
        self.inner.lock().stats
    }

    fn put_layer_delta(
        &self,
        layer: usize,
        chapter: u32,
        base_chapter: u32,
        delta: LayerDelta,
    ) -> Result<()> {
        // Grab the base's refcount (O(1) under the lock), reconstruct the
        // full layer with NO lock held, then insert. CommStats counts the
        // delta's wire size — that is what actually shipped.
        let base = {
            let g = self.inner.lock();
            match g.layers.get(&(layer, base_chapter)) {
                Some(p) => Arc::clone(p),
                None => bail!(
                    "delta publish for layer {layer} @ chapter {chapter}: base chapter {base_chapter} is not in the store"
                ),
            }
        };
        let full = Arc::new(delta.apply(&base)?);
        let mut g = self.inner.lock();
        g.stats.puts += 1;
        g.stats.bytes_put += delta.wire_bytes();
        g.layers.insert((layer, chapter), full);
        g.version += 1;
        drop(g);
        self.cv.notify_all();
        Ok(())
    }

    fn supports_deltas(&self) -> bool {
        true
    }

    // Exact presence probes (no clone, no stats — nothing ships).
    fn has_layer(&self, layer: usize, chapter: u32) -> Result<bool> {
        Ok(self.inner.lock().layers.contains_key(&(layer, chapter)))
    }

    fn has_head(&self, chapter: u32) -> Result<bool> {
        Ok(self.inner.lock().heads.contains_key(&chapter))
    }

    fn has_neg(&self, chapter: u32) -> Result<bool> {
        Ok(self.inner.lock().negs.contains_key(&chapter))
    }

    fn close(&self) {
        MemStore::close(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn params(seed: u64) -> LayerParams {
        let mut rng = Rng::new(seed);
        LayerParams {
            w: Matrix::randn_scaled(4, 3, &mut rng),
            b: vec![0.0; 3],
            normalize_input: true,
            opt: None,
        }
    }

    #[test]
    fn put_get_roundtrip() {
        let s = MemStore::new();
        let p = params(1);
        s.put_layer(2, 5, p.clone()).unwrap();
        let got = s.get_layer(2, 5, Duration::from_millis(10)).unwrap();
        assert_eq!(got.w, p.w);
        assert!(got.normalize_input);
    }

    #[test]
    fn get_times_out_when_missing() {
        let s = MemStore::new();
        let err = s.get_layer(0, 0, Duration::from_millis(20)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn blocking_get_wakes_on_put() {
        let s = Arc::new(MemStore::new());
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.get_layer(1, 7, Duration::from_secs(5)));
        // Condvar-backed handoff: publish only once the reader is parked.
        s.wait_for_waiters(1, Duration::from_secs(5)).unwrap();
        s.put_layer(1, 7, params(2)).unwrap();
        let got = h.join().unwrap().unwrap();
        assert_eq!(got.w.rows, 4);
        assert_eq!(s.waiter_count(), 0);
    }

    #[test]
    fn close_wakes_parked_readers_and_fails_new_ones() {
        let s = Arc::new(MemStore::new());
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.get_layer(0, 0, Duration::from_secs(60)));
        s.wait_for_waiters(1, Duration::from_secs(5)).unwrap();
        let t0 = std::time::Instant::now();
        s.close();
        let err = h.join().unwrap().unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "close must wake promptly");
        assert!(err.to_string().contains("closed"), "{err}");
        // future blocking reads fail fast; probes and puts still work
        assert!(s.get_layer(1, 1, Duration::from_secs(60)).is_err());
        s.put_layer(1, 1, params(9)).unwrap();
        assert!(s.try_layer(1, 1).is_some());
        assert!(s.is_closed());
    }

    #[test]
    fn wait_for_waiters_times_out_cleanly() {
        let s = MemStore::new();
        let err = s.wait_for_waiters(1, Duration::from_millis(20)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn try_probes_do_not_block() {
        let s = MemStore::new();
        assert!(s.try_layer(0, 0).is_none());
        assert!(s.try_head(0).is_none());
        assert!(s.try_neg(0).is_none());
        s.put_layer(0, 0, params(1)).unwrap();
        s.put_neg(2, vec![7]).unwrap();
        assert_eq!(s.try_layer(0, 0).unwrap().w.rows, 4);
        assert_eq!(*s.try_neg(2).unwrap(), vec![7]);
    }

    #[test]
    fn latest_layer_picks_max_chapter() {
        let s = MemStore::new();
        s.put_layer(0, 1, params(1)).unwrap();
        s.put_layer(0, 3, params(2)).unwrap();
        s.put_layer(0, 2, params(3)).unwrap();
        let (c, _) = s.latest_layer(0).unwrap().unwrap();
        assert_eq!(c, 3);
        assert!(s.latest_layer(9).unwrap().is_none());
    }

    #[test]
    fn neg_labels_roundtrip() {
        let s = MemStore::new();
        s.put_neg(0, vec![1, 2, 3]).unwrap();
        assert_eq!(s.get_neg(0, Duration::from_millis(10)).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn comm_stats_accumulate() {
        let s = MemStore::new();
        let p = params(1);
        let bytes = p.wire_bytes();
        s.put_layer(0, 0, p).unwrap();
        s.get_layer(0, 0, Duration::from_millis(10)).unwrap();
        let st = s.comm_stats();
        assert_eq!(st.puts, 1);
        assert_eq!(st.gets, 1);
        assert_eq!(st.bytes_put, bytes);
        assert_eq!(st.bytes_get, bytes);
    }

    #[test]
    fn has_probes_answer_exactly_and_ship_nothing() {
        let s = MemStore::new();
        assert!(!s.has_layer(0, 0).unwrap());
        assert!(!s.has_head(1).unwrap());
        assert!(!s.has_neg(2).unwrap());
        s.put_layer(0, 0, params(1)).unwrap();
        s.put_neg(2, vec![3]).unwrap();
        assert!(s.has_layer(0, 0).unwrap());
        assert!(!s.has_layer(0, 1).unwrap());
        assert!(s.has_neg(2).unwrap());
        // probes are free: no gets counted, no bytes moved
        let st = s.comm_stats();
        assert_eq!(st.gets, 0);
        assert_eq!(st.bytes_get, 0);
    }

    #[test]
    fn dump_is_sorted_and_restore_rehydrates() {
        let s = MemStore::new();
        s.put_layer(1, 2, params(1)).unwrap();
        s.put_layer(0, 1, params(2)).unwrap();
        s.put_layer(0, 0, params(3)).unwrap();
        s.put_neg(5, vec![9]).unwrap();
        let dump = s.dump();
        let keys: Vec<(usize, u32)> = dump.layers.iter().map(|&(l, c, _)| (l, c)).collect();
        assert_eq!(keys, vec![(0, 0), (0, 1), (1, 2)], "dump must sort by (slot, chapter)");

        let fresh = MemStore::new();
        fresh.restore(dump);
        assert!(fresh.has_layer(1, 2).unwrap());
        assert!(fresh.has_neg(5).unwrap());
        let got = fresh.get_layer(0, 1, Duration::from_millis(10)).unwrap();
        assert_eq!(got.w, params(2).w);
        // restore is not communication
        assert_eq!(fresh.comm_stats().puts, 0);
    }

    #[test]
    fn dump_shares_storage_with_entries() {
        // The copy-on-write contract, structurally: a dump entry and the
        // live store entry are the SAME allocation. If dump() ever goes
        // back to deep-copying tensors under the lock, this fails.
        let s = MemStore::new();
        s.put_layer(0, 0, params(1)).unwrap();
        s.put_neg(3, vec![1, 2, 4]).unwrap();
        let dump = s.dump();
        let live = s.try_layer(0, 0).unwrap();
        assert!(
            Arc::ptr_eq(&dump.layers[0].2, &live),
            "dump must clone refcounts, not tensors"
        );
        let live_neg = s.try_neg(3).unwrap();
        assert!(Arc::ptr_eq(&dump.negs[0].1, &live_neg));
        // Fetches share too: two gets hand out the same allocation.
        let again = s.try_layer(0, 0).unwrap();
        assert!(Arc::ptr_eq(&live, &again));
    }

    #[test]
    fn version_changes_wake_waiters_and_touch_counts() {
        let s = Arc::new(MemStore::new());
        let v0 = s.version();
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.wait_version_change(v0, Duration::from_secs(5)));
        s.put_layer(0, 0, params(1)).unwrap();
        let v1 = h.join().unwrap().unwrap();
        assert!(v1 > v0, "publish must advance the version");
        // touch also advances it (writer shutdown path)
        s.touch();
        assert!(s.version() > v1);
        // timeout returns the unchanged counter, not an error
        let same = s.wait_version_change(s.version(), Duration::from_millis(10)).unwrap();
        assert_eq!(same, s.version());
        // close fails the wait
        s.close();
        let err = s.wait_version_change(s.version(), Duration::from_secs(5)).unwrap_err();
        assert!(err.to_string().contains("closed"), "{err}");
    }

    #[test]
    fn wait_version_change_reports_advance_that_raced_a_close() {
        let s = MemStore::new();
        let v0 = s.version();
        s.put_layer(0, 0, params(1)).unwrap();
        s.close();
        // The version moved before the close: the checkpoint writer must
        // see the advance (and capture those publishes), not "run over".
        let v = s.wait_version_change(v0, Duration::from_secs(5)).unwrap();
        assert!(v > v0);
        // With nothing new to report, a closed store is an error.
        let err = s.wait_version_change(v, Duration::from_secs(5)).unwrap_err();
        assert!(err.to_string().contains("closed"), "{err}");
    }

    #[test]
    fn opt_snapshot_roundtrip() {
        let mut rng = Rng::new(3);
        let layer = FFLayer::new(3, 2, false, &mut rng);
        let mut st = AdamState::new(3, 2);
        st.t = 17;
        st.m_w.data[0] = 0.5;
        let p = LayerParams::from_layer(&layer, Some(&st));
        let (l2, opt2) = p.into_layer();
        assert_eq!(l2.w, layer.w);
        let opt2 = opt2.unwrap();
        assert_eq!(opt2.t, 17);
        assert_eq!(opt2.m_w.data[0], 0.5);
    }

    #[test]
    fn to_layer_matches_into_layer_bitwise() {
        let p = params(7);
        let (borrowed, _) = p.to_layer();
        let (owned, _) = p.into_layer();
        assert_eq!(borrowed.w, owned.w);
        assert_eq!(borrowed.b, owned.b);
        assert_eq!(borrowed.normalize_input, owned.normalize_input);
    }

    #[test]
    fn layer_delta_roundtrip_and_guards() {
        let base = params(1);
        let mut new = base.clone();
        new.w.data[0] += 1.0; // row 0
        new.w.data[2 * new.w.cols] = -3.5; // row 2
        new.b[1] = 9.0;
        let d = LayerDelta::diff(&base, &new).unwrap();
        assert_eq!(d.rows, vec![0, 2]);
        assert_eq!(d.data.rows, 2);
        assert!(d.wire_bytes() < new.wire_bytes());
        let rebuilt = d.apply(&base).unwrap();
        assert_eq!(rebuilt.w, new.w);
        assert_eq!(rebuilt.b, new.b);
        assert_eq!(rebuilt.normalize_input, new.normalize_input);

        // identical params → empty (but valid) delta
        let empty = LayerDelta::diff(&base, &base).unwrap();
        assert!(empty.rows.is_empty());
        assert_eq!(empty.apply(&base).unwrap().w, base.w);

        // opt snapshots and shape changes are not representable
        let mut with_opt = new.clone();
        with_opt.opt = Some(OptSnapshot {
            m_w: base.w.clone(),
            v_w: base.w.clone(),
            m_b: vec![0.0; 3],
            v_b: vec![0.0; 3],
            t: 1,
        });
        assert!(LayerDelta::diff(&base, &with_opt).is_none());
        let mut rng = Rng::new(5);
        let other_shape = LayerParams {
            w: Matrix::randn_scaled(5, 3, &mut rng),
            b: vec![0.0; 3],
            normalize_input: true,
            opt: None,
        };
        assert!(LayerDelta::diff(&base, &other_shape).is_none());
        // applying against the wrong base is an error, not corruption
        assert!(d.apply(&other_shape).is_err());
    }

    #[test]
    fn put_layer_delta_reconstructs_bitwise_and_counts_delta_bytes() {
        let s = MemStore::new();
        let base = params(1);
        s.put_layer(0, 0, base.clone()).unwrap();
        let mut next = base.clone();
        next.w.data[5] = 42.0;
        let d = LayerDelta::diff(&base, &next).unwrap();
        let d_bytes = d.wire_bytes();
        let before = s.comm_stats().bytes_put;
        s.put_layer_delta(0, 1, 0, d).unwrap();
        let got = s.get_layer(0, 1, Duration::from_millis(10)).unwrap();
        assert_eq!(got.w, next.w);
        assert_eq!(got.b, next.b);
        assert!(got.opt.is_none());
        assert_eq!(s.comm_stats().bytes_put - before, d_bytes, "stats count the delta, not the full layer");
        // a missing base is an immediate error, not a hang or a zero-fill
        let d2 = LayerDelta::diff(&base, &next).unwrap();
        assert!(s.put_layer_delta(3, 1, 0, d2).is_err());
        assert!(s.supports_deltas());
    }

    #[test]
    fn poisoned_store_lock_recovers_for_publishers_and_dumpers() {
        // A thread panicking while holding the store lock must not
        // cascade: OrderedMutex recovers the poisoned guard, so later
        // publishers, probes, and the checkpoint dumper all keep working
        // (the PR 6 review found exactly this poisoning failure mode).
        let s = Arc::new(MemStore::new());
        s.put_layer(0, 0, params(1)).unwrap();
        let s2 = s.clone();
        std::thread::spawn(move || s2.poison_for_test()).join().unwrap();

        s.put_layer(1, 0, params(2)).unwrap(); // publisher continues
        assert!(s.has_layer(1, 0).unwrap());
        assert_eq!(s.dump().layers.len(), 2); // dumper continues
        let got = s.get_layer(0, 0, Duration::from_millis(10)).unwrap();
        assert_eq!(got.w, params(1).w);
        assert_eq!(s.comm_stats().puts, 2);
    }
}
