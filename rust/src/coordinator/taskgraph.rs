//! The dependency graph of `(chapter, layer)` work items — the scheduling
//! currency of the coordinator since the TaskGraph redesign.
//!
//! The paper's §4.1/§4.2 publish dependencies make each chapter/layer cell
//! an independently schedulable unit: training layer *l* of chapter *c*
//! needs (a) the activations of layer *l−1* at the SAME chapter and (b)
//! the weights of layer *l* as published at the PREVIOUS chapter. Encoded
//! as edges, that is the pipeline lattice
//!
//! ```text
//!   (c, l-1) ──► (c, l)        forwarded activations (same chapter)
//!   (c-1, l) ──► (c, l)        layer weights (previous chapter)
//! ```
//!
//! plus strategy-specific extras (AdaptiveNEG label production). Each
//! task carries a *home* node — the logical node of the static plan — so
//! the derived [`SchedulePlan`] rendering, data sharding (Federated) and
//! optimizer-state continuity (`OptBank`) stay exactly as the paper's
//! static mapping describes, while the dispatcher is free to run a ready
//! task on any live worker.
//!
//! The blocker-count execution model (one atomic in-degree per task,
//! decremented as dependencies publish) follows the dynec snippet in
//! SNIPPETS.md; the ready-queue/bucket structure around it lives in
//! [`crate::coordinator::dispatch`].

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use anyhow::{bail, ensure, Context, Result};

use crate::config::ExperimentConfig;

/// One schedulable unit of work: train layer `layer` for chapter
/// `chapter`'s `C = E/S` epochs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Task {
    /// Index into [`TaskGraph::tasks`] (assigned by the builder).
    pub id: usize,
    /// Chapter (data split) index.
    pub chapter: u32,
    /// Layer index within the network.
    pub layer: usize,
    /// The static plan's owner node — used for data sharding, optimizer
    /// continuity and worker affinity (not a placement constraint).
    pub home: usize,
}

impl Task {
    /// The `(chapter, layer)` cell this task trains.
    pub fn cell(&self) -> (u32, usize) {
        (self.chapter, self.layer)
    }
}

/// An immutable dependency graph over every `(chapter, layer)` cell of a
/// run — acyclic and covering the grid exactly once, by construction.
#[derive(Clone, Debug)]
pub struct TaskGraph {
    tasks: Vec<Task>,
    /// Outgoing edges: `dependents[id]` are unblocked when `id` completes.
    dependents: Vec<Vec<usize>>,
    /// Incoming edge count per task (the blocker count at rest).
    in_degree: Vec<u32>,
    index: HashMap<(u32, usize), usize>,
    nodes: usize,
    n_layers: usize,
    splits: u32,
    shard_data: bool,
}

impl TaskGraph {
    /// Start a builder over the standard pipeline lattice for `cfg`:
    /// one task per `(chapter, layer)` cell with `home = home_of(c, l)`,
    /// edges `(c, l-1) → (c, l)` and `(c-1, l) → (c, l)`. Schedulers add
    /// their extra edges (AdaptiveNEG label production) and `build()`.
    pub fn pipeline(
        cfg: &ExperimentConfig,
        shard_data: bool,
        home_of: impl Fn(u32, usize) -> usize,
    ) -> TaskGraphBuilder {
        let mut b =
            TaskGraphBuilder::new(cfg.nodes.max(1), cfg.num_layers(), cfg.splits, shard_data);
        for c in 0..cfg.splits {
            for l in 0..cfg.num_layers() {
                b.task(c, l, home_of(c, l)).expect("pipeline grid cells are unique");
            }
        }
        for c in 0..cfg.splits {
            for l in 0..cfg.num_layers() {
                if l > 0 {
                    b.edge((c, l - 1), (c, l)).expect("lattice edge endpoints exist");
                }
                if c > 0 {
                    b.edge((c - 1, l), (c, l)).expect("lattice edge endpoints exist");
                }
            }
        }
        b
    }

    /// Number of tasks (= `splits × layers`).
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// Whether the graph has no tasks.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Logical node count the homes span.
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Layers per chapter.
    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Chapter count.
    pub fn splits(&self) -> u32 {
        self.splits
    }

    /// Whether homes train on private data shards (Federated).
    pub fn shard_data(&self) -> bool {
        self.shard_data
    }

    /// Task by id.
    pub fn task(&self, id: usize) -> Task {
        self.tasks[id]
    }

    /// All tasks, id order.
    pub fn tasks(&self) -> &[Task] {
        &self.tasks
    }

    /// Id of the task training `(chapter, layer)`, if present.
    pub fn id_of(&self, chapter: u32, layer: usize) -> Option<usize> {
        self.index.get(&(chapter, layer)).copied()
    }

    /// Tasks unblocked by `id`'s completion.
    pub fn dependents(&self, id: usize) -> &[usize] {
        &self.dependents[id]
    }

    /// Incoming-edge (blocker) count of `id`.
    pub fn in_degree(&self, id: usize) -> u32 {
        self.in_degree[id]
    }

    /// The canonical single-worker execution order: a deterministic
    /// topological sort that always runs the smallest ready
    /// `(chapter, layer)` next. With the lattice edges this is exactly
    /// the chapter-major order the static `SchedulePlan` interleaved
    /// across nodes — the property the graph-vs-plan tests pin.
    pub fn serial_order(&self) -> Vec<usize> {
        let mut in_deg = self.in_degree.clone();
        let mut heap: BinaryHeap<Reverse<(u32, usize, usize)>> = self
            .tasks
            .iter()
            .filter(|t| in_deg[t.id] == 0)
            .map(|t| Reverse((t.chapter, t.layer, t.id)))
            .collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(Reverse((_, _, id))) = heap.pop() {
            order.push(id);
            for &d in &self.dependents[id] {
                in_deg[d] -= 1;
                if in_deg[d] == 0 {
                    let t = self.tasks[d];
                    heap.push(Reverse((t.chapter, t.layer, t.id)));
                }
            }
        }
        debug_assert_eq!(order.len(), self.len(), "build() guarantees acyclicity");
        order
    }
}

/// Builder for [`TaskGraph`] — collects tasks and edges, then validates
/// grid coverage and acyclicity in [`TaskGraphBuilder::build`].
pub struct TaskGraphBuilder {
    nodes: usize,
    n_layers: usize,
    splits: u32,
    shard_data: bool,
    tasks: Vec<Task>,
    index: HashMap<(u32, usize), usize>,
    edges: Vec<(usize, usize)>,
}

impl TaskGraphBuilder {
    /// Empty builder for a `splits × n_layers` grid over `nodes` homes.
    pub fn new(nodes: usize, n_layers: usize, splits: u32, shard_data: bool) -> Self {
        TaskGraphBuilder {
            nodes: nodes.max(1),
            n_layers,
            splits,
            shard_data,
            tasks: Vec::with_capacity(splits as usize * n_layers),
            index: HashMap::new(),
            edges: Vec::new(),
        }
    }

    /// Add the task for `(chapter, layer)` with home `home`. Errors on a
    /// duplicate cell or out-of-range coordinates.
    pub fn task(&mut self, chapter: u32, layer: usize, home: usize) -> Result<usize> {
        ensure!(
            chapter < self.splits && layer < self.n_layers,
            "task ({chapter}, {layer}) outside the {}x{} grid",
            self.splits,
            self.n_layers
        );
        ensure!(home < self.nodes, "task ({chapter}, {layer}) home {home} >= nodes {}", self.nodes);
        let id = self.tasks.len();
        ensure!(
            self.index.insert((chapter, layer), id).is_none(),
            "duplicate task for cell ({chapter}, {layer})"
        );
        self.tasks.push(Task { id, chapter, layer, home });
        Ok(id)
    }

    /// Add a dependency edge `from → to` (`to` cannot start before `from`
    /// completes). Both cells must already exist.
    pub fn edge(&mut self, from: (u32, usize), to: (u32, usize)) -> Result<()> {
        let f = *self
            .index
            .get(&from)
            .with_context(|| format!("edge source ({}, {}) is not a task", from.0, from.1))?;
        let t = *self
            .index
            .get(&to)
            .with_context(|| format!("edge target ({}, {}) is not a task", to.0, to.1))?;
        ensure!(f != t, "self-edge on cell ({}, {})", from.0, from.1);
        self.edges.push((f, t));
        Ok(())
    }

    /// Validate (full grid coverage, acyclicity) and freeze the graph.
    pub fn build(self) -> Result<TaskGraph> {
        let want = self.splits as usize * self.n_layers;
        ensure!(
            self.tasks.len() == want,
            "task graph covers {} of {} (chapter, layer) cells",
            self.tasks.len(),
            want
        );
        let mut dependents = vec![Vec::new(); self.tasks.len()];
        let mut in_degree = vec![0u32; self.tasks.len()];
        for &(f, t) in &self.edges {
            dependents[f].push(t);
            in_degree[t] += 1;
        }
        // Deterministic unblock order (and stable serial_order ties).
        for d in &mut dependents {
            d.sort_unstable();
            d.dedup();
        }
        // Recount after dedup so duplicate edges don't deadlock a task.
        in_degree.iter_mut().for_each(|d| *d = 0);
        for d in dependents.iter().flatten() {
            in_degree[*d] += 1;
        }
        let g = TaskGraph {
            tasks: self.tasks,
            dependents,
            in_degree,
            index: self.index,
            nodes: self.nodes,
            n_layers: self.n_layers,
            splits: self.splits,
            shard_data: self.shard_data,
        };
        if g.serial_order().len() != g.len() {
            bail!("task graph contains a dependency cycle");
        }
        Ok(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(nodes: usize, splits: u32) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::tiny();
        cfg.nodes = nodes;
        cfg.splits = splits;
        cfg.epochs = splits;
        cfg
    }

    #[test]
    fn pipeline_lattice_has_expected_shape() {
        let cfg = cfg(2, 4);
        let g = TaskGraph::pipeline(&cfg, false, |c, _| c as usize % 2).build().unwrap();
        assert_eq!(g.len(), 4 * cfg.num_layers());
        // (0,0) has no blockers, (1,1) has two: (1,0) and (0,1).
        assert_eq!(g.in_degree(g.id_of(0, 0).unwrap()), 0);
        assert_eq!(g.in_degree(g.id_of(1, 1).unwrap()), 2);
        // (0,0) unblocks (0,1) and (1,0).
        let deps: Vec<(u32, usize)> = g
            .dependents(g.id_of(0, 0).unwrap())
            .iter()
            .map(|&d| g.task(d).cell())
            .collect();
        assert_eq!(deps, vec![(0, 1), (1, 0)]);
    }

    #[test]
    fn serial_order_is_chapter_major() {
        let cfg = cfg(2, 3);
        let g = TaskGraph::pipeline(&cfg, false, |c, _| c as usize % 2).build().unwrap();
        let cells: Vec<(u32, usize)> =
            g.serial_order().into_iter().map(|id| g.task(id).cell()).collect();
        let mut want = Vec::new();
        for c in 0..3u32 {
            for l in 0..cfg.num_layers() {
                want.push((c, l));
            }
        }
        assert_eq!(cells, want);
    }

    #[test]
    fn duplicate_cell_and_cycle_are_rejected() {
        let mut b = TaskGraphBuilder::new(1, 1, 2, false);
        b.task(0, 0, 0).unwrap();
        assert!(b.task(0, 0, 0).is_err(), "duplicate cell must be rejected");
        b.task(1, 0, 0).unwrap();
        b.edge((0, 0), (1, 0)).unwrap();
        b.edge((1, 0), (0, 0)).unwrap();
        let err = b.build().unwrap_err();
        assert!(err.to_string().contains("cycle"), "{err}");
    }

    #[test]
    fn partial_grid_is_rejected() {
        let mut b = TaskGraphBuilder::new(1, 2, 2, false);
        b.task(0, 0, 0).unwrap();
        let err = b.build().unwrap_err();
        assert!(err.to_string().contains("covers"), "{err}");
    }

    #[test]
    fn unknown_edge_endpoint_is_rejected() {
        let mut b = TaskGraphBuilder::new(1, 1, 1, false);
        b.task(0, 0, 0).unwrap();
        assert!(b.edge((0, 0), (5, 0)).is_err());
        assert!(b.edge((5, 0), (0, 0)).is_err());
    }
}
