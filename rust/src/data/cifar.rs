//! CIFAR-10 binary-version loader (`data_batch_{1..5}.bin`, `test_batch.bin`).
//!
//! Each record is 1 label byte + 3072 pixel bytes (R, G, B planes).

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::dataset::{DataBundle, Dataset};
use crate::tensor::Matrix;

/// Bytes per record in the binary format.
pub const RECORD: usize = 1 + 3072;

/// Parse one CIFAR binary batch buffer into `(x, y)`, scaled to `[0,1]`.
pub fn parse_batch(buf: &[u8], limit: usize) -> Result<(Matrix, Vec<u8>)> {
    if buf.len() % RECORD != 0 {
        bail!("cifar: file size {} not a multiple of {RECORD}", buf.len());
    }
    let mut n = buf.len() / RECORD;
    if limit > 0 {
        n = n.min(limit);
    }
    let mut x = Matrix::zeros(n, 3072);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let rec = &buf[i * RECORD..(i + 1) * RECORD];
        let label = rec[0];
        if label > 9 {
            bail!("cifar: label {label} out of range");
        }
        y.push(label);
        for (j, &px) in rec[1..].iter().enumerate() {
            x.row_mut(i)[j] = f32::from(px) / 255.0;
        }
    }
    Ok((x, y))
}

/// Load CIFAR-10 from `dir`, concatenating the five training batches.
pub fn load(dir: impl AsRef<Path>, train_n: usize, test_n: usize) -> Result<DataBundle> {
    let dir = dir.as_ref();
    let mut xs: Option<Matrix> = None;
    let mut ys: Vec<u8> = Vec::new();
    for i in 1..=5 {
        if train_n > 0 && ys.len() >= train_n {
            break;
        }
        let remaining = if train_n > 0 { train_n - ys.len() } else { 0 };
        let path = dir.join(format!("data_batch_{i}.bin"));
        let buf = fs::read(&path).with_context(|| format!("reading {}", path.display()))?;
        let (x, mut y) = parse_batch(&buf, remaining)?;
        xs = Some(match xs {
            None => x,
            Some(prev) => prev.vcat(&x),
        });
        ys.append(&mut y);
    }
    let train_x = xs.context("cifar: no training batches found")?;
    let buf = fs::read(dir.join("test_batch.bin")).context("reading test_batch.bin")?;
    let (test_x, test_y) = parse_batch(&buf, test_n)?;
    Ok(DataBundle {
        train: Dataset { x: train_x, y: ys, classes: 10 },
        test: Dataset { x: test_x, y: test_y, classes: 10 },
        name: "cifar10".into(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(label: u8, fill: u8) -> Vec<u8> {
        let mut r = vec![label];
        r.extend(std::iter::repeat(fill).take(3072));
        r
    }

    #[test]
    fn parse_roundtrip() {
        let mut buf = record(3, 255);
        buf.extend(record(9, 0));
        let (x, y) = parse_batch(&buf, 0).unwrap();
        assert_eq!(y, vec![3, 9]);
        assert_eq!((x.rows, x.cols), (2, 3072));
        assert!((x.at(0, 0) - 1.0).abs() < 1e-6);
        assert_eq!(x.at(1, 100), 0.0);
    }

    #[test]
    fn parse_limit() {
        let mut buf = record(1, 1);
        buf.extend(record(2, 2));
        let (x, y) = parse_batch(&buf, 1).unwrap();
        assert_eq!((x.rows, y.len()), (1, 1));
    }

    #[test]
    fn bad_size_rejected() {
        assert!(parse_batch(&[0u8; 100], 0).is_err());
    }

    #[test]
    fn bad_label_rejected() {
        let buf = record(11, 0);
        assert!(parse_batch(&buf, 0).is_err());
    }
}
