//! Dataset container, sharding and minibatch iteration.

use crate::tensor::{Matrix, Rng};

/// A labelled dense dataset: `x` is `(n, dim)` in `[0, 1]`, `y` integer
/// class labels.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Features, one row per example.
    pub x: Matrix,
    /// Labels, `len == x.rows`.
    pub y: Vec<u8>,
    /// Number of classes.
    pub classes: usize,
}

impl Dataset {
    /// Number of examples.
    pub fn len(&self) -> usize {
        self.x.rows
    }

    /// True iff empty.
    pub fn is_empty(&self) -> bool {
        self.x.rows == 0
    }

    /// Feature dimensionality.
    pub fn dim(&self) -> usize {
        self.x.cols
    }

    /// Truncate to the first `n` examples (0 = keep all).
    pub fn truncate(mut self, n: usize) -> Dataset {
        if n == 0 || n >= self.len() {
            return self;
        }
        self.x.data.truncate(n * self.x.cols);
        self.x.rows = n;
        self.y.truncate(n);
        self
    }

    /// Split into `shards` near-equal contiguous shards (Federated PFF:
    /// each node trains on its own private shard). Examples are dealt
    /// round-robin so every shard sees every class.
    pub fn shard(&self, shards: usize) -> Vec<Dataset> {
        assert!(shards >= 1);
        let mut idx: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for i in 0..self.len() {
            idx[i % shards].push(i);
        }
        idx.into_iter()
            .map(|rows| Dataset {
                x: self.x.gather_rows(&rows),
                y: rows.iter().map(|&r| self.y[r]).collect(),
                classes: self.classes,
            })
            .collect()
    }

    /// Minibatch index iterator for one epoch, shuffled from `rng`.
    pub fn batches(&self, batch: usize, rng: &mut Rng) -> BatchIter {
        let mut order: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut order);
        BatchIter { order, batch, pos: 0 }
    }

    /// Per-sample centering: each row becomes zero-mean (pixel scale is
    /// kept). FF needs centered inputs — with all-positive pixels, any
    /// uniform down-pressure on a unit moves every weight the same
    /// direction and ReLUs die. Centering WITHOUT variance scaling keeps
    /// the label overlay's relative strength at MNIST-like levels (full
    /// unit-std standardization inflates ‖x‖ ~8× and drowns the overlay —
    /// measured in EXPERIMENTS.md §Stability).
    pub fn center_rows(&mut self) {
        for r in 0..self.x.rows {
            let row = self.x.row_mut(r);
            let mean: f32 = row.iter().sum::<f32>() / row.len() as f32;
            for v in row {
                *v -= mean;
            }
        }
    }

    /// Per-class counts — test/debug helper.
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.classes];
        for &l in &self.y {
            h[l as usize] += 1;
        }
        h
    }
}

/// Bundle of train + test splits.
#[derive(Clone, Debug)]
pub struct DataBundle {
    /// Training split.
    pub train: Dataset,
    /// Held-out test split.
    pub test: Dataset,
    /// Human-readable dataset name.
    pub name: String,
}

/// Iterator over shuffled minibatch row-index slices.
pub struct BatchIter {
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl Iterator for BatchIter {
    type Item = Vec<usize>;
    fn next(&mut self) -> Option<Vec<usize>> {
        if self.pos >= self.order.len() {
            return None;
        }
        let end = (self.pos + self.batch).min(self.order.len());
        let out = self.order[self.pos..end].to_vec();
        self.pos = end;
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(n: usize) -> Dataset {
        Dataset {
            x: Matrix::from_vec(n, 2, (0..2 * n).map(|v| v as f32).collect()),
            y: (0..n).map(|i| (i % 3) as u8).collect(),
            classes: 3,
        }
    }

    #[test]
    fn batches_cover_every_example_once() {
        let d = tiny(10);
        let mut rng = Rng::new(1);
        let mut seen: Vec<usize> = d.batches(3, &mut rng).flatten().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn batch_sizes() {
        let d = tiny(10);
        let mut rng = Rng::new(2);
        let sizes: Vec<usize> = d.batches(4, &mut rng).map(|b| b.len()).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
    }

    #[test]
    fn shards_partition_and_balance() {
        let d = tiny(11);
        let shards = d.shard(3);
        assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 11);
        let lens: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(lens, vec![4, 4, 3]);
        // every shard sees every class when strides don't align
        // (labels are i % 3 here, so shard(4) breaks the alignment)
        let shards4 = tiny(12).shard(4);
        for s in &shards4 {
            assert!(s.class_histogram().iter().all(|&c| c > 0));
        }
    }

    #[test]
    fn truncate_caps() {
        let d = tiny(10).truncate(4);
        assert_eq!(d.len(), 4);
        assert_eq!(d.y.len(), 4);
        let d2 = tiny(5).truncate(0);
        assert_eq!(d2.len(), 5);
    }
}
