//! MNIST IDX loader (uncompressed `train-images-idx3-ubyte` et al.).
//!
//! Drop the four uncompressed IDX files into `data/mnist/` to run the
//! paper's experiments on real MNIST; otherwise use
//! [`crate::data::DatasetKind::SynthMnist`]. Gzip is not handled — `gunzip`
//! the canonical downloads first (offline environment, no flate2 dep).

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::data::dataset::{DataBundle, Dataset};
use crate::tensor::Matrix;

/// Parse an IDX3 (images) byte buffer into a `(n, rows*cols)` matrix
/// scaled to `[0, 1]`.
pub fn parse_idx3_images(buf: &[u8], limit: usize) -> Result<Matrix> {
    if buf.len() < 16 {
        bail!("idx3: truncated header");
    }
    let magic = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if magic != 0x0000_0803 {
        bail!("idx3: bad magic {magic:#x}");
    }
    let n = u32::from_be_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    let rows = u32::from_be_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
    let cols = u32::from_be_bytes([buf[12], buf[13], buf[14], buf[15]]) as usize;
    let n = if limit > 0 { n.min(limit) } else { n };
    let need = 16 + n * rows * cols;
    if buf.len() < need {
        bail!("idx3: want {need} bytes, have {}", buf.len());
    }
    let data = buf[16..need].iter().map(|&b| f32::from(b) / 255.0).collect();
    Ok(Matrix::from_vec(n, rows * cols, data))
}

/// Parse an IDX1 (labels) byte buffer.
pub fn parse_idx1_labels(buf: &[u8], limit: usize) -> Result<Vec<u8>> {
    if buf.len() < 8 {
        bail!("idx1: truncated header");
    }
    let magic = u32::from_be_bytes([buf[0], buf[1], buf[2], buf[3]]);
    if magic != 0x0000_0801 {
        bail!("idx1: bad magic {magic:#x}");
    }
    let n = u32::from_be_bytes([buf[4], buf[5], buf[6], buf[7]]) as usize;
    let n = if limit > 0 { n.min(limit) } else { n };
    if buf.len() < 8 + n {
        bail!("idx1: want {} bytes, have {}", 8 + n, buf.len());
    }
    Ok(buf[8..8 + n].to_vec())
}

/// Load real MNIST from `dir` (expects the 4 canonical uncompressed files).
pub fn load(dir: impl AsRef<Path>, train_n: usize, test_n: usize) -> Result<DataBundle> {
    let dir = dir.as_ref();
    let read = |name: &str| -> Result<Vec<u8>> {
        fs::read(dir.join(name)).with_context(|| format!("reading {}/{name}", dir.display()))
    };
    let train_x = parse_idx3_images(&read("train-images-idx3-ubyte")?, train_n)?;
    let train_y = parse_idx1_labels(&read("train-labels-idx1-ubyte")?, train_n)?;
    let test_x = parse_idx3_images(&read("t10k-images-idx3-ubyte")?, test_n)?;
    let test_y = parse_idx1_labels(&read("t10k-labels-idx1-ubyte")?, test_n)?;
    if train_x.rows != train_y.len() || test_x.rows != test_y.len() {
        bail!("mnist: image/label count mismatch");
    }
    Ok(DataBundle {
        train: Dataset { x: train_x, y: train_y, classes: 10 },
        test: Dataset { x: test_x, y: test_y, classes: 10 },
        name: "mnist".into(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idx3(n: u32, r: u32, c: u32, pixels: &[u8]) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        b.extend_from_slice(&n.to_be_bytes());
        b.extend_from_slice(&r.to_be_bytes());
        b.extend_from_slice(&c.to_be_bytes());
        b.extend_from_slice(pixels);
        b
    }

    #[test]
    fn parse_images_scales_to_unit() {
        let buf = idx3(2, 2, 2, &[0, 128, 255, 64, 0, 0, 0, 255]);
        let m = parse_idx3_images(&buf, 0).unwrap();
        assert_eq!((m.rows, m.cols), (2, 4));
        assert!((m.at(0, 2) - 1.0).abs() < 1e-6);
        assert!((m.at(0, 1) - 128.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn parse_images_limit() {
        let buf = idx3(2, 1, 2, &[1, 2, 3, 4]);
        let m = parse_idx3_images(&buf, 1).unwrap();
        assert_eq!(m.rows, 1);
    }

    #[test]
    fn parse_labels() {
        let mut b = Vec::new();
        b.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        b.extend_from_slice(&3u32.to_be_bytes());
        b.extend_from_slice(&[7, 1, 9]);
        assert_eq!(parse_idx1_labels(&b, 0).unwrap(), vec![7, 1, 9]);
        assert_eq!(parse_idx1_labels(&b, 2).unwrap(), vec![7, 1]);
    }

    #[test]
    fn bad_magic_rejected() {
        let b = vec![0u8; 16];
        assert!(parse_idx3_images(&b, 0).is_err());
        assert!(parse_idx1_labels(&b, 0).is_err());
    }

    #[test]
    fn truncated_rejected() {
        let buf = idx3(10, 28, 28, &[0u8; 10]); // claims 10 images, has 10 bytes
        assert!(parse_idx3_images(&buf, 0).is_err());
    }
}
