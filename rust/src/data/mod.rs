//! Datasets: real loaders (MNIST IDX, CIFAR-10 binary) and deterministic
//! synthetic stand-ins sized/shaped like the originals.
//!
//! The paper evaluates on MNIST and CIFAR-10. This environment has no
//! network access, so by default experiments use [`synth`] — deterministic
//! class-conditional generators with MNIST/CIFAR geometry (black border for
//! the label overlay, structured intra-class variation, inter-class
//! confusability). If real files are present under `data/mnist/` /
//! `data/cifar-10-batches-bin/` they are used instead (see
//! [`load_dataset`]). The substitution is documented in DESIGN.md.

pub mod cifar;
pub mod dataset;
pub mod mnist;
pub mod synth;

pub use dataset::{BatchIter, DataBundle, Dataset};

use anyhow::Result;

/// Which dataset an experiment runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// Real MNIST if present under `data/mnist/`, else panics.
    Mnist,
    /// Real CIFAR-10 if present under `data/cifar-10-batches-bin/`.
    Cifar10,
    /// Synthetic MNIST-geometry data (784-dim, 10 classes).
    SynthMnist,
    /// Synthetic CIFAR-geometry data (3072-dim, 10 classes, harder).
    SynthCifar,
}

impl std::fmt::Display for DatasetKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DatasetKind::Mnist => write!(f, "mnist"),
            DatasetKind::Cifar10 => write!(f, "cifar10"),
            DatasetKind::SynthMnist => write!(f, "synth-mnist"),
            DatasetKind::SynthCifar => write!(f, "synth-cifar"),
        }
    }
}

impl std::str::FromStr for DatasetKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "mnist" => Ok(DatasetKind::Mnist),
            "cifar10" | "cifar" => Ok(DatasetKind::Cifar10),
            "synth-mnist" => Ok(DatasetKind::SynthMnist),
            "synth-cifar" => Ok(DatasetKind::SynthCifar),
            other => anyhow::bail!("unknown dataset '{other}'"),
        }
    }
}

/// Load `kind` with at most `train_n`/`test_n` examples (0 = all), with
/// per-sample centering applied (see
/// [`dataset::Dataset::center_rows`] for why FF requires it).
/// Synthetic sets are generated deterministically from `seed`.
pub fn load_dataset(kind: DatasetKind, train_n: usize, test_n: usize, seed: u64) -> Result<DataBundle> {
    let mut bundle = load_dataset_raw(kind, train_n, test_n, seed)?;
    bundle.train.center_rows();
    bundle.test.center_rows();
    Ok(bundle)
}

/// [`load_dataset`] without the standardization pass (loaders/tests).
pub fn load_dataset_raw(kind: DatasetKind, train_n: usize, test_n: usize, seed: u64) -> Result<DataBundle> {
    match kind {
        DatasetKind::Mnist => mnist::load("data/mnist", train_n, test_n),
        DatasetKind::Cifar10 => cifar::load("data/cifar-10-batches-bin", train_n, test_n),
        DatasetKind::SynthMnist => {
            let tn = if train_n == 0 { 60_000 } else { train_n };
            let en = if test_n == 0 { 10_000 } else { test_n };
            Ok(synth::synth_mnist(tn, en, seed))
        }
        DatasetKind::SynthCifar => {
            let tn = if train_n == 0 { 50_000 } else { train_n };
            let en = if test_n == 0 { 10_000 } else { test_n };
            Ok(synth::synth_cifar(tn, en, seed))
        }
    }
}
