//! Deterministic synthetic datasets with MNIST/CIFAR geometry.
//!
//! Design goals (what FF actually needs from the data — DESIGN.md
//! substitution table):
//!
//! 1. **Black border** around the image so the label overlay occupies dead
//!    pixels (Hinton's trick requires the first 10 dims to carry no signal).
//! 2. **Class structure**: each class is a smooth prototype (sum of
//!    Gaussian bumps on the image grid) so a 1-hidden-layer net is far from
//!    trivial 100% but multi-layer FF can climb well past chance.
//! 3. **Confusability**: each sample mixes in a second "distractor" class
//!    prototype at low weight, so AdaptiveNEG's "most-predicted incorrect
//!    label" is meaningfully non-uniform (the property Table 1 exercises).
//! 4. **Determinism**: everything derives from one seed, so distributed
//!    nodes and repeated runs agree bit-for-bit.
//!
//! The CIFAR variant uses 3 channels, more bumps, heavier noise and
//! stronger distractor mixing — making it markedly harder, mirroring the
//! paper's MNIST ≫ CIFAR accuracy gap (Table 5).

use crate::data::dataset::{DataBundle, Dataset};
use crate::tensor::{Matrix, Rng};

/// Geometry + noise knobs for a synthetic set.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    /// Image side (square images).
    pub side: usize,
    /// Channels (1 = MNIST-like, 3 = CIFAR-like).
    pub channels: usize,
    /// Zero border width in pixels (label overlay lives here).
    pub border: usize,
    /// Gaussian bumps per class prototype.
    pub bumps: usize,
    /// Additive pixel noise σ.
    pub noise: f32,
    /// Weight of the distractor class prototype mixed into each sample.
    pub distractor: f32,
    /// Number of classes.
    pub classes: usize,
}

impl SynthSpec {
    /// MNIST-geometry spec: 28×28×1, 2-px border.
    pub fn mnist() -> Self {
        SynthSpec { side: 28, channels: 1, border: 2, bumps: 5, noise: 0.18, distractor: 0.25, classes: 10 }
    }

    /// CIFAR-geometry spec: 32×32×3 — noisier and far more confusable.
    pub fn cifar() -> Self {
        SynthSpec { side: 32, channels: 3, border: 2, bumps: 7, noise: 0.42, distractor: 0.55, classes: 10 }
    }

    /// Flat feature dimension.
    pub fn dim(&self) -> usize {
        self.side * self.side * self.channels
    }
}

/// Per-class prototype images in `[0,1]`, deterministic in `seed`.
fn prototypes(spec: &SynthSpec, seed: u64) -> Vec<Vec<f32>> {
    let mut protos = Vec::with_capacity(spec.classes);
    for c in 0..spec.classes {
        let mut rng = Rng::derive(seed, 0x5052_4F54 ^ c as u64); // "PROT"
        let mut img = vec![0.0f32; spec.dim()];
        for _ in 0..spec.bumps {
            // Bump center inside the non-border region.
            let lo = spec.border as f32 + 2.0;
            let hi = (spec.side - spec.border) as f32 - 3.0;
            let cx = lo + (hi - lo) * rng.f32();
            let cy = lo + (hi - lo) * rng.f32();
            let sig = 1.5 + 2.5 * rng.f32();
            let amp = 0.5 + 0.5 * rng.f32();
            let ch = rng.below(spec.channels);
            for y in 0..spec.side {
                for x in 0..spec.side {
                    let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                    let v = amp * (-d2 / (2.0 * sig * sig)).exp();
                    img[ch * spec.side * spec.side + y * spec.side + x] += v;
                }
            }
        }
        for v in &mut img {
            *v = v.min(1.0);
        }
        protos.push(img);
    }
    protos
}

/// Zero out the border band of every channel (keeps the overlay area dead).
fn apply_border(img: &mut [f32], spec: &SynthSpec) {
    let s = spec.side;
    for ch in 0..spec.channels {
        let base = ch * s * s;
        for y in 0..s {
            for x in 0..s {
                if y < spec.border || y >= s - spec.border || x < spec.border || x >= s - spec.border {
                    img[base + y * s + x] = 0.0;
                }
            }
        }
    }
}

/// Generate `n` samples from `spec`; stream tag distinguishes train/test.
fn generate(spec: &SynthSpec, n: usize, seed: u64, stream: u64) -> Dataset {
    let protos = prototypes(spec, seed);
    let dim = spec.dim();
    let mut x = Matrix::zeros(n, dim);
    let mut y = Vec::with_capacity(n);
    let mut rng = Rng::derive(seed, stream);
    for i in 0..n {
        let class = rng.below(spec.classes);
        let distractor = rng.wrong_label(class as u8, spec.classes) as usize;
        let intensity = 0.65 + 0.35 * rng.f32();
        let dw = spec.distractor * rng.f32();
        let row = x.row_mut(i);
        let (p, q) = (&protos[class], &protos[distractor]);
        for j in 0..dim {
            let v = intensity * p[j] + dw * q[j] + spec.noise * rng.normal();
            row[j] = v.clamp(0.0, 1.0);
        }
        apply_border(row, spec);
        y.push(class as u8);
    }
    Dataset { x, y, classes: spec.classes }
}

/// Synthetic MNIST-like bundle (784-dim, 10 classes).
pub fn synth_mnist(train_n: usize, test_n: usize, seed: u64) -> DataBundle {
    let spec = SynthSpec::mnist();
    DataBundle {
        train: generate(&spec, train_n, seed, 0x7452_4E00), // "tRN"
        test: generate(&spec, test_n, seed, 0x7445_5300),   // "tES"
        name: "synth-mnist".into(),
    }
}

/// Synthetic CIFAR-like bundle (3072-dim, 10 classes, harder).
pub fn synth_cifar(train_n: usize, test_n: usize, seed: u64) -> DataBundle {
    let spec = SynthSpec::cifar();
    DataBundle {
        train: generate(&spec, train_n, seed, 0x7452_4E01),
        test: generate(&spec, test_n, seed, 0x7445_5301),
        name: "synth-cifar".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnist_geometry() {
        let b = synth_mnist(50, 20, 1);
        assert_eq!(b.train.dim(), 784);
        assert_eq!(b.train.len(), 50);
        assert_eq!(b.test.len(), 20);
        assert!(b.train.x.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn border_pixels_are_zero() {
        let b = synth_mnist(10, 1, 2);
        for r in 0..10 {
            let row = b.train.x.row(r);
            // first 10 pixels live in the 2-px top border of a 28-wide image
            assert!(row[..28 * 2].iter().all(|&v| v == 0.0), "top border must be black");
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = synth_mnist(20, 5, 42);
        let b = synth_mnist(20, 5, 42);
        assert_eq!(a.train.x.data, b.train.x.data);
        assert_eq!(a.train.y, b.train.y);
        let c = synth_mnist(20, 5, 43);
        assert_ne!(a.train.x.data, c.train.x.data);
    }

    #[test]
    fn train_test_streams_differ() {
        let b = synth_mnist(20, 20, 7);
        assert_ne!(b.train.x.data, b.test.x.data);
    }

    #[test]
    fn classes_all_present() {
        let b = synth_mnist(500, 10, 3);
        assert!(b.train.class_histogram().iter().all(|&c| c > 10));
    }

    #[test]
    fn cifar_geometry_and_difficulty_knobs() {
        let spec_m = SynthSpec::mnist();
        let spec_c = SynthSpec::cifar();
        assert_eq!(spec_c.dim(), 3072);
        assert!(spec_c.noise > spec_m.noise);
        assert!(spec_c.distractor > spec_m.distractor);
        let b = synth_cifar(30, 10, 1);
        assert_eq!(b.train.dim(), 3072);
    }

    /// Same-class samples must be closer to their prototype than to other
    /// classes' prototypes on average — the separability FF relies on.
    #[test]
    fn class_structure_is_learnable() {
        let spec = SynthSpec::mnist();
        let protos = prototypes(&spec, 5);
        let d = generate(&spec, 200, 5, 99);
        let mut own = 0.0f64;
        let mut other = 0.0f64;
        let mut n_other = 0usize;
        for i in 0..d.len() {
            let row = d.x.row(i);
            let l = d.y[i] as usize;
            for (c, p) in protos.iter().enumerate() {
                let dot: f32 = row.iter().zip(p).map(|(a, b)| a * b).sum();
                if c == l {
                    own += f64::from(dot);
                } else {
                    other += f64::from(dot);
                    n_other += 1;
                }
            }
        }
        let own_mean = own / d.len() as f64;
        let other_mean = other / n_other as f64;
        assert!(
            own_mean > 1.3 * other_mean,
            "class signal too weak: own {own_mean:.3} vs other {other_mean:.3}"
        );
    }
}
