//! The compute contract between the coordinator (L3) and the math (L2/L1).
//!
//! Every numeric operation the schedulers need is behind [`Engine`]:
//!
//! * [`NativeEngine`] — pure-Rust reference implementation. Used as the
//!   numeric oracle for the XLA path, as the substrate for coordinator unit
//!   and property tests, and for artifact-free benches.
//! * [`XlaEngine`] — loads the AOT artifacts (`artifacts/*.hlo.txt`
//!   produced by `python/compile/aot.py` from the JAX/Pallas sources) and
//!   executes them on the PJRT CPU client. This is the production path —
//!   Python is never involved at run time.
//!
//! Engines are deliberately `&mut self`: the XLA engine caches compiled
//! executables and scratch buffers keyed by shape, and the native engine
//! owns a [`crate::tensor::Workspace`] buffer arena so its steady-state
//! train steps allocate nothing. Native kernels run multi-threaded over
//! [`crate::tensor::pool`] (`--threads` / `PFF_THREADS`) and are
//! bit-identical at every thread count.

pub mod native;
#[cfg(feature = "xla")]
pub mod xla;

pub use native::NativeEngine;
#[cfg(feature = "xla")]
pub use self::xla::XlaEngine;

use anyhow::Result;

use crate::config::EngineKind;
use crate::ff::layer::{FFLayer, FFStepStats, LinearHead};
use crate::tensor::{AdamState, Matrix};

/// Compute backend used by every scheduler, classifier and baseline.
///
/// All methods take the layer/head *parameter containers* by reference and
/// mutate them in place for the training steps, so the coordinator's
/// publish/fetch logic is byte-identical across backends.
pub trait Engine: Send {
    /// Human-readable backend name (for logs/reports).
    fn name(&self) -> &'static str;

    /// FF layer forward: `y = relu(x̂ · W + b)` where `x̂` is the row-wise
    /// length-normalized input iff `layer.normalize_input`.
    fn layer_forward(&mut self, layer: &FFLayer, x: &Matrix) -> Result<Matrix>;

    /// One FF minibatch update (§3): positive batch pushes goodness above
    /// `theta`, negative batch below; a single fused Adam step on `(W, b)`.
    ///
    /// `x_pos` and `x_neg` must have equal shape.
    fn ff_train_step(
        &mut self,
        layer: &mut FFLayer,
        opt: &mut AdamState,
        x_pos: &Matrix,
        x_neg: &Matrix,
        theta: f32,
        lr: f32,
    ) -> Result<FFStepStats>;

    /// Head logits: `x · W + b` (no softmax).
    fn head_logits(&mut self, head: &LinearHead, x: &Matrix) -> Result<Matrix>;

    /// Softmax-cross-entropy step on a linear head; returns mean CE loss.
    fn head_train_step(
        &mut self,
        head: &mut LinearHead,
        opt: &mut AdamState,
        x: &Matrix,
        labels: &[u8],
        lr: f32,
    ) -> Result<f32>;

    /// Performance-Optimized step (§4.4): joint CE update of
    /// `(layer, head)` with gradients stopped at the layer input; returns
    /// mean CE loss.
    #[allow(clippy::too_many_arguments)]
    fn perfopt_train_step(
        &mut self,
        layer: &mut FFLayer,
        head: &mut LinearHead,
        opt_layer: &mut AdamState,
        opt_head: &mut AdamState,
        x: &Matrix,
        labels: &[u8],
        lr: f32,
    ) -> Result<f32>;
}

/// How an experiment constructs its per-node engine. Each node thread calls
/// the factory exactly once, so non-`Send` backend internals (PJRT buffers)
/// never cross threads.
pub type EngineFactory = std::sync::Arc<dyn Fn() -> Result<Box<dyn Engine>> + Send + Sync>;

/// Factory for [`NativeEngine`]s.
pub fn native_factory() -> EngineFactory {
    std::sync::Arc::new(|| Ok(Box::new(NativeEngine::new()) as Box<dyn Engine>))
}

/// Factory for [`XlaEngine`]s reading from `artifact_dir`.
#[cfg(feature = "xla")]
pub fn xla_factory(artifact_dir: std::path::PathBuf) -> EngineFactory {
    std::sync::Arc::new(move || Ok(Box::new(XlaEngine::new(&artifact_dir)?) as Box<dyn Engine>))
}

/// Resolve a configured [`EngineKind`] to a concrete [`EngineFactory`] —
/// the backend-registry seam every experiment goes through.
///
/// With default features this build carries only the native backend;
/// selecting [`EngineKind::Xla`] then returns an error telling the user
/// to rebuild with `--features xla` instead of failing deep inside a
/// worker thread.
pub fn factory_for(kind: EngineKind, artifact_dir: &std::path::Path) -> Result<EngineFactory> {
    match kind {
        EngineKind::Native => Ok(native_factory()),
        EngineKind::Xla => xla_factory_for(artifact_dir),
    }
}

#[cfg(feature = "xla")]
fn xla_factory_for(artifact_dir: &std::path::Path) -> Result<EngineFactory> {
    Ok(xla_factory(artifact_dir.to_path_buf()))
}

// The factory seam's contract is pinned by `tests/engine_factory.rs`
// through the public API (native resolves and computes; Xla fails fast
// with a rebuild hint on default builds, resolves under `--features xla`).
#[cfg(not(feature = "xla"))]
fn xla_factory_for(_artifact_dir: &std::path::Path) -> Result<EngineFactory> {
    anyhow::bail!(
        "engine 'xla' is not compiled into this binary — rebuild with \
         `cargo build --features xla` (and generate AOT artifacts via \
         `python/compile/aot.py`; see README \"Build matrix\")"
    )
}
