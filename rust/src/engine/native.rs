//! Pure-Rust reference engine.
//!
//! Implements exactly the math of the L1 Pallas kernels / L2 JAX model
//! (`python/compile/`): the integration test `rust/tests/xla_vs_native.rs`
//! pins the two against each other through the AOT artifacts, and
//! `python/tests/test_kernel.py` pins the Pallas kernels against the jnp
//! oracle — so all three implementations agree.
//!
//! Compute runs through the multi-threaded kernels in
//! [`crate::tensor::ops`] (bit-identical at every thread count), and every
//! per-step scratch tensor comes from a [`Workspace`] arena the engine
//! owns: steady-state `ff_train_step` / `head_train_step` /
//! `perfopt_train_step` perform **zero heap allocation** (pinned by the
//! workspace-reuse test below). When `normalize_input` is off the input is
//! borrowed (`Cow::Borrowed`) instead of cloned.

use std::borrow::Cow;

use anyhow::Result;

use crate::engine::Engine;
use crate::ff::layer::{FFLayer, FFStepStats, LinearHead};
use crate::tensor::{ops, AdamState, Matrix, Workspace};

/// Epsilon for length normalization — matches `kernels/ref.py::EPS`.
pub const NORM_EPS: f32 = 1e-8;

/// Pure-Rust [`Engine`].
#[derive(Default, Debug, Clone)]
pub struct NativeEngine {
    ws: Workspace,
}

impl NativeEngine {
    /// Construct (cheap; the workspace arena fills lazily).
    pub fn new() -> Self {
        NativeEngine { ws: Workspace::default() }
    }

    /// Times the workspace could not serve a buffer from its free list —
    /// must stop growing once training reaches steady state (the
    /// zero-alloc acceptance knob).
    pub fn workspace_fresh_allocs(&self) -> usize {
        self.ws.fresh_allocs()
    }

    /// Park every scratch buffer of a step back into the arena.
    fn recycle_xn(&mut self, xn: Cow<'_, Matrix>) {
        if let Cow::Owned(m) = xn {
            self.ws.recycle(m);
        }
    }
}

/// Forward pass returning both the input actually fed to the matmul —
/// borrowed when no normalization is needed, arena-backed otherwise — and
/// the ReLU output; the train step needs `x̂` for the weight gradient.
fn forward_parts<'a>(
    ws: &mut Workspace,
    layer: &FFLayer,
    x: &'a Matrix,
) -> (Cow<'a, Matrix>, Matrix) {
    let xn: Cow<'a, Matrix> = if layer.normalize_input {
        let mut n = ws.matrix(x.rows, x.cols);
        ops::normalize_rows_into(&mut n, x, NORM_EPS);
        Cow::Owned(n)
    } else {
        Cow::Borrowed(x)
    };
    let mut z = ws.matrix(x.rows, layer.d_out());
    ops::matmul_into(&mut z, xn.as_ref(), &layer.w);
    ops::add_bias(&mut z, &layer.b);
    ops::relu_inplace(&mut z);
    (xn, z)
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn layer_forward(&mut self, layer: &FFLayer, x: &Matrix) -> Result<Matrix> {
        let (xn, z) = forward_parts(&mut self.ws, layer, x);
        self.recycle_xn(xn);
        Ok(z)
    }

    fn ff_train_step(
        &mut self,
        layer: &mut FFLayer,
        opt: &mut AdamState,
        x_pos: &Matrix,
        x_neg: &Matrix,
        theta: f32,
        lr: f32,
    ) -> Result<FFStepStats> {
        assert_eq!((x_pos.rows, x_pos.cols), (x_neg.rows, x_neg.cols));
        let b = x_pos.rows as f32;
        // One fused batch: rows [0, B) positive, [B, 2B) negative — same
        // layout the L1 kernel uses so a single matmul covers both passes.
        let mut x = self.ws.matrix(x_pos.rows * 2, x_pos.cols);
        x.data[..x_pos.data.len()].copy_from_slice(&x_pos.data);
        x.data[x_pos.data.len()..].copy_from_slice(&x_neg.data);
        let (xn, y) = forward_parts(&mut self.ws, layer, &x);
        // Goodness = MEAN of squared activations (paper Eq. 1 with the
        // 1/D "threshold coefficient" folded in). Mean — not sum — so a
        // fresh layer starts with g ≪ θ and the positive pass dominates
        // early training; with sums, g(init) > θ puts every unit under
        // uniform down-pressure and the all-positive inputs then kill the
        // whole layer (dead-ReLU collapse). Matches the reference FF
        // implementations.
        let d_out = layer.d_out() as f32;
        let n_rows = x.rows;
        let mut g = self.ws.vec(n_rows);
        ops::row_sumsq_into(&mut g, &y);
        for v in &mut g {
            *v /= d_out;
        }

        let mut stats = FFStepStats::default();
        // dL/dg per row, with the 1/(2B) batch-mean and the dg/dy = 2y/D
        // chain factor folded in below.
        let mut coef = self.ws.vec(n_rows);
        for (i, &gi) in g.iter().enumerate() {
            if i < x_pos.rows {
                // positive: L = softplus(θ - g), dL/dg = -σ(θ - g)
                stats.loss_pos += ops::softplus(theta - gi);
                stats.goodness_pos += gi;
                coef[i] = -ops::sigmoid(theta - gi);
            } else {
                // negative: L = softplus(g - θ), dL/dg = σ(g - θ)
                stats.loss_neg += ops::softplus(gi - theta);
                stats.goodness_neg += gi;
                coef[i] = ops::sigmoid(gi - theta);
            }
        }
        stats.loss_pos /= b;
        stats.loss_neg /= b;
        stats.goodness_pos /= b;
        stats.goodness_neg /= b;

        // dz = coef ⊙ 2y / (2B·D)  (ReLU mask implicit: y == 0 ⇒ dz == 0)
        let mut dz = y;
        let scale = 1.0 / (2.0 * b * d_out);
        for r in 0..n_rows {
            let c = coef[r] * 2.0 * scale;
            for v in dz.row_mut(r) {
                *v *= c;
            }
        }
        let mut dw = self.ws.matrix(layer.d_in(), layer.d_out());
        ops::matmul_at_b_into(&mut dw, xn.as_ref(), &dz);
        let mut db = self.ws.vec(layer.d_out());
        ops::col_sum_into(&mut db, &dz);
        opt.step(&mut layer.w, &mut layer.b, &dw, &db, lr);
        self.recycle_xn(xn);
        self.ws.recycle(x);
        self.ws.recycle(dz);
        self.ws.recycle(dw);
        self.ws.recycle_vec(db);
        self.ws.recycle_vec(g);
        self.ws.recycle_vec(coef);
        Ok(stats)
    }

    fn head_logits(&mut self, head: &LinearHead, x: &Matrix) -> Result<Matrix> {
        let mut z = self.ws.matrix(x.rows, head.w.cols);
        ops::matmul_into(&mut z, x, &head.w);
        ops::add_bias(&mut z, &head.b);
        Ok(z)
    }

    fn head_train_step(
        &mut self,
        head: &mut LinearHead,
        opt: &mut AdamState,
        x: &Matrix,
        labels: &[u8],
        lr: f32,
    ) -> Result<f32> {
        assert_eq!(x.rows, labels.len());
        let mut dlogits = self.head_logits(head, x)?;
        ops::softmax_rows_inplace(&mut dlogits);
        let loss = ops::cross_entropy(&dlogits, labels);
        // dlogits = (p - onehot) / B
        let inv_b = 1.0 / x.rows as f32;
        for (r, &l) in labels.iter().enumerate() {
            let row = dlogits.row_mut(r);
            row[l as usize] -= 1.0;
            for v in row {
                *v *= inv_b;
            }
        }
        let mut dw = self.ws.matrix(head.w.rows, head.w.cols);
        ops::matmul_at_b_into(&mut dw, x, &dlogits);
        let mut db = self.ws.vec(head.w.cols);
        ops::col_sum_into(&mut db, &dlogits);
        opt.step(&mut head.w, &mut head.b, &dw, &db, lr);
        self.ws.recycle(dlogits);
        self.ws.recycle(dw);
        self.ws.recycle_vec(db);
        Ok(loss)
    }

    fn perfopt_train_step(
        &mut self,
        layer: &mut FFLayer,
        head: &mut LinearHead,
        opt_layer: &mut AdamState,
        opt_head: &mut AdamState,
        x: &Matrix,
        labels: &[u8],
        lr: f32,
    ) -> Result<f32> {
        assert_eq!(x.rows, labels.len());
        let (xn, y) = forward_parts(&mut self.ws, layer, x);
        let mut dlogits = self.ws.matrix(x.rows, head.w.cols);
        ops::matmul_into(&mut dlogits, &y, &head.w);
        ops::add_bias(&mut dlogits, &head.b);
        ops::softmax_rows_inplace(&mut dlogits);
        let loss = ops::cross_entropy(&dlogits, labels);

        let inv_b = 1.0 / x.rows as f32;
        for (r, &l) in labels.iter().enumerate() {
            let row = dlogits.row_mut(r);
            row[l as usize] -= 1.0;
            for v in row {
                *v *= inv_b;
            }
        }
        // Head gradients.
        let mut dwh = self.ws.matrix(head.w.rows, head.w.cols);
        ops::matmul_at_b_into(&mut dwh, &y, &dlogits);
        let mut dbh = self.ws.vec(head.w.cols);
        ops::col_sum_into(&mut dbh, &dlogits);
        // Layer gradients through ReLU: dz = (dlogits · Wᵀ) ⊙ [y > 0].
        let mut dz = self.ws.matrix(x.rows, head.w.rows);
        ops::matmul_a_bt_into(&mut dz, &dlogits, &head.w);
        for (dv, yv) in dz.data.iter_mut().zip(&y.data) {
            if *yv <= 0.0 {
                *dv = 0.0;
            }
        }
        let mut dwl = self.ws.matrix(layer.d_in(), layer.d_out());
        ops::matmul_at_b_into(&mut dwl, xn.as_ref(), &dz);
        let mut dbl = self.ws.vec(layer.d_out());
        ops::col_sum_into(&mut dbl, &dz);
        // Gradients stop here — x̂'s producer is never touched (§4.4).
        opt_head.step(&mut head.w, &mut head.b, &dwh, &dbh, lr);
        opt_layer.step(&mut layer.w, &mut layer.b, &dwl, &dbl, lr);
        self.recycle_xn(xn);
        self.ws.recycle(y);
        self.ws.recycle(dlogits);
        self.ws.recycle(dwh);
        self.ws.recycle(dz);
        self.ws.recycle(dwl);
        self.ws.recycle_vec(dbh);
        self.ws.recycle_vec(dbl);
        Ok(loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn setup(d_in: usize, d_out: usize, norm: bool, seed: u64) -> (FFLayer, AdamState, Rng) {
        let mut rng = Rng::new(seed);
        let layer = FFLayer::new(d_in, d_out, norm, &mut rng);
        let opt = AdamState::new(d_in, d_out);
        (layer, opt, rng)
    }

    #[test]
    fn forward_nonnegative_and_shape() {
        let (layer, _, mut rng) = setup(10, 7, true, 1);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(4, 10, -1.0, 1.0, &mut rng);
        let y = eng.layer_forward(&layer, &x).unwrap();
        assert_eq!((y.rows, y.cols), (4, 7));
        assert!(y.data.iter().all(|&v| v >= 0.0));
    }

    /// The FF objective must grow the pos/neg goodness margin when the
    /// positive and negative inputs are actually distinguishable.
    #[test]
    fn ff_training_separates_goodness() {
        let (mut layer, mut opt, mut rng) = setup(20, 32, false, 2);
        let mut eng = NativeEngine::new();
        // pos: energy in first half of dims; neg: second half.
        let mut x_pos = Matrix::rand_uniform(32, 20, 0.0, 0.1, &mut rng);
        let mut x_neg = Matrix::rand_uniform(32, 20, 0.0, 0.1, &mut rng);
        for r in 0..32 {
            for c in 0..10 {
                x_pos.row_mut(r)[c] += 1.0;
                x_neg.row_mut(r)[10 + c] += 1.0;
            }
        }
        let first = eng.ff_train_step(&mut layer, &mut opt, &x_pos, &x_neg, 2.0, 0.01).unwrap();
        let mut last = first;
        for _ in 0..200 {
            last = eng.ff_train_step(&mut layer, &mut opt, &x_pos, &x_neg, 2.0, 0.01).unwrap();
        }
        assert!(
            last.margin() > first.margin() + 1.0,
            "margin should grow: first {} last {}",
            first.margin(),
            last.margin()
        );
        assert!(last.loss() < first.loss(), "loss should fall");
    }

    /// Steady-state training must not touch the allocator: after a warmup
    /// step per shape, every scratch buffer comes from the workspace arena
    /// (the PR's zero-alloc acceptance criterion).
    #[test]
    fn train_steps_are_zero_alloc_in_steady_state() {
        let (mut layer, mut opt, mut rng) = setup(24, 40, true, 8);
        let mut eng = NativeEngine::new();
        let x_pos = Matrix::rand_uniform(16, 24, 0.0, 1.0, &mut rng);
        let x_neg = Matrix::rand_uniform(16, 24, 0.0, 1.0, &mut rng);
        let labels: Vec<u8> = (0..16).map(|i| (i % 4) as u8).collect();
        let mut head = LinearHead::new(24, 4, &mut rng);
        let mut hopt = AdamState::new(24, 4);
        let mut po_layer = FFLayer::new(24, 40, false, &mut rng);
        let mut po_head = LinearHead::new(40, 4, &mut rng);
        let (mut po_lo, mut po_ho) = (AdamState::new(24, 40), AdamState::new(40, 4));

        for _ in 0..3 {
            eng.ff_train_step(&mut layer, &mut opt, &x_pos, &x_neg, 2.0, 0.01).unwrap();
            eng.head_train_step(&mut head, &mut hopt, &x_pos, &labels, 0.01).unwrap();
            eng.perfopt_train_step(
                &mut po_layer, &mut po_head, &mut po_lo, &mut po_ho, &x_pos, &labels, 0.01,
            )
            .unwrap();
        }
        let baseline = eng.workspace_fresh_allocs();
        for _ in 0..32 {
            eng.ff_train_step(&mut layer, &mut opt, &x_pos, &x_neg, 2.0, 0.01).unwrap();
            eng.head_train_step(&mut head, &mut hopt, &x_pos, &labels, 0.01).unwrap();
            eng.perfopt_train_step(
                &mut po_layer, &mut po_head, &mut po_lo, &mut po_ho, &x_pos, &labels, 0.01,
            )
            .unwrap();
        }
        assert_eq!(
            eng.workspace_fresh_allocs(),
            baseline,
            "steady-state train steps must reuse arena buffers, not allocate"
        );
    }

    /// Without normalization a layer could pass goodness straight through;
    /// with it, the input magnitude is erased.
    #[test]
    fn normalization_erases_magnitude() {
        let (layer, _, mut rng) = setup(12, 8, true, 3);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(3, 12, 0.1, 1.0, &mut rng);
        let mut x_scaled = x.clone();
        for v in &mut x_scaled.data {
            *v *= 37.0;
        }
        let y1 = eng.layer_forward(&layer, &x).unwrap();
        let y2 = eng.layer_forward(&layer, &x_scaled).unwrap();
        assert!(y1.max_abs_diff(&y2) < 1e-4);
    }

    #[test]
    fn head_train_reduces_ce() {
        let mut rng = Rng::new(4);
        let mut head = LinearHead::new(16, 10, &mut rng);
        let mut opt = AdamState::new(16, 10);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(64, 16, 0.0, 1.0, &mut rng);
        let labels: Vec<u8> = (0..64).map(|i| (i % 10) as u8).collect();
        let first = eng.head_train_step(&mut head, &mut opt, &x, &labels, 0.05).unwrap();
        let mut last = first;
        for _ in 0..100 {
            last = eng.head_train_step(&mut head, &mut opt, &x, &labels, 0.05).unwrap();
        }
        assert!(last < first * 0.8, "CE should fall: {first} -> {last}");
    }

    #[test]
    fn perfopt_learns_separable_classes() {
        let mut rng = Rng::new(5);
        let mut layer = FFLayer::new(20, 24, false, &mut rng);
        let mut head = LinearHead::new(24, 4, &mut rng);
        let (mut ol, mut oh) = (AdamState::new(20, 24), AdamState::new(24, 4));
        let mut eng = NativeEngine::new();
        // 4 classes, each a distinct 5-dim block lit up.
        let n = 64;
        let mut x = Matrix::rand_uniform(n, 20, 0.0, 0.1, &mut rng);
        let labels: Vec<u8> = (0..n).map(|i| (i % 4) as u8).collect();
        for (r, &l) in labels.iter().enumerate() {
            for c in 0..5 {
                x.row_mut(r)[l as usize * 5 + c] += 1.0;
            }
        }
        let first =
            eng.perfopt_train_step(&mut layer, &mut head, &mut ol, &mut oh, &x, &labels, 0.01)
                .unwrap();
        let mut last = first;
        for _ in 0..200 {
            last = eng
                .perfopt_train_step(&mut layer, &mut head, &mut ol, &mut oh, &x, &labels, 0.01)
                .unwrap();
        }
        assert!(last < 0.1, "perfopt CE should converge, got {last} (from {first})");
    }

    /// Numerical gradient check of the FF layer loss wrt one weight.
    #[test]
    fn ff_gradient_matches_finite_difference() {
        let mut rng = Rng::new(6);
        let layer = FFLayer::new(6, 5, true, &mut rng);
        let x_pos = Matrix::rand_uniform(4, 6, 0.0, 1.0, &mut rng);
        let x_neg = Matrix::rand_uniform(4, 6, 0.0, 1.0, &mut rng);
        let theta = 1.5f32;

        let d_out = 5.0f32;
        let loss_of = |l: &FFLayer| -> f64 {
            let mut ws = Workspace::new();
            let (_, y) = forward_parts(&mut ws, l, &x_pos.vcat(&x_neg));
            let g: Vec<f32> = ops::row_sumsq(&y).iter().map(|v| v / d_out).collect();
            let b = x_pos.rows as f64;
            let mut loss = 0.0f64;
            for (i, &gi) in g.iter().enumerate() {
                let t = if i < x_pos.rows { theta - gi } else { gi - theta };
                loss += f64::from(ops::softplus(t));
            }
            loss / (2.0 * b) * 2.0 // mean over 2B of (pos+neg), matches step scaling
        };

        // Analytic gradient via the same code path the engine uses.
        let mut ws = Workspace::new();
        let x = x_pos.vcat(&x_neg); // bound: xn borrows it past this statement
        let (xn, y) = forward_parts(&mut ws, &layer, &x);
        let g: Vec<f32> = ops::row_sumsq(&y).iter().map(|v| v / d_out).collect();
        let mut dz = y.clone();
        let scale = 1.0 / (2.0 * x_pos.rows as f32 * d_out);
        for (i, &gi) in g.iter().enumerate() {
            let c = if i < x_pos.rows {
                -ops::sigmoid(theta - gi)
            } else {
                ops::sigmoid(gi - theta)
            } * 2.0
                * scale;
            for v in dz.row_mut(i) {
                *v *= c;
            }
        }
        let dw = ops::matmul_at_b(xn.as_ref(), &dz);

        // Finite differences on a handful of entries.
        let h = 1e-3f32;
        for &(r, c) in &[(0usize, 0usize), (2, 3), (5, 4), (3, 1)] {
            let mut lp = layer.clone();
            lp.w.data[r * 5 + c] += h;
            let mut lm = layer.clone();
            lm.w.data[r * 5 + c] -= h;
            let num = (loss_of(&lp) - loss_of(&lm)) / (2.0 * f64::from(h));
            let ana = f64::from(dw.data[r * 5 + c]) * 2.0; // loss_of uses mean·2 scaling
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + ana.abs()),
                "grad mismatch at ({r},{c}): numeric {num}, analytic {ana}"
            );
        }
    }
}
