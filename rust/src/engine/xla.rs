//! PJRT-backed [`Engine`]: every training/prediction step is one AOT
//! XLA module execution (the L2 JAX function, with the L1 Pallas kernels
//! fused inside). Python never runs here — only the HLO text it left in
//! `artifacts/`.
//!
//! Batch handling: HLO modules are shape-static. Each op is lowered for
//! one batch size `B`; this engine pads smaller batches with zero rows and
//! passes a 0/1 `mask` so padded rows contribute nothing to losses or
//! gradients, and loops row-chunks of `B` for larger inputs (evaluation
//! sweeps).

use std::path::Path;

use anyhow::{ensure, Result};

use crate::engine::Engine;
use crate::ff::layer::{FFLayer, FFStepStats, LinearHead};
use crate::runtime::{
    literal_matrix, literal_scalar, literal_vec, matrix_literal, scalar_literal, vec_literal,
    ManifestEntry, Runtime,
};
use crate::tensor::{AdamState, Matrix};

/// [`Engine`] backed by AOT HLO artifacts on the PJRT CPU client.
pub struct XlaEngine {
    rt: Runtime,
}

// SAFETY: the PJRT wrapper types hold raw pointers without Send, but an
// `XlaEngine` is owned by exactly one node thread for its whole life (the
// EngineFactory constructs it on the worker thread; nothing is shared).
// `Send` is only needed to move the freshly-built Box into that thread /
// out at join. PJRT's CPU client itself is thread-safe for compile/execute.
unsafe impl Send for XlaEngine {}

impl XlaEngine {
    /// Open `artifact_dir` (must contain `manifest.txt`; see `make
    /// artifacts`).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<XlaEngine> {
        Ok(XlaEngine { rt: Runtime::open(artifact_dir)? })
    }

    /// Access the underlying runtime (tests/benches).
    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Pad `m` to exactly `rows` rows with zeros (no-op when equal).
    fn pad_rows(m: &Matrix, rows: usize) -> Matrix {
        if m.rows == rows {
            return m.clone();
        }
        let mut out = Matrix::zeros(rows, m.cols);
        out.data[..m.rows * m.cols].copy_from_slice(&m.data);
        out
    }

    /// 0/1 mask marking the first `real` of `total` rows.
    fn mask(real: usize, total: usize) -> Vec<f32> {
        let mut v = vec![0.0f32; total];
        v[..real].fill(1.0);
        v
    }

    /// One-hot matrix for labels (padded rows stay all-zero).
    fn onehot(labels: &[u8], classes: usize, rows: usize) -> Matrix {
        let mut m = Matrix::zeros(rows, classes);
        for (r, &l) in labels.iter().enumerate() {
            m.data[r * classes + l as usize] = 1.0;
        }
        m
    }

    fn opt_literals(opt: &AdamState) -> Result<Vec<xla::Literal>> {
        Ok(vec![
            matrix_literal(&opt.m_w)?,
            matrix_literal(&opt.v_w)?,
            vec_literal(&opt.m_b),
            vec_literal(&opt.v_b),
        ])
    }

    /// Chunked forward through a shape-static module: pads the tail chunk.
    fn forward_chunks(
        &mut self,
        entry: &ManifestEntry,
        w: &Matrix,
        b: &[f32],
        x: &Matrix,
    ) -> Result<Matrix> {
        let bsz = entry.batch;
        let mut out = Matrix::zeros(x.rows, entry.dout);
        let mut r0 = 0;
        while r0 < x.rows {
            let r1 = (r0 + bsz).min(x.rows);
            let rows: Vec<usize> = (r0..r1).collect();
            let chunk = Self::pad_rows(&x.gather_rows(&rows), bsz);
            let outs = self.rt.run(
                entry,
                &[matrix_literal(w)?, vec_literal(b), matrix_literal(&chunk)?],
            )?;
            ensure!(outs.len() == 1, "{}: expected 1 output, got {}", entry.op, outs.len());
            let y = literal_matrix(&outs[0], bsz, entry.dout)?;
            out.data[r0 * entry.dout..r1 * entry.dout]
                .copy_from_slice(&y.data[..(r1 - r0) * entry.dout]);
            r0 = r1;
        }
        Ok(out)
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn layer_forward(&mut self, layer: &FFLayer, x: &Matrix) -> Result<Matrix> {
        let entry = self.rt.entry("layer_fwd", layer.d_in(), layer.d_out(), layer.normalize_input)?;
        self.forward_chunks(&entry, &layer.w, &layer.b, x)
    }

    fn ff_train_step(
        &mut self,
        layer: &mut FFLayer,
        opt: &mut AdamState,
        x_pos: &Matrix,
        x_neg: &Matrix,
        theta: f32,
        lr: f32,
    ) -> Result<FFStepStats> {
        let entry = self.rt.entry("ff_step", layer.d_in(), layer.d_out(), layer.normalize_input)?;
        let bsz = entry.batch;
        ensure!(
            x_pos.rows <= bsz,
            "ff_step: batch {} exceeds artifact batch {bsz}",
            x_pos.rows
        );
        let real = x_pos.rows;
        let xp = Self::pad_rows(x_pos, bsz);
        let xn = Self::pad_rows(x_neg, bsz);
        let mask = Self::mask(real, bsz);

        let mut inputs = vec![matrix_literal(&layer.w)?, vec_literal(&layer.b)];
        inputs.extend(Self::opt_literals(opt)?);
        inputs.push(scalar_literal((opt.t + 1) as f32));
        inputs.push(matrix_literal(&xp)?);
        inputs.push(matrix_literal(&xn)?);
        inputs.push(vec_literal(&mask));
        inputs.push(scalar_literal(theta));
        inputs.push(scalar_literal(lr));

        let outs = self.rt.run(&entry, &inputs)?;
        ensure!(outs.len() == 10, "ff_step: expected 10 outputs, got {}", outs.len());
        layer.w = literal_matrix(&outs[0], layer.w.rows, layer.w.cols)?;
        layer.b = literal_vec(&outs[1])?;
        opt.m_w = literal_matrix(&outs[2], opt.m_w.rows, opt.m_w.cols)?;
        opt.v_w = literal_matrix(&outs[3], opt.v_w.rows, opt.v_w.cols)?;
        opt.m_b = literal_vec(&outs[4])?;
        opt.v_b = literal_vec(&outs[5])?;
        opt.t += 1;
        Ok(FFStepStats {
            loss_pos: literal_scalar(&outs[6])?,
            loss_neg: literal_scalar(&outs[7])?,
            goodness_pos: literal_scalar(&outs[8])?,
            goodness_neg: literal_scalar(&outs[9])?,
        })
    }

    fn head_logits(&mut self, head: &LinearHead, x: &Matrix) -> Result<Matrix> {
        let entry = self.rt.entry("head_logits", head.w.rows, head.w.cols, false)?;
        self.forward_chunks(&entry, &head.w, &head.b, x)
    }

    fn head_train_step(
        &mut self,
        head: &mut LinearHead,
        opt: &mut AdamState,
        x: &Matrix,
        labels: &[u8],
        lr: f32,
    ) -> Result<f32> {
        let entry = self.rt.entry("head_step", head.w.rows, head.w.cols, false)?;
        let bsz = entry.batch;
        ensure!(x.rows <= bsz, "head_step: batch {} exceeds artifact batch {bsz}", x.rows);
        let real = x.rows;
        let xp = Self::pad_rows(x, bsz);
        let onehot = Self::onehot(labels, head.w.cols, bsz);
        let mask = Self::mask(real, bsz);

        let mut inputs = vec![matrix_literal(&head.w)?, vec_literal(&head.b)];
        inputs.extend(Self::opt_literals(opt)?);
        inputs.push(scalar_literal((opt.t + 1) as f32));
        inputs.push(matrix_literal(&xp)?);
        inputs.push(matrix_literal(&onehot)?);
        inputs.push(vec_literal(&mask));
        inputs.push(scalar_literal(lr));

        let outs = self.rt.run(&entry, &inputs)?;
        ensure!(outs.len() == 7, "head_step: expected 7 outputs, got {}", outs.len());
        head.w = literal_matrix(&outs[0], head.w.rows, head.w.cols)?;
        head.b = literal_vec(&outs[1])?;
        opt.m_w = literal_matrix(&outs[2], opt.m_w.rows, opt.m_w.cols)?;
        opt.v_w = literal_matrix(&outs[3], opt.v_w.rows, opt.v_w.cols)?;
        opt.m_b = literal_vec(&outs[4])?;
        opt.v_b = literal_vec(&outs[5])?;
        opt.t += 1;
        literal_scalar(&outs[6])
    }

    fn perfopt_train_step(
        &mut self,
        layer: &mut FFLayer,
        head: &mut LinearHead,
        opt_layer: &mut AdamState,
        opt_head: &mut AdamState,
        x: &Matrix,
        labels: &[u8],
        lr: f32,
    ) -> Result<f32> {
        let entry =
            self.rt.entry("perfopt_step", layer.d_in(), layer.d_out(), layer.normalize_input)?;
        let bsz = entry.batch;
        ensure!(x.rows <= bsz, "perfopt_step: batch {} exceeds artifact batch {bsz}", x.rows);
        let real = x.rows;
        let xp = Self::pad_rows(x, bsz);
        let onehot = Self::onehot(labels, head.w.cols, bsz);
        let mask = Self::mask(real, bsz);

        let mut inputs = vec![
            matrix_literal(&layer.w)?,
            vec_literal(&layer.b),
            matrix_literal(&head.w)?,
            vec_literal(&head.b),
        ];
        inputs.extend(Self::opt_literals(opt_layer)?);
        inputs.extend(Self::opt_literals(opt_head)?);
        inputs.push(scalar_literal((opt_layer.t + 1) as f32));
        inputs.push(matrix_literal(&xp)?);
        inputs.push(matrix_literal(&onehot)?);
        inputs.push(vec_literal(&mask));
        inputs.push(scalar_literal(lr));

        let outs = self.rt.run(&entry, &inputs)?;
        ensure!(outs.len() == 13, "perfopt_step: expected 13 outputs, got {}", outs.len());
        layer.w = literal_matrix(&outs[0], layer.w.rows, layer.w.cols)?;
        layer.b = literal_vec(&outs[1])?;
        head.w = literal_matrix(&outs[2], head.w.rows, head.w.cols)?;
        head.b = literal_vec(&outs[3])?;
        opt_layer.m_w = literal_matrix(&outs[4], opt_layer.m_w.rows, opt_layer.m_w.cols)?;
        opt_layer.v_w = literal_matrix(&outs[5], opt_layer.v_w.rows, opt_layer.v_w.cols)?;
        opt_layer.m_b = literal_vec(&outs[6])?;
        opt_layer.v_b = literal_vec(&outs[7])?;
        opt_head.m_w = literal_matrix(&outs[8], opt_head.m_w.rows, opt_head.m_w.cols)?;
        opt_head.v_w = literal_matrix(&outs[9], opt_head.v_w.rows, opt_head.v_w.cols)?;
        opt_head.m_b = literal_vec(&outs[10])?;
        opt_head.v_b = literal_vec(&outs[11])?;
        opt_layer.t += 1;
        opt_head.t += 1;
        literal_scalar(&outs[12])
    }
}
