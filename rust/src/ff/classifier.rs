//! The two prediction modes of §3 / §5.3.
//!
//! * **Goodness**: run the input once per candidate label overlay and pick
//!   the label whose accumulated goodness over all-but-the-first layer is
//!   highest. Matches the training objective; 10× forward cost.
//! * **Softmax**: overlay the neutral label, collect normalized activations
//!   of all-but-the-first layer, and classify with a linear head trained by
//!   cross-entropy. Single pass; slightly less accurate on MNIST (Table 2).

use anyhow::Result;

use crate::engine::Engine;
use crate::ff::network::FFNetwork;
use crate::ff::overlay::overlay_neutral;
use crate::ff::LinearHead;
use crate::tensor::{ops, Matrix};

/// Which classifier the experiment uses (paper Tables 1–3 column axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClassifierMode {
    /// Per-class goodness accumulation (§3 "Goodness prediction").
    Goodness,
    /// Neutral-overlay + linear softmax head (§3 "Softmax prediction").
    Softmax,
}

impl std::fmt::Display for ClassifierMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClassifierMode::Goodness => write!(f, "Goodness"),
            ClassifierMode::Softmax => write!(f, "Softmax"),
        }
    }
}

/// Per-class goodness scores for raw (label-free) inputs `x`:
/// `scores[i][c] = Σ_{l ≥ 1} g_l(overlay(x_i, c))`.
///
/// All but the *first* hidden layer contribute (the first layer mostly
/// encodes the overlay itself, so it is excluded — §3).
///
/// All `classes` overlay variants are stacked into ONE tall batch so each
/// layer runs a single large matmul instead of `classes` small ones
/// (§Perf iteration 7: the weight panes amortize over 10× the rows).
/// Callers chunk `x` (`eval_chunk`), bounding the stacked tensor.
pub fn goodness_scores(eng: &mut dyn Engine, net: &FFNetwork, x: &Matrix) -> Result<Matrix> {
    let n = x.rows;
    let classes = net.classes;
    assert!(x.cols >= classes, "input dim {} < classes {classes}", x.cols);
    // rows [c*n, (c+1)*n) hold overlay class c — appended straight into
    // reserved capacity (no zero-fill pass, no per-class intermediate).
    let mut data = Vec::with_capacity(n * classes * x.cols);
    for c in 0..classes {
        let start = data.len();
        data.extend_from_slice(&x.data);
        for r in 0..n {
            let overlay = &mut data[start + r * x.cols..start + r * x.cols + classes];
            overlay.fill(0.0);
            overlay[c] = 1.0;
        }
    }
    let stacked = Matrix::from_vec(n * classes, x.cols, data);
    let mut scores = Matrix::zeros(n, classes);
    let mut h = stacked;
    for (l, layer) in net.layers.iter().enumerate() {
        h = eng.layer_forward(layer, &h)?;
        if l >= 1 {
            // mean-of-squares goodness (see engine::native) — also
            // weights equally-wide layers equally in the accumulation
            let inv_d = 1.0 / h.cols as f32;
            let g = ops::row_sumsq(&h);
            for c in 0..classes {
                for i in 0..n {
                    scores.data[i * classes + c] += g[c * n + i] * inv_d;
                }
            }
        }
    }
    Ok(scores)
}

/// Goodness-mode prediction: argmax over [`goodness_scores`].
pub fn predict_goodness(eng: &mut dyn Engine, net: &FFNetwork, x: &Matrix) -> Result<Vec<u8>> {
    Ok(ops::argmax_rows(&goodness_scores(eng, net, x)?))
}

/// Feature vector for the softmax head: neutral overlay, forward pass,
/// concatenate **length-normalized** activations of layers `1..L`.
pub fn head_features(eng: &mut dyn Engine, net: &FFNetwork, x: &Matrix) -> Result<Matrix> {
    let xn = overlay_neutral(x, net.classes);
    let outs = net.forward_all(eng, &xn)?;
    let mut feats: Option<Matrix> = None;
    for out in outs.iter().skip(1) {
        let n = ops::normalize_rows(out, 1e-8);
        feats = Some(match feats {
            None => n,
            Some(f) => f.hcat(&n),
        });
    }
    Ok(feats.expect("softmax head needs ≥2 layers"))
}

/// Softmax-mode prediction through a trained head.
pub fn predict_softmax(
    eng: &mut dyn Engine,
    net: &FFNetwork,
    head: &LinearHead,
    x: &Matrix,
) -> Result<Vec<u8>> {
    let feats = head_features(eng, net, x)?;
    let logits = eng.head_logits(head, &feats)?;
    Ok(ops::argmax_rows(&logits))
}

/// Fraction of `pred` equal to `truth`.
pub fn accuracy(pred: &[u8], truth: &[u8]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(truth).filter(|(a, b)| a == b).count();
    hits as f64 / pred.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::tensor::Rng;

    #[test]
    fn goodness_scores_shape() {
        let mut rng = Rng::new(21);
        let net = FFNetwork::new(&[16, 8, 8], 10, &mut rng);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(5, 16, 0.0, 1.0, &mut rng);
        let s = goodness_scores(&mut eng, &net, &x).unwrap();
        assert_eq!((s.rows, s.cols), (5, 10));
        assert!(s.data.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn head_features_dim() {
        let mut rng = Rng::new(22);
        let net = FFNetwork::new(&[16, 8, 6, 4], 10, &mut rng);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(3, 16, 0.0, 1.0, &mut rng);
        let f = head_features(&mut eng, &net, &x).unwrap();
        assert_eq!((f.rows, f.cols), (3, 10)); // 6 + 4
    }

    #[test]
    fn accuracy_counts() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 4]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
    }
}
