//! Parameter containers for FF layers and linear (softmax) heads.
//!
//! Deliberately *just data*: all math goes through
//! [`crate::engine::Engine`] so the same coordinator drives both the native
//! and the PJRT/XLA implementations.

use crate::tensor::{Matrix, Rng};

/// One fully-connected ReLU layer trained with the FF objective.
#[derive(Clone, Debug)]
pub struct FFLayer {
    /// Weights, `(d_in, d_out)` row-major.
    pub w: Matrix,
    /// Bias, `d_out`.
    pub b: Vec<f32>,
    /// Whether this layer length-normalizes its input first. First layer:
    /// `false` (raw overlaid pixels); hidden layers: `true` (Hinton's rule —
    /// only the *direction* of the previous activity is passed on).
    pub normalize_input: bool,
}

impl FFLayer {
    /// Random init: `W ~ N(0, 1/d_in)`, `b = 0`.
    pub fn new(d_in: usize, d_out: usize, normalize_input: bool, rng: &mut Rng) -> Self {
        FFLayer { w: Matrix::randn_scaled(d_in, d_out, rng), b: vec![0.0; d_out], normalize_input }
    }

    /// Input dimensionality.
    pub fn d_in(&self) -> usize {
        self.w.rows
    }

    /// Output dimensionality.
    pub fn d_out(&self) -> usize {
        self.w.cols
    }

    /// Parameter count (weights + bias).
    pub fn param_count(&self) -> usize {
        self.w.rows * self.w.cols + self.b.len()
    }

    /// Serialized size in bytes on the wire (f32 params + shape header).
    /// This is what one PFF publish costs — the paper's key communication
    /// advantage over DFF (which ships *activations* for the whole dataset).
    pub fn wire_bytes(&self) -> u64 {
        (self.param_count() * 4 + 16) as u64
    }
}

/// A linear classification head (`d_in → classes`), trained with softmax
/// cross-entropy. Used by the Softmax classifier mode and by every layer of
/// the Performance-Optimized variant.
#[derive(Clone, Debug)]
pub struct LinearHead {
    /// Weights, `(d_in, classes)`.
    pub w: Matrix,
    /// Bias, `classes`.
    pub b: Vec<f32>,
}

impl LinearHead {
    /// Random init, same scaling as layers.
    pub fn new(d_in: usize, classes: usize, rng: &mut Rng) -> Self {
        LinearHead { w: Matrix::randn_scaled(d_in, classes, rng), b: vec![0.0; classes] }
    }

    /// Number of classes.
    pub fn classes(&self) -> usize {
        self.w.cols
    }

    /// Parameter count.
    pub fn param_count(&self) -> usize {
        self.w.rows * self.w.cols + self.b.len()
    }
}

/// Scalar diagnostics from one FF minibatch step.
#[derive(Clone, Copy, Debug, Default)]
pub struct FFStepStats {
    /// Mean softplus(θ − g) over positive samples.
    pub loss_pos: f32,
    /// Mean softplus(g − θ) over negative samples.
    pub loss_neg: f32,
    /// Mean goodness of positive samples.
    pub goodness_pos: f32,
    /// Mean goodness of negative samples.
    pub goodness_neg: f32,
}

impl FFStepStats {
    /// Total layer loss (pos + neg terms).
    pub fn loss(&self) -> f32 {
        self.loss_pos + self.loss_neg
    }

    /// Goodness separation margin — the quantity FF training grows.
    pub fn margin(&self) -> f32 {
        self.goodness_pos - self.goodness_neg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_shapes_and_counts() {
        let mut rng = Rng::new(1);
        let l = FFLayer::new(784, 2000, false, &mut rng);
        assert_eq!((l.d_in(), l.d_out()), (784, 2000));
        assert_eq!(l.param_count(), 784 * 2000 + 2000);
        assert_eq!(l.wire_bytes(), (784 * 2000 + 2000) as u64 * 4 + 16);
    }

    #[test]
    fn head_shapes() {
        let mut rng = Rng::new(2);
        let h = LinearHead::new(6000, 10, &mut rng);
        assert_eq!(h.classes(), 10);
        assert_eq!(h.param_count(), 60010);
    }

    #[test]
    fn stats_margin() {
        let s = FFStepStats { goodness_pos: 5.0, goodness_neg: 2.0, ..Default::default() };
        assert_eq!(s.margin(), 3.0);
    }
}
