//! The Forward-Forward algorithm (Hinton, 2022) as used by the paper.
//!
//! FF trains each layer with two *forward* passes instead of
//! forward+backward: a **positive** pass on real data (label overlaid on the
//! input) pushes the layer's *goodness* `g = Σ yⱼ²` above a threshold θ, a
//! **negative** pass on corrupted data (wrong label overlaid) pushes it
//! below. Because the objective is layer-local, layers can be trained
//! independently — the property the paper's pipeline schedulers exploit.
//!
//! Submodules:
//! * [`overlay`] — label embedding into the first `C` input dims.
//! * [`layer`] — layer/head parameter containers.
//! * [`network`] — the multi-layer FF network and forward transforms.
//! * [`negative`] — AdaptiveNEG / RandomNEG / FixedNEG strategies (§5).
//! * [`classifier`] — Goodness and Softmax prediction modes (§3, §5.3).
//! * [`perfopt`] — the Performance-Optimized goodness function (§4.4).

pub mod classifier;
pub mod layer;
pub mod negative;
pub mod network;
pub mod overlay;
pub mod perfopt;

pub use classifier::{predict_goodness, predict_softmax, ClassifierMode};
pub use layer::{FFLayer, FFStepStats, LinearHead};
pub use negative::NegStrategy;
pub use network::FFNetwork;
