//! Negative-sample label strategies (§5, Table 1 row axis).
//!
//! FF's negative pass needs *wrong* labels. How they are picked drives the
//! accuracy/cost trade-off the paper measures:
//!
//! * **AdaptiveNEG** — the *most-predicted incorrect* label under the
//!   current network, recomputed every chapter. Best accuracy, and the most
//!   expensive: it costs a full goodness sweep over the training set.
//! * **RandomNEG** — a fresh random wrong label per sample per chapter.
//!   Nearly as accurate, much cheaper. Crucially, it is derived from a
//!   `(seed, chapter)` stream, so in the distributed setting every node
//!   re-rolls identical labels **without any communication**.
//! * **FixedNEG** — one random wrong label per sample, chosen once at
//!   initialization. Cheapest, least accurate (negatives go stale).

use anyhow::Result;

use crate::engine::Engine;
use crate::ff::classifier::goodness_scores;
use crate::ff::network::FFNetwork;
use crate::tensor::{Matrix, Rng};

/// Negative-data strategy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NegStrategy {
    /// Most-predicted incorrect label, refreshed per chapter (§5).
    Adaptive,
    /// Random incorrect label, refreshed per chapter.
    Random,
    /// Random incorrect label, fixed at start of training.
    Fixed,
}

impl std::fmt::Display for NegStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NegStrategy::Adaptive => write!(f, "AdaptiveNEG"),
            NegStrategy::Random => write!(f, "RandomNEG"),
            NegStrategy::Fixed => write!(f, "FixedNEG"),
        }
    }
}

/// RNG stream tag for negative-label derivation (see [`Rng::derive`]).
const NEG_STREAM_BASE: u64 = 0x4E45_4721; // "NEG!"

/// Deterministic wrong labels for `chapter` — the RandomNEG/FixedNEG
/// primitive. FixedNEG always passes `chapter = 0`.
pub fn random_wrong_labels(seed: u64, chapter: u32, truth: &[u8], classes: usize) -> Vec<u8> {
    let mut rng = Rng::derive(seed, NEG_STREAM_BASE ^ u64::from(chapter));
    truth.iter().map(|&t| rng.wrong_label(t, classes)).collect()
}

/// AdaptiveNEG labels: for each sample, the incorrect class with the
/// highest goodness under the current network ("most predicted incorrect
/// label", §5). Runs in minibatch chunks of `chunk` rows.
pub fn adaptive_neg_labels(
    eng: &mut dyn Engine,
    net: &FFNetwork,
    x: &Matrix,
    truth: &[u8],
    chunk: usize,
) -> Result<Vec<u8>> {
    assert_eq!(x.rows, truth.len());
    let mut out = Vec::with_capacity(truth.len());
    let mut r0 = 0;
    while r0 < x.rows {
        let r1 = (r0 + chunk).min(x.rows);
        let xb = x.rows_range(r0, r1);
        let scores = goodness_scores(eng, net, &xb)?;
        for (i, &t) in truth[r0..r1].iter().enumerate() {
            let row = scores.row(i);
            let mut best: Option<usize> = None;
            for (c, &s) in row.iter().enumerate() {
                if c == t as usize {
                    continue;
                }
                if best.map_or(true, |b| s > row[b]) {
                    best = Some(c);
                }
            }
            out.push(best.expect("≥2 classes") as u8);
        }
        r0 = r1;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;

    #[test]
    fn random_wrong_labels_deterministic_and_wrong() {
        let truth: Vec<u8> = (0..100).map(|i| (i % 10) as u8).collect();
        let a = random_wrong_labels(7, 3, &truth, 10);
        let b = random_wrong_labels(7, 3, &truth, 10);
        assert_eq!(a, b, "same (seed, chapter) must agree across nodes");
        let c = random_wrong_labels(7, 4, &truth, 10);
        assert_ne!(a, c, "different chapters must re-roll");
        assert!(a.iter().zip(&truth).all(|(n, t)| n != t));
    }

    #[test]
    fn fixed_equals_chapter_zero() {
        let truth = vec![1u8, 5, 9];
        assert_eq!(
            random_wrong_labels(11, 0, &truth, 10),
            random_wrong_labels(11, 0, &truth, 10)
        );
    }

    #[test]
    fn adaptive_labels_never_truth_and_in_range() {
        let mut rng = Rng::new(31);
        let net = FFNetwork::new(&[16, 8, 8], 10, &mut rng);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(23, 16, 0.0, 1.0, &mut rng);
        let truth: Vec<u8> = (0..23).map(|i| (i % 10) as u8).collect();
        let neg = adaptive_neg_labels(&mut eng, &net, &x, &truth, 8).unwrap();
        assert_eq!(neg.len(), 23);
        for (n, t) in neg.iter().zip(&truth) {
            assert_ne!(n, t);
            assert!(*n < 10);
        }
    }

    #[test]
    fn adaptive_chunking_invariant() {
        // Same labels regardless of chunk size.
        let mut rng = Rng::new(32);
        let net = FFNetwork::new(&[12, 6, 6], 10, &mut rng);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(17, 12, 0.0, 1.0, &mut rng);
        let truth: Vec<u8> = (0..17).map(|i| (i % 10) as u8).collect();
        let a = adaptive_neg_labels(&mut eng, &net, &x, &truth, 4).unwrap();
        let b = adaptive_neg_labels(&mut eng, &net, &x, &truth, 17).unwrap();
        assert_eq!(a, b);
    }
}
