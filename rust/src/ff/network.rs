//! The multi-layer FF network: a stack of [`FFLayer`]s plus optional heads.

use anyhow::Result;

use crate::engine::Engine;
use crate::ff::layer::{FFLayer, LinearHead};
use crate::tensor::{Matrix, Rng};

/// A feed-forward FF network, e.g. the paper's `[784, 2000, 2000, 2000,
/// 2000]` MNIST architecture (`dims = [784, 2000, 2000, 2000, 2000]`).
#[derive(Clone, Debug)]
pub struct FFNetwork {
    /// The FF-trained layers, input-first.
    pub layers: Vec<FFLayer>,
    /// Number of label classes (10 for MNIST/CIFAR-10).
    pub classes: usize,
}

impl FFNetwork {
    /// Build a randomly-initialized network from layer widths
    /// (`dims[0]` = input dim).
    ///
    /// # Panics
    /// If fewer than two dims are given.
    pub fn new(dims: &[usize], classes: usize, rng: &mut Rng) -> Self {
        assert!(dims.len() >= 2, "need at least input + one layer");
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(i, w)| FFLayer::new(w[0], w[1], i > 0, rng))
            .collect();
        FFNetwork { layers, classes }
    }

    /// Number of trainable FF layers.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total FF parameter count.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(|l| l.param_count()).sum()
    }

    /// Layer widths including the input dim (inverse of [`FFNetwork::new`]).
    pub fn dims(&self) -> Vec<usize> {
        let mut d: Vec<usize> = self.layers.iter().map(|l| l.d_in()).collect();
        d.push(self.layers.last().unwrap().d_out());
        d
    }

    /// Forward `x` through layers `[0, upto)`, returning the activation fed
    /// to layer `upto`. `upto == 0` returns `x` unchanged.
    pub fn transform_upto(&self, eng: &mut dyn Engine, x: &Matrix, upto: usize) -> Result<Matrix> {
        let mut h = x.clone();
        for layer in &self.layers[..upto] {
            h = eng.layer_forward(layer, &h)?;
        }
        Ok(h)
    }

    /// Forward through every layer, returning all per-layer activations
    /// (`out[l]` = output of layer `l`). Used by both classifier modes.
    pub fn forward_all(&self, eng: &mut dyn Engine, x: &Matrix) -> Result<Vec<Matrix>> {
        let mut outs = Vec::with_capacity(self.layers.len());
        let mut h = x.clone();
        for layer in &self.layers {
            h = eng.layer_forward(layer, &h)?;
            outs.push(h.clone());
        }
        Ok(outs)
    }

    /// Input dimensionality the softmax classifier head expects:
    /// concatenated activations of all but the first layer (§3 Prediction).
    pub fn head_input_dim(&self) -> usize {
        self.layers.iter().skip(1).map(|l| l.d_out()).sum()
    }

    /// Fresh softmax head sized for this network.
    pub fn new_head(&self, rng: &mut Rng) -> LinearHead {
        LinearHead::new(self.head_input_dim(), self.classes, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;

    #[test]
    fn construction_matches_dims() {
        let mut rng = Rng::new(3);
        let net = FFNetwork::new(&[784, 100, 100, 100], 10, &mut rng);
        assert_eq!(net.num_layers(), 3);
        assert_eq!(net.dims(), vec![784, 100, 100, 100]);
        assert!(!net.layers[0].normalize_input);
        assert!(net.layers[1].normalize_input);
        assert_eq!(net.head_input_dim(), 200);
    }

    #[test]
    fn transform_upto_zero_is_identity() {
        let mut rng = Rng::new(4);
        let net = FFNetwork::new(&[8, 6, 4], 2, &mut rng);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(3, 8, 0.0, 1.0, &mut rng);
        let y = net.transform_upto(&mut eng, &x, 0).unwrap();
        assert_eq!(y, x);
    }

    #[test]
    fn forward_all_shapes() {
        let mut rng = Rng::new(5);
        let net = FFNetwork::new(&[8, 6, 4], 2, &mut rng);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(3, 8, 0.0, 1.0, &mut rng);
        let outs = net.forward_all(&mut eng, &x).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!((outs[0].rows, outs[0].cols), (3, 6));
        assert_eq!((outs[1].rows, outs[1].cols), (3, 4));
        // ReLU output is non-negative
        assert!(outs.iter().all(|m| m.data.iter().all(|&v| v >= 0.0)));
    }
}
