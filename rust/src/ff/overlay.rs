//! Label overlay (§3 "Negative Data").
//!
//! MNIST digits have a black border, so Hinton's trick is to *write the
//! label into the image*: the first `C` pixels become a one-hot label. A
//! positive sample carries its true label, a negative sample a wrong one,
//! and at prediction time either all `C` candidates are tried (Goodness
//! mode) or a neutral `1/C`-ish overlay is used (Softmax mode).

use crate::tensor::Matrix;

/// Value used for every class slot in the neutral overlay (paper: 0.1).
pub const NEUTRAL_VALUE: f32 = 0.1;

/// Overlay one-hot `labels` onto the first `classes` columns of `x`
/// (returns a copy; `x` is the raw, label-free data).
///
/// # Panics
/// If `x.cols < classes` or `labels.len() != x.rows`.
pub fn overlay_labels(x: &Matrix, labels: &[u8], classes: usize) -> Matrix {
    assert!(x.cols >= classes, "input dim {} < classes {}", x.cols, classes);
    assert_eq!(x.rows, labels.len());
    let mut out = x.clone();
    for (r, &l) in labels.iter().enumerate() {
        let row = out.row_mut(r);
        row[..classes].fill(0.0);
        row[l as usize] = 1.0;
    }
    out
}

/// Overlay the same label `l` onto every row — used by Goodness prediction
/// which scores each candidate class in turn.
pub fn overlay_uniform_label(x: &Matrix, l: u8, classes: usize) -> Matrix {
    assert!(x.cols >= classes);
    let mut out = x.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        row[..classes].fill(0.0);
        row[l as usize] = 1.0;
    }
    out
}

/// Overlay the neutral label (all slots = [`NEUTRAL_VALUE`]) — Softmax
/// prediction path (§3 "Prediction").
pub fn overlay_neutral(x: &Matrix, classes: usize) -> Matrix {
    assert!(x.cols >= classes);
    let mut out = x.clone();
    for r in 0..out.rows {
        out.row_mut(r)[..classes].fill(NEUTRAL_VALUE);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Matrix {
        Matrix::from_vec(2, 12, (0..24).map(|i| i as f32 / 24.0).collect())
    }

    #[test]
    fn overlay_writes_onehot_and_preserves_rest() {
        let x = base();
        let o = overlay_labels(&x, &[3, 0], 10);
        assert_eq!(o.row(0)[..10], [0., 0., 0., 1., 0., 0., 0., 0., 0., 0.]);
        assert_eq!(o.row(1)[..10], [1., 0., 0., 0., 0., 0., 0., 0., 0., 0.]);
        // non-overlay region untouched
        assert_eq!(o.row(0)[10..], x.row(0)[10..]);
        assert_eq!(o.row(1)[10..], x.row(1)[10..]);
        // original not mutated
        assert_ne!(x.row(0)[..10], o.row(0)[..10]);
    }

    #[test]
    fn uniform_label_same_for_all_rows() {
        let o = overlay_uniform_label(&base(), 7, 10);
        for r in 0..2 {
            assert_eq!(o.row(r)[7], 1.0);
            assert_eq!(o.row(r)[..7].iter().sum::<f32>(), 0.0);
        }
    }

    #[test]
    fn neutral_is_point_one() {
        let o = overlay_neutral(&base(), 10);
        for r in 0..2 {
            assert!(o.row(r)[..10].iter().all(|&v| (v - 0.1).abs() < 1e-7));
        }
    }

    #[test]
    #[should_panic(expected = "input dim")]
    fn overlay_rejects_narrow_input() {
        let x = Matrix::zeros(1, 5);
        overlay_labels(&x, &[0], 10);
    }
}
