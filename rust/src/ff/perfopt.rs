//! Performance-Optimized PFF (§4.4, Figures 7–8, Tables 4–5).
//!
//! The paper replaces the goodness function with *classification accuracy*:
//! each FF layer gets its own softmax head, and layer+head are trained by
//! backprop **local to that pair** (gradients stop at the layer's input).
//! There is **no negative data**; inputs carry the neutral overlay. The
//! pipeline structure is unchanged — a "layer" stage just trains
//! (layer, head) with cross-entropy instead of the two-pass FF objective.
//!
//! Prediction (Table 4's two rows):
//! * *only last layer* — argmax of the last layer's head.
//! * *using all layers* — sum of softmax probabilities across every head.

use anyhow::Result;

use crate::engine::Engine;
use crate::ff::network::FFNetwork;
use crate::ff::overlay::overlay_neutral;
use crate::ff::LinearHead;
use crate::tensor::{ops, Matrix, Rng};

/// Which heads vote at prediction time (Table 4 / Table 5 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PerfOptReadout {
    /// Use only the last layer's head.
    LastLayer,
    /// Sum softmax probabilities over all per-layer heads.
    AllLayers,
}

impl std::fmt::Display for PerfOptReadout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PerfOptReadout::LastLayer => write!(f, "only last layer"),
            PerfOptReadout::AllLayers => write!(f, "using all layers"),
        }
    }
}

/// Fresh per-layer heads for a network (one per FF layer).
pub fn new_heads(net: &FFNetwork, rng: &mut Rng) -> Vec<LinearHead> {
    net.layers.iter().map(|l| LinearHead::new(l.d_out(), net.classes, rng)).collect()
}

/// Predict with trained per-layer heads.
pub fn predict(
    eng: &mut dyn Engine,
    net: &FFNetwork,
    heads: &[LinearHead],
    x: &Matrix,
    readout: PerfOptReadout,
) -> Result<Vec<u8>> {
    assert_eq!(heads.len(), net.num_layers());
    let xn = overlay_neutral(x, net.classes);
    let outs = net.forward_all(eng, &xn)?;
    match readout {
        PerfOptReadout::LastLayer => {
            let logits = eng.head_logits(heads.last().unwrap(), outs.last().unwrap())?;
            Ok(ops::argmax_rows(&logits))
        }
        PerfOptReadout::AllLayers => {
            let mut vote = Matrix::zeros(x.rows, net.classes);
            for (h, out) in heads.iter().zip(&outs) {
                let p = ops::softmax_rows(&eng.head_logits(h, out)?);
                for (v, pv) in vote.data.iter_mut().zip(&p.data) {
                    *v += pv;
                }
            }
            Ok(ops::argmax_rows(&vote))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;

    #[test]
    fn heads_match_layer_widths() {
        let mut rng = Rng::new(41);
        let net = FFNetwork::new(&[16, 12, 8], 10, &mut rng);
        let heads = new_heads(&net, &mut rng);
        assert_eq!(heads.len(), 2);
        assert_eq!(heads[0].w.rows, 12);
        assert_eq!(heads[1].w.rows, 8);
    }

    #[test]
    fn predict_both_readouts_in_range() {
        let mut rng = Rng::new(42);
        let net = FFNetwork::new(&[16, 12, 8], 10, &mut rng);
        let heads = new_heads(&net, &mut rng);
        let mut eng = NativeEngine::new();
        let x = Matrix::rand_uniform(9, 16, 0.0, 1.0, &mut rng);
        for ro in [PerfOptReadout::LastLayer, PerfOptReadout::AllLayers] {
            let p = predict(&mut eng, &net, &heads, &x, ro).unwrap();
            assert_eq!(p.len(), 9);
            assert!(p.iter().all(|&c| c < 10));
        }
    }
}
