//! Shared harness plumbing: scales, measured-run helper, DES helper.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{EngineKind, ExperimentConfig, Scheduler};
use crate::coordinator::{Experiment, ExperimentReport};
use crate::data::{load_dataset, DataBundle, DatasetKind};
use crate::ff::{ClassifierMode, NegStrategy};
use crate::sim::schedules::{SimParams, SimVariant};
use crate::sim::{build_schedule, simulate, CostModel};

/// Workload extents for measured runs.
#[derive(Clone, Debug)]
pub struct Scale {
    /// Layer widths (input first).
    pub dims: Vec<usize>,
    /// Train/test example counts.
    pub train_n: usize,
    /// Test examples.
    pub test_n: usize,
    /// Epochs E.
    pub epochs: u32,
    /// Splits S.
    pub splits: u32,
    /// Minibatch size.
    pub batch: usize,
    /// DFF baseline rounds (DFF needs ~10× the epochs, §6).
    pub dff_rounds: u32,
}

impl Scale {
    /// Bench-default scale: full code paths, ~seconds per run on 1 core.
    /// Keeps the paper's L=4 so Single-Layer uses N=4. 80 epochs — FF
    /// needs them (see `ExperimentConfig::tiny`).
    pub fn quick() -> Scale {
        Scale {
            dims: vec![784, 64, 64, 64, 64],
            train_n: 512,
            test_n: 256,
            epochs: 160,
            splits: 8,
            batch: 64,
            dff_rounds: 320,
        }
    }

    /// Larger reduced scale for EXPERIMENTS.md headline runs
    /// (~1 min per experiment on this host).
    pub fn reduced() -> Scale {
        Scale {
            dims: vec![784, 256, 256, 256, 256],
            train_n: 2048,
            test_n: 512,
            epochs: 64,
            splits: 8,
            batch: 64,
            dff_rounds: 320,
        }
    }

    /// CIFAR-geometry variant of this scale (3072-dim input).
    pub fn cifarized(&self) -> Scale {
        let mut s = self.clone();
        s.dims[0] = 3072;
        s
    }

    /// Base config at this scale.
    pub fn config(&self, dataset: DatasetKind, engine: EngineKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.dataset = dataset;
        cfg.dims = self.dims.clone();
        cfg.train_n = self.train_n;
        cfg.test_n = self.test_n;
        cfg.epochs = self.epochs;
        cfg.splits = self.splits;
        cfg.batch = self.batch;
        cfg.engine = engine;
        cfg
    }
}

/// One measured experiment variant (a row of a table).
#[derive(Clone, Debug)]
pub struct MeasuredRun {
    /// Row label, e.g. "AdaptiveNEG-Goodness".
    pub model: String,
    /// Implementation label ("Sequential" / "Single-Layer" / "All-Layers").
    pub implementation: String,
    /// The report.
    pub report: ExperimentReport,
}

/// Configure scheduler + nodes for an implementation label.
pub fn apply_impl(cfg: &mut ExperimentConfig, implementation: Scheduler) {
    cfg.scheduler = implementation;
    cfg.nodes = match implementation {
        Scheduler::Sequential => 1,
        Scheduler::SingleLayer => cfg.num_layers(),
        // Paper uses 4 nodes for All-Layers on the 4-layer net (and notes
        // 5 for the softmax pipeline); we use the largest N ≤ L that
        // divides the split count (All-Layers requires S % N == 0).
        Scheduler::AllLayers | Scheduler::Federated => {
            let l = cfg.num_layers();
            (1..=l).rev().find(|n| cfg.splits as usize % n == 0).unwrap_or(1)
        }
    };
}

/// Run one measured variant.
pub fn run_measured(
    bundle: &Arc<DataBundle>,
    base: &ExperimentConfig,
    model: &str,
    implementation: Scheduler,
    neg: NegStrategy,
    classifier: ClassifierMode,
    perfopt: bool,
) -> Result<MeasuredRun> {
    let mut cfg = base.clone();
    cfg.name = format!("{model}/{implementation}");
    cfg.neg = neg;
    cfg.classifier = classifier;
    cfg.perfopt = perfopt;
    apply_impl(&mut cfg, implementation);
    // Arc clone — the tables run many variants off one loaded bundle and
    // must not deep-copy the data per run.
    let report = Experiment::builder().config(cfg).data(bundle.clone()).run()?;
    Ok(MeasuredRun {
        model: model.to_string(),
        implementation: implementation.to_string(),
        report,
    })
}

/// Load the bundle for a scale once (shared: sessions take `Arc` clones).
pub fn load_bundle(scale: &Scale, dataset: DatasetKind, seed: u64) -> Result<Arc<DataBundle>> {
    load_dataset(dataset, scale.train_n, scale.test_n, seed).map(Arc::new)
}

/// DES makespan (seconds) of a variant at the paper's full scale.
pub fn des_paper_time(
    variant: SimVariant,
    neg: NegStrategy,
    softmax_head: bool,
    perfopt: bool,
    cifar: bool,
) -> f64 {
    let mut cfg = ExperimentConfig::paper_mnist();
    if cifar {
        cfg.dims[0] = 3072;
        cfg.train_n = 50_000;
    }
    let cm = CostModel::paper_testbed(&cfg);
    let params = SimParams { nodes: 4, neg, softmax_head, perfopt };
    let tasks = build_schedule(variant, &cm, &params);
    simulate(&tasks).makespan
}

/// Scheduler → simulator variant mapping.
pub fn sim_variant(s: Scheduler) -> SimVariant {
    match s {
        Scheduler::Sequential => SimVariant::SequentialFF,
        Scheduler::SingleLayer => SimVariant::SingleLayerPFF,
        Scheduler::AllLayers => SimVariant::AllLayersPFF,
        Scheduler::Federated => SimVariant::FederatedPFF,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_validate() {
        for s in [Scale::quick(), Scale::reduced()] {
            let cfg = s.config(DatasetKind::SynthMnist, EngineKind::Native);
            cfg.clone().validated().unwrap();
            assert_eq!(cfg.num_layers(), 4);
        }
        assert_eq!(Scale::quick().cifarized().dims[0], 3072);
    }

    #[test]
    fn apply_impl_sets_nodes() {
        let s = Scale::quick();
        let mut cfg = s.config(DatasetKind::SynthMnist, EngineKind::Native);
        apply_impl(&mut cfg, Scheduler::SingleLayer);
        assert_eq!(cfg.nodes, 4);
        apply_impl(&mut cfg, Scheduler::Sequential);
        assert_eq!(cfg.nodes, 1);
    }

    #[test]
    fn des_paper_times_ordered() {
        let seq = des_paper_time(SimVariant::SequentialFF, NegStrategy::Adaptive, false, false, false);
        let all = des_paper_time(SimVariant::AllLayersPFF, NegStrategy::Adaptive, false, false, false);
        assert!(seq > 2.0 * all, "seq {seq:.0}s vs all-layers {all:.0}s");
    }
}
