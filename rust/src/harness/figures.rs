//! Figures 1–6: schedule diagrams (ASCII Gantt from the DES) and the
//! split-count study of Figure 3.

use anyhow::Result;

use crate::config::{EngineKind, ExperimentConfig, Scheduler};
use crate::coordinator::Experiment;
use crate::data::DatasetKind;
use crate::ff::NegStrategy;
use crate::harness::common::{load_bundle, Scale};
use crate::sim::cost::CostModel;
use crate::sim::gantt;
use crate::sim::schedules::{build_schedule, SimParams, SimVariant};
use crate::sim::simulate;

/// Small config for legible schedule diagrams (3 layers, like the paper's
/// figures).
fn figure_cfg(splits: u32) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper_mnist();
    cfg.dims = vec![784, 2000, 2000, 2000];
    cfg.splits = splits;
    cfg.epochs = splits; // C = 1
    cfg
}

fn render(variant: SimVariant, nodes: usize, splits: u32, neg: NegStrategy) -> String {
    let cfg = figure_cfg(splits);
    let cm = CostModel::paper_testbed(&cfg);
    let p = SimParams { nodes, neg, softmax_head: false, perfopt: false };
    let tasks = build_schedule(variant, &cm, &p);
    let result = simulate(&tasks);
    format!("{}\n{}", gantt::summary_line(&variant.to_string(), &result), gantt::render(&tasks, &result, 96))
}

/// Figure 1 — backprop pipeline bubbles (3 stages).
pub fn figure1() -> String {
    render(SimVariant::BackpropPipeline, 3, 6, NegStrategy::Random)
}

/// Figure 2 — FF parallelization (3 nodes, no backward dependencies).
pub fn figure2() -> String {
    render(SimVariant::AllLayersPFF, 3, 6, NegStrategy::Random)
}

/// Figure 4 — Single-Layer PFF, 3 layers × 3 splits.
pub fn figure4() -> String {
    render(SimVariant::SingleLayerPFF, 3, 3, NegStrategy::Random)
}

/// Figure 5 — All-Layers PFF, 3 layers × 6 splits.
pub fn figure5() -> String {
    render(SimVariant::AllLayersPFF, 3, 6, NegStrategy::Random)
}

/// Figure 6 — Federated PFF, 3 layers × 6 splits.
pub fn figure6() -> String {
    render(SimVariant::FederatedPFF, 3, 6, NegStrategy::Random)
}

/// Figure 3 — the split-count study: accuracy of split=1 (each layer
/// trained to completion before the next) vs split=S (fine-grained
/// chapters), measured end-to-end at `scale`. Returns (S, accuracy) pairs.
pub fn figure3_measured(
    scale: &Scale,
    engine: EngineKind,
    seed: u64,
    split_values: &[u32],
) -> Result<Vec<(u32, f64)>> {
    let bundle = load_bundle(scale, DatasetKind::SynthMnist, seed)?;
    let mut out = Vec::new();
    for &s in split_values {
        let mut cfg = scale.config(DatasetKind::SynthMnist, engine);
        cfg.seed = seed;
        cfg.name = format!("fig3-S{s}");
        cfg.scheduler = Scheduler::Sequential;
        cfg.neg = NegStrategy::Random;
        cfg.splits = s;
        // keep E divisible by S
        cfg.epochs = cfg.epochs.max(s);
        if cfg.epochs % s != 0 {
            cfg.epochs = s * (cfg.epochs / s + 1);
        }
        let rep = Experiment::builder().config(cfg).data(bundle.clone()).run()?;
        out.push((s, rep.test_accuracy));
    }
    Ok(out)
}

/// All schedule figures as one printable bundle.
pub fn all_schedule_figures() -> String {
    let mut s = String::new();
    s.push_str("── Figure 1: backprop pipeline (F/B dependency bubbles) ──\n");
    s.push_str(&figure1());
    s.push_str("\n── Figure 2: FF parallelization (no backward deps) ──\n");
    s.push_str(&figure2());
    s.push_str("\n── Figure 4: Single-Layer PFF (3 layers, 3 splits) ──\n");
    s.push_str(&figure4());
    s.push_str("\n── Figure 5: All-Layers PFF (3 layers, 6 splits) ──\n");
    s.push_str(&figure5());
    s.push_str("\n── Figure 6: Federated PFF (3 layers, 6 splits) ──\n");
    s.push_str(&figure6());
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_figures_render() {
        let all = all_schedule_figures();
        for fig in ["Figure 1", "Figure 2", "Figure 4", "Figure 5", "Figure 6"] {
            assert!(all.contains(fig), "missing {fig}");
        }
        assert!(all.contains("node  1"));
        assert!(all.contains("legend"));
    }

    #[test]
    fn figure3_more_splits_not_worse() {
        // The paper's Figure 3 claim: fine-grained splits help accuracy.
        let mut scale = Scale::quick();
        scale.dims = vec![784, 48, 48, 48];
        scale.train_n = 384;
        scale.test_n = 192;
        scale.epochs = 32;
        scale.splits = 8;
        let pts = figure3_measured(&scale, EngineKind::Native, 9, &[1, 4]).unwrap();
        assert_eq!(pts.len(), 2);
        let (a1, a4) = (pts[0].1, pts[1].1);
        assert!(
            a4 >= a1 - 0.05,
            "split=4 ({a4:.3}) should not be clearly worse than split=1 ({a1:.3})"
        );
    }
}
