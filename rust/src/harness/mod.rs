//! Experiment harness: one driver per paper table/figure.
//!
//! Every driver produces two kinds of evidence, printed side by side with
//! the paper's numbers:
//!
//! 1. **Measured** — a real end-to-end run of the full stack at reduced
//!    scale on this host (accuracy is real; timing is per-node busy time
//!    plus the modeled makespan, since one core cannot run 4 nodes in
//!    parallel).
//! 2. **DES** — the discrete-event simulation at the paper's full scale
//!    (`[784, 2000×4]`, E = S = 100), which carries the timing claims.
//!
//! Benches (`rust/benches/table*.rs`) and the CLI (`pff table1` …) both
//! call these.

pub mod common;
pub mod figures;
pub mod table1;
pub mod table2;
pub mod table3;
pub mod table4;
pub mod table5;

pub use common::{MeasuredRun, Scale};
