//! Table 1 — Original FF, DFF and PFF comparison (Goodness classifier):
//! {Adaptive, Random, Fixed}NEG × {Sequential, Single-Layer, All-Layers},
//! plus the DFF baseline and Hinton's Matlab reference row.

use anyhow::Result;

use crate::baselines::dff::run_dff;
use crate::bench_util::{print_table, Row};
use crate::config::{EngineKind, Scheduler};
use crate::data::DatasetKind;
use crate::engine::NativeEngine;
use crate::ff::{ClassifierMode, NegStrategy};
use crate::harness::common::{
    des_paper_time, load_bundle, run_measured, sim_variant, Scale,
};
use crate::row;

/// Paper Table 1 reference values: (model, impl, time_s, accuracy_%).
pub const PAPER: &[(&str, &str, f64, f64)] = &[
    ("AdaptiveNEG-Goodness", "Sequential", 11_190.72, 98.52),
    ("AdaptiveNEG-Goodness", "Single-Layer", 5_254.87, 98.43),
    ("AdaptiveNEG-Goodness", "All-Layers", 2_980.76, 98.51),
    ("RandomNEG-Goodness", "Sequential", 7_178.71, 98.33),
    ("RandomNEG-Goodness", "Single-Layer", 1_974.10, 98.26),
    ("RandomNEG-Goodness", "All-Layers", 2_008.25, 98.17),
    ("FixedNEG-Goodness", "Sequential", 7_143.28, 97.95),
    ("FixedNEG-Goodness", "Single-Layer", 1_920.80, 97.94),
    ("FixedNEG-Goodness", "All-Layers", 1_978.21, 97.89),
];

/// Run Table 1 at `scale` and print it; returns the rows.
pub fn run(scale: &Scale, engine: EngineKind, seed: u64) -> Result<Vec<Row>> {
    let bundle = load_bundle(scale, DatasetKind::SynthMnist, seed)?;
    let mut base = scale.config(DatasetKind::SynthMnist, engine);
    base.seed = seed;

    let negs = [
        ("AdaptiveNEG-Goodness", NegStrategy::Adaptive),
        ("RandomNEG-Goodness", NegStrategy::Random),
        ("FixedNEG-Goodness", NegStrategy::Fixed),
    ];
    let impls = [Scheduler::Sequential, Scheduler::SingleLayer, Scheduler::AllLayers];

    let mut rows = Vec::new();

    // DFF baseline (measured) + its paper reference.
    let mut eng = NativeEngine::new();
    let dff = run_dff(&mut eng, &base, &bundle, scale.dff_rounds)?;
    rows.push(row![
        "DFF (1000 epochs) [11]",
        "-",
        format!("{:.2}", dff.test_accuracy * 100.0),
        format!("{:.1}", dff.wall_s),
        "-",
        "93.15",
        "-",
    ]);
    rows.push(row!["Hinton's Matlab [12]", "-", "-", "-", "-", "98.53", "-"]);

    for (model, neg) in negs {
        for implementation in impls {
            let m = run_measured(
                &bundle,
                &base,
                model,
                implementation,
                neg,
                ClassifierMode::Goodness,
                false,
            )?;
            let des = des_paper_time(sim_variant(implementation), neg, false, false, false);
            let paper = PAPER
                .iter()
                .find(|(pm, pi, _, _)| *pm == model && *pi == implementation.to_string())
                .copied();
            rows.push(row![
                model,
                implementation,
                format!("{:.2}", m.report.test_accuracy * 100.0),
                format!("{:.1}", m.report.modeled.modeled_makespan),
                format!("{:.0}", des),
                paper.map_or("-".into(), |(_, _, _, a)| format!("{a:.2}")),
                paper.map_or("-".into(), |(_, _, t, _)| format!("{t:.0}")),
            ]);
        }
    }

    print_table(
        "Table 1 — FF / DFF / PFF comparison (Goodness)",
        &[
            "model",
            "impl",
            "acc% (measured)",
            "time_s (measured-modeled)",
            "time_s (DES @paper scale)",
            "paper acc%",
            "paper time_s",
        ],
        &rows,
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The shape claims of Table 1 at tiny scale: every PFF variant beats
    /// DFF; pipeline variants match Sequential accuracy within tolerance.
    #[test]
    fn table1_shape_holds_at_tiny_scale() {
        let mut scale = Scale::quick();
        scale.train_n = 384;
        scale.test_n = 192;
        let rows = run(&scale, EngineKind::Native, 42).unwrap();
        // 2 baseline rows + 9 grid rows
        assert_eq!(rows.len(), 11);
        let acc = |i: usize| rows[i].cells[2].parse::<f64>().unwrap_or(0.0);
        let dff_acc = acc(0);
        // Table 1's headline shape: minibatched PFF beats full-batch DFF.
        // At tiny scale individual variants fluctuate (AdaptiveNEG is
        // fragile — the paper's own Table 5 shows it collapsing on harder
        // data), so require the majority of the grid and the best model to
        // beat DFF decisively.
        let beats = (2..11).filter(|&i| acc(i) > dff_acc).count();
        assert!(beats >= 5, "only {beats}/9 PFF rows beat DFF ({dff_acc}%)");
        let best = (2..11).map(acc).fold(0.0f64, f64::max);
        assert!(
            best > dff_acc + 10.0,
            "best PFF ({best}%) should beat DFF ({dff_acc}%) by ≥10 pts"
        );
    }
}
