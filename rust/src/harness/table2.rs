//! Table 2 — Classifier-mode comparison for AdaptiveNEG: Goodness vs
//! Softmax across the three implementations.

use anyhow::Result;

use crate::bench_util::{print_table, Row};
use crate::config::{EngineKind, Scheduler};
use crate::data::DatasetKind;
use crate::ff::{ClassifierMode, NegStrategy};
use crate::harness::common::{des_paper_time, load_bundle, run_measured, sim_variant, Scale};
use crate::row;

/// Paper Table 2 reference: (model, impl, time_s, accuracy_%).
pub const PAPER: &[(&str, &str, f64, f64)] = &[
    ("AdaptiveNEG-Goodness", "Sequential", 11_190.72, 98.52),
    ("AdaptiveNEG-Goodness", "Single-Layer", 5_254.87, 98.43),
    ("AdaptiveNEG-Goodness", "All-Layers", 2_980.76, 98.51),
    ("AdaptiveNEG-Softmax", "Sequential", 8_365.96, 98.38),
    ("AdaptiveNEG-Softmax", "Single-Layer", 2_471.27, 98.31),
    ("AdaptiveNEG-Softmax", "All-Layers", 1_886.42, 98.30),
];

/// Run Table 2 at `scale`; prints and returns rows.
pub fn run(scale: &Scale, engine: EngineKind, seed: u64) -> Result<Vec<Row>> {
    let bundle = load_bundle(scale, DatasetKind::SynthMnist, seed)?;
    let mut base = scale.config(DatasetKind::SynthMnist, engine);
    base.seed = seed;

    let classifiers =
        [("AdaptiveNEG-Goodness", ClassifierMode::Goodness), ("AdaptiveNEG-Softmax", ClassifierMode::Softmax)];
    let impls = [Scheduler::Sequential, Scheduler::SingleLayer, Scheduler::AllLayers];

    let mut rows = Vec::new();
    for (model, classifier) in classifiers {
        for implementation in impls {
            let m = run_measured(
                &bundle,
                &base,
                model,
                implementation,
                NegStrategy::Adaptive,
                classifier,
                false,
            )?;
            let des = des_paper_time(
                sim_variant(implementation),
                NegStrategy::Adaptive,
                classifier == ClassifierMode::Softmax,
                false,
                false,
            );
            let paper = PAPER
                .iter()
                .find(|(pm, pi, _, _)| *pm == model && *pi == implementation.to_string())
                .copied();
            rows.push(row![
                model,
                implementation,
                format!("{:.2}", m.report.test_accuracy * 100.0),
                format!("{:.1}", m.report.modeled.modeled_makespan),
                format!("{:.0}", des),
                paper.map_or("-".into(), |(_, _, _, a)| format!("{a:.2}")),
                paper.map_or("-".into(), |(_, _, t, _)| format!("{t:.0}")),
            ]);
        }
    }
    print_table(
        "Table 2 — Classifier mode for AdaptiveNEG",
        &[
            "model",
            "impl",
            "acc% (measured)",
            "time_s (measured-modeled)",
            "time_s (DES @paper scale)",
            "paper acc%",
            "paper time_s",
        ],
        &rows,
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_runs_and_softmax_trains_head() {
        let mut scale = Scale::quick();
        scale.train_n = 384;
        scale.test_n = 192;
        scale.epochs = 96; // adaptive sweeps are the cost here
        let rows = run(&scale, EngineKind::Native, 7).unwrap();
        assert_eq!(rows.len(), 6);
        for r in &rows {
            let acc: f64 = r.cells[2].parse().unwrap();
            assert!(acc > 12.0, "{}/{} too weak: {acc}", r.cells[0], r.cells[1]);
        }
    }
}
