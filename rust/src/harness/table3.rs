//! Table 3 — Classifier-mode comparison for RandomNEG (the proposed
//! balanced model is RandomNEG-Softmax All-Layers).

use anyhow::Result;

use crate::bench_util::{print_table, Row};
use crate::config::{EngineKind, Scheduler};
use crate::data::DatasetKind;
use crate::ff::{ClassifierMode, NegStrategy};
use crate::harness::common::{des_paper_time, load_bundle, run_measured, sim_variant, Scale};
use crate::row;

/// Paper Table 3 reference: (model, impl, time_s, accuracy_%).
pub const PAPER: &[(&str, &str, f64, f64)] = &[
    ("RandomNEG-Goodness", "Sequential", 7_178.71, 98.33),
    ("RandomNEG-Goodness", "Single-Layer", 1_974.15, 98.26),
    ("RandomNEG-Goodness", "All-Layers", 2_008.25, 98.17),
    ("RandomNEG-Softmax", "Sequential", 8_104.96, 98.48),
    ("RandomNEG-Softmax", "Single-Layer", 1_891.86, 98.31),
    ("RandomNEG-Softmax", "All-Layers", 1_786.30, 98.33),
];

/// Run Table 3 at `scale`; prints and returns rows.
pub fn run(scale: &Scale, engine: EngineKind, seed: u64) -> Result<Vec<Row>> {
    let bundle = load_bundle(scale, DatasetKind::SynthMnist, seed)?;
    let mut base = scale.config(DatasetKind::SynthMnist, engine);
    base.seed = seed;

    let classifiers =
        [("RandomNEG-Goodness", ClassifierMode::Goodness), ("RandomNEG-Softmax", ClassifierMode::Softmax)];
    let impls = [Scheduler::Sequential, Scheduler::SingleLayer, Scheduler::AllLayers];

    let mut rows = Vec::new();
    for (model, classifier) in classifiers {
        for implementation in impls {
            let m = run_measured(
                &bundle,
                &base,
                model,
                implementation,
                NegStrategy::Random,
                classifier,
                false,
            )?;
            let des = des_paper_time(
                sim_variant(implementation),
                NegStrategy::Random,
                classifier == ClassifierMode::Softmax,
                false,
                false,
            );
            let paper = PAPER
                .iter()
                .find(|(pm, pi, _, _)| *pm == model && *pi == implementation.to_string())
                .copied();
            rows.push(row![
                model,
                implementation,
                format!("{:.2}", m.report.test_accuracy * 100.0),
                format!("{:.1}", m.report.modeled.modeled_makespan),
                format!("{:.0}", des),
                paper.map_or("-".into(), |(_, _, _, a)| format!("{a:.2}")),
                paper.map_or("-".into(), |(_, _, t, _)| format!("{t:.0}")),
            ]);
        }
    }
    print_table(
        "Table 3 — Classifier mode for RandomNEG",
        &[
            "model",
            "impl",
            "acc% (measured)",
            "time_s (measured-modeled)",
            "time_s (DES @paper scale)",
            "paper acc%",
            "paper time_s",
        ],
        &rows,
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_runs_all_rows() {
        let mut scale = Scale::quick();
        scale.train_n = 384;
        scale.test_n = 192;
        let rows = run(&scale, EngineKind::Native, 11).unwrap();
        assert_eq!(rows.len(), 6);
        // DES shape: RandomNEG Sequential must be much slower than the
        // pipelined variants at paper scale.
        let des: Vec<f64> = rows.iter().map(|r| r.cells[4].parse().unwrap()).collect();
        assert!(des[0] > 2.0 * des[2], "seq {} vs all-layers {}", des[0], des[2]);
    }
}
