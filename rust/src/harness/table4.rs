//! Table 4 — Performance-Optimized model vs the Table 1/3 baselines on
//! MNIST geometry. One PerfOpt training run serves both readout rows
//! (last-layer vs all-layers voting are evaluation-time choices).

use anyhow::Result;

use crate::bench_util::{print_table, Row};
use crate::config::{EngineKind, Scheduler};
use crate::coordinator::eval::evaluate_perfopt_readout;
use crate::data::DatasetKind;
use crate::engine::NativeEngine;
use crate::ff::perfopt::PerfOptReadout;
use crate::ff::{ClassifierMode, NegStrategy};
use crate::harness::common::{des_paper_time, load_bundle, run_measured, Scale};
use crate::row;
use crate::sim::schedules::SimVariant;

/// Paper Table 4 reference: (model, time_s, accuracy_%).
pub const PAPER: &[(&str, f64, f64)] = &[
    ("AdaptiveNEG-Goodness", 11_190.72, 98.52),
    ("RandomNEG-Softmax", 8_104.96, 98.48),
    ("PerfOpt (only last layer)", 4_219.97, 98.30),
    ("PerfOpt (using all layers)", 4_219.97, 98.38),
];

/// Run Table 4 at `scale`; prints and returns rows.
pub fn run(scale: &Scale, engine: EngineKind, seed: u64) -> Result<Vec<Row>> {
    let bundle = load_bundle(scale, DatasetKind::SynthMnist, seed)?;
    let mut base = scale.config(DatasetKind::SynthMnist, engine);
    base.seed = seed;

    let mut rows = Vec::new();

    // Baseline rows (Sequential AdaptiveNEG-Goodness, RandomNEG-Softmax).
    let b1 = run_measured(
        &bundle,
        &base,
        "AdaptiveNEG-Goodness",
        Scheduler::Sequential,
        NegStrategy::Adaptive,
        ClassifierMode::Goodness,
        false,
    )?;
    let b2 = run_measured(
        &bundle,
        &base,
        "RandomNEG-Softmax",
        Scheduler::Sequential,
        NegStrategy::Random,
        ClassifierMode::Softmax,
        false,
    )?;

    // One PerfOpt run (Sequential, like the paper's table), two readouts.
    let po = run_measured(
        &bundle,
        &base,
        "PerfOpt",
        Scheduler::Sequential,
        NegStrategy::Random, // unused by perfopt
        ClassifierMode::Softmax,
        true,
    )?;
    let mut eng = NativeEngine::new();
    let acc_last = evaluate_perfopt_readout(
        &mut eng,
        &po.report.model,
        &bundle.test,
        &base,
        PerfOptReadout::LastLayer,
    )?;
    let acc_all = evaluate_perfopt_readout(
        &mut eng,
        &po.report.model,
        &bundle.test,
        &base,
        PerfOptReadout::AllLayers,
    )?;

    let des_seq = |neg, softmax, perfopt| {
        des_paper_time(SimVariant::SequentialFF, neg, softmax, perfopt, false)
    };
    let push = |rows: &mut Vec<Row>, name: &str, acc: f64, t: f64, des: f64| {
        let paper = PAPER.iter().find(|(pm, _, _)| *pm == name).copied();
        rows.push(row![
            name,
            format!("{:.2}", acc * 100.0),
            format!("{t:.1}"),
            format!("{des:.0}"),
            paper.map_or("-".into(), |(_, _, a)| format!("{a:.2}")),
            paper.map_or("-".into(), |(_, t, _)| format!("{t:.0}")),
        ]);
    };

    push(
        &mut rows,
        "AdaptiveNEG-Goodness",
        b1.report.test_accuracy,
        b1.report.modeled.modeled_makespan,
        des_seq(NegStrategy::Adaptive, false, false),
    );
    push(
        &mut rows,
        "RandomNEG-Softmax",
        b2.report.test_accuracy,
        b2.report.modeled.modeled_makespan,
        des_seq(NegStrategy::Random, true, false),
    );
    let po_t = po.report.modeled.modeled_makespan;
    let po_des = des_seq(NegStrategy::Fixed, false, true);
    push(&mut rows, "PerfOpt (only last layer)", acc_last, po_t, po_des);
    push(&mut rows, "PerfOpt (using all layers)", acc_all, po_t, po_des);

    print_table(
        "Table 4 — Performance-Optimized model (MNIST geometry)",
        &[
            "model",
            "acc% (measured)",
            "time_s (measured-modeled)",
            "time_s (DES @paper)",
            "paper acc%",
            "paper time_s",
        ],
        &rows,
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_perfopt_cheaper_than_adaptive_at_paper_scale() {
        let mut scale = Scale::quick();
        scale.train_n = 384;
        scale.test_n = 192;
        scale.epochs = 64;
        let rows = run(&scale, EngineKind::Native, 3).unwrap();
        assert_eq!(rows.len(), 4);
        let des: Vec<f64> = rows.iter().map(|r| r.cells[3].parse().unwrap()).collect();
        // PerfOpt (no negatives, no 10-way sweeps) < AdaptiveNEG-Goodness
        assert!(des[2] < des[0], "perfopt {} !< adaptive {}", des[2], des[0]);
    }
}
