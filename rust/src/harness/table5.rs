//! Table 5 — CIFAR-10 experiments: the PerfOpt variants win, the
//! AdaptiveNEG-Goodness model collapses (11.10% in the paper).

use anyhow::Result;

use crate::bench_util::{print_table, Row};
use crate::config::{EngineKind, Scheduler};
use crate::coordinator::eval::evaluate_perfopt_readout;
use crate::data::DatasetKind;
use crate::engine::NativeEngine;
use crate::ff::perfopt::PerfOptReadout;
use crate::ff::{ClassifierMode, NegStrategy};
use crate::harness::common::{des_paper_time, load_bundle, run_measured, Scale};
use crate::row;
use crate::sim::schedules::SimVariant;

/// Paper Table 5 reference: (model, time_s, accuracy_%).
pub const PAPER: &[(&str, f64, f64)] = &[
    ("PerfOpt (using all layers)", 4_920.97, 53.50),
    ("PerfOpt (only last layer)", 4_920.97, 53.11),
    ("FixedNEG-Softmax", 8_021.15, 50.89),
    ("RandomNEG-Softmax", 7_636.99, 52.18),
    ("AdaptiveNEG-Goodness", 10_148.23, 11.10),
];

/// Run Table 5 on CIFAR-geometry data; prints and returns rows.
pub fn run(scale: &Scale, engine: EngineKind, seed: u64) -> Result<Vec<Row>> {
    let scale = scale.cifarized();
    let bundle = load_bundle(&scale, DatasetKind::SynthCifar, seed)?;
    let mut base = scale.config(DatasetKind::SynthCifar, engine);
    base.seed = seed;

    let mut rows = Vec::new();
    let mut push = |name: &str, acc: f64, t: f64, des: f64| {
        let paper = PAPER.iter().find(|(pm, _, _)| *pm == name).copied();
        rows.push(row![
            name,
            format!("{:.2}", acc * 100.0),
            format!("{t:.1}"),
            format!("{des:.0}"),
            paper.map_or("-".into(), |(_, _, a)| format!("{a:.2}")),
            paper.map_or("-".into(), |(_, t, _)| format!("{t:.0}")),
        ]);
    };

    // PerfOpt — one run, two readouts.
    let po = run_measured(
        &bundle,
        &base,
        "PerfOpt",
        Scheduler::Sequential,
        NegStrategy::Random,
        ClassifierMode::Softmax,
        true,
    )?;
    let mut eng = NativeEngine::new();
    let acc_all = evaluate_perfopt_readout(
        &mut eng,
        &po.report.model,
        &bundle.test,
        &base,
        PerfOptReadout::AllLayers,
    )?;
    let acc_last = evaluate_perfopt_readout(
        &mut eng,
        &po.report.model,
        &bundle.test,
        &base,
        PerfOptReadout::LastLayer,
    )?;
    let po_des = des_paper_time(SimVariant::SequentialFF, NegStrategy::Fixed, false, true, true);
    push("PerfOpt (using all layers)", acc_all, po.report.modeled.modeled_makespan, po_des);
    push("PerfOpt (only last layer)", acc_last, po.report.modeled.modeled_makespan, po_des);

    // FixedNEG-Softmax / RandomNEG-Softmax / AdaptiveNEG-Goodness.
    for (name, neg, cls) in [
        ("FixedNEG-Softmax", NegStrategy::Fixed, ClassifierMode::Softmax),
        ("RandomNEG-Softmax", NegStrategy::Random, ClassifierMode::Softmax),
        ("AdaptiveNEG-Goodness", NegStrategy::Adaptive, ClassifierMode::Goodness),
    ] {
        let m = run_measured(&bundle, &base, name, Scheduler::Sequential, neg, cls, false)?;
        let des = des_paper_time(
            SimVariant::SequentialFF,
            neg,
            cls == ClassifierMode::Softmax,
            false,
            true,
        );
        push(name, m.report.test_accuracy, m.report.modeled.modeled_makespan, des);
    }

    print_table(
        "Table 5 — CIFAR-10 (synthetic CIFAR-geometry data)",
        &[
            "model",
            "acc% (measured)",
            "time_s (measured-modeled)",
            "time_s (DES @paper)",
            "paper acc%",
            "paper time_s",
        ],
        &rows,
    );
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_runs_on_cifar_geometry() {
        let mut scale = Scale::quick();
        scale.train_n = 256;
        scale.test_n = 128;
        scale.epochs = 32; // CIFAR-geometry rows just need to run, not win
        let rows = run(&scale, EngineKind::Native, 5).unwrap();
        assert_eq!(rows.len(), 5);
        // every row produced a finite accuracy
        for r in &rows {
            let acc: f64 = r.cells[1].parse().unwrap();
            assert!((0.0..=100.0).contains(&acc));
        }
    }
}
