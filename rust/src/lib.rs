//! # PFF — Pipeline Forward-Forward distributed training
//!
//! Reproduction of *"Going Forward-Forward in Distributed Deep Learning"*
//! (Aktemur et al., 2024): Hinton's Forward-Forward (FF) algorithm trained
//! layer-locally and pipelined across compute nodes.
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`) implement the FF
//!   compute hot-spot (fused normalize→matmul→ReLU forward, goodness
//!   reduction, local gradient, Adam).
//! * **L2** — a JAX model (`python/compile/model.py`) composes the kernels
//!   into whole train/predict steps, lowered **once** to HLO text artifacts
//!   by `python/compile/aot.py`.
//! * **L3** — this crate: loads the artifacts through PJRT ([`runtime`]),
//!   and schedules them across nodes with the paper's pipeline algorithms
//!   ([`coordinator`]). Python never runs on the training path.
//!
//! ## Quick tour
//!
//! * [`tensor`] — minimal dense f32 matrix substrate (blocked matmul, Adam,
//!   deterministic RNG) used by the native engine and data generators.
//! * [`data`] — MNIST/CIFAR loaders + deterministic synthetic stand-ins.
//! * [`ff`] — the Forward-Forward algorithm itself: goodness, label
//!   overlays, negative-sample strategies, classifiers, Performance-
//!   Optimized (local-BP head) layers.
//! * [`engine`] — the compute contract ([`engine::Engine`]) with two
//!   implementations: pure-Rust [`engine::NativeEngine`] and the
//!   PJRT-backed `engine::XlaEngine` (behind the off-by-default `xla`
//!   cargo feature; see README "Build matrix").
//! * [`coordinator`] — the paper's contribution: Sequential / Single-Layer
//!   / All-Layers / Federated PFF schedulers (an open
//!   [`coordinator::Scheduler`] trait + registry) over a chapter-versioned
//!   parameter store, driven through the [`Experiment`] session API with a
//!   typed [`coordinator::RunEvent`] stream and per-node busy/idle metrics.
//! * [`transport`] — in-process channels and a real TCP wire (length-
//!   prefixed, hand-rolled codec) for the parameter store.
//! * [`sim`] — discrete-event pipeline simulator regenerating the paper's
//!   figures (schedules/Gantt) and full-scale timing tables.
//! * [`baselines`] — DFF [11] and backpropagation-pipeline comparators.
//! * [`harness`] — drivers that regenerate every table and figure.
//!
//! ## Quickstart
//!
//! Describe a session with [`Experiment::builder`], launch it, and either
//! watch the typed event stream or just join for the report:
//!
//! ```no_run
//! use pff::coordinator::RunEvent;
//! use pff::{Experiment, ExperimentConfig};
//!
//! let mut cfg = ExperimentConfig::reduced_mnist();
//! cfg.scheduler = pff::config::Scheduler::AllLayers;
//! cfg.nodes = 4;
//!
//! let handle = Experiment::builder()
//!     .config(cfg)
//!     .observer(|ev| {
//!         if let RunEvent::ChapterFinished { node, chapter, loss, .. } = ev {
//!             eprintln!("node {node}: chapter {chapter} done (loss {loss:.4})");
//!         }
//!     })
//!     .launch()?;
//! // handle.cancel() would abort promptly; handle.events() streams RunEvents.
//! let report = handle.join()?;
//! println!("accuracy = {:.2}%", report.test_accuracy * 100.0);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod analyze;
pub mod bench_util;
pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod ff;
pub mod harness;
pub mod metrics;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod sim;
pub mod sync;
pub mod tensor;
pub mod testing;
pub mod transport;

pub use config::ExperimentConfig;
pub use coordinator::{Experiment, ExperimentReport, RunHandle};
