//! `pff` — launcher CLI for the Pipeline Forward-Forward framework.
//!
//! ```text
//! pff train   [--config FILE] [--follow] [--event-csv PATH] [--resume CKPT] [--key value ...]
//! pff worker  --connect HOST:PORT [--node-id K]   join a cluster leader
//! pff serve   --checkpoint PATH [--addr HOST:PORT] [--max-batch N] [--max-delay-us D]
//! pff table1..table5 [--scale quick|reduced] [--engine native|xla]
//! pff figures                                     render Figures 1–6
//! pff fig3    [--scale quick|reduced]             split-count study
//! pff simulate --variant all-layers [--nodes N]   DES at paper scale
//! pff inspect-artifacts [--artifact_dir DIR]      list AOT artifacts
//! pff analyze [--json] [PATHS]                    repo-invariant static analysis
//! pff help
//! ```
//!
//! The library is silent; this binary attaches the stderr observer to the
//! run's event bus (`--follow` or `verbose = true` streams per-chapter
//! progress; cluster registration always prints). `--event-csv PATH`
//! additionally records every [`pff::coordinator::RunEvent`] to a CSV.
//!
//! Cluster mode: the leader runs `pff train --transport tcp --cluster true
//! --tcp_port P --nodes N ...` and opens the task graph once
//! `min_workers` (default: `N`) `pff worker` processes (same config
//! flags, plus `--connect`) have registered; more workers may join
//! mid-run, and a departed worker's task leases are requeued to the
//! survivors.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use pff::config::{EngineKind, ExperimentConfig};
use pff::coordinator::{EventLog, Experiment, RunCheckpoint, RunEvent};
use pff::ff::NegStrategy;
use pff::harness::{figures, table1, table2, table3, table4, table5, Scale};
use pff::sim::schedules::{SimParams, SimVariant};
use pff::sim::{build_schedule, gantt, simulate, CostModel};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "train" => cmd_train(rest),
        "worker" => cmd_worker(rest),
        "serve" => cmd_serve(rest),
        "table1" => cmd_table(rest, 1),
        "table2" => cmd_table(rest, 2),
        "table3" => cmd_table(rest, 3),
        "table4" => cmd_table(rest, 4),
        "table5" => cmd_table(rest, 5),
        "figures" => {
            println!("{}", figures::all_schedule_figures());
            Ok(())
        }
        "fig3" => cmd_fig3(rest),
        "simulate" => cmd_simulate(rest),
        "inspect-artifacts" => cmd_inspect(rest),
        "analyze" => cmd_analyze(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `pff help`)"),
    }
}

fn print_help() {
    println!(
        "pff — Pipeline Forward-Forward distributed training\n\n\
         commands:\n\
         \u{20}  train              run one experiment (--config FILE, --key value overrides;\n\
         \u{20}                     --follow streams per-chapter progress, --event-csv PATH\n\
         \u{20}                     logs the run's event stream;\n\
         \u{20}                     --checkpoint_dir DIR writes durable checkpoints,\n\
         \u{20}                     --resume PATH continues an interrupted run from one;\n\
         \u{20}                     --cluster true parks the leader for external workers)\n\
         \u{20}  worker             join a cluster leader (--connect HOST:PORT, optional --node-id K,\n\
         \u{20}                     --connect-wait-s S, plus the same config flags as train)\n\
         \u{20}  serve              batched inference from a checkpoint (--checkpoint PATH;\n\
         \u{20}                     --addr HOST:PORT bind address, --max-batch N rows per flush,\n\
         \u{20}                     --max-delay-us D queue deadline, --follow streams serve events;\n\
         \u{20}                     answers CLASSIFY/CLASSIFY_BATCH frames — see PROTOCOL.md)\n\
         \u{20}  table1..table5     reproduce a paper table (--scale quick|reduced, --engine native|xla)\n\
         \u{20}  figures            render Figures 1/2/4/5/6 (DES Gantt charts)\n\
         \u{20}  fig3               split-count accuracy study (Figure 3)\n\
         \u{20}  simulate           DES one schedule at paper scale (--variant, --nodes, --neg)\n\
         \u{20}  inspect-artifacts  list AOT artifacts and compile them\n\
         \u{20}  analyze            repo-invariant static analysis (--json for machine output;\n\
         \u{20}                     optional PATHS override the default src/tests/examples roots;\n\
         \u{20}                     exits nonzero on any finding — see README \"Static analysis\")\n\n\
         config keys (train): scheduler, neg, classifier, perfopt, dims, epochs, splits,\n\
         \u{20}  nodes, batch, dataset, engine, transport, seed, theta, lr_ff, lr_head,\n\
         \u{20}  threads (kernel worker threads; 0 = auto via PFF_THREADS env or all cores;\n\
         \u{20}  results are bit-identical at any value),\n\
         \u{20}  workers (in-proc task-graph worker threads; 0 = one per logical node;\n\
         \u{20}  results are bit-identical at any value),\n\
         \u{20}  min_workers (cluster admission: open the task graph at this many\n\
         \u{20}  registered workers instead of parking for exactly `nodes`; 0 = nodes;\n\
         \u{20}  late joiners are admitted mid-run and crashed workers' leases requeued),\n\
         \u{20}  checkpoint_dir (durable RunCheckpoint dir; empty = off),\n\
         \u{20}  checkpoint_every (chapters between checkpoint writes),\n\
         \u{20}  wire_codec (f32|bf16|i8: quantize published matrices and\n\
         \u{20}  checkpoint payloads; deterministic across transports), ...\n"
    );
}

/// Split `--config FILE` off an arg list.
fn split_config(args: &[String]) -> Result<(Option<String>, Vec<String>)> {
    let mut cfg_file = None;
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--config" {
            cfg_file = Some(args.get(i + 1).context("--config needs a path")?.clone());
            i += 2;
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    Ok((cfg_file, rest))
}

/// The CLI's default event observer: the library prints nothing, so this
/// is where run progress reaches stderr. Cluster registration always
/// prints (the old leader log line); everything else only with
/// `--follow` / `verbose = true`.
fn stderr_observer(progress: bool) -> impl Fn(&RunEvent) + Send + Sync + 'static {
    move |ev: &RunEvent| {
        let show = matches!(ev, RunEvent::WorkersRegistered { .. }) || progress;
        if show {
            eprintln!("[pff] {ev}");
        }
    }
}

fn cmd_train(args: &[String]) -> Result<()> {
    let (cfg_file, rest) = split_config(args)?;
    // Strip the binary-level flags before the remainder hits the config
    // parser (which rejects unknown keys).
    let mut follow = false;
    let mut event_csv: Option<String> = None;
    let mut resume: Option<String> = None;
    let mut cfg_args = Vec::new();
    let mut i = 0;
    while i < rest.len() {
        match rest[i].as_str() {
            "--follow" => {
                follow = true;
                i += 1;
            }
            "--event-csv" => {
                event_csv =
                    Some(rest.get(i + 1).context("--event-csv needs a path")?.clone());
                i += 2;
            }
            "--resume" => {
                resume = Some(rest.get(i + 1).context("--resume needs a checkpoint path")?.clone());
                i += 2;
            }
            _ => {
                cfg_args.push(rest[i].clone());
                i += 1;
            }
        }
    }
    // Resuming starts from the checkpoint's embedded config, so plain
    // `pff train --resume PATH` continues the run exactly as launched;
    // CLI overrides still apply (training-relevant keys are guarded at
    // launch). The file is loaded ONCE and handed to the builder — the
    // store dump inside can be large.
    let mut loaded: Option<RunCheckpoint> = None;
    let mut cfg = match (&resume, cfg_file) {
        (Some(_), Some(_)) => bail!(
            "--resume and --config are mutually exclusive: the checkpoint embeds its \
             config (apply --key value overrides on top if needed)"
        ),
        (Some(path), None) => {
            let ck = RunCheckpoint::load(path)?;
            let cfg = ck.experiment_config()?;
            loaded = Some(ck);
            cfg
        }
        (None, Some(path)) => ExperimentConfig::from_file(path)?,
        (None, None) => ExperimentConfig::reduced_mnist(),
    };
    cfg.apply_cli(&cfg_args)?;
    if cfg.cluster {
        let min = if cfg.min_workers == 0 { cfg.nodes } else { cfg.min_workers };
        eprintln!(
            "[leader] hosting store on 127.0.0.1:{}, opening the task graph at {} \
             worker(s) — more may join mid-run (pff worker --connect 127.0.0.1:{})",
            cfg.tcp_port, min, cfg.tcp_port
        );
    }

    let mut builder = Experiment::builder()
        .config(cfg.clone())
        .observer(stderr_observer(follow || cfg.verbose));
    if let Some(ck) = loaded {
        builder = builder.resume_from_checkpoint(ck);
    }
    let log = event_csv.as_ref().map(|_| Arc::new(EventLog::new()));
    if let Some(log) = &log {
        let sink = log.clone();
        builder = builder.observer(move |ev| sink.record(ev));
    }
    let report = builder.launch()?.join()?;
    if let (Some(path), Some(log)) = (&event_csv, &log) {
        log.write_csv(path)?;
        eprintln!("[pff] event log written to {path}");
    }
    println!("{}", report.summary());
    println!("\ntraining curve:\n{}", report.curve.render(12));
    for n in &report.node_reports {
        println!("node {}: busy {:.2}s, waiting {:.2}s", n.node, n.busy(), n.waiting());
    }
    println!(
        "comm: {} puts / {} gets, {:.2} MB published",
        report.comm.puts,
        report.comm.gets,
        report.comm.bytes_put as f64 / 1e6
    );
    Ok(())
}

/// `pff serve`: load a checkpoint, keep the network resident behind a
/// batching admission queue, and answer `CLASSIFY`/`CLASSIFY_BATCH`
/// frames on the store protocol until killed (SIGTERM/Ctrl-C — the
/// process holds no durable state, so default signal teardown is clean).
fn cmd_serve(args: &[String]) -> Result<()> {
    use pff::coordinator::store::MemStore;
    use pff::coordinator::{BatchServer, NodeRegistry, SchedulerRegistry, ServeOptions};
    use pff::transport::tcp::StoreServer;

    let mut checkpoint: Option<String> = None;
    let mut addr = "127.0.0.1:7447".to_string();
    let mut max_batch: usize = 32;
    let mut max_delay_us: u64 = 500;
    let mut follow = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--checkpoint" => {
                checkpoint =
                    Some(args.get(i + 1).context("--checkpoint needs a path")?.clone());
                i += 2;
            }
            "--addr" => {
                addr = args.get(i + 1).context("--addr needs HOST:PORT")?.clone();
                i += 2;
            }
            "--max-batch" => {
                max_batch = args.get(i + 1).context("--max-batch needs a value")?.parse()?;
                i += 2;
            }
            "--max-delay-us" => {
                max_delay_us =
                    args.get(i + 1).context("--max-delay-us needs a value")?.parse()?;
                i += 2;
            }
            "--follow" => {
                follow = true;
                i += 1;
            }
            other => bail!("serve: unknown flag '{other}' (try `pff help`)"),
        }
    }
    let checkpoint = checkpoint.context(
        "serve needs --checkpoint PATH (write one with `pff train --checkpoint_dir DIR`)",
    )?;
    let ck = RunCheckpoint::load(&checkpoint)?;
    let cfg = ck.experiment_config()?.validated()?;
    // The --resume registry guard, reused: a checkpoint records the
    // *registry* name of whatever scheduler ran, and a file from a binary
    // with custom registrations must fail here with the known names —
    // not panic deep inside rehydration/assembly.
    SchedulerRegistry::global().resolve(&ck.scheduler).with_context(|| {
        format!(
            "checkpoint '{checkpoint}' records scheduler '{}', which this binary \
             cannot serve",
            ck.scheduler
        )
    })?;

    let store = Arc::new(MemStore::new());
    store.restore(ck.store);
    let model = pff::coordinator::eval::assemble(store.as_ref(), &cfg)
        .context("assembling the served model from the checkpoint store")?;
    let factory = pff::engine::factory_for(cfg.engine, &cfg.artifact_dir)?;
    let opts = ServeOptions {
        max_batch,
        max_delay: std::time::Duration::from_micros(max_delay_us),
    };
    let serve = BatchServer::start(model, factory, opts)?;
    if follow {
        serve.events().observe(|ev| eprintln!("[pff-serve] {ev}"));
    }
    let server = StoreServer::start_serving(store, Arc::new(NodeRegistry::new()), serve, &addr)?;
    eprintln!(
        "[pff-serve] serving '{checkpoint}' on {} (max_batch {max_batch} rows, \
         max_delay {max_delay_us} us)",
        server.addr
    );
    // Serve until killed. Park instead of joining anything: every live
    // thread (accept loop, conn loops, the batcher) is self-sufficient.
    loop {
        std::thread::park();
    }
}

fn cmd_worker(args: &[String]) -> Result<()> {
    use std::net::ToSocketAddrs;

    let mut connect: Option<String> = None;
    let mut node_id: Option<u32> = None;
    let mut wait_s: u64 = 30;
    let mut rest = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--connect" => {
                connect = Some(args.get(i + 1).context("--connect needs HOST:PORT")?.clone());
                i += 2;
            }
            "--node-id" => {
                node_id = Some(args.get(i + 1).context("--node-id needs a value")?.parse()?);
                i += 2;
            }
            "--connect-wait-s" => {
                wait_s = args.get(i + 1).context("--connect-wait-s needs a value")?.parse()?;
                i += 2;
            }
            _ => {
                rest.push(args[i].clone());
                i += 1;
            }
        }
    }
    let connect = connect.context("worker needs --connect HOST:PORT")?;
    let addr = connect
        .to_socket_addrs()
        .with_context(|| format!("resolving '{connect}'"))?
        .next()
        .with_context(|| format!("'{connect}' resolved to no address"))?;

    let (cfg_file, rest) = split_config(&rest)?;
    let mut cfg = match cfg_file {
        Some(path) => ExperimentConfig::from_file(path)?,
        None => ExperimentConfig::reduced_mnist(),
    };
    cfg.transport = pff::config::TransportKind::Tcp;
    cfg.apply_cli(&rest)?;
    // Workers never lead a cluster themselves, whatever the shared config
    // file says.
    cfg.cluster = false;

    let run = pff::coordinator::node::run_worker(
        &cfg,
        addr,
        node_id,
        std::time::Duration::from_secs(wait_s),
    )?;
    println!(
        "worker {}: busy {:.2}s, waiting {:.2}s, wall {:.2}s",
        run.node_id,
        run.report.busy(),
        run.report.waiting(),
        run.wall_s
    );
    Ok(())
}

/// Parse common harness flags: --scale, --engine, --seed.
fn harness_opts(args: &[String]) -> Result<(Scale, EngineKind, u64)> {
    let mut scale = Scale::quick();
    let mut engine = EngineKind::Native;
    let mut seed = 42u64;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--scale" => {
                let v = args.get(i + 1).context("--scale needs a value")?;
                scale = match v.as_str() {
                    "quick" => Scale::quick(),
                    "reduced" => Scale::reduced(),
                    other => bail!("unknown scale '{other}'"),
                };
                i += 2;
            }
            "--engine" => {
                engine = args.get(i + 1).context("--engine needs a value")?.parse()?;
                i += 2;
            }
            "--seed" => {
                seed = args.get(i + 1).context("--seed needs a value")?.parse()?;
                i += 2;
            }
            other => bail!("unknown flag '{other}'"),
        }
    }
    Ok((scale, engine, seed))
}

fn cmd_table(args: &[String], which: u8) -> Result<()> {
    let (scale, engine, seed) = harness_opts(args)?;
    match which {
        1 => table1::run(&scale, engine, seed).map(|_| ()),
        2 => table2::run(&scale, engine, seed).map(|_| ()),
        3 => table3::run(&scale, engine, seed).map(|_| ()),
        4 => table4::run(&scale, engine, seed).map(|_| ()),
        5 => table5::run(&scale, engine, seed).map(|_| ()),
        _ => unreachable!(),
    }
}

fn cmd_fig3(args: &[String]) -> Result<()> {
    let (scale, engine, seed) = harness_opts(args)?;
    let pts = figures::figure3_measured(&scale, engine, seed, &[1, 2, 4, scale.splits])?;
    println!("Figure 3 — accuracy vs split count (Sequential, RandomNEG):");
    for (s, acc) in pts {
        println!("  S = {s:<4} accuracy = {:.2}%", acc * 100.0);
    }
    Ok(())
}

fn cmd_simulate(args: &[String]) -> Result<()> {
    let mut variant = SimVariant::AllLayersPFF;
    let mut nodes = 4usize;
    let mut neg = NegStrategy::Adaptive;
    let mut splits = 0u32; // 0 = paper default
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--variant" => {
                let v = args.get(i + 1).context("--variant needs a value")?;
                variant = match v.as_str() {
                    "sequential" => SimVariant::SequentialFF,
                    "single-layer" => SimVariant::SingleLayerPFF,
                    "all-layers" => SimVariant::AllLayersPFF,
                    "federated" => SimVariant::FederatedPFF,
                    "backprop" => SimVariant::BackpropPipeline,
                    "dff" => SimVariant::Dff,
                    other => bail!("unknown variant '{other}'"),
                };
                i += 2;
            }
            "--nodes" => {
                nodes = args.get(i + 1).context("--nodes needs a value")?.parse()?;
                i += 2;
            }
            "--neg" => {
                neg = match args.get(i + 1).context("--neg needs a value")?.as_str() {
                    "adaptive" => NegStrategy::Adaptive,
                    "random" => NegStrategy::Random,
                    "fixed" => NegStrategy::Fixed,
                    other => bail!("unknown neg '{other}'"),
                };
                i += 2;
            }
            "--splits" => {
                splits = args.get(i + 1).context("--splits needs a value")?.parse()?;
                i += 2;
            }
            other => bail!("unknown flag '{other}'"),
        }
    }
    let mut cfg = ExperimentConfig::paper_mnist();
    if splits > 0 {
        cfg.splits = splits;
        cfg.epochs = splits;
    }
    if variant == SimVariant::SingleLayerPFF {
        nodes = cfg.num_layers();
    }
    let cm = CostModel::paper_testbed(&cfg);
    let p = SimParams { nodes, neg, softmax_head: false, perfopt: false };
    let tasks = build_schedule(variant, &cm, &p);
    let result = simulate(&tasks);
    println!("{}", gantt::summary_line(&variant.to_string(), &result));
    println!("{}", gantt::render(&tasks, &result, 100));
    // speedup vs sequential at same settings
    let seq = simulate(&build_schedule(SimVariant::SequentialFF, &cm, &p));
    println!(
        "speedup vs Sequential: {:.2}x (paper claims 3.75x for All-Layers AdaptiveNEG, N=4)",
        seq.makespan / result.makespan
    );
    Ok(())
}

#[cfg(feature = "xla")]
fn cmd_inspect(args: &[String]) -> Result<()> {
    let mut dir = std::path::PathBuf::from("artifacts");
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--artifact_dir" {
            dir = args.get(i + 1).context("--artifact_dir needs a value")?.into();
            i += 2;
        } else {
            bail!("unknown flag '{}'", args[i]);
        }
    }
    let mut rt = pff::runtime::Runtime::open(&dir)?;
    println!("artifacts in {}:", dir.display());
    let entries = rt.manifest().entries.clone();
    for e in &entries {
        print!(
            "  {:<14} din={:<5} dout={:<5} b={:<4} norm={}  {}",
            e.op, e.din, e.dout, e.batch, u8::from(e.norm), e.file
        );
        match rt.executable(e) {
            Ok(_) => println!("  [compiles OK]"),
            Err(err) => println!("  [COMPILE FAILED: {err}]"),
        }
    }
    println!("{} modules, {} compiled", entries.len(), rt.cached());
    let _ = pff::harness::common::sim_variant(pff::config::Scheduler::AllLayers); // keep harness linked
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn cmd_inspect(_args: &[String]) -> Result<()> {
    bail!(
        "inspect-artifacts needs the PJRT runtime — rebuild with \
         `cargo build --features xla` (see README \"Build matrix\")"
    )
}

/// `pff analyze [--json] [PATHS]` — run the repo-invariant analyzer.
///
/// With no PATHS the default roots (`rust/src`, `rust/tests`,
/// `examples/`, `README.md`) are scanned; explicit PATHS (files or
/// directories) narrow the tree, and rules whose anchor files fall
/// outside it simply report nothing. Exits nonzero on any finding, so
/// the CI job is just this command.
fn cmd_analyze(args: &[String]) -> Result<()> {
    let mut json = false;
    let mut paths: Vec<std::path::PathBuf> = Vec::new();
    for a in args {
        match a.as_str() {
            "--json" => json = true,
            "--help" | "-h" => {
                println!("pff analyze [--json] [PATHS]\n\nrules:");
                for r in pff::analyze::rules::ALL {
                    println!("  {:<22} {}", r.id, r.summary);
                }
                println!(
                    "\nsuppress a finding at the site with\n  \
                     // pff-allow(rule-id): reason\non the line or in the \
                     comment block directly above it."
                );
                return Ok(());
            }
            other if other.starts_with("--") => {
                bail!("analyze: unknown flag '{other}' (try `pff analyze --help`)")
            }
            other => paths.push(other.into()),
        }
    }
    let roots = if paths.is_empty() { pff::analyze::default_roots()? } else { paths };
    let tree = pff::analyze::Tree::load(&roots)?;
    let findings = pff::analyze::analyze(&tree);
    if json {
        println!("{}", pff::analyze::render_json(&findings));
    } else {
        print!("{}", pff::analyze::render_human(&findings));
        println!(
            "analyze: {} finding(s) over {} file(s), {} rule(s)",
            findings.len(),
            tree.files().len(),
            pff::analyze::rules::ALL.len()
        );
    }
    if findings.is_empty() {
        Ok(())
    } else {
        bail!("analyze: {} finding(s)", findings.len())
    }
}
