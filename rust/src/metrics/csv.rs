//! Tiny CSV writer for experiment outputs (no serde offline), plus the
//! canonical [`RunEvent`] → CSV row projection consumed by
//! `EventLog::write_csv` / `pff train --event-csv`.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::events::RunEvent;

/// Write rows of stringifiable cells to `path`, with a header.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let escaped: Vec<String> = row
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(f, "{}", escaped.join(","))?;
    }
    Ok(())
}

/// Column order of the event CSV. Every [`event_csv_row`] fills exactly
/// these eleven cells (empty where a column does not apply). `raw_bytes`
/// rides at the end so pre-compression consumers' column indices hold.
pub const EVENT_CSV_HEADER: &[&str] = &[
    "event", "node", "layer", "chapter", "loss", "wire_bytes", "accuracy", "ok", "busy_s",
    "wait_s", "raw_bytes",
];

/// Project one [`RunEvent`] onto the [`EVENT_CSV_HEADER`] columns.
///
/// Exhaustive over the `RunEvent` enum by construction (no `_` arm), and
/// checked against the variant list by the `event-csv-exhaustive` rule of
/// `pff analyze` — adding a variant without a row here is a CI failure,
/// not a silently-empty CSV column.
pub fn event_csv_row(ev: &RunEvent) -> Vec<String> {
    let mut row = vec![String::new(); EVENT_CSV_HEADER.len()];
    match ev {
        RunEvent::WorkersRegistered { workers } => {
            row[0] = "workers_registered".into();
            row[1] = workers.len().to_string();
        }
        RunEvent::ChapterStarted { node, layer, chapter } => {
            row[0] = "chapter_started".into();
            row[1] = node.to_string();
            row[2] = layer.map(|l| l.to_string()).unwrap_or_default();
            row[3] = chapter.to_string();
        }
        RunEvent::ChapterFinished { node, layer, chapter, loss, busy_s, wait_s } => {
            row[0] = "chapter_finished".into();
            row[1] = node.to_string();
            row[2] = layer.map(|l| l.to_string()).unwrap_or_default();
            row[3] = chapter.to_string();
            row[4] = format!("{loss}");
            row[8] = format!("{busy_s:.6}");
            row[9] = format!("{wait_s:.6}");
        }
        RunEvent::LayerPublished { node, layer, chapter, wire_bytes, raw_bytes } => {
            row[0] = "layer_published".into();
            row[1] = node.to_string();
            row[2] = layer.to_string();
            row[3] = chapter.to_string();
            row[5] = wire_bytes.to_string();
            row[10] = raw_bytes.to_string();
        }
        RunEvent::HeadPublished { node, chapter, wire_bytes } => {
            row[0] = "head_published".into();
            row[1] = node.to_string();
            row[3] = chapter.to_string();
            row[5] = wire_bytes.to_string();
        }
        RunEvent::CheckpointWritten { wire_bytes, raw_bytes, .. } => {
            row[0] = "checkpoint_written".into();
            row[5] = wire_bytes.to_string();
            row[10] = raw_bytes.to_string();
        }
        RunEvent::TaskStarted { worker, chapter, layer } => {
            row[0] = "task_started".into();
            row[1] = worker.to_string();
            row[2] = layer.to_string();
            row[3] = chapter.to_string();
        }
        RunEvent::TaskStolen { worker, from, chapter, layer } => {
            row[0] = "task_stolen".into();
            row[1] = worker.to_string();
            row[2] = layer.to_string();
            row[3] = chapter.to_string();
            row[4] = from.to_string();
        }
        RunEvent::WorkerJoined { worker, .. } => {
            row[0] = "worker_joined".into();
            row[1] = worker.to_string();
        }
        RunEvent::WorkerLeft { worker, requeued } => {
            row[0] = "worker_left".into();
            row[1] = worker.to_string();
            row[5] = requeued.to_string();
        }
        RunEvent::Eval { accuracy } => {
            row[0] = "eval".into();
            row[6] = format!("{accuracy}");
        }
        RunEvent::Done { ok } => {
            row[0] = "done".into();
            row[7] = ok.to_string();
        }
    }
    row
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join(format!("pff_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        write_csv(
            &path,
            &["a", "b"],
            &[vec!["1".into(), "x,y".into()], vec!["2".into(), "q\"z".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"q\"\"z\""));
        std::fs::remove_dir_all(dir).ok();
    }
}
