//! Tiny CSV writer for experiment outputs (no serde offline).

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Write rows of stringifiable cells to `path`, with a header.
pub fn write_csv(
    path: impl AsRef<Path>,
    header: &[&str],
    rows: &[Vec<String>],
) -> Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        let escaped: Vec<String> = row
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(f, "{}", escaped.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join(format!("pff_csv_{}", std::process::id()));
        let path = dir.join("t.csv");
        write_csv(
            &path,
            &["a", "b"],
            &[vec!["1".into(), "x,y".into()], vec!["2".into(), "q\"z".into()]],
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("a,b\n"));
        assert!(text.contains("\"x,y\""));
        assert!(text.contains("\"q\"\"z\""));
        std::fs::remove_dir_all(dir).ok();
    }
}
