//! Loss/accuracy curves logged during training.

/// One logged point on the training curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    /// Global epoch index (chapter · C + mini-epoch).
    pub epoch: f32,
    /// Mean FF layer loss (or CE for PerfOpt) over the epoch.
    pub loss: f32,
    /// Optional accuracy measurement (NaN = not measured).
    pub accuracy: f32,
}

/// Append-only training curve.
#[derive(Clone, Debug, Default)]
pub struct LossCurve {
    /// Logged points in order.
    pub points: Vec<CurvePoint>,
}

impl LossCurve {
    /// Log a loss-only point.
    pub fn push_loss(&mut self, epoch: f32, loss: f32) {
        self.points.push(CurvePoint { epoch, loss, accuracy: f32::NAN });
    }

    /// Log a point with accuracy.
    pub fn push(&mut self, epoch: f32, loss: f32, accuracy: f32) {
        self.points.push(CurvePoint { epoch, loss, accuracy });
    }

    /// Log one finished chapter's loss at its end-of-chapter epoch — the
    /// event-stream entry point (`RunEvent::ChapterFinished` consumers
    /// build curves with this; see `coordinator::EventLog::chapter_curve`).
    pub fn push_chapter(&mut self, chapter: u32, epochs_per_chapter: u32, loss: f32) {
        self.push_loss((chapter + 1) as f32 * epochs_per_chapter as f32, loss);
    }

    /// Restore epoch order after out-of-order pushes (concurrent nodes
    /// finish chapters out of sequence).
    pub fn sort_by_epoch(&mut self) {
        self.points.sort_by(|a, b| a.epoch.partial_cmp(&b.epoch).unwrap());
    }

    /// Merge another curve (e.g. from another node), keeping epoch order.
    pub fn merge(&mut self, other: &LossCurve) {
        self.points.extend_from_slice(&other.points);
        self.sort_by_epoch();
    }

    /// Final loss (last point), if any.
    pub fn final_loss(&self) -> Option<f32> {
        self.points.last().map(|p| p.loss)
    }

    /// Render as a compact multi-line string for logs/EXPERIMENTS.md.
    pub fn render(&self, max_rows: usize) -> String {
        if self.points.is_empty() {
            return "(empty curve)".into();
        }
        let stride = (self.points.len() / max_rows.max(1)).max(1);
        let mut out = String::from("epoch   loss      acc\n");
        for (i, p) in self.points.iter().enumerate() {
            if i % stride != 0 && i != self.points.len() - 1 {
                continue;
            }
            if p.accuracy.is_nan() {
                out.push_str(&format!("{:<7.2} {:<9.4} -\n", p.epoch, p.loss));
            } else {
                out.push_str(&format!("{:<7.2} {:<9.4} {:.2}%\n", p.epoch, p.loss, p.accuracy * 100.0));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn merge_sorts_by_epoch() {
        let mut a = LossCurve::default();
        a.push_loss(0.0, 1.0);
        a.push_loss(2.0, 0.5);
        let mut b = LossCurve::default();
        b.push_loss(1.0, 0.8);
        a.merge(&b);
        let epochs: Vec<f32> = a.points.iter().map(|p| p.epoch).collect();
        assert_eq!(epochs, vec![0.0, 1.0, 2.0]);
        assert_eq!(a.final_loss(), Some(0.5));
    }

    #[test]
    fn render_contains_rows() {
        let mut c = LossCurve::default();
        for i in 0..10 {
            c.push(i as f32, 1.0 / (i + 1) as f32, 0.1 * i as f32);
        }
        let s = c.render(5);
        assert!(s.contains("epoch"));
        assert!(s.lines().count() <= 12);
    }
}
