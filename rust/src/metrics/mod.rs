//! Timing spans, utilization accounting, loss curves and CSV output.
//!
//! The paper reports wall-clock training time and (implicitly) node
//! utilization ("94% utilization (3.75/4)"). On this 1-core testbed,
//! concurrent node threads cannot exhibit real parallel speedup, so the
//! measured path records *per-node spans* (what each node did, when, for
//! how long) and [`makespan`] replays the span DAG as if nodes ran on
//! dedicated hardware — yielding an honest multi-node wall-clock estimate
//! alongside raw busy-time sums. The DES (`crate::sim`) covers the paper's
//! full scale analytically.

pub mod csv;
pub mod curve;
pub mod span;

pub use curve::LossCurve;
pub use span::{makespan, MakespanModel, NodeReport, Span, SpanKind, SpanRecorder};

/// Communication accounting from the parameter store.
#[derive(Clone, Copy, Debug, Default)]
pub struct CommStats {
    /// Number of publish (put) operations.
    pub puts: u64,
    /// Number of fetch (get) operations.
    pub gets: u64,
    /// Total published payload bytes.
    pub bytes_put: u64,
    /// Total fetched payload bytes.
    pub bytes_get: u64,
}

impl CommStats {
    /// Accumulate another stats block.
    pub fn merge(&mut self, o: &CommStats) {
        self.puts += o.puts;
        self.gets += o.gets;
        self.bytes_put += o.bytes_put;
        self.bytes_get += o.bytes_get;
    }
}
