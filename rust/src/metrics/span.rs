//! Per-node activity spans and pipeline makespan replay.

use std::time::Instant;

/// What a node was doing during a span.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpanKind {
    /// FF (or PerfOpt) layer training.
    Train,
    /// Forward transform of the dataset through earlier layers.
    Forward,
    /// Blocked waiting for a layer publish from another node.
    WaitLayer,
    /// Blocked waiting for negative labels.
    WaitNeg,
    /// Blocked waiting for a task lease from the dispatcher.
    WaitTask,
    /// Generating negative labels (AdaptiveNEG sweep).
    NegGen,
    /// Publishing parameters to the store.
    Publish,
    /// Softmax-head training.
    HeadTrain,
    /// Evaluation (test sweeps).
    Eval,
}

impl SpanKind {
    /// Does this span count as useful work (vs waiting)?
    pub fn is_busy(self) -> bool {
        !matches!(self, SpanKind::WaitLayer | SpanKind::WaitNeg | SpanKind::WaitTask)
    }

    /// Short label for Gantt rendering.
    pub fn tag(self) -> &'static str {
        match self {
            SpanKind::Train => "T",
            SpanKind::Forward => "F",
            SpanKind::WaitLayer => ".",
            SpanKind::WaitNeg => ",",
            SpanKind::WaitTask => "w",
            SpanKind::NegGen => "N",
            SpanKind::Publish => "P",
            SpanKind::HeadTrain => "H",
            SpanKind::Eval => "E",
        }
    }
}

/// One timed activity on one node.
#[derive(Clone, Debug)]
pub struct Span {
    /// Activity class.
    pub kind: SpanKind,
    /// Start offset from experiment t0, seconds.
    pub t0: f64,
    /// End offset, seconds.
    pub t1: f64,
    /// Layer index the activity concerned (usize::MAX = none).
    pub layer: usize,
    /// Chapter index.
    pub chapter: u32,
}

impl Span {
    /// Span duration in seconds.
    pub fn dur(&self) -> f64 {
        self.t1 - self.t0
    }
}

/// Records spans on one node against a shared epoch origin.
pub struct SpanRecorder {
    origin: Instant,
    node: usize,
    spans: Vec<Span>,
}

impl SpanRecorder {
    /// New recorder for `node`, measuring from `origin`.
    pub fn new(origin: Instant, node: usize) -> Self {
        SpanRecorder { origin, node, spans: Vec::new() }
    }

    /// Time an activity, recording a span around the closure.
    pub fn time<T>(&mut self, kind: SpanKind, layer: usize, chapter: u32, f: impl FnOnce() -> T) -> T {
        let t0 = self.origin.elapsed().as_secs_f64();
        let out = f();
        let t1 = self.origin.elapsed().as_secs_f64();
        self.spans.push(Span { kind, t0, t1, layer, chapter });
        out
    }

    /// Position marker for [`SpanRecorder::split_since`] — call before a
    /// chapter, pass back after it to get that chapter's timing split.
    pub fn mark(&self) -> usize {
        self.spans.len()
    }

    /// `(busy_s, wait_s)` accumulated over spans recorded since `mark` —
    /// the per-chapter compute/wait split surfaced on
    /// `RunEvent::ChapterFinished`.
    pub fn split_since(&self, mark: usize) -> (f64, f64) {
        let (mut busy, mut wait) = (0.0, 0.0);
        for s in &self.spans[mark.min(self.spans.len())..] {
            if s.kind.is_busy() {
                busy += s.dur();
            } else {
                wait += s.dur();
            }
        }
        (busy, wait)
    }

    /// Finish, producing the node's report.
    pub fn finish(self) -> NodeReport {
        NodeReport { node: self.node, spans: self.spans }
    }
}

/// All spans recorded by one node.
#[derive(Clone, Debug)]
pub struct NodeReport {
    /// Node index.
    pub node: usize,
    /// Spans in recording order.
    pub spans: Vec<Span>,
}

impl NodeReport {
    /// Total busy (non-wait) seconds.
    pub fn busy(&self) -> f64 {
        self.spans.iter().filter(|s| s.kind.is_busy()).map(Span::dur).sum()
    }

    /// Total wait seconds.
    pub fn waiting(&self) -> f64 {
        self.spans.iter().filter(|s| !s.kind.is_busy()).map(Span::dur).sum()
    }

    /// Seconds spent in `kind`.
    pub fn in_kind(&self, kind: SpanKind) -> f64 {
        self.spans.iter().filter(|s| s.kind == kind).map(Span::dur).sum()
    }

    /// Last span end (node-local wall).
    pub fn end(&self) -> f64 {
        self.spans.iter().map(|s| s.t1).fold(0.0, f64::max)
    }
}

/// Replay per-node busy spans as if each node had a dedicated core:
/// node-local order is preserved, wait spans are collapsed to the true
/// dependency (they only existed because another node hadn't published).
///
/// This is a *lower bound* makespan model: it assumes waits shrink to zero
/// when producers run in true parallel, which holds for PFF's structure
/// (waits are only on predecessor publishes). Returns per-node busy sums
/// and the modeled pipeline makespan = max over nodes of busy time, i.e.
/// the steady-state bound the paper's utilization figure references.
pub fn makespan(reports: &[NodeReport]) -> MakespanModel {
    let busy: Vec<f64> = reports.iter().map(NodeReport::busy).collect();
    let total_busy: f64 = busy.iter().sum();
    let modeled = busy.iter().copied().fold(0.0, f64::max);
    let n = reports.len().max(1) as f64;
    MakespanModel {
        per_node_busy: busy,
        modeled_makespan: modeled,
        total_busy,
        utilization: if modeled > 0.0 { total_busy / (modeled * n) } else { 0.0 },
    }
}

/// Output of [`makespan`].
#[derive(Clone, Debug)]
pub struct MakespanModel {
    /// Busy seconds per node.
    pub per_node_busy: Vec<f64>,
    /// Modeled parallel wall-clock (max node busy).
    pub modeled_makespan: f64,
    /// Sum of busy seconds over nodes (≈ sequential cost).
    pub total_busy: f64,
    /// total_busy / (makespan · N) — the paper's utilization metric.
    pub utilization: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, t0: f64, t1: f64) -> Span {
        Span { kind, t0, t1, layer: 0, chapter: 0 }
    }

    #[test]
    fn busy_wait_accounting() {
        let r = NodeReport {
            node: 0,
            spans: vec![
                span(SpanKind::Train, 0.0, 2.0),
                span(SpanKind::WaitLayer, 2.0, 3.0),
                span(SpanKind::Publish, 3.0, 3.5),
            ],
        };
        assert!((r.busy() - 2.5).abs() < 1e-9);
        assert!((r.waiting() - 1.0).abs() < 1e-9);
        assert!((r.end() - 3.5).abs() < 1e-9);
        assert!((r.in_kind(SpanKind::Train) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn makespan_is_max_busy() {
        let a = NodeReport { node: 0, spans: vec![span(SpanKind::Train, 0.0, 4.0)] };
        let b = NodeReport {
            node: 1,
            spans: vec![span(SpanKind::WaitLayer, 0.0, 2.0), span(SpanKind::Train, 2.0, 5.0)],
        };
        let m = makespan(&[a, b]);
        assert!((m.modeled_makespan - 4.0).abs() < 1e-9);
        assert!((m.total_busy - 7.0).abs() < 1e-9);
        assert!((m.utilization - 7.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn split_since_separates_busy_and_wait() {
        let mut rec = SpanRecorder::new(Instant::now(), 0);
        rec.time(SpanKind::Train, 0, 0, || {});
        let mark = rec.mark();
        // pff-allow(no-sleep-sync): the sleep IS the measured workload
        // here (a span must have nonzero duration), not a wait.
        let nap = || std::thread::sleep(std::time::Duration::from_millis(2));
        rec.time(SpanKind::Train, 0, 1, nap);
        rec.time(SpanKind::WaitLayer, 0, 1, nap);
        let (busy, wait) = rec.split_since(mark);
        assert!(busy >= 0.001, "busy {busy}");
        assert!(wait >= 0.001, "wait {wait}");
        let (all_busy, _) = rec.split_since(0);
        assert!(all_busy >= busy);
        assert_eq!(rec.split_since(usize::MAX), (0.0, 0.0), "future mark is empty");
    }

    #[test]
    fn recorder_orders_spans() {
        let mut rec = SpanRecorder::new(Instant::now(), 3);
        // pff-allow(no-sleep-sync): the sleep is the measured workload.
        rec.time(SpanKind::Train, 0, 0, || std::thread::sleep(std::time::Duration::from_millis(2)));
        rec.time(SpanKind::Publish, 0, 0, || {});
        let rep = rec.finish();
        assert_eq!(rep.node, 3);
        assert_eq!(rep.spans.len(), 2);
        assert!(rep.spans[0].t1 <= rep.spans[1].t0 + 1e-6);
        assert!(rep.spans[0].dur() >= 0.001);
    }
}
