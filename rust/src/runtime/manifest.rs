//! `artifacts/manifest.txt` parser.

use std::path::Path;

use anyhow::{bail, Context, Result};

/// One artifact record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    /// Operation name (`layer_fwd`, `ff_step`, `head_logits`, `head_step`,
    /// `perfopt_step`).
    pub op: String,
    /// Input feature dim.
    pub din: usize,
    /// Output dim (layer width or classes).
    pub dout: usize,
    /// Static batch the module was lowered for.
    pub batch: usize,
    /// Whether the op length-normalizes its input rows.
    pub norm: bool,
    /// HLO text file name (relative to the artifact dir).
    pub file: String,
}

/// Parsed artifact manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// All entries in file order.
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load from `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.as_ref().display()
            )
        })?;
        Manifest::parse(&text)
    }

    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut op = None;
            let mut din = None;
            let mut dout = None;
            let mut batch = None;
            let mut norm = None;
            let mut file = None;
            for tok in line.split_whitespace() {
                let Some((k, v)) = tok.split_once('=') else {
                    bail!("manifest line {}: bad token '{tok}'", lineno + 1);
                };
                match k {
                    "op" => op = Some(v.to_string()),
                    "din" => din = Some(v.parse()?),
                    "dout" => dout = Some(v.parse()?),
                    "b" => batch = Some(v.parse()?),
                    "norm" => norm = Some(v == "1" || v == "true"),
                    "file" => file = Some(v.to_string()),
                    other => bail!("manifest line {}: unknown key '{other}'", lineno + 1),
                }
            }
            entries.push(ManifestEntry {
                op: op.context("manifest: missing op")?,
                din: din.context("manifest: missing din")?,
                dout: dout.context("manifest: missing dout")?,
                batch: batch.context("manifest: missing b")?,
                norm: norm.unwrap_or(false),
                file: file.context("manifest: missing file")?,
            });
        }
        Ok(Manifest { entries })
    }

    /// Find the entry for `(op, din, dout, norm)`.
    pub fn find(&self, op: &str, din: usize, dout: usize, norm: bool) -> Option<ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.op == op && e.din == din && e.dout == dout && e.norm == norm)
            .cloned()
    }

    /// All distinct ops present.
    pub fn ops(&self) -> Vec<String> {
        let mut ops: Vec<String> = self.entries.iter().map(|e| e.op.clone()).collect();
        ops.sort();
        ops.dedup();
        ops
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment line
op=ff_step din=784 dout=256 b=64 norm=0 file=ff_step_784x256_b64_raw.hlo.txt
op=ff_step din=256 dout=256 b=64 norm=1 file=ff_step_256x256_b64_norm.hlo.txt

op=layer_fwd din=784 dout=256 b=64 norm=0 file=layer_fwd_784x256_b64_raw.hlo.txt
";

    #[test]
    fn parses_entries_and_find() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 3);
        let e = m.find("ff_step", 256, 256, true).unwrap();
        assert_eq!(e.batch, 64);
        assert!(e.norm);
        assert!(m.find("ff_step", 256, 256, false).is_none());
        assert_eq!(m.ops(), vec!["ff_step".to_string(), "layer_fwd".to_string()]);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Manifest::parse("op=x din=1").is_err()); // missing fields
        assert!(Manifest::parse("not_kv_token\n").is_err());
        assert!(Manifest::parse("op=x din=1 dout=1 b=1 zzz=2 file=f").is_err());
    }
}
