//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! Interchange is **HLO text**, not serialized `HloModuleProto` — jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `/opt/xla-example/README.md` and DESIGN.md).
//!
//! Artifacts are described by `artifacts/manifest.txt`, one entry per
//! line of whitespace-separated `key=value` tokens:
//!
//! ```text
//! op=ff_step din=784 dout=256 b=64 norm=0 file=ff_step_784x256_b64_raw.hlo.txt
//! ```

pub mod manifest;

pub use manifest::{Manifest, ManifestEntry};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::tensor::Matrix;

/// A PJRT CPU session holding compiled executables, lazily compiled from
/// the artifact directory and cached by file name.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new() })
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Find the manifest entry for `(op, din, dout, norm)`.
    pub fn entry(&self, op: &str, din: usize, dout: usize, norm: bool) -> Result<ManifestEntry> {
        self.manifest.find(op, din, dout, norm).with_context(|| {
            format!(
                "no artifact for op={op} din={din} dout={dout} norm={} — regenerate with \
                 `make artifacts` (profile must cover these dims)",
                u8::from(norm)
            )
        })
    }

    /// Compile (or fetch cached) the executable for a manifest entry.
    pub fn executable(&mut self, entry: &ManifestEntry) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&entry.file) {
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", path.display()))?;
            self.cache.insert(entry.file.clone(), exe);
        }
        Ok(&self.cache[&entry.file])
    }

    /// Execute a compiled entry on literal inputs; returns the flattened
    /// tuple of outputs (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&mut self, entry: &ManifestEntry, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(entry)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", entry.file))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {}: {e}", entry.file))?;
        lit.to_tuple().map_err(|e| anyhow::anyhow!("untupling {}: {e}", entry.file))
    }

    /// Number of compiled executables currently cached.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}

/// Convert a [`Matrix`] to a 2-D f32 literal.
pub fn matrix_literal(m: &Matrix) -> Result<xla::Literal> {
    xla::Literal::vec1(&m.data)
        .reshape(&[m.rows as i64, m.cols as i64])
        .map_err(|e| anyhow::anyhow!("reshape literal: {e}"))
}

/// Convert a slice to a 1-D f32 literal.
pub fn vec_literal(v: &[f32]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// f32 scalar literal.
pub fn scalar_literal(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read a 2-D literal back into a [`Matrix`] with the given shape.
pub fn literal_matrix(lit: &xla::Literal, rows: usize, cols: usize) -> Result<Matrix> {
    let data = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?;
    anyhow::ensure!(data.len() == rows * cols, "literal size {} != {rows}x{cols}", data.len());
    Ok(Matrix::from_vec(rows, cols, data))
}

/// Read a 1-D literal into a Vec.
pub fn literal_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))
}

/// Read a scalar literal.
pub fn literal_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to_vec: {e}"))?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}
