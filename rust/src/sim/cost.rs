//! Analytic cost model for the simulator.
//!
//! FLOP counts are exact for the implemented algorithms; the two free
//! parameters (`node_gflops`, `adaptive_subsample`) are calibrated so the
//! *Sequential RandomNEG* baseline and the AdaptiveNEG/RandomNEG time
//! ratio land near the paper's Table 1 (7,178 s and 11,190/7,178 ≈ 1.56).
//! Everything else (speedups, crossovers, utilization) is then emergent —
//! the quantity we claim to reproduce is the **shape**, per DESIGN.md.

use crate::config::ExperimentConfig;

/// Cost model for one experiment configuration.
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Layer widths including input.
    pub dims: Vec<usize>,
    /// Training examples.
    pub train_n: usize,
    /// Minibatch size.
    pub batch: usize,
    /// Total epochs E.
    pub epochs: u32,
    /// Splits S.
    pub splits: u32,
    /// Classes (goodness prediction fans out this many forwards).
    pub classes: usize,
    /// Effective node throughput, GFLOP/s.
    pub node_gflops: f64,
    /// Link bandwidth, bytes/s.
    pub bandwidth: f64,
    /// Per-message latency, seconds.
    pub latency: f64,
    /// Fraction of the train set swept by the AdaptiveNEG refresh.
    pub adaptive_subsample: f64,
}

impl CostModel {
    /// Model of the paper's testbed (§5.1 scale), calibrated per module
    /// docs: commodity nodes over sockets.
    pub fn paper_testbed(cfg: &ExperimentConfig) -> CostModel {
        CostModel {
            dims: cfg.dims.clone(),
            train_n: if cfg.train_n == 0 { 60_000 } else { cfg.train_n },
            batch: cfg.batch,
            epochs: cfg.epochs,
            splits: cfg.splits,
            classes: cfg.classes,
            node_gflops: 90.0,
            bandwidth: 117e6, // ~1 GbE effective
            latency: 2e-3,
            adaptive_subsample: 0.22,
        }
    }

    /// Epochs per chapter.
    pub fn epochs_per_chapter(&self) -> f64 {
        f64::from(self.epochs) / f64::from(self.splits)
    }

    /// Number of FF layers.
    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// Minibatches per epoch.
    pub fn batches_per_epoch(&self) -> f64 {
        (self.train_n as f64 / self.batch as f64).ceil()
    }

    fn gf(&self, flops: f64) -> f64 {
        flops / (self.node_gflops * 1e9)
    }

    /// FLOPs of one FF minibatch step on layer `l` (pos+neg = 2B rows):
    /// forward 2·(2B)·din·dout, grad dW 2·(2B)·din·dout, Adam ~10·din·dout.
    pub fn ff_step_flops(&self, l: usize) -> f64 {
        let (din, dout) = (self.dims[l] as f64, self.dims[l + 1] as f64);
        let b2 = 2.0 * self.batch as f64;
        4.0 * b2 * din * dout + 10.0 * din * dout
    }

    /// Seconds to train layer `l` for one chapter (C epochs).
    pub fn train_chapter_s(&self, l: usize) -> f64 {
        self.gf(self.ff_step_flops(l) * self.batches_per_epoch() * self.epochs_per_chapter())
    }

    /// Seconds of one PerfOpt chapter on layer `l` (adds the head's
    /// forward+backward: ≈ 6·B·dout·classes per step).
    pub fn perfopt_chapter_s(&self, l: usize) -> f64 {
        let dout = self.dims[l + 1] as f64;
        let head = 6.0 * self.batch as f64 * dout * self.classes as f64;
        // PerfOpt uses only B rows (no negative pass): half the FF matmuls.
        let step = self.ff_step_flops(l) / 2.0 + head;
        self.gf(step * self.batches_per_epoch() * self.epochs_per_chapter())
    }

    /// Seconds to forward the full train set through layer `l` once.
    pub fn forward_s(&self, l: usize) -> f64 {
        let (din, dout) = (self.dims[l] as f64, self.dims[l + 1] as f64);
        self.gf(2.0 * self.train_n as f64 * din * dout)
    }

    /// Wire seconds to publish (or fetch) layer `l`'s parameters.
    pub fn publish_s(&self, l: usize) -> f64 {
        let bytes = (self.dims[l] * self.dims[l + 1] + self.dims[l + 1]) as f64 * 4.0;
        self.latency + bytes / self.bandwidth
    }

    /// Wire seconds to ship the *activations* of the full dataset at layer
    /// `l`'s output — DFF's per-exchange cost (the paper's §6 comparison).
    pub fn activations_wire_s(&self, l: usize) -> f64 {
        let bytes = (self.train_n * self.dims[l + 1]) as f64 * 4.0 * 2.0; // pos+neg
        self.latency + bytes / self.bandwidth
    }

    /// Seconds of one AdaptiveNEG refresh: goodness sweep = `classes`
    /// forwards of the (subsampled) train set through all layers.
    pub fn neggen_s(&self) -> f64 {
        let full: f64 = (0..self.n_layers()).map(|l| self.forward_s(l)).sum();
        self.classes as f64 * full * self.adaptive_subsample
    }

    /// Seconds of one softmax-head chapter (train head on all-but-first
    /// activations: din = Σ dims[2..], plus the feature forward).
    pub fn head_chapter_s(&self) -> f64 {
        let din: f64 = self.dims[2..].iter().map(|&d| d as f64).sum();
        let steps = self.batches_per_epoch() * self.epochs_per_chapter();
        let step = 6.0 * self.batch as f64 * din * self.classes as f64;
        let feature_fwd: f64 = (0..self.n_layers()).map(|l| self.forward_s(l)).sum();
        self.gf(step * steps) + feature_fwd
    }

    /// Total FF training FLOPs for the whole run (all layers, all epochs)
    /// — used for roofline sanity checks.
    pub fn total_train_flops(&self) -> f64 {
        (0..self.n_layers())
            .map(|l| self.ff_step_flops(l) * self.batches_per_epoch() * f64::from(self.epochs))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper() -> CostModel {
        CostModel::paper_testbed(&ExperimentConfig::paper_mnist())
    }

    #[test]
    fn sequential_randomneg_lands_near_paper() {
        // Sequential RandomNEG ≈ sum of all chapter train costs + fwd
        // transforms. Paper: 7,178 s. Accept a generous band — we claim
        // shape, not absolutes.
        let m = paper();
        let mut total = 0.0;
        for _c in 0..m.splits {
            for l in 0..m.n_layers() {
                total += m.train_chapter_s(l);
                if l + 1 < m.n_layers() {
                    total += 2.0 * m.forward_s(l); // pos+neg transform
                }
            }
        }
        assert!(
            (4000.0..12_000.0).contains(&total),
            "sequential estimate {total:.0}s should be near the paper's 7,178 s"
        );
    }

    #[test]
    fn adaptive_overhead_ratio_near_paper() {
        // AdaptiveNEG adds one neggen per chapter; ratio vs RandomNEG
        // should be near 11,190/7,178 ≈ 1.56.
        let m = paper();
        let train: f64 = (0..m.splits as usize)
            .map(|_| (0..m.n_layers()).map(|l| m.train_chapter_s(l)).sum::<f64>())
            .sum();
        let adaptive = train + f64::from(m.splits) * m.neggen_s();
        let ratio = adaptive / train;
        assert!((1.3..1.9).contains(&ratio), "adaptive/random ratio {ratio:.2}");
    }

    #[test]
    fn publish_far_cheaper_than_activations() {
        // The §6 claim: PFF ships params, DFF ships activations — orders
        // of magnitude more bytes at MNIST scale.
        let m = paper();
        assert!(m.activations_wire_s(0) > 20.0 * m.publish_s(0));
    }

    #[test]
    fn flop_counts_scale_with_dims() {
        let m = paper();
        assert!(m.ff_step_flops(1) > m.ff_step_flops(0)); // 2000×2000 > 784×2000
        assert!(m.total_train_flops() > 1e14);
    }
}
