//! Dependency-graph schedule executor.
//!
//! Tasks carry a node assignment, duration and dependency list. Each node
//! executes its tasks strictly in submission order (matching the real
//! schedulers, which are straight-line loops); a task starts when its node
//! is free AND all dependencies have finished — exactly the semantics of
//! a blocking `get_layer` against the parameter store.

use crate::metrics::SpanKind;

/// One simulated activity.
#[derive(Clone, Debug)]
pub struct Task {
    /// Node that executes it.
    pub node: usize,
    /// Duration, seconds.
    pub dur: f64,
    /// Indices of tasks that must finish first (must be < own index).
    pub deps: Vec<usize>,
    /// Activity class (drives Gantt glyphs).
    pub kind: SpanKind,
    /// Human label, e.g. `T(L2,c3)`.
    pub label: String,
}

/// Executed schedule.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// Start time per task.
    pub start: Vec<f64>,
    /// End time per task.
    pub end: Vec<f64>,
    /// Total makespan.
    pub makespan: f64,
    /// Busy seconds per node.
    pub node_busy: Vec<f64>,
    /// Node count.
    pub n_nodes: usize,
}

impl SimResult {
    /// total busy / (makespan · N) — the paper's utilization metric.
    pub fn utilization(&self) -> f64 {
        let total: f64 = self.node_busy.iter().sum();
        if self.makespan > 0.0 && self.n_nodes > 0 {
            total / (self.makespan * self.n_nodes as f64)
        } else {
            0.0
        }
    }
}

/// Execute `tasks` (see module docs).
///
/// # Panics
/// If a dependency references a later task (graphs are built in program
/// order, so this indicates a scheduler-builder bug).
pub fn simulate(tasks: &[Task]) -> SimResult {
    let n_nodes = tasks.iter().map(|t| t.node + 1).max().unwrap_or(0);
    let mut node_free = vec![0.0f64; n_nodes];
    let mut node_busy = vec![0.0f64; n_nodes];
    let mut start = vec![0.0f64; tasks.len()];
    let mut end = vec![0.0f64; tasks.len()];
    for (i, t) in tasks.iter().enumerate() {
        let dep_ready = t
            .deps
            .iter()
            .map(|&d| {
                assert!(d < i, "task {i} depends on later task {d}");
                end[d]
            })
            .fold(0.0f64, f64::max);
        let s = node_free[t.node].max(dep_ready);
        start[i] = s;
        end[i] = s + t.dur;
        node_free[t.node] = end[i];
        node_busy[t.node] += t.dur;
    }
    let makespan = end.iter().copied().fold(0.0, f64::max);
    SimResult { start, end, makespan, node_busy, n_nodes }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(node: usize, dur: f64, deps: Vec<usize>) -> Task {
        Task { node, dur, deps, kind: SpanKind::Train, label: String::new() }
    }

    #[test]
    fn sequential_on_one_node_sums() {
        let r = simulate(&[t(0, 1.0, vec![]), t(0, 2.0, vec![]), t(0, 3.0, vec![])]);
        assert_eq!(r.makespan, 6.0);
        assert_eq!(r.node_busy, vec![6.0]);
        assert!((r.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dependency_delays_start() {
        // node 1's task waits for node 0's.
        let r = simulate(&[t(0, 2.0, vec![]), t(1, 1.0, vec![0])]);
        assert_eq!(r.start[1], 2.0);
        assert_eq!(r.makespan, 3.0);
    }

    #[test]
    fn independent_nodes_run_parallel() {
        let r = simulate(&[t(0, 2.0, vec![]), t(1, 2.0, vec![])]);
        assert_eq!(r.makespan, 2.0);
        assert!((r.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pipeline_fill_shape() {
        // 2-stage pipeline, 3 items: classic makespan = (stages + items - 1) · d
        let mut tasks = Vec::new();
        for item in 0..3usize {
            let dep0 = if item == 0 { vec![] } else { vec![(item - 1) * 2] };
            tasks.push(t(0, 1.0, dep0)); // stage A
            tasks.push(t(1, 1.0, vec![item * 2])); // stage B dep on own A
        }
        let r = simulate(&tasks);
        assert_eq!(r.makespan, 4.0); // (2 + 3 - 1) · 1
    }
}
