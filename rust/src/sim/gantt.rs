//! ASCII Gantt rendering of simulated schedules — the regenerator for the
//! paper's Figures 1–6.
//!
//! One row per node, time bucketed to a fixed width; each bucket shows the
//! glyph of the task occupying it (`T` train, `F` forward, `P` publish,
//! `N` neg-gen, `H` head, `.` idle).

use crate::sim::engine::{SimResult, Task};

/// Render `width`-column Gantt chart of a simulated schedule.
pub fn render(tasks: &[Task], result: &SimResult, width: usize) -> String {
    let width = width.max(10);
    let span = result.makespan.max(1e-9);
    let dt = span / width as f64;
    let mut rows = vec![vec!['.'; width]; result.n_nodes];
    for (i, t) in tasks.iter().enumerate() {
        if t.dur <= 0.0 {
            continue;
        }
        let c0 = (result.start[i] / dt).floor() as usize;
        let c1 = ((result.end[i] / dt).ceil() as usize).min(width);
        let glyph = t.kind.tag().chars().next().unwrap_or('?');
        for cell in rows[t.node].iter_mut().take(c1).skip(c0.min(width)) {
            // Publish is usually sub-bucket; don't let it erase Train.
            if *cell == '.' || glyph == 'T' {
                *cell = glyph;
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "time: 0 ──────────────────────────────▶ {:.1}s   (util {:.1}%)\n",
        span,
        result.utilization() * 100.0
    ));
    for (n, row) in rows.iter().enumerate() {
        out.push_str(&format!("node {:>2} │", n + 1));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str("legend: T=train F=forward P=publish N=neg-gen H=head .=idle\n");
    out
}

/// Compact per-variant summary line for table output.
pub fn summary_line(name: &str, result: &SimResult) -> String {
    format!(
        "{:<22} makespan {:>10.1}s   util {:>5.1}%   node-busy [{}]",
        name,
        result.makespan,
        result.utilization() * 100.0,
        result
            .node_busy
            .iter()
            .map(|b| format!("{b:.0}"))
            .collect::<Vec<_>>()
            .join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::SpanKind;
    use crate::sim::engine::simulate;

    #[test]
    fn renders_rows_per_node() {
        let tasks = vec![
            Task { node: 0, dur: 1.0, deps: vec![], kind: SpanKind::Train, label: "a".into() },
            Task { node: 1, dur: 0.5, deps: vec![0], kind: SpanKind::Forward, label: "b".into() },
        ];
        let r = simulate(&tasks);
        let g = render(&tasks, &r, 40);
        assert_eq!(g.lines().count(), 4); // header + 2 nodes + legend
        assert!(g.contains("node  1 │T"));
        assert!(g.contains('F'));
        // node 2 idle during node 1's work
        let node2 = g.lines().nth(2).unwrap();
        assert!(node2.contains('.'));
    }

    #[test]
    fn summary_contains_util() {
        let tasks =
            vec![Task { node: 0, dur: 2.0, deps: vec![], kind: SpanKind::Train, label: String::new() }];
        let r = simulate(&tasks);
        let s = summary_line("x", &r);
        assert!(s.contains("100.0%"));
    }
}
