//! Discrete-event pipeline simulator.
//!
//! The paper's headline numbers (Tables 1–5 training times, Figures 1–6
//! schedules) come from a 4–5-machine socket testbed. This host has one
//! CPU core, so wall-clock multi-node speedups cannot be *measured*
//! locally; they are *simulated* here instead, at the paper's full scale
//! ([784, 2000×4], E = S = 100, N = 4), from an analytic cost model
//! calibrated so the Sequential baseline lands in the paper's ballpark
//! (§DESIGN.md substitution table).
//!
//! The simulator is a plain dependency-graph executor ([`engine`]): every
//! scheduler builds the same task graph its real counterpart executes
//! (train/forward/publish/neggen per (layer, chapter)), with durations
//! from [`cost::CostModel`]. [`gantt`] renders the resulting schedules —
//! these are Figures 1–6.

pub mod cost;
pub mod engine;
pub mod gantt;
pub mod schedules;

pub use cost::CostModel;
pub use engine::{simulate, SimResult, Task};
pub use schedules::{build_schedule, SimVariant};
