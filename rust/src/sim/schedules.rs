//! Task-graph builders: one per schedule the paper draws or times.
//!
//! Each builder mirrors its real scheduler loop in
//! [`crate::coordinator::schedulers`] — same (layer, chapter) order, same
//! blocking dependencies — with durations from the [`CostModel`].

use std::collections::HashMap;

use crate::ff::NegStrategy;
use crate::metrics::SpanKind;
use crate::sim::cost::CostModel;
use crate::sim::engine::Task;

/// Which schedule to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimVariant {
    /// Original FF on one node (≡ Sequential).
    SequentialFF,
    /// Single-Layer PFF (§4.1, Figure 4).
    SingleLayerPFF,
    /// All-Layers PFF (§4.2, Figure 5).
    AllLayersPFF,
    /// Federated PFF (§4.3, Figure 6) — All-Layers over shards (1/N data).
    FederatedPFF,
    /// Backprop pipeline à la Figure 1 (GPipe-style F/B wavefront).
    BackpropPipeline,
    /// DFF [11]: full-batch, activation-shipping layer servers.
    Dff,
}

impl std::fmt::Display for SimVariant {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimVariant::SequentialFF => write!(f, "Sequential FF"),
            SimVariant::SingleLayerPFF => write!(f, "Single-Layer PFF"),
            SimVariant::AllLayersPFF => write!(f, "All-Layers PFF"),
            SimVariant::FederatedPFF => write!(f, "Federated PFF"),
            SimVariant::BackpropPipeline => write!(f, "Backprop pipeline"),
            SimVariant::Dff => write!(f, "DFF"),
        }
    }
}

/// Scheduler-level knobs for a simulated run.
#[derive(Clone, Debug)]
pub struct SimParams {
    /// Node count N.
    pub nodes: usize,
    /// Negative-sample strategy (drives NegGen tasks).
    pub neg: NegStrategy,
    /// Add the inline softmax-head stage.
    pub softmax_head: bool,
    /// PerfOpt variant (no negatives, CE step cost).
    pub perfopt: bool,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { nodes: 4, neg: NegStrategy::Adaptive, softmax_head: false, perfopt: false }
    }
}

/// Build the task graph for `variant`.
pub fn build_schedule(variant: SimVariant, cm: &CostModel, p: &SimParams) -> Vec<Task> {
    match variant {
        SimVariant::SequentialFF => all_layers(cm, &SimParams { nodes: 1, ..p.clone() }, 1.0),
        SimVariant::AllLayersPFF => all_layers(cm, p, 1.0),
        SimVariant::FederatedPFF => all_layers(cm, p, 1.0 / p.nodes as f64),
        SimVariant::SingleLayerPFF => single_layer(cm, p),
        SimVariant::BackpropPipeline => backprop_pipeline(cm, p),
        SimVariant::Dff => dff(cm, p),
    }
}

fn chapter_train_s(cm: &CostModel, l: usize, p: &SimParams, data_frac: f64) -> f64 {
    let base = if p.perfopt { cm.perfopt_chapter_s(l) } else { cm.train_chapter_s(l) };
    base * data_frac
}

/// All-Layers PFF (also Sequential with N=1, Federated with data_frac=1/N):
/// node i runs chapters i, i+N, …; within a chapter trains layers in
/// order, fetching layer l @ chapter-1 (published by the previous node).
fn all_layers(cm: &CostModel, p: &SimParams, data_frac: f64) -> Vec<Task> {
    let n_layers = cm.n_layers();
    let mut tasks = Vec::new();
    // publish task id per (layer, chapter) — the dependency handle.
    let mut published: HashMap<(usize, u32), usize> = HashMap::new();
    for chapter in 0..cm.splits {
        let node = (chapter as usize) % p.nodes;
        for l in 0..n_layers {
            let mut deps = Vec::new();
            if chapter > 0 {
                deps.push(published[&(l, chapter - 1)]);
            }
            tasks.push(Task {
                node,
                dur: chapter_train_s(cm, l, p, data_frac),
                deps,
                kind: SpanKind::Train,
                label: format!("T(L{},c{})", l + 1, chapter + 1),
            });
            let train_id = tasks.len() - 1;
            tasks.push(Task {
                node,
                dur: cm.publish_s(l),
                deps: vec![train_id],
                kind: SpanKind::Publish,
                label: format!("P(L{},c{})", l + 1, chapter + 1),
            });
            published.insert((l, chapter), tasks.len() - 1);
            if l + 1 < n_layers {
                // forward pos+neg (PerfOpt: single tensor)
                let fwd = cm.forward_s(l) * data_frac * if p.perfopt { 1.0 } else { 2.0 };
                tasks.push(Task {
                    node,
                    dur: fwd,
                    deps: vec![train_id],
                    kind: SpanKind::Forward,
                    label: format!("F(L{},c{})", l + 1, chapter + 1),
                });
            }
        }
        if p.softmax_head && !p.perfopt {
            tasks.push(Task {
                node,
                dur: cm.head_chapter_s() * data_frac,
                deps: vec![],
                kind: SpanKind::HeadTrain,
                label: format!("H(c{})", chapter + 1),
            });
        }
        if p.neg == NegStrategy::Adaptive && chapter + (p.nodes as u32) < cm.splits {
            tasks.push(Task {
                node,
                dur: cm.neggen_s() * data_frac,
                deps: vec![],
                kind: SpanKind::NegGen,
                label: format!("N(c{})", chapter + 1),
            });
        }
    }
    tasks
}

/// Single-Layer PFF: node i owns layer i; per chapter it re-forwards the
/// dataset through fetched predecessors, trains, publishes. AdaptiveNEG
/// labels come from the last node's publish of the previous chapter.
fn single_layer(cm: &CostModel, p: &SimParams) -> Vec<Task> {
    let n_layers = cm.n_layers();
    assert_eq!(p.nodes, n_layers, "Single-Layer: nodes must equal layers");
    let mut tasks = Vec::new();
    let mut published: HashMap<(usize, u32), usize> = HashMap::new();
    let mut neg_published: HashMap<u32, usize> = HashMap::new();
    // Build in (chapter, layer) wavefront order so deps precede dependents.
    for chapter in 0..cm.splits {
        for l in 0..n_layers {
            let node = l;
            let mut deps = Vec::new();
            // needs every predecessor AT THIS chapter
            if l > 0 {
                deps.push(published[&(l - 1, chapter)]);
            }
            // AdaptiveNEG labels arrive with a 2-chapter lag (produced by
            // the last node after chapter c-2): waiting on chapter c-1's
            // labels would serialize the whole wavefront — the bottleneck
            // §5.2 attributes to Single-Layer, which their measured 2.1x
            // speedup shows must be overlapped in practice.
            if p.neg == NegStrategy::Adaptive {
                if let Some(&n) = neg_published.get(&chapter) {
                    deps.push(n);
                }
            }
            // forward through predecessors (fetch cost + fwd of l prior layers)
            if l > 0 {
                let fwd: f64 = (0..l)
                    .map(|j| cm.forward_s(j) * if p.perfopt { 1.0 } else { 2.0 } + cm.publish_s(j))
                    .sum();
                tasks.push(Task {
                    node,
                    dur: fwd,
                    deps: deps.clone(),
                    kind: SpanKind::Forward,
                    label: format!("F(<L{},c{})", l + 1, chapter + 1),
                });
                deps = vec![tasks.len() - 1];
            }
            tasks.push(Task {
                node,
                dur: chapter_train_s(cm, l, p, 1.0),
                deps,
                kind: SpanKind::Train,
                label: format!("T(L{},c{})", l + 1, chapter + 1),
            });
            let train_id = tasks.len() - 1;
            tasks.push(Task {
                node,
                dur: cm.publish_s(l),
                deps: vec![train_id],
                kind: SpanKind::Publish,
                label: format!("P(L{},c{})", l + 1, chapter + 1),
            });
            published.insert((l, chapter), tasks.len() - 1);
            // last node extras
            if l == n_layers - 1 {
                if p.neg == NegStrategy::Adaptive && chapter + 2 < cm.splits {
                    tasks.push(Task {
                        node,
                        dur: cm.neggen_s(),
                        deps: vec![train_id],
                        kind: SpanKind::NegGen,
                        label: format!("N(c{})", chapter + 3),
                    });
                    // consumed at chapter + 2 (lag 2, see above)
                    neg_published.insert(chapter + 2, tasks.len() - 1);
                }
                if p.softmax_head && !p.perfopt {
                    tasks.push(Task {
                        node,
                        dur: cm.head_chapter_s(),
                        deps: vec![train_id],
                        kind: SpanKind::HeadTrain,
                        label: format!("H(c{})", chapter + 1),
                    });
                }
            }
        }
    }
    tasks
}

/// Backprop pipeline (Figure 1): L stage-nodes, M microbatch wavefronts
/// per epoch aggregate; F(l,m) → F(l+1,m), B(l,m) → B(l−1,m), B waits for
/// the corresponding F and for the *last* stage's turnaround. This is the
/// GPipe fill-drain shape with its (L−1)/(M+L−1) bubble fraction.
fn backprop_pipeline(cm: &CostModel, p: &SimParams) -> Vec<Task> {
    let n_layers = cm.n_layers();
    let nodes = p.nodes.min(n_layers).max(1);
    // Aggregate: one simulated "item" = one chapter's worth of minibatches
    // on one stage. F+B per chapter per stage costs ≈ the FF chapter cost
    // (same matmuls: fwd + dW) plus dx backward matmul (×1.5).
    let m_items = cm.splits; // same granularity as PFF chapters
    let mut tasks = Vec::new();
    let mut f_id: HashMap<(usize, u32), usize> = HashMap::new();
    let mut b_id: HashMap<(usize, u32), usize> = HashMap::new();
    for item in 0..m_items {
        for l in 0..nodes {
            let mut deps = Vec::new();
            if l > 0 {
                deps.push(f_id[&(l - 1, item)]);
            }
            let fwd_cost = cm.train_chapter_s(l) * 0.4; // fwd share of F+B
            tasks.push(Task {
                node: l,
                dur: fwd_cost,
                deps,
                kind: SpanKind::Forward,
                label: format!("F({},{})", l + 1, item + 1),
            });
            f_id.insert((l, item), tasks.len() - 1);
        }
        for l in (0..nodes).rev() {
            let mut deps = vec![f_id[&(l, item)]];
            if l + 1 < nodes {
                deps.push(b_id[&(l + 1, item)]);
            }
            let bwd_cost = cm.train_chapter_s(l) * 0.6 * 1.5; // bwd share + dx
            tasks.push(Task {
                node: l,
                dur: bwd_cost,
                deps,
                kind: SpanKind::Train,
                label: format!("B({},{})", l + 1, item + 1),
            });
            b_id.insert((l, item), tasks.len() - 1);
        }
    }
    tasks
}

/// DFF [11]: one master, layer-servers; the *whole dataset's activations*
/// travel between servers once per round, weights update infrequently
/// (full-batch). Rounds = epochs.
fn dff(cm: &CostModel, p: &SimParams) -> Vec<Task> {
    let n_layers = cm.n_layers();
    let nodes = p.nodes.min(n_layers).max(1);
    let mut tasks = Vec::new();
    let mut prev_out: Option<usize> = None;
    for round in 0..cm.epochs {
        for l in 0..n_layers {
            let node = l % nodes;
            let mut deps = Vec::new();
            if let Some(pid) = prev_out {
                deps.push(pid);
            }
            // full-batch step: one fwd + grad over the whole set (no
            // minibatching — DFF's accuracy handicap, §6).
            let dur = cm.forward_s(l) * 2.0 * 2.0; // fwd(pos+neg) + grad
            tasks.push(Task {
                node,
                dur,
                deps,
                kind: SpanKind::Train,
                label: format!("T(L{},r{})", l + 1, round + 1),
            });
            let tid = tasks.len() - 1;
            // ship activations of the full dataset to the next server
            tasks.push(Task {
                node,
                dur: cm.activations_wire_s(l),
                deps: vec![tid],
                kind: SpanKind::Publish,
                label: format!("X(L{},r{})", l + 1, round + 1),
            });
            prev_out = Some(tasks.len() - 1);
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::sim::engine::simulate;

    fn cm() -> CostModel {
        let mut cfg = ExperimentConfig::paper_mnist();
        cfg.splits = 12; // keep graphs small in tests
        cfg.epochs = 12;
        CostModel::paper_testbed(&cfg)
    }

    #[test]
    fn all_layers_speedup_over_sequential() {
        let cm = cm();
        let p = SimParams { nodes: 4, neg: NegStrategy::Random, ..Default::default() };
        let seq = simulate(&build_schedule(SimVariant::SequentialFF, &cm, &p));
        let pff = simulate(&build_schedule(SimVariant::AllLayersPFF, &cm, &p));
        let speedup = seq.makespan / pff.makespan;
        assert!(
            speedup > 2.0 && speedup <= 4.05,
            "All-Layers N=4 speedup should approach 4x, got {speedup:.2}"
        );
        assert!(pff.utilization() > 0.5, "utilization {:.2}", pff.utilization());
    }

    #[test]
    fn single_layer_between_sequential_and_all_layers() {
        // Paper Table 1 (AdaptiveNEG): Sequential 11190 > Single-Layer
        // 5254 > All-Layers 2980.
        let cm = cm();
        let p = SimParams { nodes: 4, neg: NegStrategy::Adaptive, ..Default::default() };
        let seq = simulate(&build_schedule(SimVariant::SequentialFF, &cm, &p));
        let single = simulate(&build_schedule(SimVariant::SingleLayerPFF, &cm, &p));
        let all = simulate(&build_schedule(SimVariant::AllLayersPFF, &cm, &p));
        assert!(
            seq.makespan > single.makespan && single.makespan > all.makespan,
            "expected seq {:.0} > single {:.0} > all {:.0}",
            seq.makespan,
            single.makespan,
            all.makespan
        );
    }

    #[test]
    fn ff_pipeline_beats_backprop_pipeline_utilization() {
        // The Figure 1 vs Figure 2 story: FF has no backward dependency
        // chain, so utilization is higher at equal node count.
        let cm = cm();
        let p = SimParams { nodes: 4, neg: NegStrategy::Random, ..Default::default() };
        let bp = simulate(&build_schedule(SimVariant::BackpropPipeline, &cm, &p));
        let ff = simulate(&build_schedule(SimVariant::AllLayersPFF, &cm, &p));
        assert!(
            ff.utilization() > bp.utilization(),
            "FF util {:.2} should beat BP util {:.2}",
            ff.utilization(),
            bp.utilization()
        );
    }

    #[test]
    fn dff_ships_vastly_more_and_is_slower_per_epoch() {
        let cm = cm();
        let p = SimParams { nodes: 4, neg: NegStrategy::Fixed, ..Default::default() };
        let dff = simulate(&build_schedule(SimVariant::Dff, &cm, &p));
        let pff = simulate(&build_schedule(SimVariant::AllLayersPFF, &cm, &p));
        // same epoch budget: DFF (full batch + activation shipping) slower
        assert!(dff.makespan > pff.makespan, "dff {:.0} vs pff {:.0}", dff.makespan, pff.makespan);
    }

    #[test]
    fn federated_scales_with_shards() {
        let cm = cm();
        let p = SimParams { nodes: 4, neg: NegStrategy::Random, ..Default::default() };
        let all = simulate(&build_schedule(SimVariant::AllLayersPFF, &cm, &p));
        let fed = simulate(&build_schedule(SimVariant::FederatedPFF, &cm, &p));
        // each node trains 1/N of the data per chapter → much shorter
        assert!(fed.makespan < all.makespan);
    }

    #[test]
    fn graphs_are_well_formed() {
        let cm = cm();
        for v in [
            SimVariant::SequentialFF,
            SimVariant::SingleLayerPFF,
            SimVariant::AllLayersPFF,
            SimVariant::FederatedPFF,
            SimVariant::BackpropPipeline,
            SimVariant::Dff,
        ] {
            let p = SimParams { nodes: 4, neg: NegStrategy::Adaptive, ..Default::default() };
            let tasks = build_schedule(v, &cm, &p);
            assert!(!tasks.is_empty(), "{v}: empty graph");
            for (i, t) in tasks.iter().enumerate() {
                assert!(t.dur >= 0.0);
                assert!(t.deps.iter().all(|&d| d < i), "{v}: forward dep at {i}");
            }
            let r = simulate(&tasks);
            assert!(r.makespan.is_finite() && r.makespan > 0.0);
        }
    }
}
