//! Ranked synchronization primitives: [`OrderedMutex`] / [`OrderedCondvar`].
//!
//! Every lock in the coordinator/transport layer carries a static
//! [`LockRank`]; a thread may only acquire a lock of *strictly greater*
//! rank than every lock it already holds. Under `debug_assertions` a
//! thread-local stack of held ranks asserts this on every acquisition —
//! a cheap runtime deadlock detector that rides along in every existing
//! test. Release builds compile the checks out entirely.
//!
//! The wrappers also absorb lock poisoning: a thread that panics while
//! holding a guard poisons the underlying `std` lock, and the historical
//! `.lock().unwrap()` idiom then cascades that one panic through every
//! other thread touching the lock (publishers, checkpoint dumpers, the
//! event bus). [`OrderedMutex::lock`] instead recovers the inner guard
//! with a one-time warning — the protected state is still structurally
//! sound (every mutation in this codebase is a single insert/remove),
//! so the run degrades to "one worker died" instead of a panic storm.
//!
//! The `lock-discipline` rule of `pff analyze` keeps raw
//! `Mutex`/`Condvar` out of the coordinator/transport modules, so new
//! lock sites are forced through this file and into the rank table.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError, WaitTimeoutResult};
use std::time::Duration;

/// The global lock-acquisition order, smallest first (e.g.
/// `Registry < Dispatcher < Store < Events < Pool`). Holding rank R, a
/// thread may only acquire ranks strictly greater than R — so any cycle
/// between two threads requires one of them to acquire downward, which
/// the debug checker catches on the spot.
///
/// The discriminants are spaced so a new subsystem can slot between two
/// existing ranks without renumbering the table.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum LockRank {
    /// [`crate::coordinator::experiment::CancelToken`] hook list. Hooks
    /// run *after* the guard drops, so this rank never pins another.
    Cancel = 0,
    /// Cluster membership — `coordinator/registry.rs`. Held while
    /// requeueing a dead worker's leases into the dispatcher.
    Registry = 10,
    /// Task-graph work buckets — `coordinator/dispatch.rs`.
    Dispatcher = 20,
    /// The parameter store — `coordinator/store.rs`.
    Store = 30,
    /// Inference admission queue — `coordinator/serve.rs`. Ranks above
    /// the store (the batcher reads resident parameters) and below the
    /// event bus (flushes emit `ServeEvent`s after the queue lock drops).
    Serve = 35,
    /// Event bus + event log — `coordinator/events.rs`. Observers run
    /// outside the bus lock, so emission nests under nothing.
    Events = 40,
    /// The scheduler name registry — `coordinator/schedulers/mod.rs`.
    SchedRegistry = 50,
    /// Per-home Adam-state bank — `coordinator/node.rs`.
    OptState = 60,
    /// TCP client death flag; held (via `if let`) while draining the
    /// pending map, so it ranks below [`LockRank::ConnPending`].
    ConnDead = 70,
    /// TCP connection write half (server replies, client requests);
    /// held while unwinding a failed write from the pending map.
    ConnWriter = 71,
    /// TCP client pending-response map — the innermost transport lock.
    ConnPending = 72,
    /// Kernel worker-pool internals — `tensor/pool.rs`. The pool's
    /// queue/latch/bookkeeping locks are never held simultaneously, so
    /// one terminal rank covers all three.
    Pool = 90,
}

impl fmt::Display for LockRank {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

#[cfg(debug_assertions)]
mod rank_check {
    use super::LockRank;
    use std::cell::RefCell;

    thread_local! {
        /// Ranks this thread currently holds, in acquisition order.
        /// Guards may drop out of order, so violation checks compare
        /// against the *maximum* held rank, not the top of the stack.
        static HELD: RefCell<Vec<LockRank>> = const { RefCell::new(Vec::new()) };
    }

    /// Assert `rank` may be acquired now, and record it. Called *before*
    /// the underlying acquisition, so a violation panics cleanly instead
    /// of deadlocking first.
    pub(super) fn acquire(rank: LockRank) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(&top) = held.iter().max() {
                assert!(
                    rank > top,
                    "lock-rank violation: acquiring {rank:?} while holding {top:?} \
                     — the global order is declared in sync.rs (LockRank)"
                );
            }
            held.push(rank);
        });
    }

    /// Forget one held instance of `rank` (guard dropped or parked in a
    /// condvar wait).
    pub(super) fn release(rank: LockRank) {
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(i) = held.iter().rposition(|&r| r == rank) {
                held.remove(i);
            }
        });
    }
}

/// Recover the guard from a poisoned lock instead of propagating the
/// original panic into every other thread (warns once per process).
fn recover<G>(res: Result<G, PoisonError<G>>) -> G {
    match res {
        Ok(g) => g,
        Err(poisoned) => {
            static WARNED: AtomicBool = AtomicBool::new(false);
            if !WARNED.swap(true, Ordering::Relaxed) {
                // pff-allow(no-print-in-lib): poison recovery has no bus
                // handle (it fires inside arbitrary lock wrappers); this
                // one-time stderr warning is the only reporting channel.
                eprintln!(
                    "[pff-sync] recovered a poisoned lock (a thread panicked while \
                     holding it); continuing with the inner state"
                );
            }
            poisoned.into_inner()
        }
    }
}

/// A [`Mutex`] carrying a static [`LockRank`]. See the module docs.
pub struct OrderedMutex<T> {
    rank: LockRank,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    /// Wrap `value` at `rank`.
    pub const fn new(rank: LockRank, value: T) -> Self {
        OrderedMutex { rank, inner: Mutex::new(value) }
    }

    /// This lock's rank.
    pub fn rank(&self) -> LockRank {
        self.rank
    }

    /// Acquire, asserting rank order (debug builds) and recovering from
    /// poisoning. Infallible by design: the historical
    /// `.lock().unwrap()` sites become plain `.lock()`.
    pub fn lock(&self) -> OrderedGuard<'_, T> {
        #[cfg(debug_assertions)]
        rank_check::acquire(self.rank);
        let guard = recover(self.inner.lock());
        OrderedGuard { rank: self.rank, guard: Some(guard) }
    }

    /// Consume the mutex and return the inner value.
    pub fn into_inner(self) -> T {
        recover(self.inner.into_inner())
    }
}

impl<T: fmt::Debug> fmt::Debug for OrderedMutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("OrderedMutex").field("rank", &self.rank).finish_non_exhaustive()
    }
}

/// RAII guard returned by [`OrderedMutex::lock`]. The inner `std` guard
/// lives in an `Option` so [`OrderedCondvar`] can take it across a park
/// (the rank is released while parked — the mutex genuinely isn't held).
pub struct OrderedGuard<'a, T> {
    rank: LockRank,
    guard: Option<MutexGuard<'a, T>>,
}

impl<T> OrderedGuard<'_, T> {
    /// The rank of the lock this guard holds.
    pub fn rank(&self) -> LockRank {
        self.rank
    }
}

impl<T> std::ops::Deref for OrderedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.guard.as_ref().expect("guard present outside a condvar park")
    }
}

impl<T> std::ops::DerefMut for OrderedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.guard.as_mut().expect("guard present outside a condvar park")
    }
}

impl<T> Drop for OrderedGuard<'_, T> {
    fn drop(&mut self) {
        if self.guard.take().is_some() {
            #[cfg(debug_assertions)]
            rank_check::release(self.rank);
        }
    }
}

/// [`Condvar`] companion to [`OrderedMutex`]: waits return the guard
/// directly (poisoning on reacquisition is recovered, so there is no
/// `Result` to unwrap), and the held-rank stack tracks the park.
pub struct OrderedCondvar {
    inner: Condvar,
}

impl OrderedCondvar {
    /// Fresh condition variable.
    pub const fn new() -> Self {
        OrderedCondvar { inner: Condvar::new() }
    }

    /// Park until notified. The lock is released for the duration of the
    /// park (and so is its rank).
    pub fn wait<'a, T>(&self, mut guard: OrderedGuard<'a, T>) -> OrderedGuard<'a, T> {
        let inner = guard.guard.take().expect("guard present entering wait");
        #[cfg(debug_assertions)]
        rank_check::release(guard.rank);
        let inner = recover(self.inner.wait(inner));
        #[cfg(debug_assertions)]
        rank_check::acquire(guard.rank);
        guard.guard = Some(inner);
        guard
    }

    /// Park until notified or `dur` elapses.
    pub fn wait_timeout<'a, T>(
        &self,
        mut guard: OrderedGuard<'a, T>,
        dur: Duration,
    ) -> (OrderedGuard<'a, T>, WaitTimeoutResult) {
        let inner = guard.guard.take().expect("guard present entering wait");
        #[cfg(debug_assertions)]
        rank_check::release(guard.rank);
        let (inner, timed_out) = recover(self.inner.wait_timeout(inner, dur));
        #[cfg(debug_assertions)]
        rank_check::acquire(guard.rank);
        guard.guard = Some(inner);
        (guard, timed_out)
    }

    /// Wake one parked waiter.
    pub fn notify_one(&self) {
        self.inner.notify_one();
    }

    /// Wake every parked waiter.
    pub fn notify_all(&self) {
        self.inner.notify_all();
    }
}

impl Default for OrderedCondvar {
    fn default() -> Self {
        OrderedCondvar::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn in_order_acquisition_is_clean() {
        let reg = OrderedMutex::new(LockRank::Registry, 1);
        let disp = OrderedMutex::new(LockRank::Dispatcher, 2);
        let store = OrderedMutex::new(LockRank::Store, 3);
        let a = reg.lock();
        let b = disp.lock();
        let c = store.lock();
        assert_eq!(*a + *b + *c, 6);
        // Out-of-order *release* is fine — only acquisition is ranked.
        drop(b);
        drop(a);
        drop(c);
        // The stack drained: a fresh low-rank acquisition still works.
        assert_eq!(*reg.lock(), 1);
    }

    #[test]
    #[cfg(debug_assertions)]
    fn out_of_order_acquisition_panics_in_debug() {
        let hi = Arc::new(OrderedMutex::new(LockRank::Events, ()));
        let lo = Arc::new(OrderedMutex::new(LockRank::Registry, ()));
        let res = std::thread::Builder::new()
            .name("rank-violator".into())
            .spawn(move || {
                let _e = hi.lock();
                let _r = lo.lock(); // Registry under Events: violation
            })
            .unwrap()
            .join();
        assert!(res.is_err(), "acquiring a lower rank must panic in debug builds");
    }

    #[test]
    #[cfg(debug_assertions)]
    fn same_rank_nesting_panics_in_debug() {
        let a = Arc::new(OrderedMutex::new(LockRank::Store, ()));
        let b = Arc::new(OrderedMutex::new(LockRank::Store, ()));
        let res = std::thread::spawn(move || {
            let _a = a.lock();
            let _b = b.lock(); // equal rank is not *strictly* greater
        })
        .join();
        assert!(res.is_err());
    }

    #[test]
    fn poisoned_lock_recovers_inner_value() {
        let m = Arc::new(OrderedMutex::new(LockRank::Store, 7usize));
        let m2 = m.clone();
        let res = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison the lock");
        })
        .join();
        assert!(res.is_err());
        // The historical idiom would now cascade the panic; the wrapper
        // recovers the guard and the state.
        *m.lock() += 1;
        assert_eq!(*m.lock(), 8);
    }

    #[test]
    fn condvar_roundtrip_wakes_and_rank_survives() {
        let pair = Arc::new((OrderedMutex::new(LockRank::Store, false), OrderedCondvar::new()));
        let p2 = pair.clone();
        let t = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut g = m.lock();
            while !*g {
                g = cv.wait(g);
            }
            // Reacquisition restored the rank bookkeeping: acquiring a
            // higher rank under it must still be legal.
            let extra = OrderedMutex::new(LockRank::Events, 5);
            *extra.lock()
        });
        {
            let (m, cv) = &*pair;
            *m.lock() = true;
            cv.notify_all();
        }
        assert_eq!(t.join().unwrap(), 5);
    }

    #[test]
    fn wait_timeout_times_out() {
        let m = OrderedMutex::new(LockRank::Store, ());
        let cv = OrderedCondvar::new();
        let t0 = Instant::now();
        let (_g, res) = cv.wait_timeout(m.lock(), Duration::from_millis(10));
        assert!(res.timed_out());
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn condvar_wait_recovers_poisoned_reacquisition() {
        // A waiter parked on a condvar reacquires a lock another thread
        // poisoned; the wait returns the inner guard instead of panicking.
        let pair = Arc::new((OrderedMutex::new(LockRank::Store, 0u32), OrderedCondvar::new()));
        let p2 = pair.clone();
        let waiter = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut g = m.lock();
            while *g == 0 {
                g = cv.wait(g);
            }
            *g
        });
        let p3 = pair.clone();
        let res = std::thread::spawn(move || {
            let (m, cv) = &*p3;
            let mut g = m.lock();
            *g = 9;
            cv.notify_all();
            panic!("poison while the waiter is being woken");
        })
        .join();
        assert!(res.is_err());
        assert_eq!(waiter.join().unwrap(), 9);
    }
}
