//! Adam optimizer state + update (Kingma & Ba), as used by the paper for
//! both the FF layers and the softmax head (§5.1).

use crate::tensor::Matrix;

/// Adam hyperparameters. Paper §5.1: lr 0.01 for FF layers, 1e-4 for the
/// softmax head, with a cooldown after half the epochs (handled by
/// [`crate::coordinator::lr`]).
#[derive(Clone, Copy, Debug)]
pub struct AdamConfig {
    /// β₁ — first-moment decay.
    pub beta1: f32,
    /// β₂ — second-moment decay.
    pub beta2: f32,
    /// ε — denominator fuzz.
    pub eps: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// First/second-moment state for a weight matrix + bias vector pair.
#[derive(Clone, Debug)]
pub struct AdamState {
    /// First moment of the weight matrix.
    pub m_w: Matrix,
    /// Second moment of the weight matrix.
    pub v_w: Matrix,
    /// First moment of the bias.
    pub m_b: Vec<f32>,
    /// Second moment of the bias.
    pub v_b: Vec<f32>,
    /// Step counter (for bias correction).
    pub t: u32,
    /// Hyperparameters.
    pub cfg: AdamConfig,
}

impl AdamState {
    /// Fresh zeroed state for a `(d_in, d_out)` layer.
    pub fn new(d_in: usize, d_out: usize) -> Self {
        AdamState {
            m_w: Matrix::zeros(d_in, d_out),
            v_w: Matrix::zeros(d_in, d_out),
            m_b: vec![0.0; d_out],
            v_b: vec![0.0; d_out],
            t: 0,
            cfg: AdamConfig::default(),
        }
    }

    /// One Adam step: applies gradients `(dw, db)` to `(w, b)` in place.
    pub fn step(&mut self, w: &mut Matrix, b: &mut [f32], dw: &Matrix, db: &[f32], lr: f32) {
        debug_assert_eq!((w.rows, w.cols), (dw.rows, dw.cols));
        debug_assert_eq!(b.len(), db.len());
        self.t += 1;
        let AdamConfig { beta1, beta2, eps } = self.cfg;
        let bc1 = 1.0 - beta1.powi(self.t as i32);
        let bc2 = 1.0 - beta2.powi(self.t as i32);
        // Fold the bias corrections into one scalar on lr — standard trick,
        // same as the fused form in the L1 Adam kernel.
        let alpha = lr * bc2.sqrt() / bc1;
        for ((wv, mv), (vv, gv)) in w
            .data
            .iter_mut()
            .zip(self.m_w.data.iter_mut())
            .zip(self.v_w.data.iter_mut().zip(dw.data.iter()))
        {
            *mv = beta1 * *mv + (1.0 - beta1) * gv;
            *vv = beta2 * *vv + (1.0 - beta2) * gv * gv;
            *wv -= alpha * *mv / (vv.sqrt() + eps);
        }
        for ((bv, mv), (vv, gv)) in b
            .iter_mut()
            .zip(self.m_b.iter_mut())
            .zip(self.v_b.iter_mut().zip(db.iter()))
        {
            *mv = beta1 * *mv + (1.0 - beta1) * gv;
            *vv = beta2 * *vv + (1.0 - beta2) * gv * gv;
            *bv -= alpha * *mv / (vv.sqrt() + eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimize f(w) = (w-3)² with Adam; must converge near 3.
    #[test]
    fn adam_converges_on_quadratic() {
        let mut w = Matrix::zeros(1, 1);
        let mut b = vec![0.0f32];
        let mut st = AdamState::new(1, 1);
        for _ in 0..2000 {
            let grad = Matrix::from_vec(1, 1, vec![2.0 * (w.data[0] - 3.0)]);
            st.step(&mut w, &mut b, &grad, &[0.0], 0.05);
        }
        assert!((w.data[0] - 3.0).abs() < 0.01, "w = {}", w.data[0]);
    }

    /// First step must equal -lr * sign(g) (bias-corrected Adam property).
    #[test]
    fn first_step_is_signed_lr() {
        let mut w = Matrix::zeros(1, 2);
        let mut b = vec![0.0f32, 0.0];
        let mut st = AdamState::new(1, 2);
        let g = Matrix::from_vec(1, 2, vec![10.0, -0.001]);
        st.step(&mut w, &mut b, &g, &[0.0, 0.0], 0.1);
        assert!((w.data[0] + 0.1).abs() < 1e-3, "{}", w.data[0]);
        assert!((w.data[1] - 0.1).abs() < 1e-3, "{}", w.data[1]);
    }

    #[test]
    fn zero_grad_keeps_params() {
        let mut w = Matrix::full(2, 2, 1.5);
        let mut b = vec![0.5f32, 0.5];
        let mut st = AdamState::new(2, 2);
        st.step(&mut w, &mut b, &Matrix::zeros(2, 2), &[0.0, 0.0], 0.1);
        assert!(w.data.iter().all(|&v| (v - 1.5).abs() < 1e-6));
        assert!(b.iter().all(|&v| (v - 0.5).abs() < 1e-6));
    }
}
