//! Row-major dense f32 matrix.

use crate::tensor::Rng;

/// A dense, row-major, `f32` matrix. The only tensor type the coordinator
/// needs: minibatches are `(batch, dim)`, weights are `(d_in, d_out)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row-major storage, `len == rows * cols`.
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `v`.
    pub fn full(rows: usize, cols: usize, v: f32) -> Self {
        Matrix { rows, cols, data: vec![v; rows * cols] }
    }

    /// Build from an existing row-major buffer.
    ///
    /// # Panics
    /// If `data.len() != rows * cols`.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer/shape mismatch");
        Matrix { rows, cols, data }
    }

    /// He/Kaiming-style normal init scaled by `1/sqrt(rows)` — matches the
    /// reference FF implementations (weights ~ N(0, 1/d_in)).
    pub fn randn_scaled(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let scale = 1.0 / (rows as f32).sqrt();
        let data = (0..rows * cols).map(|_| rng.normal() * scale).collect();
        Matrix { rows, cols, data }
    }

    /// Uniform random in `[lo, hi)`.
    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| lo + (hi - lo) * rng.f32()).collect();
        Matrix { rows, cols, data }
    }

    /// Immutable view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Element accessor (debug/test convenience; hot paths index `data`).
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    /// Copy rows `idx` (in order) into a new matrix — minibatch gather.
    /// Appends into reserved capacity (no zero-fill-then-overwrite pass).
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &r in idx {
            data.extend_from_slice(self.row(r));
        }
        Matrix { rows: idx.len(), cols: self.cols, data }
    }

    /// Copy the contiguous row range `[r0, r1)` — the chunked-eval gather,
    /// one memcpy instead of a per-row index walk.
    ///
    /// # Panics
    /// If `r0 > r1` or `r1 > self.rows`.
    pub fn rows_range(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows, "rows_range: bad range {r0}..{r1} of {}", self.rows);
        let data = self.data[r0 * self.cols..r1 * self.cols].to_vec();
        Matrix { rows: r1 - r0, cols: self.cols, data }
    }

    /// Vertical concatenation `[self; other]`.
    ///
    /// # Panics
    /// If column counts differ.
    pub fn vcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "vcat: column mismatch");
        let mut data = Vec::with_capacity((self.rows + other.rows) * self.cols);
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    /// Horizontal concatenation `[self, other]` (row-wise feature concat).
    ///
    /// # Panics
    /// If row counts differ.
    pub fn hcat(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.rows, other.rows, "hcat: row mismatch");
        let cols = self.cols + other.cols;
        let mut data = Vec::with_capacity(self.rows * cols);
        for r in 0..self.rows {
            data.extend_from_slice(self.row(r));
            data.extend_from_slice(other.row(r));
        }
        Matrix { rows: self.rows, cols, data }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Max absolute elementwise difference — test utility.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_shape_and_content() {
        let m = Matrix::zeros(3, 4);
        assert_eq!((m.rows, m.cols, m.data.len()), (3, 4, 12));
        assert!(m.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn row_views() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.row(0), &[1., 2., 3.]);
        assert_eq!(m.row(1), &[4., 5., 6.]);
        assert_eq!(m.at(1, 2), 6.0);
    }

    #[test]
    fn gather_rows_reorders() {
        let m = Matrix::from_vec(3, 2, vec![0., 0., 1., 1., 2., 2.]);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![2., 2., 0., 0.]);
    }

    #[test]
    fn rows_range_is_contiguous_slice() {
        let m = Matrix::from_vec(4, 2, (0..8).map(|i| i as f32).collect());
        let s = m.rows_range(1, 3);
        assert_eq!((s.rows, s.cols), (2, 2));
        assert_eq!(s.data, vec![2., 3., 4., 5.]);
        assert_eq!(m.rows_range(2, 2).rows, 0);
    }

    #[test]
    fn vcat_hcat() {
        let a = Matrix::from_vec(1, 2, vec![1., 2.]);
        let b = Matrix::from_vec(1, 2, vec![3., 4.]);
        assert_eq!(a.vcat(&b).data, vec![1., 2., 3., 4.]);
        assert_eq!(a.hcat(&b).data, vec![1., 2., 3., 4.]);
        assert_eq!(a.vcat(&b).rows, 2);
        assert_eq!(a.hcat(&b).cols, 4);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(7);
        let m = Matrix::rand_uniform(5, 3, -1.0, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn randn_scaled_variance_sane() {
        let mut rng = Rng::new(42);
        let m = Matrix::randn_scaled(400, 50, &mut rng);
        let mean: f32 = m.data.iter().sum::<f32>() / m.data.len() as f32;
        let var: f32 =
            m.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / m.data.len() as f32;
        // target variance = 1/400 = 0.0025
        assert!(mean.abs() < 0.005, "mean {mean}");
        assert!((var - 0.0025).abs() < 0.0005, "var {var}");
    }
}
