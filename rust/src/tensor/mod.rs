//! Dense f32 tensor substrate.
//!
//! The paper's compute is small dense linear algebra (B×D_in · D_in×D_out
//! matmuls, elementwise ReLU, per-row reductions). The production path runs
//! this inside AOT-compiled XLA modules; this module is the pure-Rust
//! equivalent used by [`crate::engine::NativeEngine`] for tests, oracles and
//! artifact-free benchmarks, plus the RNG and Adam state shared everywhere.
//!
//! Kernels run multi-threaded over the scoped worker pool in [`pool`]
//! (sized by `--threads` / `PFF_THREADS`, bit-identical at every thread
//! count) and draw scratch buffers from a [`Workspace`] arena so the
//! engine hot path is allocation-free in steady state.

pub mod adam;
pub mod matrix;
pub mod ops;
pub mod pool;
pub mod rng;
pub mod workspace;

pub use adam::AdamState;
pub use matrix::Matrix;
pub use rng::{Rng, RngState};
pub use workspace::Workspace;
