//! Linear-algebra kernels for the native engine.
//!
//! These mirror, op-for-op, the Pallas kernels in
//! `python/compile/kernels/ff_layer.py`; the integration test
//! `rust/tests/xla_vs_native.rs` pins the two implementations against each
//! other through the AOT artifacts.
//!
//! The heavy kernels (`matmul` family, `normalize_rows`, the elementwise
//! sweeps) run multi-threaded over [`pool::parallel_rows`], partitioned
//! strictly over **output rows**: every output element is produced by one
//! span with the exact accumulation order of the serial loop, so results
//! are **bit-identical at every thread count** (§Perf iteration 8; pinned
//! by `tests/kernel_determinism.rs`). Shapes too small to amortize a
//! dispatch take the serial path — same code, one span.
//!
//! `*_into` variants write into caller-provided (usually
//! [`crate::tensor::Workspace`]-recycled) buffers so the engine hot path
//! allocates nothing per step; the allocating wrappers remain for tests,
//! baselines and one-shot callers.

use crate::tensor::pool::{self, RowsMut};
use crate::tensor::Matrix;

/// K-tile edge for the blocked matmul (per-(i, k0) pass streams `NTILE`
/// contiguous floats of B per k).
const TILE: usize = 32;
/// N-tile edge: a 32×256 f32 B-panel is 32 KB — L1-resident, so the k-loop
/// re-reads it from L1 instead of L2 (§Perf iteration 4).
const NTILE: usize = 256;
/// Row-span quantum handed to the pool by the row-parallel matmuls.
const MM_CHUNK: usize = 8;
/// Below this many multiply-adds a parallel dispatch costs more than the
/// kernel; run the same code as one span. Purely a shape function, so the
/// serial/parallel decision never depends on runtime state.
const PAR_MIN_MACS: usize = 1 << 17;
/// Elementwise/row-sweep ops parallelize above this many elements.
const PAR_MIN_ELEMS: usize = 1 << 16;

/// `C = A · B` — blocked i/k/n matmul, row-major everywhere.
///
/// # Panics
/// On inner-dimension mismatch.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.cols);
    matmul_into(&mut c, a, b);
    c
}

/// [`matmul`] into a pre-shaped `(a.rows, b.cols)` output (contents are
/// overwritten; prior values do not matter).
pub fn matmul_into(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.cols, b.rows, "matmul: {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "matmul_into: bad output shape");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    c.data.fill(0.0);
    let out = RowsMut::of(c);
    let kernel = |lo: usize, hi: usize| {
        // SAFETY: spans are disjoint row ranges.
        let cdata = unsafe { out.rows(lo, hi) };
        for n0 in (0..n).step_by(NTILE) {
            let n1 = (n0 + NTILE).min(n);
            for k0 in (0..k).step_by(TILE) {
                let k1 = (k0 + TILE).min(k);
                for i in 0..(hi - lo) {
                    let arow = &a.data[(lo + i) * k..(lo + i + 1) * k];
                    let crow = &mut cdata[i * n + n0..i * n + n1];
                    for kk in k0..k1 {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue; // ReLU outputs are ~50% zeros — real win
                        }
                        let brow = &b.data[kk * n + n0..kk * n + n1];
                        // autovectorizes: contiguous fused multiply-add sweep
                        for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    };
    if m * k * n < PAR_MIN_MACS {
        kernel(0, m);
    } else {
        pool::parallel_rows(m, MM_CHUNK, kernel);
    }
}

/// `C = Aᵀ · B` without materializing the transpose (gradient `dW = x̂ᵀ·dz`).
///
/// Output-panel tiled: C is (d_in × d_out) — far larger than cache — so
/// sweeping all of it per sample row thrashes L2. Restricting each pass
/// to an `ITILE`-row C panel keeps the panel resident across the whole
/// batch (§Perf iteration 5). Spans are `ITILE`-aligned, so the panel
/// walk is identical at every thread count.
pub fn matmul_at_b(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols, b.cols);
    matmul_at_b_into(&mut c, a, b);
    c
}

/// C-panel rows per `matmul_at_b` pass: 64×256 f32 = 64 KB, L2-resident.
const ITILE: usize = 64;

/// [`matmul_at_b`] into a pre-shaped `(a.cols, b.cols)` output.
pub fn matmul_at_b_into(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.rows, b.rows, "matmul_at_b: {}x{}ᵀ · {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.cols, b.cols), "matmul_at_b_into: bad output shape");
    let (m, k, n) = (a.cols, a.rows, b.cols);
    c.data.fill(0.0);
    let out = RowsMut::of(c);
    let kernel = |lo: usize, hi: usize| {
        // SAFETY: spans are disjoint row ranges.
        let cdata = unsafe { out.rows(lo, hi) };
        for i0 in (lo..hi).step_by(ITILE) {
            let i1 = (i0 + ITILE).min(hi);
            for kk in 0..k {
                let arow = &a.data[kk * m + i0..kk * m + i1];
                let brow = &b.data[kk * n..(kk + 1) * n];
                for (i, &aik) in (i0..i1).zip(arow.iter()) {
                    if aik == 0.0 {
                        continue;
                    }
                    let crow = &mut cdata[(i - lo) * n..(i - lo + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    };
    if m * k * n < PAR_MIN_MACS {
        kernel(0, m);
    } else {
        pool::parallel_rows(m, ITILE, kernel);
    }
}

/// `C = A · Bᵀ` (used by backprop baselines: `dx = dz · Wᵀ`).
pub fn matmul_a_bt(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows, b.rows);
    matmul_a_bt_into(&mut c, a, b);
    c
}

/// [`matmul_a_bt`] into a pre-shaped `(a.rows, b.rows)` output.
pub fn matmul_a_bt_into(c: &mut Matrix, a: &Matrix, b: &Matrix) {
    assert_eq!(a.cols, b.cols, "matmul_a_bt: {}x{} · {}x{}ᵀ", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "matmul_a_bt_into: bad output shape");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let out = RowsMut::of(c);
    let kernel = |lo: usize, hi: usize| {
        // SAFETY: spans are disjoint row ranges.
        let cdata = unsafe { out.rows(lo, hi) };
        for i in lo..hi {
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut cdata[(i - lo) * n..(i - lo + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &b.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (av, bv) in arow.iter().zip(brow.iter()) {
                    acc += av * bv;
                }
                *cv = acc;
            }
        }
    };
    if m * k * n < PAR_MIN_MACS {
        kernel(0, m);
    } else {
        pool::parallel_rows(m, MM_CHUNK, kernel);
    }
}

/// Add a row-vector bias to every row, in place.
pub fn add_bias(m: &mut Matrix, bias: &[f32]) {
    assert_eq!(m.cols, bias.len());
    let (rows, cols) = (m.rows, m.cols);
    let out = RowsMut::of(m);
    let kernel = |lo: usize, hi: usize| {
        // SAFETY: spans are disjoint row ranges.
        let data = unsafe { out.rows(lo, hi) };
        for r in 0..(hi - lo) {
            for (v, b) in data[r * cols..(r + 1) * cols].iter_mut().zip(bias.iter()) {
                *v += b;
            }
        }
    };
    if rows * cols < PAR_MIN_ELEMS {
        kernel(0, rows);
    } else {
        pool::parallel_rows(rows, 32, kernel);
    }
}

/// In-place ReLU.
pub fn relu_inplace(m: &mut Matrix) {
    let (rows, cols) = (m.rows, m.cols);
    if rows * cols < PAR_MIN_ELEMS {
        for v in &mut m.data {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        return;
    }
    let out = RowsMut::of(m);
    pool::parallel_rows(rows, 32, |lo, hi| {
        // SAFETY: spans are disjoint row ranges.
        for v in unsafe { out.rows(lo, hi) } {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
    });
}

/// Row-wise L2 length normalization: `x / (‖x‖₂ + eps)`.
///
/// Hinton's FF feeds each hidden layer the *direction* of the previous
/// layer's activity, destroying the goodness magnitude so the next layer
/// can't trivially reuse it.
pub fn normalize_rows(m: &Matrix, eps: f32) -> Matrix {
    let mut out = Matrix::zeros(m.rows, m.cols);
    normalize_rows_into(&mut out, m, eps);
    out
}

/// [`normalize_rows`] into a pre-shaped output (single fused copy+scale
/// pass per row instead of whole-matrix clone then rescale).
pub fn normalize_rows_into(out: &mut Matrix, m: &Matrix, eps: f32) {
    assert_eq!((out.rows, out.cols), (m.rows, m.cols), "normalize_rows_into: bad output shape");
    let (rows, cols) = (m.rows, m.cols);
    let dst = RowsMut::of(out);
    let kernel = |lo: usize, hi: usize| {
        // SAFETY: spans are disjoint row ranges.
        let d = unsafe { dst.rows(lo, hi) };
        d.copy_from_slice(&m.data[lo * cols..hi * cols]);
        normalize_row_span(d, cols, eps);
    };
    if rows * cols < PAR_MIN_ELEMS {
        kernel(0, rows);
    } else {
        pool::parallel_rows(rows, 32, kernel);
    }
}

/// In-place variant of [`normalize_rows`].
pub fn normalize_rows_inplace(m: &mut Matrix, eps: f32) {
    let (rows, cols) = (m.rows, m.cols);
    if rows * cols < PAR_MIN_ELEMS {
        normalize_row_span(&mut m.data, cols, eps);
        return;
    }
    let dst = RowsMut::of(m);
    pool::parallel_rows(rows, 32, |lo, hi| {
        // SAFETY: spans are disjoint row ranges.
        normalize_row_span(unsafe { dst.rows(lo, hi) }, cols, eps);
    });
}

/// Normalize each `cols`-wide row of a contiguous span.
fn normalize_row_span(data: &mut [f32], cols: usize, eps: f32) {
    if cols == 0 {
        return;
    }
    for row in data.chunks_exact_mut(cols) {
        let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt();
        let inv = 1.0 / (norm + eps);
        for v in row {
            *v *= inv;
        }
    }
}

/// Per-row goodness `g_i = Σ_j y_ij²` (paper Eq. 1's inner sum).
pub fn row_sumsq(m: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0f32; m.rows];
    row_sumsq_into(&mut out, m);
    out
}

/// [`row_sumsq`] into a pre-sized `m.rows` slice.
pub fn row_sumsq_into(out: &mut [f32], m: &Matrix) {
    assert_eq!(out.len(), m.rows);
    for (o, row) in out.iter_mut().zip(m.data.chunks_exact(m.cols.max(1))) {
        *o = row.iter().map(|v| v * v).sum();
    }
    if m.cols == 0 {
        out.fill(0.0);
    }
}

/// Column-wise sum — bias gradient `db_j = Σ_i dz_ij`. Serial on purpose:
/// it reduces *across* rows, so a row partition would reorder the adds.
pub fn col_sum(m: &Matrix) -> Vec<f32> {
    let mut out = vec![0.0f32; m.cols];
    col_sum_into(&mut out, m);
    out
}

/// [`col_sum`] into a pre-sized `m.cols` slice.
pub fn col_sum_into(out: &mut [f32], m: &Matrix) {
    assert_eq!(out.len(), m.cols);
    out.fill(0.0);
    for r in 0..m.rows {
        for (o, v) in out.iter_mut().zip(m.row(r)) {
            *o += v;
        }
    }
}

/// Numerically-stable logistic `σ(x)`.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        let e = (-x).exp();
        1.0 / (1.0 + e)
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Numerically-stable `softplus(x) = ln(1+eˣ)`.
#[inline]
pub fn softplus(x: f32) -> f32 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        0.0
    } else {
        x.exp().ln_1p()
    }
}

/// Row-wise softmax (stable: max-shifted).
pub fn softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    softmax_rows_inplace(&mut out);
    out
}

/// In-place variant of [`softmax_rows`].
pub fn softmax_rows_inplace(m: &mut Matrix) {
    let (rows, cols) = (m.rows, m.cols);
    if cols == 0 {
        return;
    }
    let soften = |data: &mut [f32]| {
        for row in data.chunks_exact_mut(cols) {
            let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                sum += *v;
            }
            let inv = 1.0 / sum;
            for v in row {
                *v *= inv;
            }
        }
    };
    if rows * cols < PAR_MIN_ELEMS {
        soften(&mut m.data);
        return;
    }
    let dst = RowsMut::of(m);
    pool::parallel_rows(rows, 32, |lo, hi| {
        // SAFETY: spans are disjoint row ranges.
        soften(unsafe { dst.rows(lo, hi) });
    });
}

/// Mean cross-entropy of softmax rows `p` against integer labels.
pub fn cross_entropy(p: &Matrix, labels: &[u8]) -> f32 {
    assert_eq!(p.rows, labels.len());
    let mut loss = 0.0f32;
    for (r, &l) in labels.iter().enumerate() {
        loss -= p.at(r, l as usize).max(1e-12).ln();
    }
    loss / p.rows as f32
}

/// Row-wise argmax — predictions from logits/goodness tables.
pub fn argmax_rows(m: &Matrix) -> Vec<u8> {
    (0..m.rows)
        .map(|r| {
            let row = m.row(r);
            let mut best = 0usize;
            for (j, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = j;
                }
            }
            best as u8
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                c.data[i * b.cols + j] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (33, 65, 17), (64, 128, 40)] {
            let a = Matrix::rand_uniform(m, k, -1.0, 1.0, &mut rng);
            let b = Matrix::rand_uniform(k, n, -1.0, 1.0, &mut rng);
            let got = matmul(&a, &b);
            let want = naive_matmul(&a, &b);
            assert!(got.max_abs_diff(&want) < 1e-4, "({m},{k},{n})");
        }
    }

    #[test]
    fn into_variants_overwrite_garbage() {
        let mut rng = Rng::new(15);
        let a = Matrix::rand_uniform(9, 12, -1.0, 1.0, &mut rng);
        let b = Matrix::rand_uniform(12, 7, -1.0, 1.0, &mut rng);
        let mut c = Matrix::full(9, 7, f32::NAN);
        matmul_into(&mut c, &a, &b);
        assert_eq!(c.data, matmul(&a, &b).data, "prior contents must not leak");

        let bt = Matrix::rand_uniform(5, 12, -1.0, 1.0, &mut rng);
        let mut c = Matrix::full(9, 5, f32::NAN);
        matmul_a_bt_into(&mut c, &a, &bt);
        assert_eq!(c.data, matmul_a_bt(&a, &bt).data);

        let b2 = Matrix::rand_uniform(9, 4, -1.0, 1.0, &mut rng);
        let mut c = Matrix::full(12, 4, f32::NAN);
        matmul_at_b_into(&mut c, &a, &b2);
        assert_eq!(c.data, matmul_at_b(&a, &b2).data);

        let mut n = Matrix::full(9, 12, f32::NAN);
        normalize_rows_into(&mut n, &a, 1e-8);
        assert_eq!(n.data, normalize_rows(&a, 1e-8).data);
    }

    #[test]
    fn at_b_equals_explicit_transpose() {
        let mut rng = Rng::new(12);
        let a = Matrix::rand_uniform(17, 9, -1.0, 1.0, &mut rng);
        let b = Matrix::rand_uniform(17, 13, -1.0, 1.0, &mut rng);
        let got = matmul_at_b(&a, &b);
        let want = matmul(&a.transpose(), &b);
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn a_bt_equals_explicit_transpose() {
        let mut rng = Rng::new(13);
        let a = Matrix::rand_uniform(7, 11, -1.0, 1.0, &mut rng);
        let b = Matrix::rand_uniform(5, 11, -1.0, 1.0, &mut rng);
        let got = matmul_a_bt(&a, &b);
        let want = matmul(&a, &b.transpose());
        assert!(got.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut rng = Rng::new(14);
        let m = Matrix::rand_uniform(6, 20, -2.0, 2.0, &mut rng);
        let n = normalize_rows(&m, 1e-8);
        for r in 0..n.rows {
            let norm: f32 = n.row(r).iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!((norm - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn normalize_zero_row_is_finite() {
        let m = Matrix::zeros(1, 4);
        let n = normalize_rows(&m, 1e-8);
        assert!(n.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sigmoid_softplus_stability() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
        assert!(sigmoid(100.0) <= 1.0 && sigmoid(100.0) > 0.999);
        assert!(sigmoid(-100.0) >= 0.0 && sigmoid(-100.0) < 1e-3);
        assert!((softplus(100.0) - 100.0).abs() < 1e-3);
        assert_eq!(softplus(-100.0), 0.0);
        assert!((softplus(0.0) - std::f32::consts::LN_2).abs() < 1e-6);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., -1000., 0., 1000.]);
        let p = softmax_rows(&m);
        for r in 0..2 {
            let s: f32 = p.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(p.row(r).iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn argmax_and_colsum() {
        let m = Matrix::from_vec(2, 3, vec![0.1, 0.9, 0.0, 5.0, 1.0, 2.0]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
        assert_eq!(col_sum(&m), vec![5.1, 1.9, 2.0]);
    }

    #[test]
    fn cross_entropy_perfect_prediction_near_zero() {
        let p = Matrix::from_vec(1, 3, vec![0.0001, 0.9998, 0.0001]);
        assert!(cross_entropy(&p, &[1]) < 0.001);
    }
}
