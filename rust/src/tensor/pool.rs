//! Scoped worker pool for the parallel tensor kernels.
//!
//! Dependency-free (std `thread` + the crate's ranked lock wrappers):
//! persistent worker threads drain a shared job queue, and
//! [`parallel_rows`] splits a row
//! range into contiguous spans that borrow the caller's closure for the
//! duration of the call — a completion latch guarantees every span
//! finishes before the call returns, so the borrow is sound even though
//! the queue itself is `'static`.
//!
//! **Determinism contract**: work is partitioned over *output rows only*.
//! Each output element is produced by exactly one span, with the same
//! inner-loop accumulation order the serial kernel uses, so results are
//! bit-identical at every thread count (pinned by
//! `tests/kernel_determinism.rs`). Thread count only changes wall-clock.
//!
//! Sizing: the effective thread count resolves, in priority order, from
//! [`set_threads`] (driven by `ExperimentConfig.threads` / the `--threads`
//! CLI key), the `PFF_THREADS` environment variable, then
//! `std::thread::available_parallelism()`. `threads = 1` takes a
//! zero-overhead serial path (no queue, no synchronization).

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::sync::{LockRank, OrderedCondvar, OrderedMutex};
use crate::tensor::Matrix;

// ---------------------------------------------------------------------------
// completion latch
// ---------------------------------------------------------------------------

struct LatchState {
    remaining: usize,
    panicked: bool,
}

/// Counts outstanding spans of one `parallel_rows` call; the caller parks
/// on it until every span has run (or panicked).
struct Latch {
    state: OrderedMutex<LatchState>,
    cv: OrderedCondvar,
}

impl Latch {
    fn new(count: usize) -> Self {
        Latch {
            state: OrderedMutex::new(
                LockRank::Pool,
                LatchState { remaining: count, panicked: false },
            ),
            cv: OrderedCondvar::new(),
        }
    }

    fn count_down(&self, panicked: bool) {
        let mut g = self.state.lock();
        g.remaining -= 1;
        g.panicked |= panicked;
        if g.remaining == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until all spans completed; returns whether any panicked.
    fn wait(&self) -> bool {
        let mut g = self.state.lock();
        while g.remaining > 0 {
            g = self.cv.wait(g);
        }
        g.panicked
    }
}

// ---------------------------------------------------------------------------
// jobs + worker loop
// ---------------------------------------------------------------------------

/// One row span of one `parallel_rows` call.
struct Job {
    lo: usize,
    hi: usize,
    /// Borrow of the caller's closure, lifetime-erased. Sound because the
    /// issuing `parallel_rows` call blocks on `latch` until this job has
    /// run — the borrow can never outlive the closure.
    task: &'static (dyn Fn(usize, usize) + Sync),
    latch: Arc<Latch>,
}

fn run_job(job: Job) {
    let panicked = catch_unwind(AssertUnwindSafe(|| (job.task)(job.lo, job.hi))).is_err();
    job.latch.count_down(panicked);
}

struct Shared {
    queue: OrderedMutex<VecDeque<Job>>,
    work: OrderedCondvar,
    shutdown: AtomicBool,
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = shared.work.wait(q);
            }
        };
        match job {
            Some(j) => run_job(j),
            None => return,
        }
    }
}

// ---------------------------------------------------------------------------
// the pool
// ---------------------------------------------------------------------------

/// A worker pool executing row-partitioned tasks. Most code uses the
/// process-global pool through the module-level [`parallel_rows`]; tests
/// and tools can build private pools with a fixed size.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Target parallelism including the calling thread.
    threads: usize,
    /// Helper threads spawned so far (grown on demand, never shrunk).
    spawned: OrderedMutex<usize>,
}

impl WorkerPool {
    /// Pool with a total parallelism of `threads` (callers count as one;
    /// `threads - 1` helper workers are spawned lazily).
    pub fn new(threads: usize) -> Self {
        WorkerPool {
            shared: Arc::new(Shared {
                queue: OrderedMutex::new(LockRank::Pool, VecDeque::new()),
                work: OrderedCondvar::new(),
                shutdown: AtomicBool::new(false),
            }),
            threads: threads.max(1),
            spawned: OrderedMutex::new(LockRank::Pool, 0),
        }
    }

    /// Total parallelism this pool targets.
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn ensure_workers(&self, helpers: usize) {
        let mut n = self.spawned.lock();
        while *n < helpers {
            let shared = self.shared.clone();
            std::thread::Builder::new()
                .name(format!("pff-pool-{n}"))
                .spawn(move || worker_loop(shared))
                .expect("spawning pool worker");
            *n += 1;
        }
    }

    /// Split rows `[0, m)` into at most `self.threads()` contiguous spans
    /// (each a multiple of `chunk` rows, except the last) and run `f` on
    /// every span. See [`parallel_rows`] for the determinism contract.
    pub fn parallel_rows(&self, m: usize, chunk: usize, f: impl Fn(usize, usize) + Sync) {
        self.run(self.threads, m, chunk, &f);
    }

    fn run(&self, threads: usize, m: usize, chunk: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        let chunk = chunk.max(1);
        let jobs = threads.min(m.div_ceil(chunk)).max(1);
        if jobs <= 1 {
            if m > 0 {
                f(0, m);
            }
            return;
        }
        self.ensure_workers(jobs - 1);
        // Span length: ceil(m / jobs) rounded up to a chunk multiple, so
        // span boundaries stay aligned with the kernels' tile edges.
        let span = m.div_ceil(jobs).div_ceil(chunk) * chunk;
        let njobs = m.div_ceil(span);
        let latch = Arc::new(Latch::new(njobs.saturating_sub(1)));
        // SAFETY: the latch wait below blocks until every queued job has
        // run, so the erased borrow never outlives `f`.
        let task = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize, usize) + Sync),
                &'static (dyn Fn(usize, usize) + Sync),
            >(f)
        };
        {
            let mut q = self.shared.queue.lock();
            for j in 1..njobs {
                q.push_back(Job {
                    lo: j * span,
                    hi: ((j + 1) * span).min(m),
                    task,
                    latch: latch.clone(),
                });
            }
        }
        self.shared.work.notify_all();
        // The caller runs the first span itself, then helps drain the
        // queue (its own spans or a concurrent call's — work conserving),
        // then parks until its last span lands on a worker.
        let own_panic = catch_unwind(AssertUnwindSafe(|| f(0, span.min(m)))).is_err();
        loop {
            let job = self.shared.queue.lock().pop_front();
            match job {
                Some(j) => run_job(j),
                None => break,
            }
        }
        if latch.wait() || own_panic {
            panic!("pff worker pool: a parallel_rows task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        // Take the queue lock before notifying: a worker between its
        // shutdown check and its wait holds that lock, so this can't slip
        // into the gap and strand it.
        let _g = self.shared.queue.lock();
        self.shared.work.notify_all();
    }
}

// ---------------------------------------------------------------------------
// process-global pool + thread-count resolution
// ---------------------------------------------------------------------------

static EFFECTIVE: AtomicUsize = AtomicUsize::new(0); // 0 = not resolved yet
static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

fn global_pool() -> &'static WorkerPool {
    // Workers grow on demand inside run(); the initial size is irrelevant.
    GLOBAL.get_or_init(|| WorkerPool::new(1))
}

/// Hardware parallelism (`available_parallelism`, 1 when unknown).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn env_threads() -> Option<usize> {
    std::env::var("PFF_THREADS").ok()?.trim().parse::<usize>().ok().filter(|&n| n >= 1)
}

/// Set the effective kernel thread count. `0` re-resolves the default
/// (`PFF_THREADS` env, else all cores). Returns the resolved count.
/// Results never depend on this value — only wall-clock does.
pub fn set_threads(threads: usize) -> usize {
    let n = if threads == 0 { env_threads().unwrap_or_else(available_threads) } else { threads };
    let n = n.max(1);
    EFFECTIVE.store(n, Ordering::Relaxed);
    n
}

/// The effective kernel thread count (resolving the default on first use).
pub fn current_threads() -> usize {
    match EFFECTIVE.load(Ordering::Relaxed) {
        0 => set_threads(0),
        n => n,
    }
}

/// Run `f(lo, hi)` over disjoint contiguous spans covering rows `[0, m)`,
/// on the process-global pool at the current effective thread count.
///
/// Spans are multiples of `chunk` rows (except the last), so kernels can
/// align spans with their tile edges. At `threads == 1`, or when `m`
/// fits one chunk, `f(0, m)` runs inline with zero synchronization.
pub fn parallel_rows(m: usize, chunk: usize, f: impl Fn(usize, usize) + Sync) {
    let t = current_threads();
    if t <= 1 || m <= chunk.max(1) {
        if m > 0 {
            f(0, m);
        }
        return;
    }
    global_pool().run(t, m, chunk, &f);
}

// ---------------------------------------------------------------------------
// shared-output helper
// ---------------------------------------------------------------------------

/// Row-major output buffer shared across `parallel_rows` spans. Each span
/// may only touch rows inside its own `[lo, hi)` range — ranges are
/// disjoint by construction, so the aliasing is sound.
pub struct RowsMut {
    ptr: *mut f32,
    cols: usize,
}

unsafe impl Send for RowsMut {}
unsafe impl Sync for RowsMut {}

impl RowsMut {
    /// Wrap a matrix whose rows will be written by disjoint spans.
    pub fn of(m: &mut Matrix) -> Self {
        RowsMut { ptr: m.data.as_mut_ptr(), cols: m.cols }
    }

    /// Rows `[lo, hi)` as one mutable slice.
    ///
    /// # Safety
    /// Concurrent callers must use disjoint row ranges (which
    /// `parallel_rows` spans are).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn rows(&self, lo: usize, hi: usize) -> &mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr.add(lo * self.cols), (hi - lo) * self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    #[test]
    fn covers_every_row_exactly_once() {
        let pool = WorkerPool::new(4);
        for &(m, chunk) in &[(1usize, 1usize), (5, 2), (64, 8), (97, 16), (1000, 7)] {
            let hits: Vec<AtomicU32> = (0..m).map(|_| AtomicU32::new(0)).collect();
            pool.parallel_rows(m, chunk, |lo, hi| {
                for h in &hits[lo..hi] {
                    h.fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "({m},{chunk}): some row not covered exactly once"
            );
        }
    }

    #[test]
    fn zero_rows_is_a_noop() {
        let pool = WorkerPool::new(4);
        pool.parallel_rows(0, 8, |_, _| panic!("must not run"));
        parallel_rows(0, 8, |_, _| panic!("must not run"));
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        let tid = std::thread::current().id();
        pool.parallel_rows(100, 8, |lo, hi| {
            assert_eq!(std::thread::current().id(), tid, "threads=1 must stay on the caller");
            assert_eq!((lo, hi), (0, 100), "threads=1 must take one span");
        });
    }

    #[test]
    fn spans_align_to_chunk() {
        let pool = WorkerPool::new(3);
        pool.parallel_rows(100, 16, |lo, hi| {
            assert_eq!(lo % 16, 0, "span start {lo} not chunk-aligned");
            assert!(hi == 100 || hi % 16 == 0, "span end {hi} not chunk-aligned");
        });
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_rows(64, 4, |lo, _| {
                if lo == 0 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "task panic must surface to the caller");
        // the pool is still usable afterwards
        let n = AtomicU32::new(0);
        pool.parallel_rows(64, 4, |lo, hi| {
            n.fetch_add((hi - lo) as u32, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn set_threads_resolves() {
        let prev = current_threads();
        assert_eq!(set_threads(3), 3);
        assert_eq!(current_threads(), 3);
        assert!(set_threads(0) >= 1, "0 must re-resolve a sane default");
        set_threads(prev);
    }

    #[test]
    fn rows_mut_disjoint_writes() {
        let mut m = Matrix::zeros(32, 4);
        let out = RowsMut::of(&mut m);
        let pool = WorkerPool::new(4);
        pool.parallel_rows(32, 4, |lo, hi| {
            let rows = unsafe { out.rows(lo, hi) };
            for (i, v) in rows.iter_mut().enumerate() {
                *v = (lo * 4 + i) as f32;
            }
        });
        assert!(m.data.iter().enumerate().all(|(i, &v)| v == i as f32));
    }
}
