//! Deterministic RNG (SplitMix64 core) — no external crates available
//! offline, and determinism across nodes/chapters is load-bearing for the
//! paper's RandomNEG strategy (every node must re-derive the same negative
//! labels for a given chapter without communication).

/// Serializable snapshot of a [`Rng`]'s full internal state — the
/// SplitMix64 counter *and* the cached Box-Muller spare. Checkpoints
/// persist this so a resumed run continues every random stream (negative
/// sampling, shuffles, init) exactly where the interrupted run left off
/// instead of silently restarting it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    /// SplitMix64 counter.
    pub state: u64,
    /// Cached second Box-Muller output, if one is pending.
    pub spare_normal: Option<f32>,
}

/// SplitMix64-based pseudo-random generator with normal/uniform helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller output.
    spare_normal: Option<f32>,
}

impl Rng {
    /// Snapshot the generator's full internal state (see [`RngState`]).
    pub fn state(&self) -> RngState {
        RngState { state: self.state, spare_normal: self.spare_normal }
    }

    /// Rebuild a generator from a snapshot: the stream continues bit-for-bit
    /// where [`Rng::state`] captured it.
    pub fn from_state(s: RngState) -> Self {
        Rng { state: s.state, spare_normal: s.spare_normal }
    }

    /// New generator from a seed. Equal seeds ⇒ identical streams.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare_normal: None }
    }

    /// Derive an independent stream for a (node, chapter, purpose) triple.
    /// Used so every node can re-derive chapter-local randomness without
    /// messages (paper §5: RandomNEG re-rolls "at the end of each chapter").
    pub fn derive(seed: u64, stream: u64) -> Self {
        let mut r = Rng::new(seed ^ stream.wrapping_mul(0xA24BAED4963EE407));
        r.next_u64(); // decorrelate
        r
    }

    /// Next raw 64-bit value (SplitMix64).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 mantissa bits of uniformity.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`.
    ///
    /// # Panics
    /// If `n == 0`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.f32();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random incorrect label in `[0, classes)` different from `correct`.
    /// This is the primitive behind FixedNEG and RandomNEG.
    pub fn wrong_label(&mut self, correct: u8, classes: usize) -> u8 {
        debug_assert!(classes >= 2);
        let r = self.below(classes - 1) as u8;
        if r >= correct {
            r + 1
        } else {
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn wrong_label_never_correct_and_covers_all() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let w = r.wrong_label(3, 10);
            assert_ne!(w, 3);
            seen[w as usize] = true;
        }
        let covered = seen.iter().filter(|&&s| s).count();
        assert_eq!(covered, 9, "all 9 wrong labels should appear");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(77);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn state_roundtrip_continues_every_stream() {
        // Plain u64 stream.
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let mut b = Rng::from_state(snap);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }

        // Mid-Box-Muller: the spare normal must survive the round trip,
        // or the resumed stream is offset by one draw.
        let mut c = Rng::new(5);
        let _ = c.normal(); // leaves a spare cached
        let snap = c.state();
        assert!(snap.spare_normal.is_some(), "normal() must cache a spare");
        let mut d = Rng::from_state(snap);
        for _ in 0..50 {
            assert_eq!(c.normal().to_bits(), d.normal().to_bits());
        }
    }

    #[test]
    fn derive_streams_independent() {
        let mut a = Rng::derive(42, 1);
        let mut b = Rng::derive(42, 2);
        assert_ne!(a.next_u64(), b.next_u64());
        // but reproducible
        let mut a2 = Rng::derive(42, 1);
        a2.next_u64();
        let _ = a2; // stream equality checked above via determinism test
    }
}
