//! Reusable buffer arena for the engine hot path.
//!
//! Every FF train step needs the same handful of scratch tensors (fused
//! pos/neg batch, normalized input, activations, gradients). Allocating
//! them fresh per step puts the allocator on the hot path; a [`Workspace`]
//! parks the buffers between steps instead, so steady-state training does
//! **zero** heap allocation per step (pinned by the workspace-reuse test
//! in `engine::native`). Buffers are matched best-fit by capacity, so the
//! arena reaches a fixed point after one step of each shape.

use crate::tensor::Matrix;

/// A pool of reusable `f32` buffers. Take with [`Workspace::matrix`] /
/// [`Workspace::vec`], return with [`Workspace::recycle`] /
/// [`Workspace::recycle_vec`].
#[derive(Clone, Debug, Default)]
pub struct Workspace {
    free: Vec<Vec<f32>>,
    fresh_allocs: usize,
}

impl Workspace {
    /// Empty arena.
    pub fn new() -> Self {
        Workspace::default()
    }

    /// A `(rows, cols)` matrix with **unspecified contents** (see
    /// [`Workspace::vec`]), backed by a recycled buffer when one with
    /// enough capacity is parked. Callers must fully overwrite it.
    pub fn matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        Matrix::from_vec(rows, cols, self.vec(rows * cols))
    }

    /// A length-`len` vector with **unspecified contents** — recycled
    /// buffers keep their stale values so a steady-state take does no
    /// memset (fresh growth is zero-filled; stale data is initialized
    /// memory, so this is safe). Every engine consumer fully overwrites
    /// its buffer; callers needing zeros must fill themselves. Matching
    /// is best fit: the smallest parked buffer with sufficient capacity.
    pub fn vec(&mut self, len: usize) -> Vec<f32> {
        let mut best: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            let beats = best.map_or(true, |j: usize| b.capacity() < self.free[j].capacity());
            if b.capacity() >= len && beats {
                best = Some(i);
            }
        }
        let mut buf = match best {
            Some(i) => self.free.swap_remove(i),
            None => {
                self.fresh_allocs += 1;
                Vec::with_capacity(len)
            }
        };
        if buf.len() > len {
            buf.truncate(len);
        } else {
            buf.resize(len, 0.0); // fills only the shortfall
        }
        buf
    }

    /// Park a matrix's buffer for reuse.
    pub fn recycle(&mut self, m: Matrix) {
        self.recycle_vec(m.data);
    }

    /// Park a vector for reuse.
    pub fn recycle_vec(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 {
            self.free.push(v);
        }
    }

    /// How many requests could not be served from the free list — the
    /// steady-state hot path must stop growing this.
    pub fn fresh_allocs(&self) -> usize {
        self.fresh_allocs
    }

    /// Buffers currently parked.
    pub fn parked(&self) -> usize {
        self.free.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_matrix_is_zero_filled_and_shaped() {
        let mut ws = Workspace::new();
        let m = ws.matrix(3, 5);
        assert_eq!((m.rows, m.cols, m.data.len()), (3, 5, 15));
        assert!(m.data.iter().all(|&v| v == 0.0), "fresh growth is zero-filled");
        assert_eq!(ws.fresh_allocs(), 1);
    }

    #[test]
    fn recycled_buffer_is_reused_without_memset() {
        let mut ws = Workspace::new();
        let mut m = ws.matrix(4, 4);
        m.data.fill(7.0);
        ws.recycle(m);
        assert_eq!(ws.parked(), 1);
        let m2 = ws.matrix(4, 4);
        assert_eq!((m2.rows, m2.cols, m2.data.len()), (4, 4, 16));
        // Contents are unspecified by contract; same-size reuse keeps the
        // stale values — the proof no memset happened on the hot path.
        assert!(m2.data.iter().all(|&v| v == 7.0));
        assert_eq!(ws.fresh_allocs(), 1, "same-shape take must not allocate");
        assert_eq!(ws.parked(), 0);
    }

    #[test]
    fn shrinking_reuse_truncates_and_growing_reuse_fills_tail() {
        let mut ws = Workspace::new();
        let mut v = ws.vec(8);
        v.fill(3.0);
        ws.recycle_vec(v);
        let small = ws.vec(4);
        assert_eq!(small.len(), 4);
        ws.recycle_vec(small);
        let grown = ws.vec(8);
        assert_eq!(grown.len(), 8);
        assert!(grown[4..].iter().all(|&v| v == 0.0), "regrown tail is zero-filled");
        assert_eq!(ws.fresh_allocs(), 1, "capacity-8 buffer serves every take");
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let mut ws = Workspace::new();
        let big = ws.matrix(10, 10);
        let small = ws.matrix(2, 2);
        ws.recycle(big);
        ws.recycle(small);
        let take = ws.vec(4);
        assert!(take.capacity() < 100, "must pick the 4-cap buffer, not the 100-cap one");
        assert_eq!(ws.fresh_allocs(), 2);
    }

    #[test]
    fn too_small_buffers_do_not_satisfy() {
        let mut ws = Workspace::new();
        ws.recycle_vec(Vec::with_capacity(4));
        let v = ws.vec(100);
        assert_eq!(v.len(), 100);
        assert_eq!(ws.fresh_allocs(), 1, "undersized park must not be taken");
        assert_eq!(ws.parked(), 1);
    }
}
