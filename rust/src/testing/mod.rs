//! Mini property-testing harness.
//!
//! proptest is unavailable in this offline registry (DESIGN.md
//! substitution table), so this module provides the subset the test suite
//! needs: seeded case generation with failure reporting, plus generators
//! for the domain types. No shrinking — cases are reported with their
//! generation index and seed so any failure is perfectly reproducible.

use crate::tensor::{Matrix, Rng};

/// How many cases [`forall`] runs by default.
pub const DEFAULT_CASES: u32 = 64;

/// Run `prop` on `cases` generated inputs; panics on the first failure
/// with the case index and seed baked into the message.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: u32,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> bool,
) {
    for case in 0..cases {
        let mut rng = Rng::derive(seed, u64::from(case) ^ 0x50524F50); // "PROP"
        let input = gen(&mut rng);
        assert!(
            prop(&input),
            "property '{name}' failed at case {case} (seed {seed}): input = {input:?}"
        );
    }
}

/// Like [`forall`] but the property returns `Result<(), String>` for
/// richer failure messages.
pub fn forall_r<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: u32,
    gen: impl Fn(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let mut rng = Rng::derive(seed, u64::from(case) ^ 0x50524F50);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property '{name}' failed at case {case} (seed {seed}): {msg}\ninput = {input:?}");
        }
    }
}

/// Generator: usize in `[lo, hi]`.
pub fn gen_usize(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Generator: matrix with dims in the given ranges, values in `[lo, hi)`.
pub fn gen_matrix(rng: &mut Rng, rows: (usize, usize), cols: (usize, usize), lo: f32, hi: f32) -> Matrix {
    let r = gen_usize(rng, rows.0, rows.1);
    let c = gen_usize(rng, cols.0, cols.1);
    Matrix::rand_uniform(r, c, lo, hi, rng)
}

/// Generator: label vector of length `n` over `classes`.
pub fn gen_labels(rng: &mut Rng, n: usize, classes: usize) -> Vec<u8> {
    (0..n).map(|_| rng.below(classes) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivially_true() {
        forall("tautology", 1, 16, |r| r.f32(), |_| true);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed at case 0")]
    fn forall_reports_failure_with_case() {
        forall("always-false", 2, 4, |r| r.f32(), |_| false);
    }

    #[test]
    fn generators_respect_bounds() {
        forall(
            "gen-bounds",
            3,
            32,
            |rng| {
                let m = gen_matrix(rng, (1, 5), (1, 7), -2.0, 3.0);
                let l = gen_labels(rng, 9, 4);
                (m, l)
            },
            |(m, l)| {
                m.rows >= 1
                    && m.rows <= 5
                    && m.cols >= 1
                    && m.cols <= 7
                    && m.data.iter().all(|&v| (-2.0..3.0).contains(&v))
                    && l.len() == 9
                    && l.iter().all(|&c| c < 4)
            },
        );
    }
}
