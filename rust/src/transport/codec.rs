//! Hand-rolled binary codec for store messages.
//!
//! Layout conventions (little-endian throughout):
//! * `Matrix`  = `u32 rows, u32 cols, rows*cols × f32`
//! * `Vec<f32>` = `u32 len, len × f32`
//! * `Vec<u8>`  = `u32 len, len × u8`
//! * `Option<OptSnapshot>` = `u8 flag (0/1)` then the snapshot fields
//! * frame     = `u32 payload_len, payload`
//!
//! Protocol-v2 multiplexing headers (full wire spec: `transport/PROTOCOL.md`):
//! * request payload  = `u64 req_id, u8 opcode, body`
//! * response payload = `u64 req_id, u8 status, body`

use anyhow::{bail, Result};

use crate::coordinator::store::{HeadParams, LayerDelta, LayerParams, OptSnapshot};
use crate::tensor::Matrix;

/// Incremental byte writer.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// Fresh encoder.
    pub fn new() -> Self {
        Enc::default()
    }

    /// Finish, returning the payload bytes.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    /// Append a `u8`.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u32`.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32`.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a length-prefixed f32 slice.
    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        self.f32_raw(v);
    }

    /// Append raw f32 data (no length prefix). On little-endian targets
    /// this is one memcpy — the wire format is LE, and the per-element
    /// `to_le_bytes` loop was the TCP-path bottleneck (§Perf iteration 8:
    /// codec 3.9 → ~12 GB/s).
    fn f32_raw(&mut self, v: &[f32]) {
        #[cfg(target_endian = "little")]
        {
            // SAFETY: f32 is POD; reinterpreting as bytes is always valid.
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.f32(x);
        }
    }

    /// Append a length-prefixed byte slice.
    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Append raw bytes with **no** length prefix — for already-encoded
    /// message bodies appended after a header (the frame layer adds the
    /// outer length).
    pub fn raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Append a matrix.
    pub fn matrix(&mut self, m: &Matrix) {
        self.u32(m.rows as u32);
        self.u32(m.cols as u32);
        self.f32_raw(&m.data);
    }

    /// Append layer params.
    pub fn layer_params(&mut self, p: &LayerParams) {
        self.matrix(&p.w);
        self.f32s(&p.b);
        self.u8(u8::from(p.normalize_input));
        self.opt_snapshot(&p.opt);
    }

    /// Append head params.
    pub fn head_params(&mut self, p: &HeadParams) {
        self.matrix(&p.w);
        self.f32s(&p.b);
        self.opt_snapshot(&p.opt);
    }

    /// Append a row-level layer delta (`PUT_LAYER_DELTA` body, v3):
    /// `u32 n, n × u32 row, Matrix data, Vec<f32> b, u8 normalize`.
    pub fn layer_delta(&mut self, d: &LayerDelta) {
        self.u32(d.rows.len() as u32);
        for &r in &d.rows {
            self.u32(r);
        }
        self.matrix(&d.data);
        self.f32s(&d.b);
        self.u8(u8::from(d.normalize_input));
    }

    /// Append a v2 request header (`u64 req_id, u8 opcode`). The body
    /// follows via the other `Enc` methods.
    pub fn req_header(&mut self, req_id: u64, opcode: u8) {
        self.u64(req_id);
        self.u8(opcode);
    }

    /// Append a v2 response header (`u64 req_id, u8 status`).
    pub fn resp_header(&mut self, req_id: u64, status: u8) {
        self.u64(req_id);
        self.u8(status);
    }

    fn opt_snapshot(&mut self, o: &Option<OptSnapshot>) {
        match o {
            None => self.u8(0),
            Some(s) => {
                self.u8(1);
                self.matrix(&s.m_w);
                self.matrix(&s.v_w);
                self.f32s(&s.m_b);
                self.f32s(&s.v_b);
                self.u32(s.t);
            }
        }
    }
}

/// Incremental byte reader.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// Decode from a payload slice.
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("codec: wanted {n} bytes, have {}", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Read an `f32`.
    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a length-prefixed f32 vec.
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(decode_f32s(raw))
    }

    /// Read a length-prefixed byte vec.
    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Read a length-prefixed string.
    pub fn str(&mut self) -> Result<String> {
        Ok(String::from_utf8(self.bytes()?)?)
    }

    /// Read a v2 request/response header: `(u64 req_id, u8 opcode_or_status)`.
    pub fn header(&mut self) -> Result<(u64, u8)> {
        Ok((self.u64()?, self.u8()?))
    }

    /// Read a matrix.
    pub fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let raw = self.take(rows * cols * 4)?;
        Ok(Matrix::from_vec(rows, cols, decode_f32s(raw)))
    }

    /// Read layer params.
    pub fn layer_params(&mut self) -> Result<LayerParams> {
        Ok(LayerParams {
            w: self.matrix()?,
            b: self.f32s()?,
            normalize_input: self.u8()? != 0,
            opt: self.opt_snapshot()?,
        })
    }

    /// Read head params.
    pub fn head_params(&mut self) -> Result<HeadParams> {
        Ok(HeadParams { w: self.matrix()?, b: self.f32s()?, opt: self.opt_snapshot()? })
    }

    /// Read a row-level layer delta (see [`Enc::layer_delta`]).
    pub fn layer_delta(&mut self) -> Result<LayerDelta> {
        let n = self.u32()? as usize;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push(self.u32()?);
        }
        let data = self.matrix()?;
        if data.rows != n {
            bail!("codec: layer delta carries {} data rows for {n} row indices", data.rows);
        }
        Ok(LayerDelta { rows, data, b: self.f32s()?, normalize_input: self.u8()? != 0 })
    }

    fn opt_snapshot(&mut self) -> Result<Option<OptSnapshot>> {
        if self.u8()? == 0 {
            return Ok(None);
        }
        Ok(Some(OptSnapshot {
            m_w: self.matrix()?,
            v_w: self.matrix()?,
            m_b: self.f32s()?,
            v_b: self.f32s()?,
            t: self.u32()?,
        }))
    }
}

/// Decode raw LE bytes into f32s (bulk copy on little-endian hosts).
fn decode_f32s(raw: &[u8]) -> Vec<f32> {
    let n = raw.len() / 4;
    #[cfg(target_endian = "little")]
    {
        let mut out = vec![0.0f32; n];
        // SAFETY: out is allocated with exactly raw.len() bytes of f32s;
        // any bit pattern is a valid f32.
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr().cast::<u8>(), raw.len());
        }
        out
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        out
    }
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl std::io::Write, payload: &[u8]) -> Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame (up to `max` bytes — 1 GiB default guard).
pub fn read_frame(r: &mut impl std::io::Read, max: usize) -> Result<Vec<u8>> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > max {
        bail!("codec: frame of {len} bytes exceeds cap {max}");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn scalar_roundtrip() {
        let mut e = Enc::new();
        e.u8(7);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX - 3);
        e.f32(-1.25);
        e.str("hello");
        let buf = e.finish();
        let mut d = Dec::new(&buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX - 3);
        assert_eq!(d.f32().unwrap(), -1.25);
        assert_eq!(d.str().unwrap(), "hello");
        assert_eq!(d.remaining(), 0);
    }

    #[test]
    fn layer_params_roundtrip_with_opt() {
        let mut rng = Rng::new(1);
        let p = LayerParams {
            w: Matrix::randn_scaled(5, 4, &mut rng),
            b: vec![0.1, 0.2, 0.3, 0.4],
            normalize_input: true,
            opt: Some(OptSnapshot {
                m_w: Matrix::randn_scaled(5, 4, &mut rng),
                v_w: Matrix::randn_scaled(5, 4, &mut rng),
                m_b: vec![1.0; 4],
                v_b: vec![2.0; 4],
                t: 99,
            }),
        };
        let mut e = Enc::new();
        e.layer_params(&p);
        let buf = e.finish();
        let got = Dec::new(&buf).layer_params().unwrap();
        assert_eq!(got.w, p.w);
        assert_eq!(got.b, p.b);
        assert!(got.normalize_input);
        let o = got.opt.unwrap();
        assert_eq!(o.t, 99);
        assert_eq!(o.v_b, vec![2.0; 4]);
    }

    #[test]
    fn layer_delta_roundtrip() {
        let mut rng = Rng::new(4);
        let d = LayerDelta {
            rows: vec![0, 3, 7],
            data: Matrix::randn_scaled(3, 5, &mut rng),
            b: vec![0.5; 5],
            normalize_input: true,
        };
        let mut e = Enc::new();
        e.layer_delta(&d);
        let buf = e.finish();
        let got = Dec::new(&buf).layer_delta().unwrap();
        assert_eq!(got.rows, d.rows);
        assert_eq!(got.data, d.data);
        assert_eq!(got.b, d.b);
        assert!(got.normalize_input);

        // row-count / data-row mismatch is rejected at decode
        let mut e = Enc::new();
        e.u32(2); // claims 2 rows
        e.u32(0);
        e.u32(1);
        e.matrix(&Matrix::zeros(3, 5)); // but carries 3
        e.f32s(&[0.0; 5]);
        e.u8(0);
        let buf = e.finish();
        assert!(Dec::new(&buf).layer_delta().is_err());
    }

    #[test]
    fn truncated_decode_fails_cleanly() {
        let mut e = Enc::new();
        e.matrix(&Matrix::zeros(4, 4));
        let buf = e.finish();
        let mut d = Dec::new(&buf[..10]);
        assert!(d.matrix().is_err());
    }

    #[test]
    fn frame_roundtrip() {
        let mut pipe: Vec<u8> = Vec::new();
        write_frame(&mut pipe, b"abc").unwrap();
        write_frame(&mut pipe, b"").unwrap();
        let mut cur = std::io::Cursor::new(pipe);
        assert_eq!(read_frame(&mut cur, 1 << 20).unwrap(), b"abc");
        assert_eq!(read_frame(&mut cur, 1 << 20).unwrap(), b"");
    }

    #[test]
    fn v2_header_roundtrip() {
        let mut e = Enc::new();
        e.req_header(u64::MAX - 1, 0x12);
        e.u32(7);
        let buf = e.finish();
        let mut d = Dec::new(&buf);
        assert_eq!(d.header().unwrap(), (u64::MAX - 1, 0x12));
        assert_eq!(d.u32().unwrap(), 7);

        let mut e = Enc::new();
        e.resp_header(3, 0);
        let buf = e.finish();
        assert_eq!(Dec::new(&buf).header().unwrap(), (3, 0));
    }

    #[test]
    fn frame_cap_enforced() {
        let mut pipe: Vec<u8> = Vec::new();
        write_frame(&mut pipe, &[0u8; 100]).unwrap();
        let mut cur = std::io::Cursor::new(pipe);
        assert!(read_frame(&mut cur, 50).is_err());
    }
}
