//! Hand-rolled binary codec for store messages.
//!
//! Layout conventions (little-endian throughout):
//! * `Matrix`  = `u32 rows, u32 cols, rows*cols × f32`
//! * `Vec<f32>` = `u32 len, len × f32`
//! * `Vec<u8>`  = `u32 len, len × u8`
//! * `Option<OptSnapshot>` = `u8 flag (0/1)` then the snapshot fields
//! * frame     = `u32 payload_len, payload`
//!
//! Quantized frames (protocol v4, checkpoint format v2) are self-describing:
//! * `QuantMatrix` = `u8 tag` then a tag-specific body
//!   - tag 0 (f32)  = `Matrix`
//!   - tag 1 (bf16) = `u32 rows, u32 cols, rows*cols × u16`
//!   - tag 2 (i8)   = `u32 rows, u32 cols`, then per row
//!     `u8 kind` — kind 0 (raw) `cols × f32`; kind 1 (affine)
//!     `f32 lo, f32 scale, cols × u8`
//!
//! Protocol-v2 multiplexing headers (full wire spec: `transport/PROTOCOL.md`):
//! * request payload  = `u64 req_id, u8 opcode, body`
//! * response payload = `u64 req_id, u8 status, body`

use anyhow::{bail, Result};

use crate::coordinator::store::{HeadParams, LayerDelta, LayerParams, OptSnapshot};
use crate::tensor::Matrix;

/// Incremental byte writer.
#[derive(Default)]
pub struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    /// Fresh encoder.
    pub fn new() -> Self {
        Enc::default()
    }

    /// Finish, returning the payload bytes.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    /// Append a `u8`.
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a `u16`.
    pub fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u32`.
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a `u64`.
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an `f32`.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a length-prefixed f32 slice.
    pub fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        self.f32_raw(v);
    }

    /// Append raw f32 data (no length prefix). On little-endian targets
    /// this is one memcpy — the wire format is LE, and the per-element
    /// `to_le_bytes` loop was the TCP-path bottleneck (§Perf iteration 8:
    /// codec 3.9 → ~12 GB/s).
    fn f32_raw(&mut self, v: &[f32]) {
        #[cfg(target_endian = "little")]
        {
            // SAFETY: f32 is POD; reinterpreting as bytes is always valid.
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 4) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.f32(x);
        }
    }

    /// Append raw u16 data (no length prefix) — bf16 payloads, same
    /// LE-memcpy fast path as [`Enc::f32_raw`].
    fn u16_raw(&mut self, v: &[u16]) {
        #[cfg(target_endian = "little")]
        {
            // SAFETY: u16 is POD; reinterpreting as bytes is always valid.
            let bytes =
                unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), v.len() * 2) };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        for &x in v {
            self.u16(x);
        }
    }

    /// Append a length-prefixed byte slice.
    pub fn bytes(&mut self, v: &[u8]) {
        self.u32(v.len() as u32);
        self.buf.extend_from_slice(v);
    }

    /// Append raw bytes with **no** length prefix — for already-encoded
    /// message bodies appended after a header (the frame layer adds the
    /// outer length).
    pub fn raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Append a length-prefixed UTF-8 string.
    pub fn str(&mut self, v: &str) {
        self.bytes(v.as_bytes());
    }

    /// Append a matrix.
    pub fn matrix(&mut self, m: &Matrix) {
        self.u32(m.rows as u32);
        self.u32(m.cols as u32);
        self.f32_raw(&m.data);
    }

    /// Append layer params.
    pub fn layer_params(&mut self, p: &LayerParams) {
        self.matrix(&p.w);
        self.f32s(&p.b);
        self.u8(u8::from(p.normalize_input));
        self.opt_snapshot(&p.opt);
    }

    /// Append head params.
    pub fn head_params(&mut self, p: &HeadParams) {
        self.matrix(&p.w);
        self.f32s(&p.b);
        self.opt_snapshot(&p.opt);
    }

    /// Append a row-level layer delta (`PUT_LAYER_DELTA` body, v3):
    /// `u32 n, n × u32 row, Matrix data, Vec<f32> b, u8 normalize`.
    pub fn layer_delta(&mut self, d: &LayerDelta) {
        self.u32(d.rows.len() as u32);
        for &r in &d.rows {
            self.u32(r);
        }
        self.matrix(&d.data);
        self.f32s(&d.b);
        self.u8(u8::from(d.normalize_input));
    }

    /// Append a quantized matrix (self-describing `u8 tag` + body, see
    /// the module docs for the per-tag layouts).
    pub fn quant_matrix(&mut self, m: &QuantMatrix) {
        match m {
            QuantMatrix::F32(m) => {
                self.u8(QM_F32);
                self.matrix(m);
            }
            QuantMatrix::Bf16 { rows, cols, data } => {
                self.u8(QM_BF16);
                self.u32(*rows as u32);
                self.u32(*cols as u32);
                self.u16_raw(data);
            }
            QuantMatrix::I8 { rows, cols, rows_enc } => {
                self.u8(QM_I8);
                self.u32(*rows as u32);
                self.u32(*cols as u32);
                for r in rows_enc {
                    match r {
                        I8Row::Raw(v) => {
                            self.u8(0);
                            self.f32_raw(v);
                        }
                        I8Row::Affine { lo, scale, q } => {
                            self.u8(1);
                            self.f32(*lo);
                            self.f32(*scale);
                            self.raw(q);
                        }
                    }
                }
            }
        }
    }

    /// Append quantized layer params (`PUT_LAYER_Q` body, v4). Biases
    /// travel as full f32 — they are tiny, exactly like [`LayerDelta`].
    pub fn quant_layer_params(&mut self, p: &QuantLayerParams) {
        self.quant_matrix(&p.w);
        self.f32s(&p.b);
        self.u8(u8::from(p.normalize_input));
        self.quant_opt_snapshot(&p.opt);
    }

    /// Append quantized head params (`PUT_HEAD_Q` body, v4).
    pub fn quant_head_params(&mut self, p: &QuantHeadParams) {
        self.quant_matrix(&p.w);
        self.f32s(&p.b);
        self.quant_opt_snapshot(&p.opt);
    }

    fn quant_opt_snapshot(&mut self, o: &Option<QuantOptSnapshot>) {
        match o {
            None => self.u8(0),
            Some(o) => {
                self.u8(1);
                self.quant_matrix(&o.m_w);
                self.quant_matrix(&o.v_w);
                self.f32s(&o.m_b);
                self.f32s(&o.v_b);
                self.u32(o.t);
            }
        }
    }

    /// Append a v2 request header (`u64 req_id, u8 opcode`). The body
    /// follows via the other `Enc` methods.
    pub fn req_header(&mut self, req_id: u64, opcode: u8) {
        self.u64(req_id);
        self.u8(opcode);
    }

    /// Append a v2 response header (`u64 req_id, u8 status`).
    pub fn resp_header(&mut self, req_id: u64, status: u8) {
        self.u64(req_id);
        self.u8(status);
    }

    fn opt_snapshot(&mut self, o: &Option<OptSnapshot>) {
        match o {
            None => self.u8(0),
            Some(s) => {
                self.u8(1);
                self.matrix(&s.m_w);
                self.matrix(&s.v_w);
                self.f32s(&s.m_b);
                self.f32s(&s.v_b);
                self.u32(s.t);
            }
        }
    }
}

/// Incremental byte reader.
pub struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    /// Decode from a payload slice.
    pub fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("codec: wanted {n} bytes, have {}", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a `u8`.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a `u16`.
    pub fn u16(&mut self) -> Result<u16> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    /// Read a `u32`.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a `u64`.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// Read an `f32`.
    pub fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a length-prefixed f32 vec.
    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(decode_f32s(raw))
    }

    /// Read a length-prefixed byte vec.
    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    /// Read a length-prefixed string.
    pub fn str(&mut self) -> Result<String> {
        Ok(String::from_utf8(self.bytes()?)?)
    }

    /// Read a v2 request/response header: `(u64 req_id, u8 opcode_or_status)`.
    pub fn header(&mut self) -> Result<(u64, u8)> {
        Ok((self.u64()?, self.u8()?))
    }

    /// Read a matrix.
    pub fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let raw = self.take(rows * cols * 4)?;
        Ok(Matrix::from_vec(rows, cols, decode_f32s(raw)))
    }

    /// Read layer params.
    pub fn layer_params(&mut self) -> Result<LayerParams> {
        Ok(LayerParams {
            w: self.matrix()?,
            b: self.f32s()?,
            normalize_input: self.u8()? != 0,
            opt: self.opt_snapshot()?,
        })
    }

    /// Read head params.
    pub fn head_params(&mut self) -> Result<HeadParams> {
        Ok(HeadParams { w: self.matrix()?, b: self.f32s()?, opt: self.opt_snapshot()? })
    }

    /// Read a row-level layer delta (see [`Enc::layer_delta`]).
    pub fn layer_delta(&mut self) -> Result<LayerDelta> {
        let n = self.u32()? as usize;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push(self.u32()?);
        }
        let data = self.matrix()?;
        if data.rows != n {
            bail!("codec: layer delta carries {} data rows for {n} row indices", data.rows);
        }
        Ok(LayerDelta { rows, data, b: self.f32s()?, normalize_input: self.u8()? != 0 })
    }

    fn opt_snapshot(&mut self) -> Result<Option<OptSnapshot>> {
        if self.u8()? == 0 {
            return Ok(None);
        }
        Ok(Some(OptSnapshot {
            m_w: self.matrix()?,
            v_w: self.matrix()?,
            m_b: self.f32s()?,
            v_b: self.f32s()?,
            t: self.u32()?,
        }))
    }

    /// Read a quantized matrix (see [`Enc::quant_matrix`]).
    pub fn quant_matrix(&mut self) -> Result<QuantMatrix> {
        match self.u8()? {
            QM_F32 => Ok(QuantMatrix::F32(self.matrix()?)),
            QM_BF16 => {
                let rows = self.u32()? as usize;
                let cols = self.u32()? as usize;
                let raw = self.take(rows * cols * 2)?;
                let mut data = Vec::with_capacity(rows * cols);
                for c in raw.chunks_exact(2) {
                    data.push(u16::from_le_bytes([c[0], c[1]]));
                }
                Ok(QuantMatrix::Bf16 { rows, cols, data })
            }
            QM_I8 => {
                let rows = self.u32()? as usize;
                let cols = self.u32()? as usize;
                let mut rows_enc = Vec::with_capacity(rows);
                for _ in 0..rows {
                    rows_enc.push(match self.u8()? {
                        0 => I8Row::Raw(decode_f32s(self.take(cols * 4)?)),
                        1 => I8Row::Affine {
                            lo: self.f32()?,
                            scale: self.f32()?,
                            q: self.take(cols)?.to_vec(),
                        },
                        k => bail!("codec: unknown i8 row kind {k}"),
                    });
                }
                Ok(QuantMatrix::I8 { rows, cols, rows_enc })
            }
            t => bail!("codec: unknown quantized-matrix tag {t:#04x}"),
        }
    }

    /// Read quantized layer params (see [`Enc::quant_layer_params`]).
    pub fn quant_layer_params(&mut self) -> Result<QuantLayerParams> {
        Ok(QuantLayerParams {
            w: self.quant_matrix()?,
            b: self.f32s()?,
            normalize_input: self.u8()? != 0,
            opt: self.quant_opt_snapshot()?,
        })
    }

    /// Read quantized head params (see [`Enc::quant_head_params`]).
    pub fn quant_head_params(&mut self) -> Result<QuantHeadParams> {
        Ok(QuantHeadParams {
            w: self.quant_matrix()?,
            b: self.f32s()?,
            opt: self.quant_opt_snapshot()?,
        })
    }

    fn quant_opt_snapshot(&mut self) -> Result<Option<QuantOptSnapshot>> {
        if self.u8()? == 0 {
            return Ok(None);
        }
        Ok(Some(QuantOptSnapshot {
            m_w: self.quant_matrix()?,
            v_w: self.quant_matrix()?,
            m_b: self.f32s()?,
            v_b: self.f32s()?,
            t: self.u32()?,
        }))
    }
}

/// Decode raw LE bytes into f32s (bulk copy on little-endian hosts).
fn decode_f32s(raw: &[u8]) -> Vec<f32> {
    let n = raw.len() / 4;
    #[cfg(target_endian = "little")]
    {
        let mut out = vec![0.0f32; n];
        // SAFETY: out is allocated with exactly raw.len() bytes of f32s;
        // any bit pattern is a valid f32.
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr().cast::<u8>(), raw.len());
        }
        out
    }
    #[cfg(not(target_endian = "little"))]
    {
        let mut out = Vec::with_capacity(n);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Quantized wire & checkpoint codecs (protocol v4, checkpoint format v2)
// ---------------------------------------------------------------------------

/// `QuantMatrix` wire tag: raw f32 (lossless).
const QM_F32: u8 = 0;
/// `QuantMatrix` wire tag: bf16 (upper 16 bits of each f32, round-to-nearest-even).
const QM_BF16: u8 = 1;
/// `QuantMatrix` wire tag: i8 per-row affine (f32 lo/scale per row).
const QM_I8: u8 = 2;

/// Lossy compression applied to published matrices, selected by the
/// `wire_codec` config key. Determinism is quantize-at-publish: the
/// publisher rounds its params through the codec *before* the store
/// write, so the store holds the dequantized bits on every transport —
/// in-proc and TCP runs land on identical weights, and re-encoding a
/// store entry (checkpoint, TCP relay) reproduces the same frame.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireCodec {
    /// Full f32 frames — lossless, bitwise identical to the pre-v4 wire.
    #[default]
    F32,
    /// Truncate each f32 to bfloat16 (round-to-nearest-even): ~50% of
    /// the f32 matrix payload.
    Bf16,
    /// Per-row affine u8 quantization with f32 `lo`/`scale` per row:
    /// ~26% of the f32 matrix payload. Rows holding non-finite values
    /// (or that fail to reach a bitwise encode/decode fixed point) fall
    /// back to raw f32, so NaN/Inf payloads survive untouched.
    I8,
}

impl WireCodec {
    /// Stable one-byte tag (checkpoint format v2 stores it).
    pub fn tag(self) -> u8 {
        match self {
            WireCodec::F32 => QM_F32,
            WireCodec::Bf16 => QM_BF16,
            WireCodec::I8 => QM_I8,
        }
    }

    /// Inverse of [`WireCodec::tag`].
    pub fn from_tag(tag: u8) -> Result<Self> {
        Ok(match tag {
            QM_F32 => WireCodec::F32,
            QM_BF16 => WireCodec::Bf16,
            QM_I8 => WireCodec::I8,
            t => bail!("unknown wire codec tag {t:#04x}"),
        })
    }

    /// Quantize one matrix. `F32` is the identity (a clone).
    pub fn quantize_matrix(self, m: &Matrix) -> QuantMatrix {
        match self {
            WireCodec::F32 => QuantMatrix::F32(m.clone()),
            WireCodec::Bf16 => QuantMatrix::Bf16 {
                rows: m.rows,
                cols: m.cols,
                data: m.data.iter().map(|&x| bf16_from_f32(x)).collect(),
            },
            WireCodec::I8 => {
                let cols = m.cols;
                let rows_enc =
                    (0..m.rows).map(|r| i8_quantize_row(&m.data[r * cols..(r + 1) * cols])).collect();
                QuantMatrix::I8 { rows: m.rows, cols, rows_enc }
            }
        }
    }

    /// Quantize layer params. Biases (and the Adam bias moments) stay
    /// f32 — they are tiny; only the matrices shrink.
    pub fn quantize_layer(self, p: &LayerParams) -> QuantLayerParams {
        QuantLayerParams {
            w: self.quantize_matrix(&p.w),
            b: p.b.clone(),
            normalize_input: p.normalize_input,
            opt: p.opt.as_ref().map(|o| self.quantize_opt(o)),
        }
    }

    /// Quantize head params.
    pub fn quantize_head(self, p: &HeadParams) -> QuantHeadParams {
        QuantHeadParams {
            w: self.quantize_matrix(&p.w),
            b: p.b.clone(),
            opt: p.opt.as_ref().map(|o| self.quantize_opt(o)),
        }
    }

    fn quantize_opt(self, o: &OptSnapshot) -> QuantOptSnapshot {
        QuantOptSnapshot {
            m_w: self.quantize_matrix(&o.m_w),
            v_w: self.quantize_matrix(&o.v_w),
            m_b: o.m_b.clone(),
            v_b: o.v_b.clone(),
            t: o.t,
        }
    }
}

impl std::str::FromStr for WireCodec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => WireCodec::F32,
            "bf16" => WireCodec::Bf16,
            "i8" => WireCodec::I8,
            other => bail!("unknown wire_codec '{other}' (expected f32, bf16 or i8)"),
        })
    }
}

impl std::fmt::Display for WireCodec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            WireCodec::F32 => "f32",
            WireCodec::Bf16 => "bf16",
            WireCodec::I8 => "i8",
        })
    }
}

/// One encoded row of an i8 [`QuantMatrix`].
#[derive(Clone, Debug)]
pub enum I8Row {
    /// Bit-exact f32 fallback (non-finite values, degenerate dynamics).
    Raw(Vec<f32>),
    /// Affine grid: element `i` dequantizes to `lo + scale * q[i]`
    /// (`q[i] == 0` returns `lo` exactly).
    Affine {
        /// Row minimum — the grid origin.
        lo: f32,
        /// Grid step, `(max - lo) / 255` at encode time.
        scale: f32,
        /// One grid index per column.
        q: Vec<u8>,
    },
}

/// A matrix compressed by a [`WireCodec`]. Self-describing on the wire
/// (leading tag byte), so mixed-codec streams decode unambiguously.
#[derive(Clone, Debug)]
pub enum QuantMatrix {
    /// Lossless f32 (codec `f32`, or per-entry checkpoint fallback).
    F32(Matrix),
    /// bf16 payload: each element is the rounded upper half of its f32.
    Bf16 {
        /// Row count.
        rows: usize,
        /// Column count.
        cols: usize,
        /// `rows*cols` bf16 bit patterns, row-major.
        data: Vec<u16>,
    },
    /// Per-row affine i8 payload.
    I8 {
        /// Row count.
        rows: usize,
        /// Column count.
        cols: usize,
        /// One encoded row per matrix row.
        rows_enc: Vec<I8Row>,
    },
}

impl QuantMatrix {
    /// Reconstruct the f32 matrix. This is THE rounding function of the
    /// codec: publishers store exactly this on every transport.
    pub fn dequantize(&self) -> Matrix {
        match self {
            QuantMatrix::F32(m) => m.clone(),
            QuantMatrix::Bf16 { rows, cols, data } => Matrix::from_vec(
                *rows,
                *cols,
                data.iter().map(|&h| bf16_to_f32(h)).collect(),
            ),
            QuantMatrix::I8 { rows, cols, rows_enc } => {
                let mut out = Vec::with_capacity(rows * cols);
                for r in rows_enc {
                    match r {
                        I8Row::Raw(v) => out.extend_from_slice(v),
                        I8Row::Affine { lo, scale, q } => i8_row_dequant(*lo, *scale, q, &mut out),
                    }
                }
                Matrix::from_vec(*rows, *cols, out)
            }
        }
    }

    /// Exact encoded size of this matrix (matches [`Enc::quant_matrix`]).
    pub fn wire_bytes(&self) -> u64 {
        let body = match self {
            QuantMatrix::F32(m) => 8 + 4 * m.data.len(),
            QuantMatrix::Bf16 { data, .. } => 8 + 2 * data.len(),
            QuantMatrix::I8 { rows_enc, .. } => {
                8 + rows_enc
                    .iter()
                    .map(|r| match r {
                        I8Row::Raw(v) => 1 + 4 * v.len(),
                        I8Row::Affine { q, .. } => 1 + 8 + q.len(),
                    })
                    .sum::<usize>()
            }
        };
        (1 + body) as u64
    }
}

/// Quantized Adam snapshot: moment matrices compressed, bias moments f32.
#[derive(Clone, Debug)]
pub struct QuantOptSnapshot {
    /// First moment (weights), quantized.
    pub m_w: QuantMatrix,
    /// Second moment (weights), quantized.
    pub v_w: QuantMatrix,
    /// First moment (bias), f32.
    pub m_b: Vec<f32>,
    /// Second moment (bias), f32.
    pub v_b: Vec<f32>,
    /// Adam step counter.
    pub t: u32,
}

impl QuantOptSnapshot {
    fn dequantize(&self) -> OptSnapshot {
        OptSnapshot {
            m_w: self.m_w.dequantize(),
            v_w: self.v_w.dequantize(),
            m_b: self.m_b.clone(),
            v_b: self.v_b.clone(),
            t: self.t,
        }
    }

    fn wire_bytes(&self) -> u64 {
        self.m_w.wire_bytes()
            + self.v_w.wire_bytes()
            + (4 + 4 * self.m_b.len()) as u64
            + (4 + 4 * self.v_b.len()) as u64
            + 4
    }
}

/// [`LayerParams`] compressed by a [`WireCodec`] (`PUT_LAYER_Q` body).
#[derive(Clone, Debug)]
pub struct QuantLayerParams {
    /// Weight matrix, quantized.
    pub w: QuantMatrix,
    /// Bias, f32.
    pub b: Vec<f32>,
    /// Normalize-input flag.
    pub normalize_input: bool,
    /// Optional optimizer snapshot, matrices quantized.
    pub opt: Option<QuantOptSnapshot>,
}

impl QuantLayerParams {
    /// Reconstruct the (rounded) layer params every store ends up holding.
    pub fn dequantize(&self) -> LayerParams {
        LayerParams {
            w: self.w.dequantize(),
            b: self.b.clone(),
            normalize_input: self.normalize_input,
            opt: self.opt.as_ref().map(|o| o.dequantize()),
        }
    }

    /// Exact encoded size (matches [`Enc::quant_layer_params`]).
    pub fn wire_bytes(&self) -> u64 {
        self.w.wire_bytes()
            + (4 + 4 * self.b.len()) as u64
            + 2
            + self.opt.as_ref().map_or(0, |o| o.wire_bytes())
    }
}

/// [`HeadParams`] compressed by a [`WireCodec`] (`PUT_HEAD_Q` body).
#[derive(Clone, Debug)]
pub struct QuantHeadParams {
    /// Weight matrix, quantized.
    pub w: QuantMatrix,
    /// Bias, f32.
    pub b: Vec<f32>,
    /// Optional optimizer snapshot, matrices quantized.
    pub opt: Option<QuantOptSnapshot>,
}

impl QuantHeadParams {
    /// Reconstruct the (rounded) head params every store ends up holding.
    pub fn dequantize(&self) -> HeadParams {
        HeadParams {
            w: self.w.dequantize(),
            b: self.b.clone(),
            opt: self.opt.as_ref().map(|o| o.dequantize()),
        }
    }

    /// Exact encoded size (matches [`Enc::quant_head_params`]).
    pub fn wire_bytes(&self) -> u64 {
        self.w.wire_bytes()
            + (4 + 4 * self.b.len()) as u64
            + 1
            + self.opt.as_ref().map_or(0, |o| o.wire_bytes())
    }
}

/// f32 → bf16 with round-to-nearest-even. NaNs keep their sign and
/// (truncated) payload but force a quiet bit so rounding can never carry
/// a NaN into an infinity; everything else uses the standard carry-based
/// rounding (large finites saturate to ±inf exactly like hardware bf16).
/// Idempotent on already-rounded values: a bf16 bit pattern widened by
/// [`bf16_to_f32`] maps back to itself.
pub fn bf16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = 0x7FFF + ((bits >> 16) & 1);
    ((bits.wrapping_add(round)) >> 16) as u16
}

/// bf16 → f32 (exact: the upper half carries the whole value).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Bit-exact f32 slice compare (NaN == NaN, -0.0 != +0.0).
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// One affine-quantization attempt over a row: `(lo, scale, q)` such
/// that element `i` dequantizes to `lo + scale * q[i]`. `None` when the
/// row cannot ride an affine grid (non-finite values, overflowing range).
fn i8_row_base(row: &[f32]) -> Option<(f32, f32, Vec<u8>)> {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &x in row {
        if !x.is_finite() {
            return None;
        }
        if x < lo {
            lo = x;
        }
        if x > hi {
            hi = x;
        }
    }
    if row.is_empty() {
        return Some((0.0, 0.0, Vec::new()));
    }
    let scale = (hi - lo) / 255.0;
    if !scale.is_finite() {
        return None;
    }
    let q = if scale == 0.0 {
        vec![0u8; row.len()]
    } else {
        row.iter().map(|&x| ((x - lo) / scale).round().clamp(0.0, 255.0) as u8).collect()
    };
    Some((lo, scale, q))
}

/// Dequantize one affine row into `out`. `q == 0` returns `lo`'s exact
/// bits (the grid origin), so the row minimum — and with it the next
/// encode pass's `lo` — survives re-quantization bit-for-bit.
fn i8_row_dequant(lo: f32, scale: f32, q: &[u8], out: &mut Vec<f32>) {
    out.extend(q.iter().map(|&qi| if qi == 0 { lo } else { lo + scale * qi as f32 }));
}

/// Encode one row, iterating encode→decode to a **bitwise fixed point**
/// (almost always one settle step). The fixed point is what makes the
/// codec deterministic across transports: re-encoding a row the codec
/// already rounded reproduces the identical frame, so TCP relays and
/// checkpoints of a quantized store are lossless. Rows that refuse to
/// settle (or hold non-finite values) fall back to bit-exact raw f32.
fn i8_quantize_row(row: &[f32]) -> I8Row {
    let mut cur: Vec<f32> = row.to_vec();
    for _ in 0..4 {
        let Some((lo, scale, q)) = i8_row_base(&cur) else { break };
        let mut deq = Vec::with_capacity(cur.len());
        i8_row_dequant(lo, scale, &q, &mut deq);
        if bits_eq(&deq, &cur) {
            return I8Row::Affine { lo, scale, q };
        }
        cur = deq;
    }
    I8Row::Raw(row.to_vec())
}

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl std::io::Write, payload: &[u8]) -> Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame (up to `max` bytes — 1 GiB default guard).
pub fn read_frame(r: &mut impl std::io::Read, max: usize) -> Result<Vec<u8>> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > max {
        bail!("codec: frame of {len} bytes exceeds cap {max}");
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn scalar_roundtrip() {
        let mut e = Enc::new();
        e.u8(7);
        e.u32(0xDEAD_BEEF);
        e.u64(u64::MAX - 3);
        e.f32(-1.25);
        e.str("hello");
        let buf = e.finish();
        let mut d = Dec::new(&buf);
        assert_eq!(d.u8().unwrap(), 7);
        assert_eq!(d.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(d.u64().unwrap(), u64::MAX - 3);
        assert_eq!(d.f32().unwrap(), -1.25);
        assert_eq!(d.str().unwrap(), "hello");
        assert_eq!(d.remaining(), 0);
    }

    #[test]
    fn layer_params_roundtrip_with_opt() {
        let mut rng = Rng::new(1);
        let p = LayerParams {
            w: Matrix::randn_scaled(5, 4, &mut rng),
            b: vec![0.1, 0.2, 0.3, 0.4],
            normalize_input: true,
            opt: Some(OptSnapshot {
                m_w: Matrix::randn_scaled(5, 4, &mut rng),
                v_w: Matrix::randn_scaled(5, 4, &mut rng),
                m_b: vec![1.0; 4],
                v_b: vec![2.0; 4],
                t: 99,
            }),
        };
        let mut e = Enc::new();
        e.layer_params(&p);
        let buf = e.finish();
        let got = Dec::new(&buf).layer_params().unwrap();
        assert_eq!(got.w, p.w);
        assert_eq!(got.b, p.b);
        assert!(got.normalize_input);
        let o = got.opt.unwrap();
        assert_eq!(o.t, 99);
        assert_eq!(o.v_b, vec![2.0; 4]);
    }

    #[test]
    fn layer_delta_roundtrip() {
        let mut rng = Rng::new(4);
        let d = LayerDelta {
            rows: vec![0, 3, 7],
            data: Matrix::randn_scaled(3, 5, &mut rng),
            b: vec![0.5; 5],
            normalize_input: true,
        };
        let mut e = Enc::new();
        e.layer_delta(&d);
        let buf = e.finish();
        let got = Dec::new(&buf).layer_delta().unwrap();
        assert_eq!(got.rows, d.rows);
        assert_eq!(got.data, d.data);
        assert_eq!(got.b, d.b);
        assert!(got.normalize_input);

        // row-count / data-row mismatch is rejected at decode
        let mut e = Enc::new();
        e.u32(2); // claims 2 rows
        e.u32(0);
        e.u32(1);
        e.matrix(&Matrix::zeros(3, 5)); // but carries 3
        e.f32s(&[0.0; 5]);
        e.u8(0);
        let buf = e.finish();
        assert!(Dec::new(&buf).layer_delta().is_err());
    }

    #[test]
    fn truncated_decode_fails_cleanly() {
        let mut e = Enc::new();
        e.matrix(&Matrix::zeros(4, 4));
        let buf = e.finish();
        let mut d = Dec::new(&buf[..10]);
        assert!(d.matrix().is_err());
    }

    #[test]
    fn frame_roundtrip() {
        let mut pipe: Vec<u8> = Vec::new();
        write_frame(&mut pipe, b"abc").unwrap();
        write_frame(&mut pipe, b"").unwrap();
        let mut cur = std::io::Cursor::new(pipe);
        assert_eq!(read_frame(&mut cur, 1 << 20).unwrap(), b"abc");
        assert_eq!(read_frame(&mut cur, 1 << 20).unwrap(), b"");
    }

    #[test]
    fn v2_header_roundtrip() {
        let mut e = Enc::new();
        e.req_header(u64::MAX - 1, 0x12);
        e.u32(7);
        let buf = e.finish();
        let mut d = Dec::new(&buf);
        assert_eq!(d.header().unwrap(), (u64::MAX - 1, 0x12));
        assert_eq!(d.u32().unwrap(), 7);

        let mut e = Enc::new();
        e.resp_header(3, 0);
        let buf = e.finish();
        assert_eq!(Dec::new(&buf).header().unwrap(), (3, 0));
    }

    #[test]
    fn frame_cap_enforced() {
        let mut pipe: Vec<u8> = Vec::new();
        write_frame(&mut pipe, &[0u8; 100]).unwrap();
        let mut cur = std::io::Cursor::new(pipe);
        assert!(read_frame(&mut cur, 50).is_err());
    }

    fn quant_layer(codec: WireCodec, rows: usize, cols: usize, opt: bool) -> QuantLayerParams {
        let mut rng = Rng::new(7);
        let p = LayerParams {
            w: Matrix::randn_scaled(rows, cols, &mut rng),
            b: vec![0.25; cols],
            normalize_input: true,
            opt: opt.then(|| OptSnapshot {
                m_w: Matrix::randn_scaled(rows, cols, &mut rng),
                v_w: Matrix::randn_scaled(rows, cols, &mut rng),
                m_b: vec![0.5; cols],
                v_b: vec![0.75; cols],
                t: 42,
            }),
        };
        codec.quantize_layer(&p)
    }

    #[test]
    fn quant_frames_roundtrip_and_wire_bytes_is_exact() {
        for codec in [WireCodec::F32, WireCodec::Bf16, WireCodec::I8] {
            for opt in [false, true] {
                let q = quant_layer(codec, 9, 5, opt);
                let mut e = Enc::new();
                e.quant_layer_params(&q);
                let buf = e.finish();
                assert_eq!(
                    buf.len() as u64,
                    q.wire_bytes(),
                    "{codec}: wire_bytes must match the encoded length"
                );
                let mut d = Dec::new(&buf);
                let got = d.quant_layer_params().unwrap();
                assert_eq!(d.remaining(), 0);
                // decoded frame dequantizes to the same bits
                let a = q.dequantize();
                let b = got.dequantize();
                assert_eq!(
                    a.w.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    b.w.data.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{codec}: dequantized bits differ after a wire roundtrip"
                );
                assert_eq!(a.b, b.b);
                assert_eq!(a.opt.is_some(), b.opt.is_some());
            }
        }
    }

    #[test]
    fn quantize_of_rounded_params_is_a_fixed_point() {
        // The determinism contract: quantize(dequantize(quantize(x)))
        // must reproduce the same dequantized bits — the store (holding
        // rounded values) re-encodes losslessly for TCP and checkpoints.
        let mut rng = Rng::new(11);
        let m = Matrix::randn_scaled(16, 16, &mut rng);
        for codec in [WireCodec::Bf16, WireCodec::I8] {
            let q1 = codec.quantize_matrix(&m);
            let r1 = q1.dequantize();
            let q2 = codec.quantize_matrix(&r1);
            let r2 = q2.dequantize();
            assert!(
                bits_eq(&r1.data, &r2.data),
                "{codec}: second quantize pass changed bits"
            );
            assert_eq!(q1.wire_bytes(), q2.wire_bytes());
        }
    }

    #[test]
    fn quantized_sizes_beat_the_acceptance_ratios() {
        // The ISSUE acceptance bar: bf16 ≤ 55% and i8 ≤ 35% of the f32
        // full-frame bytes at the micro_transport bench shape (256×256).
        let mut rng = Rng::new(3);
        let p = LayerParams {
            w: Matrix::randn_scaled(256, 256, &mut rng),
            b: vec![0.0; 256],
            normalize_input: true,
            opt: None,
        };
        let full = p.wire_bytes() as f64;
        let bf16 = WireCodec::Bf16.quantize_layer(&p).wire_bytes() as f64;
        let i8q = WireCodec::I8.quantize_layer(&p).wire_bytes() as f64;
        assert!(bf16 / full <= 0.55, "bf16 frame is {:.1}% of f32", 100.0 * bf16 / full);
        assert!(i8q / full <= 0.35, "i8 frame is {:.1}% of f32", 100.0 * i8q / full);
    }

    #[test]
    fn bf16_rounding_handles_specials() {
        // NaN stays NaN (never rounds into an infinity), signs survive.
        let nan = f32::from_bits(0x7F80_0001); // signaling-ish payload
        assert!(bf16_to_f32(bf16_from_f32(nan)).is_nan());
        let neg_nan = f32::from_bits(0xFFC0_1234);
        assert!(bf16_to_f32(bf16_from_f32(neg_nan)).is_nan());
        assert!(bf16_to_f32(bf16_from_f32(neg_nan)).is_sign_negative());
        // ±0 and infinities are exact.
        assert_eq!(bf16_to_f32(bf16_from_f32(0.0)).to_bits(), 0.0f32.to_bits());
        assert_eq!(bf16_to_f32(bf16_from_f32(-0.0)).to_bits(), (-0.0f32).to_bits());
        assert_eq!(bf16_to_f32(bf16_from_f32(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(bf16_from_f32(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        // Values near f32::MAX saturate to inf (carry past the bf16 max).
        assert_eq!(bf16_to_f32(bf16_from_f32(f32::MAX)), f32::INFINITY);
        // Round-to-nearest-even: the bf16 ulp at 1.0 is 2^-7, so
        // 1.0 + 2^-8 sits exactly between two grid points and must round
        // to the even one (1.0, mantissa all zeros).
        let tie = 1.0f32 + (2.0f32).powi(-8);
        assert_eq!(bf16_to_f32(bf16_from_f32(tie)), 1.0, "ties must round to even");
    }

    #[test]
    fn i8_rows_with_nonfinite_values_fall_back_to_raw() {
        let data = vec![f32::NAN, 1.0, 2.0, f32::INFINITY];
        let m = Matrix::from_vec(2, 2, data.clone());
        let q = WireCodec::I8.quantize_matrix(&m);
        let r = q.dequantize();
        assert!(bits_eq(&r.data, &data), "non-finite rows must be bit-preserved");
        // constant rows collapse to the affine grid origin exactly
        let c = Matrix::from_vec(1, 4, vec![-0.0f32; 4]);
        let rc = WireCodec::I8.quantize_matrix(&c).dequantize();
        assert!(bits_eq(&rc.data, &c.data), "-0.0 constant row must survive");
    }

    #[test]
    fn wire_codec_parses_and_displays() {
        for (s, c) in [("f32", WireCodec::F32), ("bf16", WireCodec::Bf16), ("i8", WireCodec::I8)] {
            assert_eq!(s.parse::<WireCodec>().unwrap(), c);
            assert_eq!(c.to_string(), s);
            assert_eq!(WireCodec::from_tag(c.tag()).unwrap(), c);
        }
        assert!("fp8".parse::<WireCodec>().is_err());
        assert!(WireCodec::from_tag(9).is_err());
    }
}
