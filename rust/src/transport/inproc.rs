//! In-process transport: nodes share one [`MemStore`] behind an `Arc`.
//!
//! This path bypasses the v2 wire protocol entirely — blocking gets park
//! directly on the store's Condvar with no frames, no codec, no copies.
//! It is the semantic reference the TCP transport must match bitwise
//! (`tests/scheduler_equivalence.rs` asserts exactly that).

use std::sync::Arc;

use crate::coordinator::store::{MemStore, ParamStore};

/// Build a shared in-process store handle set: one `Arc<MemStore>` cloned
/// per node. Trivial, but mirrors [`crate::transport::tcp::TcpStoreClient`]
/// so the coordinator can construct either uniformly.
pub fn shared_store() -> Arc<dyn ParamStore> {
    Arc::new(MemStore::new())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::store::LayerParams;
    use crate::tensor::Matrix;
    use std::time::Duration;

    #[test]
    fn clones_share_state() {
        let store = shared_store();
        let a = store.clone();
        let b = store.clone();
        a.put_neg(3, vec![9, 9]).unwrap();
        assert_eq!(b.get_neg(3, Duration::from_millis(5)).unwrap(), vec![9, 9]);
        let p = LayerParams { w: Matrix::zeros(2, 2), b: vec![0.0; 2], normalize_input: false, opt: None };
        b.put_layer(1, 0, p).unwrap();
        assert!(a.get_layer(1, 0, Duration::from_millis(5)).is_ok());
    }
}
