//! Wire transport for the parameter store.
//!
//! The paper's testbed used raw sockets between nodes; here the store can
//! be reached two ways:
//!
//! * [`inproc`] — nodes are threads sharing one
//!   [`crate::coordinator::store::MemStore`] (zero-copy Arc clone, no wire
//!   format at all).
//! * [`tcp`] — the leader hosts the store behind a TCP server; worker
//!   nodes (threads, or `pff worker` OS processes) use
//!   [`tcp::TcpStoreClient`]. Protocol v2 multiplexes request-id-tagged
//!   frames over one connection and moves all blocking waits server-side
//!   (`WAIT_*` opcodes park on the store's Condvar and reply on publish).
//!
//! The frame format is hand-rolled ([`codec`]) since no serde is
//! available offline: every message is a `u32` length prefix + payload,
//! all little-endian. The full wire specification — framing, handshake,
//! opcode table, blocking semantics, and versioning rules — is
//! `rust/src/transport/PROTOCOL.md`:
//!
#![doc = include_str!("PROTOCOL.md")]

pub mod codec;
pub mod inproc;
pub mod tcp;
