//! Wire transport for the parameter store.
//!
//! The paper's testbed used raw sockets between nodes; here the store can
//! be reached two ways:
//!
//! * [`inproc`] — nodes are threads sharing one
//!   [`crate::coordinator::store::MemStore`] (zero-copy Arc clone).
//! * [`tcp`] — the leader hosts the store behind a TCP server; worker
//!   nodes use [`tcp::TcpStoreClient`]. The frame format is hand-rolled
//!   ([`codec`]) since no serde is available offline: every message is a
//!   `u32` length prefix + opcode + body, all little-endian.

pub mod codec;
pub mod inproc;
pub mod tcp;
