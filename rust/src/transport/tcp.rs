//! TCP transport v4: the leader hosts the parameter store; workers speak a
//! multiplexed request/response protocol over length-prefixed frames.
//!
//! This is the socket setup of the paper's testbed (§6 "we used sockets to
//! establish communication between different nodes"), upgraded to a real
//! wire protocol (full spec: `transport/PROTOCOL.md`):
//!
//! * **Server-side blocking** — `WAIT_LAYER`/`WAIT_HEAD`/`WAIT_NEG` park a
//!   leader-side thread on the [`MemStore`] Condvar and send the response
//!   frame the moment the dependency is published (or its timeout trips).
//!   There is no client-side poll loop anywhere: the paper's pipeline
//!   arrow (§Figure 4) is a Condvar wakeup plus one frame on the wire.
//! * **Multiplexing** — every request carries a `u64 req_id`; responses may
//!   arrive out of order, so one connection carries any number of in-flight
//!   requests. A parked `WAIT_*` never head-of-line-blocks the puts/gets
//!   behind it.
//! * **Batched publish** — `PUT_LAYER` ships weights, bias, and the
//!   optional Adam snapshot (`ship_opt_state`) as one frame.
//! * **Delta publish (v3)** — `PUT_LAYER_DELTA` ships only the rows that
//!   changed against a base chapter already in the store; the server
//!   reconstructs the full layer bit-exactly. `HELLO` negotiates the
//!   version down to v2 peers, which simply keep sending full frames.
//! * **Quantized publish (v4)** — `PUT_LAYER_Q`/`PUT_HEAD_Q` carry
//!   bf16/i8 frames under `wire_codec`; the server dequantizes the same
//!   bits the publisher rounded through, so stored weights are identical
//!   on every transport. Pre-v4 peers fall back to full f32 frames of
//!   the already-rounded params — same stored bits, more bytes.
//! * **Membership** — the first frame on a connection must be `HELLO`
//!   (protocol version + role); workers are assigned node ids through the
//!   leader's [`NodeRegistry`] and report `DONE` when their chapters are
//!   finished, which is how multi-process cluster mode joins.
//! * **Task leases** — when the leader runs the graph dispatcher
//!   ([`StoreServer::start_full`]), workers pull `(chapter, layer)` work
//!   items with `TASK_NEXT` (server-side blocking, like the waits) and
//!   report them with `TASK_DONE`; a worker disconnect requeues its
//!   leases, which is how elastic membership survives crashes.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::dispatch::{Dispatcher, Poll};
use crate::coordinator::registry::{NodeInfo, NodeRegistry};
use crate::coordinator::serve::BatchServer;
use crate::coordinator::store::{HeadParams, LayerDelta, LayerParams, MemStore, ParamStore};
use crate::coordinator::taskgraph::Task;
use crate::metrics::CommStats;
use crate::sync::{LockRank, OrderedMutex};
use crate::tensor::Matrix;
use crate::transport::codec::{
    read_frame, write_frame, Dec, Enc, QuantHeadParams, QuantLayerParams,
};

/// Wire protocol major version, negotiated in `HELLO`.
pub const PROTOCOL_VERSION: u8 = 4;

/// Oldest protocol version the server still speaks. `HELLO` settles on
/// `min(client, server)` within this range; version-gated ops (v3 delta
/// publish, v4 quantized publish) are refused or fallen back client-side
/// when the negotiated version predates them.
pub const MIN_PROTOCOL_VERSION: u8 = 2;

/// Max frame size (1 GiB — a [3072,4000] f32 layer is ~49 MB).
const MAX_FRAME: usize = 1 << 30;

/// Extra slack the client grants the server past a `WAIT_*` op's own
/// timeout before declaring the connection dead.
const WAIT_GRACE: Duration = Duration::from_secs(10);

/// Client-side response deadline for immediate (non-waiting) ops.
const RPC_TIMEOUT: Duration = Duration::from_secs(60);

/// Opcodes (see `transport/PROTOCOL.md` for bodies and responses).
mod op {
    pub const HELLO: u8 = 0x01;
    pub const PUT_LAYER: u8 = 0x10;
    pub const GET_LAYER: u8 = 0x11;
    pub const WAIT_LAYER: u8 = 0x12;
    pub const PUT_HEAD: u8 = 0x13;
    pub const GET_HEAD: u8 = 0x14;
    pub const WAIT_HEAD: u8 = 0x15;
    pub const PUT_NEG: u8 = 0x16;
    pub const GET_NEG: u8 = 0x17;
    pub const WAIT_NEG: u8 = 0x18;
    pub const LATEST_LAYER: u8 = 0x19;
    pub const LATEST_HEAD: u8 = 0x1a;
    pub const STATS: u8 = 0x1b;
    pub const HAS_LAYER: u8 = 0x1c;
    pub const HAS_HEAD: u8 = 0x1d;
    pub const HAS_NEG: u8 = 0x1e;
    pub const LIST_NODES: u8 = 0x20;
    pub const WAIT_NODES: u8 = 0x21;
    pub const DONE: u8 = 0x22;
    pub const TASK_NEXT: u8 = 0x23;
    pub const TASK_DONE: u8 = 0x24;
    /// v3+ only: changed rows against a base chapter already in the store.
    pub const PUT_LAYER_DELTA: u8 = 0x25;
    /// v4+ only: layer params as a quantized frame (`wire_codec`).
    pub const PUT_LAYER_Q: u8 = 0x26;
    /// v4+ only: head params as a quantized frame (`wire_codec`).
    pub const PUT_HEAD_Q: u8 = 0x27;
    /// v4+ only: score one feature row on a serving peer (`pff serve`).
    pub const CLASSIFY: u8 = 0x28;
    /// v4+ only: score a feature matrix on a serving peer (`pff serve`).
    pub const CLASSIFY_BATCH: u8 = 0x29;
}

const ST_OK: u8 = 0;
const ST_ERR: u8 = 1;

/// Roles a connection declares in `HELLO`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Plain store client (no node id, no registry entry).
    Client,
    /// Cluster worker: registered with the leader's [`NodeRegistry`].
    Worker,
}

const ROLE_CLIENT: u8 = 0;
const ROLE_WORKER: u8 = 1;

// ---------------------------------------------------------------------------
// server
// ---------------------------------------------------------------------------

/// Running store server handle; dropping does not stop the listener —
/// call [`StoreServer::shutdown`].
pub struct StoreServer {
    /// Bound local address (use `.port()` for ephemeral binds).
    pub addr: SocketAddr,
    registry: Arc<NodeRegistry>,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl StoreServer {
    /// Start serving `store` on `127.0.0.1:port` (0 = ephemeral) with a
    /// fresh node registry.
    pub fn start(store: Arc<MemStore>, port: u16) -> Result<StoreServer> {
        StoreServer::start_with(store, Arc::new(NodeRegistry::new()), port)
    }

    /// Start serving `store` with an externally-owned registry (cluster
    /// mode: the coordinator parks on it for membership/completion).
    pub fn start_with(
        store: Arc<MemStore>,
        registry: Arc<NodeRegistry>,
        port: u16,
    ) -> Result<StoreServer> {
        StoreServer::start_full(store, registry, None, port)
    }

    /// [`StoreServer::start_with`] plus a task [`Dispatcher`]: worker
    /// connections join the dispatcher at `HELLO`, lease work through
    /// `TASK_NEXT`/`TASK_DONE`, and have their outstanding leases
    /// requeued when the connection drops (elastic cluster mode).
    pub fn start_full(
        store: Arc<MemStore>,
        registry: Arc<NodeRegistry>,
        dispatcher: Option<Arc<Dispatcher>>,
        port: u16,
    ) -> Result<StoreServer> {
        let listener = TcpListener::bind(("127.0.0.1", port)).context("binding store server")?;
        StoreServer::start_listening(listener, store, registry, dispatcher, None)
    }

    /// [`StoreServer::start_with`] plus a serve engine: `CLASSIFY` /
    /// `CLASSIFY_BATCH` frames are admitted into `serve`'s batching queue
    /// and answered (possibly out of request order) when their batch is
    /// scored. Binds `addr` verbatim — `pff serve --addr` exposes the
    /// listener beyond loopback.
    pub fn start_serving(
        store: Arc<MemStore>,
        registry: Arc<NodeRegistry>,
        serve: Arc<BatchServer>,
        addr: &str,
    ) -> Result<StoreServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding serve listener on {addr}"))?;
        StoreServer::start_listening(listener, store, registry, None, Some(serve))
    }

    fn start_listening(
        listener: TcpListener,
        store: Arc<MemStore>,
        registry: Arc<NodeRegistry>,
        dispatcher: Option<Arc<Dispatcher>>,
        serve: Option<Arc<BatchServer>>,
    ) -> Result<StoreServer> {
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let reg2 = registry.clone();
        // Blocking accept — no poll interval. `shutdown` sets the stop flag
        // and wakes the loop with a throwaway connection to itself.
        let accept_thread = std::thread::Builder::new()
            .name("pff-store-server".into())
            .spawn(move || {
                let mut consecutive_errs = 0u32;
                loop {
                    match listener.accept() {
                        Ok((sock, _)) => {
                            consecutive_errs = 0;
                            if stop2.load(Ordering::Relaxed) {
                                return;
                            }
                            sock.set_nodelay(true).ok();
                            let store = store.clone();
                            let registry = reg2.clone();
                            let dispatcher = dispatcher.clone();
                            let serve = serve.clone();
                            // Detached: a conn thread exits when its client
                            // disconnects. Joining here would deadlock
                            // shutdown against still-connected clients.
                            std::thread::spawn(move || {
                                let _ = serve_conn(
                                    sock,
                                    &store,
                                    &registry,
                                    dispatcher.as_ref(),
                                    serve.as_ref(),
                                );
                            });
                        }
                        Err(e) => {
                            if stop2.load(Ordering::Relaxed) {
                                return;
                            }
                            consecutive_errs += 1;
                            if consecutive_errs > 100 {
                                // pff-allow(no-print-in-lib): the accept
                                // loop predates any run (and any EventBus);
                                // a dying listener has no other channel.
                                eprintln!(
                                    "[pff-store-server] accept failing repeatedly, \
                                     giving up: {e}"
                                );
                                return;
                            }
                            // pff-allow(no-sleep-sync): error-path backoff
                            // only (fd pressure etc.) — not synchronization;
                            // the happy path is a plain blocking accept.
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })?;
        Ok(StoreServer { addr, registry, stop, accept_thread: Some(accept_thread) })
    }

    /// The server's node registry (cluster membership + completion).
    pub fn registry(&self) -> Arc<NodeRegistry> {
        self.registry.clone()
    }

    /// Stop accepting new connections; existing connection threads exit
    /// on their own when their clients disconnect (they are detached).
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Wake the blocking accept.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

/// Per-connection response writer, shared between the connection's request
/// loop and any wait threads parked on its behalf. Frames are written
/// whole under the lock, so concurrent repliers never interleave.
struct ConnWriter {
    w: OrderedMutex<BufWriter<TcpStream>>,
}

impl ConnWriter {
    fn reply(&self, req_id: u64, result: Result<Vec<u8>>) -> Result<()> {
        let mut enc = Enc::new();
        match result {
            Ok(body) => {
                enc.resp_header(req_id, ST_OK);
                enc.raw(&body);
            }
            Err(e) => {
                enc.resp_header(req_id, ST_ERR);
                enc.str(&format!("{e:#}"));
            }
        }
        let payload = enc.finish();
        let mut w = self.w.lock();
        write_frame(&mut *w, &payload)
    }
}

fn serve_conn(
    sock: TcpStream,
    store: &Arc<MemStore>,
    registry: &Arc<NodeRegistry>,
    dispatcher: Option<&Arc<Dispatcher>>,
    serve: Option<&Arc<BatchServer>>,
) -> Result<()> {
    let mut reader = BufReader::new(sock.try_clone()?);
    let writer =
        Arc::new(ConnWriter { w: OrderedMutex::new(LockRank::ConnWriter, BufWriter::new(sock)) });

    // --- handshake: the first frame must be HELLO --------------------------
    let first = match read_frame(&mut reader, MAX_FRAME) {
        Ok(f) => f,
        Err(_) => return Ok(()), // client closed before speaking
    };
    let mut d = Dec::new(&first);
    let (req_id, opcode) = d.header()?;
    if opcode != op::HELLO {
        writer.reply(
            req_id,
            Err(anyhow::anyhow!(
                "protocol v{PROTOCOL_VERSION}: first frame must be HELLO, got opcode {opcode:#x}"
            )),
        )?;
        return Ok(());
    }
    let version = d.u8()?;
    if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&version) {
        writer.reply(
            req_id,
            Err(anyhow::anyhow!(
                "protocol version mismatch: server speaks \
                 v{MIN_PROTOCOL_VERSION}..v{PROTOCOL_VERSION}, client sent v{version}"
            )),
        )?;
        return Ok(());
    }
    let role = d.u8()?;
    let requested = d.u32()?;
    let name = d.str()?;
    let node_id = if role == ROLE_WORKER {
        let requested = (requested != u32::MAX).then_some(requested);
        match registry.register(requested, &name) {
            Ok(id) => id,
            Err(e) => {
                writer.reply(req_id, Err(e))?;
                return Ok(());
            }
        }
    } else {
        u32::MAX
    };
    if node_id != u32::MAX {
        if let Some(d) = dispatcher {
            d.worker_joined(node_id, &name);
        }
    }
    // Echo the negotiated version (the client's, which we just range-
    // checked — `min(client, server)` since ours is the upper bound).
    let mut e = Enc::new();
    e.u8(version);
    e.u32(node_id);
    let result = writer.reply(req_id, Ok(e.finish())).and_then(|()| {
        conn_loop(&mut reader, &writer, store, registry, dispatcher, serve, node_id)
    });
    // A worker that drops before DONE is deregistered so a restarted
    // process can reclaim its node id; finished workers stay counted.
    // Its outstanding task leases (if any) go back to the dispatcher's
    // ready queue, and the registry records which cells were orphaned so
    // a lease-expiry error can name them.
    if node_id != u32::MAX {
        let cells = dispatcher.map(|d| d.worker_left(node_id)).unwrap_or_default();
        registry.disconnect_with_tasks(node_id, cells);
    }
    result
}

/// Post-handshake request loop of one connection.
fn conn_loop(
    reader: &mut BufReader<TcpStream>,
    writer: &Arc<ConnWriter>,
    store: &Arc<MemStore>,
    registry: &Arc<NodeRegistry>,
    dispatcher: Option<&Arc<Dispatcher>>,
    serve: Option<&Arc<BatchServer>>,
    conn_node: u32,
) -> Result<()> {
    loop {
        let frame = match read_frame(reader, MAX_FRAME) {
            Ok(f) => f,
            Err(_) => return Ok(()), // client closed
        };
        let mut d = Dec::new(&frame);
        let (req_id, opcode) = d.header()?;
        match opcode {
            // Blocking ops: answer inline when the value is already
            // there (the steady-state pipeline case — no thread spawn on
            // the hot path); otherwise park a dedicated thread on the
            // store/registry Condvar and reply whenever the publish
            // lands. The request loop keeps draining frames meanwhile
            // (multiplexing).
            op::WAIT_LAYER => {
                let layer = d.u32()? as usize;
                let chapter = d.u32()?;
                let timeout = Duration::from_millis(d.u64()?);
                if let Some(p) = store.try_layer(layer, chapter) {
                    let mut e = Enc::new();
                    e.layer_params(&p);
                    writer.reply(req_id, Ok(e.finish()))?;
                    continue;
                }
                let (store, writer) = (store.clone(), writer.clone());
                std::thread::Builder::new().name("pff-wait-layer".into()).spawn(move || {
                    let res = store.get_layer(layer, chapter, timeout).map(|p| {
                        let mut e = Enc::new();
                        e.layer_params(&p);
                        e.finish()
                    });
                    let _ = writer.reply(req_id, res);
                })?;
            }
            op::WAIT_HEAD => {
                let chapter = d.u32()?;
                let timeout = Duration::from_millis(d.u64()?);
                if let Some(p) = store.try_head(chapter) {
                    let mut e = Enc::new();
                    e.head_params(&p);
                    writer.reply(req_id, Ok(e.finish()))?;
                    continue;
                }
                let (store, writer) = (store.clone(), writer.clone());
                std::thread::Builder::new().name("pff-wait-head".into()).spawn(move || {
                    let res = store.get_head(chapter, timeout).map(|p| {
                        let mut e = Enc::new();
                        e.head_params(&p);
                        e.finish()
                    });
                    let _ = writer.reply(req_id, res);
                })?;
            }
            op::WAIT_NEG => {
                let chapter = d.u32()?;
                let timeout = Duration::from_millis(d.u64()?);
                if let Some(v) = store.try_neg(chapter) {
                    let mut e = Enc::new();
                    e.bytes(&v);
                    writer.reply(req_id, Ok(e.finish()))?;
                    continue;
                }
                let (store, writer) = (store.clone(), writer.clone());
                std::thread::Builder::new().name("pff-wait-neg".into()).spawn(move || {
                    let res = store.get_neg(chapter, timeout).map(|v| {
                        let mut e = Enc::new();
                        e.bytes(&v);
                        e.finish()
                    });
                    let _ = writer.reply(req_id, res);
                })?;
            }
            op::WAIT_NODES => {
                let n = d.u32()? as usize;
                let timeout = Duration::from_millis(d.u64()?);
                let nodes = registry.workers();
                if nodes.len() >= n {
                    writer.reply(req_id, Ok(encode_nodes(&nodes)))?;
                    continue;
                }
                let (registry, writer) = (registry.clone(), writer.clone());
                std::thread::Builder::new().name("pff-wait-nodes".into()).spawn(move || {
                    let res =
                        registry.wait_for_workers(n, timeout).map(|nodes| encode_nodes(&nodes));
                    let _ = writer.reply(req_id, res);
                })?;
            }
            op::TASK_NEXT => {
                let timeout = Duration::from_millis(d.u64()?);
                if conn_node == u32::MAX {
                    writer.reply(
                        req_id,
                        Err(anyhow::anyhow!(
                            "TASK_NEXT from a connection that did not register as a worker"
                        )),
                    )?;
                    continue;
                }
                let Some(disp) = dispatcher else {
                    writer.reply(
                        req_id,
                        Err(anyhow::anyhow!(
                            "TASK_NEXT: this leader does not run a task dispatcher"
                        )),
                    )?;
                    continue;
                };
                // Same inline-try + parked-thread split as WAIT_LAYER: a
                // ready (or finished) graph answers on the hot path, an
                // empty ready queue parks off-loop so the connection keeps
                // multiplexing store traffic while the worker waits.
                match disp.poll_task(conn_node) {
                    Ok(Poll::Task(t)) => {
                        writer.reply(req_id, Ok(encode_task(Some(&t))))?;
                    }
                    Ok(Poll::Complete) => {
                        writer.reply(req_id, Ok(encode_task(None)))?;
                    }
                    Ok(Poll::Pending) => {
                        let (disp, writer) = (disp.clone(), writer.clone());
                        std::thread::Builder::new().name("pff-wait-task".into()).spawn(
                            move || {
                                let res = disp.next_task(conn_node, timeout);
                                let leased = match &res {
                                    Ok(Some(t)) => Some(t.id),
                                    _ => None,
                                };
                                let sent =
                                    writer.reply(req_id, res.map(|t| encode_task(t.as_ref())));
                                // The grant never reached the worker (client
                                // gone mid-write): put the task back so it
                                // isn't stuck Leased until the read loop
                                // notices the drop.
                                if let (Err(_), Some(id)) = (sent, leased) {
                                    disp.release(conn_node, id);
                                }
                            },
                        )?;
                    }
                    Err(e) => writer.reply(req_id, Err(e))?,
                }
            }
            op::TASK_DONE => {
                let id = d.u64()? as usize;
                let loss = f32::from_bits(d.u32()?);
                let busy_s = f64::from_bits(d.u64()?);
                let wait_s = f64::from_bits(d.u64()?);
                let res = if conn_node == u32::MAX {
                    Err(anyhow::anyhow!(
                        "TASK_DONE from a connection that did not register as a worker"
                    ))
                } else if let Some(disp) = dispatcher {
                    disp.complete(conn_node, id, loss, busy_s, wait_s).map(|()| Vec::new())
                } else {
                    Err(anyhow::anyhow!("TASK_DONE: this leader does not run a task dispatcher"))
                };
                writer.reply(req_id, res)?;
            }
            // Classify ops complete from the serve batcher's callback —
            // like WAIT_* replies they may land out of request order, but
            // a parked request costs a queue slot, not a thread.
            op::CLASSIFY | op::CLASSIFY_BATCH => {
                let Some(srv) = serve else {
                    writer.reply(
                        req_id,
                        Err(anyhow::anyhow!(
                            "this server does not run a classify engine \
                             (start one with `pff serve`)"
                        )),
                    )?;
                    continue;
                };
                let single = opcode == op::CLASSIFY;
                let x = if single {
                    let features = d.f32s()?;
                    Matrix { rows: 1, cols: features.len(), data: features }
                } else {
                    d.matrix()?
                };
                let reply_writer = writer.clone();
                let admitted = srv.submit(x, move |labels| {
                    let res = labels.map(|labels| {
                        let mut e = Enc::new();
                        if single {
                            e.u8(labels[0]);
                        } else {
                            e.bytes(&labels);
                        }
                        e.finish()
                    });
                    let _ = reply_writer.reply(req_id, res);
                });
                // Rejected at admission (closed queue / bad width): the
                // callback never fires, so reply inline.
                if let Err(e) = admitted {
                    writer.reply(req_id, Err(e))?;
                }
            }
            _ => {
                let res = handle_immediate(opcode, &mut d, store, registry, conn_node);
                writer.reply(req_id, res)?;
            }
        }
    }
}

/// `TASK_NEXT` response body: flag byte 1 + task fields, or 0 when the
/// graph has fully drained (the worker should send `DONE` and exit).
fn encode_task(task: Option<&Task>) -> Vec<u8> {
    let mut e = Enc::new();
    match task {
        Some(t) => {
            e.u8(1);
            e.u64(t.id as u64);
            e.u32(t.chapter);
            e.u32(t.layer as u32);
            e.u32(t.home as u32);
        }
        None => e.u8(0),
    }
    e.finish()
}

fn encode_nodes(nodes: &[NodeInfo]) -> Vec<u8> {
    let mut e = Enc::new();
    e.u32(nodes.len() as u32);
    for n in nodes {
        e.u32(n.id);
        e.str(&n.name);
    }
    e.finish()
}

fn decode_nodes(body: &[u8]) -> Result<Vec<NodeInfo>> {
    let mut d = Dec::new(body);
    let n = d.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(NodeInfo { id: d.u32()?, name: d.str()? });
    }
    Ok(out)
}

/// Handle an op that never parks: state lookups, publishes, registry
/// queries. Runs inline on the connection's request loop. `conn_node` is
/// the node id this connection registered in `HELLO` (`u32::MAX` for
/// plain clients) — `DONE` is only accepted for the connection's own id.
fn handle_immediate(
    opcode: u8,
    d: &mut Dec<'_>,
    store: &MemStore,
    registry: &NodeRegistry,
    conn_node: u32,
) -> Result<Vec<u8>> {
    let mut e = Enc::new();
    match opcode {
        op::PUT_LAYER => {
            let layer = d.u32()? as usize;
            let chapter = d.u32()?;
            let params = d.layer_params()?;
            store.put_layer(layer, chapter, params)?;
        }
        op::PUT_LAYER_DELTA => {
            let layer = d.u32()? as usize;
            let chapter = d.u32()?;
            let base_chapter = d.u32()?;
            let delta = d.layer_delta()?;
            store.put_layer_delta(layer, chapter, base_chapter, delta)?;
        }
        op::PUT_LAYER_Q => {
            let layer = d.u32()? as usize;
            let chapter = d.u32()?;
            let q = d.quant_layer_params()?;
            // The server-side dequantize of the client's q bits — the
            // same computation an in-proc store's put_layer_q default
            // runs, so both transports store identical bytes.
            store.put_layer_q(layer, chapter, q)?;
        }
        op::PUT_HEAD_Q => {
            let chapter = d.u32()?;
            let q = d.quant_head_params()?;
            store.put_head_q(chapter, q)?;
        }
        op::GET_LAYER => {
            let layer = d.u32()? as usize;
            let chapter = d.u32()?;
            match store.try_layer(layer, chapter) {
                None => e.u8(0),
                Some(p) => {
                    e.u8(1);
                    e.layer_params(&p);
                }
            }
        }
        op::PUT_HEAD => {
            let chapter = d.u32()?;
            let params = d.head_params()?;
            store.put_head(chapter, params)?;
        }
        op::GET_HEAD => {
            let chapter = d.u32()?;
            match store.try_head(chapter) {
                None => e.u8(0),
                Some(p) => {
                    e.u8(1);
                    e.head_params(&p);
                }
            }
        }
        op::PUT_NEG => {
            let chapter = d.u32()?;
            let labels = d.bytes()?;
            store.put_neg(chapter, labels)?;
        }
        op::GET_NEG => {
            let chapter = d.u32()?;
            match store.try_neg(chapter) {
                None => e.u8(0),
                Some(v) => {
                    e.u8(1);
                    e.bytes(&v);
                }
            }
        }
        op::LATEST_LAYER => {
            let layer = d.u32()? as usize;
            match store.latest_layer(layer)? {
                None => e.u8(0),
                Some((c, p)) => {
                    e.u8(1);
                    e.u32(c);
                    e.layer_params(&p);
                }
            }
        }
        op::LATEST_HEAD => match store.latest_head()? {
            None => e.u8(0),
            Some((c, p)) => {
                e.u8(1);
                e.u32(c);
                e.head_params(&p);
            }
        },
        op::STATS => {
            let s = store.comm_stats();
            e.u64(s.puts);
            e.u64(s.gets);
            e.u64(s.bytes_put);
            e.u64(s.bytes_get);
        }
        // Presence probes: one boolean on the wire, no payload. Replacement
        // workers fast-forward past already-published chapters with these
        // instead of re-downloading every layer (crash recovery).
        op::HAS_LAYER => {
            let layer = d.u32()? as usize;
            let chapter = d.u32()?;
            e.u8(u8::from(store.has_layer(layer, chapter)?));
        }
        op::HAS_HEAD => {
            let chapter = d.u32()?;
            e.u8(u8::from(store.has_head(chapter)?));
        }
        op::HAS_NEG => {
            let chapter = d.u32()?;
            e.u8(u8::from(store.has_neg(chapter)?));
        }
        op::LIST_NODES => return Ok(encode_nodes(&registry.workers())),
        op::DONE => {
            let id = d.u32()?;
            if conn_node == u32::MAX {
                bail!("DONE from a connection that did not register as a worker");
            }
            if id != conn_node {
                bail!("DONE for node {id} from a connection registered as node {conn_node}");
            }
            registry.mark_done(id)?;
        }
        other => bail!("unknown opcode {other:#x} (protocol v{PROTOCOL_VERSION})"),
    }
    Ok(e.finish())
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// A response payload with its 9-byte `(req_id, status)` header still in
/// place — slicing on access avoids a second multi-MB copy of layer
/// bodies on the client hot path.
struct Resp(Vec<u8>);

impl Resp {
    fn body(&self) -> &[u8] {
        &self.0[9..]
    }
}

/// Pending-response routing table: req_id → the caller's reply channel.
/// Ranked innermost ([`LockRank::ConnPending`]): it is taken while the
/// writer lock (error unwind) or the dead flag (post-write race check)
/// is still held.
type PendingMap = OrderedMutex<HashMap<u64, mpsc::Sender<Result<Resp, String>>>>;

struct ClientShared {
    sock: TcpStream,
    writer: OrderedMutex<BufWriter<TcpStream>>,
    pending: PendingMap,
    next_id: AtomicU64,
    /// Set by the demux thread when the connection dies; the reason every
    /// subsequent call fails with.
    dead: OrderedMutex<Option<String>>,
}

impl ClientShared {
    /// Issue one request and block for its (possibly out-of-order)
    /// response. `wait_timeout` is Some for `WAIT_*` ops — the server owns
    /// that deadline; the client only adds grace on top.
    fn request(
        &self,
        opcode: u8,
        wait_timeout: Option<Duration>,
        build: impl FnOnce(&mut Enc),
    ) -> Result<Resp> {
        if let Some(reason) = self.dead.lock().clone() {
            bail!("store connection is down: {reason}");
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut e = Enc::new();
        e.req_header(id, opcode);
        build(&mut e);
        let payload = e.finish();
        let (tx, rx) = mpsc::channel();
        self.pending.lock().insert(id, tx);
        {
            let mut w = self.writer.lock();
            if let Err(err) = write_frame(&mut *w, &payload) {
                self.pending.lock().remove(&id);
                return Err(err).context("writing request frame");
            }
        }
        // Close the race with fail_all: if the connection died between the
        // dead-check above and the pending insert, nobody drained our
        // entry — detect it now instead of stalling out the full deadline.
        if let Some(reason) = self.dead.lock().clone() {
            if self.pending.lock().remove(&id).is_some() {
                bail!("store connection is down: {reason}");
            }
            // else: fail_all drained us; the channel already holds the error.
        }
        let deadline = wait_timeout.map_or(RPC_TIMEOUT, |t| t + WAIT_GRACE);
        match rx.recv_timeout(deadline) {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(msg)) => bail!("{msg}"),
            Err(_) => {
                self.pending.lock().remove(&id);
                bail!("store server did not reply within {deadline:?} (opcode {opcode:#x})");
            }
        }
    }
}

/// Demultiplex response frames to their waiting callers by req_id. Runs on
/// a dedicated thread for the lifetime of the connection; on connection
/// loss it fails every in-flight call with the reason.
fn demux_loop(shared: &ClientShared) {
    let mut reader = match shared.sock.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(e) => {
            fail_all(shared, format!("cloning socket: {e}"));
            return;
        }
    };
    loop {
        let frame = match read_frame(&mut reader, MAX_FRAME) {
            Ok(f) => f,
            Err(e) => {
                fail_all(shared, format!("connection lost: {e:#}"));
                return;
            }
        };
        if frame.len() < 9 {
            fail_all(shared, "malformed response frame (short header)".into());
            return;
        }
        let req_id = u64::from_le_bytes(frame[0..8].try_into().expect("length checked above"));
        let status = frame[8];
        let res = if status == ST_OK {
            Ok(Resp(frame))
        } else {
            match Dec::new(&frame[9..]).str() {
                Ok(msg) => Err(format!("store server error: {msg}")),
                Err(_) => Err("store server error (malformed error frame)".into()),
            }
        };
        // Unknown req_id = response to a call that already timed out
        // client-side; drop it.
        if let Some(tx) = shared.pending.lock().remove(&req_id) {
            let _ = tx.send(res);
        }
    }
}

fn fail_all(shared: &ClientShared, reason: String) {
    *shared.dead.lock() = Some(reason.clone());
    for (_, tx) in shared.pending.lock().drain() {
        let _ = tx.send(Err(reason.clone()));
    }
}

/// [`ParamStore`] client over TCP, protocol v4 (v2/v3 negotiated down).
///
/// One connection carries any number of concurrent in-flight requests
/// (requests are tagged with a `u64 req_id`; a demux thread routes the
/// responses), so the client is freely shareable across threads — a node
/// can publish while another of its threads is parked on a dependency.
pub struct TcpStoreClient {
    shared: Arc<ClientShared>,
    node_id: u32,
    /// Version settled in `HELLO`; gates version-dependent ops (v3 delta
    /// publish, v4 quantized publish).
    proto: u8,
    demux: Option<std::thread::JoinHandle<()>>,
}

impl TcpStoreClient {
    /// Connect to a [`StoreServer`] as a plain store client.
    pub fn connect(addr: SocketAddr) -> Result<TcpStoreClient> {
        TcpStoreClient::connect_as(addr, Role::Client, None, "client")
    }

    /// Connect and register as a cluster worker. `requested = Some(id)`
    /// claims a specific node index; `None` lets the leader assign one.
    pub fn connect_worker(
        addr: SocketAddr,
        requested: Option<u32>,
        name: &str,
    ) -> Result<TcpStoreClient> {
        TcpStoreClient::connect_as(addr, Role::Worker, requested, name)
    }

    /// [`TcpStoreClient::connect_worker`] with startup retry: worker
    /// processes are typically launched alongside the leader, so refused
    /// connections are retried with backoff until `wait` elapses. (This is
    /// connection *establishment* only — dependency waiting is always
    /// server-side, never a retry loop.)
    pub fn connect_worker_retry(
        addr: SocketAddr,
        requested: Option<u32>,
        name: &str,
        wait: Duration,
    ) -> Result<TcpStoreClient> {
        let deadline = Instant::now() + wait;
        let mut delay = Duration::from_millis(10);
        // Retry only connection establishment. HELLO rejections (taken
        // node id, version mismatch) are deterministic — surface them
        // immediately instead of hammering the leader until the deadline.
        let sock = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() + delay >= deadline {
                        return Err(e)
                            .with_context(|| format!("leader at {addr} unreachable for {wait:?}"));
                    }
                    // pff-allow(no-sleep-sync): connection-establishment
                    // backoff against a leader that has not bound its
                    // listener yet — there is no event to park on across
                    // processes; dependency waits stay server-side.
                    std::thread::sleep(delay);
                    delay = (delay * 2).min(Duration::from_millis(500));
                }
            }
        };
        TcpStoreClient::from_stream(sock, Role::Worker, requested, name)
    }

    fn connect_as(
        addr: SocketAddr,
        role: Role,
        requested: Option<u32>,
        name: &str,
    ) -> Result<TcpStoreClient> {
        let sock = TcpStream::connect(addr).context("connecting to store server")?;
        TcpStoreClient::from_stream(sock, role, requested, name)
    }

    /// Handshake an already-established connection.
    fn from_stream(
        sock: TcpStream,
        role: Role,
        requested: Option<u32>,
        name: &str,
    ) -> Result<TcpStoreClient> {
        sock.set_nodelay(true).ok();
        let shared = Arc::new(ClientShared {
            sock: sock.try_clone()?,
            writer: OrderedMutex::new(LockRank::ConnWriter, BufWriter::new(sock)),
            pending: OrderedMutex::new(LockRank::ConnPending, HashMap::new()),
            next_id: AtomicU64::new(0),
            dead: OrderedMutex::new(LockRank::ConnDead, None),
        });
        let s2 = shared.clone();
        let demux = std::thread::Builder::new()
            .name("pff-client-demux".into())
            .spawn(move || demux_loop(&s2))?;

        let hello = shared.request(op::HELLO, None, |e| {
            e.u8(PROTOCOL_VERSION);
            e.u8(match role {
                Role::Client => ROLE_CLIENT,
                Role::Worker => ROLE_WORKER,
            });
            e.u32(requested.unwrap_or(u32::MAX));
            e.str(name);
        });
        let handshake = hello.and_then(|body| {
            let mut d = Dec::new(body.body());
            let version = d.u8()?;
            if !(MIN_PROTOCOL_VERSION..=PROTOCOL_VERSION).contains(&version) {
                bail!(
                    "server replied with protocol v{version}, expected \
                     v{MIN_PROTOCOL_VERSION}..v{PROTOCOL_VERSION}"
                );
            }
            Ok((version, d.u32()?))
        });
        match handshake {
            Ok((proto, node_id)) => {
                Ok(TcpStoreClient { shared, node_id, proto, demux: Some(demux) })
            }
            Err(e) => {
                // Unwind the half-open connection so the demux thread exits.
                let _ = shared.sock.shutdown(Shutdown::Both);
                let _ = demux.join();
                Err(e).context("HELLO handshake failed")
            }
        }
    }

    /// The node id the leader assigned in `HELLO` (workers only).
    pub fn node_id(&self) -> Option<u32> {
        (self.node_id != u32::MAX).then_some(self.node_id)
    }

    /// The protocol version settled in `HELLO`.
    pub fn protocol_version(&self) -> u8 {
        self.proto
    }

    /// Non-blocking fetch of `(layer, chapter)` — `None` when not yet
    /// published (the blocking variant is [`ParamStore::get_layer`]).
    pub fn get_layer_now(&self, layer: usize, chapter: u32) -> Result<Option<LayerParams>> {
        let body = self.shared.request(op::GET_LAYER, None, |e| {
            e.u32(layer as u32);
            e.u32(chapter);
        })?;
        let mut d = Dec::new(body.body());
        if d.u8()? == 0 {
            return Ok(None);
        }
        Ok(Some(d.layer_params()?))
    }

    /// Registered workers, as the leader currently sees them.
    pub fn list_nodes(&self) -> Result<Vec<NodeInfo>> {
        decode_nodes(self.shared.request(op::LIST_NODES, None, |_| {})?.body())
    }

    /// Park (server-side) until `n` workers have registered.
    pub fn wait_nodes(&self, n: usize, timeout: Duration) -> Result<Vec<NodeInfo>> {
        let body = self.shared.request(op::WAIT_NODES, Some(timeout), |e| {
            e.u32(n as u32);
            e.u64(timeout.as_millis() as u64);
        })?;
        decode_nodes(body.body())
    }

    /// Report this worker's chapters finished (workers only).
    pub fn done(&self) -> Result<()> {
        let id = self
            .node_id()
            .context("done(): this connection did not register as a worker")?;
        self.shared.request(op::DONE, None, |e| e.u32(id)).map(|_| ())
    }

    /// Lease the next ready task from the leader's dispatcher, parking
    /// server-side up to `timeout`. `Ok(None)` means the graph drained —
    /// the worker should send [`TcpStoreClient::done`] and exit.
    pub fn next_task(&self, timeout: Duration) -> Result<Option<Task>> {
        let body = self
            .shared
            .request(op::TASK_NEXT, Some(timeout), |e| e.u64(timeout.as_millis() as u64))?;
        let mut d = Dec::new(body.body());
        if d.u8()? == 0 {
            return Ok(None);
        }
        Ok(Some(Task {
            id: d.u64()? as usize,
            chapter: d.u32()?,
            layer: d.u32()? as usize,
            home: d.u32()? as usize,
        }))
    }

    /// Release a task lease with its result metrics. Floats cross the
    /// wire as raw bits so the leader's records match the worker's
    /// bitwise.
    pub fn task_done(&self, id: u64, loss: f32, busy_s: f64, wait_s: f64) -> Result<()> {
        self.shared
            .request(op::TASK_DONE, None, |e| {
                e.u64(id);
                e.u32(loss.to_bits());
                e.u64(busy_s.to_bits());
                e.u64(wait_s.to_bits());
            })
            .map(|_| ())
    }

    /// Score one feature row on a serving peer (`pff serve`) and return
    /// its predicted label. The reply may arrive out of request order —
    /// the connection keeps multiplexing while the row sits in the
    /// server's batching queue.
    pub fn classify(&self, features: &[f32]) -> Result<u8> {
        if self.proto < 4 {
            bail!("CLASSIFY needs protocol v4, but HELLO settled on v{}", self.proto);
        }
        let body = self.shared.request(op::CLASSIFY, None, |e| e.f32s(features))?;
        Dec::new(body.body()).u8()
    }

    /// Score a feature matrix (one prediction per row) on a serving peer.
    /// Labels come back in row order, bitwise what offline eval computes
    /// for the same rows.
    pub fn classify_batch(&self, x: &Matrix) -> Result<Vec<u8>> {
        if self.proto < 4 {
            bail!("CLASSIFY_BATCH needs protocol v4, but HELLO settled on v{}", self.proto);
        }
        let body = self.shared.request(op::CLASSIFY_BATCH, None, |e| e.matrix(x))?;
        Dec::new(body.body()).bytes()
    }
}

impl Drop for TcpStoreClient {
    fn drop(&mut self) {
        let _ = self.shared.sock.shutdown(Shutdown::Both);
        if let Some(t) = self.demux.take() {
            let _ = t.join();
        }
    }
}

impl ParamStore for TcpStoreClient {
    fn put_layer(&self, layer: usize, chapter: u32, params: LayerParams) -> Result<()> {
        self.shared
            .request(op::PUT_LAYER, None, |e| {
                e.u32(layer as u32);
                e.u32(chapter);
                e.layer_params(&params);
            })
            .map(|_| ())
    }

    fn put_layer_delta(
        &self,
        layer: usize,
        chapter: u32,
        base_chapter: u32,
        delta: LayerDelta,
    ) -> Result<()> {
        if self.proto < 3 {
            bail!("delta publish needs protocol v3, but HELLO settled on v{}", self.proto);
        }
        self.shared
            .request(op::PUT_LAYER_DELTA, None, |e| {
                e.u32(layer as u32);
                e.u32(chapter);
                e.u32(base_chapter);
                e.layer_delta(&delta);
            })
            .map(|_| ())
    }

    fn supports_deltas(&self) -> bool {
        self.proto >= 3
    }

    fn put_layer_q(&self, layer: usize, chapter: u32, q: QuantLayerParams) -> Result<()> {
        if self.proto < 4 {
            // v2/v3 peer: ship the rounded params as a plain f32 full
            // frame — the exact bits a v4 server would store from `q`.
            return self.put_layer(layer, chapter, q.dequantize());
        }
        self.shared
            .request(op::PUT_LAYER_Q, None, |e| {
                e.u32(layer as u32);
                e.u32(chapter);
                e.quant_layer_params(&q);
            })
            .map(|_| ())
    }

    fn put_head_q(&self, chapter: u32, q: QuantHeadParams) -> Result<()> {
        if self.proto < 4 {
            return self.put_head(chapter, q.dequantize());
        }
        self.shared
            .request(op::PUT_HEAD_Q, None, |e| {
                e.u32(chapter);
                e.quant_head_params(&q);
            })
            .map(|_| ())
    }

    fn get_layer(&self, layer: usize, chapter: u32, timeout: Duration) -> Result<Arc<LayerParams>> {
        let body = self.shared.request(op::WAIT_LAYER, Some(timeout), |e| {
            e.u32(layer as u32);
            e.u32(chapter);
            e.u64(timeout.as_millis() as u64);
        })?;
        Ok(Arc::new(Dec::new(body.body()).layer_params()?))
    }

    fn put_head(&self, chapter: u32, params: HeadParams) -> Result<()> {
        self.shared
            .request(op::PUT_HEAD, None, |e| {
                e.u32(chapter);
                e.head_params(&params);
            })
            .map(|_| ())
    }

    fn get_head(&self, chapter: u32, timeout: Duration) -> Result<Arc<HeadParams>> {
        let body = self.shared.request(op::WAIT_HEAD, Some(timeout), |e| {
            e.u32(chapter);
            e.u64(timeout.as_millis() as u64);
        })?;
        Ok(Arc::new(Dec::new(body.body()).head_params()?))
    }

    fn put_neg(&self, chapter: u32, labels: Vec<u8>) -> Result<()> {
        self.shared
            .request(op::PUT_NEG, None, |e| {
                e.u32(chapter);
                e.bytes(&labels);
            })
            .map(|_| ())
    }

    fn get_neg(&self, chapter: u32, timeout: Duration) -> Result<Vec<u8>> {
        let body = self.shared.request(op::WAIT_NEG, Some(timeout), |e| {
            e.u32(chapter);
            e.u64(timeout.as_millis() as u64);
        })?;
        Dec::new(body.body()).bytes()
    }

    fn latest_layer(&self, layer: usize) -> Result<Option<(u32, Arc<LayerParams>)>> {
        let body = self.shared.request(op::LATEST_LAYER, None, |e| e.u32(layer as u32))?;
        let mut d = Dec::new(body.body());
        if d.u8()? == 0 {
            return Ok(None);
        }
        Ok(Some((d.u32()?, Arc::new(d.layer_params()?))))
    }

    fn latest_head(&self) -> Result<Option<(u32, Arc<HeadParams>)>> {
        let body = self.shared.request(op::LATEST_HEAD, None, |_| {})?;
        let mut d = Dec::new(body.body());
        if d.u8()? == 0 {
            return Ok(None);
        }
        Ok(Some((d.u32()?, Arc::new(d.head_params()?))))
    }

    fn has_layer(&self, layer: usize, chapter: u32) -> Result<bool> {
        let body = self.shared.request(op::HAS_LAYER, None, |e| {
            e.u32(layer as u32);
            e.u32(chapter);
        })?;
        Ok(Dec::new(body.body()).u8()? != 0)
    }

    fn has_head(&self, chapter: u32) -> Result<bool> {
        let body = self.shared.request(op::HAS_HEAD, None, |e| e.u32(chapter))?;
        Ok(Dec::new(body.body()).u8()? != 0)
    }

    fn has_neg(&self, chapter: u32) -> Result<bool> {
        let body = self.shared.request(op::HAS_NEG, None, |e| e.u32(chapter))?;
        Ok(Dec::new(body.body()).u8()? != 0)
    }

    fn comm_stats(&self) -> CommStats {
        match self.shared.request(op::STATS, None, |_| {}) {
            Ok(body) => {
                let mut d = Dec::new(body.body());
                CommStats {
                    puts: d.u64().unwrap_or(0),
                    gets: d.u64().unwrap_or(0),
                    bytes_put: d.u64().unwrap_or(0),
                    bytes_get: d.u64().unwrap_or(0),
                }
            }
            Err(_) => CommStats::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Matrix, Rng};

    fn params() -> LayerParams {
        let mut rng = Rng::new(5);
        LayerParams {
            w: Matrix::randn_scaled(6, 4, &mut rng),
            b: vec![1.0; 4],
            normalize_input: true,
            opt: None,
        }
    }

    #[test]
    fn tcp_roundtrip_layer_and_neg() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store, 0).unwrap();
        let client = TcpStoreClient::connect(server.addr).unwrap();

        let p = params();
        client.put_layer(2, 7, p.clone()).unwrap();
        let got = client.get_layer(2, 7, Duration::from_millis(100)).unwrap();
        assert_eq!(got.w, p.w);

        client.put_neg(1, vec![4, 5, 6]).unwrap();
        assert_eq!(client.get_neg(1, Duration::from_millis(100)).unwrap(), vec![4, 5, 6]);

        let (c, lp) = client.latest_layer(2).unwrap().unwrap();
        assert_eq!(c, 7);
        assert_eq!(lp.b, vec![1.0; 4]);
        assert!(client.latest_layer(9).unwrap().is_none());

        // non-blocking probe
        assert!(client.get_layer_now(2, 7).unwrap().is_some());
        assert!(client.get_layer_now(2, 8).unwrap().is_none());

        let stats = client.comm_stats();
        assert!(stats.puts >= 2);
        server.shutdown();
    }

    #[test]
    fn blocking_get_across_the_wire() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store.clone(), 0).unwrap();
        let addr = server.addr;

        let waiter = std::thread::spawn(move || {
            let client = TcpStoreClient::connect(addr).unwrap();
            client.get_layer(0, 0, Duration::from_secs(5))
        });
        // Condvar handoff: the server-side wait thread parks on the
        // MemStore before we publish — no timing guesswork.
        store.wait_for_waiters(1, Duration::from_secs(5)).unwrap();
        let publisher = TcpStoreClient::connect(addr).unwrap();
        publisher.put_layer(0, 0, params()).unwrap();
        let got = waiter.join().unwrap().unwrap();
        assert_eq!(got.w.rows, 6);
        server.shutdown();
    }

    #[test]
    fn multiplexed_connection_has_no_head_of_line_blocking() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store.clone(), 0).unwrap();
        let client = Arc::new(TcpStoreClient::connect(server.addr).unwrap());

        // Park a blocking wait on the shared connection...
        let c2 = client.clone();
        let waiter = std::thread::spawn(move || c2.get_layer(3, 9, Duration::from_secs(5)));
        store.wait_for_waiters(1, Duration::from_secs(5)).unwrap();

        // ...and keep using the SAME connection while it is parked.
        client.put_neg(0, vec![1, 2]).unwrap();
        assert_eq!(client.get_neg(0, Duration::from_millis(100)).unwrap(), vec![1, 2]);
        assert!(client.get_layer_now(3, 9).unwrap().is_none());

        // Publishing through the same connection unblocks the wait.
        client.put_layer(3, 9, params()).unwrap();
        let got = waiter.join().unwrap().unwrap();
        assert_eq!(got.w.rows, 6);
        server.shutdown();
    }

    #[test]
    fn has_probes_answer_across_the_wire() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store, 0).unwrap();
        let client = TcpStoreClient::connect(server.addr).unwrap();
        assert!(!client.has_layer(0, 0).unwrap());
        assert!(!client.has_head(0).unwrap());
        assert!(!client.has_neg(0).unwrap());
        client.put_layer(0, 0, params()).unwrap();
        client.put_neg(4, vec![1]).unwrap();
        assert!(client.has_layer(0, 0).unwrap());
        assert!(!client.has_layer(1, 0).unwrap());
        assert!(client.has_neg(4).unwrap());
        // probes ship no parameter payload — gets stay untouched
        assert_eq!(client.comm_stats().gets, 0);
        server.shutdown();
    }

    #[test]
    fn server_error_propagates() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store, 0).unwrap();
        let client = TcpStoreClient::connect(server.addr).unwrap();
        let err = client.get_neg(99, Duration::from_millis(20)).unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
        server.shutdown();
    }

    #[test]
    fn worker_handshake_assigns_and_rejects_ids() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store, 0).unwrap();
        let w0 = TcpStoreClient::connect_worker(server.addr, None, "alpha").unwrap();
        let w1 = TcpStoreClient::connect_worker(server.addr, None, "beta").unwrap();
        assert_eq!(w0.node_id(), Some(0));
        assert_eq!(w1.node_id(), Some(1));
        let err = TcpStoreClient::connect_worker(server.addr, Some(1), "dup").unwrap_err();
        assert!(format!("{err:#}").contains("already registered"), "{err:#}");

        let nodes = w0.list_nodes().unwrap();
        assert_eq!(nodes.len(), 2);
        assert_eq!(nodes[1].name, "beta");

        // plain clients get no node id and cannot report DONE
        let plain = TcpStoreClient::connect(server.addr).unwrap();
        assert_eq!(plain.node_id(), None);
        assert!(plain.done().is_err());

        // DONE flows into the registry
        w0.done().unwrap();
        w1.done().unwrap();
        assert_eq!(server.registry().done_count(), 2);
        server.shutdown();
    }

    #[test]
    fn wait_nodes_parks_until_membership() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store, 0).unwrap();
        let addr = server.addr;
        let observer = TcpStoreClient::connect(addr).unwrap();
        let h = std::thread::spawn(move || observer.wait_nodes(2, Duration::from_secs(5)));
        let _w0 = TcpStoreClient::connect_worker(addr, None, "a").unwrap();
        let _w1 = TcpStoreClient::connect_worker(addr, None, "b").unwrap();
        let nodes = h.join().unwrap().unwrap();
        assert_eq!(nodes.len(), 2);
        server.shutdown();
    }

    #[test]
    fn non_hello_first_frame_is_rejected() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store, 0).unwrap();
        // Speak raw: a STATS request before HELLO must be refused.
        let sock = TcpStream::connect(server.addr).unwrap();
        let mut w = BufWriter::new(sock.try_clone().unwrap());
        let mut e = Enc::new();
        e.req_header(0, super::op::STATS);
        write_frame(&mut w, &e.finish()).unwrap();
        let mut r = BufReader::new(sock);
        let resp = read_frame(&mut r, MAX_FRAME).unwrap();
        let mut d = Dec::new(&resp);
        let (req_id, status) = d.header().unwrap();
        assert_eq!(req_id, 0);
        assert_eq!(status, ST_ERR);
        assert!(d.str().unwrap().contains("HELLO"));
        server.shutdown();
    }

    #[test]
    fn v2_client_negotiates_down() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store, 0).unwrap();
        // Speak raw v2: the server must accept and echo the OLDER version.
        let sock = TcpStream::connect(server.addr).unwrap();
        let mut w = BufWriter::new(sock.try_clone().unwrap());
        let mut e = Enc::new();
        e.req_header(3, super::op::HELLO);
        e.u8(2);
        e.u8(ROLE_CLIENT);
        e.u32(u32::MAX);
        e.str("legacy");
        write_frame(&mut w, &e.finish()).unwrap();
        let mut r = BufReader::new(sock);
        let resp = read_frame(&mut r, MAX_FRAME).unwrap();
        let mut d = Dec::new(&resp);
        let (req_id, status) = d.header().unwrap();
        assert_eq!((req_id, status), (3, ST_OK));
        assert_eq!(d.u8().unwrap(), 2, "HELLO must settle on min(client, server)");
        server.shutdown();
    }

    #[test]
    fn delta_publish_reconstructs_across_the_wire() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store, 0).unwrap();
        let client = TcpStoreClient::connect(server.addr).unwrap();
        assert!(client.supports_deltas());
        assert_eq!(client.protocol_version(), PROTOCOL_VERSION);

        let base = params();
        client.put_layer(1, 0, base.clone()).unwrap();
        let mut next = base.clone();
        next.b[2] = -3.5;
        for c in 0..next.w.cols {
            next.w.data[next.w.cols + c] += 1.0; // row 1
        }
        let delta = LayerDelta::diff(&base, &next).unwrap();
        client.put_layer_delta(1, 1, 0, delta).unwrap();
        let got = client.get_layer(1, 1, Duration::from_millis(200)).unwrap();
        assert_eq!(got.w, next.w);
        assert_eq!(got.b, next.b);

        // A delta against a base the store never saw is refused.
        let orphan = LayerDelta::diff(&base, &next).unwrap();
        let err = client.put_layer_delta(1, 5, 9, orphan).unwrap_err();
        assert!(err.to_string().contains("base chapter"), "{err}");
        server.shutdown();
    }

    #[test]
    fn quantized_publish_reconstructs_across_the_wire() {
        use crate::transport::codec::WireCodec;
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store.clone(), 0).unwrap();
        let client = TcpStoreClient::connect(server.addr).unwrap();
        assert_eq!(client.protocol_version(), PROTOCOL_VERSION);

        for codec in [WireCodec::F32, WireCodec::Bf16, WireCodec::I8] {
            let chapter = codec.tag() as u32;
            let p = params();
            let q = codec.quantize_layer(&p);
            // The canonical store value: what the publisher's local
            // dequantize of the same q bits yields.
            let rounded = q.dequantize();
            client.put_layer_q(4, chapter, q).unwrap();
            let got = client.get_layer(4, chapter, Duration::from_millis(500)).unwrap();
            assert_eq!(got.w, rounded.w, "{codec}");
            assert_eq!(got.b, rounded.b, "{codec}");

            let hp = HeadParams {
                w: Matrix::randn_scaled(4, 3, &mut Rng::new(11)),
                b: vec![0.5; 3],
                opt: None,
            };
            let hq = codec.quantize_head(&hp);
            let hr = hq.dequantize();
            client.put_head_q(chapter, hq).unwrap();
            let got = client.get_head(chapter, Duration::from_millis(500)).unwrap();
            assert_eq!(got.w, hr.w, "{codec}");
        }
        server.shutdown();
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let store = Arc::new(MemStore::new());
        let server = StoreServer::start(store, 0).unwrap();
        let sock = TcpStream::connect(server.addr).unwrap();
        let mut w = BufWriter::new(sock.try_clone().unwrap());
        let mut e = Enc::new();
        e.req_header(7, super::op::HELLO);
        e.u8(PROTOCOL_VERSION + 1); // wrong version
        e.u8(ROLE_CLIENT);
        e.u32(u32::MAX);
        e.str("time-traveler");
        write_frame(&mut w, &e.finish()).unwrap();
        let mut r = BufReader::new(sock);
        let resp = read_frame(&mut r, MAX_FRAME).unwrap();
        let mut d = Dec::new(&resp);
        let (req_id, status) = d.header().unwrap();
        assert_eq!(req_id, 7);
        assert_eq!(status, ST_ERR);
        assert!(d.str().unwrap().contains("version mismatch"));
        server.shutdown();
    }
}
